"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps the shape/dtype space (as the session guide requires);
a handful of pinned cases cover the exact configurations the models ship
with. assert_allclose against ref.py is the core correctness signal.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels.attention import (
    flash_attention,
    mxu_utilization_estimate,
    vmem_footprint_bytes,
)
from compile.kernels.linear import linear
from compile.kernels.ref import attention_ref, linear_ref

SETTINGS = dict(max_examples=20, deadline=None)


def rand(key, shape, dtype, scale=1.0):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("heads,seq,dim", [(2, 64, 32), (3, 64, 32), (6, 64, 32), (1, 32, 64)])
@pytest.mark.parametrize("causal", [True, False])
def test_attention_pinned_configs(heads, seq, dim, causal):
    key = jax.random.PRNGKey(heads * 100 + seq + dim + int(causal))
    ks = jax.random.split(key, 3)
    q, k, v = (rand(ki, (heads, seq, dim), jnp.float32) for ki in ks)
    out = flash_attention(q, k, v, causal=causal)
    ref = attention_ref(q, k, v, causal=causal)
    assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


@settings(**SETTINGS)
@given(
    heads=st.integers(1, 4),
    seq_blocks=st.integers(1, 4),
    dim=st.sampled_from([16, 32, 64]),
    causal=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_matches_ref_hypothesis(heads, seq_blocks, dim, causal, seed):
    seq = 32 * seq_blocks
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    q, k, v = (rand(ki, (heads, seq, dim), jnp.float32) for ki in ks)
    out = flash_attention(q, k, v, causal=causal)
    ref = attention_ref(q, k, v, causal=causal)
    assert_allclose(np.asarray(out), np.asarray(ref), rtol=3e-5, atol=3e-5)


@settings(**SETTINGS)
@given(
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_dtypes(dtype, seed):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    q, k, v = (rand(ki, (2, 64, 32), dtype) for ki in ks)
    out = flash_attention(q, k, v)
    ref = attention_ref(q, k, v)
    assert out.dtype == dtype
    tol = 5e-2 if dtype == jnp.bfloat16 else 3e-5
    assert_allclose(
        np.asarray(out, dtype=np.float32), np.asarray(ref, dtype=np.float32),
        rtol=tol, atol=tol,
    )


def test_attention_large_logit_stability():
    """Online softmax must survive logits that overflow a naive exp."""
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    q, k, v = (rand(ki, (2, 64, 32), jnp.float32, scale=30.0) for ki in ks)
    out = flash_attention(q, k, v)
    ref = attention_ref(q, k, v)
    assert np.isfinite(np.asarray(out)).all()
    assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-4)


def test_attention_causality():
    """Future tokens must not influence past positions."""
    key = jax.random.PRNGKey(7)
    ks = jax.random.split(key, 4)
    q, k, v = (rand(ki, (1, 64, 32), jnp.float32) for ki in ks[:3])
    out1 = flash_attention(q, k, v, causal=True)
    # Perturb the last 32 key/value rows; first 32 outputs must not move.
    k2 = k.at[:, 32:, :].add(rand(ks[3], (1, 32, 32), jnp.float32))
    v2 = v.at[:, 32:, :].add(1.0)
    out2 = flash_attention(q, k2, v2, causal=True)
    assert_allclose(np.asarray(out1[:, :32]), np.asarray(out2[:, :32]), rtol=1e-6, atol=1e-6)


def test_attention_rejects_bad_blocks():
    q = jnp.zeros((1, 48, 32), jnp.float32)
    with pytest.raises(ValueError):
        flash_attention(q, q, q, block_q=32, block_k=32)


def test_attention_uniform_when_identical_keys():
    """All-identical K rows ⇒ attention = mean of visible V rows."""
    seq, dim = 32, 32
    k = jnp.ones((1, seq, dim), jnp.float32)
    v = jnp.arange(seq, dtype=jnp.float32)[None, :, None] * jnp.ones((1, seq, dim))
    q = jnp.ones((1, seq, dim), jnp.float32)
    out = flash_attention(q, k, v, causal=True, block_q=16, block_k=16)
    expect = jnp.cumsum(jnp.arange(seq, dtype=jnp.float32)) / jnp.arange(1, seq + 1)
    assert_allclose(np.asarray(out[0, :, 0]), np.asarray(expect), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# linear
# ---------------------------------------------------------------------------

@pytest.mark.parametrize(
    "m,k,n,bm,bn,bk",
    [
        (64, 96, 512, 32, 64, 32),   # qwen3b output head
        (64, 192, 512, 32, 64, 32),  # qwen72b output head
        (8, 256, 128, 8, 64, 64),    # embedder first projection
        (32, 256, 128, 8, 64, 64),   # embedder batch=32
    ],
)
def test_linear_pinned_configs(m, k, n, bm, bn, bk):
    key = jax.random.PRNGKey(m + k + n)
    k1, k2, k3 = jax.random.split(key, 3)
    x = rand(k1, (m, k), jnp.float32)
    w = rand(k2, (k, n), jnp.float32)
    b = rand(k3, (n,), jnp.float32)
    out = linear(x, w, b, block_m=bm, block_n=bn, block_k=bk)
    assert_allclose(np.asarray(out), np.asarray(linear_ref(x, w, b)), rtol=2e-5, atol=2e-5)


@settings(**SETTINGS)
@given(
    mb=st.integers(1, 4),
    kb=st.integers(1, 4),
    nb=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_linear_matches_ref_hypothesis(mb, kb, nb, seed):
    m, k, n = 8 * mb, 64 * kb, 64 * nb
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    x = rand(k1, (m, k), jnp.float32)
    w = rand(k2, (k, n), jnp.float32)
    b = rand(k3, (n,), jnp.float32)
    out = linear(x, w, b, block_m=8, block_n=64, block_k=64)
    assert_allclose(np.asarray(out), np.asarray(linear_ref(x, w, b)), rtol=2e-5, atol=2e-5)


def test_linear_rejects_bad_dims():
    x = jnp.zeros((10, 64), jnp.float32)
    w = jnp.zeros((64, 64), jnp.float32)
    b = jnp.zeros((64,), jnp.float32)
    with pytest.raises(ValueError):
        linear(x, w, b, block_m=8, block_n=64, block_k=64)


def test_linear_zero_bias_zero_input():
    x = jnp.zeros((8, 64), jnp.float32)
    w = jnp.ones((64, 64), jnp.float32)
    b = jnp.zeros((64,), jnp.float32)
    out = linear(x, w, b, block_m=8, block_n=64, block_k=64)
    assert_allclose(np.asarray(out), np.zeros((8, 64), np.float32))


# ---------------------------------------------------------------------------
# analytic perf model sanity
# ---------------------------------------------------------------------------

def test_vmem_footprint_under_budget():
    # Default ship config must fit VMEM with lots of headroom.
    assert vmem_footprint_bytes(32, 32, 32) < 64 * 1024
    # Even an aggressive config stays under a 16 MiB/core budget.
    assert vmem_footprint_bytes(256, 256, 128) < 16 * 1024 * 1024


def test_mxu_estimate_monotone():
    assert mxu_utilization_estimate(32, 32, 32) < mxu_utilization_estimate(128, 32, 128)
    assert mxu_utilization_estimate(128, 32, 128) == 1.0
