"""AOT pipeline tests: lowering, weight dump format, manifest coherence."""

import json
import os
import struct
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def tiny_lowered():
    cfg = model.TIERS["qwen15b"]
    fn, specs = model.make_lm_fn(cfg, 1)
    return cfg, aot.to_hlo_text(jax.jit(fn).lower(*specs))


def test_hlo_text_is_text(tiny_lowered):
    _, text = tiny_lowered
    assert text.startswith("HloModule")
    assert "ENTRY" in text


def test_hlo_no_elided_constants(tiny_lowered):
    """Weights are runtime params; no multi-MB (or elided) constants."""
    _, text = tiny_lowered
    assert "constant({...})" not in text, "elided constant would not round-trip"


def test_hlo_entry_params_match_weights_plus_tokens(tiny_lowered):
    cfg, text = tiny_lowered
    n_weights = len(model.lm_weight_order(cfg))
    entry = text[text.index("ENTRY"):]
    body = entry[: entry.index("ROOT")]
    n_params = body.count(" parameter(")
    assert n_params == n_weights + 1  # weights then tokens


def test_write_weights_layout():
    arrays = [("a", np.arange(6, dtype=np.float32).reshape(2, 3)),
              ("b", np.ones(4, dtype=np.float32))]
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "w.bin")
        specs = aot.write_weights(path, arrays)
        raw = open(path, "rb").read()
    assert len(raw) == 10 * 4
    assert specs[0] == {"name": "a", "shape": [2, 3], "offset_elems": 0, "num_elems": 6}
    assert specs[1]["offset_elems"] == 6
    vals = struct.unpack("<10f", raw)
    assert vals[:6] == (0.0, 1.0, 2.0, 3.0, 4.0, 5.0)
    assert vals[6:] == (1.0, 1.0, 1.0, 1.0)


def test_manifest_against_artifacts_dir():
    """If `make artifacts` has run, the manifest must be self-consistent."""
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    mpath = os.path.join(art, "manifest.json")
    if not os.path.exists(mpath):
        pytest.skip("artifacts not built")
    manifest = json.load(open(mpath))
    assert manifest["version"] >= 2
    for e in manifest["artifacts"]:
        assert os.path.exists(os.path.join(art, e["path"])), e["path"]
        wpath = os.path.join(art, e["weights_path"])
        assert os.path.exists(wpath), e["weights_path"]
        total_elems = sum(w["num_elems"] for w in e["weights"])
        assert os.path.getsize(wpath) == total_elems * 4
        # offsets are contiguous
        off = 0
        for w in e["weights"]:
            assert w["offset_elems"] == off
            assert w["num_elems"] == int(np.prod(w["shape"])) if w["shape"] else 1
            off += w["num_elems"]


def test_manifest_lm_entries_cover_default_tiers():
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    mpath = os.path.join(art, "manifest.json")
    if not os.path.exists(mpath):
        pytest.skip("artifacts not built")
    manifest = json.load(open(mpath))
    lm = {(e["tier"], e["batch"]) for e in manifest["artifacts"] if e["kind"] == "lm"}
    for tier in aot.DEFAULT_TIERS:
        for b in aot.LM_BATCHES:
            assert (tier, b) in lm


def test_embedder_lowering_roundtrip_numeric():
    """Lowered embedder == eager embedder on the same weights."""
    cfg = model.EmbedderConfig()
    fn, specs = model.make_embedder_fn(cfg, 8)
    params = model.init_embedder_params(cfg)
    flat = [params[n] for n in model.EMBED_WEIGHT_ORDER]
    feats = jax.random.uniform(jax.random.PRNGKey(3), (8, cfg.feat_dim))
    (eager,) = fn(*flat, feats)
    compiled = jax.jit(fn)(*flat, feats)[0]
    np.testing.assert_allclose(np.asarray(eager), np.asarray(compiled), rtol=1e-5, atol=1e-6)
