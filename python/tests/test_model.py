"""L2 model tests: shapes, determinism, weight flattening, tier zoo."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import model


@pytest.fixture(scope="module")
def tiny_cfg():
    return model.TIERS["qwen15b"]


def test_tier_zoo_well_formed():
    for name, cfg in model.TIERS.items():
        assert cfg.name == name
        assert cfg.d_model % 32 == 0, name
        assert cfg.seq % 32 == 0, name
        assert cfg.vocab % 64 == 0, name
        assert 0.0 < cfg.capability <= 1.0, name
        assert cfg.emulated_params_b > 0, name


def test_tiers_ordered_by_capability():
    """Within a family, more emulated params ⇒ more capability."""
    fam = [model.TIERS[n] for n in ("qwen05b", "qwen15b", "qwen3b", "qwen7b", "qwen72b")]
    caps = [t.capability for t in fam]
    assert caps == sorted(caps)


def test_llama3b_weaker_than_qwen3b():
    # Paper §6.4: llama3.2-3B underperforms qwen2.5-3B on EACO-RAG.
    assert model.TIERS["llama3b"].capability < model.TIERS["qwen3b"].capability


def test_lm_forward_shape_and_finite(tiny_cfg):
    params = model.init_lm_params(tiny_cfg)
    tokens = jnp.zeros((2, tiny_cfg.seq), jnp.int32)
    logits = model.lm_forward(tiny_cfg, params, tokens)
    assert logits.shape == (2, tiny_cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()


def test_lm_forward_depends_on_last_token(tiny_cfg):
    params = model.init_lm_params(tiny_cfg)
    t1 = jnp.zeros((1, tiny_cfg.seq), jnp.int32)
    t2 = t1.at[0, -1].set(5)
    l1 = model.lm_forward(tiny_cfg, params, t1)
    l2 = model.lm_forward(tiny_cfg, params, t2)
    assert float(jnp.max(jnp.abs(l1 - l2))) > 1e-4


def test_init_deterministic(tiny_cfg):
    p1 = model.init_lm_params(tiny_cfg)
    p2 = model.init_lm_params(tiny_cfg)
    assert_allclose(np.asarray(p1["embed"]), np.asarray(p2["embed"]))
    assert_allclose(
        np.asarray(p1["layers"][0]["wq"]), np.asarray(p2["layers"][0]["wq"])
    )


def test_different_seeds_differ():
    qwen = model.init_lm_params(model.TIERS["qwen3b"])
    llama = model.init_lm_params(model.TIERS["llama3b"])
    assert float(jnp.max(jnp.abs(qwen["embed"] - llama["embed"]))) > 1e-3


def test_weight_flatten_roundtrip(tiny_cfg):
    params = model.init_lm_params(tiny_cfg)
    flat = model.flatten_lm_params(tiny_cfg, params)
    names = model.lm_weight_order(tiny_cfg)
    assert len(flat) == len(names)
    back = model.unflatten_lm_params(tiny_cfg, flat)
    tokens = jnp.ones((1, tiny_cfg.seq), jnp.int32)
    assert_allclose(
        np.asarray(model.lm_forward(tiny_cfg, params, tokens)),
        np.asarray(model.lm_forward(tiny_cfg, back, tokens)),
    )


def test_weight_order_matches_manifest_names(tiny_cfg):
    names = model.lm_weight_order(tiny_cfg)
    assert names[0] == "embed" and names[1] == "pos"
    assert names[-2] == "head_w" and names[-1] == "head_b"
    assert f"layers.{tiny_cfg.layers - 1}.w2" in names


def test_make_lm_fn_runs(tiny_cfg):
    fn, specs = model.make_lm_fn(tiny_cfg, 1)
    args = [
        jnp.zeros(s.shape, s.dtype)
        if s.dtype == jnp.int32
        else jax.random.normal(jax.random.PRNGKey(i), s.shape, s.dtype) * 0.02
        for i, s in enumerate(specs)
    ]
    (out,) = fn(*args)
    assert out.shape == (1, tiny_cfg.vocab)


@pytest.mark.parametrize("name", sorted(model.TIERS))
def test_tiny_param_count_matches_flat(name):
    cfg = model.TIERS[name]
    params = model.init_lm_params(cfg)
    flat = model.flatten_lm_params(cfg, params)
    total = sum(int(np.prod(a.shape)) for a in flat)
    assert total == cfg.tiny_param_count()


def test_flops_positive_and_monotone():
    f3 = model.lm_flops_per_forward(model.TIERS["qwen3b"], 1)
    f72 = model.lm_flops_per_forward(model.TIERS["qwen72b"], 1)
    assert 0 < f3 < f72
    assert model.lm_flops_per_forward(model.TIERS["qwen3b"], 8) == pytest.approx(8 * f3)


# ---------------------------------------------------------------------------
# embedder
# ---------------------------------------------------------------------------

def test_embedder_unit_norm():
    cfg = model.EmbedderConfig()
    params = model.init_embedder_params(cfg)
    feats = jax.random.uniform(jax.random.PRNGKey(0), (8, cfg.feat_dim))
    out = model.embedder_forward(cfg, params, feats)
    assert out.shape == (8, cfg.out_dim)
    norms = np.linalg.norm(np.asarray(out), axis=1)
    assert_allclose(norms, np.ones(8), rtol=1e-5)


def test_embedder_similarity_tracks_overlap():
    """Overlapping feature buckets ⇒ higher cosine than disjoint ones."""
    cfg = model.EmbedderConfig()
    params = model.init_embedder_params(cfg)
    a = jnp.zeros((8, cfg.feat_dim)).at[:, :32].set(1.0)
    b = jnp.zeros((8, cfg.feat_dim)).at[:, 16:48].set(1.0)   # 50% overlap with a
    c = jnp.zeros((8, cfg.feat_dim)).at[:, 128:160].set(1.0)  # disjoint
    ea, eb, ec = (model.embedder_forward(cfg, params, x) for x in (a, b, c))
    sim_ab = float(jnp.sum(ea[0] * eb[0]))
    sim_ac = float(jnp.sum(ea[0] * ec[0]))
    assert sim_ab > sim_ac


def test_embedder_scale_invariant():
    cfg = model.EmbedderConfig()
    params = model.init_embedder_params(cfg)
    feats = jax.random.uniform(jax.random.PRNGKey(1), (8, cfg.feat_dim))
    o1 = model.embedder_forward(cfg, params, feats)
    o2 = model.embedder_forward(cfg, params, feats * 7.5)
    assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-4, atol=1e-5)
