"""L2: the EACO-RAG model stack in JAX (build-time only).

Two model families, both lowered to HLO text by ``aot.py`` and executed
from the Rust coordinator via PJRT:

* ``TransformerLM`` — a decoder-only transformer. Each *tier* is a tiny
  network (64–192 d_model) that stands in for a Qwen2.5/Llama3.2 class
  model of the paper (0.5B–72B). The tier's **emulated parameter count**
  drives the Rust cost model (Pope et al. TFLOPs) and delay scaling; the
  tiny network keeps the request path honest — every served token is a
  real PJRT forward pass. Attention runs on the L1 Pallas flash-attention
  kernel; the output head on the L1 tiled-linear kernel.

* ``Embedder`` — feature-hashing n-gram embedder (the `all-MiniLM-L6-v2`
  stand-in, DESIGN.md §1): L2-normalized hashed counts → 2-layer MLP →
  L2-normalized 64-d sentence vector. The Rust side computes the hashed
  counts (runtime::tokenizer) and calls this artifact for the query /
  keyword similarity tests (>50% rule, paper §5).

Weights are generated deterministically from a seed and **closed over as
constants** at lowering time, so each artifact is fully self-contained
(Rust feeds only token ids / hashed counts).
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from .kernels.attention import flash_attention
from .kernels.linear import linear


# ---------------------------------------------------------------------------
# Transformer LM
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TierConfig:
    """One emulated model tier (see DESIGN.md §1 substitution table)."""

    name: str               # e.g. "qwen3b"
    layers: int
    d_model: int            # multiple of 32 (head_dim fixed at 32)
    d_ff: int               # multiple of 64
    vocab: int              # multiple of 64
    seq: int                # fixed context window, multiple of 32
    emulated_params_b: float  # parameter count (billions) it stands in for
    capability: float       # oracle capability score in [0,1], paper-calibrated
    seed: int = 0

    @property
    def heads(self) -> int:
        return self.d_model // 32

    @property
    def head_dim(self) -> int:
        return 32

    def tiny_param_count(self) -> int:
        """Actual parameter count of the tiny stand-in network."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        # per block: 2×LN (4d) + qkvo (4d²) + mlp (2df + f + d)
        per_layer = 4 * d * d + 2 * d * f + 5 * d + f
        embed = v * d + self.seq * d
        head = 2 * d + d * v + v  # final LN + output projection
        return embed + self.layers * per_layer + head


# The tier zoo. capability values are the oracle calibration knob
# (oracle::calibration on the Rust side mirrors these names).
TIERS: dict[str, TierConfig] = {
    t.name: t
    for t in [
        TierConfig("qwen05b", layers=2, d_model=64,  d_ff=128, vocab=512, seq=64, emulated_params_b=0.5,  capability=0.30),
        TierConfig("qwen15b", layers=2, d_model=64,  d_ff=192, vocab=512, seq=64, emulated_params_b=1.5,  capability=0.42),
        TierConfig("qwen3b",  layers=3, d_model=96,  d_ff=256, vocab=512, seq=64, emulated_params_b=3.0,  capability=0.55),
        TierConfig("llama3b", layers=3, d_model=96,  d_ff=256, vocab=512, seq=64, emulated_params_b=3.0,  capability=0.48, seed=7),
        TierConfig("qwen7b",  layers=4, d_model=128, d_ff=320, vocab=512, seq=64, emulated_params_b=7.0,  capability=0.64),
        TierConfig("qwen72b", layers=6, d_model=192, d_ff=448, vocab=512, seq=64, emulated_params_b=72.0, capability=0.90),
    ]
}


def init_lm_params(cfg: TierConfig) -> dict:
    """Deterministic parameter pytree for a tier (seeded, scaled init)."""
    # NOTE: hash() of a str is salted per-process; use a stable digest.
    name_digest = sum((i + 1) * b for i, b in enumerate(cfg.name.encode())) % 65536
    key = jax.random.PRNGKey(cfg.seed * 1000003 + name_digest)
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab

    def take(shape, scale):
        nonlocal key
        key, sub = jax.random.split(key)
        return jax.random.normal(sub, shape, jnp.float32) * scale

    params = {
        "embed": take((v, d), 0.02),
        "pos": take((cfg.seq, d), 0.02),
        "layers": [],
        "ln_f_g": jnp.ones((d,), jnp.float32),
        "ln_f_b": jnp.zeros((d,), jnp.float32),
        "head_w": take((d, v), 1.0 / math.sqrt(d)),
        "head_b": jnp.zeros((v,), jnp.float32),
    }
    for _ in range(cfg.layers):
        params["layers"].append(
            {
                "ln1_g": jnp.ones((d,), jnp.float32),
                "ln1_b": jnp.zeros((d,), jnp.float32),
                "wq": take((d, d), 1.0 / math.sqrt(d)),
                "wk": take((d, d), 1.0 / math.sqrt(d)),
                "wv": take((d, d), 1.0 / math.sqrt(d)),
                "wo": take((d, d), 1.0 / math.sqrt(d)),
                "ln2_g": jnp.ones((d,), jnp.float32),
                "ln2_b": jnp.zeros((d,), jnp.float32),
                "w1": take((d, f), 1.0 / math.sqrt(d)),
                "b1": jnp.zeros((f,), jnp.float32),
                "w2": take((f, d), 1.0 / math.sqrt(f)),
                "b2": jnp.zeros((d,), jnp.float32),
            }
        )
    return params


def _layernorm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def _block(cfg: TierConfig, p: dict, x: jnp.ndarray) -> jnp.ndarray:
    """One pre-LN transformer block over the whole batch ``(b, s, d)``.

    §Perf note: an earlier revision vmapped a per-sequence block; under
    interpret-mode lowering that serialized the batch (b8 cost 1.46× of
    8×b1 per row — EXPERIMENTS.md §Perf). Folding the batch into the
    attention grid's leading dimension (b·heads) and into one big GEMM
    per projection lets XLA batch the work properly.
    """
    b, s, d = x.shape
    h = _layernorm(x, p["ln1_g"], p["ln1_b"])
    flat = h.reshape(b * s, d)

    def heads(proj):
        # (b*s, d) -> (b, s, H, hd) -> (b, H, s, hd) -> (b*H, s, hd)
        return (
            proj.reshape(b, s, cfg.heads, cfg.head_dim)
            .transpose(0, 2, 1, 3)
            .reshape(b * cfg.heads, s, cfg.head_dim)
        )

    q = heads(flat @ p["wq"])
    k = heads(flat @ p["wk"])
    v = heads(flat @ p["wv"])
    # L1 Pallas flash-attention kernel (causal); the grid's "head" axis
    # carries batch·heads so the whole batch runs in one pallas_call.
    # §Perf: 64×64 blocks (one q-tile per head at seq=64) halve the
    # interpret-mode grid-cell count vs the 32×32 default while staying
    # far below the VMEM budget (~100 KiB/cell).
    attn = flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    attn = (
        attn.reshape(b, cfg.heads, s, cfg.head_dim)
        .transpose(0, 2, 1, 3)
        .reshape(b, s, d)
    )
    x = x + attn @ p["wo"]
    h = _layernorm(x, p["ln2_g"], p["ln2_b"])
    h = jax.nn.gelu(h @ p["w1"] + p["b1"])
    return x + h @ p["w2"] + p["b2"]


def lm_forward(cfg: TierConfig, params: dict, tokens: jnp.ndarray) -> jnp.ndarray:
    """Forward pass: ``tokens (batch, seq) int32`` → last-position logits
    ``(batch, vocab) f32``.

    The Rust generation loop greedy-decodes by sliding the fixed window,
    so only the final position's logits are computed (§Perf: the head
    projection runs on ``(batch, d)`` instead of ``(batch·seq, d)`` —
    a seq-fold FLOP saving on the decode path).
    """
    b, s = tokens.shape
    assert s == cfg.seq, (s, cfg.seq)
    x = params["embed"][tokens] + params["pos"][None, :, :]
    for layer in params["layers"]:
        x = _block(cfg, layer, x)
    x = _layernorm(x, params["ln_f_g"], params["ln_f_b"])
    last = x[:, -1, :]  # (b, d)
    # L1 tiled-linear kernel for the output head (b × d @ d × vocab).
    return linear(last, params["head_w"], params["head_b"],
                  block_m=b, block_n=64, block_k=32)


LAYER_WEIGHT_NAMES = (
    "ln1_g", "ln1_b", "wq", "wk", "wv", "wo",
    "ln2_g", "ln2_b", "w1", "b1", "w2", "b2",
)


def lm_weight_order(cfg: TierConfig) -> list[str]:
    """Canonical flat weight order shared with the Rust runtime.

    The artifact's entry computation takes these as leading parameters
    (tokens last). The Rust side uploads them once as device-resident
    PjRtBuffers from ``weights_<tier>.bin`` and reuses them per call
    (``execute_b``) — the real-serving weight-residency pattern, and it
    keeps the HLO text free of multi-megabyte constants.
    """
    names = ["embed", "pos"]
    for i in range(cfg.layers):
        names += [f"layers.{i}.{n}" for n in LAYER_WEIGHT_NAMES]
    names += ["ln_f_g", "ln_f_b", "head_w", "head_b"]
    return names


def flatten_lm_params(cfg: TierConfig, params: dict) -> list[jnp.ndarray]:
    out = [params["embed"], params["pos"]]
    for layer in params["layers"]:
        out += [layer[n] for n in LAYER_WEIGHT_NAMES]
    out += [params["ln_f_g"], params["ln_f_b"], params["head_w"], params["head_b"]]
    return out


def unflatten_lm_params(cfg: TierConfig, flat: list[jnp.ndarray]) -> dict:
    it = iter(flat)
    params = {"embed": next(it), "pos": next(it), "layers": []}
    for _ in range(cfg.layers):
        params["layers"].append({n: next(it) for n in LAYER_WEIGHT_NAMES})
    params["ln_f_g"] = next(it)
    params["ln_f_b"] = next(it)
    params["head_w"] = next(it)
    params["head_b"] = next(it)
    return params


def make_lm_fn(cfg: TierConfig, batch: int):
    """Returns (fn, example_args): ``fn(*weights, tokens) -> (logits,)``.

    Weights are runtime parameters (see ``lm_weight_order``); only shapes
    are baked into the artifact.
    """
    params = init_lm_params(cfg)
    flat = flatten_lm_params(cfg, params)

    def fn(*args):
        *weights, tokens = args
        p = unflatten_lm_params(cfg, list(weights))
        return (lm_forward(cfg, p, tokens),)

    specs = tuple(jax.ShapeDtypeStruct(w.shape, w.dtype) for w in flat)
    specs = specs + (jax.ShapeDtypeStruct((batch, cfg.seq), jnp.int32),)
    return fn, specs


def lm_flops_per_forward(cfg: TierConfig, batch: int) -> float:
    """Analytic FLOPs of one *tiny-network* forward (not the emulated tier)."""
    d, f, s, v = cfg.d_model, cfg.d_ff, cfg.seq, cfg.vocab
    per_layer = 2 * s * d * d * 4 + 2 * s * s * d * 2 + 2 * s * d * f * 2
    head = 2 * s * d * v
    return float(batch * (cfg.layers * per_layer + head))


# ---------------------------------------------------------------------------
# Embedder
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class EmbedderConfig:
    """Feature-hashing sentence embedder (MiniLM stand-in)."""

    feat_dim: int = 256     # hashed n-gram buckets (runtime::tokenizer)
    hidden: int = 128
    out_dim: int = 64
    seed: int = 42


def init_embedder_params(cfg: EmbedderConfig) -> dict:
    key = jax.random.PRNGKey(cfg.seed)
    k1, k2 = jax.random.split(key)
    return {
        "w1": jax.random.normal(k1, (cfg.feat_dim, cfg.hidden), jnp.float32)
        / math.sqrt(cfg.feat_dim),
        "b1": jnp.zeros((cfg.hidden,), jnp.float32),
        "w2": jax.random.normal(k2, (cfg.hidden, cfg.out_dim), jnp.float32)
        / math.sqrt(cfg.hidden),
        "b2": jnp.zeros((cfg.out_dim,), jnp.float32),
    }


def embedder_forward(cfg: EmbedderConfig, params: dict, feats: jnp.ndarray) -> jnp.ndarray:
    """``feats (batch, feat_dim) f32`` → unit-norm ``(batch, out_dim)``.

    The hashing trick preserves lexical-overlap geometry: two texts
    sharing n-grams share feature buckets, so cosine similarity tracks
    keyword overlap — exactly the signal the paper's >50%-match rule and
    edge-selection overlap ratio need.
    """
    x = feats / jnp.sqrt(jnp.sum(feats * feats, axis=-1, keepdims=True) + 1e-8)
    # L1 tiled-linear kernel for the first (wide) projection.
    h = linear(x, params["w1"], params["b1"], block_m=8, block_n=64, block_k=64)
    h = jnp.tanh(h)
    out = h @ params["w2"] + params["b2"]
    return out / jnp.sqrt(jnp.sum(out * out, axis=-1, keepdims=True) + 1e-8)


EMBED_WEIGHT_ORDER = ("w1", "b1", "w2", "b2")


def make_embedder_fn(cfg: EmbedderConfig, batch: int):
    """``fn(w1, b1, w2, b2, feats) -> (vectors,)`` — weights as params."""
    params = init_embedder_params(cfg)
    flat = [params[n] for n in EMBED_WEIGHT_ORDER]

    def fn(*args):
        w1, b1, w2, b2, feats = args
        p = {"w1": w1, "b1": b1, "w2": w2, "b2": b2}
        return (embedder_forward(cfg, p, feats),)

    specs = tuple(jax.ShapeDtypeStruct(w.shape, w.dtype) for w in flat)
    specs = specs + (jax.ShapeDtypeStruct((batch, cfg.feat_dim), jnp.float32),)
    return fn, specs
