"""AOT lowering: JAX models → HLO *text* artifacts + weights + manifest.

This is the only place Python touches the pipeline; ``make artifacts``
runs it once and the Rust binary is self-contained afterwards.

Interchange format is HLO **text**, not serialized HloModuleProto:
jax ≥ 0.5 emits protos with 64-bit instruction ids which the ``xla``
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the
text parser reassigns ids, so text round-trips cleanly
(/opt/xla-example/README.md). Lowering goes through stablehlo →
``mlir_module_to_xla_computation(..., return_tuple=True)`` and the Rust
side unwraps with ``to_tuple1()``.

Weights are **runtime parameters**, not HLO constants: each tier's
parameters are dumped once to ``weights_<tier>.bin`` (little-endian f32,
concatenated in ``model.lm_weight_order``), uploaded by the Rust runtime
as device-resident PjRtBuffers and passed via ``execute_b`` — the
weight-residency pattern of real serving stacks, and it keeps every HLO
text file ~50 KB instead of 5–25 MB of printed constants.

Artifacts (see manifest.json for the full list):
  * ``slm_<tier>_b<batch>.hlo.txt`` — transformer forward → last-position
    logits; batch variants feed the dynamic batcher.
  * ``weights_<tier>.bin``          — flat f32 weights for the tier.
  * ``embedder_b<batch>.hlo.txt`` / ``weights_embedder.bin``.
  * ``manifest.json``               — shapes / tiers / weight offsets /
    analytic FLOPs the Rust runtime needs.

Usage: ``python -m compile.aot --out-dir ../artifacts [--tiers a,b,...]``
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import jax
import numpy as np
from jax._src.lib import xla_client as xc

from . import model
from .kernels import attention as attn_kernel


def to_hlo_text(lowered) -> str:
    """stablehlo → XlaComputation → HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# Batch sizes per artifact family; the coordinator's dynamic batcher pads
# to the nearest exported batch.
LM_BATCHES = (1, 4, 8)
EMBED_BATCHES = (8, 32)

# Tiers exported by default (every tier the benches need).
DEFAULT_TIERS = ("qwen15b", "qwen3b", "llama3b", "qwen7b", "qwen72b")


def write_weights(path: str, arrays: list) -> list[dict]:
    """Concatenate f32 arrays into a .bin; return offset specs (elements)."""
    specs = []
    offset = 0
    with open(path, "wb") as f:
        for name, arr in arrays:
            a = np.asarray(arr, dtype=np.float32)
            f.write(a.tobytes(order="C"))
            specs.append(
                {
                    "name": name,
                    "shape": list(a.shape),
                    "offset_elems": offset,
                    "num_elems": int(a.size),
                }
            )
            offset += int(a.size)
    return specs


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--tiers", default=",".join(DEFAULT_TIERS))
    args = ap.parse_args()

    out_dir = args.out_dir
    os.makedirs(out_dir, exist_ok=True)
    tiers = [t.strip() for t in args.tiers.split(",") if t.strip()]
    for t in tiers:
        if t not in model.TIERS:
            sys.exit(f"unknown tier {t!r}; known: {sorted(model.TIERS)}")

    ecfg = model.EmbedderConfig()
    entries = []
    total = 0

    for name in tiers:
        cfg = model.TIERS[name]
        params = model.init_lm_params(cfg)
        flat = model.flatten_lm_params(cfg, params)
        wnames = model.lm_weight_order(cfg)
        wpath = f"weights_{name}.bin"
        wspecs = write_weights(os.path.join(out_dir, wpath), list(zip(wnames, flat)))
        for b in LM_BATCHES:
            fn, specs = model.make_lm_fn(cfg, b)
            text = to_hlo_text(jax.jit(fn).lower(*specs))
            path = f"slm_{name}_b{b}.hlo.txt"
            with open(os.path.join(out_dir, path), "w") as f:
                f.write(text)
            total += len(text)
            print(f"wrote {path} ({len(text)} chars)")
            entries.append(
                {
                    "name": f"slm_{name}_b{b}",
                    "kind": "lm",
                    "tier": name,
                    "path": path,
                    "weights_path": wpath,
                    "weights": wspecs,
                    "batch": b,
                    "seq": cfg.seq,
                    "vocab": cfg.vocab,
                    "d_model": cfg.d_model,
                    "layers": cfg.layers,
                    "heads": cfg.heads,
                    "emulated_params_b": cfg.emulated_params_b,
                    "capability": cfg.capability,
                    "tiny_params": cfg.tiny_param_count(),
                    "tiny_flops_per_forward": model.lm_flops_per_forward(cfg, b),
                }
            )

    eparams = model.init_embedder_params(ecfg)
    ewspecs = write_weights(
        os.path.join(out_dir, "weights_embedder.bin"),
        [(n, eparams[n]) for n in model.EMBED_WEIGHT_ORDER],
    )
    for b in EMBED_BATCHES:
        fn, specs = model.make_embedder_fn(ecfg, b)
        text = to_hlo_text(jax.jit(fn).lower(*specs))
        path = f"embedder_b{b}.hlo.txt"
        with open(os.path.join(out_dir, path), "w") as f:
            f.write(text)
        total += len(text)
        print(f"wrote {path} ({len(text)} chars)")
        entries.append(
            {
                "name": f"embedder_b{b}",
                "kind": "embedder",
                "tier": "embedder",
                "path": path,
                "weights_path": "weights_embedder.bin",
                "weights": ewspecs,
                "batch": b,
                "feat_dim": ecfg.feat_dim,
                "out_dim": ecfg.out_dim,
            }
        )

    manifest = {
        "version": 2,
        "kernel": {
            "attention_block_q": 32,
            "attention_block_k": 32,
            "attention_vmem_bytes": attn_kernel.vmem_footprint_bytes(32, 32, 32),
            "attention_mxu_util": attn_kernel.mxu_utilization_estimate(32, 32, 32),
        },
        "artifacts": entries,
    }
    mpath = os.path.join(out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath}; total HLO text {total / 1e6:.1f} MB")


if __name__ == "__main__":
    main()
