"""L1 Pallas kernel: tiled flash-attention with online softmax.

This is the compute hot-spot of every transformer layer in the EACO-RAG
model stack (edge SLMs and the emulated cloud LLM). The paper's testbed
runs standard CUDA attention on RTX 4090 / A800 GPUs; here the kernel is
re-thought for TPU per DESIGN.md §Hardware-Adaptation:

* CUDA threadblock tiling        → Pallas grid over (head, q-block) with
                                   BlockSpec index maps staging Q/K/V
                                   tiles HBM→VMEM.
* shared-memory accumulators     → VMEM scratch: running max ``m``,
                                   running denominator ``l`` and the
                                   output accumulator ``acc`` persist
                                   across the k-block loop.
* tensor-core WMMA               → MXU: the QKᵀ and PV contractions use
                                   ``jnp.dot`` with
                                   ``preferred_element_type=f32`` so the
                                   128×128 systolic array accumulates in
                                   f32 even for bf16 inputs.
* warp-shuffle online softmax    → full-tile VPU ops (max / exp /
                                   rescale over the lane dimension).

VMEM footprint for block shapes (Bq, Bk, D), f32:
    q-tile  Bq*D*4   k-tile Bk*D*4   v-tile Bk*D*4
    acc     Bq*D*4   m,l    2*Bq*4   logits Bq*Bk*4
With the default Bq=Bk=32, D<=64 this is < 64 KiB — far under the
~16 MiB/core VMEM budget, leaving room for double-buffered DMA of the
next k-tile (the compiler pipelines the fori_loop body automatically on
real TPUs). MXU utilization estimate: both matmuls are (32×D)·(D×32);
with D=32/64 the systolic array is fed 32×32 tiles → 1/16 of peak per
pass, which is the expected regime for small-head-dim SLM inference and
matches the paper's edge-device setting (utilization, not raw TFLOPs, is
the roofline lever — see EXPERIMENTS.md §Perf).

``interpret=True`` ALWAYS: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret-mode lowers to plain HLO so the same artifact
runs under the Rust PJRT CPU client. Correctness (not wall-clock) is the
signal; it is asserted against ``ref.attention_ref`` by pytest.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int, seq: int, scale: float, causal: bool):
    """One (head, q-block) grid cell: stream k/v tiles with online softmax.

    q_ref:  (block_q, d)   VMEM tile of queries for this grid cell
    k_ref:  (seq, d)       full K for this head (streamed in block_k tiles)
    v_ref:  (seq, d)       full V for this head
    o_ref:  (block_q, d)   output tile
    """
    block_q, d = q_ref.shape
    q_blk = pl.program_id(1)
    q0 = q_blk * block_q  # absolute row index of this q tile

    q = q_ref[...].astype(jnp.float32) * scale

    def body(kb, carry):
        acc, m_i, l_i = carry
        k0 = kb * block_k
        k = k_ref[pl.ds(k0, block_k), :].astype(jnp.float32)
        v = v_ref[pl.ds(k0, block_k), :].astype(jnp.float32)
        # (block_q, block_k) logits on the MXU, f32 accumulation.
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        if causal:
            rows = q0 + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
            cols = k0 + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        # Online softmax update (Milakov-Gimelshein / FlashAttention).
        m_new = jnp.maximum(m_i, jnp.max(s, axis=1))
        alpha = jnp.exp(m_i - m_new)  # rescale factor for old accumulator
        p = jnp.exp(s - m_new[:, None])
        l_new = l_i * alpha + jnp.sum(p, axis=1)
        acc = acc * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        return acc, m_new, l_new

    num_kb = seq // block_k
    if causal:
        # k tiles strictly above the diagonal contribute nothing; skip them.
        num_kb_eff = (q0 + block_q + block_k - 1) // block_k
        num_kb = jnp.minimum(num_kb, num_kb_eff)

    acc0 = jnp.zeros((block_q, d), jnp.float32)
    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc, m_i, l_i = jax.lax.fori_loop(0, num_kb, body, (acc0, m0, l0))
    o_ref[...] = (acc / l_i[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_q", "block_k", "causal"))
def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    block_q: int = 32,
    block_k: int = 32,
    causal: bool = True,
) -> jnp.ndarray:
    """Tiled multi-head attention. ``q, k, v: (heads, seq, head_dim)``.

    ``seq`` must be divisible by both ``block_q`` and ``block_k`` (the
    model pads its context to a multiple of 32). Always interpret-mode —
    see the module docstring.
    """
    h, s, d = q.shape
    if s % block_q or s % block_k:
        raise ValueError(f"seq {s} not divisible by blocks ({block_q},{block_k})")
    scale = 1.0 / (d ** 0.5)
    kernel = functools.partial(
        _attn_kernel, block_k=block_k, seq=s, scale=scale, causal=causal
    )
    grid = (h, s // block_q)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, d), lambda hd, qb: (hd, qb, 0)),
            pl.BlockSpec((None, s, d), lambda hd, qb: (hd, 0, 0)),
            pl.BlockSpec((None, s, d), lambda hd, qb: (hd, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, d), lambda hd, qb: (hd, qb, 0)),
        out_shape=jax.ShapeDtypeStruct((h, s, d), q.dtype),
        interpret=True,
    )(q, k, v)


def vmem_footprint_bytes(block_q: int, block_k: int, head_dim: int, dtype_bytes: int = 4) -> int:
    """Analytic VMEM footprint of one grid cell (see module docstring)."""
    q_tile = block_q * head_dim * dtype_bytes
    kv_tiles = 2 * block_k * head_dim * dtype_bytes
    acc = block_q * head_dim * 4  # f32 accumulator
    softmax_state = 2 * block_q * 4
    logits = block_q * block_k * 4
    # ×2 on the streamed kv tiles for double buffering.
    return q_tile + 2 * kv_tiles + acc + softmax_state + logits


def mxu_utilization_estimate(block_q: int, block_k: int, head_dim: int) -> float:
    """Fraction of the 128×128 MXU fed by each matmul pass (upper bound)."""
    return min(1.0, (min(block_q, 128) / 128.0) * (min(head_dim, 128) / 128.0))
