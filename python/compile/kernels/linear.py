"""L1 Pallas kernel: tiled dense projection (matmul + bias).

Used by the embedder MLP and the transformer output head. The tiling
story mirrors ``attention.py``: grid over (m-blocks, n-blocks), the K
reduction streamed through VMEM in ``block_k`` tiles with an f32
accumulator, contraction on the MXU via ``dot_general`` with
``preferred_element_type=f32``.

VMEM per grid cell, f32: x-tile ``bm*bk*4``, w-tile ``bk*bn*4`` (×2 for
double-buffering the streamed reduction), acc ``bm*bn*4`` — with the
default (32, 128, 128) that is ~100 KiB, well inside VMEM.

interpret=True always (CPU PJRT cannot run Mosaic custom-calls).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _linear_kernel(x_ref, w_ref, b_ref, o_ref, *, block_k: int, kdim: int):
    """One (m-block, n-block) output tile; stream the K reduction."""
    bm, _ = x_ref.shape
    _, bn = w_ref.shape

    def body(kb, acc):
        k0 = kb * block_k
        x = x_ref[:, pl.ds(k0, block_k)].astype(jnp.float32)
        w = w_ref[pl.ds(k0, block_k), :].astype(jnp.float32)
        return acc + jax.lax.dot_general(
            x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )

    acc0 = jnp.zeros((bm, bn), jnp.float32)
    acc = jax.lax.fori_loop(0, kdim // block_k, body, acc0)
    acc = acc + b_ref[...].astype(jnp.float32)[None, :]
    o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k"))
def linear(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: jnp.ndarray,
    *,
    block_m: int = 32,
    block_n: int = 64,
    block_k: int = 64,
) -> jnp.ndarray:
    """Tiled ``x @ w + b``. Shapes ``(m, k) @ (k, n) + (n,)``.

    m, k, n must be divisible by their block sizes (model dims are chosen
    as multiples of 32 — see model.py).
    """
    m, kdim = x.shape
    kdim2, n = w.shape
    assert kdim == kdim2, (kdim, kdim2)
    if m % block_m or n % block_n or kdim % block_k:
        raise ValueError(f"dims ({m},{kdim},{n}) not divisible by blocks "
                         f"({block_m},{block_k},{block_n})")
    kernel = functools.partial(_linear_kernel, block_k=block_k, kdim=kdim)
    grid = (m // block_m, n // block_n)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, kdim), lambda mb, nb: (mb, 0)),
            pl.BlockSpec((kdim, block_n), lambda mb, nb: (0, nb)),
            pl.BlockSpec((block_n,), lambda mb, nb: (nb,)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda mb, nb: (mb, nb)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, w, b)
