"""Pure-jnp correctness oracles for the Pallas kernels.

These are the ground-truth implementations that the L1 kernels
(`attention.py`, `linear.py`) are validated against in
``python/tests/test_kernel.py``. They are intentionally written in the
most direct jnp style (no tiling, no numerics tricks) so that a mismatch
always indicts the kernel, not the reference.
"""

from __future__ import annotations

import jax.numpy as jnp


def attention_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    scale: float | None = None,
) -> jnp.ndarray:
    """Multi-head scaled dot-product attention, direct softmax.

    Shapes: q, k, v are ``(heads, seq, head_dim)``; returns the same.
    """
    h, s, d = q.shape
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    logits = jnp.einsum("hqd,hkd->hqk", q, k) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, s), dtype=bool))
        logits = jnp.where(mask[None, :, :], logits, -jnp.inf)
    # Softmax in f32 regardless of input dtype for a stable oracle.
    logits = logits.astype(jnp.float32)
    probs = jnp.exp(logits - jnp.max(logits, axis=-1, keepdims=True))
    probs = probs / jnp.sum(probs, axis=-1, keepdims=True)
    out = jnp.einsum("hqk,hkd->hqd", probs.astype(v.dtype), v)
    return out.astype(q.dtype)


def linear_ref(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray | None = None) -> jnp.ndarray:
    """Dense projection oracle: ``x @ w (+ b)`` with f32 accumulation.

    Shapes: x ``(m, k)``, w ``(k, n)``, b ``(n,)`` → ``(m, n)``.
    """
    out = jnp.matmul(x.astype(jnp.float32), w.astype(jnp.float32))
    if b is not None:
        out = out + b.astype(jnp.float32)
    return out.astype(x.dtype)


def layernorm_ref(x: jnp.ndarray, g: jnp.ndarray, b: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    """LayerNorm oracle over the last axis."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def l2_normalize_ref(x: jnp.ndarray, eps: float = 1e-8) -> jnp.ndarray:
    """Row-wise L2 normalization oracle."""
    n = jnp.sqrt(jnp.sum(x * x, axis=-1, keepdims=True) + eps)
    return x / n
