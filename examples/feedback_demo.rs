//! Feedback demo: the closed adaptive-knowledge loop, A/B'd against
//! the fixed-budget gossiper.
//!
//! Eight edges serve the same spatially-tilted, trend-heavy query
//! stream twice under `KnowledgeMode::Collaborative` with the
//! edge-assisted arm: once with `[cluster] feedback = "none"` (every
//! link gets the full `gossip_hot_k` digest every round) and once with
//! `feedback = "hit-rate"` (gate-observed tier hit rates and per-link
//! digest usefulness shrink each link's budget toward `min_hot_k` when
//! its offers stop turning into transfers, and per-chunk hit
//! contributions re-rank the digest). The interesting readout is the
//! A/B at the bottom: replicated bytes should drop while the edge-tier
//! hit rate holds or improves — the loop spends gossip where it is
//! observed to help.
//!
//!   cargo run --release --example feedback_demo

use eaco_rag::cluster::feedback::FeedbackMode;
use eaco_rag::config::SystemConfig;
use eaco_rag::gating::{Arm, GenLoc, Retrieval};
use eaco_rag::sim::{KnowledgeMode, RunStats, SimSystem, TIER_LOCAL, TIER_NEIGHBOR};
use eaco_rag::workload::{Workload, WorkloadSpec};

const STEPS: usize = 4000;

fn half(wl: &Workload, which: usize) -> Workload {
    let mid = wl.events.len() / 2;
    let events = if which == 0 {
        wl.events[..mid].to_vec()
    } else {
        wl.events[mid..].to_vec()
    };
    Workload {
        spec: wl.spec.clone(),
        events,
        edge_home_topics: wl.edge_home_topics.clone(),
        trends: wl.trends.clone(),
    }
}

fn edge_hit(s: &RunStats) -> f64 {
    let q = s.tier_queries[TIER_LOCAL] + s.tier_queries[TIER_NEIGHBOR];
    let h = s.tier_hits[TIER_LOCAL] + s.tier_hits[TIER_NEIGHBOR];
    if q == 0 { 0.0 } else { h as f64 / q as f64 * 100.0 }
}

struct Ab {
    first: RunStats,
    second: RunStats,
    stale: usize,
    resident: usize,
    rounds: u64,
    offered: u64,
    transferred: u64,
}

fn run_mode(mode: FeedbackMode) -> Ab {
    let mut cfg = SystemConfig {
        num_edges: 8,
        edge_capacity: 300,
        ..SystemConfig::default()
    };
    cfg.cluster.feedback = mode;

    let mut sys = SimSystem::new(cfg.clone(), KnowledgeMode::Collaborative);
    // Same skewed stream the cluster demo uses: strong spatial identity
    // plus a large trending share, so some links' digests are useful
    // (trend diffusion) and others mostly are not (settled home topics).
    let spec = WorkloadSpec {
        num_edges: cfg.num_edges,
        steps: STEPS,
        spatial_tilt: 0.85,
        trend_share: 0.45,
        ..WorkloadSpec::default()
    };
    let wl = Workload::generate(&sys.corpus, spec, cfg.seed);
    let arm = Arm {
        retrieval: Retrieval::EdgeAssisted,
        gen: GenLoc::EdgeSlm,
    };

    println!(
        "\n== feedback = {} (hot_k {}, min_hot_k {}, gossip every {} steps) ==",
        mode.name(),
        cfg.cluster.gossip_hot_k,
        cfg.cluster.min_hot_k,
        cfg.cluster.gossip_interval
    );
    let first = sys.run_baseline(&half(&wl, 0), arm);
    let second = sys.run_baseline(&half(&wl, 1), arm);
    for (label, s) in [("first  half (cold)", &first), ("second half (warm)", &second)] {
        println!(
            "    {label}: acc {:5.2}%  |  {}  |  {:7.1} KiB gossiped",
            s.accuracy * 100.0,
            s.tier_row(),
            s.bytes_replicated as f64 / 1024.0
        );
    }
    let (stale, resident) = sys.cluster.staleness();
    let g = &sys.cluster.gossiper.stats;
    println!(
        "    gossip: {} rounds, {} chunks offered -> {} transferred; staleness {stale}/{resident}",
        g.rounds, g.chunks_offered, g.chunks_transferred
    );
    if let Some(fb) = sys.cluster.feedback.as_ref() {
        let rate = |t: usize| {
            fb.tier_hit_rate(t, STEPS)
                .map(|r| format!("{:.2}", r))
                .unwrap_or_else(|| "-".into())
        };
        println!(
            "    learned: {} outcomes folded; decayed hit rate local {} / neighbor {}; miss pressure {:.2}",
            fb.observations,
            rate(TIER_LOCAL),
            rate(TIER_NEIGHBOR),
            fb.edge_miss_pressure(STEPS)
        );
    }
    Ab {
        first,
        second,
        stale,
        resident,
        rounds: g.rounds,
        offered: g.chunks_offered,
        transferred: g.chunks_transferred,
    }
}

fn main() {
    println!("EACO-RAG feedback demo: 8 edges, skewed workload, {STEPS} queries");
    println!("(per-link gossip budgets learned from gate-observed hit rates)");
    let fixed = run_mode(FeedbackMode::None);
    let learned = run_mode(FeedbackMode::HitRate);

    let bytes = |ab: &Ab| (ab.first.bytes_replicated + ab.second.bytes_replicated) as f64 / 1024.0;
    let warm_hit = |ab: &Ab| edge_hit(&ab.second);
    println!("\n== A/B (fixed budget vs learned budget) ==");
    println!(
        "    gossip bytes : {:8.1} KiB -> {:8.1} KiB ({:+.1}%)",
        bytes(&fixed),
        bytes(&learned),
        (bytes(&learned) / bytes(&fixed).max(1e-9) - 1.0) * 100.0
    );
    println!(
        "    offer volume : {} offered / {} rounds -> {} offered / {} rounds",
        fixed.offered, fixed.rounds, learned.offered, learned.rounds
    );
    println!(
        "    transfers    : {} -> {}",
        fixed.transferred, learned.transferred
    );
    println!(
        "    staleness    : {}/{} -> {}/{}",
        fixed.stale, fixed.resident, learned.stale, learned.resident
    );
    println!(
        "    warm edge-tier hit rate: {:.1}% -> {:.1}%",
        warm_hit(&fixed),
        warm_hit(&learned)
    );
    println!("\nthe learned run should gossip fewer bytes at an equal-or-better warm");
    println!("hit rate: links whose digests stop producing transfers shrink to the");
    println!("min_hot_k floor, and rising miss pressure floors budgets back up.");
}
