//! **End-to-end validation driver** (DESIGN.md deliverable): serve a
//! realistic batched workload against the real three-layer stack and
//! report latency/throughput — proving the layers compose:
//!
//!   L3 Rust coordinator (gate + batcher + stores)
//!     → PJRT CPU client
//!     → L2 transformer artifacts (AOT from JAX)
//!     → L1 Pallas flash-attention (interpret-lowered into the HLO).
//!
//! Reports BOTH time domains:
//!   * virtual delay — the paper's h_t (netsim + tier-scaled gen model),
//!     comparable to Table 4's delay column;
//!   * real wall-clock — actual PJRT execution time of the tiny stand-in
//!     networks, demonstrating true batched serving throughput.
//!
//! Run: `cargo run --release --example serve_workload -- [--steps 600]`
//! The run is recorded in EXPERIMENTS.md §E2E.

use std::path::PathBuf;

use eaco_rag::config::{QosPreset, SystemConfig};
use eaco_rag::coordinator::Coordinator;
use eaco_rag::corpus::Profile;
use eaco_rag::sim::workload_for;
use eaco_rag::util::cli::Args;
use eaco_rag::workload::Workload;

fn main() -> eaco_rag::Result<()> {
    let a = Args::new("serve_workload", "end-to-end serving driver")
        .opt("steps", "600", "number of queries to serve")
        .opt("dataset", "wiki", "dataset profile: wiki | hp")
        .opt("qos", "cost", "QoS preset: cost | delay")
        .opt("warmup", "200", "gate warm-up steps")
        .opt("gen-tokens", "4", "real tokens decoded per request")
        .opt("seed", "42", "run seed")
        .parse();

    let mut cfg = SystemConfig::default();
    cfg.dataset = Profile::parse(&a.get("dataset")).unwrap_or(Profile::Wiki);
    cfg.qos = QosPreset::parse(&a.get("qos")).unwrap_or(QosPreset::CostEfficient);
    cfg.warmup_steps = a.get_usize("warmup");
    cfg.seed = a.get_u64("seed");
    let steps = a.get_usize("steps");

    let artifacts = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    println!(
        "=== EACO-RAG end-to-end serving ===\ndataset={} qos={} steps={steps} warmup={} edges={}",
        cfg.dataset.name(),
        cfg.qos.name(),
        cfg.warmup_steps,
        cfg.num_edges
    );

    let t0 = std::time::Instant::now();
    let mut coord = Coordinator::new(cfg.clone(), &artifacts, a.get_usize("gen-tokens"))?;
    println!(
        "artifact load+compile (edge {} + cloud {}): {:.2}s",
        cfg.edge_tier,
        cfg.cloud_tier,
        t0.elapsed().as_secs_f64()
    );

    let wl = Workload::generate(&coord.sim.corpus, workload_for(&cfg, steps), cfg.seed);
    let served = coord.run(&wl)?;

    println!("\n--- serving report ---");
    println!("{}", coord.metrics.summary());
    println!("gate arm usage:        {:?}", coord.metrics.arm_histogram());
    println!(
        "dynamic batching:      {} batches, mean size {:.2}",
        coord.batcher.flushed_batches,
        coord.batcher.mean_batch_size()
    );
    println!(
        "adaptive updates:      {} pushes from cloud to edges",
        coord.sim.cloud.updates_sent
    );
    for e in coord.sim.edges() {
        println!(
            "  edge {}: {} resident chunks, {} inserted, {} evicted, {} retrievals",
            e.id,
            e.len(),
            e.stats.inserted,
            e.stats.evicted,
            e.stats.retrievals
        );
    }
    println!("\nJSON: {}", coord.metrics.to_json().to_string());
    assert_eq!(served, steps, "all requests must complete");
    Ok(())
}
