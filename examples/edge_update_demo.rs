//! Adaptive knowledge update in action (paper §3.3 + Fig. 1).
//!
//! Tracks one edge's keyword-overlap ratio against its *current* query
//! mix as user interests drift (trending topics rotate every
//! `drift_period` steps). With adaptive updates the store follows the
//! trend; with a static store, overlap decays whenever interest moves
//! away from the provisioned topics.
//!
//! Run: `cargo run --release --example edge_update_demo`

use eaco_rag::config::SystemConfig;
use eaco_rag::corpus::Profile;
use eaco_rag::gating::{Arm, GenLoc, Retrieval};
use eaco_rag::sim::{workload_for, KnowledgeMode, SimSystem};
use eaco_rag::util::cli::Args;
use eaco_rag::workload::Workload;

fn main() {
    let a = Args::new("edge_update_demo", "adaptive update visualisation")
        .opt("steps", "1000", "workload length")
        .opt("window", "100", "reporting window (steps)")
        .parse();
    let steps = a.get_usize("steps");
    let window = a.get_usize("window");

    let mut cfg = SystemConfig::default();
    cfg.dataset = Profile::Wiki;
    cfg.edge_capacity = 300; // small store so eviction pressure is visible

    println!("=== adaptive knowledge update demo (edge 0, capacity {}) ===", cfg.edge_capacity);
    println!(
        "{:<8} {:>18} {:>18} {:>14} {:>12}",
        "window", "overlap (adaptive)", "overlap (static)", "acc adaptive", "acc static"
    );

    let arm = Arm {
        retrieval: Retrieval::LocalNaive,
        gen: GenLoc::EdgeSlm,
    };

    let mut adaptive = SimSystem::new(cfg.clone(), KnowledgeMode::Adaptive);
    let mut static_sys = SimSystem::new(cfg.clone(), KnowledgeMode::Static);
    let wl = Workload::generate(&adaptive.corpus, workload_for(&cfg, steps), cfg.seed);

    let mut rows = Vec::new();
    let mut w_overlap = (0.0, 0.0);
    let mut w_correct = (0usize, 0usize);
    let mut w_n = 0usize;

    for ev in wl.events.clone() {
        // Measure the overlap each system's edge store has for the query.
        let kws_owned: Vec<String> = adaptive
            .corpus
            .qa_keywords(&adaptive.corpus.qa[ev.qa_id])
            .into_iter()
            .map(|s| s.to_string())
            .collect();
        let kws: Vec<&str> = kws_owned.iter().map(|s| s.as_str()).collect();
        w_overlap.0 += adaptive.edges()[ev.edge_id].overlap_ratio(&kws);
        w_overlap.1 += static_sys.edges()[ev.edge_id].overlap_ratio(&kws);

        let (_, c1) = adaptive.serve(ev.qa_id, ev.edge_id, ev.step, arm);
        let (_, c2) = static_sys.serve(ev.qa_id, ev.edge_id, ev.step, arm);
        w_correct.0 += c1 as usize;
        w_correct.1 += c2 as usize;
        w_n += 1;

        if w_n == window {
            let row = (
                ev.step / window,
                w_overlap.0 / w_n as f64,
                w_overlap.1 / w_n as f64,
                w_correct.0 as f64 / w_n as f64,
                w_correct.1 as f64 / w_n as f64,
            );
            println!(
                "{:<8} {:>18.3} {:>18.3} {:>13.1}% {:>11.1}%",
                row.0,
                row.1,
                row.2,
                row.3 * 100.0,
                row.4 * 100.0
            );
            rows.push(row);
            w_overlap = (0.0, 0.0);
            w_correct = (0, 0);
            w_n = 0;
        }
    }

    let mean = |f: fn(&(usize, f64, f64, f64, f64)) -> f64| {
        rows.iter().map(f).sum::<f64>() / rows.len() as f64
    };
    println!(
        "\nmeans: overlap adaptive {:.3} vs static {:.3}; accuracy adaptive {:.1}% vs static {:.1}%",
        mean(|r| r.1),
        mean(|r| r.2),
        mean(|r| r.3) * 100.0,
        mean(|r| r.4) * 100.0
    );
    println!(
        "cloud pushed {} updates; edge 0 evicted {} chunks (FIFO)",
        adaptive.cloud.updates_sent, adaptive.edges()[0].stats.evicted
    );
    println!("\ntakeaway: the FIFO update keeps the store aligned with drifting demand (paper Fig. 1).");
}
