//! Cluster demo: a skewed multi-edge workload on the distributed
//! knowledge plane.
//!
//! Eight edges serve a spatially-tilted, trend-heavy query stream under
//! `KnowledgeMode::Collaborative` with the edge-assisted arm, once with
//! the paper-faithful FIFO placement and once with hotness-LRU. The run
//! is split in half so you can watch adaptive placement + gossip kick
//! in: per-tier hit rates rise between the halves while the stale
//! fraction of the fleet's replicas falls.
//!
//!   cargo run --release --example cluster_demo

use eaco_rag::cluster::placement::PlacementPolicy;
use eaco_rag::config::SystemConfig;
use eaco_rag::gating::{Arm, GenLoc, Retrieval};
use eaco_rag::sim::{KnowledgeMode, RunStats, SimSystem, TIER_LOCAL, TIER_NEIGHBOR};
use eaco_rag::workload::{Workload, WorkloadSpec};

const STEPS: usize = 4000;

fn half(wl: &Workload, which: usize) -> Workload {
    let mid = wl.events.len() / 2;
    let events = if which == 0 {
        wl.events[..mid].to_vec()
    } else {
        wl.events[mid..].to_vec()
    };
    Workload {
        spec: wl.spec.clone(),
        events,
        edge_home_topics: wl.edge_home_topics.clone(),
        trends: wl.trends.clone(),
    }
}

fn tier_summary(label: &str, s: &RunStats) {
    println!(
        "    {label}: acc {:5.2}%  |  {}  |  {:7.1} KiB gossiped",
        s.accuracy * 100.0,
        s.tier_row(),
        s.bytes_replicated as f64 / 1024.0
    );
}

fn run_policy(policy: PlacementPolicy) {
    let mut cfg = SystemConfig {
        num_edges: 8,
        edge_capacity: 300,
        ..SystemConfig::default()
    };
    cfg.cluster.placement = policy;

    let mut sys = SimSystem::new(cfg.clone(), KnowledgeMode::Collaborative);
    // Strong spatial identity + a large trending share: the workload the
    // paper's Table 2 motivates, exaggerated so placement has work to do.
    let spec = WorkloadSpec {
        num_edges: cfg.num_edges,
        steps: STEPS,
        spatial_tilt: 0.85,
        trend_share: 0.45,
        ..WorkloadSpec::default()
    };
    let wl = Workload::generate(&sys.corpus, spec, cfg.seed);
    let arm = Arm {
        retrieval: Retrieval::EdgeAssisted,
        gen: GenLoc::EdgeSlm,
    };

    println!(
        "\n== placement = {} (degree {}, gossip every {} steps, digest {} chunks) ==",
        policy.name(),
        cfg.cluster.degree,
        cfg.cluster.gossip_interval,
        cfg.cluster.gossip_hot_k
    );
    let (stale0, resident0) = sys.cluster.staleness();
    println!("    provisioned: {resident0} resident chunks, {stale0} stale");

    let first = sys.run_baseline(&half(&wl, 0), arm);
    tier_summary("first  half (cold)", &first);
    let (stale1, resident1) = sys.cluster.staleness();

    let second = sys.run_baseline(&half(&wl, 1), arm);
    tier_summary("second half (warm)", &second);
    let (stale2, resident2) = sys.cluster.staleness();

    let g = &sys.cluster.gossiper.stats;
    println!(
        "    gossip: {} rounds, {} digests ({} suppressed by delta sync), {} chunks moved",
        g.rounds, g.digests_sent, g.digests_suppressed, g.chunks_transferred
    );
    println!(
        "    staleness: {stale1}/{resident1} after half 1 -> {stale2}/{resident2} after half 2"
    );
    println!(
        "    routing: {} local / {} neighbor decisions; cloud pushes {}",
        sys.cluster.routed_local,
        sys.cluster.routed_neighbor,
        sys.cloud.updates_sent
    );
    let topics = sys.corpus.spec.topics;
    let hottest = (0..topics)
        .max_by(|&a, &b| {
            sys.cluster
                .hotness
                .topic_hotness(a, STEPS)
                .partial_cmp(&sys.cluster.hotness.topic_hotness(b, STEPS))
                .unwrap()
        })
        .unwrap_or(0);
    let distinct: usize = sys
        .cluster
        .nodes
        .iter()
        .map(|n| n.summary.distinct_keywords())
        .sum();
    let summary_bytes: usize = sys.cluster.nodes.iter().map(|n| n.summary.wire_bytes()).sum();
    println!(
        "    demand: hottest topic {hottest} ({:.1} decayed hits); summaries: {distinct} \
         distinct keywords, {:.1} KiB total (what routing probes instead of full indexes)",
        sys.cluster.hotness.topic_hotness(hottest, STEPS),
        summary_bytes as f64 / 1024.0
    );
    let local_hit = |s: &RunStats| {
        let q = s.tier_queries[TIER_LOCAL] + s.tier_queries[TIER_NEIGHBOR];
        let h = s.tier_hits[TIER_LOCAL] + s.tier_hits[TIER_NEIGHBOR];
        if q == 0 { 0.0 } else { h as f64 / q as f64 * 100.0 }
    };
    println!(
        "    edge-tier hit rate: {:.1}% -> {:.1}%",
        local_hit(&first),
        local_hit(&second)
    );
}

fn main() {
    println!("EACO-RAG cluster demo: 8 edges, skewed workload, {STEPS} queries");
    println!("(edge-assisted retrieval via summary routing; cloud pushes + neighbor gossip)");
    run_policy(PlacementPolicy::Fifo);
    run_policy(PlacementPolicy::HotnessLru);
    println!("\nhotness-LRU keeps hot replicas resident (cold-first eviction), so the");
    println!("warm-half hit rate and staleness should both beat the FIFO baseline.");
}
