// Internal calibration probe (not part of the published examples):
// prints oracle accuracies for the four Table-4 baselines using real
// graph retrieval, per profile.
use eaco_rag::corpus::{Corpus, Profile, QaPair};
use eaco_rag::graphrag::GraphRag;
use eaco_rag::oracle::{ContextSource, Oracle};

fn main() {
    for profile in [Profile::Wiki, Profile::HarryPotter] {
        let c = Corpus::generate(profile, 1);
        let g = GraphRag::build(&c);
        let o = Oracle::new(1);
        let graph_retrieve = |qa: &QaPair| -> Vec<usize> {
            let kws = c.qa_keywords(qa);
            g.local_search(&c, &kws, 8).into_iter().map(|(ch, _)| ch).collect()
        };
        let llm_only = o.expected_accuracy(&c, 0.55, ContextSource::None, |_| vec![]);
        let naive_full = o.expected_accuracy(&c, 0.55, ContextSource::NaiveRag, |qa| {
            // naive over the full corpus index: top-8 by keyword hits
            qa.supporting_chunks.clone() // upper bound; real naive done in edge module
        });
        let graph3 = o.expected_accuracy(&c, 0.55, ContextSource::GraphRag, graph_retrieve);
        let graph72 = o.expected_accuracy(&c, 0.90, ContextSource::GraphRag, graph_retrieve);
        println!("{:?}: llm_only={:.3} naive(ub)={:.3} graph3b={:.3} graph72b={:.3} ctx_chars={}",
            profile, llm_only, naive_full, graph3, graph72, g.global_search_context_chars());
    }
}
