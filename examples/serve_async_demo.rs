//! Serving-plane demo: background gossip overlapping query service.
//!
//! Eight collaborative edges serve a gated workload through the async
//! event loop (`SimSystem::serve_async`), once with gossip in the
//! foreground on a single worker and once in the background on four —
//! the exact A/B the serving plane exists for. The printout shows what
//! moves and what must not:
//!
//!   * p50/p99 latency and mean queue wait drop when gossip wire time
//!     overlaps query service instead of blocking the servers;
//!   * the gossip-overlap ratio goes from 0 to > 0;
//!   * the retrieved-chunk digest and tier mix are **identical** —
//!     overlap is a latency optimization, never a behavior change.
//!
//!   cargo run --release --example serve_async_demo

use eaco_rag::config::SystemConfig;
use eaco_rag::serve::metrics::ServeMetrics;
use eaco_rag::serve::Driver;
use eaco_rag::sim::{workload_for, KnowledgeMode, RunStats, SimSystem};
use eaco_rag::workload::Workload;

const STEPS: usize = 3000;

fn run(background: bool) -> (RunStats, ServeMetrics) {
    let mut cfg = SystemConfig {
        num_edges: 8,
        edge_capacity: 300,
        ..SystemConfig::default()
    };
    cfg.serve.workers = if background { 4 } else { 1 };
    cfg.serve.gossip_background = background;
    let mut sys = SimSystem::new(cfg.clone(), KnowledgeMode::Collaborative);
    let wl = Workload::generate(&sys.corpus, workload_for(&cfg, STEPS), cfg.seed);
    sys.serve_async(&wl, Driver::Gated)
}

fn report(label: &str, stats: &RunStats, m: &ServeMetrics) {
    let (p50, p99) = m.latency_p50_p99();
    let shed = m.shed_total();
    println!("  {label}:");
    println!(
        "    latency p50 {p50:7.1} ms  p99 {p99:7.1} ms  |  mean wait {:6.1} ms  |  shed {:4} ({:4.1}%)",
        m.mean_wait_ms(),
        shed,
        100.0 * shed as f64 / (m.admitted + shed).max(1) as f64,
    );
    println!(
        "    gossip: {} rounds, {:7.1} ms busy, overlap ratio {:5.3}  |  acc {:5.2}%",
        m.gossip_rounds,
        m.gossip_busy_ms,
        m.overlap_ratio(),
        stats.accuracy * 100.0,
    );
    println!("    {}", m.tier_latency_row());
    println!("    retrieved digest: {:#018x}", m.retrieved_digest);
}

fn main() {
    println!("serve_async demo — 8 edges, gated, {STEPS} steps\n");
    let (fg_stats, fg) = run(false);
    report("foreground gossip, 1 worker", &fg_stats, &fg);
    let (bg_stats, bg) = run(true);
    report("background gossip, 4 workers", &bg_stats, &bg);

    println!();
    assert_eq!(
        fg.retrieved_digest, bg.retrieved_digest,
        "background gossip changed a retrieved-chunk set"
    );
    assert_eq!(fg_stats.tier_queries, bg_stats.tier_queries);
    println!(
        "retrieval identical across modes (digest {:#018x}); overlap ratio {:.3} -> {:.3}",
        fg.retrieved_digest,
        fg.overlap_ratio(),
        bg.overlap_ratio()
    );
}
