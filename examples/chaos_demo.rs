//! Chaos-plane demo: a scripted split-brain over the collaborative
//! serve plane.
//!
//! Six edges serve a gated workload while the fleet partitions into two
//! halves mid-run and heals later (`[chaos]` preset `split-brain`). The
//! run is fully deterministic — same seed + scenario reproduces every
//! bit — and the printout is the machine-readable chaos report the
//! `eaco-rag chaos` subcommand emits: measured recovery time, version
//! staleness (run-wide and while partitioned), availability, and the
//! SLA verdicts.
//!
//!   cargo run --release --example chaos_demo

use eaco_rag::chaos::{ChaosReport, SlaSpec};
use eaco_rag::config::SystemConfig;
use eaco_rag::serve::Driver;
use eaco_rag::sim::{workload_for, KnowledgeMode, SimSystem};
use eaco_rag::workload::Workload;

const STEPS: usize = 1200;

fn main() {
    let mut cfg = SystemConfig {
        num_edges: 6,
        edge_capacity: 400,
        ..SystemConfig::default()
    };
    cfg.chaos.enabled = true;
    cfg.chaos.scenario = "split-brain".into();
    cfg.chaos.at_step = 300;
    cfg.chaos.duration_steps = 300;
    cfg.chaos.sla_max_staleness = 64;
    cfg.chaos.sla_min_availability = 0.95;

    println!(
        "chaos demo — {} edges, gated, {STEPS} steps, split-brain @ step {} for {} steps\n",
        cfg.num_edges, cfg.chaos.at_step, cfg.chaos.duration_steps
    );

    let mut sys = SimSystem::new(cfg.clone(), KnowledgeMode::Collaborative);
    let wl = Workload::generate(&sys.corpus, workload_for(&cfg, STEPS), cfg.seed);
    let (stats, m) = sys.serve_async(&wl, Driver::Gated);

    let outcome = m.chaos.expect("chaos-enabled run attaches an outcome");
    println!(
        "  staleness: max {} versions (while partitioned: {}) | availability {:.3}",
        outcome.max_staleness,
        outcome.max_staleness_partitioned,
        outcome.availability()
    );
    println!(
        "  faults applied: {} | rerouted {} | shed {} | accuracy {:.2}%",
        outcome.faults_applied,
        outcome.rerouted,
        outcome.shed,
        stats.accuracy * 100.0
    );
    assert!(!sys.cluster.partitioned(), "fleet must be healed by run end");

    let report = ChaosReport::evaluate(outcome, &SlaSpec::from_config(&cfg.chaos));
    println!("\nchaos report:\n{}", report.to_json().to_string());
    assert!(report.pass, "demo SLAs are sized to pass on the default seed");
}
