//! Quickstart: the smallest end-to-end EACO-RAG serving run.
//!
//! Loads the AOT artifacts, builds a 4-edge + cloud topology over the
//! synthetic Wiki corpus, and serves 120 queries through the full
//! pipeline — SafeOBO gate → edge/cloud retrieval → **real batched PJRT
//! generation** → oracle grading — then prints the serving report.
//!
//! Run with: `make artifacts && cargo run --release --example quickstart`

use std::path::PathBuf;

use eaco_rag::config::SystemConfig;
use eaco_rag::coordinator::Coordinator;
use eaco_rag::sim::workload_for;
use eaco_rag::workload::Workload;

fn main() -> eaco_rag::Result<()> {
    let artifacts = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");

    // 1. Configure the system (defaults mirror the paper's prototype §5:
    //    1,000-chunk edge stores, updates every 20 QA pairs, 4 edges).
    let mut cfg = SystemConfig::default();
    cfg.warmup_steps = 40; // short warm-up for a quick demo

    // 2. Build the coordinator: spins up the PJRT executor thread and
    //    compiles the qwen3b (edge) + qwen72b (cloud) artifacts.
    println!("loading artifacts from {} ...", artifacts.display());
    let mut coord = Coordinator::new(cfg.clone(), &artifacts, 4)?;

    // 3. Generate a drifting, spatially-skewed workload and serve it.
    let wl = Workload::generate(&coord.sim.corpus, workload_for(&cfg, 120), cfg.seed);
    let served = coord.run(&wl)?;

    // 4. Report.
    println!("\nserved {served} requests through the full stack");
    println!("{}", coord.metrics.summary());
    println!("gate arm usage: {:?}", coord.metrics.arm_histogram());
    println!("mean PJRT batch size: {:.2}", coord.batcher.mean_batch_size());
    println!(
        "adaptive updates pushed by the cloud: {}",
        coord.sim.cloud.updates_sent
    );
    Ok(())
}
