//! Ablation example: what does the collaborative gate actually buy?
//!
//! Compares, on the same workload (virtual time, no PJRT needed):
//!   1. fixed all-cloud         (the conservative baseline)
//!   2. fixed all-local         (the cheap baseline)
//!   3. random arm selection    (gate with no learning)
//!   4. EACO-RAG SafeOBO gate   (cost-efficient and delay-oriented)
//!
//! Run: `cargo run --release --example ablation_gate -- [--dataset wiki]`

use eaco_rag::config::{QosPreset, SystemConfig};
use eaco_rag::corpus::Profile;
use eaco_rag::gating::standard_arms;
use eaco_rag::sim::{workload_for, KnowledgeMode, RunStats, SimSystem};
use eaco_rag::util::cli::Args;
use eaco_rag::util::rng::Rng;
use eaco_rag::util::stats::Running;
use eaco_rag::workload::Workload;

fn print_row(label: &str, s: &RunStats) {
    println!(
        "{label:<24} acc {:>6.2}%  delay {:>5.2}s  cost {:>9.2} TFLOPs",
        s.accuracy * 100.0,
        s.delay.mean(),
        s.resource_cost.mean()
    );
}

fn main() {
    let a = Args::new("ablation_gate", "gate on/off ablation")
        .opt("dataset", "wiki", "wiki | hp")
        .opt("steps", "1200", "workload length")
        .parse();
    let dataset = Profile::parse(&a.get("dataset")).unwrap_or(Profile::Wiki);
    let steps = a.get_usize("steps");

    let mut cfg = SystemConfig::default();
    cfg.dataset = dataset;
    println!(
        "=== gate ablation on {} ({} queries) ===",
        dataset.name(),
        steps
    );

    // 1–2: fixed strategies.
    for (label, arm) in [
        ("all-cloud (72B+graph)", "graph-llm"),
        ("all-local (naive RAG)", "naive-rag"),
    ] {
        let mut sys = SimSystem::new(cfg.clone(), KnowledgeMode::Adaptive);
        let wl = Workload::generate(&sys.corpus, workload_for(&cfg, steps), cfg.seed);
        let stats = sys.run_baseline(&wl, SimSystem::baseline_arm(arm).unwrap());
        print_row(label, &stats);
    }

    // 3: random arm selection (no learning) — measured post-"warmup" for
    // comparability with the gate run.
    {
        let mut sys = SimSystem::new(cfg.clone(), KnowledgeMode::Adaptive);
        let wl = Workload::generate(&sys.corpus, workload_for(&cfg, steps), cfg.seed);
        let arms = standard_arms();
        let mut rng = Rng::new(cfg.seed).fork("random-gate");
        let mut stats = RunStats::default();
        stats.delay = Running::new();
        let mut correct_n = 0usize;
        for ev in wl.events.clone() {
            if ev.step < cfg.warmup_steps {
                continue;
            }
            let arm = arms[rng.below(arms.len())];
            let (o, correct) = sys.serve(ev.qa_id, ev.edge_id, ev.step, arm);
            stats.queries += 1;
            if correct {
                correct_n += 1;
            }
            stats.delay.push(o.delay_s);
            stats.resource_cost.push(o.resource_cost);
        }
        stats.accuracy = correct_n as f64 / stats.queries.max(1) as f64;
        print_row("random gate", &stats);
    }

    // 4: the SafeOBO gate under both QoS presets.
    for qos in [QosPreset::CostEfficient, QosPreset::DelayOriented] {
        let mut c = cfg.clone();
        c.qos = qos;
        let mut sys = SimSystem::new(c.clone(), KnowledgeMode::Adaptive);
        let wl = Workload::generate(&sys.corpus, workload_for(&c, steps), c.seed);
        let (stats, gate) = sys.run_eaco(&wl);
        print_row(&format!("SafeOBO ({})", qos.name()), &stats);
        println!(
            "{:<24}   arms: {:?}",
            "",
            gate.arms
                .iter()
                .map(|a| a.name())
                .zip(stats.arm_counts.iter().copied())
                .filter(|(_, n)| *n > 0)
                .collect::<Vec<_>>()
        );
    }
    println!(
        "\ntakeaway: the learned gate dominates both fixed extremes and random \
         selection on the cost/accuracy frontier (paper §6.2)."
    );
}
