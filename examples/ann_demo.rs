//! ANN demo: exact scan vs the IVF index on a store far past edge scale.
//!
//! Builds a 200k×64 clustered vector store, then serves the same 200
//! queries through the flat (sharded exact) scan and through the IVF
//! index at three nprobe settings, printing per-query latency, recall@8
//! against the exact answer, and the speedup. The sweep is the knob the
//! `[ann]` config section exposes: nprobe buys recall with probed rows.
//!
//!   cargo run --release --example ann_demo

use std::time::Instant;

use eaco_rag::util::rng::Rng;
use eaco_rag::vecstore::ivf::{IvfParams, IvfStore};
use eaco_rag::vecstore::VecStore;

const ROWS: usize = 200_000;
const DIM: usize = 64;
const NLIST: usize = 128;
const K: usize = 8;
const QUERIES: usize = 200;

fn main() {
    println!("EACO-RAG ANN demo: {ROWS} rows x {DIM} dims, nlist {NLIST}, top-{K}");
    let mut rng = Rng::new(0xd340);

    // Clustered data (what the coarse quantizer is for): 256 centers,
    // tight noise, queries drawn near centers like real topical traffic.
    let n_centers = 256;
    let mut centers = vec![0.0f32; n_centers * DIM];
    for x in centers.iter_mut() {
        *x = rng.normal() as f32;
    }
    let mut flat = VecStore::with_capacity(DIM, ROWS);
    let mut v = vec![0.0f32; DIM];
    for id in 0..ROWS {
        let c = rng.below(n_centers);
        for (j, x) in v.iter_mut().enumerate() {
            *x = centers[c * DIM + j] + 0.3 * rng.normal() as f32;
        }
        flat.insert(id, &v);
    }
    let queries: Vec<Vec<f32>> = (0..QUERIES)
        .map(|_| {
            let c = rng.below(n_centers);
            (0..DIM)
                .map(|j| centers[c * DIM + j] + 0.3 * rng.normal() as f32)
                .collect()
        })
        .collect();

    let t0 = Instant::now();
    let ivf = IvfStore::from_flat(flat.clone(), IvfParams { nlist: NLIST, ..IvfParams::default() });
    println!(
        "    ivf build: {:.0} ms ({} lists, {} rows/list avg)",
        t0.elapsed().as_secs_f64() * 1e3,
        ivf.nlist_eff(),
        ROWS / ivf.nlist_eff().max(1)
    );

    // Exact baseline (the auto-sharded flat scan) + ground truth.
    let t0 = Instant::now();
    let truth: Vec<Vec<(usize, f32)>> = queries.iter().map(|q| flat.top_k(q, K)).collect();
    let exact_us = t0.elapsed().as_secs_f64() * 1e6 / QUERIES as f64;
    println!("    exact scan: {exact_us:8.1} us/query  recall 1.000  (reference)");

    for nprobe in [1usize, 8, 16] {
        let t0 = Instant::now();
        let approx: Vec<Vec<(usize, f32)>> =
            queries.iter().map(|q| ivf.top_k_with(q, K, nprobe)).collect();
        let us = t0.elapsed().as_secs_f64() * 1e6 / QUERIES as f64;
        let mut hits = 0usize;
        let mut total = 0usize;
        for (t, a) in truth.iter().zip(approx.iter()) {
            total += t.len();
            hits += t.iter().filter(|(id, _)| a.iter().any(|(x, _)| x == id)).count();
        }
        let recall = hits as f64 / total.max(1) as f64;
        println!(
            "    ivf nprobe {nprobe:2}: {us:8.1} us/query  recall {recall:.3}  ({:.1}x vs exact)",
            exact_us / us
        );
    }
    println!("\nnprobe trades probed rows for recall: ~nprobe/nlist of the store is");
    println!("scanned per query, so recall climbs toward 1.0 as nprobe grows while");
    println!("latency stays a small fraction of the full scan.");
}
