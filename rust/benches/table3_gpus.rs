//! **Table 3** — FP64 peak TFLOPS of the server GPUs used to unify time
//! and resource costs (paper §4.1). These are constants; the bench
//! verifies the cost model reproduces them exactly and shows the
//! resulting per-second time-cost scaling.

#[path = "common/mod.rs"]
mod common;

use common::banner;
use eaco_rag::cost::{CostModel, Gpu};

fn main() {
    banner(
        "Table 3 — GPU FP64 peak TFLOPS (time-cost scaling constants)",
        "EACO-RAG paper §4.1, Table 3",
    );
    let paper = [
        (Gpu::Rtx4090, 1.29),
        (Gpu::TeslaP100, 4.70),
        (Gpu::TeslaV100, 7.80),
        (Gpu::A100, 9.70),
        (Gpu::H100, 60.00),
    ];
    let model = CostModel::default();
    println!(
        "{:<28} {:>10} {:>10} {:>22}",
        "GPU", "measured", "paper", "time-cost of 1 s delay"
    );
    println!("{}", "-".repeat(74));
    for (gpu, expected) in paper {
        let got = gpu.peak_tflops();
        assert_eq!(got, expected, "{}", gpu.name());
        println!(
            "{:<28} {:>10.2} {:>10.2} {:>18.2} TFLOP",
            gpu.name(),
            got,
            expected,
            model.time_cost(1.0, gpu)
        );
    }
    println!("\nall five constants exact — cost unification identical to the paper");
}
