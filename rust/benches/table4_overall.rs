//! **Table 4** — the headline result: accuracy / delay / cost of the four
//! baselines plus EACO-RAG (cost-efficient & delay-oriented) on both
//! datasets. Reproduction criterion (DESIGN.md §5): orderings and the
//! large EACO cost reduction at near-cloud accuracy, not absolute values
//! (our substrate is a simulator on a synthetic corpus).

#[path = "common/mod.rs"]
mod common;

use common::*;
use eaco_rag::config::QosPreset;
use eaco_rag::corpus::Profile;

fn main() {
    banner(
        "Table 4 — overall performance comparison",
        "EACO-RAG paper §6.2, Table 4",
    );

    for (profile, paper_rows) in [
        (
            Profile::Wiki,
            [
                ("3b LLM-only", "28.72, 0.30, 0.60"),
                ("3b LLM+Naive RAG", "61.57, 0.88, 23.10"),
                ("3b LLM+GraphRAG", "76.01, 3.01, 60.02"),
                ("72b LLM+GraphRAG", "94.39, 0.97, 711.43"),
                ("EACO-RAG (Cost-Efficient)", "94.92, 1.27, 109.40"),
                ("EACO-RAG (Delay-Oriented)", "94.17, 0.75, 247.03"),
            ],
        ),
        (
            Profile::HarryPotter,
            [
                ("3b LLM-only", "31.69, 0.31, 0.65"),
                ("3b LLM+Naive RAG", "52.54, 1.00, 23.62"),
                ("3b LLM+GraphRAG", "63.47, 2.82, 58.99"),
                ("72b LLM+GraphRAG", "77.12, 1.03, 739.79"),
                ("EACO-RAG (Cost-Efficient)", "78.00, 1.74, 139.43"),
                ("EACO-RAG (Delay-Oriented)", "76.28, 0.79, 496.19"),
            ],
        ),
    ] {
        println!("\n--- dataset: {} ---", profile.name());
        header();
        let cfg = cfg_for(profile, QosPreset::CostEfficient);

        let arms = ["llm-only", "naive-rag", "graph-slm", "graph-llm"];
        let mut cloud_cost = 0.0;
        for (i, arm) in arms.iter().enumerate() {
            let stats = run_baseline(&cfg, arm, STEPS);
            if *arm == "graph-llm" {
                cloud_cost = stats.resource_cost.mean();
            }
            row(paper_rows[i].0, &stats, paper_rows[i].1);
        }

        let eaco_cost = run_eaco(&cfg_for(profile, QosPreset::CostEfficient), STEPS);
        row(paper_rows[4].0, &eaco_cost, paper_rows[4].1);
        let eaco_delay = run_eaco(&cfg_for(profile, QosPreset::DelayOriented), STEPS);
        row(paper_rows[5].0, &eaco_delay, paper_rows[5].1);

        let cut_cost = 100.0 * (1.0 - eaco_cost.resource_cost.mean() / cloud_cost);
        let cut_delay = 100.0 * (1.0 - eaco_delay.resource_cost.mean() / cloud_cost);
        println!(
            "\ncost reduction vs 72B+GraphRAG: cost-efficient {:.1}% (paper: {}), delay-oriented {:.1}% (paper: {})",
            cut_cost,
            if profile == Profile::Wiki { "84.6%" } else { "81.2%" },
            cut_delay,
            if profile == Profile::Wiki { "65.3%" } else { "32.9%" },
        );
    }
}
