//! **Figure 2** — LLM-only trade-offs vs model size (paper §2): left,
//! parameters vs inference TFLOPs; right, parameters vs accuracy and
//! generation delay. Shape: cost grows ~linearly in parameters, accuracy
//! saturates, delay grows.

#[path = "common/mod.rs"]
mod common;

use common::banner;
use eaco_rag::corpus::{Corpus, Profile};
use eaco_rag::cost::inference_tflops;
use eaco_rag::gating::GenLoc;
use eaco_rag::oracle::{ContextSource, Oracle};
use eaco_rag::sim::strategy::GenRates;
use eaco_rag::sim::tier_defaults;

fn main() {
    banner(
        "Figure 2 — model size vs cost / accuracy / delay (LLM-only)",
        "EACO-RAG paper §2, Figure 2 (TriviaQA-like general-domain profile)",
    );
    let corpus = Corpus::generate(Profile::Wiki, 42);
    let oracle = Oracle::new(42);
    let rates = GenRates::default();
    // Typical LLM-only token counts (paper Table 1).
    let (in_tok, out_tok) = (16.0, 27.2);

    println!(
        "{:<10} {:>10} {:>12} {:>12} {:>12}",
        "tier", "params(B)", "TFLOPs/query", "accuracy(%)", "delay(s)"
    );
    println!("{}", "-".repeat(62));
    let mut last_acc = 0.0;
    let mut last_cost = 0.0;
    for tier in ["qwen05b", "qwen15b", "qwen3b", "qwen7b", "qwen72b"] {
        let (params_b, capability) = tier_defaults(tier).unwrap();
        let cost = inference_tflops(params_b, in_tok, out_tok);
        let acc = oracle.expected_accuracy(&corpus, capability, ContextSource::None, |_| vec![]);
        let delay = rates.gen_seconds(GenLoc::EdgeSlm, params_b, in_tok, out_tok);
        println!(
            "{tier:<10} {params_b:>10.1} {cost:>12.2} {:>12.2} {delay:>12.2}",
            acc * 100.0
        );
        // Shape assertions for the regenerated figure.
        assert!(cost > last_cost, "cost must grow with size");
        assert!(acc + 1e-9 >= last_acc, "accuracy must not decrease");
        last_cost = cost;
        last_acc = acc;
    }
    println!("\nshape check: cost linear in params; accuracy saturating; delay rising (paper Fig. 2)");
}
