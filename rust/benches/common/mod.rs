//! Shared helpers for the paper-reproduction benches (criterion is not
//! available offline; each bench is `harness = false` and prints a
//! paper-vs-measured table — see DESIGN.md §5).

use eaco_rag::config::{QosPreset, SystemConfig};
use eaco_rag::corpus::Profile;
use eaco_rag::sim::{workload_for, KnowledgeMode, RunStats, SimSystem};
use eaco_rag::workload::Workload;

/// Standard experiment scale: long enough for the gate to exploit,
/// short enough for `cargo bench` to stay minutes-scale.
pub const STEPS: usize = 1200;

pub fn banner(title: &str, paper_ref: &str) {
    println!("\n================================================================");
    println!("{title}");
    println!("reproduces: {paper_ref}");
    println!("================================================================");
}

pub fn cfg_for(dataset: Profile, qos: QosPreset) -> SystemConfig {
    let mut cfg = SystemConfig::default();
    cfg.dataset = dataset;
    cfg.qos = qos;
    cfg.warmup_steps = match dataset {
        Profile::Wiki => 300,       // paper Table 5: best wiki T0
        Profile::HarryPotter => 500, // paper Table 5: best hp T0
    };
    cfg
}

/// Run one fixed-strategy baseline.
pub fn run_baseline(cfg: &SystemConfig, arm_name: &str, steps: usize) -> RunStats {
    let mut sys = SimSystem::new(cfg.clone(), KnowledgeMode::Static);
    let wl = Workload::generate(&sys.corpus, workload_for(cfg, steps), cfg.seed);
    sys.run_baseline(&wl, SimSystem::baseline_arm(arm_name).unwrap())
}

/// Run EACO-RAG (adaptive + gate).
pub fn run_eaco(cfg: &SystemConfig, steps: usize) -> RunStats {
    let mut sys = SimSystem::new(cfg.clone(), KnowledgeMode::Adaptive);
    let wl = Workload::generate(&sys.corpus, workload_for(cfg, steps), cfg.seed);
    sys.run_eaco(&wl).0
}

/// Print one comparison row: measured vs the paper's reported value.
pub fn row(label: &str, measured: &RunStats, paper: &str) {
    println!(
        "{label:<28} {:>6.2}%  {:>6.2}s  {:>9.2} TFLOPs   | paper: {paper}",
        measured.accuracy * 100.0,
        measured.delay.mean(),
        measured.resource_cost.mean(),
    );
}

pub fn header() {
    println!(
        "{:<28} {:>7} {:>8} {:>16}   | paper (acc%, delay s, cost TFLOPs)",
        "system", "acc", "delay", "cost"
    );
    println!("{}", "-".repeat(100));
}
