//! **§Perf** — hot-path micro-benchmarks for the L3 coordinator plus the
//! real PJRT execution path (criterion substitute; see DESIGN.md §7).
//!
//! Measured here and tracked in EXPERIMENTS.md §Perf:
//!   * gate decision latency vs GP observation count (target ≪ 1 ms)
//!   * GP posterior update (incremental Cholesky extend)
//!   * edge keyword retrieval + overlap scan
//!   * vector-store top-k scan rate
//!   * dynamic batcher push/flush throughput
//!   * PJRT LM forward (b1 vs b8 — batching amortization) and embedder
//!     (skipped with a notice if artifacts/ is absent)

use std::path::PathBuf;

use eaco_rag::config::SystemConfig;
use eaco_rag::corpus::{Corpus, Profile};
use eaco_rag::coordinator::batcher::{DynamicBatcher, GenRequest};
use eaco_rag::edge::EdgeNode;
use eaco_rag::gating::safeobo::{Observation, Qos, SafeObo};
use eaco_rag::gating::{standard_arms, GateContext};
use eaco_rag::runtime::{FeatureHasher, Runtime, Tokenizer};
use eaco_rag::util::rng::Rng;
use eaco_rag::util::stats::bench;
use eaco_rag::vecstore::VecStore;

fn ctx(rng: &mut Rng) -> GateContext {
    GateContext {
        cloud_delay_ms: 250.0 + rng.f64() * 150.0,
        edge_delay_ms: 15.0 + rng.f64() * 10.0,
        best_overlap: rng.f64(),
        best_edge_is_local: rng.chance(0.5),
        local_overlap: rng.f64(),
        hops: 1 + rng.below(3),
        length_tokens: 8 + rng.below(20),
        entity_count: 2 + rng.below(5),
    }
}

fn main() {
    println!("\n=== §Perf hot-path benchmarks ===\n");

    // --- gate decision latency vs observation count ---
    for n_obs in [100usize, 300, 500] {
        let mut gate = SafeObo::new(
            standard_arms(),
            Qos { min_accuracy: 0.85, max_delay_s: 5.0 },
            0,
            0.5,
            1,
        );
        let mut rng = Rng::new(2);
        for _ in 0..n_obs {
            let c = ctx(&mut rng);
            let arm = rng.below(5);
            gate.observe(
                &c,
                arm,
                Observation {
                    resource_cost: rng.f64() * 100.0,
                    delay_cost: rng.f64() * 5.0,
                    accuracy: if rng.chance(0.8) { 1.0 } else { 0.0 },
                    delay_s: rng.f64() * 3.0,
                },
            );
        }
        let mut rng2 = Rng::new(3);
        let r = bench(&format!("gate.decide @ {n_obs} obs"), 200, || {
            let c = ctx(&mut rng2);
            std::hint::black_box(gate.decide(&c));
        });
        println!("{r}");
    }

    // --- GP posterior update (incremental) ---
    {
        let mut gate = SafeObo::new(
            standard_arms(),
            Qos { min_accuracy: 0.85, max_delay_s: 5.0 },
            0,
            0.5,
            1,
        );
        let mut rng = Rng::new(4);
        let r = bench("gate.observe (incremental Cholesky)", 400, || {
            let c = ctx(&mut rng);
            let arm = rng.below(5);
            gate.observe(
                &c,
                arm,
                Observation {
                    resource_cost: 10.0,
                    delay_cost: 0.5,
                    accuracy: 1.0,
                    delay_s: 0.5,
                },
            );
        });
        println!("{r}");
    }

    // --- edge retrieval ---
    {
        let corpus = Corpus::generate(Profile::Wiki, 1);
        let cfg = SystemConfig::default();
        let mut edge = EdgeNode::new(0, cfg.edge_capacity);
        let all: Vec<usize> = (0..corpus.chunks.len().min(1000)).collect();
        edge.apply_update(&corpus, &all);
        let mut rng = Rng::new(5);
        let qas: Vec<_> = corpus.qa.iter().collect();
        let r = bench("edge.retrieve top-6 (1000-chunk store)", 2000, || {
            let qa = qas[rng.below(qas.len())];
            let kws = corpus.qa_keywords(qa);
            std::hint::black_box(edge.retrieve(&kws, 6));
        });
        println!("{r}");
        let r = bench("edge.overlap_ratio", 2000, || {
            let qa = qas[rng.below(qas.len())];
            let kws = corpus.qa_keywords(qa);
            std::hint::black_box(edge.overlap_ratio(&kws));
        });
        println!("{r}");
    }

    // --- vector store scan ---
    {
        let mut vs = VecStore::new(64);
        let mut rng = Rng::new(6);
        for i in 0..2000 {
            let v: Vec<f32> = (0..64).map(|_| rng.normal() as f32).collect();
            vs.insert(i, &v);
        }
        let q: Vec<f32> = (0..64).map(|_| rng.normal() as f32).collect();
        let r = bench("vecstore.top_k(8) over 2000×64", 500, || {
            std::hint::black_box(vs.top_k(&q, 8));
        });
        println!("{r}");
        let bytes = 2000.0 * 64.0 * 4.0;
        println!(
            "  -> effective scan rate {:.2} GB/s",
            bytes / r.mean_ns
        );
    }

    // --- batcher throughput ---
    {
        let mut b = DynamicBatcher::new(8, 50.0);
        let mut i = 0usize;
        let r = bench("batcher.push (amortized flush@8)", 20_000, || {
            i += 1;
            std::hint::black_box(b.push(GenRequest {
                request_id: i,
                tier: "qwen3b".into(),
                prompt: String::new(),
                max_new: 4,
                enqueued_ms: i as f64,
            }));
        });
        println!("{r}");
    }

    // --- real PJRT path ---
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("\n(artifacts/ missing — PJRT section skipped; run `make artifacts`)");
        return;
    }
    let mut rt = Runtime::open(&dir).expect("runtime");
    for name in ["slm_qwen3b_b1", "slm_qwen3b_b8", "slm_qwen72b_b8", "embedder_b8"] {
        rt.load(name).expect(name);
    }
    let tok = Tokenizer::new(512, 64);
    let row = tok.encode("what spell unlocks the door");
    let r = bench("PJRT lm forward qwen3b b1", 200, || {
        std::hint::black_box(rt.lm_logits("slm_qwen3b_b1", &row).unwrap());
    });
    println!("{r}");
    let mut batch8 = Vec::new();
    for _ in 0..8 {
        batch8.extend(row.iter().copied());
    }
    let r8 = bench("PJRT lm forward qwen3b b8", 200, || {
        std::hint::black_box(rt.lm_logits("slm_qwen3b_b8", &batch8).unwrap());
    });
    println!("{r8}");
    println!(
        "  -> batching amortization: b8 per-row cost is {:.2}x of b1",
        r8.mean_ns / 8.0 / r.mean_ns
    );
    let r72 = bench("PJRT lm forward qwen72b b8", 100, || {
        std::hint::black_box(rt.lm_logits("slm_qwen72b_b8", &batch8).unwrap());
    });
    println!("{r72}");
    let h = FeatureHasher::new(256);
    let feats: Vec<Vec<f32>> = (0..8)
        .map(|i| h.features(&format!("sample text number {i}")))
        .collect();
    let re = bench("PJRT embedder b8", 200, || {
        std::hint::black_box(rt.embed("embedder_b8", &feats).unwrap());
    });
    println!("{re}");
}
