//! **§Perf** — hot-path micro-benchmarks for the L3 coordinator plus the
//! real PJRT execution path (criterion substitute; see DESIGN.md §7).
//!
//! Measured here, tracked in EXPERIMENTS.md §Perf, and **emitted as a
//! machine-readable trajectory file** (`BENCH_PR10.json` at the repo
//! root — see `make bench-json`, `BENCH_OUT=` to override) so every
//! future PR has a baseline to beat:
//!   * gate decision latency vs GP observation count (target ≪ 1 ms)
//!   * GP posterior update (incremental Cholesky extend) and predict at
//!     large observation windows (2k default; 10k with EACO_BENCH_FULL=1)
//!   * edge keyword retrieval + overlap scan
//!   * cluster summary routing at 4/16/64 edges — bounded-degree and
//!     full-mesh probes vs the retained `best_edge_for` all-edges
//!     index broadcast (the committed PR-2 before/after evidence)
//!   * vector-store top-k at 2k / 100k / 1M × 64-dim rows — heap scan
//!     (auto-sharded at ≥16k rows), serial scan, and the pre-PR
//!     full-sort reference, with effective GB/s
//!   * IVF ANN top-k over the same 100k / 1M stores at nprobe 1/4/8 —
//!     the sublinear path next to its flat-scan reference
//!   * serving plane: `serve.enqueue` (bounded priority-queue push/pop)
//!     and `serve.drain 4edges` (a full collaborative workload through
//!     the async event loop per iteration)
//!   * chaos plane: `chaos.inject` (fault-event apply micro — topology
//!     rewires + link multipliers) and `serve.drain 4edges
//!     +flaky-uplink` (the same drain under a scripted degrade/restore)
//!   * staged pipeline: `pipeline.serve 4edges` — the serve.drain
//!     workload through the SafeOBO-gated `pipeline::gated_step` path
//!     (gate decide/observe + retrieve + grade + update per query)
//!   * adaptive feedback: `cluster.gossip_feedback 4edges` — the same
//!     gated workload with `[cluster] feedback = "hit-rate"`, pricing
//!     the closed loop (outcome folds + per-link budgets + blended
//!     digest re-rank) against the `pipeline.serve 4edges` row
//!   * dynamic batcher push/flush throughput
//!   * PJRT LM forward (b1 vs b8 — batching amortization) and embedder
//!     (skipped with a notice if artifacts/ is absent)
//!
//! Env knobs: `EACO_BENCH_OUT` overrides the JSON output path;
//! `EACO_BENCH_FULL=1` adds the slow scenarios (10k GP window);
//! `EACO_BENCH_SMOKE=1` runs one tiny iteration per family (the CI
//! `make bench-smoke` wiring — proves the harness runs, nothing more).

use std::path::PathBuf;

use eaco_rag::chaos::{injector, FaultEvent, LinkSel};
use eaco_rag::cluster::EdgeCluster;
use eaco_rag::config::{ClusterConfig, SystemConfig};
use eaco_rag::corpus::{ChunkId, Corpus, Profile};
use eaco_rag::coordinator::batcher::{DynamicBatcher, GenRequest};
use eaco_rag::edge::{best_edge_for, EdgeNode};
use eaco_rag::netsim::{NetSim, NetSpec};
use eaco_rag::gating::gp::{Gp, GpScratch, Kernel};
use eaco_rag::gating::safeobo::{Observation, Qos, SafeObo};
use eaco_rag::gating::{standard_arms, GateContext};
use eaco_rag::runtime::{FeatureHasher, Runtime, Tokenizer};
use eaco_rag::serve::queue::{EdgeQueue, QueuedRequest};
use eaco_rag::serve::Driver;
use eaco_rag::sim::{workload_for, KnowledgeMode, SimSystem};
use eaco_rag::testutil::artifacts_dir;
use eaco_rag::util::json::Json;
use eaco_rag::util::rng::Rng;
use eaco_rag::util::stats::{bench, BenchResult};
use eaco_rag::vecstore::ivf::{IvfParams, IvfStore};
use eaco_rag::vecstore::VecStore;
use eaco_rag::workload::Workload;

fn ctx(rng: &mut Rng) -> GateContext {
    GateContext {
        cloud_delay_ms: 250.0 + rng.f64() * 150.0,
        edge_delay_ms: 15.0 + rng.f64() * 10.0,
        best_overlap: rng.f64(),
        best_edge_is_local: rng.chance(0.5),
        local_overlap: rng.f64(),
        neighbor_overlap: rng.f64(),
        hops: 1 + rng.below(3),
        length_tokens: 8 + rng.below(20),
        entity_count: 2 + rng.below(5),
    }
}

/// Collects results for the trajectory file while echoing the human
/// table to stdout.
struct Report {
    entries: Vec<Json>,
}

impl Report {
    fn new() -> Report {
        Report { entries: Vec::new() }
    }

    fn push(&mut self, r: &BenchResult) {
        println!("{r}");
        self.entries.push(r.to_json());
    }

    /// Record a scan-rate entry: same schema plus `"gbps"`.
    fn push_scan(&mut self, r: &BenchResult, bytes_per_iter: f64) {
        println!("{r}");
        let gbps = bytes_per_iter / r.mean_ns; // bytes/ns == GB/s
        println!("  -> effective scan rate {gbps:.2} GB/s");
        let mut j = r.to_json();
        if let Json::Obj(o) = &mut j {
            o.insert("gbps".to_string(), Json::Num(gbps));
        }
        self.entries.push(j);
    }

    fn write(&self) {
        let out = std::env::var_os("EACO_BENCH_OUT")
            .map(PathBuf::from)
            .unwrap_or_else(|| {
                // rust/ → repo root.
                PathBuf::from(env!("CARGO_MANIFEST_DIR"))
                    .parent()
                    .expect("manifest dir has a parent")
                    .join("BENCH_PR10.json")
            });
        let doc = Json::Arr(self.entries.clone());
        match std::fs::write(&out, doc.to_string() + "\n") {
            Ok(()) => println!("\nwrote {} ({} entries)", out.display(), self.entries.len()),
            Err(e) => eprintln!("\nWARNING: could not write {}: {e}", out.display()),
        }
    }
}

fn random_store(rows: usize, dim: usize, rng: &mut Rng) -> VecStore {
    let mut vs = VecStore::with_capacity(dim, rows);
    let mut v = vec![0.0f32; dim];
    for i in 0..rows {
        for x in v.iter_mut() {
            *x = rng.normal() as f32;
        }
        vs.insert(i, &v);
    }
    vs
}

fn bench_vecstore(report: &mut Report, rows: usize, iters: usize, fullsort_iters: usize) {
    let dim = 64;
    let mut rng = Rng::new(6 + rows as u64);
    let vs = random_store(rows, dim, &mut rng);
    let q: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
    let bytes = (rows * dim * 4) as f64;
    let label = if rows >= 1_000_000 {
        format!("{}m", rows / 1_000_000)
    } else {
        format!("{}k", rows / 1000)
    };

    let r = bench(&format!("vecstore.top_k8 {label}x64"), iters, || {
        std::hint::black_box(vs.top_k(&q, 8));
    });
    report.push_scan(&r, bytes);

    let r = bench(&format!("vecstore.top_k8_serial {label}x64"), iters, || {
        std::hint::black_box(vs.top_k_serial(&q, 8));
    });
    report.push_scan(&r, bytes);

    let r = bench(
        &format!("vecstore.top_k8_fullsort {label}x64"),
        fullsort_iters,
        || {
            std::hint::black_box(vs.top_k_fullsort(&q, 8));
        },
    );
    report.push_scan(&r, bytes);

    let r = bench(&format!("vecstore.above_threshold {label}x64"), iters, || {
        std::hint::black_box(vs.above_threshold(&q, 0.5));
    });
    report.push_scan(&r, bytes);
}

/// IVF ANN sweeps over the same random stores as the flat scans (same
/// seed stream as [`bench_vecstore`], so rows match bit-for-bit): build
/// once per (rows, nlist), then sweep nprobe. Effective bytes/iter is
/// the probed share of the slabs, `rows/nlist · nprobe · dim · 4`.
fn bench_ivf(report: &mut Report, rows: usize, iters: usize, nlist: usize) {
    let dim = 64;
    let mut rng = Rng::new(6 + rows as u64);
    let vs = random_store(rows, dim, &mut rng);
    let q: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
    let label = if rows >= 1_000_000 {
        format!("{}M", rows / 1_000_000)
    } else {
        format!("{}k", rows / 1000)
    };
    let t0 = std::time::Instant::now();
    let ivf = IvfStore::from_flat(vs, IvfParams { nlist, ..IvfParams::default() });
    println!(
        "(ivf build {label}x64 nlist{nlist}: {:.0} ms, {} lists)",
        t0.elapsed().as_secs_f64() * 1e3,
        ivf.nlist_eff(),
    );
    for nprobe in [1usize, 4, 8] {
        let bytes = (rows / nlist * nprobe * dim * 4) as f64;
        let r = bench(
            &format!("vecstore.ivf_top_k8 {label}x64 nprobe{nprobe}"),
            iters,
            || {
                std::hint::black_box(ivf.top_k_with(&q, 8, nprobe));
            },
        );
        report.push_scan(&r, bytes);
    }
}

/// Provision an n-edge cluster (chunks striped round-robin, ~200 per
/// store) and bench query routing three ways: bounded-degree summary
/// probes, full-mesh summary probes, and the retained `best_edge_for`
/// all-edges keyword-index broadcast (the pre-PR2 serving path).
fn bench_cluster_routing(report: &mut Report, num_edges: usize, iters: usize) {
    let corpus = Corpus::generate(Profile::Wiki, 3);
    let net = NetSim::new(num_edges, NetSpec::default(), 9);
    let ccfg = ClusterConfig::default();
    let cap = 200;
    let provision = |cluster: &mut EdgeCluster| {
        for e in 0..num_edges {
            let chunks: Vec<ChunkId> = corpus
                .chunks
                .iter()
                .filter(|c| c.id % num_edges == e)
                .take(cap)
                .map(|c| c.id)
                .collect();
            cluster.nodes[e].apply_update(&corpus, &chunks);
        }
    };
    let mut deg2 = EdgeCluster::new(
        &ccfg, None, num_edges, cap, corpus.spec.topics, corpus.chunks.len(), &net,
    );
    provision(&mut deg2);
    let mut full = EdgeCluster::new(
        &ccfg,
        Some(num_edges - 1),
        num_edges,
        cap,
        corpus.spec.topics,
        corpus.chunks.len(),
        &net,
    );
    provision(&mut full);

    let qas: Vec<_> = corpus.qa.iter().collect();
    // One fresh Rng per scenario, same seed: all three replay the
    // identical query/local-edge sequence, so the before/after ratio
    // compares like with like.
    let rng_seed = 12 + num_edges as u64;
    let mut rng = Rng::new(rng_seed);
    let deg_name = format!("cluster.route deg{} {num_edges} edges", deg2.topology.degree);
    let r = bench(&deg_name, iters, || {
        let qa = qas[rng.below(qas.len())];
        let kws = corpus.qa_keywords(qa);
        let local = rng.below(num_edges);
        std::hint::black_box(deg2.route(local, &kws));
    });
    report.push(&r);
    let mut rng = Rng::new(rng_seed);
    let r = bench(&format!("cluster.route full-mesh {num_edges} edges"), iters, || {
        let qa = qas[rng.below(qas.len())];
        let kws = corpus.qa_keywords(qa);
        let local = rng.below(num_edges);
        std::hint::black_box(full.route(local, &kws));
    });
    report.push(&r);
    let mut rng = Rng::new(rng_seed);
    let r = bench(
        &format!("cluster.best_edge_for_broadcast_ref {num_edges} edges"),
        iters,
        || {
            let qa = qas[rng.below(qas.len())];
            let kws = corpus.qa_keywords(qa);
            let local = rng.below(num_edges);
            std::hint::black_box(best_edge_for(&full.nodes, local, &kws));
        },
    );
    report.push(&r);
}

/// Build a GP with `n` observations over a 4-d feature space, then
/// bench predict (shared scratch) and steady-state observe.
fn bench_gp_window(report: &mut Report, n: usize, predict_iters: usize) {
    let mut gp = Gp::new(
        Kernel {
            sf2: 0.5,
            length_scale: 0.7,
            noise: 0.05,
        },
        0.0,
        n,
    );
    let mut rng = Rng::new(40 + n as u64);
    // Fill to just under the window so observe below doesn't trim.
    for _ in 0..n - 1 {
        let x = vec![rng.f64(), rng.f64(), rng.f64(), rng.f64()];
        let y = x[0] - x[1] + 0.1 * rng.normal();
        gp.observe(x, y);
    }
    let mut scratch = GpScratch::default();
    let probe = vec![0.4, 0.6, 0.2, 0.8];
    let r = bench(&format!("gp.predict @ {n} window"), predict_iters, || {
        std::hint::black_box(gp.predict_with(&probe, &mut scratch));
    });
    report.push(&r);
}

fn bench_serve(report: &mut Report, iters: usize, drain_iters: usize) {
    // Queue micro: the bounded per-edge structure on the wall-clock
    // path — push + pop round trip across the priority lanes.
    {
        let mut q = EdgeQueue::new(0);
        let mut rng = Rng::new(17);
        let mut seq = 0usize;
        let r = bench("serve.enqueue (push+pop, 3 lanes)", iters, || {
            seq += 1;
            q.push(QueuedRequest {
                seq,
                qa_id: seq % 571,
                edge_id: seq % 4,
                step: seq,
                priority: (rng.below(3)) as u8,
                arrival_ms: seq as f64,
            });
            std::hint::black_box(q.pop());
        });
        report.push(&r);
    }

    // Event-loop drain: a fresh collaborative system per iteration,
    // fully drained through serve_workload — the end-to-end cost of
    // the serving plane itself (dominated by retrieval + gating, so
    // compare against the `eaco-cluster` rows, not absolute zero).
    {
        let cfg = SystemConfig {
            num_edges: 4,
            edge_capacity: 200,
            warmup_steps: 30,
            ..SystemConfig::default()
        };
        let arm = eaco_rag::gating::Arm {
            retrieval: eaco_rag::gating::Retrieval::EdgeAssisted,
            gen: eaco_rag::gating::GenLoc::EdgeSlm,
        };
        let r = bench("serve.drain 4edges (120-step workload)", drain_iters, || {
            let mut sys = SimSystem::new(cfg.clone(), KnowledgeMode::Collaborative);
            let wl = Workload::generate(&sys.corpus, workload_for(&cfg, 120), cfg.seed);
            std::hint::black_box(sys.serve_async(&wl, Driver::Fixed(arm)));
        });
        report.push(&r);
    }
}

fn bench_chaos(report: &mut Report, inject_iters: usize, drain_iters: usize) {
    // Event-apply micro: one full fault cycle per iteration — partition
    // + heal (two grouped topology rewires), an uplink degrade/restore
    // (link-multiplier writes), and a kill/revive pair (store wipe +
    // rewire). This is the fixed cost a scheduled fault adds to the
    // event loop.
    {
        let corpus = Corpus::generate(Profile::Wiki, 3);
        let net0 = NetSim::new(8, NetSpec::default(), 9);
        let mut cluster = EdgeCluster::new(
            &ClusterConfig::default(),
            Some(3),
            8,
            200,
            corpus.spec.topics,
            corpus.chunks.len(),
            &net0,
        );
        let mut net = NetSim::new(8, NetSpec::default(), 9);
        let cycle = [
            FaultEvent::Partition(vec![vec![0, 1, 2, 3], vec![4, 5, 6, 7]]),
            FaultEvent::HealPartition,
            FaultEvent::DegradeLink { sel: LinkSel::AllUplinks, factor: 8.0 },
            FaultEvent::RestoreLink { sel: LinkSel::AllUplinks },
            FaultEvent::KillEdge(5),
            FaultEvent::ReviveEdge(5),
        ];
        let r = bench("chaos.inject (event apply micro, 8 edges)", inject_iters, || {
            for ev in &cycle {
                injector::apply(ev, &mut cluster, &mut net);
            }
            std::hint::black_box(cluster.partitioned());
        });
        report.push(&r);
    }

    // Drain under faults: the serve.drain workload with a scripted
    // flaky-uplink mid-run — what the probe/injector hooks cost on top
    // of the fault-free drain above.
    {
        let mut cfg = SystemConfig {
            num_edges: 4,
            edge_capacity: 200,
            warmup_steps: 30,
            ..SystemConfig::default()
        };
        cfg.chaos.enabled = true;
        cfg.chaos.scenario = "flaky-uplink".into();
        cfg.chaos.at_step = 20;
        cfg.chaos.duration_steps = 60;
        let arm = eaco_rag::gating::Arm {
            retrieval: eaco_rag::gating::Retrieval::EdgeAssisted,
            gen: eaco_rag::gating::GenLoc::EdgeSlm,
        };
        let r = bench("serve.drain 4edges +flaky-uplink", drain_iters, || {
            let mut sys = SimSystem::new(cfg.clone(), KnowledgeMode::Collaborative);
            let wl = Workload::generate(&sys.corpus, workload_for(&cfg, 120), cfg.seed);
            std::hint::black_box(sys.serve_async(&wl, Driver::Fixed(arm)));
        });
        report.push(&r);
    }
}

/// The staged-pipeline family: the serve.drain workload driven through
/// `pipeline::gated_step` (Driver::Gated) — gate decide + retrieve +
/// generate + grade + observe + knowledge update per query, with the
/// StatsSink/ServeMetrics folds on the event stream. Compare against
/// `serve.drain 4edges` (Driver::Fixed) to read the gate's share.
fn bench_pipeline(report: &mut Report, drain_iters: usize) {
    let cfg = SystemConfig {
        num_edges: 4,
        edge_capacity: 200,
        warmup_steps: 30,
        ..SystemConfig::default()
    };
    let r = bench("pipeline.serve 4edges (120-step gated workload)", drain_iters, || {
        let mut sys = SimSystem::new(cfg.clone(), KnowledgeMode::Collaborative);
        let wl = Workload::generate(&sys.corpus, workload_for(&cfg, 120), cfg.seed);
        std::hint::black_box(sys.serve_async(&wl, Driver::Gated));
    });
    report.push(&r);
}

/// The adaptive-feedback family: the pipeline.serve workload with
/// `[cluster] feedback = "hit-rate"` — every query additionally folds
/// its tier/hit verdict into the feedback counters and every gossip
/// round computes per-link budgets + the blended digest re-rank.
/// Compare against `pipeline.serve 4edges` to read the loop's share.
fn bench_gossip_feedback(report: &mut Report, drain_iters: usize) {
    let mut cfg = SystemConfig {
        num_edges: 4,
        edge_capacity: 200,
        warmup_steps: 30,
        ..SystemConfig::default()
    };
    cfg.cluster.feedback = eaco_rag::cluster::feedback::FeedbackMode::HitRate;
    let r = bench(
        "cluster.gossip_feedback 4edges (120-step gated workload, hit-rate budgets)",
        drain_iters,
        || {
            let mut sys = SimSystem::new(cfg.clone(), KnowledgeMode::Collaborative);
            let wl = Workload::generate(&sys.corpus, workload_for(&cfg, 120), cfg.seed);
            std::hint::black_box(sys.serve_async(&wl, Driver::Gated));
        },
    );
    report.push(&r);
}

fn main() {
    println!("\n=== §Perf hot-path benchmarks ===\n");
    let full = std::env::var("EACO_BENCH_FULL").map(|v| v == "1").unwrap_or(false);
    let mut report = Report::new();

    // Smoke mode: one tiny iteration per family (CI `make bench-smoke`)
    // — proves the harness builds and runs end to end; the numbers are
    // not worth reading. 12k rows keeps the IVF store above its
    // exact-scan threshold so the ANN path itself is exercised.
    let smoke = std::env::var("EACO_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false);
    if smoke {
        println!("(EACO_BENCH_SMOKE=1: tiny workloads, 1 iteration each)");
        bench_vecstore(&mut report, 2000, 1, 1);
        bench_ivf(&mut report, 12_000, 1, 8);
        bench_cluster_routing(&mut report, 4, 1);
        bench_serve(&mut report, 1, 1);
        bench_chaos(&mut report, 1, 1);
        bench_pipeline(&mut report, 1);
        bench_gossip_feedback(&mut report, 1);
        report.write();
        return;
    }

    // --- gate decision latency vs observation count ---
    for n_obs in [100usize, 300, 500] {
        let mut gate = SafeObo::new(
            standard_arms(),
            Qos { min_accuracy: 0.85, max_delay_s: 5.0 },
            0,
            0.5,
            1,
        );
        let mut rng = Rng::new(2);
        for _ in 0..n_obs {
            let c = ctx(&mut rng);
            let arm = rng.below(5);
            gate.observe(
                &c,
                arm,
                Observation {
                    resource_cost: rng.f64() * 100.0,
                    delay_cost: rng.f64() * 5.0,
                    accuracy: if rng.chance(0.8) { 1.0 } else { 0.0 },
                    delay_s: rng.f64() * 3.0,
                },
            );
        }
        let mut rng2 = Rng::new(3);
        let r = bench(&format!("gate.decide @ {n_obs} obs"), 200, || {
            let c = ctx(&mut rng2);
            std::hint::black_box(gate.decide(&c));
        });
        report.push(&r);
    }

    // --- GP posterior update (incremental) ---
    {
        let mut gate = SafeObo::new(
            standard_arms(),
            Qos { min_accuracy: 0.85, max_delay_s: 5.0 },
            0,
            0.5,
            1,
        );
        let mut rng = Rng::new(4);
        let r = bench("gate.observe (incremental Cholesky)", 400, || {
            let c = ctx(&mut rng);
            let arm = rng.below(5);
            gate.observe(
                &c,
                arm,
                Observation {
                    resource_cost: 10.0,
                    delay_cost: 0.5,
                    accuracy: 1.0,
                    delay_s: 0.5,
                },
            );
        });
        report.push(&r);
    }

    // --- GP predict at large observation windows ---
    bench_gp_window(&mut report, 2000, 100);
    if full {
        bench_gp_window(&mut report, 10_000, 10);
    } else {
        println!("(EACO_BENCH_FULL=1 adds the 10k-window GP scenario)");
    }

    // --- edge retrieval ---
    {
        let corpus = Corpus::generate(Profile::Wiki, 1);
        let cfg = SystemConfig::default();
        let mut edge = EdgeNode::new(0, cfg.edge_capacity);
        let all: Vec<usize> = (0..corpus.chunks.len().min(1000)).collect();
        edge.apply_update(&corpus, &all);
        let mut rng = Rng::new(5);
        let qas: Vec<_> = corpus.qa.iter().collect();
        let r = bench("edge.retrieve top-6 (1000-chunk store)", 2000, || {
            let qa = qas[rng.below(qas.len())];
            let kws = corpus.qa_keywords(qa);
            std::hint::black_box(edge.retrieve(&kws, 6));
        });
        report.push(&r);
        let r = bench("edge.overlap_ratio", 2000, || {
            let qa = qas[rng.below(qas.len())];
            let kws = corpus.qa_keywords(qa);
            std::hint::black_box(edge.overlap_ratio(&kws));
        });
        report.push(&r);
    }

    // --- cluster summary routing vs the all-edges index broadcast ---
    bench_cluster_routing(&mut report, 4, 2000);
    bench_cluster_routing(&mut report, 16, 1000);
    bench_cluster_routing(&mut report, 64, 400);

    // --- vector store scans: paper-prototype scale and beyond ---
    bench_vecstore(&mut report, 2000, 500, 200);
    bench_vecstore(&mut report, 100_000, 50, 20);
    bench_vecstore(&mut report, 1_000_000, 10, 5);

    // --- IVF ANN: the sublinear path next to its flat references ---
    bench_ivf(&mut report, 100_000, 200, 64);
    bench_ivf(&mut report, 1_000_000, 50, 256);

    // --- serving plane: queue micro + full event-loop drain ---
    bench_serve(&mut report, 20_000, 5);

    // --- chaos plane: fault apply micro + drain under faults ---
    bench_chaos(&mut report, 2000, 5);

    // --- staged pipeline: the gated end-to-end path ---
    bench_pipeline(&mut report, 5);

    // --- adaptive feedback: the same path with hit-rate budgets ---
    bench_gossip_feedback(&mut report, 5);

    // --- batcher throughput ---
    {
        let mut b = DynamicBatcher::new(8, 50.0);
        let mut i = 0usize;
        let r = bench("batcher.push (amortized flush@8)", 20_000, || {
            i += 1;
            std::hint::black_box(b.push(GenRequest {
                request_id: i,
                tier: "qwen3b".into(),
                prompt: String::new(),
                max_new: 4,
                enqueued_ms: i as f64,
            }));
        });
        report.push(&r);
    }

    // --- real PJRT path (gated on artifacts) ---
    if let Some(dir) = artifacts_dir() {
        let mut rt = Runtime::open(&dir).expect("runtime");
        for name in ["slm_qwen3b_b1", "slm_qwen3b_b8", "slm_qwen72b_b8", "embedder_b8"] {
            rt.load(name).expect(name);
        }
        let tok = Tokenizer::new(512, 64);
        let row = tok.encode("what spell unlocks the door");
        let r = bench("PJRT lm forward qwen3b b1", 200, || {
            std::hint::black_box(rt.lm_logits("slm_qwen3b_b1", &row).unwrap());
        });
        report.push(&r);
        let mut batch8 = Vec::new();
        for _ in 0..8 {
            batch8.extend(row.iter().copied());
        }
        let r8 = bench("PJRT lm forward qwen3b b8", 200, || {
            std::hint::black_box(rt.lm_logits("slm_qwen3b_b8", &batch8).unwrap());
        });
        report.push(&r8);
        println!(
            "  -> batching amortization: b8 per-row cost is {:.2}x of b1",
            r8.mean_ns / 8.0 / r.mean_ns
        );
        let r72 = bench("PJRT lm forward qwen72b b8", 100, || {
            std::hint::black_box(rt.lm_logits("slm_qwen72b_b8", &batch8).unwrap());
        });
        report.push(&r72);
        let h = FeatureHasher::new(256);
        let feats: Vec<Vec<f32>> = (0..8)
            .map(|i| h.features(&format!("sample text number {i}")))
            .collect();
        let re = bench("PJRT embedder b8", 200, || {
            std::hint::black_box(rt.embed("embedder_b8", &feats).unwrap());
        });
        report.push(&re);
    }

    report.write();
}
