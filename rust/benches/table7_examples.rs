//! **Table 7** — illustrative QA traces through the collaborative gate
//! (paper §6.2): a simple single-hop query with full edge coverage stays
//! on the edge; a complex multi-hop query with poor coverage escalates
//! to cloud GraphRAG + the large model.

#[path = "common/mod.rs"]
mod common;

use common::*;
use eaco_rag::config::QosPreset;
use eaco_rag::corpus::Profile;
use eaco_rag::gating::GateContext;
use eaco_rag::sim::{workload_for, KnowledgeMode, SimSystem};
use eaco_rag::workload::Workload;

fn trace(
    gate: &mut eaco_rag::gating::safeobo::SafeObo,
    label: &str,
    question: &str,
    ctx: &GateContext,
) -> usize {
    let d = gate.decide(ctx);
    println!("\n{label}: {question}");
    println!(
        "  Context: {{{}-hop; {} tokens; {} entities; best edge overlap {:.0}% ({}), edge delay {:.0} ms; cloud delay {:.0} ms}}",
        ctx.hops,
        ctx.length_tokens,
        ctx.entity_count,
        ctx.best_overlap * 100.0,
        if ctx.best_edge_is_local { "local" } else { "remote edge" },
        ctx.edge_delay_ms,
        ctx.cloud_delay_ms
    );
    println!("  Safe set: {:?}", d.safe_set);
    for a in 0..gate.arms.len() {
        let ((am, asd), (dm, _), (cm, _)) = gate.predict_arm_full(ctx, a);
        println!(
            "    {:<18} acc {:.2}±{:.2}  delay {:.2}s  cost {:>8.1} TFLOPs{}",
            gate.arms[a].name(),
            am,
            asd,
            dm,
            cm,
            if a == d.arm_idx { "   <= DECISION" } else { "" }
        );
    }
    println!("  => Gate => Decision: {{{}}}", gate.arms[d.arm_idx].name());
    d.arm_idx
}

fn main() {
    banner(
        "Table 7 — illustrative gate decisions",
        "EACO-RAG paper §6.2, Table 7",
    );
    // Train a gate on the wiki workload. (The paper's two examples are
    // Harry Potter queries; on our synthetic HP profile the cross-topic
    // entity overlap decouples keyword overlap from chunk coverage, so
    // the honest gate keeps HP local arms uncertified — see
    // EXPERIMENTS.md §Table 7. The general-domain profile reproduces the
    // mechanism the table illustrates.)
    let cfg = cfg_for(Profile::Wiki, QosPreset::CostEfficient);
    let mut sys = SimSystem::new(cfg.clone(), KnowledgeMode::Adaptive);
    let wl = Workload::generate(&sys.corpus, workload_for(&cfg, STEPS), cfg.seed);
    let (_, mut gate) = sys.run_eaco(&wl);

    // Question 1 (paper): simple single-hop, full edge coverage.
    let q1 = GateContext {
        cloud_delay_ms: 300.0,
        edge_delay_ms: 20.0,
        best_overlap: 1.0,
        best_edge_is_local: true,
        local_overlap: 1.0,
        neighbor_overlap: 0.0,
        hops: 1,
        length_tokens: 15,
        entity_count: 3,
    };
    let a1 = trace(
        &mut gate,
        "Question 1 (paper: 'What is the name of the spell used to unlock doors?')",
        "single-hop, 100% edge match",
        &q1,
    );
    println!("  paper decision: {{Edge4 dataset + 3B SLM}}");

    // Question 2 (paper): complex multi-hop, poor edge coverage.
    let q2 = GateContext {
        cloud_delay_ms: 350.0,
        edge_delay_ms: 32.0,
        best_overlap: 0.25,
        best_edge_is_local: false,
        local_overlap: 0.1,
        neighbor_overlap: 0.25,
        hops: 3,
        length_tokens: 21,
        entity_count: 4,
    };
    let a2 = trace(
        &mut gate,
        "Question 2 (paper: 'What impact does Harry's friendship with Hermione have ...?')",
        "multi-hop, 25% edge match",
        &q2,
    );
    println!("  paper decision: {{Cloud GraphRAG + 72B LLM}}");

    // Shape checks: Q1 stays on the edge tier, Q2 escalates to cloud gen.
    let edge_gen = matches!(gate.arms[a1].gen, eaco_rag::gating::GenLoc::EdgeSlm);
    let cloud_gen = matches!(gate.arms[a2].gen, eaco_rag::gating::GenLoc::CloudLlm);
    println!(
        "\nshape check: Q1 edge-side generation = {edge_gen}, Q2 cloud generation = {cloud_gen}"
    );
    assert!(edge_gen, "Q1 should stay on the edge");
    assert!(cloud_gen, "Q2 should escalate to the cloud LLM");
}
