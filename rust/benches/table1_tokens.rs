//! **Table 1** — token utilization and inference cost of LLM-only vs
//! Naive RAG vs GraphRAG with a 3B model (paper §2). The shape to
//! reproduce: GraphRAG's input tokens ≫ Naive RAG ≫ LLM-only, and the
//! corresponding TFLOPs blow-up (the motivation for edge-side gating).

#[path = "common/mod.rs"]
mod common;

use common::*;
use eaco_rag::config::QosPreset;
use eaco_rag::corpus::Profile;

fn main() {
    banner(
        "Table 1 — token utilization & inference cost (3B model)",
        "EACO-RAG paper §2, Table 1",
    );
    let cfg = cfg_for(Profile::Wiki, QosPreset::CostEfficient);
    println!(
        "{:<12} {:>18} {:>18} {:>14}   | paper (in, out, TFLOPs)",
        "approach", "input tokens", "output tokens", "cost"
    );
    println!("{}", "-".repeat(96));
    for (arm, label, paper) in [
        ("llm-only", "LLM-only", "16.01±5.01, 27.21±14.83, ~0.65"),
        ("naive-rag", "Naive RAG", "3632±28.95, 26.59±19.81, ~22.98"),
        ("graph-slm", "GraphRAG", "9017±2529, 142.7±91.58, ~58.57"),
    ] {
        let stats = run_baseline(&cfg, arm, 600);
        println!(
            "{:<12} {:>9.1} ± {:<7.1} {:>9.1} ± {:<7.1} {:>9.2}   | {paper}",
            label,
            stats.in_tokens.mean(),
            stats.in_tokens.std(),
            stats.out_tokens.mean(),
            stats.out_tokens.std(),
            stats.resource_cost.mean(),
        );
    }
    println!(
        "\nshape check: GraphRAG input ≫ Naive ≫ LLM-only, cost ratios ≈ paper's 1 : 35 : 90"
    );
}
