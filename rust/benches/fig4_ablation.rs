//! **Figure 4** — ablation without the gate or cloud (paper §6.5):
//! (a) accuracy vs local adaptive-update trigger interval, with and
//!     without edge-assisted retrieval;
//! (b) accuracy vs edge chunk-store size, with and without edge-assist.
//!
//! Shapes to reproduce: frequent updates and bigger stores help; adding
//! edge-assisted retrieval flattens both sensitivities (converging near
//! 600 chunks vs ≥1000 without, per the paper).

#[path = "common/mod.rs"]
mod common;

use common::banner;
use eaco_rag::config::{QosPreset, SystemConfig};
use eaco_rag::corpus::Profile;
use eaco_rag::gating::{Arm, GenLoc, Retrieval};
use eaco_rag::sim::{workload_for, KnowledgeMode, SimSystem};
use eaco_rag::workload::Workload;

const STEPS: usize = 900;

fn run(cfg: &SystemConfig, edge_assist: bool) -> f64 {
    let mut sys = SimSystem::new(cfg.clone(), KnowledgeMode::Adaptive);
    let wl = Workload::generate(&sys.corpus, workload_for(cfg, STEPS), cfg.seed);
    let arm = Arm {
        retrieval: if edge_assist {
            Retrieval::EdgeAssisted
        } else {
            Retrieval::LocalNaive
        },
        gen: GenLoc::EdgeSlm,
    };
    sys.run_baseline(&wl, arm).accuracy
}

fn main() {
    banner(
        "Figure 4 — ablation: update interval & chunk-store size",
        "EACO-RAG paper §6.5, Figure 4 (gate and cloud removed)",
    );
    let base = || {
        let mut cfg = SystemConfig::default();
        cfg.dataset = Profile::HarryPotter;
        cfg.qos = QosPreset::CostEfficient;
        cfg.edge_capacity = 600;
        cfg
    };

    println!("\n(a) accuracy vs local update trigger interval (queries per update)");
    println!(
        "{:<12} {:>16} {:>16}",
        "interval", "local-only (%)", "edge-assist (%)"
    );
    let mut local_span = (1.0f64, 0.0f64);
    let mut assist_span = (1.0f64, 0.0f64);
    for trigger in [10usize, 20, 40, 80, 160] {
        let mut cfg = base();
        cfg.update_trigger = trigger;
        let lo = run(&cfg, false);
        let ea = run(&cfg, true);
        local_span = (local_span.0.min(lo), local_span.1.max(lo));
        assist_span = (assist_span.0.min(ea), assist_span.1.max(ea));
        println!("{trigger:<12} {:>16.2} {:>16.2}", lo * 100.0, ea * 100.0);
    }
    let local_sens = local_span.1 - local_span.0;
    let assist_sens = assist_span.1 - assist_span.0;
    println!(
        "sensitivity to interval: local-only {:.1} pts vs edge-assist {:.1} pts (paper: edge-assist reduces sensitivity)",
        local_sens * 100.0,
        assist_sens * 100.0
    );

    println!("\n(b) accuracy vs edge chunk-store size");
    println!(
        "{:<12} {:>16} {:>16}",
        "chunks", "local-only (%)", "edge-assist (%)"
    );
    let mut rows = Vec::new();
    for cap in [200usize, 400, 600, 800, 1000, 1200] {
        let mut cfg = base();
        cfg.edge_capacity = cap;
        let lo = run(&cfg, false);
        let ea = run(&cfg, true);
        rows.push((cap, lo, ea));
        println!("{cap:<12} {:>16.2} {:>16.2}", lo * 100.0, ea * 100.0);
    }
    // Shape: larger stores help; edge-assist converges earlier.
    let first = rows.first().unwrap();
    let last = rows.last().unwrap();
    println!(
        "\nshape check: accuracy rises with store size (local {:.1}→{:.1}, assist {:.1}→{:.1}); edge-assist converges earlier (paper: ~600 vs ≥1000 chunks)",
        first.1 * 100.0,
        last.1 * 100.0,
        first.2 * 100.0,
        last.2 * 100.0
    );
}
