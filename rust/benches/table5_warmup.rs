//! **Table 5** — effect of warm-up steps T₀ on EACO-RAG's gating
//! decisions (paper §6.3). Shape: more warm-up ⇒ better-trained GPs ⇒
//! fewer unnecessary cloud escalations ⇒ lower cost at equal or better
//! accuracy; the specialized HP domain needs more warm-up than wiki.

#[path = "common/mod.rs"]
mod common;

use common::*;
use eaco_rag::config::QosPreset;
use eaco_rag::corpus::Profile;

fn main() {
    banner(
        "Table 5 — impact of warm-up steps T0",
        "EACO-RAG paper §6.3, Table 5",
    );
    for (profile, t0s, paper) in [
        (
            Profile::Wiki,
            [300usize, 200, 100],
            ["94.92, 1.27, 109.40", "89.66, 1.26, 140.06", "87.22, 1.49, 346.29"],
        ),
        (
            Profile::HarryPotter,
            [500, 300, 100],
            ["78.00, 1.74, 139.43", "77.35, 1.12, 402.19", "74.44, 1.31, 511.60"],
        ),
    ] {
        println!("\n--- dataset: {} ---", profile.name());
        header();
        let mut costs = Vec::new();
        for (i, &t0) in t0s.iter().enumerate() {
            let mut cfg = cfg_for(profile, QosPreset::CostEfficient);
            cfg.warmup_steps = t0;
            let stats = run_eaco(&cfg, STEPS);
            costs.push(stats.resource_cost.mean());
            row(&format!("EACO-RAG-{t0}"), &stats, paper[i]);
        }
        // Shape: the largest warm-up should not be the most expensive.
        let max_cost = costs.iter().cloned().fold(0.0f64, f64::max);
        println!(
            "shape check: cost(T0={}) = {:.1} <= max over smaller T0 ({:.1})",
            t0s[0], costs[0], max_cost
        );
    }
}
