//! **Table 6** — EACO-RAG with different edge SLMs on Wiki QA (paper
//! §6.4). Shape: a stronger edge model (7B) resolves more queries
//! locally and can *reduce* total cost despite its higher per-call
//! expense; a weaker one (1.5B) escalates more; llama3.2-3B (pruned/
//! distilled ⇒ lower capability) underperforms qwen2.5-3B.

#[path = "common/mod.rs"]
mod common;

use common::*;
use eaco_rag::config::QosPreset;
use eaco_rag::corpus::Profile;

fn main() {
    banner(
        "Table 6 — EACO-RAG with various edge SLMs (Wiki QA)",
        "EACO-RAG paper §6.4, Table 6",
    );
    header();
    let mut acc = std::collections::BTreeMap::new();
    for (tier, label, paper) in [
        ("qwen7b", "Qwen2.5 7B", "94.57, 1.48, 93.83"),
        ("qwen3b", "Qwen2.5 3B", "94.92, 1.27, 109.40"),
        ("llama3b", "llama3.2 3B", "93.35, 1.07, 272.72"),
        ("qwen15b", "Qwen2.5 1.5B", "91.42, 0.95, 167.67"),
    ] {
        let mut cfg = cfg_for(Profile::Wiki, QosPreset::CostEfficient);
        cfg.edge_tier = tier.to_string();
        let stats = run_eaco(&cfg, STEPS);
        acc.insert(tier, stats.accuracy);
        row(label, &stats, paper);
    }
    println!(
        "\nshape check: llama3.2-3B ({:.1}%) below Qwen2.5-3B ({:.1}%) — paper §6.4's training-recipe gap",
        acc["llama3b"] * 100.0,
        acc["qwen3b"] * 100.0
    );
}
