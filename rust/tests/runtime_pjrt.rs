//! Integration tests for the PJRT runtime: real artifact load, compile,
//! execute, generate. Requires `make artifacts` (tests skip otherwise,
//! loudly).

use std::path::PathBuf;

use eaco_rag::runtime::{tokenizer::PAD, FeatureHasher, Runtime, Tokenizer};
use eaco_rag::testutil::artifacts_dir;

fn artifacts() -> Option<PathBuf> {
    artifacts_dir()
}

#[test]
fn open_runtime_and_list_tiers() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::open(&dir).unwrap();
    assert!(rt.platform().to_lowercase().contains("cpu") || !rt.platform().is_empty());
    let tiers = rt.manifest.tiers();
    for t in ["qwen3b", "qwen72b", "qwen15b"] {
        assert!(tiers.contains(&t.to_string()), "missing {t}: {tiers:?}");
    }
}

#[test]
fn lm_forward_produces_finite_logits() {
    let Some(dir) = artifacts() else { return };
    let mut rt = Runtime::open(&dir).unwrap();
    rt.load("slm_qwen15b_b1").unwrap();
    let entry = rt
        .manifest
        .artifacts
        .iter()
        .find(|a| a.name == "slm_qwen15b_b1")
        .unwrap()
        .clone();
    let tok = Tokenizer::new(entry.vocab, entry.seq);
    let tokens = tok.encode("who founded the order");
    let (logits, timing) = rt.lm_logits("slm_qwen15b_b1", &tokens).unwrap();
    assert_eq!(logits.len(), entry.vocab);
    assert!(logits.iter().all(|x| x.is_finite()));
    assert!(timing.execute_us > 0);
}

#[test]
fn lm_forward_deterministic() {
    let Some(dir) = artifacts() else { return };
    let mut rt = Runtime::open(&dir).unwrap();
    rt.load("slm_qwen15b_b1").unwrap();
    let tokens = vec![5i32; 64];
    let (a, _) = rt.lm_logits("slm_qwen15b_b1", &tokens).unwrap();
    let (b, _) = rt.lm_logits("slm_qwen15b_b1", &tokens).unwrap();
    assert_eq!(a, b);
}

#[test]
fn lm_batch_variant_consistent_with_b1() {
    // The same prompt must produce (nearly) identical logits whether it
    // runs through the b1 or b4 artifact — weights are shared.
    let Some(dir) = artifacts() else { return };
    let mut rt = Runtime::open(&dir).unwrap();
    rt.load("slm_qwen15b_b1").unwrap();
    rt.load("slm_qwen15b_b4").unwrap();
    let tok = Tokenizer::new(512, 64);
    let row = tok.encode("alpha beta gamma");
    let (l1, _) = rt.lm_logits("slm_qwen15b_b1", &row).unwrap();
    let mut batch = Vec::new();
    for _ in 0..4 {
        batch.extend(row.iter().copied());
    }
    let (l4, _) = rt.lm_logits("slm_qwen15b_b4", &batch).unwrap();
    for i in 0..l1.len() {
        assert!(
            (l1[i] - l4[i]).abs() < 1e-3,
            "logit {i}: {} vs {}",
            l1[i],
            l4[i]
        );
    }
    // All four batch rows identical.
    for r in 1..4 {
        for i in 0..l1.len() {
            assert!((l4[i] - l4[r * l1.len() + i]).abs() < 1e-3);
        }
    }
}

#[test]
fn rejects_bad_token_shape() {
    let Some(dir) = artifacts() else { return };
    let mut rt = Runtime::open(&dir).unwrap();
    rt.load("slm_qwen15b_b1").unwrap();
    assert!(rt.lm_logits("slm_qwen15b_b1", &vec![0i32; 17]).is_err());
    assert!(rt.lm_logits("never_loaded", &vec![0i32; 64]).is_err());
}

#[test]
fn generate_greedy_tokens() {
    let Some(dir) = artifacts() else { return };
    let mut rt = Runtime::open(&dir).unwrap();
    let (gen, timing) = rt
        .generate("qwen15b", &["who rules the kingdom".to_string()], 4)
        .unwrap();
    assert_eq!(gen.len(), 1);
    assert_eq!(gen[0].len(), 4);
    assert!(gen[0].iter().all(|&t| t >= 0 && t != PAD));
    assert!(timing.execute_us > 0);
    // Deterministic.
    let (gen2, _) = rt
        .generate("qwen15b", &["who rules the kingdom".to_string()], 4)
        .unwrap();
    assert_eq!(gen, gen2);
}

#[test]
fn generate_batched_prompts() {
    let Some(dir) = artifacts() else { return };
    let mut rt = Runtime::open(&dir).unwrap();
    let prompts: Vec<String> = (0..3).map(|i| format!("question number {i}")).collect();
    let (gen, _) = rt.generate("qwen15b", &prompts, 3).unwrap();
    assert_eq!(gen.len(), 3);
    // Different prompts should (generally) diverge somewhere.
    assert!(gen[0] != gen[1] || gen[1] != gen[2], "all outputs identical");
}

#[test]
fn embedder_unit_norm_and_similarity() {
    let Some(dir) = artifacts() else { return };
    let mut rt = Runtime::open(&dir).unwrap();
    rt.load("embedder_b8").unwrap();
    let h = FeatureHasher::new(256);
    let rows = vec![
        h.features("alohomora unlocking spell"),
        h.features("alohomora spell door"),
        h.features("quidditch world cup"),
    ];
    let vecs = rt.embed("embedder_b8", &rows).unwrap();
    assert_eq!(vecs.len(), 3);
    for v in &vecs {
        assert_eq!(v.len(), 64);
        let n: f32 = v.iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!((n - 1.0).abs() < 1e-3, "norm {n}");
    }
    let dot = |a: &[f32], b: &[f32]| a.iter().zip(b).map(|(x, y)| x * y).sum::<f32>();
    let close = dot(&vecs[0], &vecs[1]);
    let far = dot(&vecs[0], &vecs[2]);
    assert!(close > far, "close {close} <= far {far}");
}
