//! Equivalence properties for the PR-1 hot-path overhaul: the optimized
//! kernels must be observably identical to their naive references.
//!
//! * heap-based `top_k` ≡ naive full-sort (exact, including id
//!   tie-breaks and bitwise scores — both paths share the dot kernel)
//! * sharded parallel scan ≡ single-threaded scan, bit-identical
//! * `above_threshold` ≡ threshold filter of the full-sort reference
//! * allocation-free `Gp::predict`/`predict_with`/`predict_many` ≡ a
//!   from-scratch GP posterior built with the public linalg API (1e-8)
//! * id→slot mapped insert/remove ≡ a model `HashMap<id, vec>` store
//! * batcher with the tier side-index preserves per-tier FIFO exactness

use std::collections::HashMap;

use eaco_rag::coordinator::batcher::{DynamicBatcher, GenRequest};
use eaco_rag::gating::gp::{Gp, GpScratch, Kernel};
use eaco_rag::linalg::{dot, Cholesky, Mat};
use eaco_rag::testutil::proptest;
use eaco_rag::util::rng::Rng;
use eaco_rag::vecstore::{dot_f32, VecStore};

// ---------------------------------------------------------------------------
// vecstore
// ---------------------------------------------------------------------------

/// Naive reference: score every row (same kernel), full sort, truncate.
fn reference_top_k(vs: &VecStore, q: &[f32], k: usize) -> Vec<(usize, f32)> {
    let mut scored = vs.score_all(q);
    scored.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
    scored.truncate(k);
    scored
}

/// Random store over a small integer grid so score ties actually occur.
fn random_store(rng: &mut Rng) -> (VecStore, usize) {
    let dim = 1 + rng.below(24);
    let rows = rng.below(220);
    let mut vs = VecStore::new(dim);
    for i in 0..rows {
        // Sparse-ish integer grid vectors → frequent exact duplicates.
        let v: Vec<f32> = (0..dim)
            .map(|_| (rng.below(5) as f32) - 2.0)
            .collect();
        // Skip all-zero rows (normalization would make them degenerate
        // in both paths identically, but keep the property crisp).
        if v.iter().all(|&x| x == 0.0) {
            vs.insert(i * 7, &[&v[..dim - 1], &[1.0][..]].concat());
        } else {
            vs.insert(i * 7, &v);
        }
    }
    (vs, dim)
}

#[test]
fn heap_top_k_matches_fullsort_reference() {
    proptest(150, |rng| {
        let (vs, dim) = random_store(rng);
        let q: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
        let k = rng.below(vs.len() + 5);
        let fast = vs.top_k_serial(&q, k);
        let reference = reference_top_k(&vs, &q, k);
        assert_eq!(fast.len(), reference.len());
        for (a, b) in fast.iter().zip(&reference) {
            assert_eq!(a.0, b.0, "id order diverged: {fast:?} vs {reference:?}");
            assert!(a.1 == b.1, "score not bit-identical: {} vs {}", a.1, b.1);
        }
        // The public auto-dispatch entry point agrees too.
        assert_eq!(vs.top_k(&q, k), fast);
        // And the retained seed implementation.
        assert_eq!(vs.top_k_fullsort(&q, k), reference);
    });
}

#[test]
fn sharded_scan_bit_identical_to_serial() {
    proptest(80, |rng| {
        let (vs, dim) = random_store(rng);
        let q: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
        let k = 1 + rng.below(16);
        let serial = vs.top_k_serial(&q, k);
        let shards = 1 + rng.below(8);
        let sharded = vs.top_k_with_shards(&q, k, shards);
        assert_eq!(serial.len(), sharded.len(), "shards={shards}");
        for (a, b) in serial.iter().zip(&sharded) {
            assert_eq!(a.0, b.0, "shards={shards}");
            assert!(a.1 == b.1, "score not bit-identical under sharding");
        }
    });
}

#[test]
fn above_threshold_matches_reference_filter() {
    proptest(120, |rng| {
        let (vs, dim) = random_store(rng);
        let q: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
        let threshold = (rng.f64() * 2.0 - 1.0) as f32;
        let fast = vs.above_threshold(&q, threshold);
        let reference: Vec<(usize, f32)> = reference_top_k(&vs, &q, vs.len())
            .into_iter()
            .filter(|&(_, s)| s >= threshold)
            .collect();
        assert_eq!(fast, reference);
    });
}

#[test]
fn slot_map_store_matches_model_under_churn() {
    proptest(60, |rng| {
        let dim = 1 + rng.below(8);
        let mut vs = VecStore::new(dim);
        let mut model: HashMap<usize, Vec<f32>> = HashMap::new();
        for _ in 0..rng.below(300) {
            let id = rng.below(40);
            match rng.below(3) {
                0 | 1 => {
                    let v: Vec<f32> =
                        (0..dim).map(|_| rng.normal() as f32 + 0.01).collect();
                    vs.insert(id, &v);
                    model.insert(id, v);
                }
                _ => {
                    assert_eq!(vs.remove(id), model.remove(&id).is_some());
                }
            }
        }
        assert_eq!(vs.len(), model.len());
        for (&id, v) in &model {
            assert!(vs.contains(id));
            // The stored row is the normalized model vector: its cosine
            // against the original must be 1 (top hit score for q = v).
            let hits = vs.top_k_serial(v, vs.len());
            let mine = hits.iter().find(|h| h.0 == id).expect("id present");
            assert!((mine.1 - 1.0).abs() < 1e-5, "id {id}: {}", mine.1);
        }
    });
}

#[test]
fn dot_kernel_matches_sequential_sum() {
    proptest(100, |rng| {
        let n = rng.below(200);
        let a: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let sequential: f64 = a
            .iter()
            .zip(&b)
            .map(|(x, y)| (*x as f64) * (*y as f64))
            .sum();
        // f32 accumulation tolerance scales with length; the property is
        // "computes a dot product", not bitwise f32 == f64.
        let tol = 1e-4 + n as f64 * 5e-5;
        assert!(
            (dot_f32(&a, &b) as f64 - sequential).abs() < tol,
            "n={n}"
        );
        let af: Vec<f64> = a.iter().map(|&x| x as f64).collect();
        let bf: Vec<f64> = b.iter().map(|&x| x as f64).collect();
        assert!((dot(&af, &bf) - sequential).abs() < 1e-9, "n={n}");
    });
}

// ---------------------------------------------------------------------------
// GP posterior
// ---------------------------------------------------------------------------

/// From-scratch GP posterior using only the public linalg API: build
/// K + σ²I, factor, and evaluate the textbook mean/variance formulas.
fn reference_posterior(
    kernel: Kernel,
    prior_mean: f64,
    pts: &[(Vec<f64>, f64)],
    x: &[f64],
) -> (f64, f64) {
    let n = pts.len();
    let mut k = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            k[(i, j)] = kernel.k(&pts[i].0, &pts[j].0);
        }
        k[(i, i)] += kernel.noise;
    }
    let ch = Cholesky::new(&k).expect("reference kernel matrix SPD");
    let centered: Vec<f64> = pts.iter().map(|(_, y)| y - prior_mean).collect();
    let alpha = ch.solve(&centered);
    let kstar: Vec<f64> = pts.iter().map(|(xi, _)| kernel.k(xi, x)).collect();
    let mu = prior_mean + dot(&kstar, &alpha);
    let v = ch.solve_lower(&kstar);
    let var = (kernel.k(x, x) - dot(&v, &v)).max(1e-12);
    (mu, var.sqrt())
}

#[test]
fn gp_predict_matches_reference_posterior() {
    proptest(40, |rng| {
        let kernel = Kernel {
            sf2: 0.3 + rng.f64(),
            length_scale: 0.4 + rng.f64(),
            noise: 0.02 + rng.f64() * 0.2,
        };
        let prior_mean = rng.f64() * 2.0 - 1.0;
        let d = 1 + rng.below(4);
        let n = 1 + rng.below(60);
        let mut gp = Gp::new(kernel, prior_mean, 500);
        let mut pts = Vec::new();
        for _ in 0..n {
            let x: Vec<f64> = (0..d).map(|_| rng.f64() * 3.0).collect();
            let y = x.iter().sum::<f64>() + 0.1 * rng.normal();
            gp.observe(x.clone(), y);
            pts.push((x, y));
        }
        let mut scratch = GpScratch::default();
        let mut many = Vec::new();
        for _ in 0..5 {
            let probe: Vec<f64> = (0..d).map(|_| rng.f64() * 3.0).collect();
            let (mu_ref, sd_ref) = reference_posterior(kernel, prior_mean, &pts, &probe);
            let (mu, sd) = gp.predict(&probe);
            assert!((mu - mu_ref).abs() < 1e-8, "mu {mu} vs {mu_ref}");
            assert!((sd - sd_ref).abs() < 1e-8, "sd {sd} vs {sd_ref}");
            // Scratch-based and batch entry points agree bitwise with
            // the internal-workspace path.
            let with = gp.predict_with(&probe, &mut scratch);
            assert_eq!(with, (mu, sd));
            gp.predict_many(
                std::slice::from_ref(&probe),
                &mut scratch,
                &mut many,
            );
            assert_eq!(many[0], (mu, sd));
        }
    });
}

#[test]
fn gp_windowed_predict_stays_consistent_with_retained_points() {
    // After sliding-window trims, the posterior must equal a reference
    // built from exactly the retained observations.
    proptest(20, |rng| {
        let kernel = Kernel::default();
        let max_obs = 12 + rng.below(20);
        let mut gp = Gp::new(kernel, 0.0, max_obs);
        let mut pts: Vec<(Vec<f64>, f64)> = Vec::new();
        for _ in 0..(max_obs * 3) {
            // Replicate Gp::observe's trim: drop oldest third when full.
            if pts.len() >= max_obs {
                pts.drain(..max_obs / 3);
            }
            let x = vec![rng.f64() * 4.0, rng.f64() * 4.0];
            let y = (x[0] - x[1]).sin();
            gp.observe(x.clone(), y);
            pts.push((x, y));
        }
        let probe = vec![1.0, 2.0];
        let (mu_ref, sd_ref) = reference_posterior(kernel, 0.0, &pts, &probe);
        let (mu, sd) = gp.predict(&probe);
        assert!((mu - mu_ref).abs() < 1e-8, "mu {mu} vs {mu_ref}");
        assert!((sd - sd_ref).abs() < 1e-8, "sd {sd} vs {sd_ref}");
    });
}

// ---------------------------------------------------------------------------
// batcher
// ---------------------------------------------------------------------------

#[test]
fn batcher_serves_every_request_once_in_tier_fifo_order() {
    proptest(60, |rng| {
        let max_batch = 1 + rng.below(8);
        let mut b = DynamicBatcher::new(max_batch, 1e9);
        let tiers = 1 + rng.below(6);
        let n = rng.below(200);
        let mut expected: HashMap<String, Vec<usize>> = HashMap::new();
        let mut flushed: HashMap<String, Vec<usize>> = HashMap::new();
        for id in 0..n {
            let tier = format!("tier{}", rng.below(tiers));
            expected.entry(tier.clone()).or_default().push(id);
            if let Some(batch) = b.push(GenRequest {
                request_id: id,
                tier: tier.clone(),
                prompt: String::new(),
                max_new: 1,
                enqueued_ms: id as f64,
            }) {
                assert_eq!(batch.requests.len(), max_batch);
                assert_eq!(batch.tier, tier);
                flushed
                    .entry(batch.tier.clone())
                    .or_default()
                    .extend(batch.requests.iter().map(|r| r.request_id));
            }
        }
        for batch in b.drain() {
            assert!(batch.requests.len() <= max_batch);
            flushed
                .entry(batch.tier.clone())
                .or_default()
                .extend(batch.requests.iter().map(|r| r.request_id));
        }
        assert_eq!(b.pending(), 0);
        assert_eq!(flushed, expected, "per-tier FIFO order must be exact");
    });
}
