//! End-to-end pipeline test over the REAL stack: coordinator + dynamic
//! batcher + PJRT executor + oracle + adaptive updates. Requires
//! `make artifacts` (skips loudly otherwise).

use std::path::PathBuf;

use eaco_rag::config::{QosPreset, SystemConfig};
use eaco_rag::coordinator::Coordinator;
use eaco_rag::corpus::Profile;
use eaco_rag::sim::workload_for;
use eaco_rag::testutil::artifacts_dir;
use eaco_rag::workload::Workload;

fn artifacts() -> Option<PathBuf> {
    artifacts_dir()
}

fn small_cfg() -> SystemConfig {
    SystemConfig {
        dataset: Profile::Wiki,
        warmup_steps: 30,
        edge_capacity: 400,
        ..SystemConfig::default()
    }
}

#[test]
fn serves_every_request_exactly_once() {
    let Some(dir) = artifacts() else { return };
    let cfg = small_cfg();
    let mut coord = Coordinator::new(cfg.clone(), &dir, 2).unwrap();
    let wl = Workload::generate(&coord.sim.corpus, workload_for(&cfg, 90), cfg.seed);
    let served = coord.run(&wl).unwrap();
    assert_eq!(served, 90);
    // Every request id exactly once.
    let mut ids: Vec<usize> = coord.metrics.records.iter().map(|r| r.request_id).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..90).collect::<Vec<_>>());
}

#[test]
fn real_execution_time_recorded_and_batched() {
    let Some(dir) = artifacts() else { return };
    let cfg = small_cfg();
    let mut coord = Coordinator::new(cfg.clone(), &dir, 2).unwrap();
    let wl = Workload::generate(&coord.sim.corpus, workload_for(&cfg, 64), cfg.seed);
    coord.run(&wl).unwrap();
    // Real PJRT time must be nonzero for every record.
    for r in &coord.metrics.records {
        assert!(r.real_exec_s > 0.0, "request {} has no real exec time", r.request_id);
        assert!(r.batch_size >= 1 && r.batch_size <= 8);
    }
    // Batching must actually group requests.
    assert!(
        coord.batcher.mean_batch_size() > 1.0,
        "mean batch size {:.2}",
        coord.batcher.mean_batch_size()
    );
}

#[test]
fn adaptive_updates_flow_during_serving() {
    let Some(dir) = artifacts() else { return };
    let cfg = small_cfg();
    let mut coord = Coordinator::new(cfg.clone(), &dir, 2).unwrap();
    let wl = Workload::generate(&coord.sim.corpus, workload_for(&cfg, 120), cfg.seed);
    coord.run(&wl).unwrap();
    assert!(
        coord.sim.cloud.updates_sent > 0,
        "cloud never distributed knowledge"
    );
    let resident: usize = coord.sim.edges().iter().map(|e| e.len()).sum();
    assert!(resident > 0);
}

#[test]
fn gate_uses_both_tiers_under_real_serving() {
    let Some(dir) = artifacts() else { return };
    let mut cfg = small_cfg();
    cfg.warmup_steps = 40;
    let mut coord = Coordinator::new(cfg.clone(), &dir, 2).unwrap();
    let wl = Workload::generate(&coord.sim.corpus, workload_for(&cfg, 150), cfg.seed);
    coord.run(&wl).unwrap();
    let hist = coord.metrics.arm_histogram();
    assert!(hist.len() >= 2, "gate collapsed: {hist:?}");
}

#[test]
fn deterministic_decisions_across_runs() {
    let Some(dir) = artifacts() else { return };
    let run = || {
        let cfg = small_cfg();
        let mut coord = Coordinator::new(cfg.clone(), &dir, 2).unwrap();
        let wl = Workload::generate(&coord.sim.corpus, workload_for(&cfg, 60), cfg.seed);
        coord.run(&wl).unwrap();
        let mut recs: Vec<(usize, String, bool)> = coord
            .metrics
            .records
            .iter()
            .map(|r| (r.request_id, r.arm.clone(), r.correct))
            .collect();
        recs.sort_by_key(|r| r.0);
        recs
    };
    assert_eq!(run(), run(), "serving decisions must be deterministic");
}

#[test]
fn delay_oriented_qos_respected_in_real_pipeline() {
    let Some(dir) = artifacts() else { return };
    let mut cfg = small_cfg();
    cfg.qos = QosPreset::DelayOriented;
    cfg.warmup_steps = 60;
    let mut coord = Coordinator::new(cfg.clone(), &dir, 2).unwrap();
    let wl = Workload::generate(&coord.sim.corpus, workload_for(&cfg, 200), cfg.seed);
    coord.run(&wl).unwrap();
    // Post-warm-up virtual delays should mostly respect the 1 s budget
    // (soft check: p50 under budget + slack).
    let mut post: Vec<f64> = coord
        .metrics
        .records
        .iter()
        .filter(|r| r.request_id >= cfg.warmup_steps)
        .map(|r| r.virtual_delay_s)
        .collect();
    post.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p50 = post[post.len() / 2];
    assert!(p50 < 1.5, "p50 {p50:.2}s under delay-oriented QoS");
}
