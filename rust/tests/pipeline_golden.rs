//! PR-9 staged-pipeline guardrails.
//!
//! Two families of assertions:
//!
//! 1. **Golden digests** — every driver now composes
//!    `pipeline::exec_query`/`gated_step`, so the `RunStats` of
//!    `run_baseline`, `run_eaco`, and `serve_async` (Fixed and Gated)
//!    are digested (FNV-1a over counters + float bit patterns) and
//!    compared against `tests/golden/pipeline_digests.txt`. The file is
//!    **self-seeding**: absent (first run on a fresh checkout) it is
//!    written and the test passes; present, any digest drift fails —
//!    catching refactors that silently change RNG stream order or
//!    accumulation arithmetic. Delete the file to re-baseline after an
//!    *intentional* behavior change.
//!
//!    Cross-driver equalities (`run_baseline` ≡ `serve_async(Fixed)`,
//!    `run_eaco` ≡ `serve_async(Gated)`) are also asserted directly, so
//!    the test has teeth even on the seeding run.
//!
//! 2. **StageSink ordering invariant** — an external observer attached
//!    via `serve_workload_observed` sees `QueryDone` events in strict
//!    workload order regardless of `serve.workers` (all
//!    simulator-mutating work runs at arrival processing; workers only
//!    shape the virtual queueing model).

use eaco_rag::config::SystemConfig;
use eaco_rag::gating::{Arm, GenLoc, Retrieval};
use eaco_rag::pipeline::{StageEvent, StageSink};
use eaco_rag::serve::{serve_workload_observed, Driver};
use eaco_rag::sim::{workload_for, KnowledgeMode, RunStats, SimSystem};
use eaco_rag::workload::Workload;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

fn fnv(h: u64, x: u64) -> u64 {
    let mut h = h;
    for b in x.to_le_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// FNV-1a over every deterministic `RunStats` field: counters as-is,
/// float streams by bit pattern (count + sum + mean + min/max captures
/// the full `Running` state).
fn stats_digest(s: &RunStats) -> u64 {
    let mut h = FNV_OFFSET;
    h = fnv(h, s.queries as u64);
    h = fnv(h, s.accuracy.to_bits());
    for r in [&s.delay, &s.resource_cost, &s.total_cost, &s.in_tokens, &s.out_tokens, &s.ann_recall]
    {
        h = fnv(h, r.count());
        h = fnv(h, r.sum().to_bits());
        h = fnv(h, r.mean().to_bits());
        h = fnv(h, r.min().to_bits());
        h = fnv(h, r.max().to_bits());
    }
    for &c in &s.arm_counts {
        h = fnv(h, c as u64);
    }
    for &q in &s.tier_queries {
        h = fnv(h, q as u64);
    }
    for &q in &s.tier_hits {
        h = fnv(h, q as u64);
    }
    h = fnv(h, s.bytes_replicated as u64);
    h = fnv(h, s.ann_queries as u64);
    h = fnv(h, s.ann_exact_fallbacks as u64);
    h
}

fn cfg() -> SystemConfig {
    SystemConfig {
        num_edges: 4,
        edge_capacity: 300,
        warmup_steps: 100,
        ..SystemConfig::default()
    }
}

fn edge_assist() -> Arm {
    Arm { retrieval: Retrieval::EdgeAssisted, gen: GenLoc::EdgeSlm }
}

fn golden_path() -> std::path::PathBuf {
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests")
        .join("golden")
        .join("pipeline_digests.txt")
}

#[test]
fn golden_digests_across_all_four_drivers() {
    let cfg = cfg();
    const STEPS: usize = 400;

    let mut sys = SimSystem::new(cfg.clone(), KnowledgeMode::Collaborative);
    let wl = Workload::generate(&sys.corpus, workload_for(&cfg, STEPS), cfg.seed);
    let baseline = sys.run_baseline(&wl, edge_assist());

    let mut sys = SimSystem::new(cfg.clone(), KnowledgeMode::Collaborative);
    let (eaco, _) = sys.run_eaco(&wl);

    let mut sys = SimSystem::new(cfg.clone(), KnowledgeMode::Collaborative);
    let (serve_fixed, _) = sys.serve_async(&wl, Driver::Fixed(edge_assist()));

    let mut sys = SimSystem::new(cfg.clone(), KnowledgeMode::Collaborative);
    let (serve_gated, _) = sys.serve_async(&wl, Driver::Gated);

    // Cross-driver equivalence through the shared pipeline stages: the
    // serving plane is a latency model over the same logical calls.
    assert_eq!(
        stats_digest(&baseline),
        stats_digest(&serve_fixed),
        "run_baseline and serve_async(Fixed) diverged"
    );
    assert_eq!(
        stats_digest(&eaco),
        stats_digest(&serve_gated),
        "run_eaco and serve_async(Gated) diverged"
    );

    let lines = format!(
        "baseline {:016x}\neaco {:016x}\nserve_fixed {:016x}\nserve_gated {:016x}\n",
        stats_digest(&baseline),
        stats_digest(&eaco),
        stats_digest(&serve_fixed),
        stats_digest(&serve_gated),
    );
    let path = golden_path();
    match std::fs::read_to_string(&path) {
        Ok(golden) => assert_eq!(
            golden, lines,
            "pipeline RunStats digests drifted from {} — if the change \
             is intentional, delete the file to re-baseline",
            path.display()
        ),
        Err(_) => {
            std::fs::write(&path, &lines).expect("seed golden digest file");
            eprintln!("(seeded {} — future runs compare against it)", path.display());
        }
    }
}

/// Golden digest for the hit-rate feedback arm: the learned-budget
/// gossip path gets the same drift tripwire as the default path. Both
/// synchronous drivers run under `[cluster] feedback = "hit-rate"` and
/// their digests are pinned in `tests/golden/feedback_digests.txt`
/// (self-seeding, exactly like the main file). A `run_eaco` ≡
/// `serve_async(Gated)` equivalence is asserted directly too, so the
/// worker-order argument covers the feedback fold even on the seeding
/// run.
#[test]
fn golden_digests_for_hit_rate_feedback_arm() {
    let mut cfg = cfg();
    cfg.cluster.feedback = eaco_rag::cluster::feedback::FeedbackMode::HitRate;
    const STEPS: usize = 400;

    let mut sys = SimSystem::new(cfg.clone(), KnowledgeMode::Collaborative);
    let wl = Workload::generate(&sys.corpus, workload_for(&cfg, STEPS), cfg.seed);
    let baseline = sys.run_baseline(&wl, edge_assist());
    assert!(
        sys.cluster.feedback.as_ref().map(|f| f.observations).unwrap_or(0) > 0,
        "hit-rate run never fed the loop"
    );

    let mut sys = SimSystem::new(cfg.clone(), KnowledgeMode::Collaborative);
    let (eaco, _) = sys.run_eaco(&wl);

    let mut sys = SimSystem::new(cfg.clone(), KnowledgeMode::Collaborative);
    let (serve_gated, _) = sys.serve_async(&wl, Driver::Gated);
    assert_eq!(
        stats_digest(&eaco),
        stats_digest(&serve_gated),
        "run_eaco and serve_async(Gated) diverged under hit-rate feedback"
    );

    let lines = format!(
        "feedback_baseline {:016x}\nfeedback_eaco {:016x}\n",
        stats_digest(&baseline),
        stats_digest(&eaco),
    );
    let path = golden_path().with_file_name("feedback_digests.txt");
    match std::fs::read_to_string(&path) {
        Ok(golden) => assert_eq!(
            golden, lines,
            "hit-rate feedback digests drifted from {} — if the change \
             is intentional, delete the file to re-baseline",
            path.display()
        ),
        Err(_) => {
            std::fs::write(&path, &lines).expect("seed feedback digest file");
            eprintln!("(seeded {} — future runs compare against it)", path.display());
        }
    }
}

/// Records the `seq` of every `QueryDone` the observer sees.
#[derive(Default)]
struct SeqSink {
    done_seqs: Vec<usize>,
    arrivals: usize,
}

impl StageSink for SeqSink {
    fn emit(&mut self, ev: &StageEvent<'_>) {
        match ev {
            StageEvent::Arrival { .. } => self.arrivals += 1,
            StageEvent::QueryDone { seq, .. } => self.done_seqs.push(*seq),
            _ => {}
        }
    }
}

#[test]
fn stage_events_arrive_in_workload_order_across_worker_counts() {
    let run = |workers: usize| {
        let mut c = cfg();
        c.serve.workers = workers;
        c.serve.gossip_background = workers > 1;
        let mut sys = SimSystem::new(c.clone(), KnowledgeMode::Collaborative);
        let wl = Workload::generate(&sys.corpus, workload_for(&c, 300), c.seed);
        let mut sink = SeqSink::default();
        // Gated StatsSink skips exploration steps (warm-up), so
        // `stats.queries` may undercount — the observer stream is the
        // full per-query record.
        let (stats, _) = serve_workload_observed(&mut sys, &wl, Driver::Gated, &mut sink);
        assert!(stats.queries <= wl.events.len());
        (sink, wl.events.len())
    };
    let (one, n) = run(1);
    let (four, _) = run(4);
    assert_eq!(one.arrivals, n);
    assert_eq!(one.done_seqs.len(), n, "every admitted query completes");
    assert!(
        one.done_seqs.windows(2).all(|w| w[0] < w[1]),
        "QueryDone events must be strictly in workload order"
    );
    assert_eq!(
        one.done_seqs, four.done_seqs,
        "the event stream is invariant across serve.workers"
    );
    assert_eq!(one.arrivals, four.arrivals);
}
