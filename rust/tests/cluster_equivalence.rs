//! PR-2 equivalence + determinism guardrails for the cluster subsystem:
//!
//! * the placement engine's `Fifo` policy is **bit-identical** to the
//!   seed `EdgeNode` FIFO (same resident order, same `EdgeStats`) under
//!   randomized churn;
//! * summary routing picks the same edge as the retained
//!   `best_edge_for` oracle on ≥95% of a seeded 10k-query workload
//!   (full-mesh topology, so the candidate sets match);
//! * `KnowledgeMode::Collaborative` sim runs are reproducible from the
//!   seed (two runs → identical `RunStats`, tier mix, gossip bytes).

use eaco_rag::cluster::hotness::HotnessTracker;
use eaco_rag::cluster::placement::{PlacementEngine, PlacementPolicy};
use eaco_rag::cluster::replicate::VersionAuthority;
use eaco_rag::cluster::EdgeCluster;
use eaco_rag::config::{ClusterConfig, SystemConfig};
use eaco_rag::corpus::{ChunkId, Corpus, Profile};
use eaco_rag::edge::{best_edge_for, EdgeNode};
use eaco_rag::gating::{GenLoc, Retrieval};
use eaco_rag::netsim::{NetSim, NetSpec};
use eaco_rag::sim::{workload_for, KnowledgeMode, RunStats, SimSystem, TIER_LOCAL, TIER_NEIGHBOR};
use eaco_rag::util::rng::Rng;
use eaco_rag::workload::Workload;

// ---------------------------------------------------------------------------
// (a) Fifo placement ≡ seed EdgeNode FIFO, bit for bit
// ---------------------------------------------------------------------------

#[test]
fn fifo_placement_engine_bit_identical_to_seed_fifo() {
    let corpus = Corpus::generate(Profile::Wiki, 7);
    let mut rng = Rng::new(0xF1F0);
    for trial in 0..20 {
        let cap = 20 + rng.below(150);
        let mut seed_node = EdgeNode::new(0, cap);
        let mut engine_node = EdgeNode::new(0, cap);
        let mut engine = PlacementEngine::new(1, PlacementPolicy::Fifo);
        // Hotness deliberately non-trivial: Fifo must ignore it.
        let mut hot = HotnessTracker::new(corpus.spec.topics, 50.0);
        let mut auth = VersionAuthority::new(corpus.chunks.len());
        for step in 0..30 {
            let batch: Vec<ChunkId> = (0..rng.below(60))
                .map(|_| rng.below(corpus.chunks.len()))
                .collect();
            for &c in batch.iter().take(3) {
                hot.record_chunk(c, step);
            }
            if step % 7 == 0 {
                auth.publish(&batch);
            }
            seed_node.apply_update(&corpus, &batch);
            engine.apply_update(&mut engine_node, &corpus, &hot, step, &batch, &auth, None, step);

            let a: Vec<ChunkId> = seed_node.resident_chunks().collect();
            let b: Vec<ChunkId> = engine_node.resident_chunks().collect();
            assert_eq!(a, b, "trial {trial} step {step}: resident order diverged");
        }
        assert_eq!(seed_node.stats.updates, engine_node.stats.updates, "trial {trial}");
        assert_eq!(seed_node.stats.inserted, engine_node.stats.inserted, "trial {trial}");
        assert_eq!(seed_node.stats.evicted, engine_node.stats.evicted, "trial {trial}");
        assert_eq!(seed_node.len(), engine_node.len(), "trial {trial}");
    }
}

// ---------------------------------------------------------------------------
// (b) summary routing ≡ best_edge_for oracle on ≥95% of 10k queries
// ---------------------------------------------------------------------------

#[test]
fn summary_routing_matches_broadcast_oracle_on_10k_queries() {
    let corpus = Corpus::generate(Profile::Wiki, 2);
    let num_edges = 8;
    let net = NetSim::new(num_edges, NetSpec::default(), 21);
    let mut cluster = EdgeCluster::new(
        &ClusterConfig::default(),
        Some(num_edges - 1), // full mesh: candidate set == the oracle's scan set
        num_edges,
        300,
        corpus.spec.topics,
        corpus.chunks.len(),
        &net,
    );
    // Heterogeneous stores: topic stripes + random spill, plus churn so
    // summaries have seen removals too.
    let mut rng = Rng::new(0x10_000);
    for e in 0..num_edges {
        let stripe: Vec<ChunkId> = corpus
            .chunks
            .iter()
            .filter(|c| c.topic % num_edges == e)
            .map(|c| c.id)
            .collect();
        cluster.nodes[e].apply_update(&corpus, &stripe);
        let spill: Vec<ChunkId> = (0..80).map(|_| rng.below(corpus.chunks.len())).collect();
        cluster.nodes[e].apply_update(&corpus, &spill);
    }

    let total = 10_000;
    let mut agree = 0usize;
    for _ in 0..total {
        let qa = &corpus.qa[rng.below(corpus.qa.len())];
        let kws = corpus.qa_keywords(qa);
        let local = rng.below(num_edges);
        let (oracle_edge, oracle_overlap) = best_edge_for(&cluster.nodes, local, &kws);
        let dec = cluster.route(local, &kws);
        if dec.edge == oracle_edge {
            agree += 1;
            assert!(
                (dec.overlap - oracle_overlap).abs() < 1e-12,
                "overlap estimate drifted: {} vs {}",
                dec.overlap,
                oracle_overlap
            );
        }
    }
    assert!(
        agree * 100 >= total * 95,
        "summary routing agreed on only {agree}/{total} queries"
    );
}

// ---------------------------------------------------------------------------
// (c) Collaborative sim runs are deterministic
// ---------------------------------------------------------------------------

fn collab_cfg() -> SystemConfig {
    SystemConfig {
        num_edges: 6,
        edge_capacity: 400,
        warmup_steps: 200,
        ..SystemConfig::default()
    }
}

fn assert_stats_identical(a: &RunStats, b: &RunStats) {
    assert_eq!(a.queries, b.queries);
    assert_eq!(a.tier_queries, b.tier_queries);
    assert_eq!(a.tier_hits, b.tier_hits);
    assert_eq!(a.bytes_replicated, b.bytes_replicated);
    assert_eq!(a.arm_counts, b.arm_counts);
    assert!((a.accuracy - b.accuracy).abs() < 1e-12);
    assert!((a.delay.mean() - b.delay.mean()).abs() < 1e-12);
    assert!((a.resource_cost.mean() - b.resource_cost.mean()).abs() < 1e-9);
}

#[test]
fn collaborative_fixed_arm_run_reproducible() {
    let cfg = collab_cfg();
    let arm = eaco_rag::gating::Arm {
        retrieval: Retrieval::EdgeAssisted,
        gen: GenLoc::EdgeSlm,
    };
    let run = || {
        let mut sys = SimSystem::new(cfg.clone(), KnowledgeMode::Collaborative);
        let wl = Workload::generate(&sys.corpus, workload_for(&cfg, 800), cfg.seed);
        let stats = sys.run_baseline(&wl, arm);
        let (stale, resident) = sys.cluster.staleness();
        (stats, stale, resident, sys.cluster.gossiper.stats.rounds)
    };
    let (sa, stale_a, res_a, rounds_a) = run();
    let (sb, stale_b, res_b, rounds_b) = run();
    assert_stats_identical(&sa, &sb);
    assert_eq!((stale_a, res_a, rounds_a), (stale_b, res_b, rounds_b));
    // The collaborative plane actually did something.
    assert!(sa.bytes_replicated > 0, "no gossip traffic");
    assert!(rounds_a > 0);
    assert_eq!(
        sa.tier_queries[TIER_LOCAL] + sa.tier_queries[TIER_NEIGHBOR],
        sa.queries,
        "edge-assisted arm must serve from the edge tier"
    );
}

#[test]
fn collaborative_gated_run_reproducible() {
    let cfg = collab_cfg();
    let run = || {
        let mut sys = SimSystem::new(cfg.clone(), KnowledgeMode::Collaborative);
        let wl = Workload::generate(&sys.corpus, workload_for(&cfg, 700), cfg.seed);
        sys.run_eaco(&wl).0
    };
    assert_stats_identical(&run(), &run());
}

// ---------------------------------------------------------------------------
// (d) `feedback = "none"` is bit-identical to the pre-feedback gossiper
// ---------------------------------------------------------------------------

#[test]
fn feedback_none_is_bit_identical_to_default_gossip_path() {
    // `run_round_with(..., None)` must be byte-for-byte the fixed-budget
    // path: same digest ordering, same per-link fingerprints, same
    // suppression, same transfer set, same wire accounting. Run the
    // default config (feedback defaults to None) against a config that
    // sets it explicitly, and compare stats plus every gossip counter.
    let run = |feedback: eaco_rag::cluster::feedback::FeedbackMode| {
        let mut cfg = collab_cfg();
        cfg.cluster.feedback = feedback;
        let arm = eaco_rag::gating::Arm {
            retrieval: Retrieval::EdgeAssisted,
            gen: GenLoc::EdgeSlm,
        };
        let mut sys = SimSystem::new(cfg.clone(), KnowledgeMode::Collaborative);
        let wl = Workload::generate(&sys.corpus, workload_for(&cfg, 800), cfg.seed);
        let stats = sys.run_baseline(&wl, arm);
        (stats, sys)
    };
    let (sa, sys_a) = run(eaco_rag::cluster::feedback::FeedbackMode::None);
    let (sb, _) = run(ClusterConfig::default().feedback); // the default IS None
    assert_stats_identical(&sa, &sb);
    assert!(sys_a.cluster.feedback.is_none(), "feedback = none must carry no state");

    // And the learned arm's bookkeeping never leaks into the none arm:
    // every wire/observability counter matches across the two runs.
    let (_, sys_b2) = run(eaco_rag::cluster::feedback::FeedbackMode::None);
    let (ga, gb) = (&sys_a.cluster.gossiper.stats, &sys_b2.cluster.gossiper.stats);
    assert_eq!(ga.rounds, gb.rounds);
    assert_eq!(ga.digests_sent, gb.digests_sent);
    assert_eq!(ga.digests_suppressed, gb.digests_suppressed);
    assert_eq!(ga.chunks_offered, gb.chunks_offered);
    assert_eq!(ga.chunks_transferred, gb.chunks_transferred);
    assert_eq!(ga.bytes_transferred, gb.bytes_transferred);
    assert_eq!(ga.digest_bytes, gb.digest_bytes);
}

// ---------------------------------------------------------------------------
// Legacy modes still route through summaries — and match the seed path
// ---------------------------------------------------------------------------

#[test]
fn legacy_adaptive_run_unaffected_by_cluster_plane() {
    // The Adaptive mode now routes edge-assisted retrieval through the
    // cluster's full-mesh summaries; the decision rule is the oracle's,
    // so a full gated run must stay deterministic and keep the gossip
    // plane silent.
    let cfg = SystemConfig {
        edge_capacity: 400,
        warmup_steps: 200,
        ..SystemConfig::default()
    };
    let run = || {
        let mut sys = SimSystem::new(cfg.clone(), KnowledgeMode::Adaptive);
        let wl = Workload::generate(&sys.corpus, workload_for(&cfg, 500), cfg.seed);
        let stats = sys.run_eaco(&wl).0;
        (stats, sys.cluster.gossiper.stats.rounds, sys.cluster.bytes_gossiped())
    };
    let (sa, rounds_a, bytes_a) = run();
    let (sb, _, _) = run();
    assert_stats_identical(&sa, &sb);
    assert_eq!(rounds_a, 0, "legacy mode must not gossip");
    assert_eq!(bytes_a, 0);
    assert_eq!(sa.bytes_replicated, 0);
}
