//! PR-8 chaos-plane guardrails:
//!
//! * `[chaos]` disabled (the default) is **bit-identical** to a run
//!   with no chaos plane at all — same `RunStats`, same metric digest,
//!   no outcome attached;
//! * an enabled scenario is itself deterministic: same seed + scenario
//!   ⇒ identical run digests across repeats and across worker counts
//!   (1 vs 4);
//! * split-brain bounds staleness while partitioned, heals by run end,
//!   and the SLA checker reports recovery / staleness / availability;
//! * flaky-uplink slows cloud-tier queries without perturbing any
//!   query's retrieved-chunk set (the RNG-free injection property);
//! * rolling-restart closes a recovery window for every revived edge.

use eaco_rag::chaos::{ChaosReport, SlaSpec};
use eaco_rag::config::SystemConfig;
use eaco_rag::gating::{Arm, GenLoc, Retrieval};
use eaco_rag::serve::Driver;
use eaco_rag::sim::{workload_for, KnowledgeMode, RunStats, SimSystem};
use eaco_rag::workload::Workload;

fn collab_cfg() -> SystemConfig {
    SystemConfig {
        num_edges: 6,
        edge_capacity: 400,
        warmup_steps: 200,
        ..SystemConfig::default()
    }
}

fn edge_assist() -> Arm {
    Arm { retrieval: Retrieval::EdgeAssisted, gen: GenLoc::EdgeSlm }
}

fn assert_stats_bit_identical(a: &RunStats, b: &RunStats) {
    assert_eq!(a.queries, b.queries);
    assert_eq!(a.tier_queries, b.tier_queries);
    assert_eq!(a.tier_hits, b.tier_hits);
    assert_eq!(a.bytes_replicated, b.bytes_replicated);
    assert_eq!(a.accuracy.to_bits(), b.accuracy.to_bits());
    assert_eq!(a.delay.sum().to_bits(), b.delay.sum().to_bits());
    assert_eq!(a.total_cost.sum().to_bits(), b.total_cost.sum().to_bits());
}

/// Run the collaborative serve plane over a seeded workload.
fn run(cfg: &SystemConfig, steps: usize) -> (RunStats, eaco_rag::serve::metrics::ServeMetrics) {
    let mut sys = SimSystem::new(cfg.clone(), KnowledgeMode::Collaborative);
    let wl = Workload::generate(&sys.corpus, workload_for(cfg, steps), cfg.seed);
    sys.serve_async(&wl, Driver::Fixed(edge_assist()))
}

// ---------------------------------------------------------------------------
// (a) disabled chaos is invisible
// ---------------------------------------------------------------------------

#[test]
fn disabled_chaos_is_bit_identical_to_no_chaos_at_all() {
    let base = collab_cfg();
    // Every chaos knob set — but the plane stays off.
    let mut armed = collab_cfg();
    armed.chaos.scenario = "flaky-uplink".into();
    armed.chaos.at_step = 1;
    armed.chaos.duration_steps = 10_000;
    armed.chaos.degrade_factor = 100.0;
    armed.chaos.sla_recovery_ms = 1.0;
    assert!(!armed.chaos.enabled, "enabled must default to false");

    let (sa, ma) = run(&base, 600);
    let (sb, mb) = run(&armed, 600);
    assert_stats_bit_identical(&sa, &sb);
    assert_eq!(
        ma.digest(),
        mb.digest(),
        "a disabled [chaos] section must not move a single metric bit"
    );
    assert!(ma.chaos.is_none() && mb.chaos.is_none(), "no outcome without a scenario");
}

// ---------------------------------------------------------------------------
// (b) enabled chaos is deterministic across repeats and worker counts
// ---------------------------------------------------------------------------

#[test]
fn split_brain_runs_are_repeat_invariant() {
    let mut cfg = collab_cfg();
    cfg.chaos.enabled = true; // default scenario: split-brain @40 for 60
    let (sa, ma) = run(&cfg, 600);
    let (sb, mb) = run(&cfg, 600);
    assert_stats_bit_identical(&sa, &sb);
    assert_eq!(ma.digest(), mb.digest(), "same seed + scenario ⇒ same run digest");
    let (ca, cb) = (ma.chaos.as_ref().unwrap(), mb.chaos.as_ref().unwrap());
    assert_eq!(ca, cb);
    assert_eq!(ca.digest(), cb.digest());
}

#[test]
fn chaos_outcome_is_invariant_across_worker_counts() {
    let run_with = |workers: usize| {
        let mut cfg = collab_cfg();
        cfg.chaos.enabled = true;
        cfg.serve.workers = workers;
        run(&cfg, 600)
    };
    let (s1, m1) = run_with(1);
    let (s4, m4) = run_with(4);
    assert_stats_bit_identical(&s1, &s4);
    assert_eq!(m1.retrieved_digest, m4.retrieved_digest);
    let (c1, c4) = (m1.chaos.as_ref().unwrap(), m4.chaos.as_ref().unwrap());
    assert_eq!(c1, c4, "recovery/staleness probes must not see the worker count");
    assert_eq!(c1.digest(), c4.digest());
}

// ---------------------------------------------------------------------------
// (c) split-brain: bounded staleness, heal, SLA report
// ---------------------------------------------------------------------------

#[test]
fn split_brain_bounds_staleness_heals_and_reports_sla() {
    let mut cfg = collab_cfg();
    cfg.chaos.enabled = true;
    let mut sys = SimSystem::new(cfg.clone(), KnowledgeMode::Collaborative);
    let wl = Workload::generate(&sys.corpus, workload_for(&cfg, 600), cfg.seed);
    let (stats, m) = sys.serve_async(&wl, Driver::Gated);
    let c = m.chaos.as_ref().expect("enabled scenario attaches an outcome");

    assert_eq!(c.scenario, "split-brain");
    assert_eq!(c.faults_applied, 2, "one partition + one heal");
    assert!(
        c.max_staleness_partitioned <= c.max_staleness,
        "partition-window staleness is a restriction of the run-wide max"
    );
    // The heal fired well before the workload ended: both planes are
    // fully connected again.
    assert!(!sys.cluster.partitioned(), "cluster healed by run end");
    assert!(sys.net.reachable(0, cfg.num_edges - 1), "netsim healed by run end");
    // Default config sheds nothing — the partition degrades freshness,
    // not admission.
    assert_eq!(c.shed, 0);
    assert_eq!(c.availability(), 1.0);
    assert!(c.completed as usize >= stats.queries, "gated stats exclude exploration");

    // The SLA checker reports all three dimensions. Split-brain revives
    // nothing, so recovery passes trivially with actual 0.
    let sla = SlaSpec {
        recovery_ms: 1.0,
        max_staleness: c.max_staleness as i64,
        min_availability: 0.5,
    };
    let report = ChaosReport::evaluate(c.clone(), &sla);
    assert_eq!(report.checks.len(), 3);
    assert!(report.pass, "generous thresholds must pass: {:?}", report.checks);
    let names: Vec<&str> = report.checks.iter().map(|k| k.name).collect();
    assert_eq!(names, vec!["recovery_ms", "max_staleness_versions", "availability"]);
    // And the machine-readable form round-trips.
    let j = eaco_rag::util::json::parse(&report.to_json().to_string()).unwrap();
    assert_eq!(j.get("scenario").as_str(), Some("split-brain"));
    assert_eq!(j.get("pass").as_bool(), Some(true));
    assert_eq!(j.get("outcome").get("faults_applied").as_usize(), Some(2));
}

// ---------------------------------------------------------------------------
// (d) flaky-uplink: latency moves, retrieval does not
// ---------------------------------------------------------------------------

#[test]
fn flaky_uplink_slows_cloud_queries_without_touching_retrieval() {
    let cloud = Arm { retrieval: Retrieval::CloudGraph, gen: GenLoc::CloudLlm };
    let run_cloud = |enabled: bool| {
        let mut cfg = collab_cfg();
        cfg.chaos.enabled = enabled;
        cfg.chaos.scenario = "flaky-uplink".into();
        let mut sys = SimSystem::new(cfg.clone(), KnowledgeMode::Collaborative);
        let wl = Workload::generate(&sys.corpus, workload_for(&cfg, 400), cfg.seed);
        sys.serve_async(&wl, Driver::Fixed(cloud))
    };
    let (clean_stats, clean_m) = run_cloud(false);
    let (flaky_stats, flaky_m) = run_cloud(true);

    assert!(
        flaky_stats.delay.sum() > clean_stats.delay.sum(),
        "a degraded uplink must show up in cloud-tier latency"
    );
    // Injection is RNG-free: the same queries retrieved the same chunks
    // and scored the same accuracy, bit for bit.
    assert_eq!(clean_m.retrieved_digest, flaky_m.retrieved_digest);
    assert_eq!(clean_stats.accuracy.to_bits(), flaky_stats.accuracy.to_bits());
    assert_eq!(clean_stats.tier_queries, flaky_stats.tier_queries);
    let c = flaky_m.chaos.as_ref().unwrap();
    assert_eq!(c.faults_applied, 2, "degrade + restore");
    assert_eq!(c.max_staleness_partitioned, 0, "no partition in this scenario");
}

// ---------------------------------------------------------------------------
// (e) rolling-restart: recovery windows open and close
// ---------------------------------------------------------------------------

#[test]
fn rolling_restart_measures_recovery_for_every_edge() {
    let mut cfg = collab_cfg();
    cfg.chaos.enabled = true;
    cfg.chaos.scenario = "rolling-restart".into();
    let (stats, m) = run(&cfg, 800);
    let c = m.chaos.as_ref().unwrap();

    assert_eq!(c.faults_applied, 12, "6 kills + 6 revives");
    assert_eq!(
        c.recoveries + c.unrecovered,
        6,
        "every revive opens exactly one recovery window"
    );
    assert!(c.recoveries >= 1, "gossip re-syncs at least one revived edge in time");
    assert!(
        c.recovery_ms.unwrap_or(0.0) >= 0.0 && c.recovery_ms.unwrap_or(0.0).is_finite()
    );
    // At most one edge is ever down, so nothing is shed — traffic for
    // the down edge reroutes to an alive peer.
    assert_eq!(c.shed, 0);
    assert!(c.rerouted > 0, "down-edge arrivals rerouted");
    assert_eq!(stats.queries, c.completed as usize);
}
