//! PR-6 guardrails for the IVF ANN layer (`vecstore::ivf`):
//!
//! * the exact-scan fallback (untrained, or trained-but-small) is
//!   **bit-identical** to `VecStore::top_k_serial` across randomized
//!   stores and pathological `k` values — ANN must be invisible below
//!   `exact_below`;
//! * IVF recall@8 ≥ 0.95 against the exact scan on a seeded clustered
//!   50k×64 workload at `nprobe = nlist/8` — the quality floor the
//!   collaborative retrieval path relies on;
//! * randomized insert/remove churn keeps the id→(list,slot) map, the
//!   posting-list slabs, and the backing flat store consistent
//!   (mirrors the PR-1 slot-map model test, one level up).

use std::collections::HashMap;

use eaco_rag::testutil::proptest;
use eaco_rag::util::rng::Rng;
use eaco_rag::vecstore::ivf::{IvfParams, IvfStore};
use eaco_rag::vecstore::VecStore;

// ---------------------------------------------------------------------------
// (a) exact fallback ≡ flat serial scan, bit for bit
// ---------------------------------------------------------------------------

#[test]
fn exact_fallback_bit_identical_to_flat_serial_scan() {
    proptest(20, |rng| {
        let dim = 16;
        let rows = 50 + rng.below(200);
        // exact_below far above the store size: every query takes the
        // fallback regardless of training state.
        let params = IvfParams {
            exact_below: 100_000,
            nlist: 8,
            kmeans_iters: 2,
            ..IvfParams::default()
        };
        let mut ivf = IvfStore::new(dim, params);
        let mut flat = VecStore::new(dim);
        let mut v = vec![0.0f32; dim];
        for id in 0..rows {
            for x in v.iter_mut() {
                // Integer grid so score ties actually occur and the
                // id tie-break is exercised.
                *x = rng.below(9) as f32 - 4.0;
            }
            ivf.insert(id, &v);
            flat.insert(id, &v);
        }
        if rng.chance(0.5) {
            // Trained but still below exact_below: must stay exact.
            ivf.build();
        }
        assert!(ivf.uses_exact());
        let q: Vec<f32> = (0..dim).map(|_| rng.below(9) as f32 - 4.0).collect();
        for k in [0usize, 3, 8, rows, rows + 7] {
            let a = ivf.top_k(&q, k);
            let b = flat.top_k_serial(&q, k);
            assert_eq!(a.len(), b.len(), "k={k} rows={rows}");
            for (x, y) in a.iter().zip(b.iter()) {
                assert_eq!(x.0, y.0, "id mismatch at k={k}");
                assert_eq!(x.1.to_bits(), y.1.to_bits(), "score bits at k={k}");
            }
        }
    });
}

// ---------------------------------------------------------------------------
// (b) IVF recall@8 ≥ 0.95 on a clustered 50k×64 workload
// ---------------------------------------------------------------------------

#[test]
fn ivf_recall_at_8_meets_floor_on_clustered_workload() {
    let dim = 64;
    let rows = 50_000;
    let n_centers = 64;
    let mut rng = Rng::new(0xa22);

    // Ground-truth cluster structure: unit-ish centers, tight noise.
    let mut centers = vec![0.0f32; n_centers * dim];
    for x in centers.iter_mut() {
        *x = rng.normal() as f32;
    }
    let mut flat = VecStore::with_capacity(dim, rows);
    let mut v = vec![0.0f32; dim];
    for id in 0..rows {
        let c = rng.below(n_centers);
        for (j, x) in v.iter_mut().enumerate() {
            *x = centers[c * dim + j] + 0.25 * rng.normal() as f32;
        }
        flat.insert(id, &v);
    }

    let params = IvfParams {
        nlist: 64,
        nprobe: 8, // nlist/8
        exact_below: 1000,
        kmeans_iters: 4,
        train_sample: 8192,
        ..IvfParams::default()
    };
    let ivf = IvfStore::from_flat(flat.clone(), params);
    assert!(ivf.trained());
    assert!(!ivf.uses_exact());
    ivf.check_consistency().unwrap();

    let k = 8;
    let queries = 100;
    let mut hits = 0usize;
    let mut total = 0usize;
    for _ in 0..queries {
        // Near-center queries: the workload the coarse quantizer is for.
        let c = rng.below(n_centers);
        let q: Vec<f32> = (0..dim)
            .map(|j| centers[c * dim + j] + 0.25 * rng.normal() as f32)
            .collect();
        let exact = flat.top_k_serial(&q, k);
        let approx = ivf.top_k(&q, k);
        total += exact.len();
        hits += exact
            .iter()
            .filter(|(id, _)| approx.iter().any(|(a, _)| a == id))
            .count();
    }
    let recall = hits as f64 / total as f64;
    assert!(recall >= 0.95, "recall@8 {recall:.3} < 0.95 floor");
}

// ---------------------------------------------------------------------------
// (c) insert/remove churn keeps lists, loc map, and flat store in sync
// ---------------------------------------------------------------------------

#[test]
fn churn_keeps_posting_lists_consistent_with_model() {
    let dim = 8;
    let params = IvfParams {
        nlist: 6,
        nprobe: 2,
        exact_below: 40,
        retrain_drift: 0.3,
        kmeans_iters: 4,
        ..IvfParams::default()
    };
    let mut ivf = IvfStore::new(dim, params);
    let mut model: HashMap<usize, Vec<f32>> = HashMap::new();
    let mut rng = Rng::new(0xc4u64);
    let id_space = 120;

    for step in 0..600 {
        let id = rng.below(id_space);
        if rng.chance(0.6) {
            let v: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
            ivf.insert(id, &v);
            model.insert(id, v);
        } else {
            let removed = ivf.remove(id);
            assert_eq!(removed, model.remove(&id).is_some(), "remove({id})");
        }
        assert_eq!(ivf.len(), model.len());
        if step % 50 == 0 {
            ivf.check_consistency().unwrap();
        }
    }
    ivf.check_consistency().unwrap();
    assert!(ivf.trained(), "churn crossed exact_below and back");
    for &id in model.keys() {
        assert!(ivf.contains(id));
    }

    // Full-probe query after churn is still bit-identical to exact:
    // every surviving row is reachable through exactly one list.
    let q: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
    let all = ivf.top_k_with(&q, 10, 6);
    let exact = ivf.top_k_exact(&q, 10);
    assert_eq!(all.len(), exact.len());
    for (x, y) in all.iter().zip(exact.iter()) {
        assert_eq!(x.0, y.0);
        assert_eq!(x.1.to_bits(), y.1.to_bits());
    }
}
