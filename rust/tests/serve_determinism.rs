//! PR-7 serving-plane guardrails (see `serve`'s module docs for the
//! determinism argument):
//!
//! * with the default `[serve]` config, `serve_async` is **bit-identical**
//!   to the synchronous `run_baseline`/`run_eaco` paths on a seeded
//!   collaborative workload — tier mix, hits, bytes replicated, cost
//!   streams;
//! * same seed + virtual clock ⇒ bit-identical `RunStats` *and* metric
//!   digests across repeated runs, and across worker counts (1 vs 4);
//! * background gossip overlaps with query service (overlap ratio > 0)
//!   without changing any query's retrieved-chunk set;
//! * admission policies shed/downgrade as configured; bounded queues
//!   shed on overflow;
//! * edge churn: killed edges reroute traffic, revived edges cold-sync
//!   back through gossip.

use eaco_rag::config::SystemConfig;
use eaco_rag::gating::{Arm, GenLoc, Retrieval};
use eaco_rag::serve::queue::AdmissionPolicy;
use eaco_rag::serve::Driver;
use eaco_rag::sim::{
    workload_for, KnowledgeMode, RunStats, SimSystem, TIER_CLOUD, TIER_LOCAL,
};
use eaco_rag::workload::Workload;

fn collab_cfg() -> SystemConfig {
    SystemConfig {
        num_edges: 6,
        edge_capacity: 400,
        warmup_steps: 200,
        ..SystemConfig::default()
    }
}

fn edge_assist() -> Arm {
    Arm {
        retrieval: Retrieval::EdgeAssisted,
        gen: GenLoc::EdgeSlm,
    }
}

/// Full bit-level comparison: counters exactly, float streams by bit
/// pattern (both sides are produced by the same arithmetic on the same
/// RNG draws, so even the last ulp must match).
fn assert_stats_bit_identical(a: &RunStats, b: &RunStats) {
    assert_eq!(a.queries, b.queries);
    assert_eq!(a.tier_queries, b.tier_queries);
    assert_eq!(a.tier_hits, b.tier_hits);
    assert_eq!(a.bytes_replicated, b.bytes_replicated);
    assert_eq!(a.arm_counts, b.arm_counts);
    assert_eq!(a.ann_queries, b.ann_queries);
    assert_eq!(a.ann_exact_fallbacks, b.ann_exact_fallbacks);
    assert_eq!(a.accuracy.to_bits(), b.accuracy.to_bits());
    assert_eq!(a.delay.mean().to_bits(), b.delay.mean().to_bits());
    assert_eq!(a.delay.sum().to_bits(), b.delay.sum().to_bits());
    assert_eq!(
        a.resource_cost.mean().to_bits(),
        b.resource_cost.mean().to_bits()
    );
    assert_eq!(a.total_cost.sum().to_bits(), b.total_cost.sum().to_bits());
    assert_eq!(a.ann_recall.mean().to_bits(), b.ann_recall.mean().to_bits());
}

// ---------------------------------------------------------------------------
// (a) serve_async ≡ the synchronous paths at concurrency 1
// ---------------------------------------------------------------------------

#[test]
fn fixed_arm_serve_async_bit_identical_to_run_baseline() {
    let cfg = collab_cfg();

    let mut sync_sys = SimSystem::new(cfg.clone(), KnowledgeMode::Collaborative);
    let wl = Workload::generate(&sync_sys.corpus, workload_for(&cfg, 1000), cfg.seed);
    let sync_stats = sync_sys.run_baseline(&wl, edge_assist());

    let mut async_sys = SimSystem::new(cfg.clone(), KnowledgeMode::Collaborative);
    let (async_stats, m) = async_sys.serve_async(&wl, Driver::Fixed(edge_assist()));

    assert_stats_bit_identical(&sync_stats, &async_stats);
    assert!(
        async_stats.bytes_replicated > 0,
        "collaborative run must gossip"
    );
    // The serving plane actually fronted every query.
    let summary = async_stats.serve.as_ref().expect("serve summary");
    assert_eq!(summary.completed, wl.events.len());
    assert_eq!(summary.shed_overflow + summary.shed_deadline + summary.shed_dead_edge, 0);
    assert!(m.gossip_rounds > 0);
    assert_eq!(m.gossip_rounds, summary.gossip_rounds);
    // And the final store state matches the synchronous run's.
    assert_eq!(sync_sys.cluster.staleness(), async_sys.cluster.staleness());
    assert_eq!(
        sync_sys.cluster.gossiper.stats.rounds,
        async_sys.cluster.gossiper.stats.rounds
    );
}

#[test]
fn gated_serve_async_bit_identical_to_run_eaco() {
    let cfg = collab_cfg();

    let mut sync_sys = SimSystem::new(cfg.clone(), KnowledgeMode::Collaborative);
    let wl = Workload::generate(&sync_sys.corpus, workload_for(&cfg, 500), cfg.seed);
    let (sync_stats, _) = sync_sys.run_eaco(&wl);

    let mut async_sys = SimSystem::new(cfg.clone(), KnowledgeMode::Collaborative);
    let (async_stats, _) = async_sys.serve_async(&wl, Driver::Gated);

    assert_stats_bit_identical(&sync_stats, &async_stats);
    assert!(async_stats.arm_counts.iter().sum::<usize>() > 0);
}

// ---------------------------------------------------------------------------
// (b) bit-reproducible across runs and worker counts
// ---------------------------------------------------------------------------

#[test]
fn repeated_runs_bit_identical_including_metric_digest() {
    let mut cfg = collab_cfg();
    cfg.serve.workers = 4;
    cfg.serve.gossip_background = true;
    let run = || {
        let mut sys = SimSystem::new(cfg.clone(), KnowledgeMode::Collaborative);
        let wl = Workload::generate(&sys.corpus, workload_for(&cfg, 600), cfg.seed);
        sys.serve_async(&wl, Driver::Fixed(edge_assist()))
    };
    let (sa, ma) = run();
    let (sb, mb) = run();
    assert_stats_bit_identical(&sa, &sb);
    assert_eq!(sa.serve, sb.serve);
    assert_eq!(
        ma.digest(),
        mb.digest(),
        "same seed + virtual clock must reproduce every deterministic metric bit"
    );
    assert_eq!(ma.retrieved_digest, mb.retrieved_digest);
}

#[test]
fn run_stats_invariant_across_worker_counts() {
    let run = |workers: usize| {
        let mut cfg = collab_cfg();
        cfg.serve.workers = workers;
        let mut sys = SimSystem::new(cfg.clone(), KnowledgeMode::Collaborative);
        let wl = Workload::generate(&sys.corpus, workload_for(&cfg, 600), cfg.seed);
        sys.serve_async(&wl, Driver::Fixed(edge_assist()))
    };
    let (s1, m1) = run(1);
    let (s4, m4) = run(4);
    // Worker count shapes the latency model only — never the logical
    // call order — so the run-level stats are identical.
    assert_stats_bit_identical(&s1, &s4);
    assert_eq!(s1.serve, s4.serve, "ServeSummary is worker-count-invariant");
    assert_eq!(
        m1.retrieved_digest, m4.retrieved_digest,
        "every query retrieved the same chunks under 1 and 4 workers"
    );
}

// ---------------------------------------------------------------------------
// (c) background gossip: overlap without retrieval drift
// ---------------------------------------------------------------------------

#[test]
fn background_gossip_overlaps_without_changing_retrieval() {
    let run = |background: bool| {
        let mut cfg = collab_cfg();
        cfg.serve.workers = if background { 4 } else { 1 };
        cfg.serve.gossip_background = background;
        let mut sys = SimSystem::new(cfg.clone(), KnowledgeMode::Collaborative);
        let wl = Workload::generate(&sys.corpus, workload_for(&cfg, 800), cfg.seed);
        sys.serve_async(&wl, Driver::Fixed(edge_assist()))
    };
    let (fg_stats, fg) = run(false);
    let (bg_stats, bg) = run(true);

    // Acceptance criterion: overlap shows up, retrieval does not move.
    assert!(bg.gossip_rounds > 0);
    assert!(
        bg.overlap_ratio() > 0.0,
        "background gossip must overlap query service"
    );
    assert_eq!(fg.overlap_ratio(), 0.0, "foreground gossip never overlaps");
    assert_eq!(
        fg.retrieved_digest, bg.retrieved_digest,
        "background gossip must not change any query's retrieved-chunk set"
    );
    assert_eq!(fg_stats.tier_queries, bg_stats.tier_queries);
    assert_eq!(fg_stats.tier_hits, bg_stats.tier_hits);
    assert_eq!(fg_stats.bytes_replicated, bg_stats.bytes_replicated);
    // The physical wire-work ran and checksummed deterministically.
    assert_eq!(bg.bg_jobs, bg.bg_jobs_done);
    assert!(bg.bg_jobs > 0);
    let (_, bg2) = run(true);
    assert_eq!(bg.bg_checksum, bg2.bg_checksum);
}

// ---------------------------------------------------------------------------
// (d) admission + backpressure
// ---------------------------------------------------------------------------

#[test]
fn shed_admission_with_tiny_slo_sheds_everything() {
    let mut cfg = collab_cfg();
    cfg.serve.admission = AdmissionPolicy::Shed;
    cfg.serve.slo_ms = 0.01;
    let mut sys = SimSystem::new(cfg.clone(), KnowledgeMode::Collaborative);
    let wl = Workload::generate(&sys.corpus, workload_for(&cfg, 300), cfg.seed);
    let n = wl.events.len();
    let (stats, m) = sys.serve_async(&wl, Driver::Fixed(edge_assist()));
    assert_eq!(stats.queries, 0, "every query shed before service");
    assert_eq!(m.shed_deadline, n);
    assert_eq!(m.completed, 0);
    assert_eq!(stats.serve.unwrap().shed_deadline, n);
}

#[test]
fn downgrade_admission_forces_cheap_local_tier() {
    let mut cfg = collab_cfg();
    cfg.serve.admission = AdmissionPolicy::Downgrade;
    cfg.serve.slo_ms = 0.01;
    let mut sys = SimSystem::new(cfg.clone(), KnowledgeMode::Collaborative);
    let wl = Workload::generate(&sys.corpus, workload_for(&cfg, 300), cfg.seed);
    let n = wl.events.len();
    // Ask for the expensive cloud arm; admission downgrades every query.
    let cloud = Arm {
        retrieval: Retrieval::CloudGraph,
        gen: GenLoc::CloudLlm,
    };
    let (stats, m) = sys.serve_async(&wl, Driver::Fixed(cloud));
    assert_eq!(m.downgraded, n);
    assert_eq!(stats.queries, n, "downgrade serves everything");
    assert_eq!(stats.tier_queries[TIER_CLOUD], 0, "no query reached the cloud");
    assert_eq!(stats.tier_queries[TIER_LOCAL], n);
}

#[test]
fn bounded_queue_sheds_on_overflow() {
    let mut cfg = collab_cfg();
    cfg.serve.queue_cap = 1;
    let mut sys = SimSystem::new(cfg.clone(), KnowledgeMode::Collaborative);
    let wl = Workload::generate(&sys.corpus, workload_for(&cfg, 500), cfg.seed);
    let (stats, m) = sys.serve_async(&wl, Driver::Fixed(edge_assist()));
    assert!(
        m.shed_overflow > 0,
        "cap 1 with sub-service inter-arrival gaps must shed"
    );
    assert_eq!(stats.queries + m.shed_overflow, wl.events.len());
    assert_eq!(stats.serve.unwrap().shed_overflow, m.shed_overflow);
}

// ---------------------------------------------------------------------------
// (e) edge churn through the serving plane
// ---------------------------------------------------------------------------

#[test]
fn killed_edge_reroutes_and_revived_edge_cold_syncs() {
    let cfg = collab_cfg();
    let mut sys = SimSystem::new(cfg.clone(), KnowledgeMode::Collaborative);
    let wl = Workload::generate(&sys.corpus, workload_for(&cfg, 600), cfg.seed);
    // Split into two phases that keep the original (monotone) step
    // numbering, so the gossip cadence keeps advancing across both.
    let mid = wl.events.len() / 2;
    let first = Workload {
        spec: wl.spec.clone(),
        events: wl.events[..mid].to_vec(),
        edge_home_topics: wl.edge_home_topics.clone(),
        trends: wl.trends.clone(),
    };
    let second = Workload {
        spec: wl.spec.clone(),
        events: wl.events[mid..].to_vec(),
        edge_home_topics: wl.edge_home_topics.clone(),
        trends: wl.trends.clone(),
    };
    assert!(first.events.iter().any(|e| e.edge_id == 0));

    // Warm the cluster a little, then take edge 0 down.
    sys.cluster.kill_edge(0);
    assert!(sys.cluster.nodes[0].is_empty(), "kill wipes the store");
    let (stats, m) = sys.serve_async(&first, Driver::Fixed(edge_assist()));
    assert_eq!(stats.queries, first.events.len(), "nothing shed: rerouted instead");
    assert!(m.rerouted > 0, "edge-0 arrivals rerouted to an alive peer");
    assert!(
        m.sessions.iter().all(|s| s.edge_id != 0),
        "no session served on the dead edge"
    );
    assert!(sys.cluster.nodes[0].is_empty(), "dead edge stayed empty");

    // Revive: topology rewires edge 0 back in and subsequent gossip
    // rounds cold-sync it from its neighbors.
    sys.cluster.revive_edge(0);
    let (_, m2) = sys.serve_async(&second, Driver::Fixed(edge_assist()));
    assert_eq!(m2.rerouted, 0, "alive again: home arrivals stay home");
    assert!(m2.gossip_rounds > 0, "second phase must gossip to cold-sync");
    assert!(
        !sys.cluster.nodes[0].is_empty(),
        "revived edge repopulated via gossip"
    );
}
