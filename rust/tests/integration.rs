//! Cross-module integration tests (no PJRT; virtual-time paths) plus
//! property-based tests on coordinator/gating/state invariants using the
//! in-repo `testutil::proptest` harness (offline proptest substitute).

use eaco_rag::cloud::{CloudNode, CloudSpec};
use eaco_rag::config::{QosPreset, SystemConfig};
use eaco_rag::coordinator::batcher::{DynamicBatcher, GenRequest};
use eaco_rag::corpus::{Corpus, Profile};
use eaco_rag::edge::{best_edge_for, EdgeNode};
use eaco_rag::gating::safeobo::{Observation, Qos, SafeObo};
use eaco_rag::gating::{standard_arms, GateContext};
use eaco_rag::index::KeywordIndex;
use eaco_rag::sim::{workload_for, KnowledgeMode, SimSystem};
use eaco_rag::testutil::proptest;
use eaco_rag::util::rng::Rng;
use eaco_rag::workload::{Workload, WorkloadSpec};

// ---------------------------------------------------------------------------
// corpus × graphrag × cloud
// ---------------------------------------------------------------------------

#[test]
fn cloud_distribution_improves_edge_overlap() {
    let corpus = Corpus::generate(Profile::Wiki, 11);
    let mut cloud = CloudNode::new(&corpus, 2, CloudSpec::default());
    let mut edge = EdgeNode::new(0, 800);

    // Queries from one topic; before distribution the edge knows nothing.
    let qas = corpus.qa_by_topic(3);
    let sample: Vec<usize> = qas.iter().copied().take(25).collect();
    let kws_of = |qa: usize| -> Vec<&str> { corpus.qa_keywords(&corpus.qa[qa]) };
    let before: f64 = sample
        .iter()
        .map(|&q| edge.overlap_ratio(&kws_of(q)))
        .sum::<f64>()
        / sample.len() as f64;

    let plan = cloud.plan_update(&corpus, 0, &sample);
    edge.apply_update(&corpus, &plan.chunks);

    let after: f64 = sample
        .iter()
        .map(|&q| edge.overlap_ratio(&kws_of(q)))
        .sum::<f64>()
        / sample.len() as f64;
    assert!(before < 0.2, "before {before}");
    assert!(after > 0.8, "after {after}");
}

#[test]
fn full_sim_pipeline_all_arms_work() {
    let cfg = SystemConfig {
        dataset: Profile::HarryPotter,
        edge_capacity: 500,
        ..SystemConfig::default()
    };
    let mut sys = SimSystem::new(cfg.clone(), KnowledgeMode::Adaptive);
    let wl = Workload::generate(&sys.corpus, workload_for(&cfg, 50), cfg.seed);
    for arm in standard_arms() {
        for ev in wl.events.iter().take(10) {
            let (outcome, _) = sys.serve(ev.qa_id, ev.edge_id, ev.step, arm);
            assert!(outcome.delay_s > 0.0);
            assert!(outcome.resource_cost > 0.0);
            assert!(outcome.tokens.output > 0.0);
        }
    }
}

// ---------------------------------------------------------------------------
// property tests (proptest substitute)
// ---------------------------------------------------------------------------

#[test]
fn prop_batcher_never_loses_or_duplicates_requests() {
    proptest(100, |rng| {
        let max_batch = 1 + rng.below(8);
        let mut b = DynamicBatcher::new(max_batch, 50.0);
        let n = 1 + rng.below(60);
        let tiers = ["a", "b", "c"];
        let mut seen: Vec<usize> = Vec::new();
        let mut now = 0.0;
        for id in 0..n {
            now += rng.f64() * 30.0;
            let tier = tiers[rng.below(3)];
            if let Some(batch) = b.push(GenRequest {
                request_id: id,
                tier: tier.into(),
                prompt: String::new(),
                max_new: 1,
                enqueued_ms: now,
            }) {
                assert!(batch.requests.len() <= max_batch);
                seen.extend(batch.requests.iter().map(|r| r.request_id));
            }
            for batch in b.poll_deadline(now) {
                seen.extend(batch.requests.iter().map(|r| r.request_id));
            }
        }
        for batch in b.drain() {
            assert!(batch.requests.len() <= max_batch);
            seen.extend(batch.requests.iter().map(|r| r.request_id));
        }
        seen.sort_unstable();
        let expect: Vec<usize> = (0..n).collect();
        assert_eq!(seen, expect, "requests lost or duplicated");
    });
}

#[test]
fn prop_edge_store_capacity_and_index_consistency() {
    let corpus = Corpus::generate(Profile::Wiki, 3);
    proptest(60, |rng| {
        let cap = 1 + rng.below(120);
        let mut edge = EdgeNode::new(0, cap);
        for _ in 0..rng.below(30) {
            let k = 1 + rng.below(40);
            let chunks: Vec<usize> =
                (0..k).map(|_| rng.below(corpus.chunks.len())).collect();
            edge.apply_update(&corpus, &chunks);
            // Invariant 1: capacity never exceeded.
            assert!(edge.len() <= cap, "len {} > cap {cap}", edge.len());
            // Invariant 2: index and FIFO agree.
            assert_eq!(edge.resident_chunks().count(), edge.index.len());
            for c in edge.resident_chunks() {
                assert!(edge.contains(c));
            }
        }
    });
}

#[test]
fn prop_overlap_ratio_bounds_and_monotonicity() {
    let corpus = Corpus::generate(Profile::HarryPotter, 5);
    proptest(60, |rng| {
        let mut ix = KeywordIndex::new();
        let mut edge_chunks: Vec<usize> = Vec::new();
        for _ in 0..rng.below(50) {
            let c = rng.below(corpus.chunks.len());
            ix.add_chunk(c, &corpus.chunks[c].keywords);
            edge_chunks.push(c);
        }
        let qa = &corpus.qa[rng.below(corpus.qa.len())];
        let kws = corpus.qa_keywords(qa);
        let r = ix.overlap_ratio(&kws);
        assert!((0.0..=1.0).contains(&r), "ratio {r}");
        // Adding the supporting chunks can only increase the ratio.
        for &c in &qa.supporting_chunks {
            ix.add_chunk(c, &corpus.chunks[c].keywords);
        }
        let r2 = ix.overlap_ratio(&kws);
        assert!(r2 + 1e-12 >= r, "{r2} < {r}");
        assert!(r2 > 0.99, "support present ⇒ full overlap, got {r2}");
    });
}

#[test]
fn prop_best_edge_returns_max_overlap() {
    let corpus = Corpus::generate(Profile::Wiki, 7);
    proptest(40, |rng| {
        let n_edges = 2 + rng.below(4);
        let mut edges: Vec<EdgeNode> = (0..n_edges)
            .map(|i| EdgeNode::new(i, 200))
            .collect();
        for e in edges.iter_mut() {
            let k = rng.below(80);
            let chunks: Vec<usize> =
                (0..k).map(|_| rng.below(corpus.chunks.len())).collect();
            e.apply_update(&corpus, &chunks);
        }
        let qa = &corpus.qa[rng.below(corpus.qa.len())];
        let kws = corpus.qa_keywords(qa);
        let local = rng.below(n_edges);
        let (best, ratio) = best_edge_for(&edges, local, &kws);
        for e in &edges {
            assert!(
                e.overlap_ratio(&kws) <= ratio + 1e-12,
                "edge {} beats chosen best",
                e.id
            );
        }
        assert!(best < n_edges);
    });
}

#[test]
fn prop_gate_always_returns_valid_arm_and_safe_set() {
    proptest(20, |rng| {
        let arms = standard_arms();
        let n = arms.len();
        let mut gate = SafeObo::new(
            arms,
            Qos {
                min_accuracy: 0.5 + rng.f64() * 0.4,
                max_delay_s: 0.5 + rng.f64() * 4.0,
            },
            rng.below(40),
            0.25 + rng.f64(),
            rng.next_u64(),
        );
        for step in 0..80 {
            let ctx = GateContext {
                cloud_delay_ms: 200.0 + rng.f64() * 300.0,
                edge_delay_ms: 10.0 + rng.f64() * 30.0,
                best_overlap: rng.f64(),
                best_edge_is_local: rng.chance(0.5),
                local_overlap: rng.f64(),
                neighbor_overlap: rng.f64(),
                hops: 1 + rng.below(3),
                length_tokens: 5 + rng.below(30),
                entity_count: 2 + rng.below(5),
            };
            let d = gate.decide(&ctx);
            // Invariants: arm valid; safe set nonempty; decision ∈ safe
            // set (post-warm-up); seed-safe arm always present.
            assert!(d.arm_idx < n);
            assert!(!d.safe_set.is_empty());
            if !d.explored {
                assert!(d.safe_set.contains(&d.arm_idx));
                assert!(d.safe_set.contains(&(n - 1)));
            }
            gate.observe(
                &ctx,
                d.arm_idx,
                Observation {
                    resource_cost: rng.f64() * 1000.0,
                    delay_cost: rng.f64() * 10.0,
                    accuracy: if rng.chance(0.7) { 1.0 } else { 0.0 },
                    delay_s: rng.f64() * 4.0,
                },
            );
            let _ = step;
        }
    });
}

#[test]
fn prop_workload_events_well_formed() {
    proptest(30, |rng| {
        let corpus = Corpus::generate(
            if rng.chance(0.5) {
                Profile::Wiki
            } else {
                Profile::HarryPotter
            },
            rng.next_u64(),
        );
        let spec = WorkloadSpec {
            num_edges: 1 + rng.below(8),
            steps: 1 + rng.below(300),
            drift_period: 1 + rng.below(200),
            trend_share: rng.f64() * 0.8,
            spatial_tilt: rng.f64(),
            mean_gap_ms: 1.0 + rng.f64() * 300.0,
        };
        let wl = Workload::generate(&corpus, spec.clone(), rng.next_u64());
        assert_eq!(wl.events.len(), spec.steps);
        for ev in &wl.events {
            assert!(ev.edge_id < spec.num_edges);
            assert!(ev.qa_id < corpus.qa.len());
            assert!(ev.gap_ms >= 0.0);
        }
    });
}

#[test]
fn prop_sim_serve_accounting_invariants() {
    let cfg = SystemConfig {
        edge_capacity: 300,
        ..SystemConfig::default()
    };
    let mut sys = SimSystem::new(cfg.clone(), KnowledgeMode::Adaptive);
    let arms = standard_arms();
    proptest(60, |rng| {
        let qa_id = rng.below(sys.corpus.qa.len());
        let edge = rng.below(cfg.num_edges);
        let step = rng.below(2000);
        let arm = arms[rng.below(arms.len())];
        let (o, _) = sys.serve(qa_id, edge, step, arm);
        // Cost must decompose per Eq. (1) with δ₁ = δ₂ = 1.
        assert!((o.total_cost - (o.resource_cost + o.delay_cost)).abs() < 1e-9);
        // Delay contains at least the user-edge hop.
        assert!(o.delay_s > 0.0);
        // Token accounting is consistent with the retrieved context.
        if o.retrieved.is_empty() {
            assert!(o.tokens.input < 80.0, "no context ⇒ small input");
        }
    });
}

// ---------------------------------------------------------------------------
// QoS preset behaviour
// ---------------------------------------------------------------------------

#[test]
fn delay_oriented_run_is_faster_than_cost_oriented() {
    let mk = |qos| {
        let cfg = SystemConfig {
            qos,
            ..SystemConfig::default()
        };
        let mut sys = SimSystem::new(cfg.clone(), KnowledgeMode::Adaptive);
        let wl = Workload::generate(&sys.corpus, workload_for(&cfg, 1000), cfg.seed);
        sys.run_eaco(&wl).0
    };
    let cost_run = mk(QosPreset::CostEfficient);
    let delay_run = mk(QosPreset::DelayOriented);
    assert!(
        delay_run.delay.mean() <= cost_run.delay.mean() + 0.05,
        "delay-oriented {:.2}s vs cost {:.2}s",
        delay_run.delay.mean(),
        cost_run.delay.mean()
    );
    assert!(
        cost_run.resource_cost.mean() <= delay_run.resource_cost.mean() * 1.05,
        "cost-oriented should be cheaper: {:.1} vs {:.1}",
        cost_run.resource_cost.mean(),
        delay_run.resource_cost.mean()
    );
}
