//! Deterministic network simulator (paper §4.1 context `d_t`).
//!
//! The gating context includes "network delays, which include both cloud
//! and edge delays, helping assess network availability". The prototype
//! in the paper measures these on a real testbed (edge ≈ 20–32 ms, cloud
//! ≈ 300–350 ms, Table 7); here we synthesize them deterministically:
//! each link has a base latency, log-normal jitter, and a slow sinusoidal
//! congestion component so that network conditions *vary over time* and
//! the gate has something real to adapt to.

use crate::util::rng::Rng;

/// A directed communication link in the edge/cloud topology.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Link {
    /// User device → its serving edge node.
    UserToEdge(usize),
    /// Serving edge → a collaborating edge (edge-assisted retrieval).
    EdgeToEdge(usize, usize),
    /// Serving edge → cloud (GraphRAG / 72B escalation).
    EdgeToCloud(usize),
}

/// Network simulation parameters.
#[derive(Clone, Debug)]
pub struct NetSpec {
    pub user_edge_base_ms: f64,
    pub edge_edge_base_ms: f64,
    pub edge_cloud_base_ms: f64,
    /// Log-normal jitter sigma (multiplicative).
    pub jitter_sigma: f64,
    /// Peak-hour congestion amplitude (fraction of base).
    pub congestion_amp: f64,
    /// Steps per congestion cycle.
    pub congestion_period: usize,
}

impl Default for NetSpec {
    fn default() -> Self {
        NetSpec {
            user_edge_base_ms: 20.0,
            edge_edge_base_ms: 32.0,
            edge_cloud_base_ms: 300.0,
            jitter_sigma: 0.15,
            congestion_amp: 0.35,
            congestion_period: 400,
        }
    }
}

/// The simulator. Stateless across queries except the RNG stream; the
/// congestion phase is a pure function of the step so replays of the same
/// seed reproduce identical delay traces.
#[derive(Clone, Debug)]
pub struct NetSim {
    pub spec: NetSpec,
    pub num_edges: usize,
    rng: Rng,
    /// Per-edge phase offsets so edges don't congest in lockstep.
    edge_phase: Vec<f64>,
}

impl NetSim {
    pub fn new(num_edges: usize, spec: NetSpec, seed: u64) -> Self {
        let mut rng = Rng::new(seed).fork("netsim");
        let edge_phase = (0..num_edges.max(1))
            .map(|_| rng.f64() * std::f64::consts::TAU)
            .collect();
        NetSim {
            spec,
            num_edges,
            rng,
            edge_phase,
        }
    }

    fn base(&self, link: Link) -> f64 {
        match link {
            Link::UserToEdge(_) => self.spec.user_edge_base_ms,
            Link::EdgeToEdge(a, b) => {
                if a == b {
                    0.0 // local retrieval has no inter-edge hop
                } else {
                    self.spec.edge_edge_base_ms
                }
            }
            Link::EdgeToCloud(_) => self.spec.edge_cloud_base_ms,
        }
    }

    fn phase_of(&self, link: Link) -> f64 {
        let e = match link {
            Link::UserToEdge(e) | Link::EdgeToCloud(e) => e,
            Link::EdgeToEdge(a, _) => a,
        };
        self.edge_phase[e % self.edge_phase.len()]
    }

    /// Congestion multiplier at `step` for `link` (deterministic).
    pub fn congestion(&self, link: Link, step: usize) -> f64 {
        let phase = self.phase_of(link);
        let theta =
            step as f64 / self.spec.congestion_period as f64 * std::f64::consts::TAU + phase;
        1.0 + self.spec.congestion_amp * 0.5 * (1.0 + theta.sin()) // in [1, 1+amp]
    }

    /// One-way delay sample for a link at a step (jittered).
    pub fn delay_ms(&mut self, link: Link, step: usize) -> f64 {
        let base = self.base(link);
        if base == 0.0 {
            return 0.0;
        }
        let congested = base * self.congestion(link, step);
        let jitter = (self.rng.normal() * self.spec.jitter_sigma).exp();
        congested * jitter
    }

    /// Expected (jitter-free) delay — what a monitoring plane would
    /// report; the gate observes this as context `d_t`.
    pub fn expected_delay_ms(&self, link: Link, step: usize) -> f64 {
        self.base(link) * self.congestion(link, step)
    }

    /// Static cost (ms) of the a↔b inter-edge link, used by the cluster
    /// topology to pick neighbor sets. Derived from the base inter-edge
    /// latency scaled by a virtual *ring distance* between the edge
    /// sites (nearby ids are topologically close — same metro, adjacent
    /// rack rows), so gossip and collaborative retrieval prefer cheap
    /// links. Symmetric, deterministic (no jitter), 0 for `a == b`.
    pub fn pair_cost_ms(&self, a: usize, b: usize) -> f64 {
        if a == b {
            return 0.0;
        }
        let n = self.num_edges.max(2).max(a.max(b) + 1);
        let raw = a.abs_diff(b);
        let ring = raw.min(n - raw) as f64;
        let half = (n as f64 / 2.0).max(1.0);
        self.spec.edge_edge_base_ms * (0.5 + ring / half)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sim() -> NetSim {
        NetSim::new(4, NetSpec::default(), 7)
    }

    #[test]
    fn cloud_slower_than_edge() {
        let mut s = sim();
        let mut cloud = 0.0;
        let mut edge = 0.0;
        for step in 0..200 {
            cloud += s.delay_ms(Link::EdgeToCloud(0), step);
            edge += s.delay_ms(Link::UserToEdge(0), step);
        }
        assert!(cloud > edge * 5.0);
    }

    #[test]
    fn self_edge_link_free() {
        let mut s = sim();
        assert_eq!(s.delay_ms(Link::EdgeToEdge(2, 2), 10), 0.0);
        assert!(s.delay_ms(Link::EdgeToEdge(2, 3), 10) > 0.0);
    }

    #[test]
    fn congestion_varies_over_time() {
        let s = sim();
        let d: Vec<f64> = (0..400)
            .step_by(40)
            .map(|t| s.expected_delay_ms(Link::EdgeToCloud(1), t))
            .collect();
        let min = d.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = d.iter().cloned().fold(0.0, f64::max);
        assert!(max / min > 1.15, "congestion range too flat: {min}..{max}");
    }

    #[test]
    fn expected_delay_deterministic() {
        let a = sim();
        let b = sim();
        for step in [0, 17, 391] {
            assert_eq!(
                a.expected_delay_ms(Link::EdgeToCloud(0), step),
                b.expected_delay_ms(Link::EdgeToCloud(0), step)
            );
        }
    }

    #[test]
    fn jitter_positive_and_bounded() {
        let mut s = sim();
        for step in 0..500 {
            let d = s.delay_ms(Link::UserToEdge(0), step);
            assert!(d > 0.0 && d < 200.0, "delay {d}");
        }
    }

    #[test]
    fn pair_cost_symmetric_and_ring_shaped() {
        let s = NetSim::new(8, NetSpec::default(), 3);
        assert_eq!(s.pair_cost_ms(2, 2), 0.0);
        for a in 0..8 {
            for b in 0..8 {
                assert_eq!(s.pair_cost_ms(a, b), s.pair_cost_ms(b, a));
            }
        }
        // Adjacent edges cheaper than antipodal ones; wraparound counts.
        assert!(s.pair_cost_ms(0, 1) < s.pair_cost_ms(0, 4));
        assert_eq!(s.pair_cost_ms(0, 7), s.pair_cost_ms(0, 1));
        assert!(s.pair_cost_ms(0, 1) > 0.0);
    }

    #[test]
    fn edges_have_distinct_phases() {
        let s = sim();
        let c0 = s.congestion(Link::EdgeToCloud(0), 100);
        let c1 = s.congestion(Link::EdgeToCloud(1), 100);
        assert_ne!(c0, c1);
    }
}
