//! Deterministic network simulator (paper §4.1 context `d_t`).
//!
//! The gating context includes "network delays, which include both cloud
//! and edge delays, helping assess network availability". The prototype
//! in the paper measures these on a real testbed (edge ≈ 20–32 ms, cloud
//! ≈ 300–350 ms, Table 7); here we synthesize them deterministically:
//! each link has a base latency, log-normal jitter, and a slow sinusoidal
//! congestion component so that network conditions *vary over time* and
//! the gate has something real to adapt to.

use crate::util::rng::Rng;

/// Runtime fault state injected by the chaos plane ([`crate::chaos`]):
/// per-link delay multipliers (degraded links) and a partition group
/// assignment (edges in different groups are mutually unreachable).
/// All fields default to the healthy state; a `NetSim` without faults
/// never allocates one, so the no-faults paths are byte-for-byte the
/// pre-chaos computation.
#[derive(Clone, Debug)]
pub struct LinkFaults {
    /// Per-edge multiplier on the edge→cloud uplink (1.0 = healthy).
    uplink: Vec<f64>,
    /// Per-edge multiplier on the user→edge access link.
    access: Vec<f64>,
    /// Symmetric n×n multipliers on the edge↔edge links.
    pair: Vec<f64>,
    /// Partition group per edge; `None` = no partition.
    group: Option<Vec<usize>>,
}

impl LinkFaults {
    fn new(num_edges: usize) -> LinkFaults {
        let n = num_edges.max(1);
        LinkFaults {
            uplink: vec![1.0; n],
            access: vec![1.0; n],
            pair: vec![1.0; n * n],
            group: None,
        }
    }
}

/// A directed communication link in the edge/cloud topology.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Link {
    /// User device → its serving edge node.
    UserToEdge(usize),
    /// Serving edge → a collaborating edge (edge-assisted retrieval).
    EdgeToEdge(usize, usize),
    /// Serving edge → cloud (GraphRAG / 72B escalation).
    EdgeToCloud(usize),
}

/// Network simulation parameters.
#[derive(Clone, Debug)]
pub struct NetSpec {
    pub user_edge_base_ms: f64,
    pub edge_edge_base_ms: f64,
    pub edge_cloud_base_ms: f64,
    /// Log-normal jitter sigma (multiplicative).
    pub jitter_sigma: f64,
    /// Peak-hour congestion amplitude (fraction of base).
    pub congestion_amp: f64,
    /// Steps per congestion cycle.
    pub congestion_period: usize,
}

impl Default for NetSpec {
    fn default() -> Self {
        NetSpec {
            user_edge_base_ms: 20.0,
            edge_edge_base_ms: 32.0,
            edge_cloud_base_ms: 300.0,
            jitter_sigma: 0.15,
            congestion_amp: 0.35,
            congestion_period: 400,
        }
    }
}

/// The simulator. Stateless across queries except the RNG stream; the
/// congestion phase is a pure function of the step so replays of the same
/// seed reproduce identical delay traces.
#[derive(Clone, Debug)]
pub struct NetSim {
    pub spec: NetSpec,
    pub num_edges: usize,
    rng: Rng,
    /// Per-edge phase offsets so edges don't congest in lockstep.
    edge_phase: Vec<f64>,
    /// Chaos-plane fault state; `None` (the default) keeps every path
    /// bit-identical to a fault-free simulator.
    faults: Option<LinkFaults>,
}

impl NetSim {
    pub fn new(num_edges: usize, spec: NetSpec, seed: u64) -> Self {
        let mut rng = Rng::new(seed).fork("netsim");
        let edge_phase = (0..num_edges.max(1))
            .map(|_| rng.f64() * std::f64::consts::TAU)
            .collect();
        NetSim {
            spec,
            num_edges,
            rng,
            edge_phase,
            faults: None,
        }
    }

    /// Lazily materialize the fault state (first chaos event).
    fn faults_mut(&mut self) -> &mut LinkFaults {
        let n = self.num_edges;
        self.faults.get_or_insert_with(|| LinkFaults::new(n))
    }

    /// Any fault state active (degraded links or a partition)?
    pub fn faulted(&self) -> bool {
        self.faults.is_some()
    }

    /// Degrade the edge→cloud uplink of edge `e` (or every edge when
    /// `None`) by `factor` (≥ 1 slows it down; 1.0 restores). RNG-free:
    /// multipliers apply after the jitter draw, so the random stream of
    /// every delay sample is untouched.
    pub fn set_uplink_factor(&mut self, e: Option<usize>, factor: f64) {
        let f = self.faults_mut();
        match e {
            Some(e) => f.uplink[e % f.uplink.len()] = factor,
            None => f.uplink.fill(factor),
        }
    }

    /// Degrade the user→edge access link of edge `e` (or all edges).
    pub fn set_access_factor(&mut self, e: Option<usize>, factor: f64) {
        let f = self.faults_mut();
        match e {
            Some(e) => f.access[e % f.access.len()] = factor,
            None => f.access.fill(factor),
        }
    }

    /// Degrade the a↔b inter-edge link (symmetric) by `factor`.
    pub fn set_pair_factor(&mut self, a: usize, b: usize, factor: f64) {
        let n = self.num_edges.max(1);
        let f = self.faults_mut();
        if a < n && b < n {
            f.pair[a * n + b] = factor;
            f.pair[b * n + a] = factor;
        }
    }

    /// Impose a partition: `group_of[e]` is edge `e`'s partition group;
    /// edges in different groups become mutually unreachable (their
    /// links report infinite delay/cost until [`Self::clear_partition`]).
    /// The cluster plane computes the same group vector so routing,
    /// gossip, and the delay model agree on reachability.
    pub fn set_partition(&mut self, group_of: &[usize]) {
        let n = self.num_edges.max(1);
        let mut g = vec![0usize; n];
        for (e, slot) in g.iter_mut().enumerate() {
            *slot = group_of.get(e).copied().unwrap_or(e);
        }
        self.faults_mut().group = Some(g);
    }

    /// Heal the partition (degraded-link factors survive).
    pub fn clear_partition(&mut self) {
        if let Some(f) = self.faults.as_mut() {
            f.group = None;
        }
    }

    /// Can edges `a` and `b` currently reach each other? Always true
    /// without a partition.
    pub fn reachable(&self, a: usize, b: usize) -> bool {
        if a == b {
            return true;
        }
        match self.faults.as_ref().and_then(|f| f.group.as_ref()) {
            Some(g) => g.get(a) == g.get(b),
            None => true,
        }
    }

    /// Current fault multiplier for `link`: 1.0 when healthy, the
    /// configured degradation factor when degraded, and +∞ for an
    /// edge↔edge link severed by a partition (an unreachable peer is an
    /// infinitely slow one — uniform across the delay/cost functions).
    fn fault_factor(&self, link: Link) -> f64 {
        let Some(f) = self.faults.as_ref() else {
            return 1.0;
        };
        match link {
            Link::UserToEdge(e) => f.access[e % f.access.len()],
            Link::EdgeToCloud(e) => f.uplink[e % f.uplink.len()],
            Link::EdgeToEdge(a, b) => {
                if !self.reachable(a, b) {
                    return f64::INFINITY;
                }
                let n = self.num_edges.max(1);
                if a < n && b < n {
                    f.pair[a * n + b]
                } else {
                    1.0
                }
            }
        }
    }

    fn base(&self, link: Link) -> f64 {
        match link {
            Link::UserToEdge(_) => self.spec.user_edge_base_ms,
            Link::EdgeToEdge(a, b) => {
                if a == b {
                    0.0 // local retrieval has no inter-edge hop
                } else {
                    self.spec.edge_edge_base_ms
                }
            }
            Link::EdgeToCloud(_) => self.spec.edge_cloud_base_ms,
        }
    }

    fn phase_of(&self, link: Link) -> f64 {
        let e = match link {
            Link::UserToEdge(e) | Link::EdgeToCloud(e) => e,
            Link::EdgeToEdge(a, _) => a,
        };
        self.edge_phase[e % self.edge_phase.len()]
    }

    /// Congestion multiplier at `step` for `link` (deterministic).
    pub fn congestion(&self, link: Link, step: usize) -> f64 {
        let phase = self.phase_of(link);
        let theta =
            step as f64 / self.spec.congestion_period as f64 * std::f64::consts::TAU + phase;
        1.0 + self.spec.congestion_amp * 0.5 * (1.0 + theta.sin()) // in [1, 1+amp]
    }

    /// One-way delay sample for a link at a step (jittered). Chaos
    /// fault multipliers apply *after* the jitter draw, so injecting or
    /// lifting a fault never changes how many RNG samples a run
    /// consumes; with no fault state active the computation is
    /// byte-for-byte the fault-free one.
    pub fn delay_ms(&mut self, link: Link, step: usize) -> f64 {
        let base = self.base(link);
        if base == 0.0 {
            return 0.0;
        }
        let congested = base * self.congestion(link, step);
        let jitter = (self.rng.normal() * self.spec.jitter_sigma).exp();
        match self.faults {
            None => congested * jitter,
            Some(_) => congested * jitter * self.fault_factor(link),
        }
    }

    /// Expected (jitter-free) delay — what a monitoring plane would
    /// report; the gate observes this as context `d_t`. Consults the
    /// chaos fault state: degraded links scale up, partitioned
    /// edge↔edge links report +∞.
    pub fn expected_delay_ms(&self, link: Link, step: usize) -> f64 {
        let base = self.base(link) * self.congestion(link, step);
        match self.faults {
            None => base,
            Some(_) => base * self.fault_factor(link),
        }
    }

    /// Static cost (ms) of the a↔b inter-edge link, used by the cluster
    /// topology to pick neighbor sets. Derived from the base inter-edge
    /// latency scaled by a virtual *ring distance* between the edge
    /// sites (nearby ids are topologically close — same metro, adjacent
    /// rack rows), so gossip and collaborative retrieval prefer cheap
    /// links. Symmetric, deterministic (no jitter), 0 for `a == b`.
    /// Consults the chaos fault state like [`Self::expected_delay_ms`]:
    /// a degraded pair link costs proportionally more and a partitioned
    /// pair costs +∞ (unreachable). Note the cluster [`Topology`]
    /// snapshots these costs at build time — machines don't move, so
    /// live fault state changes reachability/adjacency (via the
    /// partition-aware rewire), never the static geometry.
    ///
    /// [`Topology`]: crate::cluster::topology::Topology
    pub fn pair_cost_ms(&self, a: usize, b: usize) -> f64 {
        if a == b {
            return 0.0;
        }
        let n = self.num_edges.max(2).max(a.max(b) + 1);
        let raw = a.abs_diff(b);
        let ring = raw.min(n - raw) as f64;
        let half = (n as f64 / 2.0).max(1.0);
        let cost = self.spec.edge_edge_base_ms * (0.5 + ring / half);
        match self.faults {
            None => cost,
            Some(_) => cost * self.fault_factor(Link::EdgeToEdge(a, b)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sim() -> NetSim {
        NetSim::new(4, NetSpec::default(), 7)
    }

    #[test]
    fn cloud_slower_than_edge() {
        let mut s = sim();
        let mut cloud = 0.0;
        let mut edge = 0.0;
        for step in 0..200 {
            cloud += s.delay_ms(Link::EdgeToCloud(0), step);
            edge += s.delay_ms(Link::UserToEdge(0), step);
        }
        assert!(cloud > edge * 5.0);
    }

    #[test]
    fn self_edge_link_free() {
        let mut s = sim();
        assert_eq!(s.delay_ms(Link::EdgeToEdge(2, 2), 10), 0.0);
        assert!(s.delay_ms(Link::EdgeToEdge(2, 3), 10) > 0.0);
    }

    #[test]
    fn congestion_varies_over_time() {
        let s = sim();
        let d: Vec<f64> = (0..400)
            .step_by(40)
            .map(|t| s.expected_delay_ms(Link::EdgeToCloud(1), t))
            .collect();
        let min = d.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = d.iter().cloned().fold(0.0, f64::max);
        assert!(max / min > 1.15, "congestion range too flat: {min}..{max}");
    }

    #[test]
    fn expected_delay_deterministic() {
        let a = sim();
        let b = sim();
        for step in [0, 17, 391] {
            assert_eq!(
                a.expected_delay_ms(Link::EdgeToCloud(0), step),
                b.expected_delay_ms(Link::EdgeToCloud(0), step)
            );
        }
    }

    #[test]
    fn jitter_positive_and_bounded() {
        let mut s = sim();
        for step in 0..500 {
            let d = s.delay_ms(Link::UserToEdge(0), step);
            assert!(d > 0.0 && d < 200.0, "delay {d}");
        }
    }

    #[test]
    fn pair_cost_symmetric_and_ring_shaped() {
        let s = NetSim::new(8, NetSpec::default(), 3);
        assert_eq!(s.pair_cost_ms(2, 2), 0.0);
        for a in 0..8 {
            for b in 0..8 {
                assert_eq!(s.pair_cost_ms(a, b), s.pair_cost_ms(b, a));
            }
        }
        // Adjacent edges cheaper than antipodal ones; wraparound counts.
        assert!(s.pair_cost_ms(0, 1) < s.pair_cost_ms(0, 4));
        assert_eq!(s.pair_cost_ms(0, 7), s.pair_cost_ms(0, 1));
        assert!(s.pair_cost_ms(0, 1) > 0.0);
    }

    #[test]
    fn fault_free_sim_matches_pre_fault_bits() {
        // A sim that touches no fault API must draw the same RNG stream
        // and produce the exact same bits as one where faults were set
        // and fully restored (restore = factor 1.0 + healed partition).
        let mut clean = sim();
        let mut healed = sim();
        healed.set_uplink_factor(None, 8.0);
        healed.set_partition(&[0, 0, 1, 1]);
        healed.set_uplink_factor(None, 1.0);
        healed.clear_partition();
        for step in 0..100 {
            for link in [Link::UserToEdge(1), Link::EdgeToEdge(0, 3), Link::EdgeToCloud(2)] {
                assert_eq!(
                    clean.delay_ms(link, step).to_bits(),
                    healed.delay_ms(link, step).to_bits()
                );
                assert_eq!(
                    clean.expected_delay_ms(link, step).to_bits(),
                    healed.expected_delay_ms(link, step).to_bits()
                );
            }
        }
        assert_eq!(clean.pair_cost_ms(0, 2).to_bits(), healed.pair_cost_ms(0, 2).to_bits());
    }

    #[test]
    fn degraded_links_scale_without_extra_rng_draws() {
        let mut degraded = sim();
        degraded.set_uplink_factor(Some(0), 4.0);
        degraded.set_access_factor(None, 2.0);
        let mut clean = sim();
        for step in 0..50 {
            // Same RNG stream order: sample the same links in the same
            // order on both sims and compare scaled values exactly.
            let (dc, du) = (
                clean.delay_ms(Link::EdgeToCloud(0), step),
                clean.delay_ms(Link::UserToEdge(1), step),
            );
            let (fc, fu) = (
                degraded.delay_ms(Link::EdgeToCloud(0), step),
                degraded.delay_ms(Link::UserToEdge(1), step),
            );
            assert_eq!(fc.to_bits(), (dc * 4.0).to_bits());
            assert_eq!(fu.to_bits(), (du * 2.0).to_bits());
        }
        // The untouched uplink of edge 1 is unscaled.
        assert_eq!(
            degraded.expected_delay_ms(Link::EdgeToCloud(1), 7),
            clean.expected_delay_ms(Link::EdgeToCloud(1), 7)
        );
    }

    #[test]
    fn partition_severs_cross_group_links_only() {
        let mut s = sim();
        s.set_partition(&[0, 0, 1, 1]);
        assert!(s.reachable(0, 1) && s.reachable(2, 3));
        assert!(!s.reachable(0, 2) && !s.reachable(1, 3));
        assert!(s.reachable(2, 2));
        assert_eq!(s.pair_cost_ms(0, 2), f64::INFINITY);
        assert_eq!(s.expected_delay_ms(Link::EdgeToEdge(1, 2), 5), f64::INFINITY);
        assert!(s.pair_cost_ms(0, 1).is_finite());
        // Cloud/access links are unaffected by an edge partition.
        assert!(s.expected_delay_ms(Link::EdgeToCloud(0), 5).is_finite());
        s.clear_partition();
        assert!(s.reachable(0, 2));
        assert!(s.pair_cost_ms(0, 2).is_finite());
    }

    #[test]
    fn pair_degradation_is_symmetric() {
        let mut s = sim();
        let before = s.pair_cost_ms(1, 3);
        s.set_pair_factor(1, 3, 3.0);
        assert_eq!(s.pair_cost_ms(1, 3), before * 3.0);
        assert_eq!(s.pair_cost_ms(3, 1), before * 3.0);
        assert_eq!(s.pair_cost_ms(1, 2), s.pair_cost_ms(1, 2));
        s.set_pair_factor(1, 3, 1.0);
        assert_eq!(s.pair_cost_ms(1, 3).to_bits(), before.to_bits());
    }

    #[test]
    fn edges_have_distinct_phases() {
        let s = sim();
        let c0 = s.congestion(Link::EdgeToCloud(0), 100);
        let c1 = s.congestion(Link::EdgeToCloud(1), 100);
        assert_ne!(c0, c1);
    }
}
