//! Strategy execution model: tokens, delay, and cost per gate arm.
//!
//! Turns an arm choice into the observable outcome triple the paper's
//! optimization consumes — (accuracy ρ_t, response time h_t, costs u_r,
//! u_d). Token counts come from the *actual* retrieved context; delays
//! combine netsim link samples with a generation-time model calibrated
//! to Table 4 (e.g. 3B LLM-only ≈ 0.30 s on a 4090; 72B+GraphRAG ≈ 1 s
//! on the emulated 8×H100 cloud); costs follow `cost::inference_tflops`
//! and the Table-3 GPU scaling.

use crate::corpus::ChunkId;
use crate::cost::{text_tokens, CostModel, Gpu, TokenUsage};
use crate::gating::{Arm, GenLoc, Retrieval};
use crate::oracle::ContextSource;
use crate::util::rng::Rng;

/// Generation-rate model (tokens/second) for an emulated tier.
///
/// Rates scale inversely with parameter count and linearly with the
/// serving hardware: the edge runs a single RTX 4090, the cloud an
/// emulated 8×H100 pod (paper §5). Constants calibrated so Table 4's
/// delay column reproduces: 3B prefill ≈ 6k tok/s & decode ≈ 100 tok/s
/// on the edge; 72B prefill ≈ 30k tok/s & decode ≈ 400 tok/s in the
/// cloud.
#[derive(Clone, Copy, Debug)]
pub struct GenRates {
    pub edge_prefill_per_b: f64,
    pub edge_decode_per_b: f64,
    pub cloud_prefill_per_b: f64,
    pub cloud_decode_per_b: f64,
}

impl Default for GenRates {
    fn default() -> Self {
        GenRates {
            edge_prefill_per_b: 18_000.0,
            edge_decode_per_b: 300.0,
            cloud_prefill_per_b: 4_000_000.0,
            cloud_decode_per_b: 43_200.0,
        }
    }
}

impl GenRates {
    /// Generation wall-time (seconds) for a tier at a location.
    pub fn gen_seconds(
        &self,
        loc: GenLoc,
        params_b: f64,
        in_tokens: f64,
        out_tokens: f64,
    ) -> f64 {
        let (pre, dec) = match loc {
            GenLoc::EdgeSlm => (
                self.edge_prefill_per_b / params_b,
                self.edge_decode_per_b / params_b,
            ),
            GenLoc::CloudLlm => (
                self.cloud_prefill_per_b / params_b,
                self.cloud_decode_per_b / params_b,
            ),
        };
        in_tokens / pre + out_tokens / dec
    }
}

/// Fixed non-generation latencies (seconds).
pub const LOCAL_RETRIEVAL_S: f64 = 0.005;
pub const GRAPH_SEARCH_S: f64 = 0.20;

/// Everything observable about one served query.
#[derive(Clone, Debug)]
pub struct Outcome {
    pub arm: Arm,
    pub retrieved: Vec<ChunkId>,
    pub source: ContextSource,
    pub tokens: TokenUsage,
    pub delay_s: f64,
    /// u_r (TFLOPs) and u_d (delay · GPU TFLOPS).
    pub resource_cost: f64,
    pub delay_cost: f64,
    pub total_cost: f64,
    pub gen_gpu: Gpu,
}

/// Inputs needed to realize an outcome (assembled by the sim runner).
pub struct StrategyInputs<'a> {
    pub arm: Arm,
    /// Retrieved context and its char volume (by the arm's source).
    pub retrieved: Vec<ChunkId>,
    pub context_chars: usize,
    /// Whether retrieval came from community-distributed edge content.
    pub community_content: bool,
    /// Question length (tokens).
    pub question_tokens: usize,
    /// Sampled network delays for this query (seconds).
    pub net_user_edge_s: f64,
    pub net_edge_edge_s: f64,
    pub net_edge_cloud_s: f64,
    /// Emulated parameter counts.
    pub edge_params_b: f64,
    pub cloud_params_b: f64,
    pub rates: &'a GenRates,
    pub cost: &'a CostModel,
}

/// Realize the outcome of serving a query with a given arm.
pub fn execute(inp: StrategyInputs<'_>, rng: &mut Rng) -> Outcome {
    let arm = inp.arm;

    // --- context source & retrieval latency ---
    let (source, retrieval_s) = match arm.retrieval {
        Retrieval::None => (ContextSource::None, 0.0),
        Retrieval::LocalNaive => (
            if inp.community_content {
                ContextSource::EdgeCommunity
            } else {
                ContextSource::NaiveRag
            },
            LOCAL_RETRIEVAL_S,
        ),
        Retrieval::EdgeAssisted => (
            if inp.community_content {
                ContextSource::EdgeCommunity
            } else {
                ContextSource::NaiveRag
            },
            inp.net_edge_edge_s + LOCAL_RETRIEVAL_S,
        ),
        Retrieval::CloudGraph => (
            ContextSource::GraphRag,
            inp.net_edge_cloud_s + GRAPH_SEARCH_S,
        ),
    };

    // --- tokens ---
    let in_tokens = inp.question_tokens as f64 + text_tokens(inp.context_chars);
    let out_tokens = match source {
        // GraphRAG-grounded answers are verbose (Table 1: 142.7 ± 91).
        ContextSource::GraphRag => 110.0 + rng.f64() * 70.0,
        ContextSource::None => 18.0 + rng.f64() * 18.0,
        _ => 20.0 + rng.f64() * 14.0,
    };

    // --- generation ---
    let (params_b, gen_gpu) = match arm.gen {
        GenLoc::EdgeSlm => (inp.edge_params_b, Gpu::Rtx4090),
        GenLoc::CloudLlm => (inp.cloud_params_b, Gpu::H100),
    };
    let gen_s = inp.rates.gen_seconds(arm.gen, params_b, in_tokens, out_tokens);

    // Cloud generation needs a cloud hop unless retrieval already went
    // there (context is forwarded within the data center).
    let extra_cloud_hop = match (arm.gen, arm.retrieval) {
        (GenLoc::CloudLlm, Retrieval::CloudGraph) => 0.0,
        (GenLoc::CloudLlm, _) => inp.net_edge_cloud_s,
        _ => 0.0,
    };

    let delay_s = inp.net_user_edge_s + retrieval_s + extra_cloud_hop + gen_s;

    // --- costs (Eq. 1) ---
    let resource_cost = inp.cost.resource_cost(params_b, in_tokens, out_tokens);
    let delay_cost = inp.cost.time_cost(delay_s, gen_gpu);
    let total_cost = inp.cost.total(resource_cost, delay_cost);

    Outcome {
        arm,
        retrieved: inp.retrieved,
        source,
        tokens: TokenUsage {
            input: in_tokens,
            output: out_tokens,
        },
        delay_s,
        resource_cost,
        delay_cost,
        total_cost,
        gen_gpu,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::CostWeights;
    use crate::gating::{Arm, GenLoc, Retrieval};

    fn base_inputs<'a>(
        arm: Arm,
        context_chars: usize,
        rates: &'a GenRates,
        cost: &'a CostModel,
    ) -> StrategyInputs<'a> {
        StrategyInputs {
            arm,
            retrieved: vec![],
            context_chars,
            community_content: false,
            question_tokens: 16,
            net_user_edge_s: 0.020,
            net_edge_edge_s: 0.032,
            net_edge_cloud_s: 0.300,
            edge_params_b: 3.0,
            cloud_params_b: 72.0,
            rates,
            cost,
        }
    }

    fn run(arm: Arm, context_chars: usize) -> Outcome {
        let rates = GenRates::default();
        let cost = CostModel::new(CostWeights::default());
        let mut rng = Rng::new(1);
        execute(base_inputs(arm, context_chars, &rates, &cost), &mut rng)
    }

    #[test]
    fn llm_only_delay_near_table4() {
        // Table 4: 3B LLM-only = 0.30 ± 0.07 s.
        let o = run(Arm { retrieval: Retrieval::None, gen: GenLoc::EdgeSlm }, 0);
        assert!((0.15..0.55).contains(&o.delay_s), "delay {}", o.delay_s);
        assert!(o.resource_cost < 1.0, "cost {}", o.resource_cost);
    }

    #[test]
    fn naive_rag_delay_near_table4() {
        // Table 4: 3B + Naive RAG = 0.88 ± 0.11 s with ~3.6k-token context.
        let o = run(
            Arm { retrieval: Retrieval::LocalNaive, gen: GenLoc::EdgeSlm },
            14_400, // ≈3600 tokens
        );
        assert!((0.6..1.3).contains(&o.delay_s), "delay {}", o.delay_s);
        assert!((15.0..30.0).contains(&o.resource_cost), "cost {}", o.resource_cost);
    }

    #[test]
    fn graphrag_3b_slowest_cloud72_fast() {
        // Table 4: 3B+GraphRAG ≈ 3.0 s (long context on weak edge GPU),
        // 72B+GraphRAG ≈ 1.0 s (big pod) — the crossover the gate exploits.
        let slm = run(
            Arm { retrieval: Retrieval::CloudGraph, gen: GenLoc::EdgeSlm },
            24_000,
        );
        let llm = run(
            Arm { retrieval: Retrieval::CloudGraph, gen: GenLoc::CloudLlm },
            24_000,
        );
        assert!(slm.delay_s > 2.0, "slm {}", slm.delay_s);
        assert!((0.5..1.6).contains(&llm.delay_s), "llm {}", llm.delay_s);
        assert!(llm.resource_cost > slm.resource_cost * 5.0);
    }

    #[test]
    fn graph_out_tokens_verbose() {
        let o = run(
            Arm { retrieval: Retrieval::CloudGraph, gen: GenLoc::CloudLlm },
            24_000,
        );
        assert!(o.tokens.output > 100.0);
        let plain = run(Arm { retrieval: Retrieval::None, gen: GenLoc::EdgeSlm }, 0);
        assert!(plain.tokens.output < 40.0);
    }

    #[test]
    fn community_content_changes_source() {
        let rates = GenRates::default();
        let cost = CostModel::default();
        let mut rng = Rng::new(2);
        let mut inp = base_inputs(
            Arm { retrieval: Retrieval::LocalNaive, gen: GenLoc::EdgeSlm },
            4000,
            &rates,
            &cost,
        );
        inp.community_content = true;
        let o = execute(inp, &mut rng);
        assert_eq!(o.source, ContextSource::EdgeCommunity);
    }

    #[test]
    fn cloud_gen_without_cloud_retrieval_pays_hop() {
        let local_gen = run(
            Arm { retrieval: Retrieval::LocalNaive, gen: GenLoc::EdgeSlm },
            4000,
        );
        let cloud_gen = run(
            Arm { retrieval: Retrieval::LocalNaive, gen: GenLoc::CloudLlm },
            4000,
        );
        // The cloud hop (~0.3 s) must appear, but the 72B pod generates
        // faster, so compare the network component via total structure.
        assert!(cloud_gen.delay_s > 0.3, "cloud hop missing: {}", cloud_gen.delay_s);
        assert_eq!(cloud_gen.gen_gpu, Gpu::H100);
        assert_eq!(local_gen.gen_gpu, Gpu::Rtx4090);
    }

    #[test]
    fn time_cost_scales_with_gpu() {
        let edge = run(Arm { retrieval: Retrieval::None, gen: GenLoc::EdgeSlm }, 0);
        let cloud = run(
            Arm { retrieval: Retrieval::CloudGraph, gen: GenLoc::CloudLlm },
            24_000,
        );
        // Per second of delay, cloud time-cost is 60/1.29 ≈ 46× pricier.
        let edge_rate = edge.delay_cost / edge.delay_s;
        let cloud_rate = cloud.delay_cost / cloud.delay_s;
        assert!((cloud_rate / edge_rate - 60.0 / 1.29).abs() < 1e-6);
    }
}
