//! Full-system simulation harness: the paper's evaluation testbed.
//!
//! Wires every substrate together — corpus, workload, edge stores,
//! cloud GraphRAG + distributor, netsim, cost model, oracle, and the
//! SafeOBO gate — under **virtual time**, so the benches can replay the
//! paper's experiments (Tables 1/4/5/6/7, Figures 2/4) deterministically
//! and fast. The real-serving path (PJRT generation, wall-clock latency)
//! lives in [`crate::coordinator`]; both share the same retrieval,
//! gating, and cost machinery. Per-query execution itself — tier
//! routing, retrieval, generation, grading, knowledge updates — lives
//! in the staged pipeline ([`crate::pipeline`]); this module owns
//! system construction and the synchronous run loops over it.

pub mod strategy;

use crate::cloud::{CloudNode, CloudSpec};
use crate::cluster::EdgeCluster;
use crate::config::SystemConfig;
use crate::corpus::{ChunkId, Corpus, QaId};
use crate::cost::CostModel;
use crate::edge::semantic::AnnProbe;
use crate::edge::EdgeNode;
use crate::gating::safeobo::SafeObo;
use crate::gating::{Arm, GateContext, GenLoc, Retrieval};
use crate::netsim::{Link, NetSim};
use crate::oracle::Oracle;
use crate::pipeline::{self, KnowledgePolicy, StageEvent, StageSink, StatsSink};
use crate::runtime::FeatureHasher;
use crate::util::rng::Rng;
use crate::util::stats::Running;
use crate::workload::{Workload, WorkloadSpec};
use strategy::{GenRates, Outcome};

/// How edge stores are managed during a run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KnowledgeMode {
    /// Static provisioning only (the Naive-RAG baseline).
    Static,
    /// EACO-RAG adaptive updates (cloud-triggered, FIFO).
    Adaptive,
    /// The distributed knowledge plane ([`crate::cluster`]): cloud
    /// updates flow through the versioned placement engine, neighbors
    /// exchange hot chunks via delta gossip, and edge-assisted
    /// retrieval routes by per-edge keyword summaries over the
    /// configured neighbor topology.
    Collaborative,
}

/// Retrieval-tier indices for [`RunStats::tier_queries`] /
/// [`RunStats::tier_hits`].
pub const TIER_NONE: usize = 0;
pub const TIER_LOCAL: usize = 1;
pub const TIER_NEIGHBOR: usize = 2;
pub const TIER_CLOUD: usize = 3;
pub const TIER_NAMES: [&str; 4] = ["none", "local", "neighbor", "cloud"];

/// Aggregated run metrics (one Table-4 style row).
#[derive(Clone, Debug, Default)]
pub struct RunStats {
    pub queries: usize,
    pub accuracy: f64,
    pub delay: Running,
    pub resource_cost: Running,
    pub total_cost: Running,
    pub in_tokens: Running,
    pub out_tokens: Running,
    /// Arm usage histogram (gate runs only).
    pub arm_counts: Vec<usize>,
    /// Queries served per retrieval tier (none/local/neighbor/cloud).
    pub tier_queries: [usize; 4],
    /// Queries per tier whose retrieval contained a supporting chunk.
    pub tier_hits: [usize; 4],
    /// Chunk payload bytes gossiped edge↔edge during this run
    /// (collaborative mode; 0 otherwise).
    pub bytes_replicated: usize,
    /// Queries whose retrieval went through the semantic (ANN) path.
    pub ann_queries: usize,
    /// Per-query recall@k of the IVF probe vs the exact scan.
    pub ann_recall: Running,
    /// ANN queries answered by the exact-scan fallback (store below
    /// `ann.exact_below`).
    pub ann_exact_fallbacks: usize,
    /// Serving-plane counters when the run went through
    /// [`crate::serve::serve_workload`] (`None` for the synchronous
    /// paths). Only worker-count-invariant counters live here, so
    /// `RunStats` stays bit-identical across `serve.workers` settings.
    pub serve: Option<crate::serve::metrics::ServeSummary>,
}

impl RunStats {
    /// Per-tier traffic/hit-rate row (collaborative observability).
    pub fn tier_row(&self) -> String {
        let mut parts = Vec::new();
        for t in 0..4 {
            if self.tier_queries[t] == 0 {
                continue;
            }
            parts.push(format!(
                "{} {:4.1}% (hit {:4.1}%)",
                TIER_NAMES[t],
                self.tier_queries[t] as f64 / self.queries.max(1) as f64 * 100.0,
                self.tier_hits[t] as f64 / self.tier_queries[t] as f64 * 100.0,
            ));
        }
        parts.join(" | ")
    }

    /// ANN observability row: probe volume, mean recall@k, and how
    /// often the exact-scan fallback answered.
    pub fn ann_row(&self) -> String {
        if self.ann_queries == 0 {
            return "ann: off".into();
        }
        format!(
            "ann: {} probes  recall@k {:5.3}  exact-fallback {:4.1}%",
            self.ann_queries,
            self.ann_recall.mean(),
            self.ann_exact_fallbacks as f64 / self.ann_queries as f64 * 100.0,
        )
    }

    pub fn row(&self) -> String {
        format!(
            "acc {:5.2}%  delay {:5.2}s ± {:4.2}  cost {:8.2} ± {:6.2} TFLOPs  (n={})",
            self.accuracy * 100.0,
            self.delay.mean(),
            self.delay.std(),
            self.resource_cost.mean(),
            self.resource_cost.std(),
            self.queries
        )
    }
}

/// The simulated system.
pub struct SimSystem {
    pub cfg: SystemConfig,
    pub corpus: Corpus,
    /// The edge fleet + its control plane (topology, hotness, versioned
    /// placement, gossip, summary routing). The legacy paper modes use
    /// only its data plane (`cluster.nodes`) plus full-mesh summary
    /// routing, which reproduces the seed behavior bit-for-bit.
    pub cluster: EdgeCluster,
    pub cloud: CloudNode,
    pub net: NetSim,
    pub oracle: Oracle,
    pub cost: CostModel,
    pub rates: GenRates,
    pub mode: KnowledgeMode,
    /// Chunks that arrived via community distribution, per edge
    /// (maintained by the pipeline's Update stage).
    pub(crate) community_marked: Vec<std::collections::HashSet<ChunkId>>,
    /// Tier + support-hit of the most recent [`Self::serve`] call (the
    /// run loops — including the event loop in [`crate::serve`] — fold
    /// these into [`RunStats`]).
    pub(crate) last_tier: usize,
    pub(crate) last_hit: bool,
    /// ANN probe outcome of the most recent serve (collaborative
    /// local/edge-assisted retrieval only; `None` otherwise).
    pub(crate) last_ann: Option<AnnProbe>,
    /// Query embedder for the collaborative dense path (shares hasher
    /// geometry with every edge's chunk embeddings).
    pub(crate) query_hasher: Option<FeatureHasher>,
    pub(crate) rng: Rng,
    /// Tier parameters (emulated billions) — from the manifest when
    /// available, else the defaults matching `python/compile/model.py`.
    pub edge_params_b: f64,
    pub cloud_params_b: f64,
    pub edge_capability: f64,
    pub cloud_capability: f64,
}

/// Default tier table mirroring `python/compile/model.py::TIERS` (used
/// when running simulation-only, without loading the artifact manifest).
pub fn tier_defaults(name: &str) -> Option<(f64, f64)> {
    // (emulated_params_b, capability)
    match name {
        "qwen05b" => Some((0.5, 0.30)),
        "qwen15b" => Some((1.5, 0.42)),
        "qwen3b" => Some((3.0, 0.55)),
        "llama3b" => Some((3.0, 0.48)),
        "qwen7b" => Some((7.0, 0.64)),
        "qwen72b" => Some((72.0, 0.90)),
        _ => None,
    }
}

impl SimSystem {
    /// Build a system per config; edges are provisioned with chunks for
    /// their home topics (pre-deployment state).
    pub fn new(cfg: SystemConfig, mode: KnowledgeMode) -> SimSystem {
        let corpus = Corpus::generate(cfg.dataset, cfg.seed);
        let cloud_spec = CloudSpec {
            update_trigger: cfg.update_trigger,
            distribute_max_chunks: cfg.distribute_max_chunks,
            top_k_communities: cfg.top_k_communities,
        };
        let cloud = CloudNode::new(&corpus, cfg.num_edges, cloud_spec);
        let net = NetSim::new(cfg.num_edges, cfg.net.clone(), cfg.seed);
        // Legacy modes keep the seed's all-edges semantics by wiring a
        // full mesh; collaborative runs use the configured degree.
        let degree_override = match mode {
            KnowledgeMode::Collaborative => None,
            _ => Some(cfg.num_edges.saturating_sub(1)),
        };
        let mut cluster = EdgeCluster::new(
            &cfg.cluster,
            degree_override,
            cfg.num_edges,
            cfg.edge_capacity,
            corpus.spec.topics,
            corpus.chunks.len(),
            &net,
        );
        // Collaborative mode gets the dense/ANN retrieval plane: stores
        // attach now (empty) and stay in sync through the insert/evict
        // hooks, so provisioning below also fills them.
        if mode == KnowledgeMode::Collaborative {
            cluster.enable_ann(&corpus, &cfg.ann, cfg.seed);
        }
        let query_hasher = match mode {
            KnowledgeMode::Collaborative => Some(FeatureHasher::new(cfg.ann.embed_dim)),
            _ => None,
        };
        let oracle = Oracle::new(cfg.seed ^ 0x5eed);
        let cost = CostModel::new(cfg.cost_weights);
        let (edge_params_b, edge_capability) =
            tier_defaults(&cfg.edge_tier).unwrap_or((3.0, 0.55));
        let (cloud_params_b, cloud_capability) =
            tier_defaults(&cfg.cloud_tier).unwrap_or((72.0, 0.90));
        let rng = Rng::new(cfg.seed).fork("sim");
        let community_marked = vec![std::collections::HashSet::new(); cfg.num_edges];
        let mut sys = SimSystem {
            cfg,
            corpus,
            cluster,
            cloud,
            net,
            oracle,
            cost,
            rates: GenRates::default(),
            mode,
            community_marked,
            last_tier: TIER_NONE,
            last_hit: false,
            last_ann: None,
            query_hasher,
            rng,
            edge_params_b,
            cloud_params_b,
            edge_capability,
            cloud_capability,
        };
        sys.provision_edges();
        sys
    }

    /// Initial edge provisioning: fill each store with chunks from its
    /// home topics (round-robin pages), capped at capacity.
    fn provision_edges(&mut self) {
        let num_edges = self.cfg.num_edges;
        let topics = self.corpus.spec.topics;
        let per_edge = (topics as f64 / num_edges as f64).ceil() as usize;
        for e in 0..num_edges {
            let home: Vec<usize> = (0..per_edge.max(1))
                .map(|i| (e * per_edge + i) % topics)
                .collect();
            let chunks: Vec<ChunkId> = self
                .corpus
                .chunks
                .iter()
                .filter(|c| home.contains(&c.topic))
                .take(self.cfg.edge_capacity)
                .map(|c| c.id)
                .collect();
            // Pre-deployment fill (below capacity, version 0): identical
            // under every placement policy, so it bypasses the engine.
            // Gossip needs no notification: digests fingerprint store
            // content directly, so the first round advertises this.
            self.cluster.nodes[e].apply_update(&self.corpus, &chunks);
        }
    }

    /// The edge fleet (compatibility accessor; the stores live in the
    /// cluster's data plane).
    pub fn edges(&self) -> &[EdgeNode] {
        &self.cluster.nodes
    }

    /// Assemble the gate context for a query event. Edge coverage comes
    /// from cluster summary routing — in the legacy modes the full-mesh
    /// topology makes this equal to the retained `best_edge_for` oracle,
    /// and the neighbor signal is pinned to 0.0 so their GP posteriors
    /// stay bit-identical to the pre-cluster gate.
    pub fn gate_context(&mut self, qa_id: QaId, edge_id: usize, step: usize) -> GateContext {
        let kws = self.corpus.qa_keywords(&self.corpus.qa[qa_id]);
        let dec = self.cluster.route(edge_id, &kws);
        let local_overlap = self.cluster.nodes[edge_id].overlap_ratio(&kws);
        let qa = &self.corpus.qa[qa_id];
        GateContext {
            cloud_delay_ms: self.net.expected_delay_ms(Link::EdgeToCloud(edge_id), step),
            edge_delay_ms: self.net.expected_delay_ms(Link::UserToEdge(edge_id), step),
            best_overlap: dec.overlap,
            best_edge_is_local: dec.edge == edge_id,
            local_overlap,
            neighbor_overlap: if self.mode == KnowledgeMode::Collaborative {
                dec.neighbor_overlap
            } else {
                0.0
            },
            hops: qa.hops,
            length_tokens: qa.length_tokens,
            entity_count: qa.entities.len(),
        }
    }

    /// Serve one query with a fixed arm; returns the outcome + verdict.
    /// Thin wrapper over the staged pipeline ([`crate::pipeline`]) with
    /// no observer attached — every retrieval-tier, gossip, and
    /// knowledge-update decision lives there now.
    pub fn serve(
        &mut self,
        qa_id: QaId,
        edge_id: usize,
        step: usize,
        arm: Arm,
    ) -> (Outcome, bool) {
        pipeline::exec_query(self, qa_id, edge_id, step, arm, &mut pipeline::NullSink)
    }

    /// Run a fixed-strategy baseline over a workload slice. Stats fold
    /// off the pipeline's event stream via [`StatsSink`].
    pub fn run_baseline(&mut self, workload: &Workload, arm: Arm) -> RunStats {
        let mut sink = StatsSink::new(1, false);
        let bytes0 = self.cluster.bytes_gossiped();
        for (i, ev) in workload.events.iter().enumerate() {
            let (outcome, correct) =
                pipeline::exec_query(self, ev.qa_id, ev.edge_id, ev.step, arm, &mut sink);
            sink.emit(&StageEvent::QueryDone {
                seq: i,
                edge_id: ev.edge_id,
                arrival_ms: 0.0,
                outcome: &outcome,
                correct,
                arm_idx: 0,
                explored: false,
                tier: self.last_tier,
                hit: self.last_hit,
                ann: self.last_ann,
                store_empty: false,
            });
        }
        let mut stats = sink.finish();
        stats.bytes_replicated = self.cluster.bytes_gossiped() - bytes0;
        stats
    }

    /// Run EACO-RAG: SafeOBO gate over the workload. Metrics cover the
    /// exploitation phase only (post-warm-up), matching Table 5's
    /// sensitivity to T₀. Returns (stats, gate) for inspection.
    pub fn run_eaco(&mut self, workload: &Workload) -> (RunStats, SafeObo) {
        let mut gate = pipeline::build_gate(&self.cfg);
        let mut sink = StatsSink::new(gate.arms.len(), true);
        let policy = KnowledgePolicy::from_mode(self.mode);
        let bytes0 = self.cluster.bytes_gossiped();
        for (i, ev) in workload.events.iter().enumerate() {
            // Run any due gossip round *before* building the gate
            // context, so the gate trains on the same store state the
            // serve-time routing will see (the pipeline's own pre-query
            // gossip is then a no-op for this step).
            if let Some(round) = policy.pre_query(&mut self.cluster, &self.corpus, ev.step) {
                sink.emit(&StageEvent::GossipRound {
                    step: ev.step,
                    round: round.round,
                    wire_bytes: round.wire_bytes(),
                    version_lag: None,
                });
            }
            let r = pipeline::gated_step(
                self, &mut gate, ev.qa_id, ev.edge_id, ev.step, None, &mut sink,
            );
            sink.emit(&StageEvent::QueryDone {
                seq: i,
                edge_id: ev.edge_id,
                arrival_ms: 0.0,
                outcome: &r.outcome,
                correct: r.correct,
                arm_idx: r.arm_idx,
                explored: r.explored,
                tier: self.last_tier,
                hit: self.last_hit,
                ann: self.last_ann,
                store_empty: false,
            });
        }
        let mut stats = sink.finish();
        stats.bytes_replicated = self.cluster.bytes_gossiped() - bytes0;
        (stats, gate)
    }

    /// Run a workload through the asynchronous serving plane
    /// ([`crate::serve`]): per-edge queue accounting, deadline-aware
    /// admission, and gossip as schedulable (optionally background)
    /// work items, all under the deterministic virtual clock.
    /// `KnowledgeMode`-agnostic — legacy modes simply have no gossip to
    /// schedule. With the default `[serve]` config (unbounded queue,
    /// 1 worker, admission off, foreground gossip) the returned
    /// `RunStats` is bit-identical to [`Self::run_baseline`] /
    /// [`Self::run_eaco`] on the same workload — asserted in
    /// `tests/serve_determinism.rs`.
    pub fn serve_async(
        &mut self,
        workload: &Workload,
        driver: crate::serve::Driver,
    ) -> (RunStats, crate::serve::metrics::ServeMetrics) {
        crate::serve::serve_workload(self, workload, driver)
    }

    /// The standard baseline arms of Table 4.
    pub fn baseline_arm(name: &str) -> Option<Arm> {
        match name {
            "llm-only" => Some(Arm { retrieval: Retrieval::None, gen: GenLoc::EdgeSlm }),
            "naive-rag" => Some(Arm { retrieval: Retrieval::LocalNaive, gen: GenLoc::EdgeSlm }),
            "graph-slm" => Some(Arm { retrieval: Retrieval::CloudGraph, gen: GenLoc::EdgeSlm }),
            "graph-llm" => Some(Arm { retrieval: Retrieval::CloudGraph, gen: GenLoc::CloudLlm }),
            _ => None,
        }
    }
}

/// Convenience: workload spec matching a config.
pub fn workload_for(cfg: &SystemConfig, steps: usize) -> WorkloadSpec {
    WorkloadSpec {
        num_edges: cfg.num_edges,
        steps,
        ..WorkloadSpec::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::QosPreset;
    use crate::corpus::Profile;
    use crate::workload::Workload;

    fn small_cfg(profile: Profile) -> SystemConfig {
        SystemConfig {
            dataset: profile,
            edge_capacity: 400,
            warmup_steps: 300,
            ..SystemConfig::default()
        }
    }

    fn run_pair(profile: Profile, steps: usize) -> (SimSystem, Workload) {
        let cfg = small_cfg(profile);
        let sys = SimSystem::new(cfg.clone(), KnowledgeMode::Adaptive);
        let wl = Workload::generate(&sys.corpus, workload_for(&cfg, steps), cfg.seed);
        (sys, wl)
    }

    #[test]
    fn baselines_ordered_like_table4() {
        let cfg = small_cfg(Profile::Wiki);
        let mut results = Vec::new();
        for name in ["llm-only", "naive-rag", "graph-slm", "graph-llm"] {
            let mut sys = SimSystem::new(cfg.clone(), KnowledgeMode::Static);
            let wl = Workload::generate(&sys.corpus, workload_for(&cfg, 400), cfg.seed);
            let arm = SimSystem::baseline_arm(name).unwrap();
            let stats = sys.run_baseline(&wl, arm);
            results.push((name, stats));
        }
        let acc: Vec<f64> = results.iter().map(|(_, s)| s.accuracy).collect();
        // Table 4 ordering: LLM-only < NaiveRAG < GraphRAG-3B < GraphRAG-72B.
        assert!(acc[0] < acc[1], "llm {} !< naive {}", acc[0], acc[1]);
        assert!(acc[1] < acc[2] + 0.05, "naive {} !< graph {}", acc[1], acc[2]);
        assert!(acc[2] < acc[3], "graph3b {} !< graph72b {}", acc[2], acc[3]);
        // Cost ordering too.
        let cost: Vec<f64> = results.iter().map(|(_, s)| s.resource_cost.mean()).collect();
        assert!(cost[0] < cost[1] && cost[1] < cost[2] && cost[2] < cost[3]);
        // Delay: graph-slm slowest.
        let delay: Vec<f64> = results.iter().map(|(_, s)| s.delay.mean()).collect();
        assert!(delay[2] > delay[3], "3b graph should be slowest");
    }

    #[test]
    fn eaco_cuts_cost_vs_cloud_at_similar_accuracy() {
        let (mut sys, wl) = run_pair(Profile::Wiki, 1500);
        let (eaco, _) = sys.run_eaco(&wl);

        let cfg = small_cfg(Profile::Wiki);
        let mut base = SimSystem::new(cfg.clone(), KnowledgeMode::Static);
        let cloud = base.run_baseline(&wl, SimSystem::baseline_arm("graph-llm").unwrap());

        assert!(
            eaco.accuracy > cloud.accuracy - 0.08,
            "eaco acc {:.3} vs cloud {:.3}",
            eaco.accuracy,
            cloud.accuracy
        );
        assert!(
            eaco.resource_cost.mean() < cloud.resource_cost.mean() * 0.6,
            "eaco cost {:.1} vs cloud {:.1}",
            eaco.resource_cost.mean(),
            cloud.resource_cost.mean()
        );
    }

    #[test]
    fn adaptive_updates_improve_local_coverage() {
        let cfg = small_cfg(Profile::Wiki);
        let wl_spec = workload_for(&cfg, 600);

        let mut static_sys = SimSystem::new(cfg.clone(), KnowledgeMode::Static);
        let wl = Workload::generate(&static_sys.corpus, wl_spec, cfg.seed);
        let arm = SimSystem::baseline_arm("naive-rag").unwrap();
        let s_static = static_sys.run_baseline(&wl, arm);

        let mut adaptive_sys = SimSystem::new(cfg, KnowledgeMode::Adaptive);
        let s_adapt = adaptive_sys.run_baseline(&wl, arm);

        assert!(
            s_adapt.accuracy > s_static.accuracy + 0.02,
            "adaptive {:.3} !> static {:.3}",
            s_adapt.accuracy,
            s_static.accuracy
        );
        assert!(adaptive_sys.cloud.updates_sent > 0);
    }

    #[test]
    fn delay_oriented_gate_meets_deadline() {
        let mut cfg = small_cfg(Profile::Wiki);
        cfg.qos = QosPreset::DelayOriented;
        let mut sys = SimSystem::new(cfg.clone(), KnowledgeMode::Adaptive);
        let wl = Workload::generate(&sys.corpus, workload_for(&cfg, 900), cfg.seed);
        let (stats, _) = sys.run_eaco(&wl);
        assert!(
            stats.delay.mean() < 1.3,
            "delay-oriented mean {:.2}s",
            stats.delay.mean()
        );
    }

    #[test]
    fn deterministic_runs() {
        let (mut a, wl) = run_pair(Profile::Wiki, 300);
        let (sa, _) = a.run_eaco(&wl);
        let (mut b, wl2) = run_pair(Profile::Wiki, 300);
        let (sb, _) = b.run_eaco(&wl2);
        assert_eq!(sa.queries, sb.queries);
        assert!((sa.accuracy - sb.accuracy).abs() < 1e-12);
        assert!((sa.resource_cost.mean() - sb.resource_cost.mean()).abs() < 1e-9);
    }

    #[test]
    fn collaborative_mode_gossips_and_tracks_tiers() {
        let mut cfg = small_cfg(Profile::Wiki);
        cfg.num_edges = 6;
        let mut sys = SimSystem::new(cfg.clone(), KnowledgeMode::Collaborative);
        let wl = Workload::generate(&sys.corpus, workload_for(&cfg, 900), cfg.seed);
        let arm = Arm { retrieval: Retrieval::EdgeAssisted, gen: GenLoc::EdgeSlm };
        let stats = sys.run_baseline(&wl, arm);
        assert_eq!(stats.queries, 900);
        // Every query lands in the local or neighbor tier under this arm.
        assert_eq!(stats.tier_queries[TIER_LOCAL] + stats.tier_queries[TIER_NEIGHBOR], 900);
        assert!(stats.bytes_replicated > 0, "no gossip traffic");
        assert!(sys.cluster.gossiper.stats.rounds > 0);
        // Neighbor-degree topology: routing is bounded, not broadcast.
        assert_eq!(sys.cluster.topology.degree, cfg.cluster.degree);
    }

    #[test]
    fn collaborative_ann_recall_accounted() {
        let mut cfg = small_cfg(Profile::Wiki);
        // Stores hold 400 chunks; push exact_below under that so the
        // real IVF probe path (not the exact fallback) serves queries.
        cfg.ann.exact_below = 64;
        cfg.ann.nlist = 8;
        cfg.ann.nprobe = 4;
        let mut sys = SimSystem::new(cfg.clone(), KnowledgeMode::Collaborative);
        let wl = Workload::generate(&sys.corpus, workload_for(&cfg, 400), cfg.seed);
        let arm = Arm { retrieval: Retrieval::EdgeAssisted, gen: GenLoc::EdgeSlm };
        let stats = sys.run_baseline(&wl, arm);
        assert_eq!(stats.ann_queries, 400, "every query probes the ANN path");
        assert!(
            stats.ann_exact_fallbacks < stats.ann_queries,
            "stores above exact_below must take the IVF path"
        );
        assert!(
            stats.ann_recall.mean() > 0.5,
            "ivf recall@k mean {:.3}",
            stats.ann_recall.mean()
        );
        assert!(stats.ann_row().starts_with("ann: 400 probes"));

        // Legacy modes never touch the ANN path.
        let mut legacy = SimSystem::new(cfg, KnowledgeMode::Adaptive);
        let s = legacy.run_baseline(&wl, arm);
        assert_eq!(s.ann_queries, 0);
        assert_eq!(s.ann_row(), "ann: off");
    }

    #[test]
    fn collaborative_runs_deterministic() {
        let cfg = small_cfg(Profile::Wiki);
        let run = || {
            let mut sys = SimSystem::new(cfg.clone(), KnowledgeMode::Collaborative);
            let wl = Workload::generate(&sys.corpus, workload_for(&cfg, 500), cfg.seed);
            sys.run_eaco(&wl).0
        };
        let (a, b) = (run(), run());
        assert_eq!(a.queries, b.queries);
        assert_eq!(a.tier_queries, b.tier_queries);
        assert_eq!(a.tier_hits, b.tier_hits);
        assert_eq!(a.bytes_replicated, b.bytes_replicated);
        assert!((a.accuracy - b.accuracy).abs() < 1e-12);
        assert!((a.resource_cost.mean() - b.resource_cost.mean()).abs() < 1e-9);
    }

    #[test]
    fn collaborative_hit_rate_feedback_deterministic_and_learning() {
        let mut cfg = small_cfg(Profile::Wiki);
        cfg.num_edges = 6;
        cfg.cluster.feedback = crate::cluster::feedback::FeedbackMode::HitRate;
        let run = || {
            let mut sys = SimSystem::new(cfg.clone(), KnowledgeMode::Collaborative);
            let wl = Workload::generate(&sys.corpus, workload_for(&cfg, 500), cfg.seed);
            let arm = Arm { retrieval: Retrieval::EdgeAssisted, gen: GenLoc::EdgeSlm };
            let stats = sys.run_baseline(&wl, arm);
            (stats, sys)
        };
        let (sa, sys_a) = run();
        let (sb, sys_b) = run();
        assert_eq!(sa.queries, sb.queries);
        assert_eq!(sa.tier_queries, sb.tier_queries);
        assert_eq!(sa.tier_hits, sb.tier_hits);
        assert_eq!(sa.bytes_replicated, sb.bytes_replicated);
        assert!((sa.accuracy - sb.accuracy).abs() < 1e-12);
        let fb = sys_a.cluster.feedback.as_ref().expect("hit-rate mode owns feedback state");
        assert_eq!(fb.observations, sa.queries as u64, "every query feeds the loop");
        assert_eq!(fb.observations, sys_b.cluster.feedback.as_ref().unwrap().observations);
        // The default mode carries no learned state at all.
        let plain = SimSystem::new(small_cfg(Profile::Wiki), KnowledgeMode::Collaborative);
        assert!(plain.cluster.feedback.is_none());
    }

    #[test]
    fn collaborative_gate_sees_neighbor_signal() {
        let cfg = small_cfg(Profile::Wiki);
        let mut sys = SimSystem::new(cfg.clone(), KnowledgeMode::Collaborative);
        let wl = Workload::generate(&sys.corpus, workload_for(&cfg, 200), cfg.seed);
        let mut saw_nonzero = false;
        for ev in wl.events.iter().take(200) {
            let ctx = sys.gate_context(ev.qa_id, ev.edge_id, ev.step);
            if ctx.neighbor_overlap > 0.0 {
                saw_nonzero = true;
                break;
            }
        }
        assert!(saw_nonzero, "neighbor overlap never observed");
        // Legacy mode pins the signal to zero.
        let mut legacy = SimSystem::new(cfg.clone(), KnowledgeMode::Adaptive);
        for ev in wl.events.iter().take(50) {
            let ctx = legacy.gate_context(ev.qa_id, ev.edge_id, ev.step);
            assert_eq!(ctx.neighbor_overlap, 0.0);
        }
    }

    #[test]
    fn gate_uses_multiple_arms() {
        let (mut sys, wl) = run_pair(Profile::Wiki, 1500);
        let (stats, _) = sys.run_eaco(&wl);
        let used = stats.arm_counts.iter().filter(|&&c| c > 0).count();
        assert!(used >= 2, "gate collapsed to one arm: {:?}", stats.arm_counts);
    }
}
