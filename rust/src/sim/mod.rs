//! Full-system simulation harness: the paper's evaluation testbed.
//!
//! Wires every substrate together — corpus, workload, edge stores,
//! cloud GraphRAG + distributor, netsim, cost model, oracle, and the
//! SafeOBO gate — under **virtual time**, so the benches can replay the
//! paper's experiments (Tables 1/4/5/6/7, Figures 2/4) deterministically
//! and fast. The real-serving path (PJRT generation, wall-clock latency)
//! lives in [`crate::coordinator`]; both share the same retrieval,
//! gating, and cost machinery.

pub mod strategy;

use crate::cloud::{CloudNode, CloudSpec};
use crate::config::SystemConfig;
use crate::corpus::{ChunkId, Corpus, QaId};
use crate::cost::CostModel;
use crate::edge::{best_edge_for, EdgeNode};
use crate::gating::safeobo::{Observation, Qos, SafeObo};
use crate::gating::{standard_arms, Arm, GateContext, GenLoc, Retrieval};
use crate::netsim::{Link, NetSim};
use crate::oracle::Oracle;
use crate::util::rng::Rng;
use crate::util::stats::Running;
use crate::workload::{Workload, WorkloadSpec};
use strategy::{execute, GenRates, Outcome, StrategyInputs};

/// How edge stores are managed during a run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KnowledgeMode {
    /// Static provisioning only (the Naive-RAG baseline).
    Static,
    /// EACO-RAG adaptive updates (cloud-triggered, FIFO).
    Adaptive,
}

/// Aggregated run metrics (one Table-4 style row).
#[derive(Clone, Debug, Default)]
pub struct RunStats {
    pub queries: usize,
    pub accuracy: f64,
    pub delay: Running,
    pub resource_cost: Running,
    pub total_cost: Running,
    pub in_tokens: Running,
    pub out_tokens: Running,
    /// Arm usage histogram (gate runs only).
    pub arm_counts: Vec<usize>,
}

impl RunStats {
    pub fn row(&self) -> String {
        format!(
            "acc {:5.2}%  delay {:5.2}s ± {:4.2}  cost {:8.2} ± {:6.2} TFLOPs  (n={})",
            self.accuracy * 100.0,
            self.delay.mean(),
            self.delay.std(),
            self.resource_cost.mean(),
            self.resource_cost.std(),
            self.queries
        )
    }
}

/// The simulated system.
pub struct SimSystem {
    pub cfg: SystemConfig,
    pub corpus: Corpus,
    pub edges: Vec<EdgeNode>,
    pub cloud: CloudNode,
    pub net: NetSim,
    pub oracle: Oracle,
    pub cost: CostModel,
    pub rates: GenRates,
    pub mode: KnowledgeMode,
    /// Chunks that arrived via community distribution, per edge.
    community_marked: Vec<std::collections::HashSet<ChunkId>>,
    rng: Rng,
    /// Tier parameters (emulated billions) — from the manifest when
    /// available, else the defaults matching `python/compile/model.py`.
    pub edge_params_b: f64,
    pub cloud_params_b: f64,
    pub edge_capability: f64,
    pub cloud_capability: f64,
}

/// Default tier table mirroring `python/compile/model.py::TIERS` (used
/// when running simulation-only, without loading the artifact manifest).
pub fn tier_defaults(name: &str) -> Option<(f64, f64)> {
    // (emulated_params_b, capability)
    match name {
        "qwen05b" => Some((0.5, 0.30)),
        "qwen15b" => Some((1.5, 0.42)),
        "qwen3b" => Some((3.0, 0.55)),
        "llama3b" => Some((3.0, 0.48)),
        "qwen7b" => Some((7.0, 0.64)),
        "qwen72b" => Some((72.0, 0.90)),
        _ => None,
    }
}

impl SimSystem {
    /// Build a system per config; edges are provisioned with chunks for
    /// their home topics (pre-deployment state).
    pub fn new(cfg: SystemConfig, mode: KnowledgeMode) -> SimSystem {
        let corpus = Corpus::generate(cfg.dataset, cfg.seed);
        let cloud_spec = CloudSpec {
            update_trigger: cfg.update_trigger,
            distribute_max_chunks: cfg.distribute_max_chunks,
            top_k_communities: cfg.top_k_communities,
        };
        let cloud = CloudNode::new(&corpus, cfg.num_edges, cloud_spec);
        let edges: Vec<EdgeNode> = (0..cfg.num_edges)
            .map(|i| EdgeNode::new(i, cfg.edge_capacity))
            .collect();
        let net = NetSim::new(cfg.num_edges, cfg.net.clone(), cfg.seed);
        let oracle = Oracle::new(cfg.seed ^ 0x5eed);
        let cost = CostModel::new(cfg.cost_weights);
        let (edge_params_b, edge_capability) =
            tier_defaults(&cfg.edge_tier).unwrap_or((3.0, 0.55));
        let (cloud_params_b, cloud_capability) =
            tier_defaults(&cfg.cloud_tier).unwrap_or((72.0, 0.90));
        let rng = Rng::new(cfg.seed).fork("sim");
        let community_marked = vec![std::collections::HashSet::new(); cfg.num_edges];
        let mut sys = SimSystem {
            cfg,
            corpus,
            edges,
            cloud,
            net,
            oracle,
            cost,
            rates: GenRates::default(),
            mode,
            community_marked,
            rng,
            edge_params_b,
            cloud_params_b,
            edge_capability,
            cloud_capability,
        };
        sys.provision_edges();
        sys
    }

    /// Initial edge provisioning: fill each store with chunks from its
    /// home topics (round-robin pages), capped at capacity.
    fn provision_edges(&mut self) {
        let num_edges = self.cfg.num_edges;
        let topics = self.corpus.spec.topics;
        let per_edge = (topics as f64 / num_edges as f64).ceil() as usize;
        for e in 0..num_edges {
            let home: Vec<usize> = (0..per_edge.max(1))
                .map(|i| (e * per_edge + i) % topics)
                .collect();
            let chunks: Vec<ChunkId> = self
                .corpus
                .chunks
                .iter()
                .filter(|c| home.contains(&c.topic))
                .take(self.cfg.edge_capacity)
                .map(|c| c.id)
                .collect();
            self.edges[e].apply_update(&self.corpus, &chunks);
        }
    }

    /// Assemble the gate context for a query event.
    pub fn gate_context(&self, qa_id: QaId, edge_id: usize, step: usize) -> GateContext {
        let qa = &self.corpus.qa[qa_id];
        let kws = self.corpus.qa_keywords(qa);
        let (best_edge, best_overlap) = best_edge_for(&self.edges, edge_id, &kws);
        let local_overlap = self.edges[edge_id].overlap_ratio(&kws);
        GateContext {
            cloud_delay_ms: self.net.expected_delay_ms(Link::EdgeToCloud(edge_id), step),
            edge_delay_ms: self.net.expected_delay_ms(Link::UserToEdge(edge_id), step),
            best_overlap,
            best_edge_is_local: best_edge == edge_id,
            local_overlap,
            hops: qa.hops,
            length_tokens: qa.length_tokens,
            entity_count: qa.entities.len(),
        }
    }

    /// Serve one query with a fixed arm; returns the outcome + verdict.
    pub fn serve(
        &mut self,
        qa_id: QaId,
        edge_id: usize,
        step: usize,
        arm: Arm,
    ) -> (Outcome, bool) {
        // Borrow keywords straight from the corpus: retrieval mutates
        // `self.edges`/`self.cloud`/`self.net` only, all disjoint from
        // `self.corpus`, so the per-query String clone the seed did here
        // was pure hot-path allocation overhead.
        let kws: Vec<&str> = self.corpus.qa_keywords(&self.corpus.qa[qa_id]);

        // --- retrieval ---
        let (retrieved, context_chars, community, edge_edge_s) = match arm.retrieval {
            Retrieval::None => (Vec::new(), 0, false, 0.0),
            Retrieval::LocalNaive => {
                let chunks = self.edges[edge_id].retrieve(&kws, self.cfg.retrieve_k);
                let chars = self.edges[edge_id].retrieval_context_chars(&self.corpus, &chunks);
                let community = chunks
                    .iter()
                    .any(|c| self.community_marked[edge_id].contains(c));
                (chunks, chars, community, 0.0)
            }
            Retrieval::EdgeAssisted => {
                let (best, _) = best_edge_for(&self.edges, edge_id, &kws);
                let chunks = self.edges[best].retrieve(&kws, self.cfg.retrieve_k);
                let chars = self.edges[best].retrieval_context_chars(&self.corpus, &chunks);
                let community = chunks
                    .iter()
                    .any(|c| self.community_marked[best].contains(c));
                let hop = if best == edge_id {
                    0.0
                } else {
                    self.net.delay_ms(Link::EdgeToEdge(edge_id, best), step) / 1000.0
                };
                (chunks, chars, community, hop)
            }
            Retrieval::CloudGraph => {
                let (chunks, chars) =
                    self.cloud
                        .retrieve_graph(&self.corpus, &kws, self.cfg.retrieve_k);
                (chunks, chars, false, 0.0)
            }
        };

        let qa = &self.corpus.qa[qa_id];
        let inputs = StrategyInputs {
            arm,
            retrieved,
            context_chars,
            community_content: community,
            question_tokens: qa.length_tokens,
            net_user_edge_s: self.net.delay_ms(Link::UserToEdge(edge_id), step) / 1000.0,
            net_edge_edge_s: edge_edge_s,
            net_edge_cloud_s: self.net.delay_ms(Link::EdgeToCloud(edge_id), step) / 1000.0,
            edge_params_b: self.edge_params_b,
            cloud_params_b: self.cloud_params_b,
            rates: &self.rates,
            cost: &self.cost,
        };
        let outcome = execute(inputs, &mut self.rng);

        // --- grading ---
        let capability = match arm.gen {
            GenLoc::EdgeSlm => self.edge_capability,
            GenLoc::CloudLlm => self.cloud_capability,
        };
        let correct = self.oracle.judge(
            self.corpus.spec.profile,
            qa,
            capability,
            &outcome.retrieved,
            outcome.source,
            step,
        );

        // --- adaptive knowledge update ---
        if self.mode == KnowledgeMode::Adaptive {
            if let Some(plan) = self.cloud.record_query(&self.corpus, edge_id, qa_id) {
                self.edges[plan.edge_id].apply_update(&self.corpus, &plan.chunks);
                let marked = &mut self.community_marked[plan.edge_id];
                for &c in &plan.chunks {
                    marked.insert(c);
                }
            }
        }

        (outcome, correct)
    }

    /// Run a fixed-strategy baseline over a workload slice.
    pub fn run_baseline(&mut self, workload: &Workload, arm: Arm) -> RunStats {
        let mut stats = RunStats {
            arm_counts: vec![0; 1],
            ..Default::default()
        };
        let mut correct_n = 0usize;
        for ev in workload.events.clone() {
            let (outcome, correct) = self.serve(ev.qa_id, ev.edge_id, ev.step, arm);
            accumulate(&mut stats, &outcome, correct, &mut correct_n);
        }
        finalize(&mut stats, correct_n);
        stats
    }

    /// Run EACO-RAG: SafeOBO gate over the workload. Metrics cover the
    /// exploitation phase only (post-warm-up), matching Table 5's
    /// sensitivity to T₀. Returns (stats, gate) for inspection.
    pub fn run_eaco(&mut self, workload: &Workload) -> (RunStats, SafeObo) {
        let (min_acc, max_delay) = self.cfg.qos.constraints_for(self.cfg.dataset);
        let mut gate = SafeObo::new(
            standard_arms(),
            Qos {
                min_accuracy: min_acc,
                max_delay_s: max_delay,
            },
            self.cfg.warmup_steps,
            self.cfg.beta,
            self.cfg.seed,
        );
        let mut stats = RunStats {
            arm_counts: vec![0; gate.arms.len()],
            ..Default::default()
        };
        let mut correct_n = 0usize;
        for ev in workload.events.clone() {
            let ctx = self.gate_context(ev.qa_id, ev.edge_id, ev.step);
            let decision = gate.decide(&ctx);
            let arm = gate.arms[decision.arm_idx];
            let (outcome, correct) = self.serve(ev.qa_id, ev.edge_id, ev.step, arm);
            gate.observe(
                &ctx,
                decision.arm_idx,
                Observation {
                    resource_cost: outcome.resource_cost,
                    delay_cost: outcome.delay_cost,
                    accuracy: if correct { 1.0 } else { 0.0 },
                    delay_s: outcome.delay_s,
                },
            );
            if !decision.explored {
                stats.arm_counts[decision.arm_idx] += 1;
                accumulate(&mut stats, &outcome, correct, &mut correct_n);
            }
        }
        finalize(&mut stats, correct_n);
        (stats, gate)
    }

    /// The standard baseline arms of Table 4.
    pub fn baseline_arm(name: &str) -> Option<Arm> {
        match name {
            "llm-only" => Some(Arm { retrieval: Retrieval::None, gen: GenLoc::EdgeSlm }),
            "naive-rag" => Some(Arm { retrieval: Retrieval::LocalNaive, gen: GenLoc::EdgeSlm }),
            "graph-slm" => Some(Arm { retrieval: Retrieval::CloudGraph, gen: GenLoc::EdgeSlm }),
            "graph-llm" => Some(Arm { retrieval: Retrieval::CloudGraph, gen: GenLoc::CloudLlm }),
            _ => None,
        }
    }
}

fn accumulate(stats: &mut RunStats, o: &Outcome, correct: bool, correct_n: &mut usize) {
    stats.queries += 1;
    if correct {
        *correct_n += 1;
    }
    stats.delay.push(o.delay_s);
    stats.resource_cost.push(o.resource_cost);
    stats.total_cost.push(o.total_cost);
    stats.in_tokens.push(o.tokens.input);
    stats.out_tokens.push(o.tokens.output);
}

fn finalize(stats: &mut RunStats, correct_n: usize) {
    stats.accuracy = if stats.queries == 0 {
        0.0
    } else {
        correct_n as f64 / stats.queries as f64
    };
}

/// Convenience: workload spec matching a config.
pub fn workload_for(cfg: &SystemConfig, steps: usize) -> WorkloadSpec {
    WorkloadSpec {
        num_edges: cfg.num_edges,
        steps,
        ..WorkloadSpec::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::QosPreset;
    use crate::corpus::Profile;
    use crate::workload::Workload;

    fn small_cfg(profile: Profile) -> SystemConfig {
        SystemConfig {
            dataset: profile,
            edge_capacity: 400,
            warmup_steps: 300,
            ..SystemConfig::default()
        }
    }

    fn run_pair(profile: Profile, steps: usize) -> (SimSystem, Workload) {
        let cfg = small_cfg(profile);
        let sys = SimSystem::new(cfg.clone(), KnowledgeMode::Adaptive);
        let wl = Workload::generate(&sys.corpus, workload_for(&cfg, steps), cfg.seed);
        (sys, wl)
    }

    #[test]
    fn baselines_ordered_like_table4() {
        let cfg = small_cfg(Profile::Wiki);
        let mut results = Vec::new();
        for name in ["llm-only", "naive-rag", "graph-slm", "graph-llm"] {
            let mut sys = SimSystem::new(cfg.clone(), KnowledgeMode::Static);
            let wl = Workload::generate(&sys.corpus, workload_for(&cfg, 400), cfg.seed);
            let arm = SimSystem::baseline_arm(name).unwrap();
            let stats = sys.run_baseline(&wl, arm);
            results.push((name, stats));
        }
        let acc: Vec<f64> = results.iter().map(|(_, s)| s.accuracy).collect();
        // Table 4 ordering: LLM-only < NaiveRAG < GraphRAG-3B < GraphRAG-72B.
        assert!(acc[0] < acc[1], "llm {} !< naive {}", acc[0], acc[1]);
        assert!(acc[1] < acc[2] + 0.05, "naive {} !< graph {}", acc[1], acc[2]);
        assert!(acc[2] < acc[3], "graph3b {} !< graph72b {}", acc[2], acc[3]);
        // Cost ordering too.
        let cost: Vec<f64> = results.iter().map(|(_, s)| s.resource_cost.mean()).collect();
        assert!(cost[0] < cost[1] && cost[1] < cost[2] && cost[2] < cost[3]);
        // Delay: graph-slm slowest.
        let delay: Vec<f64> = results.iter().map(|(_, s)| s.delay.mean()).collect();
        assert!(delay[2] > delay[3], "3b graph should be slowest");
    }

    #[test]
    fn eaco_cuts_cost_vs_cloud_at_similar_accuracy() {
        let (mut sys, wl) = run_pair(Profile::Wiki, 1500);
        let (eaco, _) = sys.run_eaco(&wl);

        let cfg = small_cfg(Profile::Wiki);
        let mut base = SimSystem::new(cfg.clone(), KnowledgeMode::Static);
        let cloud = base.run_baseline(&wl, SimSystem::baseline_arm("graph-llm").unwrap());

        assert!(
            eaco.accuracy > cloud.accuracy - 0.08,
            "eaco acc {:.3} vs cloud {:.3}",
            eaco.accuracy,
            cloud.accuracy
        );
        assert!(
            eaco.resource_cost.mean() < cloud.resource_cost.mean() * 0.6,
            "eaco cost {:.1} vs cloud {:.1}",
            eaco.resource_cost.mean(),
            cloud.resource_cost.mean()
        );
    }

    #[test]
    fn adaptive_updates_improve_local_coverage() {
        let cfg = small_cfg(Profile::Wiki);
        let wl_spec = workload_for(&cfg, 600);

        let mut static_sys = SimSystem::new(cfg.clone(), KnowledgeMode::Static);
        let wl = Workload::generate(&static_sys.corpus, wl_spec, cfg.seed);
        let arm = SimSystem::baseline_arm("naive-rag").unwrap();
        let s_static = static_sys.run_baseline(&wl, arm);

        let mut adaptive_sys = SimSystem::new(cfg, KnowledgeMode::Adaptive);
        let s_adapt = adaptive_sys.run_baseline(&wl, arm);

        assert!(
            s_adapt.accuracy > s_static.accuracy + 0.02,
            "adaptive {:.3} !> static {:.3}",
            s_adapt.accuracy,
            s_static.accuracy
        );
        assert!(adaptive_sys.cloud.updates_sent > 0);
    }

    #[test]
    fn delay_oriented_gate_meets_deadline() {
        let mut cfg = small_cfg(Profile::Wiki);
        cfg.qos = QosPreset::DelayOriented;
        let mut sys = SimSystem::new(cfg.clone(), KnowledgeMode::Adaptive);
        let wl = Workload::generate(&sys.corpus, workload_for(&cfg, 900), cfg.seed);
        let (stats, _) = sys.run_eaco(&wl);
        assert!(
            stats.delay.mean() < 1.3,
            "delay-oriented mean {:.2}s",
            stats.delay.mean()
        );
    }

    #[test]
    fn deterministic_runs() {
        let (mut a, wl) = run_pair(Profile::Wiki, 300);
        let (sa, _) = a.run_eaco(&wl);
        let (mut b, wl2) = run_pair(Profile::Wiki, 300);
        let (sb, _) = b.run_eaco(&wl2);
        assert_eq!(sa.queries, sb.queries);
        assert!((sa.accuracy - sb.accuracy).abs() < 1e-12);
        assert!((sa.resource_cost.mean() - sb.resource_cost.mean()).abs() < 1e-9);
    }

    #[test]
    fn gate_uses_multiple_arms() {
        let (mut sys, wl) = run_pair(Profile::Wiki, 1500);
        let (stats, _) = sys.run_eaco(&wl);
        let used = stats.arm_counts.iter().filter(|&&c| c > 0).count();
        assert!(used >= 2, "gate collapsed to one arm: {:?}", stats.arm_counts);
    }
}
