//! # EACO-RAG — Edge-Assisted and Collaborative RAG
//!
//! Full-system reproduction of *"EACO-RAG: Towards Distributed Tiered LLM
//! Deployment using Edge-Assisted and Collaborative RAG with Adaptive
//! Knowledge Update"* (Li et al., cs.DC 2024) as a three-layer
//! Rust + JAX + Pallas stack.
//!
//! The crate is the **L3 coordinator**: it owns the serving event loop,
//! the distributed edge/cloud topology, the adaptive knowledge-update
//! machinery, and the collaborative gating mechanism (Safe Online
//! Bayesian Optimization). Model compute (L2 JAX transformer tiers, L1
//! Pallas flash-attention) is AOT-compiled by `python/compile/aot.py`
//! into `artifacts/*.hlo.txt` and executed through [`runtime`] on the
//! PJRT CPU client — Python is never on the request path.
//!
//! ## Module map (see DESIGN.md §4 for the full inventory)
//!
//! * [`util`] — PRNG, CLI parsing, JSON, stats (offline substitutes for
//!   rand/clap/serde/criterion).
//! * [`config`] — typed system configuration + TOML-subset parser.
//! * [`linalg`] — dense matrices and Cholesky solves for the GP.
//! * [`corpus`] — synthetic corpora + QA datasets (wiki / hp profiles).
//! * [`workload`] — query streams with temporal drift and spatial skew.
//! * [`index`] — inverted keyword index and overlap-ratio scoring.
//! * [`vecstore`] — cosine top-k vector store (+ IVF ANN sublayer).
//! * [`graphrag`] — entity graph, communities, local/global search.
//! * [`netsim`] — deterministic network delay simulation.
//! * [`cost`] — Pope-et-al TFLOPs cost model + Table-3 GPU constants.
//! * [`oracle`] — answer-accuracy oracle (GPT-4o grading substitute).
//! * [`edge`] — edge node: FIFO chunk store + adaptive knowledge update.
//! * [`cluster`] — the distributed knowledge plane: edge topology with
//!   netsim-derived link costs, decayed popularity counters, pluggable
//!   versioned placement (FIFO / hotness-LRU), round-based delta gossip
//!   between neighbors, and summary-routed collaborative retrieval
//!   (replacing the per-query all-edges index broadcast).
//! * [`cloud`] — cloud node: GraphRAG retrieval + knowledge distributor.
//! * [`gating`] — GP regression + SafeOBO collaborative gate (Alg. 1).
//! * [`pipeline`] — the staged per-query execution pipeline (Admit →
//!   Route → Retrieve → Gate → Generate → Grade → Update) with a typed
//!   [`pipeline::StageEvent`] stream; every driver composes it.
//! * [`runtime`] — PJRT artifact loading/execution, tokenizer, generation.
//! * [`coordinator`] — router, dynamic batcher, serving pipeline, metrics.
//! * [`serve`] — async serving plane: deterministic event loop with
//!   per-edge bounded queues, deadline-aware admission, background
//!   gossip as schedulable work, and virtual/wall clock abstraction.
//! * [`chaos`] — deterministic fault-injection plane: scripted
//!   partitions, correlated failures, link degradation; recovery /
//!   staleness / availability probes and SLA reports.
//! * [`sim`] — full-system simulation harness used by benches/examples.
//! * [`testutil`] — mini property-testing framework.

pub mod chaos;
pub mod cloud;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod corpus;
pub mod cost;
pub mod edge;
pub mod gating;
pub mod graphrag;
pub mod index;
pub mod linalg;
pub mod netsim;
pub mod oracle;
pub mod pipeline;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod testutil;
pub mod util;
pub mod vecstore;
pub mod workload;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
