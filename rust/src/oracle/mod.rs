//! Answer-accuracy oracle — the GPT-4o grading substitute (DESIGN.md §1).
//!
//! The paper measures accuracy "by comparing generated responses to
//! ground truth using GPT-4o". Without access to real LLMs, correctness
//! is modeled mechanically from the two factors that actually determine
//! RAG accuracy:
//!
//! 1. **Retrieval coverage** — the fraction of the query's supporting
//!    chunks present in the generation context. No retrieval ⇒ coverage
//!    0 and the model falls back on parametric knowledge.
//! 2. **Model capability** — the emulated tier's `capability` score
//!    (manifest), discounted for multi-hop reasoning.
//!
//! p(correct) = know + (1 − know) · coverage · quality · hop_mult · distraction
//!
//! The constants are calibrated once against the paper's Table 4
//! baselines (3B LLM-only ≈ 29%/32%, 3B+NaiveRAG ≈ 62%/53%, 3B+GraphRAG
//! ≈ 76%/63%, 72B+GraphRAG ≈ 94%/77%) and then *never* conditioned on
//! the gate's decision — the gate can only influence accuracy through
//! retrieval coverage and tier choice, exactly like the real system.
//!
//! Draws are deterministic per (seed, qa, step) so experiments replay.

use crate::corpus::{ChunkId, Corpus, Profile, QaPair};
use crate::util::rng::Rng;

/// Where the generation context came from (affects distraction/coherence).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ContextSource {
    /// No retrieval: parametric knowledge only.
    None,
    /// Flat top-k keyword/vector retrieval (local or edge-assisted).
    NaiveRag,
    /// Naive retrieval over *community-extracted* chunks distributed by
    /// the cloud's adaptive update (paper §3.2: "strong intra-community
    /// alignment … ensures that even lightweight mechanisms, like Naive
    /// RAG, operate with well-structured and semantically coherent
    /// data") — gets the coherence bonus without cloud latency.
    EdgeCommunity,
    /// Community-structured retrieval (cloud knowledge graph).
    GraphRag,
}

/// Oracle parameters (exposed for ablations; defaults are calibrated).
#[derive(Clone, Debug)]
pub struct OracleParams {
    /// Parametric-knowledge intercept/slope per profile.
    pub know_base_wiki: f64,
    pub know_slope_wiki: f64,
    pub know_base_hp: f64,
    pub know_slope_hp: f64,
    /// Multi-hop discount on parametric knowledge.
    pub know_multihop_factor: f64,
    /// Generation quality intercept/slope on capability.
    pub quality_base: f64,
    pub quality_slope: f64,
    /// Hop-penalty strength (scaled by (1 − capability)).
    pub hop2_penalty: f64,
    pub hop3_penalty: f64,
    /// Specialized-domain quality factor (paper §6.1: HP questions
    /// "require specific background knowledge").
    pub hp_quality_factor: f64,
    /// Accuracy loss per fully-irrelevant context ("misleading retrieval
    /// degrades output quality", paper §1).
    pub distraction_penalty: f64,
    /// Coherence bonus for community-extracted chunks served from the
    /// edge (paper §3.2: intra-community alignment lets naive RAG
    /// operate on well-structured data).
    pub community_coherence_bonus: f64,
}

impl Default for OracleParams {
    fn default() -> Self {
        OracleParams {
            know_base_wiki: 0.10,
            know_slope_wiki: 0.38,
            know_base_hp: 0.20,
            know_slope_hp: 0.30,
            know_multihop_factor: 0.6,
            quality_base: 0.60,
            quality_slope: 0.50,
            hop2_penalty: 0.65,
            hop3_penalty: 0.95,
            hp_quality_factor: 0.80,
            distraction_penalty: 0.05,
            community_coherence_bonus: 1.12,
        }
    }
}

/// The oracle. One instance per experiment run.
pub struct Oracle {
    pub params: OracleParams,
    seed: u64,
}

impl Oracle {
    pub fn new(seed: u64) -> Oracle {
        Oracle {
            params: OracleParams::default(),
            seed,
        }
    }

    pub fn with_params(seed: u64, params: OracleParams) -> Oracle {
        Oracle { params, seed }
    }

    /// Retrieval coverage: fraction of supporting chunks in context.
    pub fn coverage(&self, qa: &QaPair, context: &[ChunkId]) -> f64 {
        if qa.supporting_chunks.is_empty() {
            return 0.0;
        }
        let hit = qa
            .supporting_chunks
            .iter()
            .filter(|c| context.contains(c))
            .count();
        hit as f64 / qa.supporting_chunks.len() as f64
    }

    /// Probability the (emulated) model answers correctly.
    pub fn p_correct(
        &self,
        profile: Profile,
        qa: &QaPair,
        capability: f64,
        context: &[ChunkId],
        source: ContextSource,
    ) -> f64 {
        let p = &self.params;

        // Parametric knowledge.
        let mut know = match profile {
            Profile::Wiki => p.know_base_wiki + p.know_slope_wiki * capability,
            Profile::HarryPotter => p.know_base_hp + p.know_slope_hp * capability,
        };
        if qa.hops > 1 {
            know *= p.know_multihop_factor;
        }

        // Retrieval-grounded path.
        let coverage = match source {
            ContextSource::None => 0.0,
            _ => self.coverage(qa, context),
        };
        let mut quality = (p.quality_base + p.quality_slope * capability).min(1.0);
        if profile == Profile::HarryPotter {
            quality *= p.hp_quality_factor;
        }
        let hop_mult = match qa.hops {
            1 => 1.0,
            2 => 1.0 - p.hop2_penalty * (1.0 - capability),
            _ => 1.0 - p.hop3_penalty * (1.0 - capability),
        };
        let irrelevant_share = if context.is_empty() {
            0.0
        } else {
            let irrelevant = context
                .iter()
                .filter(|c| !qa.supporting_chunks.contains(c))
                .count();
            irrelevant as f64 / context.len() as f64
        };
        let mut grounded = coverage * quality * hop_mult
            * (1.0 - p.distraction_penalty * irrelevant_share);
        if source == ContextSource::EdgeCommunity {
            grounded = (grounded * p.community_coherence_bonus).min(1.0);
        }

        (know + (1.0 - know) * grounded).clamp(0.0, 1.0)
    }

    /// Bernoulli judgement, deterministic per (seed, qa, step).
    pub fn judge(
        &self,
        profile: Profile,
        qa: &QaPair,
        capability: f64,
        context: &[ChunkId],
        source: ContextSource,
        step: usize,
    ) -> bool {
        let p = self.p_correct(profile, qa, capability, context, source);
        let mut rng = Rng::new(
            self.seed
                .wrapping_mul(0x9E37_79B9_7F4A_7C15)
                .wrapping_add(qa.id as u64)
                .wrapping_add((step as u64) << 32),
        );
        rng.chance(p)
    }

    /// Convenience: judge over a whole corpus sample with a fixed
    /// strategy's (capability, retrieval) — used by calibration tests.
    pub fn expected_accuracy<F>(
        &self,
        corpus: &Corpus,
        capability: f64,
        source: ContextSource,
        mut retrieve: F,
    ) -> f64
    where
        F: FnMut(&QaPair) -> Vec<ChunkId>,
    {
        let mut sum = 0.0;
        for qa in &corpus.qa {
            let ctx = retrieve(qa);
            sum += self.p_correct(corpus.spec.profile, qa, capability, &ctx, source);
        }
        sum / corpus.qa.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::{Corpus, Profile};
    use crate::graphrag::GraphRag;

    const CAP_3B: f64 = 0.55;
    const CAP_72B: f64 = 0.90;

    #[test]
    fn llm_only_matches_table4() {
        // Table 4: 3B LLM-only = 28.72% (wiki), 31.69% (hp).
        let o = Oracle::new(1);
        for (profile, target) in [(Profile::Wiki, 0.287), (Profile::HarryPotter, 0.317)] {
            let c = Corpus::generate(profile, 1);
            let acc = o.expected_accuracy(&c, CAP_3B, ContextSource::None, |_| vec![]);
            assert!(
                (acc - target).abs() < 0.06,
                "{profile:?}: {acc:.3} vs paper {target}"
            );
        }
    }

    #[test]
    fn perfect_graph_retrieval_matches_table4_72b() {
        // Table 4: 72B + GraphRAG = 94.39% (wiki) — near-full coverage.
        let o = Oracle::new(1);
        let c = Corpus::generate(Profile::Wiki, 1);
        let acc = o.expected_accuracy(&c, CAP_72B, ContextSource::GraphRag, |qa| {
            qa.supporting_chunks.clone()
        });
        assert!(acc > 0.88, "acc {acc:.3}");
    }

    #[test]
    fn real_graphrag_retrieval_3b_near_table4() {
        // Table 4: 3B + GraphRAG = 76.01% (wiki), 63.47% (hp) — with
        // *actual* graph retrieval, not oracle-supplied chunks.
        for (profile, target, tol) in [
            (Profile::Wiki, 0.76, 0.10),
            (Profile::HarryPotter, 0.635, 0.10),
        ] {
            let c = Corpus::generate(profile, 1);
            let g = GraphRag::build(&c);
            let o = Oracle::new(1);
            let acc = o.expected_accuracy(&c, CAP_3B, ContextSource::GraphRag, |qa| {
                let kws = c.qa_keywords(qa);
                g.local_search(&c, &kws, 8)
                    .into_iter()
                    .map(|(ch, _)| ch)
                    .collect()
            });
            assert!(
                (acc - target).abs() < tol,
                "{profile:?}: {acc:.3} vs paper {target}"
            );
        }
    }

    #[test]
    fn coverage_fraction() {
        let c = Corpus::generate(Profile::Wiki, 1);
        let o = Oracle::new(1);
        let qa = c.qa.iter().find(|q| q.supporting_chunks.len() >= 2).unwrap();
        let half: Vec<_> = qa.supporting_chunks[..1].to_vec();
        let cov = o.coverage(qa, &half);
        assert!(cov > 0.0 && cov < 1.0);
        assert_eq!(o.coverage(qa, &qa.supporting_chunks), 1.0);
        assert_eq!(o.coverage(qa, &[]), 0.0);
    }

    #[test]
    fn more_capability_more_accuracy() {
        let c = Corpus::generate(Profile::Wiki, 1);
        let o = Oracle::new(1);
        let full = |qa: &QaPair| qa.supporting_chunks.clone();
        let a3 = o.expected_accuracy(&c, CAP_3B, ContextSource::NaiveRag, full);
        let a72 = o.expected_accuracy(&c, CAP_72B, ContextSource::NaiveRag, full);
        assert!(a72 > a3);
    }

    #[test]
    fn retrieval_beats_no_retrieval() {
        let c = Corpus::generate(Profile::HarryPotter, 1);
        let o = Oracle::new(1);
        let none = o.expected_accuracy(&c, CAP_3B, ContextSource::None, |_| vec![]);
        let full = o.expected_accuracy(&c, CAP_3B, ContextSource::NaiveRag, |qa| {
            qa.supporting_chunks.clone()
        });
        assert!(full > none + 0.2);
    }

    #[test]
    fn multihop_harder_for_weak_models() {
        let c = Corpus::generate(Profile::HarryPotter, 1);
        let o = Oracle::new(1);
        let single: Vec<&QaPair> = c.qa.iter().filter(|q| q.hops == 1).collect();
        let multi: Vec<&QaPair> = c.qa.iter().filter(|q| q.hops > 1).collect();
        let avg = |qs: &[&QaPair], cap: f64| {
            qs.iter()
                .map(|q| {
                    o.p_correct(
                        c.spec.profile,
                        q,
                        cap,
                        &q.supporting_chunks,
                        ContextSource::NaiveRag,
                    )
                })
                .sum::<f64>()
                / qs.len() as f64
        };
        let gap_3b = avg(&single, CAP_3B) - avg(&multi, CAP_3B);
        let gap_72b = avg(&single, CAP_72B) - avg(&multi, CAP_72B);
        assert!(gap_3b > gap_72b, "3b gap {gap_3b:.3} vs 72b gap {gap_72b:.3}");
    }

    #[test]
    fn distraction_hurts() {
        let c = Corpus::generate(Profile::Wiki, 1);
        let o = Oracle::new(1);
        let qa = &c.qa[0];
        let clean = qa.supporting_chunks.clone();
        let mut noisy = clean.clone();
        for extra in 0..20 {
            let cid = (qa.supporting_chunks[0] + 1 + extra) % c.chunks.len();
            if !noisy.contains(&cid) {
                noisy.push(cid);
            }
        }
        let p_clean =
            o.p_correct(Profile::Wiki, qa, CAP_3B, &clean, ContextSource::NaiveRag);
        let p_noisy =
            o.p_correct(Profile::Wiki, qa, CAP_3B, &noisy, ContextSource::NaiveRag);
        assert!(p_clean > p_noisy);
    }

    #[test]
    fn judge_deterministic() {
        let c = Corpus::generate(Profile::Wiki, 1);
        let o = Oracle::new(7);
        let qa = &c.qa[3];
        let a = o.judge(Profile::Wiki, qa, CAP_3B, &qa.supporting_chunks, ContextSource::NaiveRag, 10);
        let b = o.judge(Profile::Wiki, qa, CAP_3B, &qa.supporting_chunks, ContextSource::NaiveRag, 10);
        assert_eq!(a, b);
    }

    #[test]
    fn judge_rate_tracks_probability() {
        let c = Corpus::generate(Profile::Wiki, 1);
        let o = Oracle::new(9);
        let qa = &c.qa[0];
        let p = o.p_correct(Profile::Wiki, qa, CAP_3B, &qa.supporting_chunks, ContextSource::NaiveRag);
        let n = 2000;
        let hits = (0..n)
            .filter(|&s| {
                o.judge(Profile::Wiki, qa, CAP_3B, &qa.supporting_chunks, ContextSource::NaiveRag, s)
            })
            .count();
        let rate = hits as f64 / n as f64;
        assert!((rate - p).abs() < 0.05, "rate {rate:.3} vs p {p:.3}");
    }
}
