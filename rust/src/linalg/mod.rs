//! Dense linear algebra for the Gaussian-process gate.
//!
//! Row-major `Mat`, Cholesky factorization and triangular solves — the
//! complete set of operations `gating::gp` needs for posterior inference
//! (the offline image has no nalgebra/ndarray). Sizes are modest (GP
//! training sets of a few hundred to a few thousand points), so clarity
//! beats blocking; the hot `solve` paths are still cache-friendly
//! (row-major forward/backward substitution).

/// Row-major dense matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: &[Vec<f64>]) -> Self {
        let r = rows.len();
        let c = if r == 0 { 0 } else { rows[0].len() };
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Mat { rows: r, cols: c, data }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// `self * v` for a column vector `v`.
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, v.len());
        (0..self.rows)
            .map(|i| dot(self.row(i), v))
            .collect()
    }

    /// `self * other`.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows);
        let mut out = Mat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                let orow = other.row(k);
                let out_row = out.row_mut(i);
                for j in 0..orow.len() {
                    out_row[j] += a * orow[j];
                }
            }
        }
        out
    }

    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out[(j, i)] = self[(i, j)];
            }
        }
        out
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

/// Dot product, 4-lane unrolled. Independent accumulators break the
/// serial FP dependency chain so the autovectorizer can keep multiple
/// FMAs in flight; this sits on the GP hot path (`kstar·alpha`, forward
/// substitution partials) where slices are hundreds to thousands long.
/// The pairwise reduction differs from a strict sequential sum only in
/// the last ulps — every consumer tolerates ≤1e-8.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f64; 4];
    let chunks_a = a.chunks_exact(4);
    let chunks_b = b.chunks_exact(4);
    let rem_a = chunks_a.remainder();
    let rem_b = chunks_b.remainder();
    for (ca, cb) in chunks_a.zip(chunks_b) {
        acc[0] += ca[0] * cb[0];
        acc[1] += ca[1] * cb[1];
        acc[2] += ca[2] * cb[2];
        acc[3] += ca[3] * cb[3];
    }
    let mut s = (acc[0] + acc[2]) + (acc[1] + acc[3]);
    for (x, y) in rem_a.iter().zip(rem_b) {
        s += x * y;
    }
    s
}

/// Lower-triangular Cholesky factor of a symmetric positive-definite
/// matrix: `A = L Lᵀ`. Returns `None` if A is not (numerically) SPD.
pub struct Cholesky {
    pub l: Mat,
    /// Reusable forward-substitution workspace for [`Cholesky::extend`]
    /// (keeps the per-observation GP update allocation-free).
    wbuf: Vec<f64>,
}

impl Cholesky {
    pub fn new(a: &Mat) -> Option<Cholesky> {
        assert_eq!(a.rows, a.cols);
        let n = a.rows;
        let mut l = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                // Partial sums run through the unrolled `dot` over the
                // contiguous row prefixes (this is the O(n³) rebuild
                // path hit on every sliding-window trim).
                let s = a[(i, j)] - dot(&l.row(i)[..j], &l.row(j)[..j]);
                if i == j {
                    if s <= 0.0 || !s.is_finite() {
                        return None;
                    }
                    l[(i, j)] = s.sqrt();
                } else {
                    l[(i, j)] = s / l[(j, j)];
                }
            }
        }
        Some(Cholesky { l, wbuf: Vec::new() })
    }

    /// Extend an existing factor with one new row/col of A (rank-1 grow):
    /// given L for A_n and the new column `a_new = [A(n+1, 0..n), A(n+1,n+1)]`,
    /// produce L for A_{n+1}. O(n²) instead of O(n³) refactorization —
    /// this is the incremental update the gate uses every serving step.
    /// The square storage is regrown in place (stride n → n+1) so steady
    /// state does no fresh matrix allocation once capacity has grown.
    pub fn extend(&mut self, a_col: &[f64], a_diag: f64) -> bool {
        let n = self.l.rows;
        assert_eq!(a_col.len(), n);
        // Solve L w = a_col (forward substitution) into the workspace.
        let mut w = std::mem::take(&mut self.wbuf);
        w.clear();
        w.extend_from_slice(a_col);
        self.solve_lower_in_place(&mut w);
        let d = a_diag - dot(&w, &w);
        if d <= 0.0 || !d.is_finite() {
            self.wbuf = w;
            return false;
        }
        // Re-stride the row-major square storage from n to n+1 in place.
        // Rows move back-to-front; row i's destination i*(n+1) is at or
        // beyond its source i*n and strictly beyond every lower row's
        // source, so copy order never clobbers unread data.
        let m = n + 1;
        self.l.data.resize(m * m, 0.0);
        for i in (1..n).rev() {
            self.l.data.copy_within(i * n..i * n + i + 1, i * m);
        }
        // Clear the (strictly upper) remainder of each widened row.
        for i in 0..n {
            for v in &mut self.l.data[i * m + i + 1..(i + 1) * m] {
                *v = 0.0;
            }
        }
        self.l.data[n * m..n * m + n].copy_from_slice(&w);
        self.l.data[n * m + n] = d.sqrt();
        self.l.rows = m;
        self.l.cols = m;
        self.wbuf = w;
        true
    }

    /// Solve `L y = b` (forward substitution).
    pub fn solve_lower(&self, b: &[f64]) -> Vec<f64> {
        let mut y = b.to_vec();
        self.solve_lower_in_place(&mut y);
        y
    }

    /// Forward substitution in place: on entry `x` holds `b`, on exit
    /// `L x_out = b`. The per-row partial sum uses the unrolled [`dot`]
    /// over the already-solved prefix — contiguous row-major access.
    pub fn solve_lower_in_place(&self, x: &mut [f64]) {
        let n = self.l.rows;
        assert_eq!(x.len(), n);
        for i in 0..n {
            let row = self.l.row(i);
            let s = x[i] - dot(&row[..i], &x[..i]);
            x[i] = s / row[i];
        }
    }

    /// Solve `Lᵀ x = y` (backward substitution).
    pub fn solve_upper(&self, y: &[f64]) -> Vec<f64> {
        let mut x = y.to_vec();
        self.solve_upper_in_place(&mut x);
        x
    }

    /// Backward substitution in place: on entry `x` holds `y`, on exit
    /// `Lᵀ x_out = y`.
    pub fn solve_upper_in_place(&self, x: &mut [f64]) {
        let n = self.l.rows;
        assert_eq!(x.len(), n);
        for i in (0..n).rev() {
            let mut s = x[i];
            for j in i + 1..n {
                s -= self.l[(j, i)] * x[j];
            }
            x[i] = s / self.l[(i, i)];
        }
    }

    /// Solve `A x = b` via the factor.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let mut x = b.to_vec();
        self.solve_in_place(&mut x);
        x
    }

    /// Solve `A x = b` in place (forward then backward substitution).
    pub fn solve_in_place(&self, x: &mut [f64]) {
        self.solve_lower_in_place(x);
        self.solve_upper_in_place(x);
    }

    /// log|A| = 2·Σ log L_ii.
    pub fn log_det(&self) -> f64 {
        (0..self.l.rows).map(|i| self.l[(i, i)].ln()).sum::<f64>() * 2.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_spd(n: usize, rng: &mut Rng) -> Mat {
        // A = B Bᵀ + n·I is SPD.
        let mut b = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                b[(i, j)] = rng.normal();
            }
        }
        let mut a = b.matmul(&b.transpose());
        for i in 0..n {
            a[(i, i)] += n as f64;
        }
        a
    }

    #[test]
    fn matvec_and_matmul() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        assert_eq!(a.matvec(&[1.0, 1.0]), vec![3.0, 7.0]);
        let b = Mat::from_rows(&[vec![0.0, 1.0], vec![1.0, 0.0]]);
        let c = a.matmul(&b);
        assert_eq!(c, Mat::from_rows(&[vec![2.0, 1.0], vec![4.0, 3.0]]));
    }

    #[test]
    fn transpose_involution() {
        let a = Mat::from_rows(&[vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]]);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn cholesky_reconstructs() {
        let mut rng = Rng::new(1);
        for n in [1, 2, 5, 20] {
            let a = random_spd(n, &mut rng);
            let ch = Cholesky::new(&a).expect("SPD");
            let recon = ch.l.matmul(&ch.l.transpose());
            for i in 0..n {
                for j in 0..n {
                    assert!(
                        (recon[(i, j)] - a[(i, j)]).abs() < 1e-8,
                        "n={n} ({i},{j})"
                    );
                }
            }
        }
    }

    #[test]
    fn cholesky_solve_matches() {
        let mut rng = Rng::new(2);
        let n = 12;
        let a = random_spd(n, &mut rng);
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let ch = Cholesky::new(&a).unwrap();
        let x = ch.solve(&b);
        let ax = a.matvec(&x);
        for i in 0..n {
            assert!((ax[i] - b[i]).abs() < 1e-8);
        }
    }

    #[test]
    fn cholesky_rejects_non_spd() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![2.0, 1.0]]); // indefinite
        assert!(Cholesky::new(&a).is_none());
    }

    #[test]
    fn extend_matches_full_factorization() {
        let mut rng = Rng::new(3);
        let n = 10;
        let a = random_spd(n, &mut rng);
        // Factor the leading 6×6 block, then extend one row at a time.
        let m0 = 6;
        let mut sub = Mat::zeros(m0, m0);
        for i in 0..m0 {
            for j in 0..m0 {
                sub[(i, j)] = a[(i, j)];
            }
        }
        let mut ch = Cholesky::new(&sub).unwrap();
        for m in m0..n {
            let col: Vec<f64> = (0..m).map(|j| a[(m, j)]).collect();
            assert!(ch.extend(&col, a[(m, m)]));
        }
        let full = Cholesky::new(&a).unwrap();
        for i in 0..n {
            for j in 0..=i {
                assert!(
                    (ch.l[(i, j)] - full.l[(i, j)]).abs() < 1e-8,
                    "({i},{j}): {} vs {}",
                    ch.l[(i, j)],
                    full.l[(i, j)]
                );
            }
        }
    }

    #[test]
    fn log_det_matches_2x2() {
        let a = Mat::from_rows(&[vec![4.0, 0.0], vec![0.0, 9.0]]);
        let ch = Cholesky::new(&a).unwrap();
        assert!((ch.log_det() - (36.0f64).ln()).abs() < 1e-12);
    }
}
