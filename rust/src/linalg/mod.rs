//! Dense linear algebra for the Gaussian-process gate.
//!
//! Row-major `Mat`, Cholesky factorization and triangular solves — the
//! complete set of operations `gating::gp` needs for posterior inference
//! (the offline image has no nalgebra/ndarray). Sizes are modest (GP
//! training sets of a few hundred to a few thousand points), so clarity
//! beats blocking; the hot `solve` paths are still cache-friendly
//! (row-major forward/backward substitution).

/// Row-major dense matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: &[Vec<f64>]) -> Self {
        let r = rows.len();
        let c = if r == 0 { 0 } else { rows[0].len() };
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Mat { rows: r, cols: c, data }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// `self * v` for a column vector `v`.
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, v.len());
        (0..self.rows)
            .map(|i| dot(self.row(i), v))
            .collect()
    }

    /// `self * other`.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows);
        let mut out = Mat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                let orow = other.row(k);
                let out_row = out.row_mut(i);
                for j in 0..orow.len() {
                    out_row[j] += a * orow[j];
                }
            }
        }
        out
    }

    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out[(j, i)] = self[(i, j)];
            }
        }
        out
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

/// Dot product.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0.0;
    for i in 0..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// Lower-triangular Cholesky factor of a symmetric positive-definite
/// matrix: `A = L Lᵀ`. Returns `None` if A is not (numerically) SPD.
pub struct Cholesky {
    pub l: Mat,
}

impl Cholesky {
    pub fn new(a: &Mat) -> Option<Cholesky> {
        assert_eq!(a.rows, a.cols);
        let n = a.rows;
        let mut l = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let mut s = a[(i, j)];
                for k in 0..j {
                    s -= l[(i, k)] * l[(j, k)];
                }
                if i == j {
                    if s <= 0.0 || !s.is_finite() {
                        return None;
                    }
                    l[(i, j)] = s.sqrt();
                } else {
                    l[(i, j)] = s / l[(j, j)];
                }
            }
        }
        Some(Cholesky { l })
    }

    /// Extend an existing factor with one new row/col of A (rank-1 grow):
    /// given L for A_n and the new column `a_new = [A(n+1, 0..n), A(n+1,n+1)]`,
    /// produce L for A_{n+1}. O(n²) instead of O(n³) refactorization —
    /// this is the incremental update the gate uses every serving step.
    pub fn extend(&mut self, a_col: &[f64], a_diag: f64) -> bool {
        let n = self.l.rows;
        assert_eq!(a_col.len(), n);
        // Solve L w = a_col (forward substitution).
        let w = self.solve_lower(a_col);
        let d = a_diag - dot(&w, &w);
        if d <= 0.0 || !d.is_finite() {
            return false;
        }
        let mut l = Mat::zeros(n + 1, n + 1);
        for i in 0..n {
            let src = self.l.row(i);
            l.row_mut(i)[..=i].copy_from_slice(&src[..=i]);
        }
        l.row_mut(n)[..n].copy_from_slice(&w);
        l[(n, n)] = d.sqrt();
        self.l = l;
        true
    }

    /// Solve `L y = b` (forward substitution).
    pub fn solve_lower(&self, b: &[f64]) -> Vec<f64> {
        let n = self.l.rows;
        assert_eq!(b.len(), n);
        let mut y = vec![0.0; n];
        for i in 0..n {
            let row = self.l.row(i);
            let mut s = b[i];
            for j in 0..i {
                s -= row[j] * y[j];
            }
            y[i] = s / row[i];
        }
        y
    }

    /// Solve `Lᵀ x = y` (backward substitution).
    pub fn solve_upper(&self, y: &[f64]) -> Vec<f64> {
        let n = self.l.rows;
        assert_eq!(y.len(), n);
        let mut x = vec![0.0; n];
        for i in (0..n).rev() {
            let mut s = y[i];
            for j in i + 1..n {
                s -= self.l[(j, i)] * x[j];
            }
            x[i] = s / self.l[(i, i)];
        }
        x
    }

    /// Solve `A x = b` via the factor.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        self.solve_upper(&self.solve_lower(b))
    }

    /// log|A| = 2·Σ log L_ii.
    pub fn log_det(&self) -> f64 {
        (0..self.l.rows).map(|i| self.l[(i, i)].ln()).sum::<f64>() * 2.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_spd(n: usize, rng: &mut Rng) -> Mat {
        // A = B Bᵀ + n·I is SPD.
        let mut b = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                b[(i, j)] = rng.normal();
            }
        }
        let mut a = b.matmul(&b.transpose());
        for i in 0..n {
            a[(i, i)] += n as f64;
        }
        a
    }

    #[test]
    fn matvec_and_matmul() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        assert_eq!(a.matvec(&[1.0, 1.0]), vec![3.0, 7.0]);
        let b = Mat::from_rows(&[vec![0.0, 1.0], vec![1.0, 0.0]]);
        let c = a.matmul(&b);
        assert_eq!(c, Mat::from_rows(&[vec![2.0, 1.0], vec![4.0, 3.0]]));
    }

    #[test]
    fn transpose_involution() {
        let a = Mat::from_rows(&[vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]]);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn cholesky_reconstructs() {
        let mut rng = Rng::new(1);
        for n in [1, 2, 5, 20] {
            let a = random_spd(n, &mut rng);
            let ch = Cholesky::new(&a).expect("SPD");
            let recon = ch.l.matmul(&ch.l.transpose());
            for i in 0..n {
                for j in 0..n {
                    assert!(
                        (recon[(i, j)] - a[(i, j)]).abs() < 1e-8,
                        "n={n} ({i},{j})"
                    );
                }
            }
        }
    }

    #[test]
    fn cholesky_solve_matches() {
        let mut rng = Rng::new(2);
        let n = 12;
        let a = random_spd(n, &mut rng);
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let ch = Cholesky::new(&a).unwrap();
        let x = ch.solve(&b);
        let ax = a.matvec(&x);
        for i in 0..n {
            assert!((ax[i] - b[i]).abs() < 1e-8);
        }
    }

    #[test]
    fn cholesky_rejects_non_spd() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![2.0, 1.0]]); // indefinite
        assert!(Cholesky::new(&a).is_none());
    }

    #[test]
    fn extend_matches_full_factorization() {
        let mut rng = Rng::new(3);
        let n = 10;
        let a = random_spd(n, &mut rng);
        // Factor the leading 6×6 block, then extend one row at a time.
        let m0 = 6;
        let mut sub = Mat::zeros(m0, m0);
        for i in 0..m0 {
            for j in 0..m0 {
                sub[(i, j)] = a[(i, j)];
            }
        }
        let mut ch = Cholesky::new(&sub).unwrap();
        for m in m0..n {
            let col: Vec<f64> = (0..m).map(|j| a[(m, j)]).collect();
            assert!(ch.extend(&col, a[(m, m)]));
        }
        let full = Cholesky::new(&a).unwrap();
        for i in 0..n {
            for j in 0..=i {
                assert!(
                    (ch.l[(i, j)] - full.l[(i, j)]).abs() < 1e-8,
                    "({i},{j}): {} vs {}",
                    ch.l[(i, j)],
                    full.l[(i, j)]
                );
            }
        }
    }

    #[test]
    fn log_det_matches_2x2() {
        let a = Mat::from_rows(&[vec![4.0, 0.0], vec![0.0, 9.0]]);
        let ch = Cholesky::new(&a).unwrap();
        assert!((ch.log_det() - (36.0f64).ln()).abs() < 1e-12);
    }
}
