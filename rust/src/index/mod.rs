//! Inverted keyword index + overlap-ratio scoring (paper §5).
//!
//! The paper's edge-assisted retrieval picks the target edge dataset by
//! the *overlap ratio* — "the proportion of query keywords present in the
//! target dataset". This module provides the keyword machinery both the
//! edge chunk stores and the cloud distributor use: an inverted index
//! from keyword → chunk ids, plus set-overlap scoring.

use std::collections::{HashMap, HashSet};

/// Reusable scoring workspace for [`KeywordIndex::retrieve_with`]: the
/// per-query maps/sets/buffers are cleared (capacity retained) instead
/// of re-allocated, which keeps the retrieval hot path allocation-free
/// in steady state. One scratch per caller (e.g. per edge node).
#[derive(Clone, Debug, Default)]
pub struct RetrieveScratch {
    /// chunk id → distinct-keyword hit count.
    scores: HashMap<usize, usize>,
    /// normalized query keywords already counted.
    seen_kw: HashSet<String>,
    /// ranked (chunk, hits) working buffer.
    ranked: Vec<(usize, usize)>,
    /// normalization buffer (avoids a fresh String per keyword).
    norm_buf: String,
}

/// Inverted index over an (externally owned) chunk collection.
#[derive(Clone, Debug, Default)]
pub struct KeywordIndex {
    /// keyword -> chunk ids containing it (insertion order preserved).
    postings: HashMap<String, Vec<usize>>,
    /// all indexed chunk ids, for len/contains queries.
    chunk_keywords: HashMap<usize, Vec<String>>,
}

impl KeywordIndex {
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of indexed chunks.
    pub fn len(&self) -> usize {
        self.chunk_keywords.len()
    }

    pub fn is_empty(&self) -> bool {
        self.chunk_keywords.is_empty()
    }

    pub fn contains_chunk(&self, chunk_id: usize) -> bool {
        self.chunk_keywords.contains_key(&chunk_id)
    }

    /// The normalized keyword multiset indexed for a chunk (None if the
    /// chunk is not resident). The edge store uses this on eviction to
    /// keep its [`KeywordSummary`] in lock-step with the index.
    pub fn chunk_keywords(&self, chunk_id: usize) -> Option<&[String]> {
        self.chunk_keywords.get(&chunk_id).map(|v| v.as_slice())
    }

    /// Index a chunk's keywords (idempotent per chunk id: re-adding
    /// replaces the previous keyword set).
    pub fn add_chunk(&mut self, chunk_id: usize, keywords: &[String]) {
        if self.chunk_keywords.contains_key(&chunk_id) {
            self.remove_chunk(chunk_id);
        }
        for kw in keywords {
            let norm = normalize(kw);
            self.postings.entry(norm).or_default().push(chunk_id);
        }
        self.chunk_keywords
            .insert(chunk_id, keywords.iter().map(|k| normalize(k)).collect());
    }

    /// Remove a chunk (FIFO eviction path of the edge store).
    pub fn remove_chunk(&mut self, chunk_id: usize) {
        if let Some(kws) = self.chunk_keywords.remove(&chunk_id) {
            for kw in kws {
                if let Some(v) = self.postings.get_mut(&kw) {
                    v.retain(|&c| c != chunk_id);
                    if v.is_empty() {
                        self.postings.remove(&kw);
                    }
                }
            }
        }
    }

    /// Does any indexed chunk mention this keyword?
    pub fn has_keyword(&self, kw: &str) -> bool {
        let mut buf = String::new();
        normalize_into(kw, &mut buf);
        self.postings.contains_key(buf.as_str())
    }

    /// Overlap ratio: |query keywords found in the index| / |query keywords|.
    /// This is the paper's edge-selection score. One normalization
    /// buffer serves the whole query (no per-keyword String).
    pub fn overlap_ratio(&self, query_keywords: &[&str]) -> f64 {
        if query_keywords.is_empty() {
            return 0.0;
        }
        let mut buf = String::new();
        let hits = query_keywords
            .iter()
            .filter(|kw| {
                normalize_into(kw, &mut buf);
                self.postings.contains_key(buf.as_str())
            })
            .count();
        hits as f64 / query_keywords.len() as f64
    }

    /// Retrieve top-k chunks ranked by the number of distinct query
    /// keywords they contain (ties broken by chunk id for determinism).
    /// Convenience wrapper over [`Self::retrieve_with`] with a one-shot
    /// workspace; hot callers hold a [`RetrieveScratch`] instead.
    pub fn retrieve(&self, query_keywords: &[&str], k: usize) -> Vec<(usize, usize)> {
        let mut scratch = RetrieveScratch::default();
        self.retrieve_with(query_keywords, k, &mut scratch).to_vec()
    }

    /// [`Self::retrieve`] against a caller-held workspace: the scoring
    /// map, dedup set, and ranking buffer are reused across queries, so
    /// steady-state retrieval does no allocation at all — the ranked
    /// result is borrowed from the workspace (valid until its next use).
    pub fn retrieve_with<'s>(
        &self,
        query_keywords: &[&str],
        k: usize,
        scratch: &'s mut RetrieveScratch,
    ) -> &'s [(usize, usize)] {
        scratch.scores.clear();
        scratch.seen_kw.clear();
        for kw in query_keywords {
            normalize_into(kw, &mut scratch.norm_buf);
            if scratch.seen_kw.contains(scratch.norm_buf.as_str()) {
                continue; // count each distinct keyword once
            }
            scratch.seen_kw.insert(scratch.norm_buf.clone());
            if let Some(chunks) = self.postings.get(scratch.norm_buf.as_str()) {
                for &c in chunks {
                    *scratch.scores.entry(c).or_insert(0) += 1;
                }
            }
        }
        scratch.ranked.clear();
        scratch
            .ranked
            .extend(scratch.scores.iter().map(|(&c, &s)| (c, s)));
        scratch
            .ranked
            .sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        scratch.ranked.truncate(k);
        &scratch.ranked
    }

    /// All distinct keywords currently indexed.
    pub fn keywords(&self) -> impl Iterator<Item = &str> {
        self.postings.keys().map(|s| s.as_str())
    }
}

/// FNV-1a over a byte slice — the keyword fingerprint the cluster's
/// per-edge summaries use. 64 bits make cross-keyword collisions
/// negligible at edge-store scale (a few thousand distinct keywords).
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Fingerprint of one query keyword: normalize (same rules the index
/// applies) into the caller's buffer, then hash. Allocation-free when
/// the buffer's capacity suffices.
pub fn keyword_sig(kw: &str, buf: &mut String) -> u64 {
    normalize_into(kw, buf);
    fnv1a(buf.as_bytes())
}

/// Compact per-store keyword digest: a refcounted set of 64-bit keyword
/// fingerprints, kept in lock-step with a store's [`KeywordIndex`] by the
/// edge node's insert/evict paths. Probing it costs one integer-set
/// lookup per query keyword — no string normalization or postings access
/// — which is what lets [`crate::cluster::EdgeCluster`] score many
/// candidate edges per query without touching their full indexes.
#[derive(Clone, Debug, Default)]
pub struct KeywordSummary {
    /// fingerprint -> number of resident (chunk, keyword) occurrences.
    counts: HashMap<u64, u32>,
    /// normalization buffer (no fresh String per keyword).
    norm_buf: String,
}

impl KeywordSummary {
    pub fn new() -> Self {
        Self::default()
    }

    /// Distinct keyword fingerprints currently present.
    pub fn distinct_keywords(&self) -> usize {
        self.counts.len()
    }

    /// Approximate wire size of the summary (what a control plane would
    /// ship to peers): fingerprint (8 B) + refcount (4 B) per entry.
    pub fn wire_bytes(&self) -> usize {
        const SUMMARY_ENTRY_BYTES: usize = 12;
        self.counts.len() * SUMMARY_ENTRY_BYTES
    }

    /// Record one (chunk, keyword) occurrence.
    pub fn add(&mut self, kw: &str) {
        let mut buf = std::mem::take(&mut self.norm_buf);
        let h = keyword_sig(kw, &mut buf);
        self.norm_buf = buf;
        *self.counts.entry(h).or_insert(0) += 1;
    }

    /// Remove one (chunk, keyword) occurrence; drops the fingerprint when
    /// its last occurrence goes.
    pub fn remove(&mut self, kw: &str) {
        let mut buf = std::mem::take(&mut self.norm_buf);
        let h = keyword_sig(kw, &mut buf);
        self.norm_buf = buf;
        if let Some(c) = self.counts.get_mut(&h) {
            *c -= 1;
            if *c == 0 {
                self.counts.remove(&h);
            }
        }
    }

    pub fn contains_hash(&self, h: u64) -> bool {
        self.counts.contains_key(&h)
    }

    /// Number of query fingerprints present in this summary — the
    /// integer numerator of [`KeywordIndex::overlap_ratio`], computed
    /// without touching the index.
    pub fn hits(&self, query_sig: &[u64]) -> usize {
        query_sig
            .iter()
            .filter(|&h| self.counts.contains_key(h))
            .count()
    }

    /// Estimated overlap ratio for a pre-hashed query. Matches
    /// [`KeywordIndex::overlap_ratio`] exactly (same per-occurrence
    /// counting, same `hits / len` arithmetic) up to 64-bit fingerprint
    /// collisions.
    pub fn overlap_ratio_est(&self, query_sig: &[u64]) -> f64 {
        if query_sig.is_empty() {
            return 0.0;
        }
        self.hits(query_sig) as f64 / query_sig.len() as f64
    }
}

/// Keyword normalization: lowercase, trim punctuation.
pub fn normalize(kw: &str) -> String {
    let mut out = String::new();
    normalize_into(kw, &mut out);
    out
}

/// [`normalize`] into a reusable buffer (cleared first) — the hot paths
/// use this to avoid a fresh String per keyword.
pub fn normalize_into(kw: &str, out: &mut String) {
    out.clear();
    let trimmed = kw.trim_matches(|c: char| !c.is_alphanumeric() && c != '_');
    for c in trimmed.chars() {
        for lc in c.to_lowercase() {
            out.push(lc);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kws(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn overlap_ratio_basic() {
        let mut ix = KeywordIndex::new();
        ix.add_chunk(0, &kws(&["Alohomora", "spell", "door"]));
        assert_eq!(ix.overlap_ratio(&["alohomora", "spell"]), 1.0);
        assert_eq!(ix.overlap_ratio(&["alohomora", "dragon"]), 0.5);
        assert_eq!(ix.overlap_ratio(&["dragon"]), 0.0);
        assert_eq!(ix.overlap_ratio(&[]), 0.0);
    }

    #[test]
    fn retrieve_ranks_by_keyword_hits() {
        let mut ix = KeywordIndex::new();
        ix.add_chunk(0, &kws(&["a", "b"]));
        ix.add_chunk(1, &kws(&["a", "b", "c"]));
        ix.add_chunk(2, &kws(&["c"]));
        let r = ix.retrieve(&["a", "b", "c"], 2);
        assert_eq!(r[0], (1, 3));
        assert_eq!(r[1], (0, 2));
    }

    #[test]
    fn retrieve_dedups_query_keywords() {
        let mut ix = KeywordIndex::new();
        ix.add_chunk(0, &kws(&["a"]));
        ix.add_chunk(1, &kws(&["a", "b"]));
        let r = ix.retrieve(&["a", "a", "a", "b"], 2);
        assert_eq!(r[0], (1, 2)); // not inflated by repeated "a"
        assert_eq!(r[1], (0, 1));
    }

    #[test]
    fn remove_chunk_cleans_postings() {
        let mut ix = KeywordIndex::new();
        ix.add_chunk(0, &kws(&["x", "y"]));
        ix.add_chunk(1, &kws(&["x"]));
        ix.remove_chunk(0);
        assert!(!ix.contains_chunk(0));
        assert!(ix.has_keyword("x"));
        assert!(!ix.has_keyword("y"));
        assert_eq!(ix.len(), 1);
    }

    #[test]
    fn re_adding_replaces() {
        let mut ix = KeywordIndex::new();
        ix.add_chunk(0, &kws(&["old"]));
        ix.add_chunk(0, &kws(&["new"]));
        assert!(!ix.has_keyword("old"));
        assert!(ix.has_keyword("new"));
        assert_eq!(ix.len(), 1);
    }

    #[test]
    fn summary_tracks_membership_like_postings() {
        let mut ix = KeywordIndex::new();
        let mut sum = KeywordSummary::new();
        for (cid, kws_) in [(0usize, ["Alohomora", "spell"]), (1, ["spell", "door"])] {
            ix.add_chunk(cid, &kws(&kws_));
            for k in kws_ {
                sum.add(k);
            }
        }
        let mut buf = String::new();
        for probe in ["alohomora", "SPELL.", "door", "dragon"] {
            let h = keyword_sig(probe, &mut buf);
            assert_eq!(
                sum.contains_hash(h),
                ix.has_keyword(probe),
                "summary and postings disagree on {probe:?}"
            );
        }
        // Removing one of two "spell" occurrences keeps the fingerprint.
        sum.remove("spell");
        assert!(sum.contains_hash(keyword_sig("spell", &mut buf)));
        sum.remove("spell");
        assert!(!sum.contains_hash(keyword_sig("spell", &mut buf)));
    }

    #[test]
    fn summary_overlap_matches_index_overlap() {
        let mut ix = KeywordIndex::new();
        let mut sum = KeywordSummary::new();
        let chunk = ["Hermione", "wand", "library"];
        ix.add_chunk(0, &kws(&chunk));
        for k in chunk {
            sum.add(k);
        }
        let query = ["hermione", "wand", "dragon", "dragon"];
        let mut buf = String::new();
        let sig: Vec<u64> = query.iter().map(|k| keyword_sig(k, &mut buf)).collect();
        assert_eq!(sum.overlap_ratio_est(&sig), ix.overlap_ratio(&query));
        assert_eq!(sum.overlap_ratio_est(&[]), 0.0);
        assert_eq!(sum.hits(&sig), 2);
    }

    #[test]
    fn fnv1a_stable_and_distinct() {
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_ne!(fnv1a(b"alohomora"), fnv1a(b"hermione"));
        let mut buf = String::new();
        // Normalization folds into the fingerprint.
        assert_eq!(keyword_sig("Hermione.", &mut buf), keyword_sig("hermione", &mut buf));
    }

    #[test]
    fn normalization_case_insensitive() {
        let mut ix = KeywordIndex::new();
        ix.add_chunk(0, &kws(&["Hermione."]));
        assert!(ix.has_keyword("hermione"));
        assert!(ix.has_keyword("HERMIONE"));
        assert_eq!(ix.overlap_ratio(&["Hermione"]), 1.0);
    }
}
