//! Cloud node: GraphRAG retrieval + adaptive knowledge distributor
//! (paper §3.3, §5).
//!
//! The cloud "periodically collects and processes queries from users
//! across various edge nodes, maintaining a knowledge graph that
//! organizes nodes and communities based on evolving information
//! trends". Implemented here as:
//!
//! * **Graph retrieval** — `retrieve_graph` serves the gate's CloudGraph
//!   arm: GraphRAG local search plus the global community-report scan
//!   (the token-heavy part, Table 1).
//! * **Adaptive knowledge distribution** — `record_query` accumulates
//!   per-edge query keywords; once `update_trigger` (prototype: 20) new
//!   QA pairs arrive for an edge, the distributor extracts their
//!   keywords, ranks communities (`top_k`), and ships up to
//!   `distribute_max_chunks` (prototype: 500) member chunks to the edge.

use crate::corpus::{ChunkId, Corpus, QaId};
use crate::graphrag::GraphRag;
use crate::index::KeywordIndex;

/// A knowledge push for one edge node.
#[derive(Clone, Debug)]
pub struct UpdatePlan {
    pub edge_id: usize,
    pub chunks: Vec<ChunkId>,
    pub communities: Vec<usize>,
}

/// Cloud configuration knobs (paper §5 prototype values by default).
#[derive(Clone, Copy, Debug)]
pub struct CloudSpec {
    pub update_trigger: usize,
    pub distribute_max_chunks: usize,
    pub top_k_communities: usize,
}

impl Default for CloudSpec {
    fn default() -> Self {
        CloudSpec {
            update_trigger: 20,
            distribute_max_chunks: 500,
            top_k_communities: 5,
        }
    }
}

/// The cloud tier.
pub struct CloudNode {
    pub graph: GraphRag,
    pub spec: CloudSpec,
    /// Full-corpus keyword index (the centralized-RAG baseline path).
    pub full_index: KeywordIndex,
    /// Recent QA ids per edge since its last update.
    pending: Vec<Vec<QaId>>,
    pub updates_sent: usize,
}

impl CloudNode {
    pub fn new(corpus: &Corpus, num_edges: usize, spec: CloudSpec) -> CloudNode {
        let graph = GraphRag::build(corpus);
        let mut full_index = KeywordIndex::new();
        for ch in &corpus.chunks {
            full_index.add_chunk(ch.id, &ch.keywords);
        }
        CloudNode {
            graph,
            spec,
            full_index,
            pending: vec![Vec::new(); num_edges],
            updates_sent: 0,
        }
    }

    /// GraphRAG retrieval for a query: top-k community chunks. Returns
    /// `(chunks, context_chars)` where `context_chars` includes the
    /// global community-report scan — the paper's ~9k-token input.
    pub fn retrieve_graph(
        &self,
        corpus: &Corpus,
        query_keywords: &[&str],
        k: usize,
    ) -> (Vec<ChunkId>, usize) {
        let hits = self.graph.local_search(corpus, query_keywords, k);
        let chunks: Vec<ChunkId> = hits.into_iter().map(|(c, _)| c).collect();
        let chunk_chars: usize = chunks.iter().map(|&c| corpus.chunks[c].text.len()).sum();
        let context_chars = chunk_chars + self.graph.global_search_context_chars();
        (chunks, context_chars)
    }

    /// Centralized naive retrieval over the full corpus (baseline).
    pub fn retrieve_naive(
        &self,
        corpus: &Corpus,
        query_keywords: &[&str],
        k: usize,
    ) -> (Vec<ChunkId>, usize) {
        let chunks: Vec<ChunkId> = self
            .full_index
            .retrieve(query_keywords, k)
            .into_iter()
            .map(|(c, _)| c)
            .collect();
        let chars = chunks.iter().map(|&c| corpus.chunks[c].text.len()).sum();
        (chunks, chars)
    }

    /// Record a served query; if the edge has accumulated
    /// `update_trigger` new QA pairs, emit an [`UpdatePlan`] for it
    /// (paper §5: "triggering updates when the cloud accumulates 20 new
    /// QA pairs").
    pub fn record_query(
        &mut self,
        corpus: &Corpus,
        edge_id: usize,
        qa_id: QaId,
    ) -> Option<UpdatePlan> {
        self.pending[edge_id].push(qa_id);
        if self.pending[edge_id].len() < self.spec.update_trigger {
            return None;
        }
        let recent: Vec<QaId> = std::mem::take(&mut self.pending[edge_id]);
        Some(self.plan_update(corpus, edge_id, &recent))
    }

    /// Build an update plan from a set of recent queries: extract their
    /// keywords, pick top-k communities, ship member chunks (bounded).
    pub fn plan_update(
        &mut self,
        corpus: &Corpus,
        edge_id: usize,
        recent_qa: &[QaId],
    ) -> UpdatePlan {
        // Keywords of recent queries (entity names, deduped).
        let mut kws: Vec<&str> = Vec::new();
        for &qid in recent_qa {
            for kw in corpus.qa_keywords(&corpus.qa[qid]) {
                if !kws.contains(&kw) {
                    kws.push(kw);
                }
            }
        }
        let communities = self.graph.top_communities(&kws, self.spec.top_k_communities);
        let mut chunks: Vec<ChunkId> = Vec::new();
        'outer: for &cid in &communities {
            for &ch in &self.graph.communities[cid].chunks {
                if !chunks.contains(&ch) {
                    chunks.push(ch);
                    if chunks.len() >= self.spec.distribute_max_chunks {
                        break 'outer;
                    }
                }
            }
        }
        self.updates_sent += 1;
        UpdatePlan {
            edge_id,
            chunks,
            communities,
        }
    }

    /// Pending queue length for an edge (observability).
    pub fn pending_for(&self, edge_id: usize) -> usize {
        self.pending[edge_id].len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::Profile;

    fn setup() -> (Corpus, CloudNode) {
        let c = Corpus::generate(Profile::Wiki, 2);
        let cloud = CloudNode::new(&c, 3, CloudSpec::default());
        (c, cloud)
    }

    #[test]
    fn graph_retrieval_token_heavy() {
        let (c, cloud) = setup();
        let qa = &c.qa[0];
        let kws = c.qa_keywords(qa);
        let (chunks, chars) = cloud.retrieve_graph(&c, &kws, 8);
        assert!(!chunks.is_empty());
        let (_, naive_chars) = cloud.retrieve_naive(&c, &kws, 8);
        assert!(
            chars > naive_chars * 3 / 2,
            "graph context {chars} not ≫ naive {naive_chars}"
        );
    }

    #[test]
    fn update_triggers_at_threshold() {
        let (c, mut cloud) = setup();
        for i in 0..19 {
            assert!(cloud.record_query(&c, 1, i).is_none());
        }
        assert_eq!(cloud.pending_for(1), 19);
        let plan = cloud.record_query(&c, 1, 19).expect("20th query triggers");
        assert_eq!(plan.edge_id, 1);
        assert!(!plan.chunks.is_empty());
        assert_eq!(cloud.pending_for(1), 0, "queue drained");
    }

    #[test]
    fn triggers_are_per_edge() {
        let (c, mut cloud) = setup();
        for i in 0..19 {
            cloud.record_query(&c, 0, i);
            cloud.record_query(&c, 1, i + 100);
        }
        assert!(cloud.record_query(&c, 0, 50).is_some());
        assert_eq!(cloud.pending_for(1), 19, "edge 1 untouched");
    }

    #[test]
    fn distributed_chunks_match_query_topics() {
        let (c, mut cloud) = setup();
        // Pick 20 queries from one topic; the plan should carry chunks
        // covering those queries' support.
        let topic_qas: Vec<QaId> = c.qa_by_topic(c.qa[0].topic).into_iter().take(20).collect();
        let plan = cloud.plan_update(&c, 0, &topic_qas);
        let mut covered = 0;
        for &qid in &topic_qas {
            if c.qa[qid]
                .supporting_chunks
                .iter()
                .any(|s| plan.chunks.contains(s))
            {
                covered += 1;
            }
        }
        assert!(
            covered * 2 >= topic_qas.len(),
            "only {covered}/{} queries covered",
            topic_qas.len()
        );
    }

    #[test]
    fn distribution_bounded() {
        let (c, mut cloud) = setup();
        let all: Vec<QaId> = (0..c.qa.len()).collect();
        let plan = cloud.plan_update(&c, 0, &all);
        assert!(plan.chunks.len() <= cloud.spec.distribute_max_chunks);
        assert!(plan.communities.len() <= cloud.spec.top_k_communities);
        // No duplicates.
        let mut d = plan.chunks.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), plan.chunks.len());
    }

    #[test]
    fn naive_retrieval_over_full_corpus() {
        let (c, cloud) = setup();
        let qa = &c.qa[42];
        let kws = c.qa_keywords(qa);
        let (chunks, _) = cloud.retrieve_naive(&c, &kws, 8);
        assert!(
            qa.supporting_chunks.iter().any(|s| chunks.contains(s)),
            "full-index naive retrieval should find support"
        );
    }
}
