//! Tiny CLI argument parser (clap substitute for the offline image).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.
//! Each binary declares its options up front so `--help` is generated.

use std::collections::BTreeMap;

/// Declared option for help output.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<String>,
    pub is_flag: bool,
}

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    specs: Vec<OptSpec>,
    program: String,
    about: String,
}

impl Args {
    /// Build a parser: declare options, then call [`Args::parse`].
    pub fn new(program: &str, about: &str) -> Self {
        Args {
            program: program.to_string(),
            about: about.to_string(),
            ..Default::default()
        }
    }

    pub fn opt(mut self, name: &'static str, default: &str, help: &'static str) -> Self {
        self.specs.push(OptSpec {
            name,
            help,
            default: Some(default.to_string()),
            is_flag: false,
        });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(OptSpec {
            name,
            help,
            default: None,
            is_flag: true,
        });
        self
    }

    /// Parse an explicit argv (no leading program name). Returns Err with
    /// a usage string on unknown options or `--help`.
    pub fn parse_from<I: IntoIterator<Item = String>>(mut self, argv: I) -> Result<Self, String> {
        let known_flag = |specs: &[OptSpec], n: &str| {
            specs.iter().any(|s| s.name == n && s.is_flag)
        };
        let known_opt = |specs: &[OptSpec], n: &str| {
            specs.iter().any(|s| s.name == n && !s.is_flag)
        };
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if arg == "--help" || arg == "-h" {
                return Err(self.usage());
            }
            if let Some(body) = arg.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    if !known_opt(&self.specs, k) {
                        return Err(format!("unknown option --{k}\n\n{}", self.usage()));
                    }
                    self.values.insert(k.to_string(), v.to_string());
                } else if known_flag(&self.specs, body) {
                    self.flags.push(body.to_string());
                } else if known_opt(&self.specs, body) {
                    match it.next() {
                        Some(v) => {
                            self.values.insert(body.to_string(), v);
                        }
                        None => return Err(format!("option --{body} expects a value")),
                    }
                } else {
                    return Err(format!("unknown option --{body}\n\n{}", self.usage()));
                }
            } else {
                self.positional.push(arg);
            }
        }
        Ok(self)
    }

    /// Parse `std::env::args()` (skipping the program name); exits the
    /// process with the usage text on error.
    pub fn parse(self) -> Self {
        match self.parse_from(std::env::args().skip(1)) {
            Ok(a) => a,
            Err(msg) => {
                eprintln!("{msg}");
                std::process::exit(2);
            }
        }
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nOptions:\n", self.program, self.about);
        for spec in &self.specs {
            let tail = if spec.is_flag {
                String::new()
            } else {
                format!(" <v> (default: {})", spec.default.as_deref().unwrap_or(""))
            };
            s.push_str(&format!("  --{}{}\n      {}\n", spec.name, tail, spec.help));
        }
        s
    }

    pub fn get(&self, name: &str) -> String {
        if let Some(v) = self.values.get(name) {
            return v.clone();
        }
        self.specs
            .iter()
            .find(|s| s.name == name)
            .and_then(|s| s.default.clone())
            .unwrap_or_default()
    }

    pub fn get_usize(&self, name: &str) -> usize {
        self.get(name).parse().unwrap_or_else(|_| {
            eprintln!("option --{name} expects an integer (got {:?})", self.get(name));
            std::process::exit(2);
        })
    }

    pub fn get_f64(&self, name: &str) -> f64 {
        self.get(name).parse().unwrap_or_else(|_| {
            eprintln!("option --{name} expects a number (got {:?})", self.get(name));
            std::process::exit(2);
        })
    }

    pub fn get_u64(&self, name: &str) -> u64 {
        self.get(name).parse().unwrap_or_else(|_| {
            eprintln!("option --{name} expects an integer (got {:?})", self.get(name));
            std::process::exit(2);
        })
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    fn parser() -> Args {
        Args::new("t", "test")
            .opt("steps", "100", "number of steps")
            .opt("dataset", "wiki", "dataset profile")
            .flag("verbose", "chatty output")
    }

    #[test]
    fn defaults_apply() {
        let a = parser().parse_from(argv(&[])).unwrap();
        assert_eq!(a.get("steps"), "100");
        assert_eq!(a.get_usize("steps"), 100);
        assert!(!a.has_flag("verbose"));
    }

    #[test]
    fn key_value_forms() {
        let a = parser()
            .parse_from(argv(&["--steps", "5", "--dataset=hp", "--verbose", "pos1"]))
            .unwrap();
        assert_eq!(a.get_usize("steps"), 5);
        assert_eq!(a.get("dataset"), "hp");
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn unknown_option_errors() {
        assert!(parser().parse_from(argv(&["--bogus"])).is_err());
    }

    #[test]
    fn help_errors_with_usage() {
        let err = parser().parse_from(argv(&["--help"])).unwrap_err();
        assert!(err.contains("--steps"));
        assert!(err.contains("--dataset"));
    }

    #[test]
    fn missing_value_errors() {
        assert!(parser().parse_from(argv(&["--steps"])).is_err());
    }
}
