//! Minimal JSON parser + writer (serde_json substitute).
//!
//! Parses the artifact manifest written by `python/compile/aot.py` and
//! serializes metrics/experiment reports. Supports the full JSON value
//! model; numbers are f64 (adequate: the manifest's largest integers are
//! element counts < 2^53).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field access; `Json::Null` if missing or not an object.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience constructors for report writing.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}
pub fn num(n: f64) -> Json {
    Json::Num(n)
}
pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

/// Parse a JSON document.
pub fn parse(input: &str) -> Result<Json, String> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(format!("trailing data at byte {}", p.pos));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!(
                "expected {:?} at byte {} (found {:?})",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {other:?} at byte {}", self.pos)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number {text:?}: {e}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| "bad \\u escape")?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape")?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Copy a run of plain bytes (fast path, keeps UTF-8 intact).
                    let start = self.pos;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.pos += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|e| e.to_string())?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => return Err(format!("expected , or ] (found {other:?})")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                other => return Err(format!("expected , or }} (found {other:?})")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b").as_str(), Some("x"));
        assert_eq!(v.get("c"), &Json::Null);
    }

    #[test]
    fn parse_escapes() {
        let v = parse(r#""a\n\t\"\\ A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"\\ A"));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"k":[1,2.5,"s",true,null],"m":{"x":-3}}"#;
        let v = parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(parse(&out).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("nul").is_err());
    }

    #[test]
    fn get_on_missing_is_null() {
        let v = parse(r#"{"a":1}"#).unwrap();
        assert_eq!(v.get("zz"), &Json::Null);
        assert_eq!(v.get("zz").as_f64(), None);
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{"version":2,"artifacts":[{"name":"slm_qwen3b_b1","batch":1,
            "weights":[{"name":"embed","shape":[512,96],"offset_elems":0,"num_elems":49152}]}]}"#;
        let v = parse(src).unwrap();
        let a = &v.get("artifacts").as_arr().unwrap()[0];
        assert_eq!(a.get("name").as_str(), Some("slm_qwen3b_b1"));
        assert_eq!(
            a.get("weights").as_arr().unwrap()[0].get("num_elems").as_usize(),
            Some(49152)
        );
    }
}
