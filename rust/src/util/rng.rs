//! Deterministic PRNG: SplitMix64 seeding + xoshiro256++ core.
//!
//! The offline image has no `rand` crate; every stochastic component in
//! the system (corpus synthesis, workload drift, gate warm-up, oracle
//! draws, netsim jitter) draws from this generator so that runs are
//! exactly reproducible from a single seed.

/// xoshiro256++ generator (public-domain reference algorithm).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed the generator; any u64 is a valid seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream for a named subsystem. Streams from
    /// different labels are statistically independent of the parent.
    pub fn fork(&mut self, label: &str) -> Rng {
        let mut h = 0xcbf2_9ce4_8422_2325u64; // FNV-1a
        for b in label.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
        Rng::new(self.next_u64() ^ h)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in `[0, n)` (Lemire's method, unbiased enough for
    /// simulation purposes).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    /// Bernoulli draw.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Exponential with rate `lambda` (mean `1/lambda`).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -self.f64().max(1e-12).ln() / lambda
    }

    /// Zipf-like draw over `[0, n)` with exponent `s` (popularity skew).
    /// Uses inverse-CDF over precomputed weights for small n, rejection
    /// otherwise; for simulation fidelity not numeric perfection.
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        debug_assert!(n > 0);
        // Inverse transform on the harmonic CDF.
        let h: f64 = (1..=n).map(|k| (k as f64).powf(-s)).sum();
        let mut u = self.f64() * h;
        for k in 1..=n {
            u -= (k as f64).powf(-s);
            if u <= 0.0 {
                return k - 1;
            }
        }
        n - 1
    }

    /// Choose one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        debug_assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn f64_mean_near_half() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn zipf_skews_to_head() {
        let mut r = Rng::new(5);
        let mut counts = [0usize; 10];
        for _ in 0..10_000 {
            counts[r.zipf(10, 1.2)] += 1;
        }
        assert!(counts[0] > counts[4], "{counts:?}");
        assert!(counts[0] > counts[9] * 3, "{counts:?}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(1);
        let mut a = root.fork("corpus");
        let mut root2 = Rng::new(1);
        let mut b = root2.fork("corpus");
        assert_eq!(a.next_u64(), b.next_u64());
        let mut root3 = Rng::new(1);
        let mut c = root3.fork("workload");
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(17);
        let s = r.sample_indices(20, 10);
        let mut dedup = s.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), 10);
    }
}
