//! Summary statistics + a small measurement harness (criterion substitute).

/// Online mean/variance accumulator (Welford).
#[derive(Clone, Debug, Default)]
pub struct Running {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Running {
    pub fn new() -> Self {
        Running {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }
    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
    pub fn min(&self) -> f64 {
        self.min
    }
    pub fn max(&self) -> f64 {
        self.max
    }
    pub fn sum(&self) -> f64 {
        self.mean() * self.n as f64
    }
}

/// Percentile over a sample (interpolated; sorts a copy).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (rank - lo as f64)
    }
}

/// Benchmark one closure: warm up, then time `iters` runs and report.
/// Substitutes criterion in the offline image (see DESIGN.md §1).
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub std_ns: f64,
    pub min_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
}

impl BenchResult {
    pub fn throughput_per_sec(&self) -> f64 {
        if self.mean_ns == 0.0 {
            0.0
        } else {
            1e9 / self.mean_ns
        }
    }

    /// Machine-readable record with the stable BENCH_*.json schema:
    /// `{"bench", "iters", "mean_ns", "p50_ns", "p99_ns", "min_ns",
    /// "throughput_per_s"}`. Perf-tracking files (e.g. `BENCH_PR1.json`
    /// at the repo root) are arrays of these records.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::{num, obj, s};
        obj(vec![
            ("bench", s(&self.name)),
            ("iters", num(self.iters as f64)),
            ("mean_ns", num(self.mean_ns)),
            ("p50_ns", num(self.p50_ns)),
            ("p99_ns", num(self.p99_ns)),
            ("min_ns", num(self.min_ns)),
            ("throughput_per_s", num(self.throughput_per_sec())),
        ])
    }
}

impl std::fmt::Display for BenchResult {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<40} mean {:>12} ± {:>10}  p50 {:>12}  p99 {:>12}  ({} iters)",
            self.name,
            fmt_ns(self.mean_ns),
            fmt_ns(self.std_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p99_ns),
            self.iters
        )
    }
}

/// Human-readable nanoseconds.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Time `f` with automatic warm-up; `iters` measured iterations.
pub fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) -> BenchResult {
    // Warm-up: 10% of iters, at least 1.
    for _ in 0..(iters / 10).max(1) {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = std::time::Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    let mut r = Running::new();
    for &s in &samples {
        r.push(s);
    }
    BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: r.mean(),
        std_ns: r.std(),
        min_ns: r.min(),
        p50_ns: percentile(&samples, 50.0),
        p99_ns: percentile(&samples, 99.0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn running_matches_direct() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut r = Running::new();
        for &x in &xs {
            r.push(x);
        }
        assert_eq!(r.count(), 5);
        assert!((r.mean() - 4.0).abs() < 1e-12);
        let direct_var = xs.iter().map(|x| (x - 4.0) * (x - 4.0)).sum::<f64>() / 4.0;
        assert!((r.var() - direct_var).abs() < 1e-12);
        assert_eq!(r.min(), 1.0);
        assert_eq!(r.max(), 10.0);
    }

    #[test]
    fn percentile_basics() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_empty_nan() {
        assert!(percentile(&[], 50.0).is_nan());
    }

    #[test]
    fn bench_runs() {
        let r = bench("noop", 10, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(r.iters, 10);
        assert!(r.mean_ns >= 0.0);
    }

    #[test]
    fn bench_result_json_schema() {
        let r = bench("noop-json", 5, || {
            std::hint::black_box(1 + 1);
        });
        let j = r.to_json();
        assert_eq!(j.get("bench").as_str(), Some("noop-json"));
        assert_eq!(j.get("iters").as_usize(), Some(5));
        for key in ["mean_ns", "p50_ns", "p99_ns", "min_ns", "throughput_per_s"] {
            assert!(j.get(key).as_f64().is_some(), "missing {key}");
        }
    }

    #[test]
    fn fmt_ns_scales() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5_000.0).contains("µs"));
        assert!(fmt_ns(5_000_000.0).contains("ms"));
        assert!(fmt_ns(5e9).contains(" s"));
    }
}
