//! Shared utilities — in-repo substitutes for crates unavailable in the
//! offline image (rand, clap, serde/serde_json, criterion's stats).

pub mod cli;
pub mod json;
pub mod rng;
pub mod stats;

/// Format a float with fixed decimals, trimming `-0.00` artifacts.
pub fn fmt_f(v: f64, decimals: usize) -> String {
    let s = format!("{v:.decimals$}");
    if s.starts_with("-0.") && s[1..].parse::<f64>() == Ok(0.0) {
        s[1..].to_string()
    } else {
        s
    }
}
