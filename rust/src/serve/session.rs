//! Per-query session state machine.
//!
//! Every admitted query owns a [`Session`] that walks
//! `Admitted → Retrieving → Gating → Generating → Done`, or exits early
//! to `Shed` from any non-terminal stage. Each transition stamps the
//! clock, so latency decompositions (queue wait vs service) fall out of
//! the stamps.
//!
//! Under the **virtual clock** the retrieval/gating/generation stamps
//! coincide with dispatch: the simulator models delay end-to-end
//! (`Outcome::delay_s`), so the interior stages are logically
//! instantaneous and only `Admitted → Retrieving` (queue wait) and
//! `Generating → Done` (service) carry duration. A wall-clock run
//! separates them with real timestamps; the machine and its legality
//! rules are identical in both modes.

/// Lifecycle stage of one query.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    Admitted,
    Retrieving,
    Gating,
    Generating,
    Done,
    Shed,
}

impl Stage {
    pub fn is_terminal(&self) -> bool {
        matches!(self, Stage::Done | Stage::Shed)
    }

    pub fn name(&self) -> &'static str {
        match self {
            Stage::Admitted => "admitted",
            Stage::Retrieving => "retrieving",
            Stage::Gating => "gating",
            Stage::Generating => "generating",
            Stage::Done => "done",
            Stage::Shed => "shed",
        }
    }
}

/// Why a query was shed instead of served.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShedReason {
    /// The edge's queue was at capacity (`serve.queue_cap`).
    QueueFull,
    /// Predicted latency exceeded the SLO under `admission = "shed"`.
    Deadline,
    /// The home edge is dead and no alive edge exists to reroute to.
    DeadEdge,
}

/// Per-query state with per-stage timestamps (ms since run start).
/// Unvisited stage stamps are `NaN`.
#[derive(Clone, Debug)]
pub struct Session {
    /// Position in the workload event stream.
    pub seq: usize,
    pub qa_id: usize,
    /// Edge the query was *served* at (after any liveness reroute).
    pub edge_id: usize,
    pub step: usize,
    pub stage: Stage,
    pub t_admitted_ms: f64,
    pub t_retrieving_ms: f64,
    pub t_gating_ms: f64,
    pub t_generating_ms: f64,
    /// Done or Shed time.
    pub t_end_ms: f64,
    /// Serving tier (sim::TIER_*), set when the query completes.
    pub tier: usize,
    pub shed: Option<ShedReason>,
}

impl Session {
    pub fn new(seq: usize, qa_id: usize, edge_id: usize, step: usize, now_ms: f64) -> Session {
        Session {
            seq,
            qa_id,
            edge_id,
            step,
            stage: Stage::Admitted,
            t_admitted_ms: now_ms,
            t_retrieving_ms: f64::NAN,
            t_gating_ms: f64::NAN,
            t_generating_ms: f64::NAN,
            t_end_ms: f64::NAN,
            tier: 0,
            shed: None,
        }
    }

    /// Attempt a transition to `to` at time `t_ms`. Returns `false` (and
    /// mutates nothing) when the transition is illegal — terminal stages
    /// never advance, interior stages only advance forward, and `Shed`
    /// is reachable from any non-terminal stage. Time must not run
    /// backwards relative to the last stamp.
    pub fn advance(&mut self, to: Stage, t_ms: f64) -> bool {
        if self.stage.is_terminal() {
            return false;
        }
        let legal = matches!(
            (self.stage, to),
            (Stage::Admitted, Stage::Retrieving)
                | (Stage::Retrieving, Stage::Gating)
                | (Stage::Gating, Stage::Generating)
                | (Stage::Generating, Stage::Done)
                | (_, Stage::Shed)
        );
        if !legal || t_ms + 1e-9 < self.last_stamp_ms() {
            return false;
        }
        match to {
            Stage::Retrieving => self.t_retrieving_ms = t_ms,
            Stage::Gating => self.t_gating_ms = t_ms,
            Stage::Generating => self.t_generating_ms = t_ms,
            Stage::Done | Stage::Shed => self.t_end_ms = t_ms,
            Stage::Admitted => return false,
        }
        self.stage = to;
        true
    }

    /// Shed the session at `t_ms` with the given reason.
    pub fn mark_shed(&mut self, reason: ShedReason, t_ms: f64) -> bool {
        if !self.advance(Stage::Shed, t_ms) {
            return false;
        }
        self.shed = Some(reason);
        true
    }

    /// The most recent stamped time.
    fn last_stamp_ms(&self) -> f64 {
        for t in [self.t_end_ms, self.t_generating_ms, self.t_gating_ms, self.t_retrieving_ms] {
            if !t.is_nan() {
                return t;
            }
        }
        self.t_admitted_ms
    }

    /// End-to-end latency (arrival → Done/Shed); NaN while in flight.
    pub fn latency_ms(&self) -> f64 {
        self.t_end_ms - self.t_admitted_ms
    }

    /// Queue wait (arrival → dispatch); NaN if never dispatched.
    pub fn wait_ms(&self) -> f64 {
        self.t_retrieving_ms - self.t_admitted_ms
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn happy_path_stamps_every_stage() {
        let mut s = Session::new(0, 42, 3, 7, 100.0);
        assert_eq!(s.stage, Stage::Admitted);
        assert!(s.advance(Stage::Retrieving, 130.0));
        assert!(s.advance(Stage::Gating, 130.0));
        assert!(s.advance(Stage::Generating, 135.0));
        assert!(s.advance(Stage::Done, 900.0));
        assert_eq!(s.stage, Stage::Done);
        assert!(s.stage.is_terminal());
        assert_eq!(s.latency_ms(), 800.0);
        assert_eq!(s.wait_ms(), 30.0);
        assert_eq!(s.t_gating_ms, 130.0);
        assert_eq!(s.t_generating_ms, 135.0);
        assert!(s.shed.is_none());
    }

    #[test]
    fn illegal_transitions_are_rejected_without_mutation() {
        let mut s = Session::new(0, 0, 0, 0, 0.0);
        // Skipping stages is illegal.
        assert!(!s.advance(Stage::Gating, 1.0));
        assert!(!s.advance(Stage::Generating, 1.0));
        assert!(!s.advance(Stage::Done, 1.0));
        assert_eq!(s.stage, Stage::Admitted);
        assert!(s.t_gating_ms.is_nan());
        // Backwards transitions are illegal.
        assert!(s.advance(Stage::Retrieving, 1.0));
        assert!(!s.advance(Stage::Retrieving, 2.0));
        // Time cannot run backwards.
        assert!(!s.advance(Stage::Gating, 0.5));
        assert!(s.advance(Stage::Gating, 1.0));
        assert_eq!(s.stage, Stage::Gating);
    }

    #[test]
    fn shed_reachable_from_any_nonterminal_stage() {
        for pre in 0..4usize {
            let mut s = Session::new(0, 0, 0, 0, 0.0);
            let path = [Stage::Retrieving, Stage::Gating, Stage::Generating];
            for st in path.iter().take(pre) {
                assert!(s.advance(*st, 1.0));
            }
            assert!(s.mark_shed(ShedReason::Deadline, 2.0));
            assert_eq!(s.stage, Stage::Shed);
            assert_eq!(s.shed, Some(ShedReason::Deadline));
            assert_eq!(s.t_end_ms, 2.0);
            // Terminal: nothing moves any more.
            assert!(!s.advance(Stage::Done, 3.0));
            assert!(!s.mark_shed(ShedReason::QueueFull, 3.0));
            assert_eq!(s.shed, Some(ShedReason::Deadline));
        }
    }

    #[test]
    fn done_is_terminal() {
        let mut s = Session::new(0, 0, 0, 0, 0.0);
        for st in [Stage::Retrieving, Stage::Gating, Stage::Generating, Stage::Done] {
            assert!(s.advance(st, 1.0));
        }
        assert!(!s.mark_shed(ShedReason::DeadEdge, 2.0));
        assert_eq!(s.stage, Stage::Done);
    }
}
