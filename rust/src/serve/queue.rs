//! Bounded per-edge request queues and deadline-aware admission.
//!
//! Two pieces live here:
//!
//! * [`EdgeQueue`] — a bounded FIFO-within-priority queue of
//!   [`QueuedRequest`]s with backpressure accounting (pushed / popped /
//!   rejected / peak depth). This is the wall-clock serving structure:
//!   requests wait here between arrival and worker pickup. Under the
//!   virtual clock the serve loop in [`super::serve_workload`] derives
//!   queue occupancy analytically from in-flight departure times (the
//!   set of requests whose virtual completion lies in the future), which
//!   realizes the same bounded-occupancy contract without buffering
//!   already-computed results; `EdgeQueue` is exercised directly by unit
//!   tests and the `serve.enqueue` bench scenario.
//! * [`admission_decision`] — the deadline-aware admission rule: given a
//!   predicted end-to-end latency (queue-wait estimate + the monitored
//!   `NetSim::expected_delay_ms` link term + a running mean of observed
//!   service time) and the configured SLO, either accept, shed, or
//!   downgrade the query to the cheapest local arm
//!   (`local-rag+slm`). The predictor is deliberately the *expected*
//!   (jitter-free) delay — admission must not consume simulation RNG,
//!   or accepted queries would see a different random stream than the
//!   synchronous path and break bit-equivalence.

use std::collections::VecDeque;

/// Number of priority lanes. Lane 0 is the highest priority.
pub const NUM_PRIORITIES: usize = 3;

/// What to do when the predicted latency for a query would blow the SLO.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdmissionPolicy {
    /// Admit everything (the default; preserves sync-path equivalence).
    None,
    /// Reject the query outright; it never touches the simulator.
    Shed,
    /// Admit, but force the cheapest local arm (`local-rag+slm`).
    Downgrade,
}

impl AdmissionPolicy {
    pub fn parse(s: &str) -> Option<AdmissionPolicy> {
        match s.to_ascii_lowercase().as_str() {
            "none" | "off" => Some(AdmissionPolicy::None),
            "shed" => Some(AdmissionPolicy::Shed),
            "downgrade" => Some(AdmissionPolicy::Downgrade),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            AdmissionPolicy::None => "none",
            AdmissionPolicy::Shed => "shed",
            AdmissionPolicy::Downgrade => "downgrade",
        }
    }
}

/// Outcome of the admission rule for one query.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admission {
    Accept,
    Shed,
    Downgrade,
}

/// Deadline-aware admission: compare the predicted end-to-end latency
/// against the SLO and apply the configured policy.
pub fn admission_decision(policy: AdmissionPolicy, predicted_ms: f64, slo_ms: f64) -> Admission {
    if predicted_ms <= slo_ms {
        return Admission::Accept;
    }
    match policy {
        AdmissionPolicy::None => Admission::Accept,
        AdmissionPolicy::Shed => Admission::Shed,
        AdmissionPolicy::Downgrade => Admission::Downgrade,
    }
}

/// One enqueued query.
#[derive(Clone, Debug, PartialEq)]
pub struct QueuedRequest {
    /// Global arrival sequence number (position in the workload).
    pub seq: usize,
    pub qa_id: usize,
    pub edge_id: usize,
    pub step: usize,
    /// Priority lane, 0 (highest) .. NUM_PRIORITIES-1 (lowest).
    pub priority: u8,
    /// Virtual arrival time in ms since run start.
    pub arrival_ms: f64,
}

/// A bounded per-edge request queue: strict FIFO within each priority
/// lane, higher lanes always drain first (or weighted-fair across lanes
/// when weights are set — see [`EdgeQueue::new_weighted`]), pushes
/// beyond `cap` are rejected (backpressure).
#[derive(Clone, Debug)]
pub struct EdgeQueue {
    /// Capacity across all lanes; 0 means unbounded.
    cap: usize,
    lanes: [VecDeque<QueuedRequest>; NUM_PRIORITIES],
    /// Weighted-fair dequeue weights per lane; `None` = strict
    /// priority (the legacy pop, bit-identical).
    weights: Option<[f64; 3]>,
    /// Pops served per lane (the WFQ virtual-time counters).
    served: [u64; NUM_PRIORITIES],
    /// Backpressure accounting.
    pub pushed: u64,
    pub popped: u64,
    pub rejected: u64,
    pub peak_depth: usize,
}

impl EdgeQueue {
    pub fn new(cap: usize) -> EdgeQueue {
        EdgeQueue::new_weighted(cap, None)
    }

    /// A queue with weighted-fair dequeue across the priority lanes:
    /// pop picks the non-empty lane with the lowest `served/weight`
    /// ratio (ties → higher-priority lane), so a heavy high-priority
    /// backlog — fault-induced or otherwise — cannot starve the lower
    /// lanes; lanes drain in proportion to their weights. `None`
    /// preserves the strict-priority pop exactly.
    pub fn new_weighted(cap: usize, weights: Option<[f64; 3]>) -> EdgeQueue {
        debug_assert!(
            weights.is_none_or(|w| w.iter().all(|x| x.is_finite() && *x > 0.0)),
            "WFQ weights must be finite and positive"
        );
        EdgeQueue {
            cap,
            lanes: [VecDeque::new(), VecDeque::new(), VecDeque::new()],
            weights,
            served: [0; NUM_PRIORITIES],
            pushed: 0,
            popped: 0,
            rejected: 0,
            peak_depth: 0,
        }
    }

    /// The queue the `[serve]` section describes: `queue_cap` bound and
    /// `wfq_weights` dequeue discipline.
    pub fn from_config(cfg: &crate::config::ServeConfig) -> EdgeQueue {
        EdgeQueue::new_weighted(cfg.queue_cap, cfg.wfq_weights)
    }

    pub fn cap(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.lanes.iter().map(|l| l.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.lanes.iter().all(|l| l.is_empty())
    }

    /// Enqueue a request. Returns `false` (and counts a rejection) when
    /// the queue is at capacity.
    pub fn push(&mut self, req: QueuedRequest) -> bool {
        if self.cap > 0 && self.len() >= self.cap {
            self.rejected += 1;
            return false;
        }
        let lane = (req.priority as usize).min(NUM_PRIORITIES - 1);
        self.lanes[lane].push_back(req);
        self.pushed += 1;
        self.peak_depth = self.peak_depth.max(self.len());
        true
    }

    /// Dequeue the next request. Strict priority (no weights): the
    /// oldest entry of the highest non-empty lane. Weighted-fair: the
    /// oldest entry of the non-empty lane with the lowest
    /// `served/weight` ratio, ties to the higher-priority lane.
    pub fn pop(&mut self) -> Option<QueuedRequest> {
        let Some(w) = self.weights else {
            // Legacy strict-priority pop, byte-for-byte.
            for lane in self.lanes.iter_mut() {
                if let Some(req) = lane.pop_front() {
                    self.popped += 1;
                    return Some(req);
                }
            }
            return None;
        };
        let mut pick: Option<usize> = None;
        for lane in 0..NUM_PRIORITIES {
            if self.lanes[lane].is_empty() {
                continue;
            }
            let ratio = self.served[lane] as f64 / w[lane];
            // Strictly-lower ratio wins; ties keep the earlier (higher
            // priority) lane.
            match pick {
                Some(p) if ratio >= self.served[p] as f64 / w[p] => {}
                _ => pick = Some(lane),
            }
        }
        let lane = pick?;
        let req = self.lanes[lane].pop_front();
        debug_assert!(req.is_some());
        self.served[lane] += 1;
        self.popped += 1;
        // Re-baseline the WFQ virtual-time counters whenever the queue
        // fully drains: `served` is otherwise monotone for the queue's
        // lifetime, so a lane that sat idle through a long busy stretch
        // would re-enter with a stale low `served/weight` ratio and
        // monopolize pops until it "caught up" on history it never
        // competed for. An empty queue has no backlog to be fair
        // across, so the reset cannot change any contended ordering.
        if self.is_empty() {
            self.served = [0; NUM_PRIORITIES];
        }
        req
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(seq: usize, priority: u8) -> QueuedRequest {
        QueuedRequest { seq, qa_id: seq, edge_id: 0, step: seq, priority, arrival_ms: seq as f64 }
    }

    #[test]
    fn fifo_within_priority_across_lanes() {
        let mut q = EdgeQueue::new(0);
        // Interleave lanes; drain order must be lane 0 FIFO, then lane 1
        // FIFO, then lane 2 FIFO.
        for (seq, pri) in [(0, 1u8), (1, 0), (2, 2), (3, 0), (4, 1)] {
            assert!(q.push(req(seq, pri)));
        }
        let order: Vec<usize> = std::iter::from_fn(|| q.pop()).map(|r| r.seq).collect();
        assert_eq!(order, vec![1, 3, 0, 4, 2]);
        assert_eq!(q.pushed, 5);
        assert_eq!(q.popped, 5);
        assert_eq!(q.rejected, 0);
        assert!(q.is_empty());
    }

    #[test]
    fn bounded_queue_rejects_and_accounts() {
        let mut q = EdgeQueue::new(2);
        assert!(q.push(req(0, 1)));
        assert!(q.push(req(1, 0)));
        assert!(!q.push(req(2, 0)), "push beyond cap must be rejected");
        assert_eq!(q.len(), 2);
        assert_eq!(q.rejected, 1);
        assert_eq!(q.peak_depth, 2);
        // Draining one slot re-opens capacity.
        assert_eq!(q.pop().unwrap().seq, 1);
        assert!(q.push(req(3, 2)));
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn zero_cap_means_unbounded() {
        let mut q = EdgeQueue::new(0);
        for seq in 0..1000 {
            assert!(q.push(req(seq, (seq % 3) as u8)));
        }
        assert_eq!(q.len(), 1000);
        assert_eq!(q.rejected, 0);
        assert_eq!(q.peak_depth, 1000);
    }

    #[test]
    fn out_of_range_priority_clamps_to_lowest_lane() {
        let mut q = EdgeQueue::new(0);
        assert!(q.push(req(0, 200)));
        assert!(q.push(req(1, 0)));
        assert_eq!(q.pop().unwrap().seq, 1);
        assert_eq!(q.pop().unwrap().seq, 0);
    }

    #[test]
    fn wfq_none_is_bit_identical_to_strict_priority() {
        let pushes: Vec<(usize, u8)> =
            (0..60).map(|i| (i, [1u8, 0, 2, 0, 1, 2, 0][i % 7])).collect();
        let mut strict = EdgeQueue::new(8);
        let mut weighted_off = EdgeQueue::new_weighted(8, None);
        for &(seq, pri) in &pushes {
            assert_eq!(strict.push(req(seq, pri)), weighted_off.push(req(seq, pri)));
            // Interleave pops to exercise refill behavior too.
            if seq % 3 == 0 {
                assert_eq!(strict.pop(), weighted_off.pop());
            }
        }
        loop {
            let (a, b) = (strict.pop(), weighted_off.pop());
            assert_eq!(a, b);
            if a.is_none() {
                break;
            }
        }
        assert_eq!(strict.pushed, weighted_off.pushed);
        assert_eq!(strict.popped, weighted_off.popped);
        assert_eq!(strict.rejected, weighted_off.rejected);
        assert_eq!(strict.peak_depth, weighted_off.peak_depth);
    }

    #[test]
    fn wfq_prevents_low_priority_starvation() {
        // Saturated lanes: strict priority would drain all of lane 0
        // before lane 2 sees a single pop. 4:2:1 weights interleave.
        let mut q = EdgeQueue::new_weighted(0, Some([4.0, 2.0, 1.0]));
        for seq in 0..70 {
            assert!(q.push(req(seq, (seq % 3) as u8 % 3)));
        }
        let mut lane_counts = [0usize; 3];
        for _ in 0..35 {
            let r = q.pop().unwrap();
            lane_counts[(r.priority as usize).min(2)] += 1;
        }
        // After 35 pops of a saturated 4:2:1 queue, lanes get ~20/10/5.
        assert_eq!(lane_counts, [20, 10, 5]);
        assert!(lane_counts[2] > 0, "low lane starved");
    }

    #[test]
    fn wfq_ties_prefer_higher_priority_and_fifo_within_lane() {
        let mut q = EdgeQueue::new_weighted(0, Some([1.0, 1.0, 1.0]));
        for (seq, pri) in [(0, 2u8), (1, 0), (2, 0), (3, 1)] {
            assert!(q.push(req(seq, pri)));
        }
        // All ratios start 0 → first pop takes the highest lane; equal
        // weights then round-robin high→low, FIFO inside each lane.
        let order: Vec<usize> = std::iter::from_fn(|| q.pop()).map(|r| r.seq).collect();
        assert_eq!(order, vec![1, 3, 0, 2]);
    }

    #[test]
    fn wfq_rebaselines_after_full_drain_so_idle_lane_cannot_monopolize() {
        let mut q = EdgeQueue::new_weighted(0, Some([4.0, 2.0, 1.0]));
        // Long busy stretch with the low lane idle: lanes 0/1 accumulate
        // served history while lane 2's counter stays at zero.
        for seq in 0..60 {
            assert!(q.push(req(seq, (seq % 2) as u8)));
        }
        while q.pop().is_some() {}
        assert!(q.is_empty());
        // Fresh burst across all lanes. Without the drain re-baseline
        // the idle lane re-enters with a stale 0 ratio and takes every
        // pop until it catches up (here: the first 7 pops would all be
        // lane 2); with it, the weights apply from a clean slate.
        for seq in 0..21 {
            assert!(q.push(req(seq, (seq % 3) as u8)));
        }
        let mut lane_counts = [0usize; 3];
        for _ in 0..7 {
            let r = q.pop().unwrap();
            lane_counts[(r.priority as usize).min(2)] += 1;
        }
        assert_eq!(lane_counts, [4, 2, 1], "WFQ counters must re-baseline on drain");
    }

    #[test]
    fn wfq_falls_back_to_nonempty_lanes() {
        // Only the low lane has work: WFQ must serve it even though its
        // ratio is the worst.
        let mut q = EdgeQueue::new_weighted(0, Some([8.0, 4.0, 1.0]));
        for seq in 0..5 {
            assert!(q.push(req(seq, 2)));
        }
        for seq in 0..5 {
            assert_eq!(q.pop().unwrap().seq, seq);
        }
        assert!(q.pop().is_none());
    }

    #[test]
    fn from_config_threads_cap_and_weights() {
        let mut cfg = crate::config::ServeConfig::default();
        cfg.queue_cap = 2;
        cfg.wfq_weights = Some([4.0, 2.0, 1.0]);
        let mut q = EdgeQueue::from_config(&cfg);
        assert_eq!(q.cap(), 2);
        assert!(q.push(req(0, 0)));
        assert!(q.push(req(1, 2)));
        assert!(!q.push(req(2, 0)), "configured cap enforced");
        // Weighted discipline active: default config stays strict.
        let strict = EdgeQueue::from_config(&crate::config::ServeConfig::default());
        assert_eq!(strict.cap(), crate::config::ServeConfig::default().queue_cap);
    }

    #[test]
    fn admission_rule_matrix() {
        use Admission::*;
        use AdmissionPolicy as P;
        // Under SLO: always accept, whatever the policy.
        for p in [P::None, P::Shed, P::Downgrade] {
            assert_eq!(admission_decision(p, 100.0, 2000.0), Accept);
        }
        // Over SLO: policy decides.
        assert_eq!(admission_decision(P::None, 5000.0, 2000.0), Accept);
        assert_eq!(admission_decision(P::Shed, 5000.0, 2000.0), Shed);
        assert_eq!(admission_decision(P::Downgrade, 5000.0, 2000.0), Downgrade);
        // Exactly at the SLO counts as meeting it.
        assert_eq!(admission_decision(P::Shed, 2000.0, 2000.0), Accept);
    }

    #[test]
    fn admission_policy_parse_roundtrip() {
        for p in [AdmissionPolicy::None, AdmissionPolicy::Shed, AdmissionPolicy::Downgrade] {
            assert_eq!(AdmissionPolicy::parse(p.name()), Some(p));
        }
        assert_eq!(AdmissionPolicy::parse("off"), Some(AdmissionPolicy::None));
        assert_eq!(AdmissionPolicy::parse("SHED"), Some(AdmissionPolicy::Shed));
        assert_eq!(AdmissionPolicy::parse("bogus"), None);
    }
}
