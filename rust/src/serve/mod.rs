//! The asynchronous serving plane: a deterministic event loop in front
//! of [`SimSystem`]/[`crate::cluster::EdgeCluster`].
//!
//! The synchronous sim paths (`run_baseline`/`run_eaco`) drive every
//! query to completion in-line — retrieval, gossip, and generation
//! never overlap, which is exactly the wall-clock concurrency the
//! paper's delay/cost trade-offs assume away. This subsystem adds that
//! layer:
//!
//! * [`clock`] — virtual/wall clock abstraction (discrete-event time
//!   for tests, monotonic wall time for real runs).
//! * [`queue`] — bounded per-edge queues, FIFO-within-priority, and
//!   deadline-aware admission (shed/downgrade against the SLO using
//!   `NetSim::expected_delay_ms` — the jitter-free predictor, so
//!   admission consumes no simulation RNG).
//! * [`executor`] — event heap + `std::thread` worker pool (no tokio).
//! * [`session`] — per-query state machine with per-stage stamps.
//! * [`metrics`] — latency histograms, depth, sheds, gossip overlap.
//!
//! ## The determinism argument
//!
//! [`serve_workload`] is a discrete-event simulation: arrivals are
//! scheduled at their cumulative `gap_ms` offsets and *all
//! simulator-mutating work runs at arrival processing, in strict event
//! order* — gossip rounds (which consume no RNG) fire under the exact
//! due-at-arrival rule the synchronous loops use, then gating and
//! service execute immediately. Worker count and background gossip only
//! shape the *virtual queueing model* (when servers free up, what
//! overlaps what) and the physical thread pool — never the order of
//! logical calls. Hence, with admission off and an unbounded queue:
//!
//! 1. `RunStats` is bit-identical to the synchronous path on the same
//!    seeded workload (tier mix, hits, bytes replicated, cost streams);
//! 2. runs are bit-identical across repeats *and across worker counts*;
//! 3. toggling `gossip_background` changes latency/overlap metrics but
//!    not any query's retrieved-chunk set
//!    ([`metrics::ServeMetrics::retrieved_digest`]).
//!
//! All three are asserted in `tests/serve_determinism.rs`.
//!
//! The adaptive-knowledge feedback loop (`[cluster] feedback =
//! "hit-rate"`) inherits this argument for free: outcome observations
//! feed the cluster-owned [`crate::cluster::feedback::FeedbackState`]
//! inside `exec_query` — i.e. at arrival processing, in strict
//! workload order — so learned per-link gossip budgets are invariant
//! across `serve.workers` settings exactly like every other
//! simulator mutation.

pub mod clock;
pub mod executor;
pub mod metrics;
pub mod queue;
pub mod session;

use crate::chaos::{self, ChaosProbe, Scenario};
use crate::gating::{Arm, GenLoc, Retrieval};
use crate::netsim::{Link, NetSpec};
use crate::pipeline::{
    build_gate, exec_query, gated_step, KnowledgePolicy, NullSink, StageEvent, StageSink,
    StatsSink,
};
use crate::sim::{RunStats, SimSystem};
use crate::util::stats::Running;
use crate::workload::Workload;

use clock::ServeClock;
use executor::{EventHeap, Job, WorkerPool};
use metrics::ServeMetrics;
use queue::{admission_decision, Admission, AdmissionPolicy};
use session::{Session, ShedReason, Stage};

/// Prior mean service time used by the admission predictor before any
/// query has completed (ms).
const DEFAULT_SVC_MS: f64 = 500.0;

/// Modeled edge uplink throughput for gossip wire time (bytes per ms;
/// 10 MB/s — a conservative edge NIC share).
const GOSSIP_BYTES_PER_MS: f64 = 10_000.0;

/// Who picks the arm for each query.
pub enum Driver {
    /// Fixed arm for every query (the `run_baseline` counterpart).
    Fixed(Arm),
    /// SafeOBO gate, constructed exactly as `run_eaco` does (same QoS
    /// preset, warm-up, β, and seed — equivalence by construction).
    Gated,
}

/// Events on the virtual timeline.
enum Tick {
    /// Workload arrival (index into `workload.events`).
    Arrival(usize),
    /// A gossip round's modeled wire time elapsed.
    GossipDone,
    /// A scheduled fault fires (index into the chaos scenario's
    /// schedule). Pushed before same-time arrivals, so a fault at step
    /// `s` applies before the first query of step `s` is processed.
    Fault(usize),
}

/// Virtual time at which a fault pinned to `at_step` fires: the arrival
/// time of the first workload event at or after that step (falling back
/// to the last arrival for schedules past the workload's end).
fn fault_time(arrival_times: &[f64], workload: &Workload, at_step: usize) -> f64 {
    for (i, ev) in workload.events.iter().enumerate() {
        if ev.step >= at_step {
            return arrival_times[i];
        }
    }
    arrival_times.last().copied().unwrap_or(0.0)
}

/// Virtual wire time of one gossip round: a neighbor round trip plus
/// the payload at the modeled uplink rate. Pure function of the round's
/// byte accounting — no RNG.
fn gossip_service_ms(spec: &NetSpec, wire_bytes: usize) -> f64 {
    2.0 * spec.edge_edge_base_ms + wire_bytes as f64 / GOSSIP_BYTES_PER_MS
}

/// Overlap (ms) of two half-open intervals.
fn overlap(a: (f64, f64), b: (f64, f64)) -> f64 {
    (a.1.min(b.1) - a.0.max(b.0)).max(0.0)
}

/// Fan-out sink for one serve run: the stats fold, the metrics surface,
/// the optional chaos probe, and the caller's observer all see every
/// event, in that fixed order. Each sink owns disjoint state, so the
/// fan-out order is unobservable in any digest.
struct ServeSinks<'a> {
    stats: StatsSink,
    metrics: ServeMetrics,
    probe: Option<ChaosProbe>,
    observer: &'a mut dyn StageSink,
}

impl StageSink for ServeSinks<'_> {
    fn emit(&mut self, ev: &StageEvent<'_>) {
        self.stats.emit(ev);
        self.metrics.emit(ev);
        if let Some(p) = self.probe.as_mut() {
            p.emit(ev);
        }
        self.observer.emit(ev);
    }
}

/// Drive a workload through the serving plane. Returns the run's
/// `RunStats` (with the worker-invariant [`metrics::ServeSummary`]
/// attached) plus the full [`ServeMetrics`].
pub fn serve_workload(
    sys: &mut SimSystem,
    workload: &Workload,
    driver: Driver,
) -> (RunStats, ServeMetrics) {
    serve_workload_observed(sys, workload, driver, &mut NullSink)
}

/// [`serve_workload`] with an external [`StageSink`] attached: the
/// observer receives every pipeline event (arrivals, admission
/// verdicts, gossip rounds, faults, completions) in strict workload
/// order — the emission points run at arrival processing, so the
/// stream is invariant across `serve.workers` settings.
pub fn serve_workload_observed(
    sys: &mut SimSystem,
    workload: &Workload,
    driver: Driver,
    observer: &mut dyn StageSink,
) -> (RunStats, ServeMetrics) {
    let scfg = sys.cfg.serve.clone();
    let workers = scfg.workers.max(1);
    let policy = KnowledgePolicy::from_mode(sys.mode);

    // Shared gate recipe (`pipeline::build_gate`): same constructor
    // inputs as `run_eaco` ⇒ same GP streams ⇒ same decisions on the
    // same contexts.
    let mut gate = match driver {
        Driver::Gated => Some(build_gate(&sys.cfg)),
        Driver::Fixed(_) => None,
    };
    let downgrade_arm = Arm { retrieval: Retrieval::LocalNaive, gen: GenLoc::EdgeSlm };
    let downgrade_idx = gate
        .as_ref()
        .and_then(|g| g.arms.iter().position(|a| *a == downgrade_arm));

    let bytes0 = sys.cluster.bytes_gossiped();
    let mut clk = ServeClock::virtual_clock();

    // Cumulative inter-arrival offsets, precomputed so scheduled
    // faults can be pinned to the arrival time of their step.
    let mut arrival_times = Vec::with_capacity(workload.events.len());
    let mut t_arr = 0.0f64;
    for ev in &workload.events {
        t_arr += ev.gap_ms;
        arrival_times.push(t_arr);
    }

    // Chaos plan: resolve the configured scenario (name validity is
    // enforced at config-parse time) and its probe. Fault ticks go on
    // the heap *before* arrivals so that at equal timestamps — the heap
    // is FIFO at ties — a fault applies before that step's first query.
    let scenario = if sys.cfg.chaos.enabled {
        Scenario::from_config(&sys.cfg.chaos, sys.cfg.num_edges)
    } else {
        None
    };
    let mut sinks = ServeSinks {
        stats: StatsSink::new(
            gate.as_ref().map(|g| g.arms.len()).unwrap_or(1),
            matches!(driver, Driver::Gated),
        ),
        metrics: ServeMetrics::new(sys.cfg.num_edges, &scfg),
        probe: scenario.as_ref().map(|_| ChaosProbe::new(sys.cfg.num_edges)),
        observer,
    };
    let mut heap: EventHeap<Tick> = EventHeap::new();
    if let Some(sc) = &scenario {
        for (fi, f) in sc.schedule.iter().enumerate() {
            let t = fault_time(&arrival_times, workload, f.at_step);
            heap.push(t, Tick::Fault(fi));
        }
    }

    // Schedule every arrival at its cumulative inter-arrival offset.
    // Ties (zero gaps) pop in event order, so arrival processing order
    // equals workload order.
    for (i, &t) in arrival_times.iter().enumerate() {
        heap.push(t, Tick::Arrival(i));
    }

    // Virtual queueing state: `workers` servers and the set of
    // in-flight (start, done) intervals (per-edge id attached for the
    // bounded per-edge occupancy check). This is the analytic form of
    // the per-edge `queue::EdgeQueue` contract under virtual time.
    let mut server_free = vec![0.0f64; workers];
    let mut in_flight: Vec<(f64, f64, usize)> = Vec::new();
    let mut gossip_windows: Vec<(f64, f64)> = Vec::new();
    let mut svc_est = Running::new();
    let mut pool = scfg.gossip_background.then(|| WorkerPool::new(workers));

    while let Some((now, tick)) = heap.pop() {
        clk.advance_to(now);
        let i = match tick {
            Tick::GossipDone => {
                sinks.metrics.gossip_completed += 1;
                continue;
            }
            Tick::Fault(fi) => {
                // Apply the scheduled fault to both planes, then emit
                // the event with the post-fault version lag (the probe
                // folds it). Injection is RNG-free, so admitted queries
                // keep the exact random streams of a fault-free run.
                let sc = scenario.as_ref().expect("fault tick implies a scenario");
                let f = &sc.schedule[fi];
                chaos::injector::apply(&f.event, &mut sys.cluster, &mut sys.net);
                let lag = sys.cluster.max_version_lag();
                sinks.emit(&StageEvent::FaultApplied {
                    event: &f.event,
                    now_ms: now,
                    version_lag: lag,
                });
                continue;
            }
            Tick::Arrival(i) => i,
        };
        let ev = &workload.events[i];

        // Gossip as a schedulable work item, under the exact trigger
        // rule of the synchronous loops (due-at-arrival, before the
        // query touches the stores) — rounds consume no RNG, so store
        // state and the byte stream stay bit-identical to
        // `run_baseline`/`run_eaco`. The pipeline's own pre-query
        // gossip then no-ops for this step.
        if let Some(report) = policy.pre_query(&mut sys.cluster, &sys.corpus, ev.step) {
            let g_ms = gossip_service_ms(&sys.net.spec, report.wire_bytes());
            let lag = sinks.probe.as_ref().map(|_| sys.cluster.max_version_lag());
            sinks.emit(&StageEvent::GossipRound {
                step: ev.step,
                round: report.round,
                wire_bytes: report.wire_bytes(),
                version_lag: lag,
            });
            sinks.metrics.gossip_busy_ms += g_ms;
            if scfg.gossip_background {
                // Background: the round's logical effects land at the
                // same deterministic point as the sync path (so no
                // query's retrieved set can change); only its modeled
                // wire time runs concurrently with query service.
                for &(s, d, _) in &in_flight {
                    sinks.metrics.gossip_overlap_ms += overlap((now, now + g_ms), (s, d));
                }
                gossip_windows.push((now, now + g_ms));
                // Physical wire-work (checksum of the round's bytes)
                // goes to the thread pool; results are XOR-folded so
                // completion order cannot leak into the digest.
                if let Some(p) = pool.as_mut() {
                    p.submit(Job::GossipWire { round: report.round, bytes: report.wire_bytes() });
                    sinks.metrics.bg_jobs += 1;
                }
            } else {
                // Foreground: the round blocks every virtual server.
                for f in server_free.iter_mut() {
                    *f = f.max(now + g_ms);
                }
            }
            heap.push(now + g_ms, Tick::GossipDone);
        }

        // Queue accounting at arrival: drop departed sessions, then
        // read depths.
        in_flight.retain(|&(_, d, _)| d > now);
        let depth = in_flight.len();
        let edge_depth = in_flight.iter().filter(|&&(_, _, e)| e == ev.edge_id).count();
        sinks.emit(&StageEvent::Arrival {
            seq: i,
            edge_id: ev.edge_id,
            step: ev.step,
            now_ms: now,
            depth,
        });

        let mut session = Session::new(i, ev.qa_id, ev.edge_id, ev.step, now);

        // Backpressure: bounded per-edge occupancy.
        if scfg.queue_cap > 0 && edge_depth >= scfg.queue_cap {
            session.mark_shed(ShedReason::QueueFull, now);
            sinks.emit(&StageEvent::SessionShed { session: &session });
            continue;
        }

        // Liveness: route around a dead home edge (nearest alive peer
        // by link cost); shed only when the whole fleet is down.
        let mut edge_id = ev.edge_id;
        if !sys.cluster.is_alive(edge_id) {
            match sys.cluster.nearest_alive(edge_id) {
                Some(alt) => {
                    edge_id = alt;
                    session.edge_id = alt;
                    sinks.emit(&StageEvent::Rerouted { seq: i, from: ev.edge_id, to: alt });
                }
                None => {
                    session.mark_shed(ShedReason::DeadEdge, now);
                    sinks.emit(&StageEvent::SessionShed { session: &session });
                    continue;
                }
            }
        }

        // Deadline-aware admission: predicted latency = queue-wait
        // estimate + monitored access link + mean observed service.
        // Everything here is jitter-free (`expected_delay_ms` is pure),
        // so admitted queries consume the same RNG stream as the
        // synchronous path.
        let mut downgrade = false;
        if scfg.admission != AdmissionPolicy::None {
            let svc_ms = if svc_est.count() > 0 { svc_est.mean() } else { DEFAULT_SVC_MS };
            let wait_ms = depth as f64 * svc_ms / workers as f64;
            let predicted_ms =
                wait_ms + sys.net.expected_delay_ms(Link::UserToEdge(edge_id), ev.step) + svc_ms;
            match admission_decision(scfg.admission, predicted_ms, scfg.slo_ms) {
                Admission::Accept => {}
                Admission::Shed => {
                    session.mark_shed(ShedReason::Deadline, now);
                    sinks.emit(&StageEvent::SessionShed { session: &session });
                    continue;
                }
                Admission::Downgrade => {
                    downgrade = true;
                    sinks.emit(&StageEvent::Downgraded { seq: i });
                }
            }
        }

        sinks.emit(&StageEvent::Admitted { seq: i });

        // Dispatch to the earliest-free virtual server (tie → lowest
        // index — deterministic).
        let mut slot = 0usize;
        for w in 1..server_free.len() {
            if server_free[w] < server_free[slot] {
                slot = w;
            }
        }
        let start = now.max(server_free[slot]);
        session.advance(Stage::Retrieving, start);
        session.advance(Stage::Gating, start);
        session.advance(Stage::Generating, start);

        // Logical work through the pipeline, strictly in event order —
        // this is what keeps the run bit-identical across worker
        // counts. Under virtual time the interior stage stamps coincide
        // with dispatch (the simulator models delay end-to-end; see
        // `session`).
        let (outcome, correct, used_idx, explored) = match (&driver, gate.as_mut()) {
            (Driver::Gated, Some(g)) => {
                let override_idx = if downgrade { downgrade_idx } else { None };
                let r = gated_step(
                    sys, g, ev.qa_id, edge_id, ev.step, override_idx, &mut sinks,
                );
                (r.outcome, r.correct, r.arm_idx, r.explored)
            }
            (Driver::Fixed(arm), _) => {
                let arm = if downgrade { downgrade_arm } else { *arm };
                let (outcome, correct) =
                    exec_query(sys, ev.qa_id, edge_id, ev.step, arm, &mut sinks);
                (outcome, correct, 0, false)
            }
            (Driver::Gated, None) => unreachable!("gated driver always has a gate"),
        };

        // Virtual service completes after the modeled end-to-end delay.
        let service_ms = outcome.delay_s * 1000.0;
        let done = start + service_ms;
        server_free[slot] = done;
        in_flight.push((start, done, edge_id));
        svc_est.push(service_ms);
        if scfg.gossip_background {
            // This session's overlap with every already-open gossip
            // window (the trigger-time pass above covers sessions that
            // were in flight when a window opened).
            for &(g0, g1) in &gossip_windows {
                sinks.metrics.gossip_overlap_ms += overlap((g0, g1), (start, done));
            }
        }
        session.advance(Stage::Done, done);
        session.tier = sys.last_tier;
        // Terminal events: `arrival_ms` carries the arrival stamp
        // (`now`), so recovery measurements stay invariant to the
        // worker count; `store_empty` is the served edge's post-update
        // state (closes chaos recovery windows).
        let store_empty = sys.cluster.nodes[edge_id].is_empty();
        sinks.emit(&StageEvent::QueryDone {
            seq: i,
            edge_id,
            arrival_ms: now,
            outcome: &outcome,
            correct,
            arm_idx: used_idx,
            explored,
            tier: sys.last_tier,
            hit: sys.last_hit,
            ann: sys.last_ann,
            store_empty,
        });
        sinks.emit(&StageEvent::SessionDone { session: &session });
    }

    let ServeSinks { stats, metrics: mut m, probe, observer: _ } = sinks;
    let mut stats = stats.finish();
    stats.bytes_replicated = sys.cluster.bytes_gossiped() - bytes0;
    if let Some(mut p) = pool {
        let (checksum, busy_ns, done) = p.drain();
        m.bg_checksum = checksum;
        m.bg_wall_busy_ns = busy_ns;
        m.bg_jobs_done = done;
    }
    if let (Some(p), Some(sc)) = (&probe, &scenario) {
        m.chaos = Some(p.outcome(&sc.name, m.completed, m.shed_total(), m.rerouted));
    }
    stats.serve = Some(m.summary());
    (stats, m)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;
    use crate::corpus::Profile;
    use crate::sim::{workload_for, KnowledgeMode};

    fn small_cfg() -> SystemConfig {
        SystemConfig {
            dataset: Profile::Wiki,
            num_edges: 3,
            edge_capacity: 300,
            warmup_steps: 50,
            ..SystemConfig::default()
        }
    }

    fn arm() -> Arm {
        SimSystem::baseline_arm("naive-rag").unwrap()
    }

    #[test]
    fn static_mode_smoke_all_queries_complete() {
        let cfg = small_cfg();
        let mut sys = SimSystem::new(cfg.clone(), KnowledgeMode::Static);
        let wl = Workload::generate(&sys.corpus, workload_for(&cfg, 120), cfg.seed);
        let n = wl.events.len();
        let (stats, m) = serve_workload(&mut sys, &wl, Driver::Fixed(arm()));
        assert_eq!(stats.queries, n);
        assert_eq!(m.admitted, n);
        assert_eq!(m.completed, n);
        assert_eq!(m.shed_total(), 0);
        assert_eq!(m.gossip_rounds, 0, "static mode has no gossip to schedule");
        let (p50, p99) = m.latency_p50_p99();
        assert!(p50 > 0.0 && p99 >= p50);
        let summary = stats.serve.expect("serve summary attached");
        assert_eq!(summary.completed, n);
        assert_eq!(summary, m.summary());
        assert_eq!(m.sessions.len(), n);
        assert!(m.sessions.iter().all(|s| s.stage == Stage::Done));
    }

    #[test]
    fn module_digest_reproducible_across_runs() {
        let cfg = small_cfg();
        let run = || {
            let mut sys = SimSystem::new(cfg.clone(), KnowledgeMode::Static);
            let wl = Workload::generate(&sys.corpus, workload_for(&cfg, 100), cfg.seed);
            let (_, m) = serve_workload(&mut sys, &wl, Driver::Fixed(arm()));
            m.digest()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn gossip_duration_model_is_monotone_in_bytes() {
        let spec = NetSpec::default();
        let a = gossip_service_ms(&spec, 0);
        let b = gossip_service_ms(&spec, 100_000);
        assert!(a > 0.0);
        assert!(b > a);
        assert!((b - a - 10.0).abs() < 1e-9, "100 kB at 10 MB/s is 10 ms");
    }

    #[test]
    fn interval_overlap_math() {
        assert_eq!(overlap((0.0, 10.0), (5.0, 20.0)), 5.0);
        assert_eq!(overlap((0.0, 10.0), (10.0, 20.0)), 0.0);
        assert_eq!(overlap((0.0, 10.0), (2.0, 3.0)), 1.0);
        assert_eq!(overlap((5.0, 6.0), (0.0, 100.0)), 1.0);
        assert_eq!(overlap((0.0, 1.0), (2.0, 3.0)), 0.0);
    }
}
