//! Virtual/wall clock abstraction for the serving plane.
//!
//! The contract (documented in ROADMAP.md §serve):
//!
//! * **Virtual** — a discrete-event clock owned by the serve loop. Time
//!   only moves when [`ServeClock::advance_to`] is called with the
//!   timestamp of the event being dispatched, and it never moves
//!   backwards. Every timestamp is derived from workload data
//!   (`QueryEvent::gap_ms`) and deterministic service models, so a run
//!   under the virtual clock is **bit-reproducible**: same seed ⇒ same
//!   event order ⇒ same `RunStats`, regardless of how many OS threads
//!   the executor uses (see `tests/serve_determinism.rs`).
//! * **Wall** — a monotonic real clock (`std::time::Instant`) for real
//!   serving runs. `advance_to` is a no-op (real time cannot be set)
//!   and `now_ms` reads elapsed wall time. Nothing derived from a wall
//!   clock may feed determinism-checked stats — wall readings live only
//!   in observability fields that [`super::metrics::ServeMetrics::digest`]
//!   excludes.

use std::time::Instant;

/// The serving plane's single time authority.
#[derive(Clone, Debug)]
pub enum ServeClock {
    /// Discrete-event time in milliseconds since run start.
    Virtual { now_ms: f64 },
    /// Monotonic wall time since construction.
    Wall { start: Instant },
}

impl ServeClock {
    /// A virtual clock starting at t = 0 ms.
    pub fn virtual_clock() -> ServeClock {
        ServeClock::Virtual { now_ms: 0.0 }
    }

    /// A wall clock anchored at "now".
    pub fn wall() -> ServeClock {
        ServeClock::Wall { start: Instant::now() }
    }

    pub fn is_virtual(&self) -> bool {
        matches!(self, ServeClock::Virtual { .. })
    }

    /// Current time in milliseconds since run start.
    pub fn now_ms(&self) -> f64 {
        match self {
            ServeClock::Virtual { now_ms } => *now_ms,
            ServeClock::Wall { start } => start.elapsed().as_secs_f64() * 1e3,
        }
    }

    /// Advance a virtual clock to an event's timestamp. Time never runs
    /// backwards: an earlier timestamp leaves the clock where it is (the
    /// event heap pops in time order, so this only happens for
    /// same-instant ties). No-op on a wall clock.
    pub fn advance_to(&mut self, t_ms: f64) {
        if let ServeClock::Virtual { now_ms } = self {
            debug_assert!(t_ms + 1e-9 >= *now_ms, "clock moved backwards: {now_ms} -> {t_ms}");
            if t_ms > *now_ms {
                *now_ms = t_ms;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn virtual_clock_is_set_not_sampled() {
        let mut c = ServeClock::virtual_clock();
        assert!(c.is_virtual());
        assert_eq!(c.now_ms(), 0.0);
        c.advance_to(12.5);
        assert_eq!(c.now_ms(), 12.5);
        // Same-instant tie: stays put.
        c.advance_to(12.5);
        assert_eq!(c.now_ms(), 12.5);
        c.advance_to(100.0);
        assert_eq!(c.now_ms(), 100.0);
    }

    #[test]
    fn virtual_clock_deterministic_across_instances() {
        let mut a = ServeClock::virtual_clock();
        let mut b = ServeClock::virtual_clock();
        for t in [3.0, 7.25, 7.25, 91.5] {
            a.advance_to(t);
            b.advance_to(t);
            assert_eq!(a.now_ms().to_bits(), b.now_ms().to_bits());
        }
    }

    #[test]
    fn wall_clock_monotone_and_unsettable() {
        let mut c = ServeClock::wall();
        assert!(!c.is_virtual());
        let t0 = c.now_ms();
        c.advance_to(1e12); // ignored
        let t1 = c.now_ms();
        assert!(t1 >= t0);
        assert!(t1 < 1e9, "advance_to must not set wall time");
    }
}
