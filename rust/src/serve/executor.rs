//! Offline-deps-only event-loop machinery: a deterministic event heap
//! keyed by the serve clock, and a `std::thread` worker pool (no tokio)
//! that absorbs background gossip wire-work.
//!
//! Determinism split:
//!
//! * [`EventHeap`] orders *logical* work. Pops are totally ordered by
//!   `(time, insertion sequence)`, so the loop that drains it is
//!   bit-reproducible no matter how events were interleaved at push
//!   time.
//! * [`WorkerPool`] absorbs *physical* work — per-round gossip wire
//!   checksums standing in for serialization/transfer CPU. Jobs complete
//!   in nondeterministic thread order, so every job result is designed
//!   to be order-independent: per-job checksums are XOR-folded, and the
//!   only order-sensitive observable (wall busy time) is excluded from
//!   [`super::metrics::ServeMetrics::digest`].

use std::collections::BinaryHeap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

/// A min-heap of timed events. Ties at the same timestamp pop in
/// insertion order. Timestamps must be finite and non-negative
/// (non-negative IEEE-754 doubles order correctly by their bit
/// patterns, which gives a total `Ord` without float comparisons).
#[derive(Debug)]
pub struct EventHeap<T> {
    heap: BinaryHeap<Entry<T>>,
    seq: u64,
}

#[derive(Debug)]
struct Entry<T> {
    key: (u64, u64),
    item: T,
}

impl<T> PartialEq for Entry<T> {
    fn eq(&self, other: &Self) -> bool {
        self.key == other.key
    }
}
impl<T> Eq for Entry<T> {}
impl<T> PartialOrd for Entry<T> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl<T> Ord for Entry<T> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Reversed: BinaryHeap is a max-heap, we want earliest first.
        other.key.cmp(&self.key)
    }
}

impl<T> EventHeap<T> {
    pub fn new() -> EventHeap<T> {
        EventHeap { heap: BinaryHeap::new(), seq: 0 }
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Schedule `item` at `time_ms` (finite, >= 0).
    pub fn push(&mut self, time_ms: f64, item: T) {
        debug_assert!(time_ms.is_finite() && time_ms >= 0.0, "bad event time {time_ms}");
        let key = (time_ms.to_bits(), self.seq);
        self.seq += 1;
        self.heap.push(Entry { key, item });
    }

    /// Pop the earliest event as `(time_ms, item)`.
    pub fn pop(&mut self) -> Option<(f64, T)> {
        self.heap.pop().map(|e| (f64::from_bits(e.key.0), e.item))
    }
}

impl<T> Default for EventHeap<T> {
    fn default() -> Self {
        EventHeap::new()
    }
}

/// Background work shipped to the pool.
#[derive(Clone, Copy, Debug)]
pub enum Job {
    /// Wire-level work for one gossip round: checksum `bytes` of
    /// payload for round `round`. Stands in for
    /// serialization/compression CPU that real gossip would burn.
    GossipWire { round: usize, bytes: usize },
}

/// Result of one background job.
#[derive(Clone, Copy, Debug)]
pub struct JobDone {
    pub checksum: u64,
    pub busy_ns: u128,
}

/// Deterministic per-job checksum: FNV-1a folded over a mix stream
/// whose length scales with the payload (capped), so bigger rounds cost
/// proportionally more CPU. Depends only on `(round, bytes)` — never on
/// thread identity or timing.
pub fn wire_checksum(round: usize, bytes: usize) -> u64 {
    const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = FNV_OFFSET;
    let mut x = ((round as u64) << 32) ^ (bytes as u64) ^ 0x9e37_79b9_7f4a_7c15;
    let iters = bytes.clamp(1, 1 << 14);
    for _ in 0..iters {
        x = x.wrapping_mul(FNV_PRIME) ^ (x >> 29);
        h = (h ^ x).wrapping_mul(FNV_PRIME);
    }
    h
}

/// A fixed-size `std::thread` pool fed over channels. Workers pull jobs
/// from a shared receiver and report [`JobDone`] results; [`WorkerPool::drain`]
/// collects exactly the outstanding results and XOR-folds their
/// checksums (order-independent by construction).
pub struct WorkerPool {
    job_tx: Option<Sender<Job>>,
    done_rx: Receiver<JobDone>,
    handles: Vec<JoinHandle<()>>,
    outstanding: usize,
}

impl WorkerPool {
    pub fn new(workers: usize) -> WorkerPool {
        let workers = workers.max(1);
        let (job_tx, job_rx) = channel::<Job>();
        let (done_tx, done_rx) = channel::<JobDone>();
        let shared_rx = Arc::new(Mutex::new(job_rx));
        let mut handles = Vec::with_capacity(workers);
        for _ in 0..workers {
            let rx = Arc::clone(&shared_rx);
            let tx = done_tx.clone();
            handles.push(std::thread::spawn(move || loop {
                let job = {
                    let guard = rx.lock().expect("serve worker rx poisoned");
                    guard.recv()
                };
                let Ok(job) = job else { break };
                let t0 = Instant::now();
                let checksum = match job {
                    Job::GossipWire { round, bytes } => wire_checksum(round, bytes),
                };
                let busy_ns = t0.elapsed().as_nanos();
                if tx.send(JobDone { checksum, busy_ns }).is_err() {
                    break;
                }
            }));
        }
        WorkerPool { job_tx: Some(job_tx), done_rx, handles, outstanding: 0 }
    }

    pub fn workers(&self) -> usize {
        self.handles.len()
    }

    /// Ship a job to the pool.
    pub fn submit(&mut self, job: Job) {
        self.job_tx
            .as_ref()
            .expect("pool already shut down")
            .send(job)
            .expect("serve worker pool hung up");
        self.outstanding += 1;
    }

    /// Block until every submitted job has completed. Returns
    /// `(xor-folded checksum, total busy ns, jobs completed)` for the
    /// jobs drained by *this* call.
    pub fn drain(&mut self) -> (u64, u128, usize) {
        let mut checksum = 0u64;
        let mut busy_ns = 0u128;
        let n = self.outstanding;
        for _ in 0..n {
            let done = self.done_rx.recv().expect("serve worker died mid-drain");
            checksum ^= done.checksum;
            busy_ns += done.busy_ns;
        }
        self.outstanding = 0;
        (checksum, busy_ns, n)
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // Close the job channel so workers observe Err(..) and exit.
        self.job_tx.take();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn heap_pops_in_time_order_with_fifo_ties() {
        let mut h: EventHeap<usize> = EventHeap::new();
        h.push(5.0, 0);
        h.push(1.0, 1);
        h.push(5.0, 2); // same time as item 0, inserted later
        h.push(0.0, 3);
        h.push(2.5, 4);
        let order: Vec<(f64, usize)> = std::iter::from_fn(|| h.pop()).collect();
        assert_eq!(order, vec![(0.0, 3), (1.0, 1), (2.5, 4), (5.0, 0), (5.0, 2)]);
        assert!(h.is_empty());
    }

    #[test]
    fn heap_time_roundtrips_bit_exact() {
        let mut h: EventHeap<()> = EventHeap::new();
        let times = [0.0, 0.1 + 0.2, 123.456789, 1e-12, 9e15];
        for &t in &times {
            h.push(t, ());
        }
        let mut sorted = times;
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for &want in &sorted {
            let (got, ()) = h.pop().unwrap();
            assert_eq!(got.to_bits(), want.to_bits());
        }
    }

    #[test]
    fn wire_checksum_is_pure_and_input_sensitive() {
        assert_eq!(wire_checksum(3, 1024), wire_checksum(3, 1024));
        assert_ne!(wire_checksum(3, 1024), wire_checksum(4, 1024));
        assert_ne!(wire_checksum(3, 1024), wire_checksum(3, 1025));
        // Zero-byte rounds still mix at least once.
        assert_eq!(wire_checksum(0, 0), wire_checksum(0, 0));
    }

    #[test]
    fn pool_checksum_matches_serial_fold_regardless_of_thread_order() {
        let jobs: Vec<(usize, usize)> = (0..64).map(|i| (i, 100 + 37 * i)).collect();
        let mut want = 0u64;
        for &(r, b) in &jobs {
            want ^= wire_checksum(r, b);
        }
        for workers in [1, 4] {
            let mut pool = WorkerPool::new(workers);
            assert_eq!(pool.workers(), workers);
            for &(r, b) in &jobs {
                pool.submit(Job::GossipWire { round: r, bytes: b });
            }
            let (got, _busy, n) = pool.drain();
            assert_eq!(n, jobs.len());
            assert_eq!(got, want, "XOR fold must be order-independent");
            // A second drain with nothing outstanding is a no-op.
            assert_eq!(pool.drain(), (0, 0, 0));
        }
    }

    #[test]
    fn pool_supports_incremental_drains() {
        let mut pool = WorkerPool::new(2);
        pool.submit(Job::GossipWire { round: 1, bytes: 10 });
        let (c1, _, n1) = pool.drain();
        assert_eq!(n1, 1);
        assert_eq!(c1, wire_checksum(1, 10));
        pool.submit(Job::GossipWire { round: 2, bytes: 20 });
        pool.submit(Job::GossipWire { round: 3, bytes: 30 });
        let (c2, _, n2) = pool.drain();
        assert_eq!(n2, 2);
        assert_eq!(c2, wire_checksum(2, 20) ^ wire_checksum(3, 30));
    }
}
