//! Serving-plane observability: per-edge / per-tier latency
//! distributions (p50/p99), queue depth, shed/downgrade/reroute counts,
//! and the gossip-overlap ratio.
//!
//! Two export surfaces:
//!
//! * [`ServeSummary`] — a compact, **worker-count-invariant** digest of
//!   the run that rides inside `RunStats` (so `eaco-rag simulate` /
//!   `serve` can print it next to the tier mix). Only counters whose
//!   values are independent of `serve.workers` belong here: the
//!   determinism suite asserts `RunStats` bit-identity across worker
//!   counts, and queue-shape numbers (latency percentiles, overlap)
//!   legitimately change with the number of virtual servers.
//! * [`ServeMetrics`] — the full picture, returned alongside `RunStats`
//!   by `serve_workload`. Everything in it is deterministic under the
//!   virtual clock except the background wall-time fields
//!   (`bg_wall_busy_ns`), which [`ServeMetrics::digest`] excludes.

use crate::config::ServeConfig;
use crate::corpus::ChunkId;
use crate::sim::TIER_NAMES;
use crate::util::stats::percentile;

use super::queue::AdmissionPolicy;
use super::session::{Session, ShedReason, Stage};

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

fn fnv_fold(h: u64, x: u64) -> u64 {
    let mut h = h;
    for b in x.to_le_bytes() {
        h = (h ^ b as u64).wrapping_mul(FNV_PRIME);
    }
    h
}

/// Worker-invariant serve counters embedded in `RunStats`.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ServeSummary {
    pub admitted: usize,
    pub completed: usize,
    pub shed_overflow: usize,
    pub shed_deadline: usize,
    pub shed_dead_edge: usize,
    pub downgraded: usize,
    pub rerouted: usize,
    pub gossip_rounds: usize,
    pub gossip_background: bool,
}

impl ServeSummary {
    pub fn shed_total(&self) -> usize {
        self.shed_overflow + self.shed_deadline + self.shed_dead_edge
    }

    /// One-line CLI row.
    pub fn row(&self) -> String {
        format!(
            "admitted {} done {} shed {} (overflow {} deadline {} dead-edge {}) downgraded {} rerouted {} gossip-rounds {}{}",
            self.admitted,
            self.completed,
            self.shed_total(),
            self.shed_overflow,
            self.shed_deadline,
            self.shed_dead_edge,
            self.downgraded,
            self.rerouted,
            self.gossip_rounds,
            if self.gossip_background { " (background)" } else { "" },
        )
    }
}

/// Full serving-plane metrics for one `serve_workload` run.
#[derive(Clone, Debug)]
pub struct ServeMetrics {
    pub workers: usize,
    pub queue_cap: usize,
    pub admission: AdmissionPolicy,
    pub gossip_background: bool,
    pub slo_ms: f64,

    pub admitted: usize,
    pub completed: usize,
    pub shed_overflow: usize,
    pub shed_deadline: usize,
    pub shed_dead_edge: usize,
    pub downgraded: usize,
    pub rerouted: usize,

    /// End-to-end latency samples (queue wait + service), ms, in
    /// completion-record order (= event order, deterministic).
    latency_ms: Vec<f64>,
    per_edge_ms: Vec<Vec<f64>>,
    per_tier_ms: [Vec<f64>; 4],
    wait_ms_sum: f64,

    pub peak_depth: usize,
    depth_sum: u64,
    depth_polls: u64,

    pub gossip_rounds: usize,
    pub gossip_completed: usize,
    pub gossip_busy_ms: f64,
    pub gossip_overlap_ms: f64,
    pub gossip_bytes: usize,

    pub bg_jobs: usize,
    pub bg_jobs_done: usize,
    /// XOR-fold of per-round wire checksums (order-independent,
    /// deterministic; part of the digest).
    pub bg_checksum: u64,
    /// Real CPU time burned by the background pool. Wall-clock —
    /// **excluded** from [`ServeMetrics::digest`].
    pub bg_wall_busy_ns: u128,

    /// Sequential FNV-1a fold over every served query's
    /// `(seq, retrieved chunk ids)`. Equal digests mean equal
    /// retrieved-chunk sets per query — asserted unchanged across
    /// background-gossip on/off and across worker counts.
    pub retrieved_digest: u64,

    /// Completed/shed sessions in event order (stage stamps included).
    pub sessions: Vec<Session>,

    /// Measured chaos outcome, attached only when a `[chaos]` scenario
    /// ran. `None` (chaos disabled) leaves the digest untouched, so the
    /// fault-free path stays bit-identical to a build without chaos.
    pub chaos: Option<crate::chaos::ChaosOutcome>,
}

impl ServeMetrics {
    pub fn new(num_edges: usize, cfg: &ServeConfig) -> ServeMetrics {
        ServeMetrics {
            workers: cfg.workers.max(1),
            queue_cap: cfg.queue_cap,
            admission: cfg.admission,
            gossip_background: cfg.gossip_background,
            slo_ms: cfg.slo_ms,
            admitted: 0,
            completed: 0,
            shed_overflow: 0,
            shed_deadline: 0,
            shed_dead_edge: 0,
            downgraded: 0,
            rerouted: 0,
            latency_ms: Vec::new(),
            per_edge_ms: vec![Vec::new(); num_edges],
            per_tier_ms: [Vec::new(), Vec::new(), Vec::new(), Vec::new()],
            wait_ms_sum: 0.0,
            peak_depth: 0,
            depth_sum: 0,
            depth_polls: 0,
            gossip_rounds: 0,
            gossip_completed: 0,
            gossip_busy_ms: 0.0,
            gossip_overlap_ms: 0.0,
            gossip_bytes: 0,
            bg_jobs: 0,
            bg_jobs_done: 0,
            bg_checksum: 0,
            bg_wall_busy_ns: 0,
            retrieved_digest: FNV_OFFSET,
            sessions: Vec::new(),
            chaos: None,
        }
    }

    /// Record the queue depth observed at one arrival.
    pub fn observe_depth(&mut self, depth: usize) {
        self.peak_depth = self.peak_depth.max(depth);
        self.depth_sum += depth as u64;
        self.depth_polls += 1;
    }

    pub fn mean_depth(&self) -> f64 {
        if self.depth_polls == 0 {
            0.0
        } else {
            self.depth_sum as f64 / self.depth_polls as f64
        }
    }

    /// Fold one served query's retrieved-chunk set into the digest.
    pub fn fold_retrieved(&mut self, seq: usize, retrieved: &[ChunkId]) {
        let mut h = fnv_fold(self.retrieved_digest, seq as u64);
        h = fnv_fold(h, retrieved.len() as u64);
        for &cid in retrieved {
            h = fnv_fold(h, cid as u64);
        }
        self.retrieved_digest = h;
    }

    /// Record a completed session.
    pub fn record_done(&mut self, session: Session) {
        debug_assert_eq!(session.stage, Stage::Done);
        let latency = session.latency_ms();
        let wait = session.wait_ms();
        self.completed += 1;
        self.latency_ms.push(latency);
        if let Some(edge) = self.per_edge_ms.get_mut(session.edge_id) {
            edge.push(latency);
        }
        if session.tier < 4 {
            self.per_tier_ms[session.tier].push(latency);
        }
        if wait.is_finite() {
            self.wait_ms_sum += wait;
        }
        self.sessions.push(session);
    }

    /// Record a shed session.
    pub fn record_shed(&mut self, session: Session) {
        debug_assert_eq!(session.stage, Stage::Shed);
        match session.shed {
            Some(ShedReason::QueueFull) => self.shed_overflow += 1,
            Some(ShedReason::Deadline) => self.shed_deadline += 1,
            Some(ShedReason::DeadEdge) => self.shed_dead_edge += 1,
            None => debug_assert!(false, "shed session without reason"),
        }
        self.sessions.push(session);
    }

    pub fn shed_total(&self) -> usize {
        self.shed_overflow + self.shed_deadline + self.shed_dead_edge
    }

    /// Overall latency percentiles `(p50, p99)` in ms; zeros when
    /// nothing completed.
    pub fn latency_p50_p99(&self) -> (f64, f64) {
        Self::p50_p99(&self.latency_ms)
    }

    pub fn edge_p50_p99(&self, edge: usize) -> (f64, f64) {
        Self::p50_p99(self.per_edge_ms.get(edge).map(|v| v.as_slice()).unwrap_or(&[]))
    }

    pub fn tier_p50_p99(&self, tier: usize) -> (f64, f64) {
        Self::p50_p99(&self.per_tier_ms[tier.min(3)])
    }

    fn p50_p99(xs: &[f64]) -> (f64, f64) {
        if xs.is_empty() {
            return (0.0, 0.0); // percentile() returns NaN on empty; callers want zeros
        }
        (percentile(xs, 50.0), percentile(xs, 99.0))
    }

    pub fn mean_wait_ms(&self) -> f64 {
        if self.completed == 0 {
            0.0
        } else {
            self.wait_ms_sum / self.completed as f64
        }
    }

    /// Fraction of gossip busy time that overlapped query service.
    /// Zero when gossip runs in the foreground (service is blocked, so
    /// nothing can overlap) or when no gossip ran.
    pub fn overlap_ratio(&self) -> f64 {
        if self.gossip_busy_ms <= 0.0 {
            0.0
        } else {
            self.gossip_overlap_ms / self.gossip_busy_ms
        }
    }

    /// The worker-invariant summary embedded in `RunStats`.
    pub fn summary(&self) -> ServeSummary {
        ServeSummary {
            admitted: self.admitted,
            completed: self.completed,
            shed_overflow: self.shed_overflow,
            shed_deadline: self.shed_deadline,
            shed_dead_edge: self.shed_dead_edge,
            downgraded: self.downgraded,
            rerouted: self.rerouted,
            gossip_rounds: self.gossip_rounds,
            gossip_background: self.gossip_background,
        }
    }

    /// FNV-1a digest over every deterministic field — counters, latency
    /// sample bit patterns in record order, depth accounting, gossip
    /// timing, the background checksum, and the retrieved-set digest.
    /// Excludes wall-clock observability (`bg_wall_busy_ns`). Two runs
    /// with the same seed and virtual clock must produce equal digests.
    pub fn digest(&self) -> u64 {
        let mut h = FNV_OFFSET;
        for x in [
            self.workers as u64,
            self.queue_cap as u64,
            self.gossip_background as u64,
            self.slo_ms.to_bits(),
            self.admitted as u64,
            self.completed as u64,
            self.shed_overflow as u64,
            self.shed_deadline as u64,
            self.shed_dead_edge as u64,
            self.downgraded as u64,
            self.rerouted as u64,
            self.peak_depth as u64,
            self.depth_sum,
            self.depth_polls,
            self.gossip_rounds as u64,
            self.gossip_completed as u64,
            self.gossip_busy_ms.to_bits(),
            self.gossip_overlap_ms.to_bits(),
            self.gossip_bytes as u64,
            self.bg_jobs as u64,
            self.bg_jobs_done as u64,
            self.bg_checksum,
            self.retrieved_digest,
            self.wait_ms_sum.to_bits(),
        ] {
            h = fnv_fold(h, x);
        }
        for v in &self.latency_ms {
            h = fnv_fold(h, v.to_bits());
        }
        for tier in &self.per_tier_ms {
            h = fnv_fold(h, tier.len() as u64);
        }
        if let Some(c) = &self.chaos {
            h = fnv_fold(h, c.digest());
        }
        h
    }

    /// One-line CLI row: latency shape, shed rate, depth, overlap.
    pub fn row(&self) -> String {
        let (p50, p99) = self.latency_p50_p99();
        let total = self.admitted + self.shed_total();
        let shed_rate = if total == 0 { 0.0 } else { self.shed_total() as f64 / total as f64 };
        format!(
            "workers {} | p50 {:.0} ms p99 {:.0} ms wait {:.1} ms | shed {:.1}% | depth peak {} mean {:.2} | gossip {} rounds {:.0} ms overlap {:.0}%",
            self.workers,
            p50,
            p99,
            self.mean_wait_ms(),
            shed_rate * 100.0,
            self.peak_depth,
            self.mean_depth(),
            self.gossip_rounds,
            self.gossip_busy_ms,
            self.overlap_ratio() * 100.0,
        )
    }

    /// Per-tier latency rows for verbose output.
    pub fn tier_latency_row(&self) -> String {
        let mut parts = Vec::new();
        for (t, name) in TIER_NAMES.iter().enumerate() {
            let n = self.per_tier_ms[t].len();
            if n == 0 {
                continue;
            }
            let (p50, p99) = self.tier_p50_p99(t);
            parts.push(format!("{name} n={n} p50 {p50:.0}/p99 {p99:.0} ms"));
        }
        if parts.is_empty() {
            "no completed queries".to_string()
        } else {
            parts.join(" | ")
        }
    }
}

/// The metrics surface as a pipeline observer: every counter that used
/// to be incremented inline in the serve loop now folds off the typed
/// event stream. Scheduling-model quantities the pipeline cannot know
/// (gossip busy/overlap time, background-pool accounting) stay owned by
/// the serving plane, which writes them directly.
impl crate::pipeline::StageSink for ServeMetrics {
    fn emit(&mut self, ev: &crate::pipeline::StageEvent<'_>) {
        use crate::pipeline::StageEvent as E;
        match ev {
            E::Arrival { depth, .. } => self.observe_depth(*depth),
            E::Admitted { .. } => self.admitted += 1,
            E::Downgraded { .. } => self.downgraded += 1,
            E::Rerouted { .. } => self.rerouted += 1,
            E::SessionShed { session } => self.record_shed((*session).clone()),
            E::GossipRound { wire_bytes, .. } => {
                self.gossip_rounds += 1;
                self.gossip_bytes += *wire_bytes;
            }
            E::QueryDone { seq, outcome, .. } => self.fold_retrieved(*seq, &outcome.retrieved),
            E::SessionDone { session } => self.record_done((*session).clone()),
            E::FaultApplied { .. } | E::TierChosen { .. } | E::RecallProbe { .. } => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ServeConfig {
        ServeConfig::default()
    }

    fn done_session(seq: usize, edge: usize, tier: usize, t_arr: f64, t_start: f64, t_done: f64) -> Session {
        let mut s = Session::new(seq, seq, edge, seq, t_arr);
        assert!(s.advance(Stage::Retrieving, t_start));
        assert!(s.advance(Stage::Gating, t_start));
        assert!(s.advance(Stage::Generating, t_start));
        assert!(s.advance(Stage::Done, t_done));
        s.tier = tier;
        s
    }

    #[test]
    fn percentiles_and_wait_accounting() {
        let mut m = ServeMetrics::new(2, &cfg());
        for i in 0..100usize {
            // Latencies 1..=100 ms, waits all 2 ms, alternate edges/tiers.
            let t0 = i as f64 * 10.0;
            let s = done_session(i, i % 2, 1 + (i % 2), t0, t0 + 2.0, t0 + 2.0 + (i + 1) as f64 - 2.0);
            m.record_done(s);
        }
        let (p50, p99) = m.latency_p50_p99();
        assert!((p50 - 50.5).abs() < 1.0, "p50 {p50}");
        assert!(p99 > 98.0 && p99 <= 100.0, "p99 {p99}");
        assert!((m.mean_wait_ms() - 2.0).abs() < 1e-9);
        assert_eq!(m.completed, 100);
        // Per-edge and per-tier splits each hold half the samples.
        assert_eq!(m.per_edge_ms[0].len() + m.per_edge_ms[1].len(), 100);
        assert_eq!(m.per_tier_ms[1].len(), 50);
        assert_eq!(m.per_tier_ms[2].len(), 50);
        let (tp50, _) = m.tier_p50_p99(1);
        assert!(tp50 > 0.0);
        assert!(m.tier_latency_row().contains("local"));
    }

    #[test]
    fn empty_metrics_are_zero_not_nan() {
        let m = ServeMetrics::new(4, &cfg());
        assert_eq!(m.latency_p50_p99(), (0.0, 0.0));
        assert_eq!(m.edge_p50_p99(0), (0.0, 0.0));
        assert_eq!(m.mean_depth(), 0.0);
        assert_eq!(m.mean_wait_ms(), 0.0);
        assert_eq!(m.overlap_ratio(), 0.0);
        assert_eq!(m.tier_latency_row(), "no completed queries");
        assert!(m.row().contains("p50 0 ms"));
    }

    #[test]
    fn shed_counters_split_by_reason() {
        let mut m = ServeMetrics::new(1, &cfg());
        for (i, reason) in
            [ShedReason::QueueFull, ShedReason::Deadline, ShedReason::Deadline, ShedReason::DeadEdge]
                .iter()
                .enumerate()
        {
            let mut s = Session::new(i, i, 0, i, 0.0);
            assert!(s.mark_shed(*reason, 1.0));
            m.record_shed(s);
        }
        assert_eq!(m.shed_overflow, 1);
        assert_eq!(m.shed_deadline, 2);
        assert_eq!(m.shed_dead_edge, 1);
        assert_eq!(m.shed_total(), 4);
        assert_eq!(m.summary().shed_total(), 4);
        assert!(m.summary().row().contains("deadline 2"));
    }

    #[test]
    fn digest_is_stable_and_sensitive() {
        let build = |latency: f64, fold_extra: bool| {
            let mut m = ServeMetrics::new(1, &cfg());
            m.record_done(done_session(0, 0, 1, 0.0, 0.0, latency));
            m.fold_retrieved(0, &[7, 9]);
            if fold_extra {
                m.fold_retrieved(1, &[11]);
            }
            m
        };
        let a = build(10.0, false);
        let b = build(10.0, false);
        assert_eq!(a.digest(), b.digest());
        assert_eq!(a.retrieved_digest, b.retrieved_digest);
        // Different latency or retrieved set changes the digest.
        assert_ne!(a.digest(), build(11.0, false).digest());
        assert_ne!(a.digest(), build(10.0, true).digest());
        // Wall-time field is excluded.
        let mut c = build(10.0, false);
        c.bg_wall_busy_ns = 123_456_789;
        assert_eq!(a.digest(), c.digest());
    }

    #[test]
    fn overlap_ratio_clamps_to_busy_time() {
        let mut m = ServeMetrics::new(1, &cfg());
        m.gossip_busy_ms = 200.0;
        m.gossip_overlap_ms = 50.0;
        assert!((m.overlap_ratio() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn depth_accounting() {
        let mut m = ServeMetrics::new(1, &cfg());
        for d in [0usize, 3, 1, 5, 1] {
            m.observe_depth(d);
        }
        assert_eq!(m.peak_depth, 5);
        assert!((m.mean_depth() - 2.0).abs() < 1e-12);
    }
}
