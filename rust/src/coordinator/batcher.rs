//! Dynamic batcher: groups generation requests per model tier so the
//! PJRT executor runs the largest exported batch variant instead of
//! per-request forwards (continuous batching at the granularity the
//! AOT artifacts allow: b ∈ {1, 4, 8}).
//!
//! Policy: a tier's queue flushes when it reaches `max_batch` or when a
//! request has waited longer than `max_wait` virtual milliseconds
//! (deadline batching, the vLLM-style latency/throughput knob).

use std::collections::{HashMap, VecDeque};

/// A queued generation request.
#[derive(Clone, Debug)]
pub struct GenRequest {
    pub request_id: usize,
    pub tier: String,
    pub prompt: String,
    pub max_new: usize,
    /// Virtual enqueue timestamp (ms).
    pub enqueued_ms: f64,
}

/// A flushed batch, ready for the PJRT executor.
#[derive(Clone, Debug)]
pub struct GenBatch {
    pub tier: String,
    pub requests: Vec<GenRequest>,
}

/// Per-tier queues with size/deadline flush.
#[derive(Debug)]
pub struct DynamicBatcher {
    pub max_batch: usize,
    pub max_wait_ms: f64,
    queues: Vec<(String, VecDeque<GenRequest>)>,
    /// tier name → slot in `queues`; keeps per-request push O(1) in the
    /// number of tiers (queues are never removed, so slots are stable).
    tier_index: HashMap<String, usize>,
    pub flushed_batches: usize,
    pub flushed_requests: usize,
}

impl DynamicBatcher {
    pub fn new(max_batch: usize, max_wait_ms: f64) -> DynamicBatcher {
        DynamicBatcher {
            max_batch: max_batch.max(1),
            max_wait_ms,
            queues: Vec::new(),
            tier_index: HashMap::new(),
            flushed_batches: 0,
            flushed_requests: 0,
        }
    }

    fn queue_mut(&mut self, tier: &str) -> &mut VecDeque<GenRequest> {
        if let Some(&pos) = self.tier_index.get(tier) {
            &mut self.queues[pos].1
        } else {
            let pos = self.queues.len();
            self.tier_index.insert(tier.to_string(), pos);
            self.queues.push((tier.to_string(), VecDeque::new()));
            &mut self.queues[pos].1
        }
    }

    /// Total queued requests across tiers.
    pub fn pending(&self) -> usize {
        self.queues.iter().map(|(_, q)| q.len()).sum()
    }

    /// Enqueue; returns a batch if the tier hit `max_batch`.
    pub fn push(&mut self, req: GenRequest) -> Option<GenBatch> {
        let max = self.max_batch;
        let q = self.queue_mut(&req.tier);
        let tier = req.tier.clone();
        q.push_back(req);
        if q.len() >= max {
            return self.flush_tier(&tier);
        }
        None
    }

    /// Flush any queue whose head has waited past the deadline at `now`.
    pub fn poll_deadline(&mut self, now_ms: f64) -> Vec<GenBatch> {
        let expired: Vec<String> = self
            .queues
            .iter()
            .filter(|(_, q)| {
                q.front()
                    .map(|r| now_ms - r.enqueued_ms >= self.max_wait_ms)
                    .unwrap_or(false)
            })
            .map(|(t, _)| t.clone())
            .collect();
        expired
            .iter()
            .filter_map(|t| self.flush_tier(t))
            .collect()
    }

    /// Force-flush one tier.
    pub fn flush_tier(&mut self, tier: &str) -> Option<GenBatch> {
        let max = self.max_batch;
        let q = self.queue_mut(tier);
        if q.is_empty() {
            return None;
        }
        let take = q.len().min(max);
        let requests: Vec<GenRequest> = q.drain(..take).collect();
        self.flushed_batches += 1;
        self.flushed_requests += requests.len();
        Some(GenBatch {
            tier: tier.to_string(),
            requests,
        })
    }

    /// Force-flush everything (end of stream).
    pub fn drain(&mut self) -> Vec<GenBatch> {
        let tiers: Vec<String> = self.queues.iter().map(|(t, _)| t.clone()).collect();
        let mut out = Vec::new();
        for t in tiers {
            while let Some(b) = self.flush_tier(&t) {
                out.push(b);
            }
        }
        out
    }

    /// Mean requests per flushed batch (batching efficiency metric).
    pub fn mean_batch_size(&self) -> f64 {
        if self.flushed_batches == 0 {
            0.0
        } else {
            self.flushed_requests as f64 / self.flushed_batches as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: usize, tier: &str, t: f64) -> GenRequest {
        GenRequest {
            request_id: id,
            tier: tier.to_string(),
            prompt: format!("q{id}"),
            max_new: 4,
            enqueued_ms: t,
        }
    }

    #[test]
    fn flushes_at_max_batch() {
        let mut b = DynamicBatcher::new(4, 100.0);
        for i in 0..3 {
            assert!(b.push(req(i, "qwen3b", 0.0)).is_none());
        }
        let batch = b.push(req(3, "qwen3b", 0.0)).expect("flush at 4");
        assert_eq!(batch.requests.len(), 4);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn tiers_batch_independently() {
        let mut b = DynamicBatcher::new(2, 100.0);
        assert!(b.push(req(0, "qwen3b", 0.0)).is_none());
        assert!(b.push(req(1, "qwen72b", 0.0)).is_none());
        let f = b.push(req(2, "qwen3b", 0.0)).expect("3b flushes");
        assert_eq!(f.tier, "qwen3b");
        assert_eq!(b.pending(), 1);
    }

    #[test]
    fn deadline_flush() {
        let mut b = DynamicBatcher::new(8, 50.0);
        b.push(req(0, "qwen3b", 0.0));
        b.push(req(1, "qwen3b", 10.0));
        assert!(b.poll_deadline(40.0).is_empty());
        let batches = b.poll_deadline(55.0);
        assert_eq!(batches.len(), 1);
        assert_eq!(batches[0].requests.len(), 2);
    }

    #[test]
    fn drain_flushes_everything_in_chunks() {
        let mut b = DynamicBatcher::new(4, 1000.0);
        for i in 0..10 {
            b.push(req(i, "qwen3b", 0.0));
        }
        // 10 pushed: two auto-flushes at 4 leave 2 queued.
        assert_eq!(b.pending(), 2);
        let rest = b.drain();
        assert_eq!(rest.len(), 1);
        assert_eq!(rest[0].requests.len(), 2);
        assert_eq!(b.flushed_requests, 10);
    }

    #[test]
    fn preserves_fifo_order() {
        let mut b = DynamicBatcher::new(3, 100.0);
        b.push(req(7, "t", 0.0));
        b.push(req(8, "t", 0.0));
        let batch = b.push(req(9, "t", 0.0)).unwrap();
        let ids: Vec<usize> = batch.requests.iter().map(|r| r.request_id).collect();
        assert_eq!(ids, vec![7, 8, 9]);
    }

    #[test]
    fn many_tiers_route_to_their_own_queues() {
        // The HashMap side index must keep tiers isolated and stable as
        // the tier count grows (push cost is O(1) in #tiers).
        let mut b = DynamicBatcher::new(2, 100.0);
        for i in 0..25 {
            assert!(b.push(req(i, &format!("t{i}"), 0.0)).is_none());
        }
        assert_eq!(b.pending(), 25);
        for i in 0..25 {
            let f = b.push(req(100 + i, &format!("t{i}"), 0.0)).expect("flush at 2");
            assert_eq!(f.tier, format!("t{i}"));
            let ids: Vec<usize> = f.requests.iter().map(|r| r.request_id).collect();
            assert_eq!(ids, vec![i, 100 + i]);
        }
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn mean_batch_size_tracks() {
        let mut b = DynamicBatcher::new(2, 100.0);
        b.push(req(0, "t", 0.0));
        b.push(req(1, "t", 0.0));
        b.push(req(2, "t", 0.0));
        b.drain();
        assert!((b.mean_batch_size() - 1.5).abs() < 1e-9);
    }
}
