//! The serving coordinator: EACO-RAG's L3 request path on real compute.
//!
//! Where [`crate::sim`] replays the paper's experiments under virtual
//! time, this module serves the same pipeline against the **real PJRT
//! runtime**: every generation is an actual batched forward pass of the
//! AOT-compiled transformer artifacts. Layout:
//!
//! * [`batcher`] — dynamic per-tier batching (size + deadline flush).
//! * [`metrics`] — per-request records, latency percentiles, throughput.
//! * [`Coordinator`] — the leader loop: context assembly → SafeOBO gate
//!   → retrieval (edge/cloud stores) → batched generation on a dedicated
//!   executor thread that owns the PJRT client → oracle grading → gate
//!   feedback → adaptive knowledge updates.
//!
//! Python never appears here: the executor thread loads `artifacts/`
//! once and serves from memory.

pub mod batcher;
pub mod metrics;

use std::path::Path;
use std::sync::mpsc;
use std::thread;

use anyhow::{anyhow, Result};

use crate::config::SystemConfig;
use crate::gating::safeobo::SafeObo;
use crate::gating::{Arm, GenLoc, Retrieval};
use crate::netsim::Link;
use crate::pipeline::{build_gate, gated_step, NullSink};
use crate::runtime::{ExecTiming, Runtime};
use crate::serve::queue::{admission_decision, Admission, AdmissionPolicy};
use crate::sim::{KnowledgeMode, SimSystem};
use crate::util::stats::Running;
use crate::workload::Workload;
use batcher::{DynamicBatcher, GenBatch, GenRequest};
use metrics::{Metrics, RequestRecord};

/// A finished generation batch from the executor.
struct ExecResult {
    request_ids: Vec<usize>,
    generated: Vec<Vec<i32>>,
    timing: ExecTiming,
    batch_size: usize,
}

/// The PJRT executor thread: owns the runtime, consumes batches.
struct Executor {
    tx: mpsc::Sender<Option<GenBatch>>,
    rx: mpsc::Receiver<Result<ExecResult>>,
    handle: Option<thread::JoinHandle<()>>,
}

impl Executor {
    fn spawn(artifacts: &Path, preload_tiers: Vec<String>, max_new: usize) -> Result<Executor> {
        let (tx, batch_rx) = mpsc::channel::<Option<GenBatch>>();
        let (result_tx, rx) = mpsc::channel::<Result<ExecResult>>();
        let dir = artifacts.to_path_buf();
        let handle = thread::Builder::new()
            .name("pjrt-executor".into())
            .spawn(move || {
                let mut rt = match Runtime::open(&dir) {
                    Ok(rt) => rt,
                    Err(e) => {
                        let _ = result_tx.send(Err(e));
                        return;
                    }
                };
                // Preload (compile + weight upload) before serving.
                for tier in &preload_tiers {
                    for b in [1usize, 4, 8] {
                        if let Some(a) = rt.manifest.lm_for(tier, b) {
                            let name = a.name.clone();
                            if let Err(e) = rt.load(&name) {
                                let _ = result_tx.send(Err(e));
                                return;
                            }
                        }
                    }
                }
                while let Ok(Some(batch)) = batch_rx.recv() {
                    let prompts: Vec<String> =
                        batch.requests.iter().map(|r| r.prompt.clone()).collect();
                    let ids: Vec<usize> =
                        batch.requests.iter().map(|r| r.request_id).collect();
                    let n = prompts.len();
                    let out = rt
                        .generate(&batch.tier, &prompts, max_new)
                        .map(|(generated, timing)| ExecResult {
                            request_ids: ids,
                            generated,
                            timing,
                            batch_size: n,
                        });
                    if result_tx.send(out).is_err() {
                        return;
                    }
                }
            })
            .map_err(|e| anyhow!("spawning executor: {e}"))?;
        Ok(Executor {
            tx,
            rx,
            handle: Some(handle),
        })
    }

    fn submit(&self, batch: GenBatch) -> Result<()> {
        self.tx
            .send(Some(batch))
            .map_err(|_| anyhow!("executor thread died"))
    }

    fn recv(&self) -> Result<ExecResult> {
        self.rx
            .recv()
            .map_err(|_| anyhow!("executor thread died"))?
    }
}

impl Drop for Executor {
    fn drop(&mut self) {
        let _ = self.tx.send(None);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Pending bookkeeping for an in-flight request.
struct Pending {
    edge_id: usize,
    arm_name: String,
    correct: bool,
    virtual_delay_s: f64,
    in_tokens: f64,
    out_tokens: f64,
    resource_tflops: f64,
    total_cost: f64,
}

/// The serving coordinator (leader).
pub struct Coordinator {
    pub cfg: SystemConfig,
    pub sim: SimSystem,
    pub gate: SafeObo,
    pub batcher: DynamicBatcher,
    pub metrics: Metrics,
    executor: Executor,
    /// Max real tokens decoded per request (each one a real PJRT pass).
    pub gen_tokens: usize,
    /// Requests shed by deadline-aware admission (`[serve]` policy).
    pub shed_deadline: usize,
    /// Requests downgraded to the cheap local arm by admission.
    pub downgraded: usize,
}

impl Coordinator {
    /// Build a coordinator: spins up the PJRT executor thread and
    /// preloads both tiers' artifacts.
    pub fn new(cfg: SystemConfig, artifacts: &Path, gen_tokens: usize) -> Result<Coordinator> {
        let sim = SimSystem::new(cfg.clone(), KnowledgeMode::Adaptive);
        let gate = build_gate(&cfg);
        let executor = Executor::spawn(
            artifacts,
            vec![cfg.edge_tier.clone(), cfg.cloud_tier.clone()],
            gen_tokens,
        )?;
        Ok(Coordinator {
            batcher: DynamicBatcher::new(8, 250.0),
            metrics: Metrics::new(),
            sim,
            gate,
            cfg,
            executor,
            gen_tokens,
            shed_deadline: 0,
            downgraded: 0,
        })
    }

    /// Serve a whole workload: the leader event loop. Returns the number
    /// of requests served.
    pub fn run(&mut self, workload: &Workload) -> Result<usize> {
        let mut now_ms = 0.0f64;
        let mut pending: Vec<Option<Pending>> = Vec::new();
        let mut inflight_batches = 0usize;
        // Deadline-aware admission (`[serve]` knobs): predicted latency
        // = in-flight backlog × mean observed service + monitored
        // access link + one mean service. All jitter-free, so shedding
        // never perturbs the virtual RNG streams of admitted requests.
        let scfg = self.cfg.serve.clone();
        let downgrade_idx = self
            .gate
            .arms
            .iter()
            .position(|a| *a == Arm { retrieval: Retrieval::LocalNaive, gen: GenLoc::EdgeSlm });
        let mut svc_est = Running::new();
        const DEFAULT_SVC_MS: f64 = 500.0;

        for ev in workload.events.clone() {
            now_ms += ev.gap_ms;

            // 0. Admission gate, ahead of any gate/sim work so a shed
            //    request costs nothing downstream.
            let mut downgrade = false;
            if scfg.admission != AdmissionPolicy::None {
                let svc_ms = if svc_est.count() > 0 { svc_est.mean() } else { DEFAULT_SVC_MS };
                let predicted_ms = inflight_batches as f64 * svc_ms
                    + self.sim.net.expected_delay_ms(Link::UserToEdge(ev.edge_id), ev.step)
                    + svc_ms;
                match admission_decision(scfg.admission, predicted_ms, scfg.slo_ms) {
                    Admission::Accept => {}
                    Admission::Shed => {
                        self.shed_deadline += 1;
                        continue;
                    }
                    Admission::Downgrade => {
                        downgrade = true;
                        self.downgraded += 1;
                    }
                }
            }

            // 1–2. Gate decision + retrieval + virtual outcome +
            //      grading + adaptive update, all through the staged
            //      pipeline (same path as `run_eaco`/`serve_workload`).
            let override_idx = if downgrade { downgrade_idx } else { None };
            let r = gated_step(
                &mut self.sim,
                &mut self.gate,
                ev.qa_id,
                ev.edge_id,
                ev.step,
                override_idx,
                &mut NullSink,
            );
            let (outcome, correct) = (r.outcome, r.correct);
            let arm = self.gate.arms[r.arm_idx];
            svc_est.push(outcome.delay_s * 1000.0);

            // 3. Build the real prompt: question + retrieved context.
            let qa = &self.sim.corpus.qa[ev.qa_id];
            let mut prompt = qa.question.clone();
            for &c in outcome.retrieved.iter().take(4) {
                prompt.push(' ');
                prompt.push_str(&self.sim.corpus.chunks[c].text);
            }
            let tier = match arm.gen {
                GenLoc::EdgeSlm => self.cfg.edge_tier.clone(),
                GenLoc::CloudLlm => self.cfg.cloud_tier.clone(),
            };

            let request_id = pending.len();
            pending.push(Some(Pending {
                edge_id: ev.edge_id,
                arm_name: arm.name().to_string(),
                correct,
                virtual_delay_s: outcome.delay_s,
                in_tokens: outcome.tokens.input,
                out_tokens: outcome.tokens.output,
                resource_tflops: outcome.resource_cost,
                total_cost: outcome.total_cost,
            }));

            // 4. Batch + submit.
            if let Some(batch) = self.batcher.push(GenRequest {
                request_id,
                tier,
                prompt,
                max_new: self.gen_tokens,
                enqueued_ms: now_ms,
            }) {
                self.executor.submit(batch)?;
                inflight_batches += 1;
            }
            for batch in self.batcher.poll_deadline(now_ms) {
                self.executor.submit(batch)?;
                inflight_batches += 1;
            }
            // Opportunistically reap finished batches.
            while inflight_batches > 0 {
                match self.try_reap(&mut pending)? {
                    true => inflight_batches -= 1,
                    false => break,
                }
            }
        }

        // 5. Drain.
        for batch in self.batcher.drain() {
            self.executor.submit(batch)?;
            inflight_batches += 1;
        }
        while inflight_batches > 0 {
            self.reap_blocking(&mut pending)?;
            inflight_batches -= 1;
        }
        self.metrics.finish();
        Ok(self.metrics.records.len())
    }

    fn try_reap(&mut self, pending: &mut [Option<Pending>]) -> Result<bool> {
        match self.executor.rx.try_recv() {
            Ok(result) => {
                self.record(result?, pending);
                Ok(true)
            }
            Err(mpsc::TryRecvError::Empty) => Ok(false),
            Err(mpsc::TryRecvError::Disconnected) => Err(anyhow!("executor died")),
        }
    }

    fn reap_blocking(&mut self, pending: &mut [Option<Pending>]) -> Result<()> {
        let result = self.executor.recv()?;
        self.record(result, pending);
        Ok(())
    }

    fn record(&mut self, result: ExecResult, pending: &mut [Option<Pending>]) {
        let per_req_exec_s = (result.timing.execute_us as f64 / 1e6)
            / result.batch_size.max(1) as f64;
        for (i, &rid) in result.request_ids.iter().enumerate() {
            debug_assert!(!result.generated[i].is_empty());
            if let Some(p) = pending[rid].take() {
                self.metrics.push(RequestRecord {
                    request_id: rid,
                    edge_id: p.edge_id,
                    arm: p.arm_name,
                    correct: p.correct,
                    virtual_delay_s: p.virtual_delay_s,
                    real_exec_s: per_req_exec_s,
                    in_tokens: p.in_tokens,
                    out_tokens: p.out_tokens,
                    resource_tflops: p.resource_tflops,
                    total_cost: p.total_cost,
                    batch_size: result.batch_size,
                });
            }
        }
    }
}
