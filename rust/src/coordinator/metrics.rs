//! Serving metrics: per-request records + aggregate report.

use crate::util::json::{num, obj, s, Json};
use crate::util::stats::{percentile, Running};

/// One served request's record.
#[derive(Clone, Debug)]
pub struct RequestRecord {
    pub request_id: usize,
    pub edge_id: usize,
    pub arm: String,
    pub correct: bool,
    /// Virtual end-to-end delay (paper's h_t, seconds).
    pub virtual_delay_s: f64,
    /// Real wall-clock spent in PJRT execution (seconds).
    pub real_exec_s: f64,
    pub in_tokens: f64,
    pub out_tokens: f64,
    pub resource_tflops: f64,
    pub total_cost: f64,
    pub batch_size: usize,
}

/// Aggregate serving metrics.
#[derive(Debug, Default)]
pub struct Metrics {
    pub records: Vec<RequestRecord>,
    pub wall_start: Option<std::time::Instant>,
    pub wall_elapsed_s: f64,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            records: Vec::new(),
            wall_start: Some(std::time::Instant::now()),
            wall_elapsed_s: 0.0,
        }
    }

    pub fn push(&mut self, r: RequestRecord) {
        self.records.push(r);
    }

    pub fn finish(&mut self) {
        if let Some(t0) = self.wall_start {
            self.wall_elapsed_s = t0.elapsed().as_secs_f64();
        }
    }

    pub fn accuracy(&self) -> f64 {
        if self.records.is_empty() {
            return 0.0;
        }
        self.records.iter().filter(|r| r.correct).count() as f64 / self.records.len() as f64
    }

    pub fn throughput_qps(&self) -> f64 {
        if self.wall_elapsed_s == 0.0 {
            0.0
        } else {
            self.records.len() as f64 / self.wall_elapsed_s
        }
    }

    fn series(&self, f: impl Fn(&RequestRecord) -> f64) -> Vec<f64> {
        self.records.iter().map(f).collect()
    }

    pub fn summary(&self) -> String {
        let vd = self.series(|r| r.virtual_delay_s);
        let re = self.series(|r| r.real_exec_s * 1000.0);
        let cost = {
            let mut c = Running::new();
            for r in &self.records {
                c.push(r.resource_tflops);
            }
            c
        };
        format!(
            "requests {}  acc {:.2}%  virt-delay p50 {:.2}s p99 {:.2}s  real-exec p50 {:.1}ms p99 {:.1}ms  cost {:.1}±{:.1} TFLOPs  wall {:.2}s  thpt {:.1} q/s",
            self.records.len(),
            self.accuracy() * 100.0,
            percentile(&vd, 50.0),
            percentile(&vd, 99.0),
            percentile(&re, 50.0),
            percentile(&re, 99.0),
            cost.mean(),
            cost.std(),
            self.wall_elapsed_s,
            self.throughput_qps(),
        )
    }

    /// Arm usage histogram.
    pub fn arm_histogram(&self) -> Vec<(String, usize)> {
        let mut hist: Vec<(String, usize)> = Vec::new();
        for r in &self.records {
            if let Some(e) = hist.iter_mut().find(|(a, _)| *a == r.arm) {
                e.1 += 1;
            } else {
                hist.push((r.arm.clone(), 1));
            }
        }
        hist.sort_by(|a, b| b.1.cmp(&a.1));
        hist
    }

    /// JSON report (for EXPERIMENTS.md appendices / tooling).
    pub fn to_json(&self) -> Json {
        let vd = self.series(|r| r.virtual_delay_s);
        let re = self.series(|r| r.real_exec_s);
        obj(vec![
            ("requests", num(self.records.len() as f64)),
            ("accuracy", num(self.accuracy())),
            ("virtual_delay_p50_s", num(percentile(&vd, 50.0))),
            ("virtual_delay_p99_s", num(percentile(&vd, 99.0))),
            ("real_exec_p50_s", num(percentile(&re, 50.0))),
            ("real_exec_p99_s", num(percentile(&re, 99.0))),
            ("wall_s", num(self.wall_elapsed_s)),
            ("throughput_qps", num(self.throughput_qps())),
            (
                "arms",
                Json::Arr(
                    self.arm_histogram()
                        .into_iter()
                        .map(|(a, n)| obj(vec![("arm", s(&a)), ("count", num(n as f64))]))
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(id: usize, arm: &str, correct: bool) -> RequestRecord {
        RequestRecord {
            request_id: id,
            edge_id: 0,
            arm: arm.to_string(),
            correct,
            virtual_delay_s: 0.5 + id as f64 * 0.1,
            real_exec_s: 0.01,
            in_tokens: 100.0,
            out_tokens: 20.0,
            resource_tflops: 23.0,
            total_cost: 25.0,
            batch_size: 4,
        }
    }

    #[test]
    fn accuracy_and_histogram() {
        let mut m = Metrics::new();
        m.push(rec(0, "local-rag+slm", true));
        m.push(rec(1, "local-rag+slm", false));
        m.push(rec(2, "cloud-graph+llm", true));
        m.finish();
        assert!((m.accuracy() - 2.0 / 3.0).abs() < 1e-9);
        let hist = m.arm_histogram();
        assert_eq!(hist[0].0, "local-rag+slm");
        assert_eq!(hist[0].1, 2);
    }

    #[test]
    fn json_report_parses() {
        let mut m = Metrics::new();
        m.push(rec(0, "a", true));
        m.finish();
        let j = m.to_json().to_string();
        let back = crate::util::json::parse(&j).unwrap();
        assert_eq!(back.get("requests").as_usize(), Some(1));
        assert_eq!(back.get("accuracy").as_f64(), Some(1.0));
    }

    #[test]
    fn empty_metrics_safe() {
        let mut m = Metrics::new();
        m.finish();
        assert_eq!(m.accuracy(), 0.0);
        let _ = m.summary();
    }
}
