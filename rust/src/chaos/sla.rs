//! Declarative SLA assertions over a chaos run's measured outcome, and
//! the machine-readable chaos report.
//!
//! Thresholds come from the `[chaos]` config section (or CLI flags) and
//! use sentinels to mean "unchecked": `sla_recovery_ms <= 0`,
//! `sla_max_staleness < 0`, `sla_min_availability <= 0` each disable
//! their check. The report serializes to JSON via [`crate::util::json`]
//! so CI and the `eaco-rag chaos` subcommand can gate on `pass`.

use crate::config::ChaosConfig;
use crate::util::json::{num, obj, s, Json};

use super::probe::ChaosOutcome;

/// Declarative SLA thresholds; sentinel values disable a check.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SlaSpec {
    /// Worst-case recovery must be ≤ this many ms (≤ 0 = unchecked).
    pub recovery_ms: f64,
    /// Max version lag must be ≤ this many versions (< 0 = unchecked).
    pub max_staleness: i64,
    /// Availability must be ≥ this fraction (≤ 0 = unchecked).
    pub min_availability: f64,
}

impl SlaSpec {
    pub fn from_config(cfg: &ChaosConfig) -> SlaSpec {
        SlaSpec {
            recovery_ms: cfg.sla_recovery_ms,
            max_staleness: cfg.sla_max_staleness,
            min_availability: cfg.sla_min_availability,
        }
    }

    /// Does any check apply at all?
    pub fn any(&self) -> bool {
        self.recovery_ms > 0.0 || self.max_staleness >= 0 || self.min_availability > 0.0
    }
}

/// One evaluated assertion.
#[derive(Clone, Debug, PartialEq)]
pub struct SlaCheck {
    pub name: &'static str,
    pub threshold: f64,
    pub actual: f64,
    pub pass: bool,
}

/// The machine-readable result of a chaos run: the measured outcome
/// plus every SLA verdict.
#[derive(Clone, Debug, PartialEq)]
pub struct ChaosReport {
    pub outcome: ChaosOutcome,
    pub checks: Vec<SlaCheck>,
    pub pass: bool,
}

impl ChaosReport {
    /// Evaluate the configured assertions against a measured outcome.
    /// With no checks configured the report trivially passes (it still
    /// carries the measurements).
    pub fn evaluate(outcome: ChaosOutcome, sla: &SlaSpec) -> ChaosReport {
        let mut checks = Vec::new();
        if sla.recovery_ms > 0.0 {
            // An open (never-closed) recovery window is an SLA failure
            // regardless of threshold; a scenario that revived nothing
            // passes with actual = 0.
            let actual = if outcome.unrecovered > 0 {
                f64::INFINITY
            } else {
                outcome.recovery_ms.unwrap_or(0.0)
            };
            checks.push(SlaCheck {
                name: "recovery_ms",
                threshold: sla.recovery_ms,
                actual,
                pass: actual <= sla.recovery_ms,
            });
        }
        if sla.max_staleness >= 0 {
            let actual = outcome.max_staleness as f64;
            checks.push(SlaCheck {
                name: "max_staleness_versions",
                threshold: sla.max_staleness as f64,
                actual,
                pass: outcome.max_staleness <= sla.max_staleness as u64,
            });
        }
        if sla.min_availability > 0.0 {
            let actual = outcome.availability();
            checks.push(SlaCheck {
                name: "availability",
                threshold: sla.min_availability,
                actual,
                pass: actual >= sla.min_availability,
            });
        }
        let pass = checks.iter().all(|c| c.pass);
        ChaosReport { outcome, checks, pass }
    }

    /// Serialize for CLI/CI consumption. Schema:
    /// `{scenario, pass, outcome: {faults_applied, recoveries,
    /// unrecovered, recovery_ms, max_staleness, max_staleness_partitioned,
    /// completed, shed, rerouted, availability}, sla: [{name, threshold,
    /// actual, pass}, ...]}`. `recovery_ms` is `null` when nothing was
    /// revived; an unrecovered edge reports `"inf"` in its check.
    pub fn to_json(&self) -> Json {
        let o = &self.outcome;
        let recovery = match o.recovery_ms {
            Some(r) => num(r),
            None => Json::Null,
        };
        obj(vec![
            ("scenario", s(&o.scenario)),
            ("pass", Json::Bool(self.pass)),
            (
                "outcome",
                obj(vec![
                    ("faults_applied", num(o.faults_applied as f64)),
                    ("recoveries", num(o.recoveries as f64)),
                    ("unrecovered", num(o.unrecovered as f64)),
                    ("recovery_ms", recovery),
                    ("max_staleness", num(o.max_staleness as f64)),
                    ("max_staleness_partitioned", num(o.max_staleness_partitioned as f64)),
                    ("completed", num(o.completed as f64)),
                    ("shed", num(o.shed as f64)),
                    ("rerouted", num(o.rerouted as f64)),
                    ("availability", num(o.availability())),
                ]),
            ),
            (
                "sla",
                Json::Arr(
                    self.checks
                        .iter()
                        .map(|c| {
                            obj(vec![
                                ("name", s(c.name)),
                                ("threshold", num(c.threshold)),
                                (
                                    "actual",
                                    if c.actual.is_finite() { num(c.actual) } else { s("inf") },
                                ),
                                ("pass", Json::Bool(c.pass)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn outcome() -> ChaosOutcome {
        ChaosOutcome {
            scenario: "split-brain".into(),
            faults_applied: 2,
            recoveries: 1,
            unrecovered: 0,
            recovery_ms: Some(1200.0),
            max_staleness: 1,
            max_staleness_partitioned: 1,
            completed: 95,
            shed: 5,
            rerouted: 3,
        }
    }

    #[test]
    fn unchecked_sla_trivially_passes() {
        let sla = SlaSpec { recovery_ms: 0.0, max_staleness: -1, min_availability: 0.0 };
        assert!(!sla.any());
        let r = ChaosReport::evaluate(outcome(), &sla);
        assert!(r.pass);
        assert!(r.checks.is_empty());
    }

    #[test]
    fn thresholds_gate_each_dimension() {
        let sla = SlaSpec { recovery_ms: 1500.0, max_staleness: 1, min_availability: 0.9 };
        assert!(sla.any());
        let r = ChaosReport::evaluate(outcome(), &sla);
        assert_eq!(r.checks.len(), 3);
        assert!(r.pass, "1200<=1500, 1<=1, 0.95>=0.9 must all pass");
        // Tighten each threshold in turn.
        let tight_r = SlaSpec { recovery_ms: 1000.0, ..sla };
        assert!(!ChaosReport::evaluate(outcome(), &tight_r).pass);
        let tight_s = SlaSpec { max_staleness: 0, ..sla };
        assert!(!ChaosReport::evaluate(outcome(), &tight_s).pass);
        let tight_a = SlaSpec { min_availability: 0.99, ..sla };
        assert!(!ChaosReport::evaluate(outcome(), &tight_a).pass);
    }

    #[test]
    fn unrecovered_edge_fails_recovery_sla() {
        let mut o = outcome();
        o.unrecovered = 1;
        let sla = SlaSpec { recovery_ms: 1e9, max_staleness: -1, min_availability: 0.0 };
        let r = ChaosReport::evaluate(o, &sla);
        assert!(!r.pass, "an open recovery window can never meet the SLA");
        assert_eq!(r.checks[0].actual, f64::INFINITY);
    }

    #[test]
    fn no_revive_scenario_passes_recovery_sla() {
        let mut o = outcome();
        o.recoveries = 0;
        o.recovery_ms = None;
        let sla = SlaSpec { recovery_ms: 100.0, max_staleness: -1, min_availability: 0.0 };
        assert!(ChaosReport::evaluate(o, &sla).pass);
    }

    #[test]
    fn json_schema_round_trips() {
        let sla = SlaSpec { recovery_ms: 1500.0, max_staleness: 1, min_availability: 0.9 };
        let r = ChaosReport::evaluate(outcome(), &sla);
        let j = r.to_json();
        let parsed = crate::util::json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("scenario").as_str(), Some("split-brain"));
        assert_eq!(parsed.get("pass").as_bool(), Some(true));
        let o = parsed.get("outcome");
        assert_eq!(o.get("completed").as_usize(), Some(95));
        assert_eq!(o.get("recovery_ms").as_f64(), Some(1200.0));
        assert!((o.get("availability").as_f64().unwrap() - 0.95).abs() < 1e-12);
        let checks = parsed.get("sla").as_arr().unwrap();
        assert_eq!(checks.len(), 3);
        assert!(checks.iter().all(|c| c.get("pass").as_bool() == Some(true)));
    }
}
