//! Deterministic fault schedules: typed events pinned to virtual-time
//! steps.
//!
//! A [`Scenario`] is nothing but data — a named, step-sorted list of
//! [`ScheduledFault`]s. No RNG is consumed building or applying one, so
//! a scenario perturbs a run only through the fault seams themselves
//! (topology rewires, link multipliers, reachability masks); every
//! admitted query draws the exact same random stream it would have
//! drawn in a fault-free run.
//!
//! Scenarios come from two places: the [`presets`](Scenario::PRESETS)
//! (`rolling-restart`, `split-brain`, `flaky-uplink`, `random`)
//! parameterized by the `[chaos]` config section, or hand-built
//! schedules composed directly from [`FaultEvent`]s in tests and
//! experiments.
//!
//! The `random` preset is the one seeded exception to "no RNG": it
//! draws its schedule from a **dedicated** RNG stream
//! (`Rng::new(random_seed).fork("chaos")`) *before* the serve loop
//! starts, so the schedule is a pure function of the seed and the
//! admitted-query streams still see their fault-free draws.

use crate::config::ChaosConfig;
use crate::util::rng::Rng;

/// Which physical link(s) a degrade/restore event targets.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum LinkSel {
    /// Every edge→cloud uplink.
    AllUplinks,
    /// One edge's edge→cloud uplink.
    Uplink(usize),
    /// One edge's user→edge access link.
    Access(usize),
    /// One symmetric edge↔edge pair link.
    Pair(usize, usize),
}

/// One typed fault. Applying an event is idempotent where the
/// underlying primitive is (kill of a dead edge, revive of an alive
/// edge, heal with no partition are all no-ops).
#[derive(Clone, Debug, PartialEq)]
pub enum FaultEvent {
    /// Machine loss: wipe the edge's store and rewire around it.
    KillEdge(usize),
    /// The edge rejoins empty and cold-syncs via gossip.
    ReviveEdge(usize),
    /// Split the fleet into reachability groups; unlisted edges are
    /// isolated in singleton groups.
    Partition(Vec<Vec<usize>>),
    /// Remove the active partition (if any).
    HealPartition,
    /// Multiply the selected link's latency by `factor` (> 1 degrades).
    DegradeLink { sel: LinkSel, factor: f64 },
    /// Reset the selected link's multiplier to 1.0.
    RestoreLink { sel: LinkSel },
    /// Correlated failure: a rack/zone of edges dies at once.
    CorrelatedFailure(Vec<usize>),
}

/// A fault pinned to the virtual-time step at which it fires. The serve
/// loop maps `at_step` to the arrival time of the first workload event
/// at or after that step and schedules the fault *before* that arrival
/// on the shared `(time, seq)` heap.
#[derive(Clone, Debug, PartialEq)]
pub struct ScheduledFault {
    pub at_step: usize,
    pub event: FaultEvent,
}

/// A named, deterministic fault schedule (sorted by `at_step`, stable —
/// same-step faults apply in schedule order).
#[derive(Clone, Debug, PartialEq)]
pub struct Scenario {
    pub name: String,
    pub schedule: Vec<ScheduledFault>,
}

impl Scenario {
    /// Preset names accepted by the `[chaos] scenario` config key.
    pub const PRESETS: [&'static str; 4] =
        ["rolling-restart", "split-brain", "flaky-uplink", "random"];

    /// Is `name` a known preset?
    pub fn is_known(name: &str) -> bool {
        Self::PRESETS.contains(&name)
    }

    /// Build the preset named by `cfg.scenario`, parameterized by the
    /// `[chaos]` knobs. `None` for an unknown name — config parsing
    /// validates the name up front, so callers holding a parsed config
    /// may expect `Some`.
    pub fn from_config(cfg: &ChaosConfig, num_edges: usize) -> Option<Scenario> {
        match cfg.scenario.as_str() {
            "rolling-restart" => {
                Some(Self::rolling_restart(num_edges, cfg.at_step, cfg.duration_steps))
            }
            "split-brain" => Some(Self::split_brain(num_edges, cfg.at_step, cfg.duration_steps)),
            "flaky-uplink" => {
                Some(Self::flaky_uplink(cfg.at_step, cfg.duration_steps, cfg.degrade_factor))
            }
            "random" => Some(Self::random(
                num_edges,
                cfg.at_step,
                cfg.duration_steps,
                cfg.random_faults,
                cfg.random_seed,
            )),
            _ => None,
        }
    }

    /// Kill and revive each edge in turn, one at a time: edge `e` dies
    /// at `at + e·stagger` and revives at `at + (e+1)·stagger` — its
    /// revive lands at the same step the next edge dies, and the
    /// schedule order (revive generated first) keeps at most one edge
    /// down at any instant.
    pub fn rolling_restart(num_edges: usize, at_step: usize, duration_steps: usize) -> Scenario {
        let n = num_edges.max(1);
        let stagger = (duration_steps / n).max(1);
        let mut schedule = Vec::with_capacity(2 * n);
        for e in 0..n {
            schedule.push(ScheduledFault {
                at_step: at_step + e * stagger,
                event: FaultEvent::ReviveEdge(e),
            });
            schedule.push(ScheduledFault {
                at_step: at_step + e * stagger,
                event: FaultEvent::KillEdge(e),
            });
        }
        // Shift revives one stagger later than their kills. Done here
        // (rather than computed inline) so the kill/revive interleaving
        // above reads in firing order.
        for f in schedule.iter_mut() {
            if matches!(f.event, FaultEvent::ReviveEdge(_)) {
                f.at_step += stagger;
            }
        }
        Scenario { name: "rolling-restart".into(), schedule: sorted(schedule) }
    }

    /// Partition the fleet into two halves at `at_step` and heal at
    /// `at_step + duration_steps`.
    pub fn split_brain(num_edges: usize, at_step: usize, duration_steps: usize) -> Scenario {
        let n = num_edges.max(1);
        let cut = (n + 1) / 2;
        let groups = vec![(0..cut).collect::<Vec<_>>(), (cut..n).collect::<Vec<_>>()];
        let schedule = vec![
            ScheduledFault { at_step, event: FaultEvent::Partition(groups) },
            ScheduledFault {
                at_step: at_step + duration_steps.max(1),
                event: FaultEvent::HealPartition,
            },
        ];
        Scenario { name: "split-brain".into(), schedule }
    }

    /// Degrade every edge→cloud uplink by `factor` at `at_step`,
    /// restore at `at_step + duration_steps`.
    pub fn flaky_uplink(at_step: usize, duration_steps: usize, factor: f64) -> Scenario {
        let schedule = vec![
            ScheduledFault {
                at_step,
                event: FaultEvent::DegradeLink { sel: LinkSel::AllUplinks, factor },
            },
            ScheduledFault {
                at_step: at_step + duration_steps.max(1),
                event: FaultEvent::RestoreLink { sel: LinkSel::AllUplinks },
            },
        ];
        Scenario { name: "flaky-uplink".into(), schedule }
    }

    /// Seeded randomized schedule: `n_faults` events drawn uniformly
    /// over `[at_step, at_step + duration_steps)` from a dedicated RNG
    /// stream. Same seed ⇒ bit-identical schedule. Event kinds are
    /// drawn among kill / revive / partition / heal, biased by a
    /// generation-order fleet model (never kill the last tracked-alive
    /// edge, only partition an unpartitioned fleet); a draw that is
    /// inapplicable in the current model state falls back to reviving a
    /// random edge, which is always idempotent-legal. A cleanup pass at
    /// the window end revives every edge still down and heals any open
    /// partition *in firing order*, so SLA probes measure recovery
    /// rather than a permanently degraded fleet.
    pub fn random(
        num_edges: usize,
        at_step: usize,
        duration_steps: usize,
        n_faults: usize,
        seed: u64,
    ) -> Scenario {
        let n = num_edges.max(1);
        let window = duration_steps.max(1);
        let mut base = Rng::new(seed);
        let mut rng = base.fork("chaos");
        // Generation-order model: biases the draws toward applicable
        // events. Firing order can differ after sorting, but every
        // event is idempotent, and cleanup replays the *sorted*
        // schedule below.
        let mut down = vec![false; n];
        let mut partitioned = false;
        let mut schedule = Vec::with_capacity(n_faults + n + 1);
        for _ in 0..n_faults {
            let step = at_step + rng.below(window);
            let event = match rng.below(4) {
                0 if n >= 2 && down.iter().filter(|d| !**d).count() >= 2 => {
                    let alive: Vec<usize> = (0..n).filter(|&e| !down[e]).collect();
                    let e = alive[rng.below(alive.len())];
                    down[e] = true;
                    FaultEvent::KillEdge(e)
                }
                1 if down.iter().any(|d| *d) => {
                    let dead: Vec<usize> = (0..n).filter(|&e| down[e]).collect();
                    let e = dead[rng.below(dead.len())];
                    down[e] = false;
                    FaultEvent::ReviveEdge(e)
                }
                2 if !partitioned && n >= 2 => {
                    let cut = rng.range(1, n);
                    partitioned = true;
                    FaultEvent::Partition(vec![(0..cut).collect(), (cut..n).collect()])
                }
                3 if partitioned => {
                    partitioned = false;
                    FaultEvent::HealPartition
                }
                _ => {
                    // Inapplicable draw: revive a random edge instead —
                    // always legal (no-op if alive), keeps the schedule
                    // length fixed at `n_faults`.
                    let e = rng.below(n);
                    down[e] = false;
                    FaultEvent::ReviveEdge(e)
                }
            };
            schedule.push(ScheduledFault { at_step: step, event });
        }
        let mut schedule = sorted(schedule);
        // Replay in firing order (which sorting may have changed from
        // generation order) to find what is still broken, then heal it
        // at the window end. Random steps are strictly below `end`, so
        // appending keeps the schedule sorted.
        let mut down = vec![false; n];
        let mut partitioned = false;
        for f in &schedule {
            match &f.event {
                FaultEvent::KillEdge(e) => down[*e] = true,
                FaultEvent::ReviveEdge(e) => down[*e] = false,
                FaultEvent::Partition(_) => partitioned = true,
                FaultEvent::HealPartition => partitioned = false,
                _ => {}
            }
        }
        let end = at_step + window;
        for (e, d) in down.iter().enumerate() {
            if *d {
                schedule.push(ScheduledFault { at_step: end, event: FaultEvent::ReviveEdge(e) });
            }
        }
        if partitioned {
            schedule.push(ScheduledFault { at_step: end, event: FaultEvent::HealPartition });
        }
        Scenario { name: "random".into(), schedule }
    }
}

/// Stable sort by step: same-step faults keep their generation order.
fn sorted(mut schedule: Vec<ScheduledFault>) -> Vec<ScheduledFault> {
    schedule.sort_by_key(|f| f.at_step);
    schedule
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_are_known_and_buildable_from_config() {
        for name in Scenario::PRESETS {
            assert!(Scenario::is_known(name));
            let cfg = ChaosConfig { scenario: name.to_string(), ..ChaosConfig::default() };
            let sc = Scenario::from_config(&cfg, 4).expect("preset builds");
            assert_eq!(sc.name, name);
            assert!(!sc.schedule.is_empty());
        }
        assert!(!Scenario::is_known("nope"));
        let bad = ChaosConfig { scenario: "nope".into(), ..ChaosConfig::default() };
        assert!(Scenario::from_config(&bad, 4).is_none());
    }

    #[test]
    fn schedules_are_step_sorted() {
        for name in Scenario::PRESETS {
            let cfg = ChaosConfig { scenario: name.to_string(), ..ChaosConfig::default() };
            let sc = Scenario::from_config(&cfg, 6).unwrap();
            for w in sc.schedule.windows(2) {
                assert!(w[0].at_step <= w[1].at_step, "{name} schedule out of order");
            }
        }
    }

    #[test]
    fn rolling_restart_downs_at_most_one_edge_at_a_time() {
        let sc = Scenario::rolling_restart(4, 100, 40); // stagger 10
        let mut down: Vec<usize> = Vec::new();
        for f in &sc.schedule {
            match &f.event {
                FaultEvent::KillEdge(e) => {
                    down.push(*e);
                    assert!(down.len() <= 1, "two edges down at step {}", f.at_step);
                }
                FaultEvent::ReviveEdge(e) => {
                    down.retain(|x| x != e);
                }
                other => panic!("unexpected event {other:?}"),
            }
        }
        assert!(down.is_empty(), "an edge was never revived");
        // Every edge cycles exactly once.
        let kills = sc
            .schedule
            .iter()
            .filter(|f| matches!(f.event, FaultEvent::KillEdge(_)))
            .count();
        assert_eq!(kills, 4);
    }

    #[test]
    fn split_brain_halves_then_heals() {
        let sc = Scenario::split_brain(5, 40, 60);
        assert_eq!(sc.schedule.len(), 2);
        let ScheduledFault { at_step, event: FaultEvent::Partition(groups) } = &sc.schedule[0]
        else {
            panic!("first event must be the partition");
        };
        assert_eq!(*at_step, 40);
        assert_eq!(groups[0], vec![0, 1, 2]);
        assert_eq!(groups[1], vec![3, 4]);
        assert_eq!(
            sc.schedule[1],
            ScheduledFault { at_step: 100, event: FaultEvent::HealPartition }
        );
    }

    #[test]
    fn random_schedule_is_seed_deterministic() {
        let a = Scenario::random(4, 40, 60, 8, 7);
        let b = Scenario::random(4, 40, 60, 8, 7);
        assert_eq!(a, b, "same seed must give a bit-identical schedule");
        let c = Scenario::random(4, 40, 60, 8, 8);
        assert_ne!(a.schedule, c.schedule, "different seeds should differ");
    }

    #[test]
    fn random_schedule_heals_everything_by_window_end() {
        for seed in [1u64, 7, 42, 99] {
            let sc = Scenario::random(5, 30, 50, 12, seed);
            let mut down = vec![false; 5];
            let mut partitioned = false;
            for f in &sc.schedule {
                assert!(
                    f.at_step >= 30 && f.at_step <= 80,
                    "fault outside window at step {}",
                    f.at_step
                );
                match &f.event {
                    FaultEvent::KillEdge(e) => down[*e] = true,
                    FaultEvent::ReviveEdge(e) => down[*e] = false,
                    FaultEvent::Partition(_) => partitioned = true,
                    FaultEvent::HealPartition => partitioned = false,
                    other => panic!("unexpected event {other:?}"),
                }
            }
            assert!(down.iter().all(|d| !d), "seed {seed}: edge left dead");
            assert!(!partitioned, "seed {seed}: partition left open");
        }
    }

    #[test]
    fn flaky_uplink_degrades_then_restores() {
        let sc = Scenario::flaky_uplink(10, 20, 6.0);
        assert_eq!(
            sc.schedule[0].event,
            FaultEvent::DegradeLink { sel: LinkSel::AllUplinks, factor: 6.0 }
        );
        assert_eq!(sc.schedule[1], ScheduledFault {
            at_step: 30,
            event: FaultEvent::RestoreLink { sel: LinkSel::AllUplinks },
        });
    }
}
