//! The deterministic fault-injection plane.
//!
//! EACO-RAG's edge tier only pays off if collaborative retrieval
//! survives real edge conditions — node churn, network partitions,
//! degraded links. This subsystem turns those conditions into
//! first-class, *reproducible* simulation inputs and measures whether
//! the gossip/placement/serve stack actually delivers its recovery and
//! staleness bounds:
//!
//! * [`scenario`] — typed fault schedules ([`FaultEvent`]: kill/revive,
//!   partitions, link degradation, correlated failures) pinned to
//!   virtual-time steps; presets `rolling-restart`, `split-brain`,
//!   `flaky-uplink`, and seeded `random` parameterized by the `[chaos]`
//!   config section.
//! * [`injector`] — applies events through the fault seams of
//!   [`crate::netsim`] (per-link multipliers, partition reachability)
//!   and [`crate::cluster`] (group kill/revive, partition-aware
//!   topology rewires that suppress cross-boundary gossip).
//! * [`probe`] — recovery time, version-lag staleness, and availability
//!   measured from arrival-order observations ([`ChaosOutcome`]).
//! * [`sla`] — declarative `recovery_ms <= X` / staleness / availability
//!   assertions producing a machine-readable JSON [`ChaosReport`].
//! * [`trend`] — cross-run SLA trend tracking: `eaco-rag chaos
//!   --append-trend <file>` appends each report to a JSON array and CI
//!   diffs the two newest entries, failing on SLA regressions.
//!
//! The whole plane is RNG-free on the request path: faults change
//! *which* work happens (reroutes, sheds, gossip reach) but never
//! perturb the random streams of admitted queries — and with `[chaos]`
//! disabled, every serve/sim path is bit-identical to a build without
//! this module (asserted in `tests/chaos_determinism.rs`). The `random`
//! scenario draws its schedule from a dedicated seeded stream *before*
//! the serve loop starts, preserving the same guarantee.

pub mod injector;
pub mod probe;
pub mod scenario;
pub mod sla;
pub mod trend;

pub use probe::{ChaosOutcome, ChaosProbe};
pub use scenario::{FaultEvent, LinkSel, Scenario, ScheduledFault};
pub use sla::{ChaosReport, SlaCheck, SlaSpec};
