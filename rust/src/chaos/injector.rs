//! Applies typed fault events through the chaos seams of the knowledge
//! and network planes.
//!
//! One event, two planes, always in agreement: topology/liveness events
//! go through [`EdgeCluster`]'s churn/partition primitives (which
//! rewire the neighbor graph, suppressing gossip and neighbor routing
//! across partition boundaries), and link events go through
//! [`NetSim`]'s per-link fault multipliers (consulted by
//! `delay_ms`/`expected_delay_ms`/`pair_cost_ms`). A `Partition` is the
//! one event that touches both — the cluster confines the knowledge
//! plane and the netsim reports +∞ for cross-group edge↔edge links —
//! so a partitioned peer is simultaneously unroutable and unreachable.
//!
//! Application is RNG-free and idempotent where the primitives are;
//! out-of-range edge ids are ignored (a scenario written for a larger
//! fleet degrades gracefully instead of panicking).

use crate::cluster::EdgeCluster;
use crate::netsim::NetSim;

use super::scenario::{FaultEvent, LinkSel};

/// Apply one fault event to the cluster + network pair.
pub fn apply(event: &FaultEvent, cluster: &mut EdgeCluster, net: &mut NetSim) {
    let n = cluster.num_edges();
    match event {
        FaultEvent::KillEdge(e) => {
            if *e < n {
                cluster.kill_edge(*e);
            }
        }
        FaultEvent::ReviveEdge(e) => {
            if *e < n {
                cluster.revive_edge(*e);
            }
        }
        FaultEvent::Partition(groups) => {
            cluster.apply_partition(groups);
            if let Some(g) = cluster.partition_groups() {
                net.set_partition(g);
            }
        }
        FaultEvent::HealPartition => {
            cluster.heal_partition();
            net.clear_partition();
        }
        FaultEvent::DegradeLink { sel, factor } => set_link(net, sel, *factor),
        FaultEvent::RestoreLink { sel } => set_link(net, sel, 1.0),
        FaultEvent::CorrelatedFailure(set) => cluster.kill_group(set),
    }
}

fn set_link(net: &mut NetSim, sel: &LinkSel, factor: f64) {
    match sel {
        LinkSel::AllUplinks => net.set_uplink_factor(None, factor),
        LinkSel::Uplink(e) => net.set_uplink_factor(Some(*e), factor),
        LinkSel::Access(e) => net.set_access_factor(Some(*e), factor),
        LinkSel::Pair(a, b) => net.set_pair_factor(*a, *b, factor),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;
    use crate::corpus::{Corpus, Profile};
    use crate::netsim::{Link, NetSpec};

    fn world(n: usize) -> (Corpus, EdgeCluster, NetSim) {
        let c = Corpus::generate(Profile::Wiki, 6);
        let net = NetSim::new(n, NetSpec::default(), 7);
        let cl = EdgeCluster::new(
            &ClusterConfig::default(),
            Some(2),
            n,
            200,
            c.spec.topics,
            c.chunks.len(),
            &net,
        );
        (c, cl, net)
    }

    #[test]
    fn kill_and_revive_round_trip() {
        let (_c, mut cl, mut net) = world(4);
        apply(&FaultEvent::KillEdge(1), &mut cl, &mut net);
        assert!(!cl.is_alive(1));
        // Re-kill and out-of-range kill are no-ops.
        apply(&FaultEvent::KillEdge(1), &mut cl, &mut net);
        apply(&FaultEvent::KillEdge(99), &mut cl, &mut net);
        assert_eq!(cl.alive_count(), 3);
        apply(&FaultEvent::ReviveEdge(1), &mut cl, &mut net);
        assert!(cl.is_alive(1));
    }

    #[test]
    fn partition_hits_both_planes_and_heals() {
        let (_c, mut cl, mut net) = world(4);
        apply(
            &FaultEvent::Partition(vec![vec![0, 1], vec![2, 3]]),
            &mut cl,
            &mut net,
        );
        assert!(cl.partitioned());
        assert!(!net.reachable(0, 2));
        assert!(net.reachable(0, 1));
        assert_eq!(net.pair_cost_ms(1, 2), f64::INFINITY);
        for &nb in cl.topology.neighbors(0) {
            assert!(nb < 2, "knowledge plane crossed the partition");
        }
        apply(&FaultEvent::HealPartition, &mut cl, &mut net);
        assert!(!cl.partitioned());
        assert!(net.reachable(0, 2));
        assert!(net.pair_cost_ms(1, 2).is_finite());
    }

    #[test]
    fn degrade_and_restore_scale_uplinks() {
        let (_c, mut cl, mut net) = world(3);
        let base = net.expected_delay_ms(Link::EdgeToCloud(0), 10);
        apply(
            &FaultEvent::DegradeLink { sel: LinkSel::AllUplinks, factor: 5.0 },
            &mut cl,
            &mut net,
        );
        let worse = net.expected_delay_ms(Link::EdgeToCloud(0), 10);
        assert_eq!(worse.to_bits(), (base * 5.0).to_bits());
        apply(&FaultEvent::RestoreLink { sel: LinkSel::AllUplinks }, &mut cl, &mut net);
        assert_eq!(net.expected_delay_ms(Link::EdgeToCloud(0), 10).to_bits(), base.to_bits());
    }

    #[test]
    fn correlated_failure_kills_the_zone() {
        let (_c, mut cl, mut net) = world(5);
        apply(&FaultEvent::CorrelatedFailure(vec![1, 2]), &mut cl, &mut net);
        assert_eq!(cl.alive_count(), 3);
        assert!(!cl.is_alive(1) && !cl.is_alive(2));
    }
}
