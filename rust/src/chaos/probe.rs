//! Recovery / staleness / availability probes sampled by the serve
//! event loop during a chaos run.
//!
//! All probe inputs are **arrival-time** observations (event order, the
//! quantity that is invariant across worker counts and repeats), never
//! dispatch/completion wall positions — so a chaos run's
//! [`ChaosOutcome`] is part of the deterministic digest surface:
//!
//! * **Recovery**: for each revived edge, the time from the revive
//!   event to the arrival of the first query that completes on that
//!   edge with a non-empty (re-synced) store. The worst case across
//!   revives is reported; an edge still empty/unserved at run end
//!   counts as unrecovered.
//! * **Staleness**: [`crate::cluster::EdgeCluster::max_version_lag`]
//!   sampled at every fault application and after every gossip round —
//!   both the run-wide max and the max while a partition was active.
//! * **Availability**: completed / (completed + shed), taken from the
//!   serve counters at run end.
//!
//! The probe is a [`StageSink`]: the serving plane stamps version lag /
//! store state onto the pipeline's typed events (`FaultApplied`,
//! `GossipRound`, `QueryDone`) and the probe folds them — it never
//! touches the cluster itself.

use crate::pipeline::{StageEvent, StageSink};

use super::scenario::FaultEvent;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

fn fnv_fold(h: u64, x: u64) -> u64 {
    let mut h = h;
    for b in x.to_le_bytes() {
        h = (h ^ b as u64).wrapping_mul(FNV_PRIME);
    }
    h
}

/// Live probe state for one serve run.
#[derive(Clone, Debug)]
pub struct ChaosProbe {
    /// Per-edge: virtual time of the pending revive awaiting its first
    /// post-revive served query.
    revive_pending: Vec<Option<f64>>,
    partition_active: bool,
    faults_applied: u64,
    max_staleness: u64,
    max_staleness_partitioned: u64,
    worst_recovery_ms: Option<f64>,
    recoveries: u64,
}

impl ChaosProbe {
    pub fn new(num_edges: usize) -> ChaosProbe {
        ChaosProbe {
            revive_pending: vec![None; num_edges],
            partition_active: false,
            faults_applied: 0,
            max_staleness: 0,
            max_staleness_partitioned: 0,
            worst_recovery_ms: None,
            recoveries: 0,
        }
    }

    /// Record a fault application at virtual time `now_ms`.
    /// `version_lag` is the cluster's max version lag sampled right
    /// after the injector applied the fault.
    pub fn on_fault(&mut self, event: &FaultEvent, now_ms: f64, version_lag: u64) {
        self.faults_applied += 1;
        match event {
            FaultEvent::ReviveEdge(e) => {
                if let Some(p) = self.revive_pending.get_mut(*e) {
                    *p = Some(now_ms);
                }
            }
            FaultEvent::KillEdge(e) => {
                if let Some(p) = self.revive_pending.get_mut(*e) {
                    *p = None;
                }
            }
            FaultEvent::CorrelatedFailure(set) => {
                for e in set {
                    if let Some(p) = self.revive_pending.get_mut(*e) {
                        *p = None;
                    }
                }
            }
            FaultEvent::Partition(_) => self.partition_active = true,
            FaultEvent::HealPartition => self.partition_active = false,
            FaultEvent::DegradeLink { .. } | FaultEvent::RestoreLink { .. } => {}
        }
        self.sample(version_lag);
    }

    /// Sample staleness after a gossip round.
    pub fn on_gossip(&mut self, version_lag: u64) {
        self.sample(version_lag);
    }

    /// Record a completed query: `edge` is the edge it was served on,
    /// `arrival_ms` its arrival time (worker-invariant), `store_empty`
    /// the edge store's post-update state. Closes any pending recovery
    /// window on that edge once its store is non-empty again.
    pub fn on_done(&mut self, edge: usize, arrival_ms: f64, store_empty: bool) {
        let Some(Some(t0)) = self.revive_pending.get(edge).copied() else {
            return;
        };
        if store_empty {
            return; // revived but not yet re-synced: keep waiting
        }
        let r = (arrival_ms - t0).max(0.0);
        self.worst_recovery_ms = Some(match self.worst_recovery_ms {
            Some(w) => w.max(r),
            None => r,
        });
        self.recoveries += 1;
        self.revive_pending[edge] = None;
    }

    fn sample(&mut self, lag: u64) {
        self.max_staleness = self.max_staleness.max(lag);
        if self.partition_active {
            self.max_staleness_partitioned = self.max_staleness_partitioned.max(lag);
        }
    }

    /// Finalize into the run's outcome. `completed`/`shed`/`rerouted`
    /// come from the serve counters.
    pub fn outcome(
        &self,
        scenario: &str,
        completed: usize,
        shed: usize,
        rerouted: usize,
    ) -> ChaosOutcome {
        ChaosOutcome {
            scenario: scenario.to_string(),
            faults_applied: self.faults_applied,
            recoveries: self.recoveries,
            unrecovered: self.revive_pending.iter().filter(|p| p.is_some()).count() as u64,
            recovery_ms: self.worst_recovery_ms,
            max_staleness: self.max_staleness,
            max_staleness_partitioned: self.max_staleness_partitioned,
            completed: completed as u64,
            shed: shed as u64,
            rerouted: rerouted as u64,
        }
    }
}

/// The probe as a pipeline observer: folds the chaos-relevant events
/// the serving plane emits. `GossipRound` events without a sampled lag
/// (synchronous drivers, probe-less runs) are ignored.
impl StageSink for ChaosProbe {
    fn emit(&mut self, ev: &StageEvent<'_>) {
        match ev {
            StageEvent::FaultApplied { event, now_ms, version_lag } => {
                self.on_fault(event, *now_ms, *version_lag)
            }
            StageEvent::GossipRound { version_lag: Some(lag), .. } => self.on_gossip(*lag),
            StageEvent::QueryDone { edge_id, arrival_ms, store_empty, .. } => {
                self.on_done(*edge_id, *arrival_ms, *store_empty)
            }
            _ => {}
        }
    }
}

/// The measured outcome of one chaos run — attached to
/// [`crate::serve::metrics::ServeMetrics`] and folded into its digest
/// (every field here is worker-invariant and bit-reproducible).
#[derive(Clone, Debug, PartialEq)]
pub struct ChaosOutcome {
    pub scenario: String,
    pub faults_applied: u64,
    /// Revive windows closed by a served query from a re-synced store.
    pub recoveries: u64,
    /// Revive windows still open at run end (edge never recovered).
    pub unrecovered: u64,
    /// Worst-case recovery time across closed windows; `None` when the
    /// scenario revived nothing (e.g. pure split-brain).
    pub recovery_ms: Option<f64>,
    /// Max version lag observed anywhere in the run.
    pub max_staleness: u64,
    /// Max version lag observed while a partition was active.
    pub max_staleness_partitioned: u64,
    pub completed: u64,
    pub shed: u64,
    pub rerouted: u64,
}

impl ChaosOutcome {
    /// Fraction of non-overflow demand that was served:
    /// completed / (completed + shed); 1.0 for an empty run.
    pub fn availability(&self) -> f64 {
        let total = self.completed + self.shed;
        if total == 0 {
            1.0
        } else {
            self.completed as f64 / total as f64
        }
    }

    /// Deterministic digest over every field (strings byte-folded,
    /// floats by bit pattern).
    pub fn digest(&self) -> u64 {
        let mut h = FNV_OFFSET;
        for b in self.scenario.as_bytes() {
            h = (h ^ *b as u64).wrapping_mul(FNV_PRIME);
        }
        for x in [
            self.faults_applied,
            self.recoveries,
            self.unrecovered,
            self.recovery_ms.map(|r| r.to_bits()).unwrap_or(u64::MAX),
            self.max_staleness,
            self.max_staleness_partitioned,
            self.completed,
            self.shed,
            self.rerouted,
        ] {
            h = fnv_fold(h, x);
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::EdgeCluster;
    use crate::config::ClusterConfig;
    use crate::corpus::{Corpus, Profile};
    use crate::netsim::{NetSim, NetSpec};

    fn cluster(n: usize) -> (Corpus, EdgeCluster) {
        let c = Corpus::generate(Profile::Wiki, 6);
        let net = NetSim::new(n, NetSpec::default(), 7);
        let cl = EdgeCluster::new(
            &ClusterConfig::default(),
            Some(2),
            n,
            200,
            c.spec.topics,
            c.chunks.len(),
            &net,
        );
        (c, cl)
    }

    #[test]
    fn recovery_window_needs_a_resynced_store() {
        let (c, mut cl) = cluster(3);
        let mut p = ChaosProbe::new(3);
        cl.kill_edge(1);
        p.on_fault(&FaultEvent::KillEdge(1), 100.0, cl.max_version_lag());
        cl.revive_edge(1);
        p.on_fault(&FaultEvent::ReviveEdge(1), 200.0, cl.max_version_lag());
        // Served while still empty: the window stays open.
        p.on_done(1, 250.0, cl.nodes[1].is_empty());
        assert_eq!(p.outcome("t", 0, 0, 0).recoveries, 0);
        assert_eq!(p.outcome("t", 0, 0, 0).unrecovered, 1);
        // Store refills → the next served query closes the window.
        cl.nodes[1].apply_update(&c, &[3, 4]);
        p.on_done(1, 350.0, cl.nodes[1].is_empty());
        let out = p.outcome("t", 10, 2, 1);
        assert_eq!(out.recoveries, 1);
        assert_eq!(out.unrecovered, 0);
        assert_eq!(out.recovery_ms, Some(150.0));
        // A second kill cancels any fantasy of the old window.
        assert_eq!(out.completed, 10);
        assert!((out.availability() - 10.0 / 12.0).abs() < 1e-12);
    }

    #[test]
    fn kill_cancels_pending_recovery() {
        let (_c, mut cl) = cluster(3);
        let mut p = ChaosProbe::new(3);
        cl.kill_edge(2);
        p.on_fault(&FaultEvent::KillEdge(2), 10.0, cl.max_version_lag());
        cl.revive_edge(2);
        p.on_fault(&FaultEvent::ReviveEdge(2), 20.0, cl.max_version_lag());
        cl.kill_edge(2);
        p.on_fault(&FaultEvent::KillEdge(2), 30.0, cl.max_version_lag());
        assert_eq!(p.outcome("t", 0, 0, 0).unrecovered, 0);
        assert_eq!(p.outcome("t", 0, 0, 0).recoveries, 0);
    }

    #[test]
    fn staleness_sampled_during_partition_only_while_active() {
        let (c, mut cl) = cluster(4);
        let mut p = ChaosProbe::new(4);
        // Everyone holds chunk 3; a publication to edge 0 makes the
        // other copies one version stale.
        for e in 1..4 {
            cl.nodes[e].apply_update(&c, &[3]);
        }
        let plan = crate::cloud::UpdatePlan { edge_id: 0, chunks: vec![3], communities: vec![] };
        cl.apply_cloud_update(&c, 0, &plan);
        cl.apply_partition(&[vec![0, 1], vec![2, 3]]);
        p.on_fault(
            &FaultEvent::Partition(vec![vec![0, 1], vec![2, 3]]),
            50.0,
            cl.max_version_lag(),
        );
        let mid = p.outcome("t", 0, 0, 0);
        assert_eq!(mid.max_staleness, 1);
        assert_eq!(mid.max_staleness_partitioned, 1);
        cl.heal_partition();
        p.on_fault(&FaultEvent::HealPartition, 90.0, cl.max_version_lag());
        // Post-heal samples no longer move the partitioned max.
        p.on_gossip(cl.max_version_lag());
        let end = p.outcome("t", 0, 0, 0);
        assert_eq!(end.max_staleness_partitioned, 1);
    }

    #[test]
    fn empty_window_availability_is_one_not_nan() {
        // Zero admitted queries (warm-up-only window, or a scenario
        // that sheds at the queue before the probe sees anything) must
        // not yield 0/0 = NaN — NaN silently passes `>=` SLA checks.
        // Nothing was refused, so the window is fully available.
        let p = ChaosProbe::new(2);
        let out = p.outcome("idle", 0, 0, 0);
        assert!(out.availability().is_finite());
        assert_eq!(out.availability(), 1.0);
        // Shed-only windows still read as a hard zero, not NaN.
        assert_eq!(p.outcome("all-shed", 0, 7, 0).availability(), 0.0);
    }

    #[test]
    fn outcome_digest_is_stable_and_sensitive() {
        let (_c, cl) = cluster(2);
        let mut p = ChaosProbe::new(2);
        p.on_fault(&FaultEvent::HealPartition, 1.0, cl.max_version_lag());
        let a = p.outcome("split-brain", 5, 1, 0);
        let b = p.outcome("split-brain", 5, 1, 0);
        assert_eq!(a, b);
        assert_eq!(a.digest(), b.digest());
        assert_ne!(a.digest(), p.outcome("split-brain", 6, 1, 0).digest());
        assert_ne!(a.digest(), p.outcome("flaky-uplink", 5, 1, 0).digest());
        assert_eq!(ChaosOutcome { recovery_ms: None, ..a.clone() }.availability(), 5.0 / 6.0);
    }
}
