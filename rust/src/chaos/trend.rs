//! Cross-run SLA trend tracking for the chaos plane.
//!
//! `eaco-rag chaos --append-trend <file>` appends each run's
//! [`ChaosReport`] JSON to a trend file holding one JSON array of
//! reports (oldest first). [`append`] does the array surgery and
//! [`regression`] diffs the two newest entries, so CI (`make
//! chaos-trend`) can fail a PR whose chaos run regressed an SLA
//! dimension relative to the previous entry — even when both runs still
//! nominally pass their absolute thresholds.
//!
//! The module is pure string/Json plumbing: file I/O stays in the CLI
//! so these functions are trivially testable and usable from tests
//! without touching the filesystem.

use crate::util::json::{parse, Json};

use super::sla::ChaosReport;

/// Append `report` to the trend document `text` (an empty or
/// whitespace-only `text` starts a fresh array) and return the new
/// serialized document. Errors if `text` is non-empty but does not
/// parse as a JSON array.
pub fn append(text: &str, report: &ChaosReport) -> Result<String, String> {
    let mut entries = if text.trim().is_empty() {
        Vec::new()
    } else {
        match parse(text)? {
            Json::Arr(entries) => entries,
            other => {
                return Err(format!(
                    "trend file must hold a JSON array of chaos reports, found {other:?}"
                ))
            }
        }
    };
    entries.push(report.to_json());
    Ok(Json::Arr(entries).to_string())
}

/// Compare the two newest trend entries; `Some(description)` if the
/// latest run regressed relative to its predecessor, `None` otherwise
/// (including when fewer than two entries exist — a first run cannot
/// regress).
///
/// A regression is any of:
/// * overall `pass` flipped from `true` to `false`;
/// * `availability` dropped;
/// * `max_staleness` grew;
/// * `unrecovered` grew;
/// * `recovery_ms` grew (only when both entries report a numeric
///   recovery — `null`/missing means nothing was revived, which is not
///   comparable).
pub fn regression(entries: &[Json]) -> Option<String> {
    let [.., prev, last] = entries else {
        return None;
    };
    let mut problems = Vec::new();
    if prev.get("pass").as_bool() == Some(true) && last.get("pass").as_bool() == Some(false) {
        problems.push("overall SLA verdict flipped pass -> fail".to_string());
    }
    let po = prev.get("outcome");
    let lo = last.get("outcome");
    if let (Some(a), Some(b)) =
        (po.get("availability").as_f64(), lo.get("availability").as_f64())
    {
        // NaN compares false against everything, so a malformed entry
        // (hand-edited file, or a probe bug reintroducing 0/0) would
        // sail through the `<` check; treat it as a regression instead
        // of a silent pass.
        if a.is_nan() || b.is_nan() {
            problems.push(format!("availability is not a number ({a} -> {b})"));
        } else if b < a - 1e-9 {
            problems.push(format!("availability dropped {a:.4} -> {b:.4}"));
        }
    }
    if let (Some(a), Some(b)) =
        (po.get("max_staleness").as_f64(), lo.get("max_staleness").as_f64())
    {
        if b > a {
            problems.push(format!("max_staleness grew {a} -> {b}"));
        }
    }
    if let (Some(a), Some(b)) = (po.get("unrecovered").as_f64(), lo.get("unrecovered").as_f64()) {
        if b > a {
            problems.push(format!("unrecovered edges grew {a} -> {b}"));
        }
    }
    if let (Some(a), Some(b)) = (po.get("recovery_ms").as_f64(), lo.get("recovery_ms").as_f64()) {
        if b > a + 1e-9 {
            problems.push(format!("recovery_ms grew {a:.1} -> {b:.1}"));
        }
    }
    if problems.is_empty() {
        None
    } else {
        Some(problems.join("; "))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chaos::probe::ChaosOutcome;
    use crate::chaos::sla::SlaSpec;

    fn report(availability_shed: usize, staleness: u64, recovery: f64) -> ChaosReport {
        let outcome = ChaosOutcome {
            scenario: "split-brain".into(),
            faults_applied: 2,
            recoveries: 1,
            unrecovered: 0,
            recovery_ms: Some(recovery),
            max_staleness: staleness,
            max_staleness_partitioned: staleness,
            completed: 100 - availability_shed,
            shed: availability_shed,
            rerouted: 0,
        };
        let sla = SlaSpec { recovery_ms: 5000.0, max_staleness: 8, min_availability: 0.5 };
        ChaosReport::evaluate(outcome, &sla)
    }

    #[test]
    fn append_starts_and_extends_an_array() {
        let one = append("", &report(5, 1, 1200.0)).unwrap();
        let parsed = parse(&one).unwrap();
        assert_eq!(parsed.as_arr().unwrap().len(), 1);
        let two = append(&one, &report(5, 1, 1200.0)).unwrap();
        let parsed = parse(&two).unwrap();
        assert_eq!(parsed.as_arr().unwrap().len(), 2);
        // Entries are full ChaosReport objects.
        assert_eq!(
            parsed.as_arr().unwrap()[1].get("scenario").as_str(),
            Some("split-brain")
        );
        // Garbage input is an error, not a silent reset.
        assert!(append("{\"not\":\"an array\"}", &report(5, 1, 1200.0)).is_err());
        assert!(append("not json", &report(5, 1, 1200.0)).is_err());
    }

    #[test]
    fn identical_entries_are_not_a_regression() {
        let doc = append(&append("", &report(5, 1, 1200.0)).unwrap(), &report(5, 1, 1200.0))
            .unwrap();
        let parsed = parse(&doc).unwrap();
        assert_eq!(regression(parsed.as_arr().unwrap()), None);
    }

    #[test]
    fn single_entry_cannot_regress() {
        let doc = append("", &report(5, 1, 1200.0)).unwrap();
        let parsed = parse(&doc).unwrap();
        assert_eq!(regression(parsed.as_arr().unwrap()), None);
        assert_eq!(regression(&[]), None);
    }

    #[test]
    fn each_dimension_trips_the_diff() {
        let base = report(5, 1, 1200.0);
        for (worse, needle) in [
            (report(30, 1, 1200.0), "availability"),
            (report(5, 3, 1200.0), "max_staleness"),
            (report(5, 1, 2400.0), "recovery_ms"),
        ] {
            let doc = append(&append("", &base).unwrap(), &worse).unwrap();
            let parsed = parse(&doc).unwrap();
            let msg = regression(parsed.as_arr().unwrap())
                .unwrap_or_else(|| panic!("expected a {needle} regression"));
            assert!(msg.contains(needle), "message {msg:?} should mention {needle}");
        }
        // Improvement in the other direction is fine.
        let doc = append(&append("", &report(30, 3, 2400.0)).unwrap(), &base).unwrap();
        let parsed = parse(&doc).unwrap();
        assert_eq!(regression(parsed.as_arr().unwrap()), None);
    }

    #[test]
    fn nan_availability_is_a_regression_not_a_silent_pass() {
        use crate::util::json::{num, obj, s, Json};
        // `ChaosOutcome::availability()` can no longer emit NaN (empty
        // windows report 1.0), so build the entries by hand — the trend
        // file is plain JSON anyone can append to.
        let entry = |avail: Json| {
            obj(vec![
                ("scenario", s("split-brain")),
                ("pass", Json::Bool(true)),
                ("outcome", obj(vec![("availability", avail)])),
            ])
        };
        let good = entry(num(0.95));
        let bad = entry(num(f64::NAN));
        // NaN on the latest side: flagged, never a quiet pass.
        let msg = regression(&[good.clone(), bad.clone()]).expect("NaN must regress");
        assert!(msg.contains("availability"), "got {msg:?}");
        // NaN on the previous side too — a drop *from* NaN is equally
        // uncomparable and must not look like an improvement.
        let msg = regression(&[bad, good.clone()]).expect("NaN must regress");
        assert!(msg.contains("not a number"), "got {msg:?}");
        // Sanity: two well-formed equal entries still pass.
        assert_eq!(regression(&[good.clone(), good]), None);
    }

    #[test]
    fn pass_to_fail_is_flagged_even_with_equal_metrics() {
        // Tighter SLA on the second run flips pass with similar outcome
        // numbers: the verdict flip alone must be flagged.
        let good = report(5, 1, 1200.0);
        let outcome = ChaosOutcome {
            scenario: "split-brain".into(),
            faults_applied: 2,
            recoveries: 1,
            unrecovered: 1,
            recovery_ms: None,
            max_staleness: 1,
            max_staleness_partitioned: 1,
            completed: 95,
            shed: 5,
            rerouted: 0,
        };
        let sla = SlaSpec { recovery_ms: 5000.0, max_staleness: 8, min_availability: 0.5 };
        let bad = ChaosReport::evaluate(outcome, &sla);
        assert!(good.pass && !bad.pass);
        let doc = append(&append("", &good).unwrap(), &bad).unwrap();
        let parsed = parse(&doc).unwrap();
        let msg = regression(parsed.as_arr().unwrap()).expect("regression");
        assert!(msg.contains("pass -> fail"));
        assert!(msg.contains("unrecovered"));
    }
}
