//! GraphRAG substrate (paper §3.2): entity graph, communities, search.
//!
//! The paper's cloud tier runs Microsoft-style GraphRAG: "nodes represent
//! discrete knowledge units, edges capture relationships, and communities
//! group semantically related concepts". We reproduce the structure the
//! paper relies on:
//!
//! * **Graph build** — nodes are corpus entities; an edge connects two
//!   entities co-mentioned by a fact, weighted by co-mention count.
//! * **Community detection** — deterministic label propagation (a
//!   lightweight stand-in for Leiden): every node adopts the most common
//!   label among weighted neighbours, smallest-label tie-break, iterated
//!   to a fixed point.
//! * **Local search** — query entities → their communities → member
//!   chunks ranked by keyword hits. Multi-hop friendly: intra-community
//!   chunks cover fact chains even when the query only names the head
//!   entity.
//! * **Global search** — community summaries ranked against the query
//!   (the expensive, token-heavy path that drives Table 1's ~9k input
//!   tokens).
//! * **Top-k community extraction** — the adaptive-update feed: given
//!   recent query keywords, return the communities with the most keyword
//!   matches plus their chunks (paper §5: "top-k communities containing
//!   the highest number of similar keywords or nodes").

use std::collections::HashMap;

use crate::corpus::{ChunkId, Corpus, EntityId};
use crate::index::normalize;

/// A detected community.
#[derive(Clone, Debug)]
pub struct Community {
    pub id: usize,
    pub entities: Vec<EntityId>,
    pub chunks: Vec<ChunkId>,
    /// Summary keyword set (entity names), the "community report".
    pub keywords: Vec<String>,
}

/// The knowledge graph over a corpus.
pub struct GraphRag {
    /// adjacency: entity -> (entity, weight)
    pub adj: Vec<Vec<(EntityId, f64)>>,
    /// entity -> community index (into `communities`)
    pub membership: Vec<usize>,
    pub communities: Vec<Community>,
    /// normalized keyword -> entity ids with that name
    keyword_entities: HashMap<String, Vec<EntityId>>,
}

impl GraphRag {
    /// Build the graph + communities from a corpus.
    pub fn build(corpus: &Corpus) -> GraphRag {
        let n = corpus.entities.len();
        let mut weights: HashMap<(EntityId, EntityId), f64> = HashMap::new();
        for f in &corpus.facts {
            let (a, b) = if f.subject < f.object {
                (f.subject, f.object)
            } else {
                (f.object, f.subject)
            };
            *weights.entry((a, b)).or_insert(0.0) += 1.0;
        }
        let mut adj: Vec<Vec<(EntityId, f64)>> = vec![Vec::new(); n];
        for (&(a, b), &w) in &weights {
            adj[a].push((b, w));
            adj[b].push((a, w));
        }
        for l in adj.iter_mut() {
            l.sort_by_key(|&(e, _)| e); // determinism
        }

        let labels = label_propagation(&adj, 20);

        // Assemble communities (ordered by label for determinism) and
        // remap membership to community indices.
        let mut by_label: HashMap<usize, Vec<EntityId>> = HashMap::new();
        for (e, &label) in labels.iter().enumerate() {
            by_label.entry(label).or_default().push(e);
        }
        let mut label_list: Vec<usize> = by_label.keys().copied().collect();
        label_list.sort_unstable();

        let mut communities: Vec<Community> = label_list
            .iter()
            .enumerate()
            .map(|(cid, &label)| {
                let entities = by_label[&label].clone();
                let keywords = entities
                    .iter()
                    .map(|&e| corpus.entities[e].name.clone())
                    .collect();
                Community {
                    id: cid,
                    entities,
                    chunks: Vec::new(),
                    keywords,
                }
            })
            .collect();
        let label_to_cid: HashMap<usize, usize> = label_list
            .iter()
            .enumerate()
            .map(|(cid, &label)| (label, cid))
            .collect();
        let membership: Vec<usize> = labels.iter().map(|l| label_to_cid[l]).collect();

        // A chunk joins every community containing one of its fact
        // entities (chunks can bridge communities).
        for ch in &corpus.chunks {
            let mut seen = Vec::new();
            for &fid in &ch.facts {
                let f = &corpus.facts[fid];
                for e in [f.subject, f.object] {
                    let cid = membership[e];
                    if !seen.contains(&cid) {
                        seen.push(cid);
                        communities[cid].chunks.push(ch.id);
                    }
                }
            }
        }

        let mut keyword_entities: HashMap<String, Vec<EntityId>> = HashMap::new();
        for e in &corpus.entities {
            keyword_entities
                .entry(normalize(&e.name))
                .or_default()
                .push(e.id);
        }

        GraphRag {
            adj,
            membership,
            communities,
            keyword_entities,
        }
    }

    /// Entities matching a keyword (exact normalized match).
    pub fn entities_for_keyword(&self, kw: &str) -> &[EntityId] {
        self.keyword_entities
            .get(&normalize(kw))
            .map(|v| v.as_slice())
            .unwrap_or(&[])
    }

    /// Community index of an entity.
    pub fn community_of(&self, e: EntityId) -> usize {
        self.membership[e]
    }

    /// **Local search**: query keywords → communities → member chunks
    /// ranked by (distinct query keyword hits, then chunk id). Returns
    /// (chunk id, score). This is the retrieval the cloud serves for the
    /// gate's `CloudGraph` arm.
    pub fn local_search(
        &self,
        corpus: &Corpus,
        query_keywords: &[&str],
        k: usize,
    ) -> Vec<(ChunkId, usize)> {
        let mut comm_hit: Vec<usize> = Vec::new();
        for kw in query_keywords {
            for &e in self.entities_for_keyword(kw) {
                let cid = self.community_of(e);
                if !comm_hit.contains(&cid) {
                    comm_hit.push(cid);
                }
            }
        }
        let mut scores: HashMap<ChunkId, usize> = HashMap::new();
        let norm_kws: Vec<String> = query_keywords.iter().map(|k| normalize(k)).collect();
        for &cid in &comm_hit {
            for &ch in &self.communities[cid].chunks {
                let chunk = &corpus.chunks[ch];
                let hits = chunk
                    .keywords
                    .iter()
                    .filter(|kw| norm_kws.contains(&normalize(kw)))
                    .count();
                // Community membership grants a base score of 1 so fact
                // chains surface even without direct keyword overlap.
                let entry = scores.entry(ch).or_insert(0);
                *entry = (*entry).max(hits.max(1));
            }
        }
        let mut ranked: Vec<(ChunkId, usize)> = scores.into_iter().collect();
        ranked.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        ranked.truncate(k);
        ranked
    }

    /// **Global search** context size: GraphRAG's map-reduce over
    /// community reports consumes tokens proportional to the number of
    /// communities scanned; returns the char volume of summaries read.
    /// This is what makes the cloud path token-heavy (Table 1).
    pub fn global_search_context_chars(&self) -> usize {
        // Community reports are verbose: a header, one described line per
        // entity (~name + 32 chars), and a reference per member chunk.
        self.communities
            .iter()
            .map(|c| {
                128 + c
                    .keywords
                    .iter()
                    .map(|k| k.len() + 32)
                    .sum::<usize>()
                    + 8 * c.chunks.len()
            })
            .sum()
    }

    /// **Top-k community extraction** for adaptive updates (paper §5):
    /// rank communities by the number of query keywords matching their
    /// entity names; return community ids, best first.
    pub fn top_communities(&self, query_keywords: &[&str], k: usize) -> Vec<usize> {
        let norm_kws: Vec<String> = query_keywords.iter().map(|q| normalize(q)).collect();
        let mut scored: Vec<(usize, usize)> = self
            .communities
            .iter()
            .map(|c| {
                let hits = c
                    .keywords
                    .iter()
                    .filter(|kw| norm_kws.contains(&normalize(kw)))
                    .count();
                (c.id, hits)
            })
            .collect();
        scored.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        scored.into_iter().take(k).map(|(id, _)| id).collect()
    }
}

/// Deterministic synchronous label propagation.
fn label_propagation(adj: &[Vec<(EntityId, f64)>], max_iters: usize) -> Vec<usize> {
    let n = adj.len();
    let mut labels: Vec<usize> = (0..n).collect();
    for _ in 0..max_iters {
        let mut changed = false;
        let snapshot = labels.clone();
        for v in 0..n {
            if adj[v].is_empty() {
                continue;
            }
            let mut tally: HashMap<usize, f64> = HashMap::new();
            for &(u, w) in &adj[v] {
                *tally.entry(snapshot[u]).or_insert(0.0) += w;
            }
            let mut entries: Vec<(usize, f64)> = tally.into_iter().collect();
            // Highest weight wins; smallest label breaks ties.
            entries.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
            if let Some(&(label, _)) = entries.first() {
                if label != labels[v] {
                    labels[v] = label;
                    changed = true;
                }
            }
        }
        if !changed {
            break;
        }
    }
    labels
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::Profile;

    fn graph() -> (Corpus, GraphRag) {
        let c = Corpus::generate(Profile::HarryPotter, 3);
        let g = GraphRag::build(&c);
        (c, g)
    }

    #[test]
    fn communities_partition_entities() {
        let (c, g) = graph();
        let total: usize = g.communities.iter().map(|cm| cm.entities.len()).sum();
        assert_eq!(total, c.entities.len());
        assert!(g.communities.len() > 1, "expected multiple communities");
        assert!(
            g.communities.len() < c.entities.len(),
            "labels should coalesce"
        );
    }

    #[test]
    fn membership_consistent_with_communities() {
        let (c, g) = graph();
        for e in 0..c.entities.len() {
            let cid = g.community_of(e);
            assert!(g.communities[cid].entities.contains(&e));
        }
    }

    #[test]
    fn communities_group_related_entities() {
        let (c, g) = graph();
        let mut internal = 0usize;
        let mut external = 0usize;
        for f in &c.facts {
            if g.membership[f.subject] == g.membership[f.object] {
                internal += 1;
            } else {
                external += 1;
            }
        }
        assert!(
            internal > external,
            "internal {internal} <= external {external}"
        );
    }

    #[test]
    fn local_search_finds_supporting_chunks() {
        let (c, g) = graph();
        let mut found = 0;
        let sample: Vec<_> = c.qa.iter().take(100).collect();
        for qa in &sample {
            let kws = c.qa_keywords(qa);
            let hits = g.local_search(&c, &kws, 8);
            if qa
                .supporting_chunks
                .iter()
                .any(|sc| hits.iter().any(|&(ch, _)| ch == *sc))
            {
                found += 1;
            }
        }
        // GraphRAG should retrieve support for the large majority.
        assert!(found >= 75, "found {found}/100");
    }

    #[test]
    fn local_search_deterministic_and_bounded() {
        let (c, g) = graph();
        let kws = c.qa_keywords(&c.qa[0]);
        let a = g.local_search(&c, &kws, 5);
        let b = g.local_search(&c, &kws, 5);
        assert_eq!(a, b);
        assert!(a.len() <= 5);
    }

    #[test]
    fn top_communities_match_keywords() {
        let (c, g) = graph();
        let qa = &c.qa[10];
        let kws = c.qa_keywords(qa);
        let top = g.top_communities(&kws, 3);
        assert!(!top.is_empty());
        let best = &g.communities[top[0]];
        assert!(
            qa.entities.iter().any(|e| best.entities.contains(e)),
            "top community misses all query entities"
        );
    }

    #[test]
    fn global_context_is_large() {
        let (_, g) = graph();
        assert!(g.global_search_context_chars() > 2000);
    }

    #[test]
    fn entities_for_keyword_normalized() {
        let (c, g) = graph();
        let name = &c.entities[0].name;
        assert!(!g.entities_for_keyword(&name.to_lowercase()).is_empty());
        assert!(!g.entities_for_keyword(&name.to_uppercase()).is_empty());
    }

    #[test]
    fn build_deterministic() {
        let c = Corpus::generate(Profile::Wiki, 4);
        let g1 = GraphRag::build(&c);
        let g2 = GraphRag::build(&c);
        assert_eq!(g1.membership, g2.membership);
        assert_eq!(g1.communities.len(), g2.communities.len());
    }
}
