//! Synthetic corpora + QA datasets (paper §6.1 substitute).
//!
//! The paper evaluates on (a) **Wiki QA** — 139 popular Wikipedia pages
//! from Natural Questions + TriviaQA/HotpotQA pairs, 571 QA total — and
//! (b) **Harry Potter QA** — 1,180 pairs over the seven books. Neither
//! corpus is available offline, so this module synthesizes statistical
//! stand-ins (DESIGN.md §1): topic/entity/fact graphs whose *retrieval
//! geometry* (topic skew, entity overlap, hop structure, chunk coverage)
//! drives every downstream mechanism — keyword indexing, GraphRAG
//! communities, adaptive edge updates, and the answer oracle.
//!
//! Ground truth is mechanical: a QA pair is answerable from a context iff
//! the context contains its supporting chunks. That is exactly the
//! property RAG accuracy depends on, so every accuracy trend the paper
//! reports emerges from the mechanism rather than being hard-coded.

use crate::util::rng::Rng;

pub type EntityId = usize;
pub type FactId = usize;
pub type ChunkId = usize;
pub type TopicId = usize;
pub type QaId = usize;

/// Which paper dataset the corpus emulates.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Profile {
    /// General-domain: broad, shallow, mostly single-hop (Wiki QA).
    Wiki,
    /// Specialized: narrow, entity-dense, more multi-hop (Harry Potter QA).
    HarryPotter,
}

impl Profile {
    pub fn name(&self) -> &'static str {
        match self {
            Profile::Wiki => "wiki",
            Profile::HarryPotter => "hp",
        }
    }

    pub fn parse(s: &str) -> Option<Profile> {
        match s {
            "wiki" => Some(Profile::Wiki),
            "hp" | "harrypotter" => Some(Profile::HarryPotter),
            _ => None,
        }
    }
}

/// Generation parameters for one profile.
#[derive(Clone, Debug)]
pub struct CorpusSpec {
    pub profile: Profile,
    pub topics: usize,          // thematic groups (wiki: page clusters; hp: books)
    pub pages: usize,           // documents (paper: 139 pages / 7 books)
    pub entities_per_topic: usize,
    pub facts_per_page: usize,
    pub chunks_per_page: usize,
    pub qa_pairs: usize,        // paper: 571 / 1,180
    pub multi_hop_share: f64,   // share of 2–3 hop questions
    pub topic_zipf: f64,        // base popularity skew across topics
    pub cross_topic_entity_share: f64, // entities mentioned outside home topic
    pub seed_label: &'static str,
}

impl CorpusSpec {
    pub fn for_profile(profile: Profile) -> CorpusSpec {
        match profile {
            Profile::Wiki => CorpusSpec {
                profile,
                topics: 20,
                pages: 139,
                entities_per_topic: 14,
                facts_per_page: 12,
                chunks_per_page: 8,
                qa_pairs: 571,
                multi_hop_share: 0.25,
                topic_zipf: 0.9,
                cross_topic_entity_share: 0.10,
                seed_label: "corpus-wiki",
            },
            Profile::HarryPotter => CorpusSpec {
                profile,
                topics: 7, // the seven books
                pages: 7 * 24,
                entities_per_topic: 30,
                facts_per_page: 14,
                chunks_per_page: 9,
                qa_pairs: 1180,
                multi_hop_share: 0.45,
                topic_zipf: 0.6,
                cross_topic_entity_share: 0.30, // recurring characters span books
                seed_label: "corpus-hp",
            },
        }
    }
}

/// A named entity (person/place/spell/...).
#[derive(Clone, Debug)]
pub struct Entity {
    pub id: EntityId,
    pub name: String,
    pub topic: TopicId,
}

/// A (subject, relation, object) fact; the atomic knowledge unit.
#[derive(Clone, Debug)]
pub struct Fact {
    pub id: FactId,
    pub subject: EntityId,
    pub relation: String,
    pub object: EntityId,
    pub topic: TopicId,
    pub page: usize,
}

/// A retrievable text chunk holding one or more facts.
#[derive(Clone, Debug)]
pub struct Chunk {
    pub id: ChunkId,
    pub topic: TopicId,
    pub page: usize,
    pub text: String,
    pub facts: Vec<FactId>,
    /// Keyword set: entity names + relation words. This is what the
    /// inverted index and the edge overlap-ratio computations consume.
    pub keywords: Vec<String>,
}

/// A question/answer pair with mechanical ground truth.
#[derive(Clone, Debug)]
pub struct QaPair {
    pub id: QaId,
    pub question: String,
    pub answer: String,
    /// Reasoning depth: 1 = single-hop, 2–3 = multi-hop chains.
    pub hops: usize,
    pub entities: Vec<EntityId>,
    pub supporting_facts: Vec<FactId>,
    /// Chunks that (together) contain all supporting facts.
    pub supporting_chunks: Vec<ChunkId>,
    pub topic: TopicId,
    /// Approximate token length of the question (context feature q_t).
    pub length_tokens: usize,
}

/// The synthesized corpus.
#[derive(Clone, Debug)]
pub struct Corpus {
    pub spec: CorpusSpec,
    pub entities: Vec<Entity>,
    pub facts: Vec<Fact>,
    pub chunks: Vec<Chunk>,
    pub qa: Vec<QaPair>,
    /// Base topic popularity (zipf-ranked), used by `workload`.
    pub topic_popularity: Vec<f64>,
}

// ---------------------------------------------------------------------------
// name synthesis
// ---------------------------------------------------------------------------

const SYLLABLES: &[&str] = &[
    "al", "ba", "cor", "da", "el", "fen", "gor", "ha", "il", "jor", "ka", "lu",
    "mor", "na", "or", "pra", "qui", "ra", "sol", "tur", "ul", "vor", "wen", "xan",
    "yor", "zel",
];

const RELATIONS: &[&str] = &[
    "founded", "defeated", "married", "invented", "discovered", "rules",
    "teaches", "guards", "wrote", "owns", "located_in", "allied_with",
    "succeeded", "created", "betrayed", "mentored",
];

fn synth_name(rng: &mut Rng, syllables: usize) -> String {
    let mut s = String::new();
    for _ in 0..syllables {
        s.push_str(SYLLABLES[rng.below(SYLLABLES.len())]);
    }
    // Capitalize to look like a proper noun.
    let mut c = s.chars();
    match c.next() {
        Some(f) => f.to_uppercase().collect::<String>() + c.as_str(),
        None => s,
    }
}

// ---------------------------------------------------------------------------
// generation
// ---------------------------------------------------------------------------

impl Corpus {
    /// Deterministically synthesize a corpus for a profile.
    pub fn generate(profile: Profile, seed: u64) -> Corpus {
        let spec = CorpusSpec::for_profile(profile);
        let mut rng = Rng::new(seed).fork(spec.seed_label);

        // --- entities, grouped by topic, with unique names ---
        let mut entities = Vec::new();
        let mut used = std::collections::HashSet::new();
        for t in 0..spec.topics {
            for _ in 0..spec.entities_per_topic {
                let mut name;
                loop {
                    let syl = 2 + rng.below(2);
                    name = synth_name(&mut rng, syl);
                    if used.insert(name.clone()) {
                        break;
                    }
                }
                entities.push(Entity {
                    id: entities.len(),
                    name,
                    topic: t,
                });
            }
        }

        // Entities available to each topic: home entities + a few borrowed
        // cross-topic ones (recurring characters / shared concepts).
        let per_topic_pool: Vec<Vec<EntityId>> = (0..spec.topics)
            .map(|t| {
                let mut pool: Vec<EntityId> = entities
                    .iter()
                    .filter(|e| e.topic == t)
                    .map(|e| e.id)
                    .collect();
                let borrow =
                    (spec.entities_per_topic as f64 * spec.cross_topic_entity_share) as usize;
                for _ in 0..borrow {
                    pool.push(rng.below(entities.len()));
                }
                pool
            })
            .collect();

        // --- facts & pages ---
        // Pages are spread over topics round-robin so each topic has
        // pages/topics documents.
        let mut facts: Vec<Fact> = Vec::new();
        for page in 0..spec.pages {
            let topic = page % spec.topics;
            let pool = &per_topic_pool[topic];
            for _ in 0..spec.facts_per_page {
                let subject = *rng.choose(pool);
                let mut object = *rng.choose(pool);
                while object == subject {
                    object = *rng.choose(pool);
                }
                facts.push(Fact {
                    id: facts.len(),
                    subject,
                    relation: RELATIONS[rng.below(RELATIONS.len())].to_string(),
                    object,
                    topic,
                    page,
                });
            }
        }

        // --- chunks: partition each page's facts, 1–3 facts per chunk ---
        let mut chunks: Vec<Chunk> = Vec::new();
        for page in 0..spec.pages {
            let topic = page % spec.topics;
            let page_facts: Vec<FactId> = facts
                .iter()
                .filter(|f| f.page == page)
                .map(|f| f.id)
                .collect();
            // Partition *all* page facts into chunks (1–3 facts each) so
            // every fact is retrievable; `chunks_per_page` is the expected
            // count (facts_per_page / 2), not a hard cap.
            let mut cursor = 0;
            while cursor < page_facts.len() {
                let take = (1 + rng.below(3)).min(page_facts.len() - cursor);
                let fids: Vec<FactId> = page_facts[cursor..cursor + take].to_vec();
                cursor += take;
                let (text, keywords) = render_chunk(&entities, &facts, &fids, &mut rng);
                chunks.push(Chunk {
                    id: chunks.len(),
                    topic,
                    page,
                    text,
                    facts: fids,
                    keywords,
                });
            }
        }

        // fact -> chunks lookup for QA support sets
        let mut fact_chunks: Vec<Vec<ChunkId>> = vec![Vec::new(); facts.len()];
        for ch in &chunks {
            for &f in &ch.facts {
                fact_chunks[f].push(ch.id);
            }
        }

        // entity -> outgoing facts (for multi-hop chains)
        let mut out_facts: Vec<Vec<FactId>> = vec![Vec::new(); entities.len()];
        for f in &facts {
            out_facts[f.subject].push(f.id);
        }

        // --- QA pairs ---
        let mut qa: Vec<QaPair> = Vec::new();
        let mut attempts = 0;
        while qa.len() < spec.qa_pairs && attempts < spec.qa_pairs * 50 {
            attempts += 1;
            let multi = rng.chance(spec.multi_hop_share);
            if multi {
                if let Some(pair) = gen_multi_hop(&entities, &facts, &out_facts, &fact_chunks, qa.len(), &mut rng)
                {
                    qa.push(pair);
                }
            } else {
                let f = &facts[rng.below(facts.len())];
                qa.push(gen_single_hop(&entities, f, &fact_chunks, qa.len(), &mut rng));
            }
        }

        // --- base topic popularity: zipf over a shuffled topic order ---
        let mut order: Vec<usize> = (0..spec.topics).collect();
        rng.shuffle(&mut order);
        let mut topic_popularity = vec![0.0; spec.topics];
        let h: f64 = (1..=spec.topics)
            .map(|k| (k as f64).powf(-spec.topic_zipf))
            .sum();
        for (rank, &t) in order.iter().enumerate() {
            topic_popularity[t] = ((rank + 1) as f64).powf(-spec.topic_zipf) / h;
        }

        Corpus {
            spec,
            entities,
            facts,
            chunks,
            qa,
            topic_popularity,
        }
    }

    /// All QA ids whose topic is `t`.
    pub fn qa_by_topic(&self, t: TopicId) -> Vec<QaId> {
        self.qa.iter().filter(|q| q.topic == t).map(|q| q.id).collect()
    }

    /// Keywords of a QA pair: its entity names (what the embedder and
    /// overlap-ratio machinery match against chunk keywords).
    pub fn qa_keywords(&self, qa: &QaPair) -> Vec<&str> {
        qa.entities.iter().map(|&e| self.entities[e].name.as_str()).collect()
    }
}

fn render_chunk(
    entities: &[Entity],
    facts: &[Fact],
    fids: &[FactId],
    rng: &mut Rng,
) -> (String, Vec<String>) {
    let mut text = String::new();
    let mut keywords: Vec<String> = Vec::new();
    for &fid in fids {
        let f = &facts[fid];
        let s = &entities[f.subject].name;
        let o = &entities[f.object].name;
        text.push_str(&format!("{} {} {}. ", s, f.relation, o));
        for w in [s.as_str(), f.relation.as_str(), o.as_str()] {
            if !keywords.iter().any(|k| k == w) {
                keywords.push(w.to_string());
            }
        }
    }
    // Filler prose emulates realistic chunk length (the paper's naive
    // RAG feeds ~3.6k tokens of context for ~6 chunks ⇒ ~2.4 kB/chunk)
    // without adding keywords.
    let filler_words = 380 + rng.below(160);
    for _ in 0..filler_words {
        text.push_str(SYLLABLES[rng.below(SYLLABLES.len())]);
        text.push_str(SYLLABLES[rng.below(SYLLABLES.len())]);
        text.push(' ');
    }
    (text, keywords)
}

fn gen_single_hop(
    entities: &[Entity],
    f: &Fact,
    fact_chunks: &[Vec<ChunkId>],
    id: QaId,
    rng: &mut Rng,
) -> QaPair {
    let s = &entities[f.subject].name;
    let o = &entities[f.object].name;
    let question = format!("Who or what did {} {}?", s, f.relation);
    QaPair {
        id,
        question,
        answer: o.clone(),
        hops: 1,
        entities: vec![f.subject, f.object],
        supporting_facts: vec![f.id],
        supporting_chunks: fact_chunks[f.id].clone(),
        topic: f.topic,
        length_tokens: 8 + rng.below(10),
    }
}

fn gen_multi_hop(
    entities: &[Entity],
    facts: &[Fact],
    out_facts: &[Vec<FactId>],
    fact_chunks: &[Vec<ChunkId>],
    id: QaId,
    rng: &mut Rng,
) -> Option<QaPair> {
    // Chain: f1 = (A r1 B), f2 = (B r2 C) [, f3 = (C r3 D)].
    let f1 = &facts[rng.below(facts.len())];
    let mid = f1.object;
    let candidates = &out_facts[mid];
    if candidates.is_empty() {
        return None;
    }
    let f2 = &facts[*rng.choose(candidates)];
    if f2.id == f1.id || f2.object == f1.subject {
        return None;
    }
    let want3 = rng.chance(0.3);
    let mut chain = vec![f1.id, f2.id];
    let mut terminal = f2.object;
    if want3 {
        let c3 = &out_facts[f2.object];
        if !c3.is_empty() {
            let f3 = &facts[*rng.choose(c3)];
            if f3.id != f1.id && f3.id != f2.id && f3.object != f1.subject {
                chain.push(f3.id);
                terminal = f3.object;
            }
        }
    }
    let hops = chain.len();
    let a = &entities[f1.subject].name;
    let question = format!(
        "Through {} and what follows, who or what is ultimately reached from {}?",
        facts[chain[0]].relation, a
    );
    let mut ents: Vec<EntityId> = Vec::new();
    let mut chunks: Vec<ChunkId> = Vec::new();
    for &fid in &chain {
        let f = &facts[fid];
        for e in [f.subject, f.object] {
            if !ents.contains(&e) {
                ents.push(e);
            }
        }
        for &c in &fact_chunks[fid] {
            if !chunks.contains(&c) {
                chunks.push(c);
            }
        }
    }
    Some(QaPair {
        id,
        question,
        answer: entities[terminal].name.clone(),
        hops,
        entities: ents,
        supporting_facts: chain,
        supporting_chunks: chunks,
        topic: f1.topic,
        length_tokens: 14 + rng.below(14),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wiki_matches_paper_scale() {
        let c = Corpus::generate(Profile::Wiki, 1);
        assert_eq!(c.spec.pages, 139);
        assert_eq!(c.qa.len(), 571);
        assert!(c.chunks.len() > 500);
    }

    #[test]
    fn hp_matches_paper_scale() {
        let c = Corpus::generate(Profile::HarryPotter, 1);
        assert_eq!(c.qa.len(), 1180);
        assert_eq!(c.spec.topics, 7);
    }

    #[test]
    fn deterministic_generation() {
        let a = Corpus::generate(Profile::Wiki, 42);
        let b = Corpus::generate(Profile::Wiki, 42);
        assert_eq!(a.entities.len(), b.entities.len());
        assert_eq!(a.qa[10].question, b.qa[10].question);
        assert_eq!(a.chunks[5].text, b.chunks[5].text);
    }

    #[test]
    fn seeds_change_content() {
        let a = Corpus::generate(Profile::Wiki, 1);
        let b = Corpus::generate(Profile::Wiki, 2);
        assert_ne!(a.qa[0].question, b.qa[0].question);
    }

    #[test]
    fn qa_support_is_consistent() {
        let c = Corpus::generate(Profile::Wiki, 7);
        for qa in &c.qa {
            assert!(!qa.supporting_facts.is_empty());
            assert!(!qa.supporting_chunks.is_empty(), "qa {} lacks chunks", qa.id);
            // Every supporting fact is present in at least one supporting chunk.
            for &fid in &qa.supporting_facts {
                assert!(
                    qa.supporting_chunks
                        .iter()
                        .any(|&cid| c.chunks[cid].facts.contains(&fid)),
                    "fact {fid} of qa {} not covered",
                    qa.id
                );
            }
            assert!(qa.hops >= 1 && qa.hops <= 3);
            assert!(qa.entities.len() >= 2);
        }
    }

    #[test]
    fn hp_has_more_multi_hop_than_wiki() {
        let wiki = Corpus::generate(Profile::Wiki, 3);
        let hp = Corpus::generate(Profile::HarryPotter, 3);
        let share = |c: &Corpus| {
            c.qa.iter().filter(|q| q.hops > 1).count() as f64 / c.qa.len() as f64
        };
        assert!(share(&hp) > share(&wiki) + 0.1);
    }

    #[test]
    fn chunk_keywords_cover_fact_entities() {
        let c = Corpus::generate(Profile::HarryPotter, 5);
        for ch in c.chunks.iter().take(200) {
            for &fid in &ch.facts {
                let f = &c.facts[fid];
                assert!(ch.keywords.contains(&c.entities[f.subject].name));
                assert!(ch.keywords.contains(&c.entities[f.object].name));
            }
        }
    }

    #[test]
    fn topic_popularity_is_distribution() {
        let c = Corpus::generate(Profile::Wiki, 9);
        let sum: f64 = c.topic_popularity.iter().sum();
        assert!((sum - 1.0).abs() < 1e-9);
        assert!(c.topic_popularity.iter().all(|&p| p > 0.0));
    }

    #[test]
    fn entity_names_unique() {
        let c = Corpus::generate(Profile::Wiki, 11);
        let mut names: Vec<&str> = c.entities.iter().map(|e| e.name.as_str()).collect();
        names.sort_unstable();
        let before = names.len();
        names.dedup();
        assert_eq!(before, names.len());
    }
}
