//! Cost model (paper §4.1 + Tables 1 & 3).
//!
//! Resource cost follows Pope et al. ("Efficiently Scaling Transformer
//! Inference"): one forward pass over `t` tokens of an `N`-parameter
//! decoder costs ≈ `2·N·t` FLOPs. The paper adds a fixed per-query
//! overhead (KV/attention bookkeeping) which we model as an extra
//! `C0_TOKENS` context tokens — this reproduces Table 1's ~0.65 TFLOPs
//! for a 3B LLM-only call with ~43 total tokens.
//!
//! Time cost is unified with resource cost "by scaling the time cost with
//! the peak TFLOPs of different GPUs" (Eq. 1 discussion + Table 3): a
//! second spent on an H100 is ~46× more costly than a second on a 4090.

/// Table 3 of the paper: FP64 peak TFLOPS of server GPUs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Gpu {
    Rtx4090,
    TeslaP100,
    TeslaV100,
    A100,
    H100,
}

impl Gpu {
    /// FP64 (double precision) peak, TFLOPS — exactly Table 3.
    pub fn peak_tflops(&self) -> f64 {
        match self {
            Gpu::Rtx4090 => 1.29,
            Gpu::TeslaP100 => 4.70,
            Gpu::TeslaV100 => 7.80,
            Gpu::A100 => 9.70,
            Gpu::H100 => 60.00,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Gpu::Rtx4090 => "NVIDIA GeForce RTX 4090",
            Gpu::TeslaP100 => "NVIDIA Tesla P100",
            Gpu::TeslaV100 => "NVIDIA Tesla V100",
            Gpu::A100 => "NVIDIA A100 Tensor Core",
            Gpu::H100 => "NVIDIA H100 Tensor Core",
        }
    }

    pub fn all() -> [Gpu; 5] {
        [Gpu::Rtx4090, Gpu::TeslaP100, Gpu::TeslaV100, Gpu::A100, Gpu::H100]
    }
}

/// Fixed per-query context overhead (tokens-equivalent); calibrated so a
/// 3B LLM-only query (~16 in + ~27 out) lands near Table 1's 0.65 TFLOPs.
pub const C0_TOKENS: f64 = 64.0;

/// Inference FLOPs (Pope et al.): 2·N·(in + out + overhead), in TFLOPs.
pub fn inference_tflops(params_b: f64, in_tokens: f64, out_tokens: f64) -> f64 {
    2.0 * params_b * 1e9 * (in_tokens + out_tokens + C0_TOKENS) / 1e12
}

/// Cost weights δ₁, δ₂ of Eq. (1).
#[derive(Clone, Copy, Debug)]
pub struct CostWeights {
    pub delta1: f64, // resource
    pub delta2: f64, // time
}

impl Default for CostWeights {
    fn default() -> Self {
        CostWeights {
            delta1: 1.0,
            delta2: 1.0,
        }
    }
}

/// The unified cost model.
#[derive(Clone, Copy, Debug, Default)]
pub struct CostModel {
    pub weights: CostWeights,
}

impl CostModel {
    pub fn new(weights: CostWeights) -> Self {
        CostModel { weights }
    }

    /// u_r: resource cost (TFLOPs) of a generation call.
    pub fn resource_cost(&self, params_b: f64, in_tokens: f64, out_tokens: f64) -> f64 {
        inference_tflops(params_b, in_tokens, out_tokens)
    }

    /// u_d: time cost — seconds of occupancy scaled by the executing
    /// GPU's peak TFLOPS ("minimal for edge devices but significant for
    /// cloud computing").
    pub fn time_cost(&self, delay_s: f64, gpu: Gpu) -> f64 {
        delay_s * gpu.peak_tflops()
    }

    /// u_t = δ₁·u_r + δ₂·u_d (Eq. 1).
    pub fn total(&self, u_r: f64, u_d: f64) -> f64 {
        self.weights.delta1 * u_r + self.weights.delta2 * u_d
    }
}

/// Token accounting for one query (drives Table 1).
#[derive(Clone, Copy, Debug, Default)]
pub struct TokenUsage {
    pub input: f64,
    pub output: f64,
}

impl TokenUsage {
    pub fn total(&self) -> f64 {
        self.input + self.output
    }
}

/// Rough tokenizer-equivalent count for retrieved context text
/// (≈ 1 token / 4 chars, the usual BPE rule of thumb).
pub fn text_tokens(text_chars: usize) -> f64 {
    text_chars as f64 / 4.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_constants_exact() {
        assert_eq!(Gpu::Rtx4090.peak_tflops(), 1.29);
        assert_eq!(Gpu::TeslaP100.peak_tflops(), 4.70);
        assert_eq!(Gpu::TeslaV100.peak_tflops(), 7.80);
        assert_eq!(Gpu::A100.peak_tflops(), 9.70);
        assert_eq!(Gpu::H100.peak_tflops(), 60.00);
    }

    #[test]
    fn llm_only_cost_near_table1() {
        // Table 1: 3B LLM-only, 16 in / 27 out ⇒ ~0.65 TFLOPs.
        let c = inference_tflops(3.0, 16.0, 27.2);
        assert!((c - 0.65).abs() < 0.05, "got {c}");
    }

    #[test]
    fn naive_rag_cost_near_table1() {
        // Table 1: Naive RAG, 3632 in / 26.6 out ⇒ ~22.98 TFLOPs.
        let c = inference_tflops(3.0, 3632.0, 26.6);
        assert!((c - 22.98).abs() < 1.5, "got {c}");
    }

    #[test]
    fn graphrag_cost_near_table1() {
        // Table 1: GraphRAG, 9017 in / 142.7 out ⇒ ~58.57 TFLOPs.
        let c = inference_tflops(3.0, 9017.0, 142.7);
        assert!((c - 58.57).abs() < 4.0, "got {c}");
    }

    #[test]
    fn cost_monotone_in_params_and_tokens() {
        assert!(inference_tflops(72.0, 100.0, 10.0) > inference_tflops(3.0, 100.0, 10.0));
        assert!(inference_tflops(3.0, 200.0, 10.0) > inference_tflops(3.0, 100.0, 10.0));
    }

    #[test]
    fn time_cost_gpu_scaling() {
        let m = CostModel::default();
        let edge = m.time_cost(1.0, Gpu::Rtx4090);
        let cloud = m.time_cost(1.0, Gpu::H100);
        assert!((cloud / edge - 60.0 / 1.29).abs() < 1e-9);
    }

    #[test]
    fn eq1_weighted_total() {
        let m = CostModel::new(CostWeights {
            delta1: 2.0,
            delta2: 0.5,
        });
        assert_eq!(m.total(10.0, 4.0), 22.0);
    }

    #[test]
    fn text_tokens_rule() {
        assert_eq!(text_tokens(400), 100.0);
    }
}
