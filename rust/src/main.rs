//! `eaco-rag` — the EACO-RAG leader binary.
//!
//! Subcommands:
//!   serve    — real serving: SafeOBO gate + dynamic batcher + PJRT
//!              generation over a synthetic workload (the E2E path).
//!   simulate — virtual-time replication of a Table-4 style run
//!              (baselines + EACO) without touching PJRT.
//!   chaos    — fault-injection run: a scripted scenario over the
//!              collaborative serve plane, emitting the JSON chaos
//!              report (recovery / staleness / availability + SLA
//!              verdicts); exits non-zero on SLA failure.
//!   inspect  — print the artifact manifest the runtime would load.
//!
//! Examples:
//!   eaco-rag serve --dataset wiki --steps 400 --qos cost
//!   eaco-rag simulate --dataset hp --steps 1500 --warmup 500
//!   eaco-rag chaos --scenario split-brain --sla-staleness 3
//!   eaco-rag inspect --artifacts artifacts

use std::path::PathBuf;

use eaco_rag::chaos::{ChaosReport, Scenario, SlaSpec};
use eaco_rag::config::{QosPreset, SystemConfig};
use eaco_rag::coordinator::Coordinator;
use eaco_rag::corpus::Profile;
use eaco_rag::runtime::Manifest;
use eaco_rag::serve::Driver;
use eaco_rag::cluster::feedback::FeedbackMode;
use eaco_rag::sim::{workload_for, KnowledgeMode, SimSystem, TIER_LOCAL, TIER_NEIGHBOR};
use eaco_rag::util::cli::Args;
use eaco_rag::workload::Workload;

fn main() {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = if argv.is_empty() {
        "help".to_string()
    } else {
        argv.remove(0)
    };
    let code = match cmd.as_str() {
        "serve" => serve(argv),
        "simulate" => simulate(argv),
        "chaos" => chaos(argv),
        "inspect" => inspect(argv),
        _ => {
            eprintln!(
                "usage: eaco-rag <serve|simulate|chaos|inspect> [options]\n  \
                 serve    — real PJRT serving over a synthetic workload\n  \
                 simulate — virtual-time Table-4 style run\n  \
                 chaos    — scripted fault-injection run + SLA report\n  \
                 inspect  — print the artifact manifest"
            );
            2
        }
    };
    std::process::exit(code);
}

fn common(program: &str, about: &str) -> Args {
    Args::new(program, about)
        .opt("dataset", "wiki", "dataset profile: wiki | hp")
        .opt("steps", "800", "workload length (queries)")
        .opt("warmup", "300", "gate warm-up steps T0")
        .opt("qos", "cost", "QoS preset: cost | delay")
        .opt("seed", "42", "run seed")
        .opt("edges", "4", "number of edge nodes")
        .opt("edge-tier", "qwen3b", "edge SLM tier")
        .opt("cloud-tier", "qwen72b", "cloud LLM tier")
}

fn build_cfg(a: &Args) -> SystemConfig {
    let mut cfg = SystemConfig::default();
    cfg.dataset = Profile::parse(&a.get("dataset")).unwrap_or(Profile::Wiki);
    cfg.warmup_steps = a.get_usize("warmup");
    cfg.qos = QosPreset::parse(&a.get("qos")).unwrap_or(QosPreset::CostEfficient);
    cfg.seed = a.get_u64("seed");
    cfg.num_edges = a.get_usize("edges");
    cfg.edge_tier = a.get("edge-tier");
    cfg.cloud_tier = a.get("cloud-tier");
    cfg
}

fn serve(argv: Vec<String>) -> i32 {
    let a = match common("eaco-rag serve", "real PJRT serving")
        .opt("artifacts", "artifacts", "artifacts directory")
        .opt("gen-tokens", "4", "real tokens decoded per request")
        .opt("admission", "none", "admission policy: none | shed | downgrade")
        .opt("slo-ms", "2000", "admission SLO target (ms)")
        .parse_from(argv)
    {
        Ok(a) => a,
        Err(m) => {
            eprintln!("{m}");
            return 2;
        }
    };
    let mut cfg = build_cfg(&a);
    match eaco_rag::serve::queue::AdmissionPolicy::parse(&a.get("admission")) {
        Some(p) => cfg.serve.admission = p,
        None => {
            eprintln!("error: bad --admission {:?} (none | shed | downgrade)", a.get("admission"));
            return 2;
        }
    }
    cfg.serve.slo_ms = a.get_usize("slo-ms") as f64;
    let steps = a.get_usize("steps");
    let artifacts = PathBuf::from(a.get("artifacts"));
    println!(
        "eaco-rag serve: dataset={} steps={steps} qos={} edges={} admission={} slo={:.0}ms",
        cfg.dataset.name(),
        cfg.qos.name(),
        cfg.num_edges,
        cfg.serve.admission.name(),
        cfg.serve.slo_ms
    );
    let mut coord = match Coordinator::new(cfg.clone(), &artifacts, a.get_usize("gen-tokens")) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e:#}");
            return 1;
        }
    };
    let wl = Workload::generate(&coord.sim.corpus, workload_for(&cfg, steps), cfg.seed);
    match coord.run(&wl) {
        Ok(n) => {
            println!("served {n} requests");
            println!("{}", coord.metrics.summary());
            println!("arm usage: {:?}", coord.metrics.arm_histogram());
            println!("mean batch size: {:.2}", coord.batcher.mean_batch_size());
            println!(
                "serve plane: admission={} slo={:.0}ms shed={} downgraded={}",
                coord.cfg.serve.admission.name(),
                coord.cfg.serve.slo_ms,
                coord.shed_deadline,
                coord.downgraded
            );
            0
        }
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}

fn simulate(argv: Vec<String>) -> i32 {
    let a = match common("eaco-rag simulate", "virtual-time experiment run").parse_from(argv) {
        Ok(a) => a,
        Err(m) => {
            eprintln!("{m}");
            return 2;
        }
    };
    let cfg = build_cfg(&a);
    let steps = a.get_usize("steps");
    println!(
        "eaco-rag simulate: dataset={} steps={steps} qos={} warmup={}",
        cfg.dataset.name(),
        cfg.qos.name(),
        cfg.warmup_steps
    );
    for name in ["llm-only", "naive-rag", "graph-slm", "graph-llm"] {
        let mut sys = SimSystem::new(cfg.clone(), KnowledgeMode::Static);
        let wl = Workload::generate(&sys.corpus, workload_for(&cfg, steps), cfg.seed);
        let stats = sys.run_baseline(&wl, SimSystem::baseline_arm(name).unwrap());
        println!("{name:>12}: {}", stats.row());
    }
    let mut sys = SimSystem::new(cfg.clone(), KnowledgeMode::Adaptive);
    let wl = Workload::generate(&sys.corpus, workload_for(&cfg, steps), cfg.seed);
    let (stats, gate) = sys.run_eaco(&wl);
    println!("{:>12}: {}", "eaco-rag", stats.row());
    println!(
        "         arm usage: {:?}",
        gate.arms
            .iter()
            .map(|a| a.name())
            .zip(stats.arm_counts.iter())
            .collect::<Vec<_>>()
    );
    // The distributed knowledge plane: summary routing over a bounded
    // neighbor topology + versioned placement + delta gossip.
    let mut sys = SimSystem::new(cfg.clone(), KnowledgeMode::Collaborative);
    let wl = Workload::generate(&sys.corpus, workload_for(&cfg, steps), cfg.seed);
    let (stats, _) = sys.run_eaco(&wl);
    println!("{:>12}: {}", "eaco-cluster", stats.row());
    let (stale, resident) = sys.cluster.staleness();
    println!(
        "         tiers: {}\n         gossip: {} rounds, {} chunks, {:.1} KiB; staleness {stale}/{resident}",
        stats.tier_row(),
        sys.cluster.gossiper.stats.rounds,
        sys.cluster.gossiper.stats.chunks_transferred,
        stats.bytes_replicated as f64 / 1024.0,
    );
    println!("         {}", stats.ann_row());
    // The closed adaptive-knowledge loop: gate-observed tier hit rates
    // drive per-link gossip budgets and digest re-ranking. Printed as a
    // bytes / staleness / edge-tier-hit A/B against the fixed-budget
    // eaco-cluster row above (same workload, same seed).
    let cluster_bytes = stats.bytes_replicated;
    let cluster_stale = stale;
    let edge_hit = |s: &eaco_rag::sim::RunStats| {
        let q = s.tier_queries[TIER_LOCAL] + s.tier_queries[TIER_NEIGHBOR];
        let h = s.tier_hits[TIER_LOCAL] + s.tier_hits[TIER_NEIGHBOR];
        if q == 0 { 0.0 } else { h as f64 / q as f64 * 100.0 }
    };
    let cluster_edge_hit = edge_hit(&stats);
    let mut cfg_f = cfg.clone();
    cfg_f.cluster.feedback = FeedbackMode::HitRate;
    let mut sys = SimSystem::new(cfg_f.clone(), KnowledgeMode::Collaborative);
    let wl = Workload::generate(&sys.corpus, workload_for(&cfg_f, steps), cfg_f.seed);
    let (stats, _) = sys.run_eaco(&wl);
    println!("{:>12}: {}", "eaco-feedback", stats.row());
    let (stale_f, resident_f) = sys.cluster.staleness();
    println!(
        "         feedback: gossip {:.1} KiB (fixed {:.1} KiB) | staleness {stale_f}/{resident_f} (fixed {cluster_stale}/{resident}) | edge-tier hit {:.1}% (fixed {:.1}%)",
        stats.bytes_replicated as f64 / 1024.0,
        cluster_bytes as f64 / 1024.0,
        edge_hit(&stats),
        cluster_edge_hit,
    );
    // The async serving plane over the same cluster: gated queries with
    // background gossip on 4 workers. Tier mix / hits / bytes stay
    // bit-identical to the synchronous row — only the latency model
    // (queueing, overlap) is new.
    let mut cfg_s = cfg.clone();
    cfg_s.serve.workers = 4;
    cfg_s.serve.gossip_background = true;
    let mut sys = SimSystem::new(cfg_s.clone(), KnowledgeMode::Collaborative);
    let wl = Workload::generate(&sys.corpus, workload_for(&cfg_s, steps), cfg_s.seed);
    let (stats, serve_m) = sys.serve_async(&wl, Driver::Gated);
    println!("{:>12}: {}", "eaco-serve", stats.row());
    println!("         serve: {}", serve_m.row());
    println!("         {}", serve_m.tier_latency_row());
    // The same serve plane under the default scripted split-brain: what
    // the fault-free rows above cost in staleness and availability when
    // the fleet partitions mid-run.
    let mut cfg_c = cfg_s.clone();
    cfg_c.chaos.enabled = true;
    let mut sys = SimSystem::new(cfg_c.clone(), KnowledgeMode::Collaborative);
    let wl = Workload::generate(&sys.corpus, workload_for(&cfg_c, steps), cfg_c.seed);
    let (stats, serve_m) = sys.serve_async(&wl, Driver::Gated);
    println!("{:>12}: {}", "eaco-chaos", stats.row());
    if let Some(c) = &serve_m.chaos {
        println!(
            "         chaos: {} | faults {} | staleness {} (partitioned {}) | availability {:.3}",
            c.scenario,
            c.faults_applied,
            c.max_staleness,
            c.max_staleness_partitioned,
            c.availability()
        );
    }
    0
}

fn chaos(argv: Vec<String>) -> i32 {
    let a = match common("eaco-rag chaos", "scripted fault-injection run + SLA report")
        .opt(
            "scenario",
            "split-brain",
            "preset: rolling-restart | split-brain | flaky-uplink | random",
        )
        .opt("at", "40", "workload step at which the scenario begins")
        .opt("duration", "60", "scenario duration in workload steps")
        .opt("factor", "8", "link degradation multiplier (flaky-uplink)")
        .opt("random-faults", "8", "number of fault events drawn (random scenario)")
        .opt("random-seed", "7", "fault-schedule seed (random scenario)")
        .opt("sla-recovery-ms", "0", "recovery SLA in ms (<= 0 disables the check)")
        .opt("sla-staleness", "-1", "staleness SLA in versions (< 0 disables the check)")
        .opt("sla-availability", "0", "availability SLA fraction (<= 0 disables the check)")
        .opt("append-trend", "", "append the report to this JSON trend file and diff vs previous")
        .parse_from(argv)
    {
        Ok(a) => a,
        Err(m) => {
            eprintln!("{m}");
            return 2;
        }
    };
    let mut cfg = build_cfg(&a);
    let scen = a.get("scenario");
    if !Scenario::is_known(&scen) {
        eprintln!(
            "error: unknown --scenario {:?} (expected one of: {})",
            scen,
            Scenario::PRESETS.join(", ")
        );
        return 2;
    }
    let factor = a.get_f64("factor");
    if !(factor.is_finite() && factor > 0.0) {
        eprintln!("error: --factor must be a positive finite multiplier (got {factor})");
        return 2;
    }
    let staleness = match a.get("sla-staleness").parse::<i64>() {
        Ok(v) => v,
        Err(_) => {
            eprintln!(
                "option --sla-staleness expects an integer (got {:?})",
                a.get("sla-staleness")
            );
            return 2;
        }
    };
    cfg.chaos.enabled = true;
    cfg.chaos.scenario = scen;
    cfg.chaos.at_step = a.get_usize("at");
    cfg.chaos.duration_steps = a.get_usize("duration");
    cfg.chaos.degrade_factor = factor;
    cfg.chaos.random_faults = a.get_usize("random-faults");
    cfg.chaos.random_seed = a.get_u64("random-seed");
    cfg.chaos.sla_recovery_ms = a.get_f64("sla-recovery-ms");
    cfg.chaos.sla_max_staleness = staleness;
    cfg.chaos.sla_min_availability = a.get_f64("sla-availability");
    let steps = a.get_usize("steps");
    let mut sys = SimSystem::new(cfg.clone(), KnowledgeMode::Collaborative);
    let wl = Workload::generate(&sys.corpus, workload_for(&cfg, steps), cfg.seed);
    let (_, serve_m) = sys.serve_async(&wl, Driver::Gated);
    let outcome = serve_m.chaos.expect("a chaos-enabled run attaches an outcome");
    let report = ChaosReport::evaluate(outcome, &SlaSpec::from_config(&cfg.chaos));
    println!("{}", report.to_json().to_string());
    // Cross-run trend tracking: append this report to the trend array
    // and fail if it regressed vs the previous entry (CI runs this via
    // `make chaos-trend`).
    let trend_path = a.get("append-trend");
    if !trend_path.is_empty() {
        let prior = std::fs::read_to_string(&trend_path).unwrap_or_default();
        let doc = match eaco_rag::chaos::trend::append(&prior, &report) {
            Ok(d) => d,
            Err(e) => {
                eprintln!("error: trend file {trend_path:?}: {e}");
                return 2;
            }
        };
        if let Err(e) = std::fs::write(&trend_path, &doc) {
            eprintln!("error: writing trend file {trend_path:?}: {e}");
            return 2;
        }
        let parsed = eaco_rag::util::json::parse(&doc).expect("append returns valid JSON");
        let entries = parsed.as_arr().unwrap_or(&[]);
        if let Some(msg) = eaco_rag::chaos::trend::regression(entries) {
            eprintln!("SLA trend regression vs previous entry: {msg}");
            return 1;
        }
        eprintln!("trend: {} entries in {trend_path} (no regression)", entries.len());
    }
    if report.pass {
        0
    } else {
        1
    }
}

fn inspect(argv: Vec<String>) -> i32 {
    let a = match Args::new("eaco-rag inspect", "print the artifact manifest")
        .opt("artifacts", "artifacts", "artifacts directory")
        .parse_from(argv)
    {
        Ok(a) => a,
        Err(m) => {
            eprintln!("{m}");
            return 2;
        }
    };
    let dir = PathBuf::from(a.get("artifacts"));
    match Manifest::load(&dir) {
        Ok(m) => {
            println!(
                "manifest: {} artifacts, attention kernel VMEM {:.1} KiB, MXU util {:.3}",
                m.artifacts.len(),
                m.attention_vmem_bytes as f64 / 1024.0,
                m.attention_mxu_util
            );
            for a in &m.artifacts {
                if a.kind == "lm" {
                    println!(
                        "  {:<20} tier {:<8} b{} seq {} vocab {} d{} L{} (emulates {}B, cap {:.2})",
                        a.name,
                        a.tier,
                        a.batch,
                        a.seq,
                        a.vocab,
                        a.d_model,
                        a.layers,
                        a.emulated_params_b,
                        a.capability
                    );
                } else {
                    println!(
                        "  {:<20} embedder b{} feat {} out {}",
                        a.name, a.batch, a.feat_dim, a.out_dim
                    );
                }
            }
            0
        }
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}
