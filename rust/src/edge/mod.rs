//! Edge node: FIFO chunk store + adaptive knowledge update (paper §3.3, §5).
//!
//! Each edge maintains "a dynamic local dataset of popular topics"
//! (Fig. 1): a capacity-bounded chunk store (prototype: 1,000 chunks)
//! updated FIFO as the cloud distributes fresh community chunks, plus a
//! keyword index for naive retrieval and overlap-ratio scoring. The edge
//! also exposes the signals the collaborative gate consumes: its current
//! overlap ratio against a query and its store occupancy.

pub mod semantic;

use std::collections::VecDeque;

use crate::config::AnnConfig;
use crate::corpus::{ChunkId, Corpus};
use crate::index::{KeywordIndex, KeywordSummary, RetrieveScratch};

use semantic::{AnnProbe, SemanticStore};

/// Counters for observability / tests.
#[derive(Clone, Copy, Debug, Default)]
pub struct EdgeStats {
    pub inserted: usize,
    pub evicted: usize,
    pub updates: usize,
    pub retrievals: usize,
}

/// One edge node's knowledge state.
pub struct EdgeNode {
    pub id: usize,
    capacity: usize,
    /// Insertion order of resident chunks (front = oldest). Under the
    /// paper's FIFO policy this *is* the eviction order; pluggable
    /// placement policies ([`crate::cluster::placement`]) drive eviction
    /// explicitly through [`Self::evict_resident`] instead.
    fifo: VecDeque<ChunkId>,
    /// Keyword index over resident chunks.
    pub index: KeywordIndex,
    /// Compact keyword digest kept in lock-step with `index` — what the
    /// cluster routing layer probes instead of the full index.
    pub summary: KeywordSummary,
    pub stats: EdgeStats,
    /// Reusable retrieval workspace (allocation-free steady state).
    scratch: RetrieveScratch,
    /// Dense (IVF ANN) store over resident chunks, kept in lock-step
    /// with the keyword index by the residency primitives. `None` until
    /// the collaborative knowledge plane enables it.
    pub semantic: Option<SemanticStore>,
}

impl EdgeNode {
    pub fn new(id: usize, capacity: usize) -> EdgeNode {
        EdgeNode {
            id,
            capacity,
            fifo: VecDeque::new(),
            index: KeywordIndex::new(),
            summary: KeywordSummary::new(),
            stats: EdgeStats::default(),
            scratch: RetrieveScratch::default(),
            semantic: None,
        }
    }

    /// Attach a semantic store, embedding every already-resident chunk.
    /// Subsequent inserts/evictions keep it in sync automatically.
    pub fn enable_semantic(&mut self, corpus: &Corpus, ann: &AnnConfig, seed: u64) {
        let mut sem = SemanticStore::new(ann, seed);
        for &cid in &self.fifo {
            sem.insert_chunk(&corpus.chunks[cid]);
        }
        self.semantic = Some(sem);
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.fifo.len()
    }

    pub fn is_empty(&self) -> bool {
        self.fifo.is_empty()
    }

    pub fn contains(&self, chunk: ChunkId) -> bool {
        self.index.contains_chunk(chunk)
    }

    pub fn resident_chunks(&self) -> impl Iterator<Item = ChunkId> + '_ {
        self.fifo.iter().copied()
    }

    /// Insert a chunk without evicting (returns false if already
    /// resident). Placement engines compose this with
    /// [`Self::evict_resident`] to realize their own eviction order; the
    /// built-in [`Self::apply_update`] composes them into the paper's
    /// FIFO policy.
    pub fn insert_resident(&mut self, corpus: &Corpus, cid: ChunkId) -> bool {
        if self.contains(cid) {
            return false;
        }
        self.fifo.push_back(cid);
        self.index.add_chunk(cid, &corpus.chunks[cid].keywords);
        for kw in &corpus.chunks[cid].keywords {
            self.summary.add(kw);
        }
        if let Some(sem) = self.semantic.as_mut() {
            sem.insert_chunk(&corpus.chunks[cid]);
        }
        self.stats.inserted += 1;
        true
    }

    /// Evict a specific resident chunk (index, summary, and order queue
    /// all updated). Returns false if the chunk is not resident.
    pub fn evict_resident(&mut self, cid: ChunkId) -> bool {
        if !self.contains(cid) {
            return false;
        }
        if self.fifo.front() == Some(&cid) {
            self.fifo.pop_front();
        } else {
            self.fifo.retain(|&c| c != cid);
        }
        if let Some(kws) = self.index.chunk_keywords(cid) {
            for kw in kws {
                self.summary.remove(kw);
            }
        }
        self.index.remove_chunk(cid);
        if let Some(sem) = self.semantic.as_mut() {
            sem.remove_chunk(cid);
        }
        self.stats.evicted += 1;
        true
    }

    /// Refresh a resident chunk's recency (move to the back of the
    /// insertion-order queue). Returns false if not resident.
    pub fn refresh_resident(&mut self, cid: ChunkId) -> bool {
        if !self.contains(cid) {
            return false;
        }
        self.fifo.retain(|&c| c != cid);
        self.fifo.push_back(cid);
        true
    }

    /// Oldest resident by insertion order — the FIFO policy's victim.
    pub fn oldest_resident(&self) -> Option<ChunkId> {
        self.fifo.front().copied()
    }

    /// Adaptive knowledge update: insert distributed chunks, evicting the
    /// oldest residents when over capacity (paper §5 FIFO policy).
    /// Re-inserted chunks are refreshed (moved to the back of the queue).
    pub fn apply_update(&mut self, corpus: &Corpus, chunks: &[ChunkId]) {
        self.stats.updates += 1;
        for &cid in chunks {
            if self.contains(cid) {
                self.refresh_resident(cid);
                continue;
            }
            self.insert_resident(corpus, cid);
            while self.fifo.len() > self.capacity {
                if let Some(old) = self.oldest_resident() {
                    self.evict_resident(old);
                }
            }
        }
    }

    /// Naive local RAG: top-k resident chunks by distinct keyword hits.
    /// Scoring reuses the node's held workspace — no per-query map/set
    /// allocation.
    pub fn retrieve(&mut self, query_keywords: &[&str], k: usize) -> Vec<ChunkId> {
        self.stats.retrievals += 1;
        self.index
            .retrieve_with(query_keywords, k, &mut self.scratch)
            .iter()
            .map(|&(c, _)| c)
            .collect()
    }

    /// Hybrid retrieval: keyword hits first, the remainder of the k
    /// budget filled from the semantic (IVF) top-k. Returns the chunks
    /// plus what the ANN probe observed (recall@k vs the exact scan,
    /// and whether the exact fallback answered). `None` probe means the
    /// semantic store is not enabled and this degenerates to
    /// [`Self::retrieve`].
    pub fn retrieve_hybrid(
        &mut self,
        query_keywords: &[&str],
        q_emb: &[f32],
        k: usize,
    ) -> (Vec<ChunkId>, Option<AnnProbe>) {
        self.stats.retrievals += 1;
        let mut out: Vec<ChunkId> = self
            .index
            .retrieve_with(query_keywords, k, &mut self.scratch)
            .iter()
            .map(|&(c, _)| c)
            .collect();
        let Some(sem) = self.semantic.as_ref() else {
            return (out, None);
        };
        let approx = sem.top_k(q_emb, k);
        let probe = if sem.uses_exact() {
            // The fallback *is* the exact scan — recall is 1 by
            // construction, no need to score the store twice.
            AnnProbe {
                recall_at_k: 1.0,
                exact_fallback: true,
            }
        } else {
            let exact = sem.top_k_exact(q_emb, k);
            let hits = exact
                .iter()
                .filter(|(id, _)| approx.iter().any(|(a, _)| a == id))
                .count();
            AnnProbe {
                recall_at_k: if exact.is_empty() {
                    1.0
                } else {
                    hits as f64 / exact.len() as f64
                },
                exact_fallback: false,
            }
        };
        for &(cid, _) in &approx {
            if out.len() >= k {
                break;
            }
            if !out.contains(&cid) {
                out.push(cid);
            }
        }
        (out, Some(probe))
    }

    /// The paper's edge-selection signal: share of query keywords this
    /// edge's dataset covers.
    pub fn overlap_ratio(&self, query_keywords: &[&str]) -> f64 {
        self.index.overlap_ratio(query_keywords)
    }

    /// Total text volume of the top-k retrieval (for token accounting).
    pub fn retrieval_context_chars(&self, corpus: &Corpus, chunks: &[ChunkId]) -> usize {
        chunks.iter().map(|&c| corpus.chunks[c].text.len()).sum()
    }
}

/// Pick the best collaborating edge for a query: highest overlap ratio,
/// preferring the local edge on ties (paper §3.3 "selects retrieval
/// sources from local, edge, or cloud datasets"). Returns
/// `(edge_id, overlap)`.
///
/// **Retained as the equivalence-test oracle and bench reference only.**
/// This probes every edge's full keyword index on every query — an
/// O(#edges × |query|) string-hashing broadcast that serving no longer
/// does: the hot path goes through [`crate::cluster::EdgeCluster::route`],
/// which scores candidates against compact per-edge
/// [`crate::index::KeywordSummary`] digests (pre-hashed integer probes)
/// and matches this function's choice (see
/// `tests/cluster_equivalence.rs`).
pub fn best_edge_for(
    edges: &[EdgeNode],
    local_edge: usize,
    query_keywords: &[&str],
) -> (usize, f64) {
    let mut best = (local_edge, edges[local_edge].overlap_ratio(query_keywords));
    for e in edges {
        let r = e.overlap_ratio(query_keywords);
        if r > best.1 + 1e-12 {
            best = (e.id, r);
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::Profile;

    fn setup() -> (Corpus, EdgeNode) {
        let c = Corpus::generate(Profile::Wiki, 2);
        let e = EdgeNode::new(0, 50);
        (c, e)
    }

    #[test]
    fn fifo_eviction_at_capacity() {
        let (c, mut e) = setup();
        let chunks: Vec<ChunkId> = (0..60).collect();
        e.apply_update(&c, &chunks);
        assert_eq!(e.len(), 50);
        assert!(!e.contains(0), "oldest evicted");
        assert!(e.contains(59), "newest resident");
        assert_eq!(e.stats.evicted, 10);
    }

    #[test]
    fn reinsert_refreshes_recency() {
        let (c, mut e) = setup();
        e.apply_update(&c, &(0..50).collect::<Vec<_>>());
        // Touch chunk 0 again, then push one more; chunk 1 (not 0) evicts.
        e.apply_update(&c, &[0]);
        e.apply_update(&c, &[50]);
        assert!(e.contains(0));
        assert!(!e.contains(1));
    }

    #[test]
    fn retrieve_finds_resident_support() {
        let (c, mut e) = setup();
        let qa = &c.qa[0];
        e.apply_update(&c, &qa.supporting_chunks);
        let kws = c.qa_keywords(qa);
        let got = e.retrieve(&kws, 6);
        assert!(
            qa.supporting_chunks.iter().any(|s| got.contains(s)),
            "support not retrieved"
        );
    }

    #[test]
    fn overlap_ratio_tracks_content() {
        let (c, mut e) = setup();
        let qa = &c.qa[0];
        let kws = c.qa_keywords(qa);
        assert_eq!(e.overlap_ratio(&kws), 0.0);
        e.apply_update(&c, &qa.supporting_chunks);
        assert!(e.overlap_ratio(&kws) > 0.5);
    }

    #[test]
    fn best_edge_prefers_higher_overlap() {
        let c = Corpus::generate(Profile::Wiki, 2);
        let mut e0 = EdgeNode::new(0, 100);
        let mut e1 = EdgeNode::new(1, 100);
        let qa = &c.qa[5];
        e1.apply_update(&c, &qa.supporting_chunks);
        // e0 gets unrelated chunks.
        let unrelated: Vec<ChunkId> = c
            .chunks
            .iter()
            .filter(|ch| ch.topic != qa.topic)
            .take(20)
            .map(|ch| ch.id)
            .collect();
        e0.apply_update(&c, &unrelated);
        let edges = vec![e0, e1];
        let kws = c.qa_keywords(qa);
        let (best, overlap) = best_edge_for(&edges, 0, &kws);
        assert_eq!(best, 1);
        assert!(overlap > 0.5);
    }

    #[test]
    fn best_edge_ties_stay_local() {
        let c = Corpus::generate(Profile::Wiki, 2);
        let e0 = EdgeNode::new(0, 10);
        let e1 = EdgeNode::new(1, 10);
        let edges = vec![e0, e1];
        let (best, overlap) = best_edge_for(&edges, 0, &["nothing"]);
        assert_eq!(best, 0);
        assert_eq!(overlap, 0.0);
    }

    #[test]
    fn placement_primitives_keep_summary_in_sync() {
        let (c, mut e) = setup();
        e.insert_resident(&c, 3);
        e.insert_resident(&c, 9);
        assert!(!e.insert_resident(&c, 3), "double insert rejected");
        assert_eq!(e.len(), 2);
        // Summary agrees with the index on every keyword of a resident
        // chunk, and forgets evicted content.
        let mut buf = String::new();
        for kw in &c.chunks[3].keywords {
            let h = crate::index::keyword_sig(kw, &mut buf);
            assert!(e.summary.contains_hash(h), "missing {kw}");
        }
        assert!(e.evict_resident(3));
        assert!(!e.evict_resident(3), "double evict rejected");
        for kw in &c.chunks[3].keywords {
            if c.chunks[9].keywords.contains(kw) {
                continue; // still held by the other resident
            }
            let h = crate::index::keyword_sig(kw, &mut buf);
            assert!(!e.summary.contains_hash(h), "stale {kw}");
        }
        assert_eq!(e.stats.inserted, 2);
        assert_eq!(e.stats.evicted, 1);
    }

    #[test]
    fn evict_specific_chunk_mid_queue() {
        let (c, mut e) = setup();
        e.apply_update(&c, &[1, 2, 3]);
        assert!(e.evict_resident(2));
        let order: Vec<ChunkId> = e.resident_chunks().collect();
        assert_eq!(order, vec![1, 3], "order of survivors preserved");
        assert_eq!(e.oldest_resident(), Some(1));
        assert!(e.refresh_resident(1));
        assert_eq!(e.oldest_resident(), Some(3));
    }

    #[test]
    fn semantic_store_tracks_residency() {
        use crate::runtime::FeatureHasher;
        let (c, mut e) = setup();
        e.apply_update(&c, &[1, 2]);
        e.enable_semantic(&c, &AnnConfig::default(), 9);
        // Pre-existing residents were embedded; new churn stays in sync.
        assert_eq!(e.semantic.as_ref().unwrap().len(), 2);
        e.apply_update(&c, &[3, 4, 5]);
        assert_eq!(e.semantic.as_ref().unwrap().len(), 5);
        e.evict_resident(4);
        assert_eq!(e.semantic.as_ref().unwrap().len(), 4);
        let qa = &c.qa[0];
        let kws = c.qa_keywords(qa);
        let hasher = FeatureHasher::new(AnnConfig::default().embed_dim);
        let q = semantic::embed_keywords(&hasher, &kws);
        let (got, probe) = e.retrieve_hybrid(&kws, &q, 6);
        assert!(got.len() <= 6);
        let p = probe.expect("semantic enabled → probe reported");
        assert!(p.exact_fallback, "tiny store must use the exact fallback");
        assert_eq!(p.recall_at_k, 1.0);
        // Semantic fill never duplicates a chunk.
        let mut dedup = got.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), got.len());
    }

    #[test]
    fn hybrid_without_semantic_matches_retrieve() {
        let (c, mut e) = setup();
        e.apply_update(&c, &c.qa[0].supporting_chunks.clone());
        let kws = c.qa_keywords(&c.qa[0]);
        let plain = e.retrieve(&kws, 6);
        let (hybrid, probe) = e.retrieve_hybrid(&kws, &[], 6);
        assert_eq!(plain, hybrid);
        assert!(probe.is_none());
    }

    #[test]
    fn update_stats_counted() {
        let (c, mut e) = setup();
        e.apply_update(&c, &[1, 2, 3]);
        e.apply_update(&c, &[4]);
        assert_eq!(e.stats.updates, 2);
        assert_eq!(e.stats.inserted, 4);
    }

    /// Regression for retrieve-after-churn on the hybrid path (see the
    /// remove-then-top_k note in `vecstore`): evicting every resident
    /// chunk leaves the semantic store empty, and a hybrid retrieve
    /// against the empty store must answer cleanly (exact fallback,
    /// perfect recall, no results) and recover after re-insertion.
    #[test]
    fn hybrid_after_full_churn_empty_semantic_store() {
        use crate::config::AnnConfig;
        use crate::runtime::FeatureHasher;
        use semantic::embed_keywords;

        let (c, mut e) = setup();
        let ann = AnnConfig::default();
        e.apply_update(&c, &c.qa[0].supporting_chunks.clone());
        e.enable_semantic(&c, &ann, 7);
        assert!(e.len() > 0);

        // Full churn: evict every resident chunk (swap-remove path in
        // the backing vector store runs once per eviction).
        let resident: Vec<ChunkId> = e.resident_chunks().collect();
        for cid in resident {
            assert!(e.evict_resident(cid));
        }
        assert!(e.is_empty());

        let kws = c.qa_keywords(&c.qa[0]);
        let hasher = FeatureHasher::new(ann.embed_dim);
        let q = embed_keywords(&hasher, &kws);
        let (got, probe) = e.retrieve_hybrid(&kws, &q, 6);
        assert!(got.is_empty(), "empty store yields no chunks");
        let probe = probe.expect("semantic enabled => probe present");
        assert_eq!(probe.recall_at_k, 1.0);
        assert!(probe.exact_fallback, "empty store takes the exact path");

        // The store recovers: re-insert support and retrieve again.
        e.apply_update(&c, &c.qa[0].supporting_chunks.clone());
        let (got, probe) = e.retrieve_hybrid(&kws, &q, 6);
        assert!(!got.is_empty());
        assert!(probe.is_some());
        assert!(
            c.qa[0].supporting_chunks.iter().any(|s| got.contains(s)),
            "support retrievable after churn + refill"
        );
    }
}
