//! Edge node: FIFO chunk store + adaptive knowledge update (paper §3.3, §5).
//!
//! Each edge maintains "a dynamic local dataset of popular topics"
//! (Fig. 1): a capacity-bounded chunk store (prototype: 1,000 chunks)
//! updated FIFO as the cloud distributes fresh community chunks, plus a
//! keyword index for naive retrieval and overlap-ratio scoring. The edge
//! also exposes the signals the collaborative gate consumes: its current
//! overlap ratio against a query and its store occupancy.

use std::collections::VecDeque;

use crate::corpus::{ChunkId, Corpus};
use crate::index::{KeywordIndex, RetrieveScratch};

/// Counters for observability / tests.
#[derive(Clone, Copy, Debug, Default)]
pub struct EdgeStats {
    pub inserted: usize,
    pub evicted: usize,
    pub updates: usize,
    pub retrievals: usize,
}

/// One edge node's knowledge state.
pub struct EdgeNode {
    pub id: usize,
    capacity: usize,
    /// FIFO order of resident chunks (front = oldest).
    fifo: VecDeque<ChunkId>,
    /// Keyword index over resident chunks.
    pub index: KeywordIndex,
    pub stats: EdgeStats,
    /// Reusable retrieval workspace (allocation-free steady state).
    scratch: RetrieveScratch,
}

impl EdgeNode {
    pub fn new(id: usize, capacity: usize) -> EdgeNode {
        EdgeNode {
            id,
            capacity,
            fifo: VecDeque::new(),
            index: KeywordIndex::new(),
            stats: EdgeStats::default(),
            scratch: RetrieveScratch::default(),
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.fifo.len()
    }

    pub fn is_empty(&self) -> bool {
        self.fifo.is_empty()
    }

    pub fn contains(&self, chunk: ChunkId) -> bool {
        self.index.contains_chunk(chunk)
    }

    pub fn resident_chunks(&self) -> impl Iterator<Item = ChunkId> + '_ {
        self.fifo.iter().copied()
    }

    /// Adaptive knowledge update: insert distributed chunks, evicting the
    /// oldest residents when over capacity (paper §5 FIFO policy).
    /// Re-inserted chunks are refreshed (moved to the back of the queue).
    pub fn apply_update(&mut self, corpus: &Corpus, chunks: &[ChunkId]) {
        self.stats.updates += 1;
        for &cid in chunks {
            if self.contains(cid) {
                // Refresh recency.
                self.fifo.retain(|&c| c != cid);
                self.fifo.push_back(cid);
                continue;
            }
            self.fifo.push_back(cid);
            self.index.add_chunk(cid, &corpus.chunks[cid].keywords);
            self.stats.inserted += 1;
            while self.fifo.len() > self.capacity {
                if let Some(old) = self.fifo.pop_front() {
                    self.index.remove_chunk(old);
                    self.stats.evicted += 1;
                }
            }
        }
    }

    /// Naive local RAG: top-k resident chunks by distinct keyword hits.
    /// Scoring reuses the node's held workspace — no per-query map/set
    /// allocation.
    pub fn retrieve(&mut self, query_keywords: &[&str], k: usize) -> Vec<ChunkId> {
        self.stats.retrievals += 1;
        self.index
            .retrieve_with(query_keywords, k, &mut self.scratch)
            .iter()
            .map(|&(c, _)| c)
            .collect()
    }

    /// The paper's edge-selection signal: share of query keywords this
    /// edge's dataset covers.
    pub fn overlap_ratio(&self, query_keywords: &[&str]) -> f64 {
        self.index.overlap_ratio(query_keywords)
    }

    /// Total text volume of the top-k retrieval (for token accounting).
    pub fn retrieval_context_chars(&self, corpus: &Corpus, chunks: &[ChunkId]) -> usize {
        chunks.iter().map(|&c| corpus.chunks[c].text.len()).sum()
    }
}

/// Pick the best collaborating edge for a query: highest overlap ratio,
/// preferring the local edge on ties (paper §3.3 "selects retrieval
/// sources from local, edge, or cloud datasets"). Returns
/// `(edge_id, overlap)`.
pub fn best_edge_for(
    edges: &[EdgeNode],
    local_edge: usize,
    query_keywords: &[&str],
) -> (usize, f64) {
    let mut best = (local_edge, edges[local_edge].overlap_ratio(query_keywords));
    for e in edges {
        let r = e.overlap_ratio(query_keywords);
        if r > best.1 + 1e-12 {
            best = (e.id, r);
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::Profile;

    fn setup() -> (Corpus, EdgeNode) {
        let c = Corpus::generate(Profile::Wiki, 2);
        let e = EdgeNode::new(0, 50);
        (c, e)
    }

    #[test]
    fn fifo_eviction_at_capacity() {
        let (c, mut e) = setup();
        let chunks: Vec<ChunkId> = (0..60).collect();
        e.apply_update(&c, &chunks);
        assert_eq!(e.len(), 50);
        assert!(!e.contains(0), "oldest evicted");
        assert!(e.contains(59), "newest resident");
        assert_eq!(e.stats.evicted, 10);
    }

    #[test]
    fn reinsert_refreshes_recency() {
        let (c, mut e) = setup();
        e.apply_update(&c, &(0..50).collect::<Vec<_>>());
        // Touch chunk 0 again, then push one more; chunk 1 (not 0) evicts.
        e.apply_update(&c, &[0]);
        e.apply_update(&c, &[50]);
        assert!(e.contains(0));
        assert!(!e.contains(1));
    }

    #[test]
    fn retrieve_finds_resident_support() {
        let (c, mut e) = setup();
        let qa = &c.qa[0];
        e.apply_update(&c, &qa.supporting_chunks);
        let kws = c.qa_keywords(qa);
        let got = e.retrieve(&kws, 6);
        assert!(
            qa.supporting_chunks.iter().any(|s| got.contains(s)),
            "support not retrieved"
        );
    }

    #[test]
    fn overlap_ratio_tracks_content() {
        let (c, mut e) = setup();
        let qa = &c.qa[0];
        let kws = c.qa_keywords(qa);
        assert_eq!(e.overlap_ratio(&kws), 0.0);
        e.apply_update(&c, &qa.supporting_chunks);
        assert!(e.overlap_ratio(&kws) > 0.5);
    }

    #[test]
    fn best_edge_prefers_higher_overlap() {
        let c = Corpus::generate(Profile::Wiki, 2);
        let mut e0 = EdgeNode::new(0, 100);
        let mut e1 = EdgeNode::new(1, 100);
        let qa = &c.qa[5];
        e1.apply_update(&c, &qa.supporting_chunks);
        // e0 gets unrelated chunks.
        let unrelated: Vec<ChunkId> = c
            .chunks
            .iter()
            .filter(|ch| ch.topic != qa.topic)
            .take(20)
            .map(|ch| ch.id)
            .collect();
        e0.apply_update(&c, &unrelated);
        let edges = vec![e0, e1];
        let kws = c.qa_keywords(qa);
        let (best, overlap) = best_edge_for(&edges, 0, &kws);
        assert_eq!(best, 1);
        assert!(overlap > 0.5);
    }

    #[test]
    fn best_edge_ties_stay_local() {
        let c = Corpus::generate(Profile::Wiki, 2);
        let e0 = EdgeNode::new(0, 10);
        let e1 = EdgeNode::new(1, 10);
        let edges = vec![e0, e1];
        let (best, overlap) = best_edge_for(&edges, 0, &["nothing"]);
        assert_eq!(best, 0);
        assert_eq!(overlap, 0.0);
    }

    #[test]
    fn update_stats_counted() {
        let (c, mut e) = setup();
        e.apply_update(&c, &[1, 2, 3]);
        e.apply_update(&c, &[4]);
        assert_eq!(e.stats.updates, 2);
        assert_eq!(e.stats.inserted, 4);
    }
}
