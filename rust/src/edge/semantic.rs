//! Semantic (dense) retrieval for an edge node: feature-hashed chunk
//! embeddings in an [`IvfStore`], plus the coarse-centroid digest the
//! cluster layer gossips for blended routing.
//!
//! Embeddings come from the deterministic [`FeatureHasher`] (char
//! 3-gram counts — the offline MiniLM stand-in): a chunk embeds its
//! keywords plus text, a query embeds its keywords, so keyword overlap
//! shows up as 3-gram overlap and cosine neighbors are topically
//! related. The store auto-trains its IVF lists once it outgrows
//! `exact_below`; below that every query is an exact scan, bit-identical
//! to the flat path, so paper-scale edges (1,000 chunks) see no
//! behavior change from enabling this module.
//!
//! Recall accounting: hybrid retrieval reports per-query recall@k of
//! the IVF probe against the exact scan. That reference scan is O(n·d)
//! — affordable at sim scale and worth it for observability; a
//! production path would sample instead.

use crate::config::AnnConfig;
use crate::corpus::{Chunk, ChunkId};
use crate::runtime::FeatureHasher;
use crate::vecstore::dot_f32;
use crate::vecstore::ivf::{IvfParams, IvfStore};

/// What one hybrid retrieval observed about its ANN probe.
#[derive(Clone, Copy, Debug)]
pub struct AnnProbe {
    /// |approx ∩ exact| / |exact| for this query's semantic top-k.
    pub recall_at_k: f64,
    /// Whether the store answered via the exact-scan fallback.
    pub exact_fallback: bool,
}

/// Per-edge coarse-centroid digest, gossiped to neighbors alongside the
/// hot-k chunk digest (~`nlist · dim · 4` B on the wire). Versioned
/// like chunks: receivers keep the last version per sender and senders
/// skip peers that already hold it.
#[derive(Clone, Debug)]
pub struct CentroidDigest {
    /// The source store's centroid version (≥ 1; version 0 means
    /// untrained and is never shipped).
    pub version: u64,
    pub dim: usize,
    /// Unit-norm centroid matrix, row-major (`nlist_eff × dim`).
    pub centroids: Vec<f32>,
}

impl CentroidDigest {
    /// Serialized size: the matrix plus a version/dim header.
    pub fn wire_bytes(&self) -> usize {
        self.centroids.len() * 4 + 12
    }

    /// Alignment of a query embedding with this digest (see
    /// [`max_alignment`]).
    pub fn alignment(&self, q_emb: &[f32], qn: f32) -> f64 {
        max_alignment(&self.centroids, self.dim, q_emb, qn)
    }
}

/// Max cosine between `q` and any centroid row, clamped at 0 so the
/// routing blend is additive-only: when every candidate's alignment is
/// zero (or the blend is disabled) the blended score reduces exactly to
/// the keyword hit count and routing matches the legacy decision.
pub fn max_alignment(centroids: &[f32], dim: usize, q: &[f32], qn: f32) -> f64 {
    if centroids.is_empty() || q.len() != dim {
        return 0.0;
    }
    let mut best = f32::NEG_INFINITY;
    for row in centroids.chunks_exact(dim) {
        let d = dot_f32(row, q);
        if d > best {
            best = d;
        }
    }
    (best / qn).max(0.0) as f64
}

/// L2 norm of a query embedding (floored like the store's own scans).
pub fn query_norm(q: &[f32]) -> f32 {
    q.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-12)
}

/// Embed query keywords with the same hasher geometry as chunks.
pub fn embed_keywords(hasher: &FeatureHasher, keywords: &[&str]) -> Vec<f32> {
    hasher.features(&keywords.join(" "))
}

/// Dense store over one edge's resident chunks: ids are [`ChunkId`]s,
/// rows are feature-hashed embeddings, queries go through the IVF layer
/// (exact below `exact_below`).
pub struct SemanticStore {
    hasher: FeatureHasher,
    store: IvfStore,
}

impl SemanticStore {
    pub fn new(ann: &AnnConfig, seed: u64) -> SemanticStore {
        let params = IvfParams {
            nlist: ann.nlist,
            nprobe: ann.nprobe,
            exact_below: ann.exact_below,
            retrain_drift: ann.retrain_drift,
            seed,
            ..IvfParams::default()
        };
        SemanticStore {
            hasher: FeatureHasher::new(ann.embed_dim),
            store: IvfStore::new(ann.embed_dim, params),
        }
    }

    pub fn len(&self) -> usize {
        self.store.len()
    }

    pub fn is_empty(&self) -> bool {
        self.store.is_empty()
    }

    /// Embed a chunk: keywords first (they dominate the 3-gram mass for
    /// short texts) plus the body.
    fn embed_chunk(&self, chunk: &Chunk) -> Vec<f32> {
        let mut text = chunk.keywords.join(" ");
        text.push(' ');
        text.push_str(&chunk.text);
        self.hasher.features(&text)
    }

    pub fn insert_chunk(&mut self, chunk: &Chunk) {
        let v = self.embed_chunk(chunk);
        self.store.insert(chunk.id, &v);
    }

    pub fn remove_chunk(&mut self, cid: ChunkId) -> bool {
        self.store.remove(cid)
    }

    /// Approximate semantic top-k (IVF at the configured nprobe; exact
    /// below the size threshold).
    pub fn top_k(&self, q_emb: &[f32], k: usize) -> Vec<(ChunkId, f32)> {
        self.store.top_k(q_emb, k)
    }

    /// Exact semantic top-k (the recall reference).
    pub fn top_k_exact(&self, q_emb: &[f32], k: usize) -> Vec<(ChunkId, f32)> {
        self.store.top_k_exact(q_emb, k)
    }

    /// Whether queries currently take the exact-scan fallback.
    pub fn uses_exact(&self) -> bool {
        self.store.uses_exact()
    }

    /// 0 until the first IVF train; bumps on retrains and refreshes.
    pub fn centroid_version(&self) -> u64 {
        self.store.centroid_version()
    }

    /// Snapshot the coarse centroids for gossip; `None` until trained.
    pub fn digest(&self) -> Option<CentroidDigest> {
        if !self.store.trained() {
            return None;
        }
        Some(CentroidDigest {
            version: self.store.centroid_version(),
            dim: self.store.dim(),
            centroids: self.store.centroids().to_vec(),
        })
    }

    /// Alignment of a query with this node's own (live) centroids.
    pub fn alignment(&self, q_emb: &[f32], qn: f32) -> f64 {
        max_alignment(self.store.centroids(), self.store.dim(), q_emb, qn)
    }

    /// Direct access for tests/diagnostics.
    pub fn ivf(&self) -> &IvfStore {
        &self.store
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::{Corpus, Profile};

    #[test]
    fn chunks_round_trip_and_fallback_is_exact() {
        let c = Corpus::generate(Profile::Wiki, 3);
        let ann = AnnConfig::default(); // exact_below 4096 ⇒ tiny store stays exact
        let mut s = SemanticStore::new(&ann, 7);
        for ch in c.chunks.iter().take(40) {
            s.insert_chunk(ch);
        }
        assert_eq!(s.len(), 40);
        assert!(s.uses_exact());
        assert!(s.digest().is_none(), "untrained store must not advertise");
        let kws = c.qa_keywords(&c.qa[0]);
        let q = embed_keywords(&FeatureHasher::new(ann.embed_dim), &kws);
        let approx = s.top_k(&q, 6);
        let exact = s.top_k_exact(&q, 6);
        assert_eq!(approx, exact, "fallback must be the exact scan");
        assert!(s.remove_chunk(c.chunks[0].id));
        assert_eq!(s.len(), 39);
    }

    #[test]
    fn trained_store_advertises_versioned_digest() {
        let c = Corpus::generate(Profile::Wiki, 3);
        let ann = AnnConfig {
            exact_below: 16,
            nlist: 4,
            ..AnnConfig::default()
        };
        let mut s = SemanticStore::new(&ann, 7);
        for ch in c.chunks.iter().take(60) {
            s.insert_chunk(ch);
        }
        assert!(!s.uses_exact());
        let d = s.digest().expect("trained store has a digest");
        assert_eq!(d.version, s.centroid_version());
        assert_eq!(d.dim, ann.embed_dim);
        assert_eq!(d.centroids.len() % ann.embed_dim, 0);
        assert!(d.wire_bytes() >= d.centroids.len() * 4);
        // A query aligned with resident content scores above zero.
        let kws = c.qa_keywords(&c.qa[0]);
        let q = embed_keywords(&FeatureHasher::new(ann.embed_dim), &kws);
        let qn = query_norm(&q);
        assert!(d.alignment(&q, qn) >= 0.0);
        assert_eq!(d.alignment(&q, qn), s.alignment(&q, qn));
    }

    #[test]
    fn alignment_is_zero_without_centroids_or_on_dim_mismatch() {
        assert_eq!(max_alignment(&[], 8, &[1.0; 8], 1.0), 0.0);
        assert_eq!(max_alignment(&[1.0; 8], 8, &[1.0; 4], 1.0), 0.0);
    }
}
