//! The Route/Retrieve stages: one home for the tiered retrieval
//! decision (paper §III) that used to live inline in `SimSystem::serve`.
//!
//! [`retrieve`] absorbs the `Retrieval::{None, LocalNaive,
//! EdgeAssisted, CloudGraph}` match — hybrid ANN probing, summary
//! routing over the cluster topology, context-chars accounting, and
//! neighbor-hop delay all live here, and every driver observes the same
//! [`Retrieved`] record. The borrow seam is [`TierCtx`]: field-granular
//! borrows of the simulator, so query keywords can stay borrowed from
//! the corpus while retrieval mutates the cluster/net planes.

use std::collections::HashSet;

use crate::cloud::CloudNode;
use crate::cluster::EdgeCluster;
use crate::corpus::{ChunkId, Corpus};
use crate::edge::semantic::AnnProbe;
use crate::gating::Retrieval;
use crate::netsim::{Link, NetSim};
use crate::sim::{TIER_CLOUD, TIER_LOCAL, TIER_NEIGHBOR, TIER_NONE};

/// Disjoint field borrows of the simulator needed by the retrieval
/// stage. Everything the stage mutates (`cluster`, `net`) is disjoint
/// from the corpus the query keywords borrow from.
pub struct TierCtx<'a> {
    pub cluster: &'a mut EdgeCluster,
    pub cloud: &'a CloudNode,
    pub net: &'a mut NetSim,
    pub corpus: &'a Corpus,
    /// Per-edge chunks that arrived via community distribution.
    pub community_marked: &'a [HashSet<ChunkId>],
    pub retrieve_k: usize,
}

/// What the Route/Retrieve stages produced for one query.
pub struct Retrieved {
    pub chunks: Vec<ChunkId>,
    pub context_chars: usize,
    /// Retrieval surfaced community-distributed content.
    pub community: bool,
    /// Neighbor-hop transfer time (s); 0 unless the neighbor tier served.
    pub edge_edge_s: f64,
    /// `TIER_NONE` / `TIER_LOCAL` / `TIER_NEIGHBOR` / `TIER_CLOUD`.
    pub tier: usize,
    /// IVF probe outcome when the ANN path answered (collaborative
    /// local/edge-assisted retrieval only).
    pub ann: Option<AnnProbe>,
}

/// Execute the retrieval tier chosen by `retrieval` for a query at
/// `edge_id`. `q_emb` is the dense query embedding (collaborative mode
/// only); without it every call degenerates to keyword-only retrieval.
///
/// Call order is load-bearing for bit-identity: summary routing mutates
/// route counters, `retrieve*` mutates per-store telemetry, and the
/// neighbor-hop `delay_ms` draws from the per-link jitter stream — all
/// in exactly the order the inline match used.
pub fn retrieve(
    ctx: &mut TierCtx<'_>,
    retrieval: Retrieval,
    edge_id: usize,
    step: usize,
    kws: &[&str],
    q_emb: Option<&[f32]>,
) -> Retrieved {
    match retrieval {
        Retrieval::None => Retrieved {
            chunks: Vec::new(),
            context_chars: 0,
            community: false,
            edge_edge_s: 0.0,
            tier: TIER_NONE,
            ann: None,
        },
        Retrieval::LocalNaive => {
            let (chunks, ann) = fetch(ctx, edge_id, kws, q_emb);
            let context_chars =
                ctx.cluster.nodes[edge_id].retrieval_context_chars(ctx.corpus, &chunks);
            let community = chunks
                .iter()
                .any(|c| ctx.community_marked[edge_id].contains(c));
            Retrieved { chunks, context_chars, community, edge_edge_s: 0.0, tier: TIER_LOCAL, ann }
        }
        Retrieval::EdgeAssisted => {
            // Summary routing over the cluster topology (full mesh in
            // the legacy modes ⇒ the oracle's choice). With ANN enabled
            // the decision also blends coarse-centroid alignment from
            // gossiped digests.
            let best = ctx.cluster.route_blended(edge_id, kws, q_emb).edge;
            ctx.cluster.note_served_route(best == edge_id);
            let (chunks, ann) = fetch(ctx, best, kws, q_emb);
            let context_chars =
                ctx.cluster.nodes[best].retrieval_context_chars(ctx.corpus, &chunks);
            let community = chunks
                .iter()
                .any(|c| ctx.community_marked[best].contains(c));
            let (edge_edge_s, tier) = if best == edge_id {
                (0.0, TIER_LOCAL)
            } else {
                (
                    ctx.net.delay_ms(Link::EdgeToEdge(edge_id, best), step) / 1000.0,
                    TIER_NEIGHBOR,
                )
            };
            Retrieved { chunks, context_chars, community, edge_edge_s, tier, ann }
        }
        Retrieval::CloudGraph => {
            let (chunks, context_chars) =
                ctx.cloud.retrieve_graph(ctx.corpus, kws, ctx.retrieve_k);
            Retrieved {
                chunks,
                context_chars,
                community: false,
                edge_edge_s: 0.0,
                tier: TIER_CLOUD,
                ann: None,
            }
        }
    }
}

/// Store-level fetch from one edge: hybrid (keyword + ANN) when a dense
/// query embedding exists, plain keyword retrieval otherwise.
fn fetch(
    ctx: &mut TierCtx<'_>,
    edge: usize,
    kws: &[&str],
    q_emb: Option<&[f32]>,
) -> (Vec<ChunkId>, Option<AnnProbe>) {
    match q_emb {
        Some(q) => ctx.cluster.nodes[edge].retrieve_hybrid(kws, q, ctx.retrieve_k),
        None => (ctx.cluster.nodes[edge].retrieve(kws, ctx.retrieve_k), None),
    }
}
