//! Shared gate construction: one `SafeObo` recipe from `SystemConfig`,
//! used by `run_eaco`, the serving plane, and the PJRT coordinator
//! (previously three identical inline copies).

use crate::config::SystemConfig;
use crate::gating::safeobo::{Qos, SafeObo};
use crate::gating::standard_arms;

/// Build the SafeOBO gate exactly as every gated driver does: standard
/// arm set, QoS constraints resolved for the configured dataset, and
/// warm-up/β/seed from the config.
pub fn build_gate(cfg: &SystemConfig) -> SafeObo {
    let (min_acc, max_delay) = cfg.qos.constraints_for(cfg.dataset);
    SafeObo::new(
        standard_arms(),
        Qos {
            min_accuracy: min_acc,
            max_delay_s: max_delay,
        },
        cfg.warmup_steps,
        cfg.beta,
        cfg.seed,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gate_matches_config_recipe() {
        let cfg = SystemConfig::default();
        let gate = build_gate(&cfg);
        assert_eq!(gate.arms.len(), standard_arms().len());
    }
}
