//! Staged per-query execution pipeline — the one query path every
//! driver composes.
//!
//! The paper's tiered serving decision (local SLM / edge-assisted /
//! cloud LLM, §III) used to be implemented four separate times:
//! `SimSystem::serve`'s inline retrieval match, `run_baseline` /
//! `run_eaco`, the async serving plane, and the PJRT coordinator. This
//! module is the single implementation they now share.
//!
//! # Stage contract
//!
//! A query moves through fixed stages, in order:
//!
//! 1. **Admit** — serving-plane only: queue-cap shed, dead-edge
//!    reroute, deadline admission (accept / shed / downgrade). The
//!    synchronous drivers admit unconditionally.
//! 2. **Route** — pick the serving store: summary routing over the
//!    cluster topology for edge-assisted retrieval ([`tier`]).
//! 3. **Retrieve** — fetch chunks at the chosen tier (hybrid ANN or
//!    keyword), with context-chars / community / hop accounting.
//! 4. **Gate** — gated drivers only: SafeOBO arm selection from the
//!    [`build_gate`] recipe; fixed-arm drivers skip this stage.
//! 5. **Generate** — the strategy model ([`crate::sim::strategy`]):
//!    tokens, delay, cost, one RNG draw.
//! 6. **Grade** — the oracle's correctness verdict.
//! 7. **Update** — the knowledge plane ([`KnowledgePolicy`]): cloud
//!    FIFO push or versioned collaborative placement; gossip cadence
//!    runs as the pre-query half of the same policy.
//!
//! Stages 2–7 are [`exec_query`]; [`gated_step`] wraps them with stage
//! 4. Everything a driver wants to know about the run arrives as typed
//! [`StageEvent`]s on a [`StageSink`] — `RunStats`, `ServeMetrics`,
//! `ChaosProbe`, and `FeedbackSink` are four sinks over the one event
//! stream.
//!
//! # Bit-identity
//!
//! The pipeline is a *relocation* of the seed's query path, not a
//! reinterpretation: every mutation and RNG draw happens in the exact
//! order the inline implementations used, so determinism digests
//! (`tests/serve_determinism.rs`, `tests/chaos_determinism.rs`,
//! `tests/pipeline_golden.rs`) are bit-identical before and after.

pub mod gate;
pub mod policy;
pub mod sink;
pub mod tier;

pub use gate::build_gate;
pub use policy::KnowledgePolicy;
pub use sink::{FeedbackSink, NullSink, StageEvent, StageSink, StatsSink};
pub use tier::{Retrieved, TierCtx};

use crate::corpus::QaId;
use crate::edge::semantic::embed_keywords;
use crate::gating::safeobo::{Observation, SafeObo};
use crate::gating::{Arm, GenLoc, Retrieval};
use crate::netsim::Link;
use crate::sim::strategy::{execute, Outcome, StrategyInputs};
use crate::sim::{KnowledgeMode, SimSystem};

/// Execute stages Route → Retrieve → Generate → Grade → Update for one
/// query with a fixed arm. Emits `GossipRound` / `TierChosen` /
/// `RecallProbe` events; terminal `QueryDone` emission stays with the
/// driver, which owns admission context (seq, arrival time) the
/// pipeline never sees.
pub fn exec_query(
    sys: &mut SimSystem,
    qa_id: QaId,
    edge_id: usize,
    step: usize,
    arm: Arm,
    sink: &mut dyn StageSink,
) -> (Outcome, bool) {
    let policy = KnowledgePolicy::from_mode(sys.mode);

    // Collaborative background work first: a due gossip round runs
    // before the query sees the stores (virtual-time cadence).
    if let Some(round) = policy.pre_query(&mut sys.cluster, &sys.corpus, step) {
        sink.emit(&StageEvent::GossipRound {
            step,
            round: round.round,
            wire_bytes: round.wire_bytes(),
            version_lag: None,
        });
    }

    // Borrow keywords straight from the corpus: retrieval mutates
    // `sys.cluster`/`sys.net` only, both disjoint from `sys.corpus`.
    let kws: Vec<&str> = sys.corpus.qa_keywords(&sys.corpus.qa[qa_id]);

    // Dense query embedding for the collaborative ANN path. Legacy
    // modes (no hasher) skip the hashing work entirely and retrieval
    // degenerates to the keyword-only seed behavior.
    let q_emb: Option<Vec<f32>> = match arm.retrieval {
        Retrieval::LocalNaive | Retrieval::EdgeAssisted => sys
            .query_hasher
            .as_ref()
            .map(|h| embed_keywords(h, &kws)),
        _ => None,
    };

    // --- route + retrieve ---
    let mut tctx = TierCtx {
        cluster: &mut sys.cluster,
        cloud: &sys.cloud,
        net: &mut sys.net,
        corpus: &sys.corpus,
        community_marked: &sys.community_marked,
        retrieve_k: sys.cfg.retrieve_k,
    };
    let r = tier::retrieve(&mut tctx, arm.retrieval, edge_id, step, &kws, q_emb.as_deref());

    let qa = &sys.corpus.qa[qa_id];
    sys.last_tier = r.tier;
    sys.last_hit = r.tier != crate::sim::TIER_NONE
        && r.chunks.iter().any(|c| qa.supporting_chunks.contains(c));
    sys.last_ann = r.ann;
    sink.emit(&StageEvent::TierChosen { step, edge_id, tier: r.tier, hit: sys.last_hit });
    if let Some(probe) = r.ann {
        sink.emit(&StageEvent::RecallProbe { step, probe });
    }
    if sys.mode == KnowledgeMode::Collaborative {
        // Demand signals feed hotness-aware placement + gossip.
        sys.cluster.observe_query(qa.topic, &r.chunks, step);
        // Outcome signals close the adaptive-knowledge loop: the
        // gate-observed tier/hit verdict drives per-link gossip
        // budgets when `[cluster] feedback = "hit-rate"`. A no-op
        // under the default `feedback = "none"`.
        sys.cluster.observe_outcome(r.tier, sys.last_hit, &r.chunks, step);
    }

    // --- generate ---
    let inputs = StrategyInputs {
        arm,
        retrieved: r.chunks,
        context_chars: r.context_chars,
        community_content: r.community,
        question_tokens: qa.length_tokens,
        net_user_edge_s: sys.net.delay_ms(Link::UserToEdge(edge_id), step) / 1000.0,
        net_edge_edge_s: r.edge_edge_s,
        net_edge_cloud_s: sys.net.delay_ms(Link::EdgeToCloud(edge_id), step) / 1000.0,
        edge_params_b: sys.edge_params_b,
        cloud_params_b: sys.cloud_params_b,
        rates: &sys.rates,
        cost: &sys.cost,
    };
    let outcome = execute(inputs, &mut sys.rng);

    // --- grade ---
    let capability = match arm.gen {
        GenLoc::EdgeSlm => sys.edge_capability,
        GenLoc::CloudLlm => sys.cloud_capability,
    };
    let correct = sys.oracle.judge(
        sys.corpus.spec.profile,
        qa,
        capability,
        &outcome.retrieved,
        outcome.source,
        step,
    );

    // --- update ---
    policy.post_query(
        &mut sys.cluster,
        &mut sys.cloud,
        &sys.corpus,
        &mut sys.community_marked,
        step,
        edge_id,
        qa_id,
    );

    (outcome, correct)
}

/// Result of one gated pipeline step.
pub struct GatedStep {
    pub outcome: Outcome,
    pub correct: bool,
    /// The arm actually served (post-override).
    pub arm_idx: usize,
    /// The gate explored (warm-up): excluded from exploitation stats.
    pub explored: bool,
}

/// Gate + execute one query: build the gate context, let SafeOBO
/// decide (optionally overridden, e.g. by admission downgrade), run
/// [`exec_query`], and feed the observation back to the gate.
pub fn gated_step(
    sys: &mut SimSystem,
    gate: &mut SafeObo,
    qa_id: QaId,
    edge_id: usize,
    step: usize,
    override_idx: Option<usize>,
    sink: &mut dyn StageSink,
) -> GatedStep {
    let ctx = sys.gate_context(qa_id, edge_id, step);
    let decision = gate.decide(&ctx);
    let arm_idx = override_idx.unwrap_or(decision.arm_idx);
    let arm = gate.arms[arm_idx];
    let (outcome, correct) = exec_query(sys, qa_id, edge_id, step, arm, sink);
    gate.observe(
        &ctx,
        arm_idx,
        Observation {
            resource_cost: outcome.resource_cost,
            delay_cost: outcome.delay_cost,
            accuracy: if correct { 1.0 } else { 0.0 },
            delay_s: outcome.delay_s,
        },
    );
    GatedStep { outcome, correct, arm_idx, explored: decision.explored }
}
