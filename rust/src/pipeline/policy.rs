//! The knowledge-plane seam: pre-query gossip cadence and post-query
//! adaptive updates, extracted from the triplicated `KnowledgeMode`
//! match in `SimSystem::serve` / `run_eaco` / the serving plane.

use std::collections::HashSet;

use crate::cloud::CloudNode;
use crate::cluster::{EdgeCluster, GossipRound};
use crate::corpus::{ChunkId, Corpus, QaId};
use crate::sim::KnowledgeMode;

/// How the Update stage maintains edge stores across queries. The
/// variants map 1:1 onto [`KnowledgeMode`]; the policy is the pipeline's
/// view of the mode (what to do around a query), while the mode remains
/// the system-construction switch (which planes get built).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KnowledgePolicy {
    /// Static provisioning only: no background work, no updates.
    Static,
    /// Cloud-triggered FIFO pushes straight into the home store
    /// (paper-faithful EACO-RAG adaptive updates).
    AdaptiveFifo,
    /// Versioned placement + delta gossip through the cluster control
    /// plane ([`crate::cluster`]).
    Collaborative,
}

impl KnowledgePolicy {
    pub fn from_mode(mode: KnowledgeMode) -> KnowledgePolicy {
        match mode {
            KnowledgeMode::Static => KnowledgePolicy::Static,
            KnowledgeMode::Adaptive => KnowledgePolicy::AdaptiveFifo,
            KnowledgeMode::Collaborative => KnowledgePolicy::Collaborative,
        }
    }

    /// Pre-query background work: run a due gossip round so the query
    /// sees post-round stores (virtual-time cadence). Returns the round
    /// report when one ran, for the event stream / serving plane.
    pub fn pre_query(
        self,
        cluster: &mut EdgeCluster,
        corpus: &Corpus,
        step: usize,
    ) -> Option<GossipRound> {
        if self == KnowledgePolicy::Collaborative && cluster.gossip_due(step) {
            Some(cluster.run_gossip_round(corpus, step))
        } else {
            None
        }
    }

    /// Post-query knowledge update: ask the cloud distributor whether
    /// this query triggers a plan, then apply it per policy. Chunks that
    /// arrive this way are marked as community-distributed content.
    pub fn post_query(
        self,
        cluster: &mut EdgeCluster,
        cloud: &mut CloudNode,
        corpus: &Corpus,
        community_marked: &mut [HashSet<ChunkId>],
        step: usize,
        edge_id: usize,
        qa_id: QaId,
    ) {
        match self {
            KnowledgePolicy::Static => {}
            KnowledgePolicy::AdaptiveFifo => {
                if let Some(plan) = cloud.record_query(corpus, edge_id, qa_id) {
                    // Paper-faithful direct FIFO push (seed semantics).
                    cluster.nodes[plan.edge_id].apply_update(corpus, &plan.chunks);
                    let marked = &mut community_marked[plan.edge_id];
                    for &c in &plan.chunks {
                        marked.insert(c);
                    }
                }
            }
            KnowledgePolicy::Collaborative => {
                if let Some(plan) = cloud.record_query(corpus, edge_id, qa_id) {
                    // Versioned publication through the placement
                    // engine; gossip spreads it onward from here.
                    cluster.apply_cloud_update(corpus, step, &plan);
                    let marked = &mut community_marked[plan.edge_id];
                    for &c in &plan.chunks {
                        marked.insert(c);
                    }
                }
            }
        }
    }
}
