//! The typed per-stage event stream and its observers.
//!
//! Every driver narrates its run as a sequence of [`StageEvent`]s —
//! admission verdicts, tier choices, recall probes, gossip rounds,
//! fault applications, completions — and observers implement
//! [`StageSink`] to fold that stream into whatever surface they own.
//! The four built-in sinks are [`StatsSink`] (the `RunStats`
//! accumulator shared by every driver), `ServeMetrics` (queueing
//! observability; impl in [`crate::serve::metrics`]), `ChaosProbe`
//! (recovery/staleness probes; impl in [`crate::chaos::probe`]), and
//! [`FeedbackSink`] (an external fold of the adaptive-knowledge
//! feedback counters — the live loop uses the cluster-owned copy fed
//! from [`crate::pipeline::exec_query`]).
//!
//! Sinks are pure folds: they never touch the simulator, consume no
//! RNG, and receive events in strict workload order regardless of the
//! serving plane's worker count — so attaching or detaching a sink can
//! never perturb a run's bit-identical digests.

use crate::chaos::FaultEvent;
use crate::edge::semantic::AnnProbe;
use crate::serve::session::Session;
use crate::sim::strategy::Outcome;
use crate::sim::RunStats;

/// One typed pipeline event. Borrowed payloads (`Outcome`, `Session`,
/// `FaultEvent`) are valid only for the duration of the `emit` call;
/// sinks clone what they keep.
///
/// Fields stamped by the serving plane only (`arrival_ms`,
/// `store_empty`, `version_lag`) are zero/`false`/`None` when a
/// synchronous driver emits the event — no synchronous driver attaches
/// a sink that reads them.
#[derive(Debug)]
pub enum StageEvent<'a> {
    /// A workload arrival entered the pipeline (pre-admission).
    /// `depth` is the in-flight queue depth observed at arrival
    /// (always 0 from the synchronous drivers).
    Arrival { seq: usize, edge_id: usize, step: usize, now_ms: f64, depth: usize },
    /// Admission verdict: the query passed every check.
    Admitted { seq: usize },
    /// Admission verdict: accepted, but rewritten to the cheap local
    /// arm (`[serve] admission = "downgrade"`).
    Downgraded { seq: usize },
    /// The home edge was dead; the query was rerouted to the nearest
    /// alive peer.
    Rerouted { seq: usize, from: usize, to: usize },
    /// Terminal: the query was shed (reason + stamps in the session).
    SessionShed { session: &'a Session },
    /// A gossip round executed (due-at-arrival cadence). `version_lag`
    /// is sampled post-round only when a chaos probe is attached.
    GossipRound { step: usize, round: usize, wire_bytes: usize, version_lag: Option<u64> },
    /// A scheduled fault was applied to the cluster/net planes.
    /// `version_lag` is sampled right after application.
    FaultApplied { event: &'a FaultEvent, now_ms: f64, version_lag: u64 },
    /// The retrieval stage picked a tier for this query and `hit` says
    /// whether the retrieved set contained a supporting chunk.
    TierChosen { step: usize, edge_id: usize, tier: usize, hit: bool },
    /// The ANN path answered this query's retrieval (recall accounting).
    RecallProbe { step: usize, probe: AnnProbe },
    /// The query finished every stage. `explored` flags gate warm-up
    /// queries (excluded from stats, exactly as `run_eaco` does);
    /// `store_empty` reports the served edge's post-update store state
    /// (closes chaos recovery windows).
    QueryDone {
        seq: usize,
        edge_id: usize,
        arrival_ms: f64,
        outcome: &'a Outcome,
        correct: bool,
        arm_idx: usize,
        explored: bool,
        tier: usize,
        hit: bool,
        ann: Option<AnnProbe>,
        store_empty: bool,
    },
    /// The serving plane closed this query's session (final stamps).
    SessionDone { session: &'a Session },
}

/// An observer over the pipeline's event stream.
pub trait StageSink {
    fn emit(&mut self, ev: &StageEvent<'_>);
}

/// The no-op sink (synchronous single-query paths).
pub struct NullSink;

impl StageSink for NullSink {
    fn emit(&mut self, _ev: &StageEvent<'_>) {}
}

/// Folds [`StageEvent::QueryDone`] into a [`RunStats`] — the one
/// accumulator shared by `run_baseline`, `run_eaco`, and
/// `serve_workload` (previously three hand-rolled copies).
pub struct StatsSink {
    stats: RunStats,
    correct_n: usize,
    /// Gated runs count arm usage and exclude exploration queries.
    gated: bool,
}

impl StatsSink {
    pub fn new(num_arms: usize, gated: bool) -> StatsSink {
        StatsSink {
            stats: RunStats { arm_counts: vec![0; num_arms], ..Default::default() },
            correct_n: 0,
            gated,
        }
    }

    /// Finalize the accuracy ratio and hand the stats back.
    pub fn finish(mut self) -> RunStats {
        self.stats.accuracy = if self.stats.queries == 0 {
            0.0
        } else {
            self.correct_n as f64 / self.stats.queries as f64
        };
        self.stats
    }
}

impl StageSink for StatsSink {
    fn emit(&mut self, ev: &StageEvent<'_>) {
        let StageEvent::QueryDone { outcome, correct, arm_idx, explored, tier, hit, ann, .. } = ev
        else {
            return;
        };
        if self.gated {
            if *explored {
                return;
            }
            self.stats.arm_counts[*arm_idx] += 1;
        }
        if *correct {
            self.correct_n += 1;
        }
        let s = &mut self.stats;
        s.queries += 1;
        s.delay.push(outcome.delay_s);
        s.resource_cost.push(outcome.resource_cost);
        s.total_cost.push(outcome.total_cost);
        s.in_tokens.push(outcome.tokens.input);
        s.out_tokens.push(outcome.tokens.output);
        s.tier_queries[*tier] += 1;
        if *hit {
            s.tier_hits[*tier] += 1;
        }
        if let Some(p) = ann {
            s.ann_queries += 1;
            s.ann_recall.push(p.recall_at_k);
            if p.exact_fallback {
                s.ann_exact_fallbacks += 1;
            }
        }
    }
}

/// Folds tier outcomes, completions, and gossip rounds into a
/// [`FeedbackState`](crate::cluster::feedback::FeedbackState) — the
/// sink embodiment of the adaptive-knowledge loop's observer half.
///
/// The *live* loop (gate-observed hit rates driving per-link gossip
/// budgets) uses the `EdgeCluster`-owned state fed at a fixed point in
/// `exec_query`, because sinks are pure folds that must never mutate
/// the simulator. This sink builds the identical counters from the
/// event stream alone, so harnesses (A/B demos, chaos reports, offline
/// analysis) can inspect what the loop *would* learn on any run —
/// including `feedback = "none"` runs — without touching cluster
/// state. `TierChosen` carries no chunk ids, so the per-chunk hit
/// contribution stays empty here; tier hit/miss pressure and link
/// usefulness are byte-for-byte the same arithmetic.
pub struct FeedbackSink {
    pub state: crate::cluster::feedback::FeedbackState,
    /// Terminal completions folded (all arms, exploration included).
    pub queries: u64,
    /// Gossip rounds observed on the stream.
    pub gossip_rounds: u64,
    /// Total gossip wire bytes observed on the stream.
    pub gossip_bytes: usize,
}

impl FeedbackSink {
    pub fn new(num_edges: usize, half_life_steps: f64, min_hot_k: usize) -> FeedbackSink {
        FeedbackSink {
            state: crate::cluster::feedback::FeedbackState::new(
                num_edges,
                half_life_steps,
                min_hot_k,
            ),
            queries: 0,
            gossip_rounds: 0,
            gossip_bytes: 0,
        }
    }
}

impl StageSink for FeedbackSink {
    fn emit(&mut self, ev: &StageEvent<'_>) {
        match ev {
            StageEvent::TierChosen { step, tier, hit, .. } => {
                self.state.observe_query(*tier, *hit, &[], *step);
            }
            StageEvent::QueryDone { .. } => self.queries += 1,
            StageEvent::GossipRound { wire_bytes, .. } => {
                self.gossip_rounds += 1;
                self.gossip_bytes += wire_bytes;
            }
            _ => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::CostModel;
    use crate::gating::{Arm, GenLoc, Retrieval};
    use crate::sim::strategy::{execute, GenRates, StrategyInputs};
    use crate::sim::TIER_LOCAL;
    use crate::util::rng::Rng;

    fn outcome() -> Outcome {
        let rates = GenRates::default();
        let cost = CostModel::default();
        let mut rng = Rng::new(7);
        execute(
            StrategyInputs {
                arm: Arm { retrieval: Retrieval::LocalNaive, gen: GenLoc::EdgeSlm },
                retrieved: vec![1, 2],
                context_chars: 400,
                community_content: false,
                question_tokens: 24,
                net_user_edge_s: 0.02,
                net_edge_edge_s: 0.0,
                net_edge_cloud_s: 0.1,
                edge_params_b: 3.0,
                cloud_params_b: 72.0,
                rates: &rates,
                cost: &cost,
            },
            &mut rng,
        )
    }

    fn done(o: &Outcome, correct: bool, explored: bool) -> StageEvent<'_> {
        StageEvent::QueryDone {
            seq: 0,
            edge_id: 0,
            arrival_ms: 0.0,
            outcome: o,
            correct,
            arm_idx: 1,
            explored,
            tier: TIER_LOCAL,
            hit: true,
            ann: None,
            store_empty: false,
        }
    }

    #[test]
    fn stats_sink_accumulates_and_finalizes() {
        let o = outcome();
        let mut sink = StatsSink::new(1, false);
        sink.emit(&done(&o, true, false));
        sink.emit(&done(&o, false, false));
        // Non-terminal events are ignored by the stats fold.
        sink.emit(&StageEvent::Admitted { seq: 2 });
        let stats = sink.finish();
        assert_eq!(stats.queries, 2);
        assert!((stats.accuracy - 0.5).abs() < 1e-12);
        assert_eq!(stats.tier_queries[TIER_LOCAL], 2);
        assert_eq!(stats.tier_hits[TIER_LOCAL], 2);
        assert_eq!(stats.arm_counts, vec![0], "ungated runs keep no arm histogram");
    }

    #[test]
    fn gated_sink_skips_exploration_and_counts_arms() {
        let o = outcome();
        let mut sink = StatsSink::new(5, true);
        sink.emit(&done(&o, true, true)); // exploration: excluded
        sink.emit(&done(&o, true, false));
        let stats = sink.finish();
        assert_eq!(stats.queries, 1);
        assert_eq!(stats.arm_counts[1], 1);
        assert!((stats.accuracy - 1.0).abs() < 1e-12);
    }

    #[test]
    fn feedback_sink_folds_tier_and_gossip_events() {
        use crate::sim::TIER_NEIGHBOR;
        let o = outcome();
        let mut sink = FeedbackSink::new(4, 50.0, 2);
        // Two local hits, one neighbor miss, at the same step.
        sink.emit(&StageEvent::TierChosen { step: 10, edge_id: 0, tier: TIER_LOCAL, hit: true });
        sink.emit(&StageEvent::TierChosen { step: 10, edge_id: 1, tier: TIER_LOCAL, hit: true });
        sink.emit(&StageEvent::TierChosen {
            step: 10,
            edge_id: 2,
            tier: TIER_NEIGHBOR,
            hit: false,
        });
        sink.emit(&StageEvent::GossipRound {
            step: 10,
            round: 0,
            wire_bytes: 96,
            version_lag: None,
        });
        sink.emit(&done(&o, true, false));
        assert_eq!(sink.queries, 1);
        assert_eq!(sink.gossip_rounds, 1);
        assert_eq!(sink.gossip_bytes, 96);
        let local = sink.state.tier_hit_rate(TIER_LOCAL, 10).expect("observed tier");
        assert!((local - 1.0).abs() < 1e-12);
        let neighbor = sink.state.tier_hit_rate(TIER_NEIGHBOR, 10).expect("observed tier");
        assert!(neighbor.abs() < 1e-12);
        // 1 miss out of 3 edge-tier observations.
        assert!((sink.state.edge_miss_pressure(10) - 1.0 / 3.0).abs() < 1e-9);
        // Non-feedback events are ignored by the fold.
        sink.emit(&StageEvent::Admitted { seq: 9 });
        assert_eq!(sink.queries, 1);
    }

    #[test]
    fn null_sink_accepts_everything() {
        let o = outcome();
        NullSink.emit(&done(&o, true, false));
        NullSink.emit(&StageEvent::Downgraded { seq: 0 });
    }
}
