//! Algorithm 1: Collaborative Gating SafeOBO.
//!
//! Faithful implementation of the paper's algorithm:
//!
//! * **Warm-up (t ≤ T₀)** — observe context, select a *random* arm,
//!   observe (response time, accuracy, resource cost, delay cost),
//!   update the three GP posteriors y⁽⁰⁾ (total cost), y⁽¹⁾ (accuracy),
//!   y⁽²⁾ (response time).
//! * **Exploitation (t > T₀)** — estimate the safe set (Eq. 3)
//!   `S_t = S₀ ∪ {x : μ⁽¹⁾ − βσ⁽¹⁾ ≥ QoSᵖ_min ∧ μ⁽²⁾ + βσ⁽²⁾ ≤ QoSʰ_max}`
//!   then pick `x_t = argmin_{x∈S_t} μ⁽⁰⁾ − β_t σ⁽⁰⁾` (Eq. 4, an
//!   optimistic lower confidence bound on cost).
//!
//! `S₀` is the seed safe set: the most conservative arm (cloud GraphRAG
//! + cloud LLM) is always admissible, mirroring the paper's assumption
//! that a known-safe fallback exists.

use super::gp::{Gp, GpScratch, Kernel};
use super::{Arm, GateContext};
use crate::util::rng::Rng;

/// QoS constraints (paper Eq. 2).
#[derive(Clone, Copy, Debug)]
pub struct Qos {
    /// QoSᵖ_min: minimum acceptable accuracy (probability).
    pub min_accuracy: f64,
    /// QoSʰ_max: maximum acceptable response time (seconds).
    pub max_delay_s: f64,
}

/// One observation fed back to the gate after serving a query.
#[derive(Clone, Copy, Debug)]
pub struct Observation {
    /// u_r: resource cost (TFLOPs).
    pub resource_cost: f64,
    /// u_d: time cost (delay · GPU TFLOPS).
    pub delay_cost: f64,
    /// ρ_t: graded accuracy (0/1 from the judge).
    pub accuracy: f64,
    /// h_t: end-to-end response time (seconds).
    pub delay_s: f64,
}

/// Decision record (for tracing / Table 7 style output).
#[derive(Clone, Debug)]
pub struct Decision {
    pub arm_idx: usize,
    pub explored: bool,
    pub safe_set: Vec<usize>,
    /// (μ_cost, σ_cost) per arm at decision time (empty during warm-up).
    pub cost_posterior: Vec<(f64, f64)>,
}

/// Per-arm GP triplet (cost y⁽⁰⁾, accuracy y⁽¹⁾, delay y⁽²⁾).
///
/// One independent triplet per arm avoids two failure modes of a single
/// shared GP over (context ⊕ one-hot arm): cross-arm bleed through the
/// kernel, and sliding-window eviction of rarely-picked arms' history
/// once the exploitation phase concentrates on a favourite.
struct ArmGps {
    cost: Gp,
    acc: Gp,
    delay: Gp,
}

impl ArmGps {
    fn new(window: usize) -> ArmGps {
        ArmGps {
            cost: Gp::new(
                Kernel { sf2: 0.5, length_scale: 0.7, noise: 0.02 },
                1.0, // pessimistic prior cost (normalized)
                window,
            ),
            acc: Gp::new(
                Kernel { sf2: 0.2, length_scale: 0.7, noise: 0.10 },
                0.5,
                window,
            ),
            delay: Gp::new(
                Kernel { sf2: 0.5, length_scale: 0.7, noise: 0.05 },
                2.0, // pessimistic prior delay (s)
                window,
            ),
        }
    }
}

/// The SafeOBO gate.
pub struct SafeObo {
    pub arms: Vec<Arm>,
    pub qos: Qos,
    /// Exploration parameter β (Eq. 3/4).
    pub beta: f64,
    /// Warm-up length T₀.
    pub t0: usize,
    /// δ₁, δ₂ (Eq. 1).
    pub delta1: f64,
    pub delta2: f64,
    /// Cost normalization scale (keeps the GP O(1)).
    pub cost_scale: f64,
    /// Seed safe arm indices (S₀).
    pub seed_safe: Vec<usize>,
    gps: Vec<ArmGps>,
    step: usize,
    rng: Rng,
    /// Shared GP workspace: one decision queries 3 GPs × |arms| and
    /// reuses these buffers for every query instead of allocating.
    scratch: GpScratch,
    /// Reusable per-decision posterior buffer (taken/restored around
    /// `decide` so the borrow checker allows `predict_many(&mut self)`).
    posterior_buf: Vec<ArmPosterior>,
}

/// Per-arm posterior triple computed by [`SafeObo::predict_many`]:
/// (μ, σ) for accuracy, delay, and (normalized) cost.
#[derive(Clone, Copy, Debug)]
pub struct ArmPosterior {
    pub acc: (f64, f64),
    pub delay: (f64, f64),
    pub cost: (f64, f64),
}

impl SafeObo {
    pub fn new(arms: Vec<Arm>, qos: Qos, t0: usize, beta: f64, seed: u64) -> SafeObo {
        let num_arms = arms.len();
        // Conservative fallback: last arm (cloud-graph+llm) is seed-safe.
        let seed_safe = vec![num_arms - 1];
        let window = 500;
        SafeObo {
            arms,
            qos,
            beta,
            t0,
            delta1: 1.0,
            delta2: 1.0,
            cost_scale: 500.0,
            seed_safe,
            gps: (0..num_arms).map(|_| ArmGps::new(window)).collect(),
            step: 0,
            rng: Rng::new(seed).fork("safeobo"),
            scratch: GpScratch::default(),
            posterior_buf: Vec::new(),
        }
    }

    /// Batch posterior over all arms for one context: every GP query in
    /// the decision shares the gate's single workspace, so a full
    /// decision performs no per-arm allocation. Appends into `out`
    /// (cleared first) so the caller can reuse its buffer as well.
    pub fn predict_many(&mut self, ctx: &GateContext, out: &mut Vec<ArmPosterior>) {
        let za = ctx.acc_features();
        let zd = ctx.delay_features();
        let zc = ctx.cost_features();
        out.clear();
        out.reserve(self.gps.len());
        for g in &self.gps {
            out.push(ArmPosterior {
                acc: g.acc.predict_with(&za, &mut self.scratch),
                delay: g.delay.predict_with(&zd, &mut self.scratch),
                cost: g.cost.predict_with(&zc, &mut self.scratch),
            });
        }
    }

    pub fn step_count(&self) -> usize {
        self.step
    }

    pub fn in_warmup(&self) -> bool {
        self.step < self.t0
    }

    /// Algorithm 1 decision step.
    pub fn decide(&mut self, ctx: &GateContext) -> Decision {
        let n = self.arms.len();
        if self.in_warmup() {
            // Warm-up: random arm (line 5).
            let arm = self.rng.below(n);
            return Decision {
                arm_idx: arm,
                explored: true,
                safe_set: (0..n).collect(),
                cost_posterior: Vec::new(),
            };
        }

        // Safe-set estimation (Eq. 3, line 17). Each GP family sees its
        // own low-dimensional feature subspace (see GateContext); all
        // 3·n posterior queries share the gate's workspace, and the
        // posterior list reuses the gate-held buffer across decisions.
        let mut arm_posteriors = std::mem::take(&mut self.posterior_buf);
        self.predict_many(ctx, &mut arm_posteriors);
        let mut safe: Vec<usize> = Vec::new();
        let mut posteriors = Vec::with_capacity(n);
        for (a, p) in arm_posteriors.iter().enumerate() {
            let (mu_acc, sd_acc) = p.acc;
            let (mu_del, sd_del) = p.delay;
            posteriors.push(p.cost);
            let acc_ok = mu_acc - self.beta * sd_acc >= self.qos.min_accuracy;
            let delay_ok = mu_del + self.beta * sd_del <= self.qos.max_delay_s;
            if acc_ok && delay_ok {
                safe.push(a);
            }
        }
        // S_t = S₀ ∪ {…}.
        for &s in &self.seed_safe {
            if !safe.contains(&s) {
                safe.push(s);
            }
        }
        safe.sort_unstable();

        self.posterior_buf = arm_posteriors;

        // Acquisition (Eq. 4, line 19): optimistic cost LCB over S_t.
        let mut best = safe[0];
        let mut best_score = f64::INFINITY;
        for &a in &safe {
            let (mu, sd) = posteriors[a];
            let score = mu - self.beta * sd;
            if score < best_score {
                best_score = score;
                best = a;
            }
        }
        Decision {
            arm_idx: best,
            explored: false,
            safe_set: safe,
            cost_posterior: posteriors,
        }
    }

    /// Posterior update (lines 8–11 / 21–25).
    pub fn observe(&mut self, ctx: &GateContext, arm_idx: usize, obs: Observation) {
        let total_cost = self.delta1 * obs.resource_cost + self.delta2 * obs.delay_cost;
        let g = &mut self.gps[arm_idx];
        g.cost.observe(ctx.cost_features(), total_cost / self.cost_scale);
        g.acc.observe(ctx.acc_features(), obs.accuracy);
        g.delay.observe(ctx.delay_features(), obs.delay_s);
        self.step += 1;
    }

    /// Full posterior (mean, sd) triple for one arm: accuracy, delay,
    /// cost (unnormalized). Used for tracing and Table-7 style output.
    pub fn predict_arm_full(
        &self,
        ctx: &GateContext,
        arm_idx: usize,
    ) -> ((f64, f64), (f64, f64), (f64, f64)) {
        let g = &self.gps[arm_idx];
        let acc = g.acc.predict(&ctx.acc_features());
        let delay = g.delay.predict(&ctx.delay_features());
        let (cm, cs) = g.cost.predict(&ctx.cost_features());
        (acc, delay, (cm * self.cost_scale, cs * self.cost_scale))
    }

    /// Posterior accuracy/delay/cost prediction for one arm (tracing).
    pub fn predict_arm(&self, ctx: &GateContext, arm_idx: usize) -> (f64, f64, f64) {
        let g = &self.gps[arm_idx];
        let (acc, _) = g.acc.predict(&ctx.acc_features());
        let (delay, _) = g.delay.predict(&ctx.delay_features());
        let (cost, _) = g.cost.predict(&ctx.cost_features());
        (acc, delay, cost * self.cost_scale)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gating::standard_arms;

    fn ctx(overlap: f64, hops: usize) -> GateContext {
        GateContext {
            cloud_delay_ms: 300.0,
            edge_delay_ms: 20.0,
            best_overlap: overlap,
            best_edge_is_local: true,
            local_overlap: overlap,
            neighbor_overlap: 0.0,
            hops,
            length_tokens: 12,
            entity_count: 3,
        }
    }

    /// Synthetic environment: arm 1 (local rag) is cheap and accurate on
    /// high-overlap queries; arm 4 (cloud) always accurate but expensive.
    fn env(arm: usize, c: &GateContext) -> Observation {
        let accurate = match arm {
            0 => c.best_overlap > 0.95 && c.hops == 1, // slm-only rarely enough
            1 | 2 => c.best_overlap > 0.6 && c.hops <= 2,
            _ => true,
        };
        let (rc, dc, delay) = match arm {
            0 => (0.6, 0.03, 0.3),
            1 => (23.0, 0.6, 0.9),
            2 => (23.0, 0.9, 1.0),
            3 => (60.0, 3.0, 2.8),
            _ => (711.0, 9.7, 1.0),
        };
        Observation {
            resource_cost: rc,
            delay_cost: dc,
            accuracy: if accurate { 1.0 } else { 0.0 },
            delay_s: delay,
        }
    }

    fn train(gate: &mut SafeObo, steps: usize) {
        let mut r = Rng::new(9);
        for _ in 0..steps {
            let c = ctx(
                if r.chance(0.7) { 0.9 } else { 0.2 },
                if r.chance(0.7) { 1 } else { 2 },
            );
            let d = gate.decide(&c);
            let o = env(d.arm_idx, &c);
            gate.observe(&c, d.arm_idx, o);
        }
    }

    #[test]
    fn warmup_is_random_then_stops() {
        let mut gate = SafeObo::new(
            standard_arms(),
            Qos { min_accuracy: 0.85, max_delay_s: 5.0 },
            50,
            2.0,
            1,
        );
        let mut arms_seen = std::collections::HashSet::new();
        for _ in 0..50 {
            let c = ctx(0.5, 1);
            let d = gate.decide(&c);
            assert!(d.explored);
            arms_seen.insert(d.arm_idx);
            gate.observe(&c, d.arm_idx, env(d.arm_idx, &c));
        }
        assert!(arms_seen.len() >= 4, "warm-up should explore most arms");
        assert!(!gate.in_warmup());
        assert!(!gate.decide(&ctx(0.5, 1)).explored);
    }

    #[test]
    fn exploitation_picks_cheap_safe_arm_on_easy_queries() {
        let mut gate = SafeObo::new(
            standard_arms(),
            Qos { min_accuracy: 0.80, max_delay_s: 5.0 },
            200,
            1.5,
            2,
        );
        train(&mut gate, 400);
        // Easy query, good local coverage: should avoid the cloud arm.
        let mut cheap = 0;
        for _ in 0..20 {
            let c = ctx(0.9, 1);
            let d = gate.decide(&c);
            if matches!(d.arm_idx, 1 | 2) {
                cheap += 1;
            }
            gate.observe(&c, d.arm_idx, env(d.arm_idx, &c));
        }
        assert!(cheap >= 15, "picked cheap arms only {cheap}/20");
    }

    #[test]
    fn exploitation_escalates_hard_queries() {
        let mut gate = SafeObo::new(
            standard_arms(),
            Qos { min_accuracy: 0.80, max_delay_s: 5.0 },
            200,
            1.5,
            3,
        );
        train(&mut gate, 400);
        let mut cloud = 0;
        for _ in 0..20 {
            let c = ctx(0.1, 3); // no edge coverage, multi-hop
            let d = gate.decide(&c);
            if d.arm_idx >= 3 {
                cloud += 1;
            }
            gate.observe(&c, d.arm_idx, env(d.arm_idx, &c));
        }
        assert!(cloud >= 15, "escalated only {cloud}/20");
    }

    #[test]
    fn delay_constraint_prunes_slow_arms() {
        // Under a strict 1 s budget, arm 3 (cloud-graph+slm, 2.8 s) must
        // leave the safe set after warm-up.
        let mut gate = SafeObo::new(
            standard_arms(),
            Qos { min_accuracy: 0.80, max_delay_s: 1.0 },
            200,
            1.5,
            4,
        );
        train(&mut gate, 500);
        let mut picked3 = 0;
        for _ in 0..30 {
            let c = ctx(0.2, 2);
            let d = gate.decide(&c);
            if d.arm_idx == 3 {
                picked3 += 1;
            }
            gate.observe(&c, d.arm_idx, env(d.arm_idx, &c));
        }
        assert!(picked3 <= 2, "slow arm picked {picked3} times under 1s QoS");
    }

    #[test]
    fn safe_set_always_contains_seed() {
        let mut gate = SafeObo::new(
            standard_arms(),
            Qos { min_accuracy: 0.99, max_delay_s: 0.01 }, // impossible QoS
            10,
            3.0,
            5,
        );
        train(&mut gate, 30);
        let d = gate.decide(&ctx(0.5, 2));
        assert!(d.safe_set.contains(&4), "seed-safe arm missing: {:?}", d.safe_set);
    }

    #[test]
    fn decisions_deterministic_for_seed() {
        let make = || {
            let mut g = SafeObo::new(
                standard_arms(),
                Qos { min_accuracy: 0.8, max_delay_s: 5.0 },
                100,
                2.0,
                7,
            );
            let mut picks = Vec::new();
            let mut r = Rng::new(1);
            for _ in 0..150 {
                let c = ctx(r.f64(), 1 + r.below(3));
                let d = g.decide(&c);
                picks.push(d.arm_idx);
                g.observe(&c, d.arm_idx, env(d.arm_idx, &c));
            }
            picks
        };
        assert_eq!(make(), make());
    }
}
