//! Gaussian-process regression for the collaborative gate (paper §4.2).
//!
//! Each estimated function (cost, accuracy, delay) is modeled as
//! `GP(μ(x), k(x, x'))` with an RBF kernel plus observation noise,
//! following Williams & Rasmussen. Posterior updates are **incremental**:
//! adding an observation extends the Cholesky factor in O(n²) (see
//! `linalg::Cholesky::extend`) instead of refactorizing in O(n³) — this
//! is what keeps the gate's per-query decision cost ≪ 1 ms (§Perf).
//!
//! A sliding observation window bounds memory and compute: when the
//! window overflows, the oldest third is dropped and the factor rebuilt
//! once (amortized O(n²) per step).

use crate::linalg::{dot, Cholesky, Mat};

/// RBF kernel with signal variance `sf2`, length scale `ls`, noise.
#[derive(Clone, Copy, Debug)]
pub struct Kernel {
    pub sf2: f64,
    pub length_scale: f64,
    pub noise: f64,
}

impl Default for Kernel {
    fn default() -> Self {
        Kernel {
            sf2: 1.0,
            length_scale: 0.8,
            noise: 0.05,
        }
    }
}

impl Kernel {
    #[inline]
    pub fn k(&self, a: &[f64], b: &[f64]) -> f64 {
        let mut d2 = 0.0;
        for i in 0..a.len() {
            let d = a[i] - b[i];
            d2 += d * d;
        }
        self.sf2 * (-d2 / (2.0 * self.length_scale * self.length_scale)).exp()
    }
}

/// Reusable workspace for posterior queries. One scratch serves any
/// number of GPs: buffers are cleared (capacity kept) per call, so a
/// steady-state `predict_with` does zero heap allocation. `SafeObo`
/// holds a single scratch and threads it through every per-arm GP query
/// of a decision step.
#[derive(Clone, Debug, Default)]
pub struct GpScratch {
    /// k(x*, X) — kernel column against the training set.
    kstar: Vec<f64>,
    /// Forward-substitution vector v = L⁻¹ k*.
    v: Vec<f64>,
}

/// A GP posterior over scalar observations.
pub struct Gp {
    pub kernel: Kernel,
    xs: Vec<Vec<f64>>,
    ys: Vec<f64>,
    /// Prior mean (observations are centered on it).
    pub prior_mean: f64,
    chol: Option<Cholesky>,
    alpha: Vec<f64>,
    /// Max observations before the sliding window trims.
    pub max_obs: usize,
    /// Kernel-column workspace for `observe` (incremental extend).
    colbuf: Vec<f64>,
    /// Fallback workspace so the scratch-less `predict` stays
    /// allocation-free in steady state too.
    own_scratch: std::cell::RefCell<GpScratch>,
}

impl Gp {
    pub fn new(kernel: Kernel, prior_mean: f64, max_obs: usize) -> Gp {
        Gp {
            kernel,
            xs: Vec::new(),
            ys: Vec::new(),
            prior_mean,
            chol: None,
            alpha: Vec::new(),
            max_obs: max_obs.max(8),
            colbuf: Vec::new(),
            own_scratch: std::cell::RefCell::new(GpScratch::default()),
        }
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    /// Add an observation; O(n²) incremental Cholesky growth. Steady
    /// state allocates only the caller-provided `x` (kernel column,
    /// substitution vectors, and alpha all reuse held buffers).
    pub fn observe(&mut self, x: Vec<f64>, y: f64) {
        if self.xs.len() >= self.max_obs {
            // Drop the oldest third, rebuild once.
            let drop = self.max_obs / 3;
            self.xs.drain(..drop);
            self.ys.drain(..drop);
            self.chol = None;
        }
        self.xs.push(x);
        self.ys.push(y);
        if let Some(ch) = &mut self.chol {
            let n = self.xs.len() - 1;
            let newx = &self.xs[n];
            self.colbuf.clear();
            let kernel = self.kernel;
            self.colbuf
                .extend(self.xs[..n].iter().map(|xi| kernel.k(xi, newx)));
            let diag = kernel.k(newx, newx) + kernel.noise;
            if !ch.extend(&self.colbuf, diag) {
                self.chol = None; // numeric trouble: rebuild below
            }
        }
        if self.chol.is_none() {
            self.rebuild();
        }
        self.refresh_alpha();
    }

    fn rebuild(&mut self) {
        let n = self.xs.len();
        let mut k = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let v = self.kernel.k(&self.xs[i], &self.xs[j]);
                k[(i, j)] = v;
                k[(j, i)] = v;
            }
            k[(i, i)] += self.kernel.noise;
        }
        self.chol = Cholesky::new(&k);
        if self.chol.is_none() {
            // Jitter retry (rare; keeps the gate alive on degeneracy).
            for i in 0..n {
                k[(i, i)] += 1e-6;
            }
            self.chol = Cholesky::new(&k);
        }
    }

    fn refresh_alpha(&mut self) {
        if let Some(ch) = &self.chol {
            // alpha = K⁻¹ (y − μ₀), solved in place in the alpha buffer.
            self.alpha.clear();
            self.alpha
                .extend(self.ys.iter().map(|y| y - self.prior_mean));
            ch.solve_in_place(&mut self.alpha);
        }
    }

    /// Posterior mean and standard deviation at `x`.
    ///
    /// Allocation-free in steady state via an internal workspace; when
    /// querying several GPs in one decision, prefer [`Gp::predict_with`]
    /// and share one [`GpScratch`] across all of them.
    pub fn predict(&self, x: &[f64]) -> (f64, f64) {
        let mut scratch = self.own_scratch.borrow_mut();
        self.predict_with(x, &mut scratch)
    }

    /// Posterior mean and standard deviation at `x`, using a
    /// caller-provided workspace (no allocation once warm).
    pub fn predict_with(&self, x: &[f64], scratch: &mut GpScratch) -> (f64, f64) {
        let n = self.xs.len();
        let prior_sd = (self.kernel.sf2 + self.kernel.noise).sqrt();
        if n == 0 {
            return (self.prior_mean, prior_sd);
        }
        let ch = match &self.chol {
            Some(c) => c,
            None => return (self.prior_mean, prior_sd),
        };
        scratch.kstar.clear();
        scratch
            .kstar
            .extend(self.xs.iter().map(|xi| self.kernel.k(xi, x)));
        let mu = self.prior_mean + dot(&scratch.kstar, &self.alpha);
        scratch.v.clear();
        scratch.v.extend_from_slice(&scratch.kstar);
        ch.solve_lower_in_place(&mut scratch.v);
        // Latent-function variance (no observation noise): repeated
        // observations at the same x genuinely shrink the bound — this is
        // what lets the SafeOBO safe set tighten (Eq. 3).
        let var = (self.kernel.k(x, x) - dot(&scratch.v, &scratch.v)).max(1e-12);
        (mu, var.sqrt())
    }

    /// Batch posterior: predict at every point of `xs`, reusing one
    /// workspace across the whole batch. Appends to `out` after
    /// clearing it, so the result buffer is reusable too.
    pub fn predict_many(
        &self,
        xs: &[Vec<f64>],
        scratch: &mut GpScratch,
        out: &mut Vec<(f64, f64)>,
    ) {
        out.clear();
        out.reserve(xs.len());
        for x in xs {
            out.push(self.predict_with(x, scratch));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn f(x: f64) -> f64 {
        (2.0 * x).sin()
    }

    #[test]
    fn fits_smooth_function() {
        let mut gp = Gp::new(
            Kernel {
                sf2: 1.0,
                length_scale: 0.5,
                noise: 1e-4,
            },
            0.0,
            500,
        );
        for i in 0..40 {
            let x = i as f64 / 40.0 * 3.0;
            gp.observe(vec![x], f(x));
        }
        for i in 0..10 {
            let x = 0.15 + i as f64 / 10.0 * 2.5;
            let (mu, sd) = gp.predict(&[x]);
            assert!((mu - f(x)).abs() < 0.1, "x={x}: {mu} vs {}", f(x));
            assert!(sd < 0.2);
        }
    }

    #[test]
    fn uncertainty_grows_off_data() {
        let mut gp = Gp::new(Kernel::default(), 0.0, 500);
        for i in 0..20 {
            gp.observe(vec![i as f64 * 0.1], 1.0);
        }
        let (_, sd_near) = gp.predict(&[1.0]);
        let (_, sd_far) = gp.predict(&[50.0]);
        assert!(sd_far > sd_near * 2.0, "near {sd_near} far {sd_far}");
    }

    #[test]
    fn prior_mean_respected_far_away() {
        let mut gp = Gp::new(Kernel::default(), 5.0, 500);
        gp.observe(vec![0.0], 7.0);
        let (mu_far, _) = gp.predict(&[100.0]);
        assert!((mu_far - 5.0).abs() < 0.1);
    }

    #[test]
    fn empty_predicts_prior() {
        let gp = Gp::new(Kernel::default(), 2.5, 100);
        let (mu, sd) = gp.predict(&[0.3, 0.4]);
        assert_eq!(mu, 2.5);
        assert!(sd > 0.9);
    }

    #[test]
    fn incremental_matches_batch() {
        // Observing one-by-one must match a fresh GP with all points.
        let mut rng = Rng::new(3);
        let pts: Vec<(Vec<f64>, f64)> = (0..30)
            .map(|_| {
                let x = vec![rng.f64() * 2.0, rng.f64() * 2.0];
                let y = x[0] - x[1] + 0.1 * rng.normal();
                (x, y)
            })
            .collect();
        let mut inc = Gp::new(Kernel::default(), 0.0, 500);
        for (x, y) in &pts {
            inc.observe(x.clone(), *y);
        }
        let mut batch = Gp::new(Kernel::default(), 0.0, 500);
        for (x, y) in &pts {
            batch.xs.push(x.clone());
            batch.ys.push(*y);
        }
        batch.rebuild();
        batch.refresh_alpha();
        for probe in [[0.5, 0.5], [1.5, 0.2], [0.1, 1.9]] {
            let (m1, s1) = inc.predict(&probe);
            let (m2, s2) = batch.predict(&probe);
            assert!((m1 - m2).abs() < 1e-8, "{m1} vs {m2}");
            assert!((s1 - s2).abs() < 1e-8);
        }
    }

    #[test]
    fn window_trims_and_survives() {
        let mut gp = Gp::new(Kernel::default(), 0.0, 30);
        for i in 0..100 {
            gp.observe(vec![(i % 10) as f64], (i % 3) as f64);
        }
        assert!(gp.len() <= 30);
        let (mu, sd) = gp.predict(&[5.0]);
        assert!(mu.is_finite() && sd.is_finite());
    }

    #[test]
    fn noisy_observations_smoothed() {
        let mut rng = Rng::new(5);
        let mut gp = Gp::new(
            Kernel {
                sf2: 1.0,
                length_scale: 1.0,
                noise: 0.25,
            },
            0.0,
            500,
        );
        // Bernoulli-style 0/1 observations of p=0.7 at the same x.
        for _ in 0..200 {
            gp.observe(vec![1.0], if rng.chance(0.7) { 1.0 } else { 0.0 });
        }
        let (mu, _) = gp.predict(&[1.0]);
        assert!((mu - 0.7).abs() < 0.1, "mu {mu}");
    }
}
