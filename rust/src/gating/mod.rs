//! The collaborative gating mechanism (paper §3.3 + §4).
//!
//! The gate observes a query's **context** `c_t = [d_t, s_t, q_t]`
//! (network delays, best edge overlap, query complexity) and picks a
//! **control policy** `x_t = [r_t, g_t]` — retrieval source × generation
//! location — to minimize total cost under QoS constraints. Submodules:
//!
//! * [`gp`] — Gaussian-process posteriors over cost/accuracy/delay.
//! * [`safeobo`] — Algorithm 1: Safe Online Bayesian Optimization with a
//!   random warm-up phase followed by safe-set-constrained exploitation.

pub mod gp;
pub mod safeobo;

/// Retrieval source `r_t` (paper §4.1: "none, edge-assisted naive
/// retrieval, or cloud knowledge graph-based retrieval" — we split
/// edge-assisted into local vs collaborating-edge, matching §3.3 and
/// Fig. 1's local/edge/cloud levels).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Retrieval {
    /// No retrieval: parametric knowledge only.
    None,
    /// Naive RAG over the local edge's chunk store.
    LocalNaive,
    /// Naive RAG over the best collaborating edge's store.
    EdgeAssisted,
    /// Cloud knowledge-graph retrieval (GraphRAG).
    CloudGraph,
}

/// Generation location `g_t`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GenLoc {
    /// Local SLM on the edge GPU.
    EdgeSlm,
    /// Large model in the cloud.
    CloudLlm,
}

/// One gate arm: a (retrieval, generation) pair.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Arm {
    pub retrieval: Retrieval,
    pub gen: GenLoc,
}

impl Arm {
    pub fn name(&self) -> &'static str {
        match (self.retrieval, self.gen) {
            (Retrieval::None, GenLoc::EdgeSlm) => "slm-only",
            (Retrieval::LocalNaive, GenLoc::EdgeSlm) => "local-rag+slm",
            (Retrieval::EdgeAssisted, GenLoc::EdgeSlm) => "edge-assist+slm",
            (Retrieval::CloudGraph, GenLoc::EdgeSlm) => "cloud-graph+slm",
            (Retrieval::CloudGraph, GenLoc::CloudLlm) => "cloud-graph+llm",
            (Retrieval::None, GenLoc::CloudLlm) => "llm-only",
            (Retrieval::LocalNaive, GenLoc::CloudLlm) => "local-rag+llm",
            (Retrieval::EdgeAssisted, GenLoc::CloudLlm) => "edge-assist+llm",
        }
    }
}

/// The deployed arm set (paper §8: "the collaborative gating mechanism
/// only selects among four retrieval and inference strategies" — plus
/// the pure-local strategy that Table 4's LLM-only baseline uses; the
/// extended arms of §8's future work are available behind
/// [`extended_arms`]).
pub fn standard_arms() -> Vec<Arm> {
    vec![
        Arm { retrieval: Retrieval::None, gen: GenLoc::EdgeSlm },
        Arm { retrieval: Retrieval::LocalNaive, gen: GenLoc::EdgeSlm },
        Arm { retrieval: Retrieval::EdgeAssisted, gen: GenLoc::EdgeSlm },
        Arm { retrieval: Retrieval::CloudGraph, gen: GenLoc::EdgeSlm },
        Arm { retrieval: Retrieval::CloudGraph, gen: GenLoc::CloudLlm },
    ]
}

/// Extended arm set (paper §8: "a broader range of adaptive strategies
/// may emerge"): adds cloud generation over edge retrieval and
/// retrieval-free cloud generation.
pub fn extended_arms() -> Vec<Arm> {
    let mut arms = standard_arms();
    arms.push(Arm { retrieval: Retrieval::None, gen: GenLoc::CloudLlm });
    arms.push(Arm { retrieval: Retrieval::EdgeAssisted, gen: GenLoc::CloudLlm });
    arms
}

/// The gate's observed context `c_t` (paper §4.1).
#[derive(Clone, Debug)]
pub struct GateContext {
    /// d_t: observed network delays (ms).
    pub cloud_delay_ms: f64,
    pub edge_delay_ms: f64,
    /// s_t: highest keyword-overlap ratio across edge datasets, and
    /// whether the best edge is the local one.
    pub best_overlap: f64,
    pub best_edge_is_local: bool,
    pub local_overlap: f64,
    /// Best summary-estimated overlap among the local edge's cluster
    /// *neighbors* (collaborative runs; 0.0 in the legacy paper modes,
    /// which — the RBF kernels being distance-based — leaves their GP
    /// posteriors bit-identical to the pre-cluster gate).
    pub neighbor_overlap: f64,
    /// q_t: query complexity — reasoning depth, length, entity count.
    pub hops: usize,
    pub length_tokens: usize,
    pub entity_count: usize,
}

impl GateContext {
    /// Normalized feature vector (all components roughly in [0, 1]).
    pub fn features(&self) -> Vec<f64> {
        vec![
            (self.cloud_delay_ms / 500.0).min(2.0),
            (self.edge_delay_ms / 100.0).min(2.0),
            self.best_overlap,
            if self.best_edge_is_local { 1.0 } else { 0.0 },
            self.local_overlap,
            self.neighbor_overlap,
            (self.hops as f64 - 1.0) / 2.0,
            (self.length_tokens as f64 / 30.0).min(2.0),
            (self.entity_count as f64 / 6.0).min(2.0),
        ]
    }

    /// Accuracy-relevant subspace: retrieval coverage + query
    /// complexity. Keeping the GP input low-dimensional is what makes
    /// T₀ ≈ 300 warm-up samples enough to certify arms (Table 5).
    pub fn acc_features(&self) -> Vec<f64> {
        vec![
            self.best_overlap,
            self.local_overlap,
            self.neighbor_overlap,
            if self.best_edge_is_local { 1.0 } else { 0.0 },
            (self.hops as f64 - 1.0) / 2.0,
            (self.entity_count as f64 / 6.0).min(2.0),
        ]
    }

    /// Delay-relevant subspace: network state + answer-length drivers.
    pub fn delay_features(&self) -> Vec<f64> {
        vec![
            (self.cloud_delay_ms / 500.0).min(2.0),
            (self.edge_delay_ms / 100.0).min(2.0),
            (self.length_tokens as f64 / 30.0).min(2.0),
            (self.hops as f64 - 1.0) / 2.0,
        ]
    }

    /// Cost-relevant subspace.
    pub fn cost_features(&self) -> Vec<f64> {
        vec![
            (self.cloud_delay_ms / 500.0).min(2.0),
            self.best_overlap,
            (self.hops as f64 - 1.0) / 2.0,
            (self.length_tokens as f64 / 30.0).min(2.0),
        ]
    }
}

/// Feature vector for a (context, arm) pair: context features ++ arm
/// one-hot over the gate's arm set.
pub fn arm_features(ctx: &GateContext, arm_idx: usize, num_arms: usize) -> Vec<f64> {
    let mut f = ctx.features();
    for i in 0..num_arms {
        f.push(if i == arm_idx { 1.0 } else { 0.0 });
    }
    f
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx() -> GateContext {
        GateContext {
            cloud_delay_ms: 300.0,
            edge_delay_ms: 20.0,
            best_overlap: 0.8,
            best_edge_is_local: true,
            local_overlap: 0.8,
            neighbor_overlap: 0.4,
            hops: 1,
            length_tokens: 15,
            entity_count: 3,
        }
    }

    #[test]
    fn standard_arm_set_matches_paper() {
        let arms = standard_arms();
        assert_eq!(arms.len(), 5);
        // The two Table-4 EACO extremes must be present.
        assert!(arms.iter().any(|a| a.name() == "slm-only"));
        assert!(arms.iter().any(|a| a.name() == "cloud-graph+llm"));
    }

    #[test]
    fn extended_arms_superset() {
        let ext = extended_arms();
        for a in standard_arms() {
            assert!(ext.contains(&a));
        }
        assert!(ext.len() > standard_arms().len());
    }

    #[test]
    fn features_bounded() {
        let f = ctx().features();
        assert_eq!(f.len(), 9);
        assert!(f.iter().all(|&x| (0.0..=2.0).contains(&x)), "{f:?}");
    }

    #[test]
    fn arm_features_one_hot() {
        let f = arm_features(&ctx(), 2, 5);
        assert_eq!(f.len(), 9 + 5);
        assert_eq!(f[9 + 2], 1.0);
        assert_eq!(f[9..].iter().sum::<f64>(), 1.0);
    }

    #[test]
    fn neighbor_overlap_feeds_accuracy_subspace() {
        let mut a = ctx();
        let mut b = ctx();
        a.neighbor_overlap = 0.0;
        b.neighbor_overlap = 0.9;
        assert_ne!(a.acc_features(), b.acc_features());
        // Legacy runs pin the signal to 0.0: equal vectors ⇒ the RBF
        // kernel sees unchanged distances ⇒ bit-identical posteriors.
        b.neighbor_overlap = 0.0;
        assert_eq!(a.acc_features(), b.acc_features());
    }

    #[test]
    fn arm_names_unique() {
        let names: Vec<&str> = extended_arms().iter().map(|a| a.name()).collect();
        let mut d = names.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), names.len());
    }
}
