//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the Rust runtime. Parsed with `util::json` (no serde offline).

use std::path::{Path, PathBuf};

use crate::util::json::{parse, Json};
use anyhow::{anyhow, bail, Context, Result};

/// One weight tensor's location inside a tier's `.bin`.
#[derive(Clone, Debug)]
pub struct WeightSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset_elems: usize,
    pub num_elems: usize,
}

/// One compiled artifact (an HLO module at a fixed batch size).
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub name: String,
    pub kind: String, // "lm" | "embedder"
    pub tier: String,
    pub path: String,
    pub weights_path: String,
    pub weights: Vec<WeightSpec>,
    pub batch: usize,
    // lm-only fields (0 for embedder)
    pub seq: usize,
    pub vocab: usize,
    pub d_model: usize,
    pub layers: usize,
    pub emulated_params_b: f64,
    pub capability: f64,
    pub tiny_flops_per_forward: f64,
    // embedder-only fields
    pub feat_dim: usize,
    pub out_dim: usize,
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactEntry>,
    pub attention_vmem_bytes: usize,
    pub attention_mxu_util: f64,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let mpath = dir.join("manifest.json");
        let text = std::fs::read_to_string(&mpath)
            .with_context(|| format!("reading {} (run `make artifacts`)", mpath.display()))?;
        let root = parse(&text).map_err(|e| anyhow!("manifest parse error: {e}"))?;
        if root.get("version").as_f64().unwrap_or(0.0) < 2.0 {
            bail!("manifest version < 2; regenerate artifacts");
        }
        let mut artifacts = Vec::new();
        for a in root
            .get("artifacts")
            .as_arr()
            .ok_or_else(|| anyhow!("manifest missing artifacts[]"))?
        {
            let weights = a
                .get("weights")
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(|w| WeightSpec {
                    name: w.get("name").as_str().unwrap_or("").to_string(),
                    shape: w
                        .get("shape")
                        .as_arr()
                        .unwrap_or(&[])
                        .iter()
                        .filter_map(|d| d.as_usize())
                        .collect(),
                    offset_elems: w.get("offset_elems").as_usize().unwrap_or(0),
                    num_elems: w.get("num_elems").as_usize().unwrap_or(0),
                })
                .collect();
            artifacts.push(ArtifactEntry {
                name: req_str(a, "name")?,
                kind: req_str(a, "kind")?,
                tier: req_str(a, "tier")?,
                path: req_str(a, "path")?,
                weights_path: req_str(a, "weights_path")?,
                weights,
                batch: a.get("batch").as_usize().unwrap_or(1),
                seq: a.get("seq").as_usize().unwrap_or(0),
                vocab: a.get("vocab").as_usize().unwrap_or(0),
                d_model: a.get("d_model").as_usize().unwrap_or(0),
                layers: a.get("layers").as_usize().unwrap_or(0),
                emulated_params_b: a.get("emulated_params_b").as_f64().unwrap_or(0.0),
                capability: a.get("capability").as_f64().unwrap_or(0.0),
                tiny_flops_per_forward: a.get("tiny_flops_per_forward").as_f64().unwrap_or(0.0),
                feat_dim: a.get("feat_dim").as_usize().unwrap_or(0),
                out_dim: a.get("out_dim").as_usize().unwrap_or(0),
            });
        }
        let kernel = root.get("kernel");
        Ok(Manifest {
            dir: dir.to_path_buf(),
            artifacts,
            attention_vmem_bytes: kernel.get("attention_vmem_bytes").as_usize().unwrap_or(0),
            attention_mxu_util: kernel.get("attention_mxu_util").as_f64().unwrap_or(0.0),
        })
    }

    /// Find the LM artifact for `tier` with the smallest batch ≥ wanted
    /// (falls back to the largest available).
    pub fn lm_for(&self, tier: &str, batch: usize) -> Option<&ArtifactEntry> {
        let mut candidates: Vec<&ArtifactEntry> = self
            .artifacts
            .iter()
            .filter(|a| a.kind == "lm" && a.tier == tier)
            .collect();
        candidates.sort_by_key(|a| a.batch);
        candidates
            .iter()
            .find(|a| a.batch >= batch)
            .copied()
            .or_else(|| candidates.last().copied())
    }

    pub fn embedder_for(&self, batch: usize) -> Option<&ArtifactEntry> {
        let mut candidates: Vec<&ArtifactEntry> = self
            .artifacts
            .iter()
            .filter(|a| a.kind == "embedder")
            .collect();
        candidates.sort_by_key(|a| a.batch);
        candidates
            .iter()
            .find(|a| a.batch >= batch)
            .copied()
            .or_else(|| candidates.last().copied())
    }

    /// All tier names with LM artifacts.
    pub fn tiers(&self) -> Vec<String> {
        let mut t: Vec<String> = self
            .artifacts
            .iter()
            .filter(|a| a.kind == "lm")
            .map(|a| a.tier.clone())
            .collect();
        t.sort();
        t.dedup();
        t
    }

    /// Capability score for a tier (from the manifest).
    pub fn capability_of(&self, tier: &str) -> Option<f64> {
        self.artifacts
            .iter()
            .find(|a| a.kind == "lm" && a.tier == tier)
            .map(|a| a.capability)
    }

    /// Emulated parameter count (billions) for a tier.
    pub fn params_of(&self, tier: &str) -> Option<f64> {
        self.artifacts
            .iter()
            .find(|a| a.kind == "lm" && a.tier == tier)
            .map(|a| a.emulated_params_b)
    }
}

fn req_str(a: &Json, key: &str) -> Result<String> {
    a.get(key)
        .as_str()
        .map(|s| s.to_string())
        .ok_or_else(|| anyhow!("manifest entry missing {key:?}"))
}

/// Read a weights `.bin` (little-endian f32) into per-tensor vectors.
pub fn read_weights(dir: &Path, entry: &ArtifactEntry) -> Result<Vec<Vec<f32>>> {
    let path = dir.join(&entry.weights_path);
    let bytes = std::fs::read(&path)
        .with_context(|| format!("reading weights {}", path.display()))?;
    let total: usize = entry.weights.iter().map(|w| w.num_elems).sum();
    if bytes.len() != total * 4 {
        bail!(
            "weights size mismatch for {}: {} bytes vs {} elems",
            entry.name,
            bytes.len(),
            total
        );
    }
    let mut out = Vec::with_capacity(entry.weights.len());
    for w in &entry.weights {
        let start = w.offset_elems * 4;
        let end = start + w.num_elems * 4;
        let mut v = Vec::with_capacity(w.num_elems);
        for c in bytes[start..end].chunks_exact(4) {
            v.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        }
        out.push(v);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::artifacts_dir;

    #[test]
    fn loads_real_manifest_if_built() {
        let Some(dir) = artifacts_dir() else { return };
        let m = Manifest::load(&dir).unwrap();
        assert!(!m.artifacts.is_empty());
        assert!(m.tiers().contains(&"qwen3b".to_string()));
        let a = m.lm_for("qwen3b", 1).unwrap();
        assert_eq!(a.batch, 1);
        assert!(a.seq > 0 && a.vocab > 0);
        assert!(m.capability_of("qwen72b").unwrap() > m.capability_of("qwen3b").unwrap());
    }

    #[test]
    fn lm_for_picks_smallest_sufficient_batch() {
        let Some(dir) = artifacts_dir() else { return };
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.lm_for("qwen3b", 3).unwrap().batch, 4);
        assert_eq!(m.lm_for("qwen3b", 5).unwrap().batch, 8);
        // Above max: falls back to largest.
        assert_eq!(m.lm_for("qwen3b", 64).unwrap().batch, 8);
        assert!(m.lm_for("nonexistent", 1).is_none());
    }

    #[test]
    fn weights_parse_and_match_shapes() {
        let Some(dir) = artifacts_dir() else { return };
        let m = Manifest::load(&dir).unwrap();
        let a = m.lm_for("qwen15b", 1).unwrap();
        let w = read_weights(&dir, a).unwrap();
        assert_eq!(w.len(), a.weights.len());
        for (data, spec) in w.iter().zip(&a.weights) {
            let expect: usize = spec.shape.iter().product();
            assert_eq!(data.len(), expect, "{}", spec.name);
        }
        // First weight is the embedding table (vocab × d).
        assert_eq!(a.weights[0].name, "embed");
        assert_eq!(a.weights[0].shape, vec![a.vocab, a.d_model]);
    }
}
