//! PJRT runtime: load AOT artifacts, execute models, generate tokens.
//!
//! The load path follows `/opt/xla-example/load_hlo`: HLO **text** →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `PjRtClient::compile`. Weights are uploaded **once** per tier as
//! device-resident `PjRtBuffer`s and reused by every `execute_b` call —
//! the weight-residency pattern of real serving stacks; per-request
//! traffic is just the token tensor.
//!
//! Python is never on this path: after `make artifacts`, the Rust binary
//! is self-contained.

pub mod manifest;
pub mod tokenizer;

use std::collections::HashMap;
use std::path::Path;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

pub use manifest::{ArtifactEntry, Manifest};
pub use tokenizer::{FeatureHasher, Tokenizer};

/// A compiled artifact with device-resident weights.
pub struct LoadedModel {
    pub entry: ArtifactEntry,
    exe: xla::PjRtLoadedExecutable,
    weights: Vec<xla::PjRtBuffer>,
}

/// Execution timing for one call (real wall-clock on the PJRT CPU client).
#[derive(Clone, Copy, Debug, Default)]
pub struct ExecTiming {
    pub upload_us: u128,
    pub execute_us: u128,
    pub download_us: u128,
}

/// The runtime: one PJRT client + lazily compiled models.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    loaded: HashMap<String, LoadedModel>,
}

impl Runtime {
    /// Open the artifacts directory (compiles nothing yet).
    pub fn open(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Runtime {
            client,
            manifest,
            loaded: HashMap::new(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn is_loaded(&self, name: &str) -> bool {
        self.loaded.contains_key(name)
    }

    /// Compile an artifact and upload its weights (idempotent).
    pub fn load(&mut self, name: &str) -> Result<()> {
        if self.loaded.contains_key(name) {
            return Ok(());
        }
        let entry = self
            .manifest
            .artifacts
            .iter()
            .find(|a| a.name == name)
            .cloned()
            .ok_or_else(|| anyhow!("unknown artifact {name:?}"))?;
        let hlo_path = self.manifest.dir.join(&entry.path);
        let proto = xla::HloModuleProto::from_text_file(
            hlo_path
                .to_str()
                .ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing {}: {e:?}", hlo_path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;

        let host_weights = manifest::read_weights(&self.manifest.dir, &entry)?;
        let mut weights = Vec::with_capacity(host_weights.len());
        for (data, spec) in host_weights.iter().zip(&entry.weights) {
            let buf = self
                .client
                .buffer_from_host_buffer::<f32>(data, &spec.shape, None)
                .map_err(|e| anyhow!("uploading {}::{}: {e:?}", name, spec.name))?;
            weights.push(buf);
        }
        self.loaded.insert(
            name.to_string(),
            LoadedModel {
                entry,
                exe,
                weights,
            },
        );
        Ok(())
    }

    fn model(&self, name: &str) -> Result<&LoadedModel> {
        self.loaded
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name:?} not loaded"))
    }

    /// Run an LM artifact on a token batch. `tokens.len()` must equal
    /// `batch * seq`. Returns `(logits[batch*vocab], timing)`.
    pub fn lm_logits(&self, name: &str, tokens: &[i32]) -> Result<(Vec<f32>, ExecTiming)> {
        let m = self.model(name)?;
        let (b, s, v) = (m.entry.batch, m.entry.seq, m.entry.vocab);
        if tokens.len() != b * s {
            bail!(
                "token tensor mismatch for {name}: got {}, want {}x{}",
                tokens.len(),
                b,
                s
            );
        }
        let mut timing = ExecTiming::default();
        let t0 = Instant::now();
        let tok_buf = self
            .client
            .buffer_from_host_buffer::<i32>(tokens, &[b, s], None)
            .map_err(|e| anyhow!("uploading tokens: {e:?}"))?;
        timing.upload_us = t0.elapsed().as_micros();

        let mut args: Vec<&xla::PjRtBuffer> = m.weights.iter().collect();
        args.push(&tok_buf);
        let t1 = Instant::now();
        let result = m
            .exe
            .execute_b(&args)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        timing.execute_us = t1.elapsed().as_micros();

        let t2 = Instant::now();
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("download: {e:?}"))?;
        // aot.py lowers with return_tuple=True → unwrap the 1-tuple.
        let out = lit.to_tuple1().map_err(|e| anyhow!("untuple: {e:?}"))?;
        let logits = out.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))?;
        timing.download_us = t2.elapsed().as_micros();
        if logits.len() != b * v {
            bail!("logits shape mismatch: {} vs {}x{}", logits.len(), b, v);
        }
        Ok((logits, timing))
    }

    /// Run the embedder on `batch` feature rows (padded to the artifact
    /// batch). Returns unit-norm vectors, one per input row.
    pub fn embed(&self, name: &str, feats: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        let m = self.model(name)?;
        let (b, fd, od) = (m.entry.batch, m.entry.feat_dim, m.entry.out_dim);
        if feats.len() > b {
            bail!("embed batch {} exceeds artifact batch {b}", feats.len());
        }
        let mut flat = vec![0.0f32; b * fd];
        for (i, row) in feats.iter().enumerate() {
            if row.len() != fd {
                bail!("feature dim {} != {fd}", row.len());
            }
            flat[i * fd..(i + 1) * fd].copy_from_slice(row);
        }
        let feat_buf = self
            .client
            .buffer_from_host_buffer::<f32>(&flat, &[b, fd], None)
            .map_err(|e| anyhow!("uploading feats: {e:?}"))?;
        let mut args: Vec<&xla::PjRtBuffer> = m.weights.iter().collect();
        args.push(&feat_buf);
        let result = m
            .exe
            .execute_b(&args)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("download: {e:?}"))?;
        let out = lit.to_tuple1().map_err(|e| anyhow!("untuple: {e:?}"))?;
        let flat_out = out.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))?;
        Ok(feats
            .iter()
            .enumerate()
            .map(|(i, _)| flat_out[i * od..(i + 1) * od].to_vec())
            .collect())
    }

    /// Greedy-decode `max_new` tokens for a batch of prompts on a tier.
    /// Prompts beyond the artifact batch are rejected. Returns per-prompt
    /// generated ids plus cumulative real execution time.
    pub fn generate(
        &mut self,
        tier: &str,
        prompts: &[String],
        max_new: usize,
    ) -> Result<(Vec<Vec<i32>>, ExecTiming)> {
        let entry = self
            .manifest
            .lm_for(tier, prompts.len())
            .ok_or_else(|| anyhow!("no artifact for tier {tier:?}"))?
            .clone();
        if prompts.len() > entry.batch {
            bail!("batch {} exceeds artifact batch {}", prompts.len(), entry.batch);
        }
        let name = entry.name.clone();
        self.load(&name)?;
        let tok = Tokenizer::new(entry.vocab, entry.seq);
        let mut generated: Vec<Vec<i32>> = vec![Vec::new(); prompts.len()];
        let mut total = ExecTiming::default();
        for _ in 0..max_new {
            // Assemble the sliding windows (dummy rows pad the batch).
            let mut tokens = Vec::with_capacity(entry.batch * entry.seq);
            for i in 0..entry.batch {
                if i < prompts.len() {
                    tokens.extend(tok.encode_with_generated(&prompts[i], &generated[i]));
                } else {
                    tokens.extend(std::iter::repeat(tokenizer::PAD).take(entry.seq));
                }
            }
            let (logits, t) = self.lm_logits(&name, &tokens)?;
            total.upload_us += t.upload_us;
            total.execute_us += t.execute_us;
            total.download_us += t.download_us;
            for (i, gen) in generated.iter_mut().enumerate() {
                let row = &logits[i * entry.vocab..(i + 1) * entry.vocab];
                let argmax = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(j, _)| j as i32)
                    .unwrap_or(0);
                gen.push(argmax);
            }
        }
        Ok((generated, total))
    }
}
