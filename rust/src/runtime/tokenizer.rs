//! Tokenizer + feature hasher — the host-side text frontend.
//!
//! The transformer artifacts consume token ids (`i32`, fixed window) and
//! the embedder consumes hashed n-gram count vectors (`f32[feat_dim]`).
//! Both mappings live entirely in Rust (Python never tokenizes at
//! runtime); only the *shape* contract is shared with the artifacts.
//!
//! Token ids: FNV-1a hash of each whitespace-separated word, mod vocab
//! (reserving 0 = PAD, 1 = BOS). Feature vector: character 3-gram
//! hashing (the `all-MiniLM` stand-in geometry — shared n-grams ⇒ shared
//! buckets ⇒ cosine similarity tracks lexical overlap).

// One FNV-1a for the crate: the keyword-summary fingerprint hash.
use crate::index::fnv1a;

pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
const RESERVED: u64 = 2;

/// Word-level hashing tokenizer with a fixed context window.
#[derive(Clone, Debug)]
pub struct Tokenizer {
    pub vocab: usize,
    pub seq: usize,
}

impl Tokenizer {
    pub fn new(vocab: usize, seq: usize) -> Tokenizer {
        assert!(vocab > 8);
        Tokenizer { vocab, seq }
    }

    fn word_id(&self, w: &str) -> i32 {
        let h = fnv1a(w.to_lowercase().as_bytes());
        (RESERVED + h % (self.vocab as u64 - RESERVED)) as i32
    }

    /// Tokenize to exactly `seq` ids: BOS + words, front-padded (the
    /// model attends causally, so content sits at the window's end).
    pub fn encode(&self, text: &str) -> Vec<i32> {
        let mut ids: Vec<i32> = vec![BOS];
        ids.extend(text.split_whitespace().map(|w| self.word_id(w)));
        if ids.len() > self.seq {
            // Keep the tail (most recent context).
            ids = ids[ids.len() - self.seq..].to_vec();
        }
        let mut out = vec![PAD; self.seq - ids.len()];
        out.extend(ids);
        out
    }

    /// Encode a prompt then append generated ids, keeping the window.
    pub fn encode_with_generated(&self, text: &str, generated: &[i32]) -> Vec<i32> {
        let mut ids: Vec<i32> = vec![BOS];
        ids.extend(text.split_whitespace().map(|w| self.word_id(w)));
        ids.extend_from_slice(generated);
        if ids.len() > self.seq {
            ids = ids[ids.len() - self.seq..].to_vec();
        }
        let mut out = vec![PAD; self.seq - ids.len()];
        out.extend(ids);
        out
    }
}

/// Character-3-gram feature hasher for the embedder artifact.
#[derive(Clone, Debug)]
pub struct FeatureHasher {
    pub feat_dim: usize,
}

impl FeatureHasher {
    pub fn new(feat_dim: usize) -> FeatureHasher {
        FeatureHasher { feat_dim }
    }

    /// Hash text into a count vector of character 3-grams.
    pub fn features(&self, text: &str) -> Vec<f32> {
        let mut v = vec![0.0f32; self.feat_dim];
        let lower = text.to_lowercase();
        let bytes: Vec<u8> = lower
            .bytes()
            .filter(|b| b.is_ascii_alphanumeric() || *b == b' ')
            .collect();
        if bytes.len() < 3 {
            if !bytes.is_empty() {
                v[(fnv1a(&bytes) % self.feat_dim as u64) as usize] += 1.0;
            }
            return v;
        }
        for w in bytes.windows(3) {
            v[(fnv1a(w) % self.feat_dim as u64) as usize] += 1.0;
        }
        v
    }

    /// Cosine similarity between two hashed texts (host-side shortcut
    /// used when the PJRT embedder is not loaded).
    pub fn cosine(&self, a: &str, b: &str) -> f64 {
        let fa = self.features(a);
        let fb = self.features(b);
        let dot: f32 = fa.iter().zip(&fb).map(|(x, y)| x * y).sum();
        let na: f32 = fa.iter().map(|x| x * x).sum::<f32>().sqrt();
        let nb: f32 = fb.iter().map(|x| x * x).sum::<f32>().sqrt();
        if na == 0.0 || nb == 0.0 {
            0.0
        } else {
            (dot / (na * nb)) as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_fixed_length_and_padded() {
        let t = Tokenizer::new(512, 64);
        let ids = t.encode("who founded Kamor");
        assert_eq!(ids.len(), 64);
        assert_eq!(ids[0], PAD);
        assert_eq!(ids[64 - 4], BOS);
        assert!(ids[61..].iter().all(|&i| i >= 2));
    }

    #[test]
    fn encode_truncates_long_input_keeping_tail() {
        let t = Tokenizer::new(512, 16);
        let words: Vec<String> = (0..100).map(|i| format!("w{i}")).collect();
        let ids = t.encode(&words.join(" "));
        assert_eq!(ids.len(), 16);
        assert!(ids.iter().all(|&i| i != PAD));
        // Tail word w99 must be present; early words gone.
        assert_eq!(*ids.last().unwrap(), t.word_id("w99"));
    }

    #[test]
    fn deterministic_and_case_insensitive() {
        let t = Tokenizer::new(512, 32);
        assert_eq!(t.encode("Harry Potter"), t.encode("harry potter"));
    }

    #[test]
    fn ids_in_vocab_range() {
        let t = Tokenizer::new(512, 32);
        for w in ["a", "zzz", "Alohomora", "x1y2z3"] {
            let id = t.word_id(w);
            assert!((2..512).contains(&id), "{w} -> {id}");
        }
    }

    #[test]
    fn encode_with_generated_appends() {
        let t = Tokenizer::new(512, 16);
        let base = t.encode("hello world");
        let gen = t.encode_with_generated("hello world", &[42, 43]);
        assert_eq!(gen.len(), 16);
        assert_eq!(gen[15], 43);
        assert_eq!(gen[14], 42);
        assert_eq!(&gen[..14], &base[2..]);
    }

    #[test]
    fn feature_hasher_shape_and_counts() {
        let h = FeatureHasher::new(256);
        let f = h.features("alohomora spell");
        assert_eq!(f.len(), 256);
        let total: f32 = f.iter().sum();
        assert!(total > 0.0);
    }

    #[test]
    fn similar_text_higher_cosine() {
        let h = FeatureHasher::new(256);
        let sim_close = h.cosine("alohomora unlocking spell", "alohomora spell door");
        let sim_far = h.cosine("alohomora unlocking spell", "quidditch world cup");
        assert!(sim_close > sim_far, "{sim_close} <= {sim_far}");
        assert!(sim_close > 0.3);
    }

    #[test]
    fn identical_text_cosine_one() {
        let h = FeatureHasher::new(256);
        assert!((h.cosine("hermione granger", "hermione granger") - 1.0).abs() < 1e-6);
    }

    #[test]
    fn empty_text_zero() {
        let h = FeatureHasher::new(256);
        assert_eq!(h.cosine("", "anything"), 0.0);
    }
}
