//! Query workload generation: temporal drift + spatial skew (paper §2).
//!
//! Table 2 of the paper motivates EACO-RAG with queries that vary over
//! *time* (elections, sports results) and *space* (regional traditions).
//! This module turns those observations into a generative model:
//!
//! * **Spatial skew** — each edge node has its own topic-preference
//!   distribution (a tilted/permuted version of the corpus base
//!   popularity), so different edges see different query mixes.
//! * **Temporal drift** — every `drift_period` steps a new *trending
//!   topic* takes over a share of the traffic (breaking news), and the
//!   underlying preference slowly rotates.
//!
//! The resulting stream is what exercises the adaptive knowledge update:
//! an edge whose local store tracked last week's interests starts missing
//! and must refresh from the cloud's knowledge graph.

use crate::corpus::{Corpus, QaId, TopicId};
use crate::util::rng::Rng;

/// One arriving query.
#[derive(Clone, Debug)]
pub struct QueryEvent {
    pub step: usize,
    pub edge_id: usize,
    pub qa_id: QaId,
    /// Virtual inter-arrival gap before this query (milliseconds).
    pub gap_ms: f64,
}

/// Workload generation parameters.
#[derive(Clone, Debug)]
pub struct WorkloadSpec {
    pub num_edges: usize,
    pub steps: usize,
    /// Steps between trend changes (temporal drift cadence).
    pub drift_period: usize,
    /// Traffic share captured by the current trending topic.
    pub trend_share: f64,
    /// How strongly an edge's preference tilts toward its own topics
    /// (0 = uniform across topics, 1 = fully local).
    pub spatial_tilt: f64,
    /// Mean inter-arrival gap (ms) — Poisson arrivals.
    pub mean_gap_ms: f64,
}

impl Default for WorkloadSpec {
    fn default() -> Self {
        WorkloadSpec {
            num_edges: 4,
            steps: 1000,
            drift_period: 120,
            trend_share: 0.35,
            spatial_tilt: 0.6,
            mean_gap_ms: 120.0,
        }
    }
}

/// A generated workload: the full event stream plus the evolving
/// popularity model (exposed for tests and the knowledge distributor).
pub struct Workload {
    pub spec: WorkloadSpec,
    pub events: Vec<QueryEvent>,
    /// Per-edge home-topic assignment (spatial identity).
    pub edge_home_topics: Vec<Vec<TopicId>>,
    /// Trending topic per drift window.
    pub trends: Vec<TopicId>,
}

impl Workload {
    /// Generate a deterministic stream over `corpus`.
    pub fn generate(corpus: &Corpus, spec: WorkloadSpec, seed: u64) -> Workload {
        let mut rng = Rng::new(seed).fork("workload");
        let topics = corpus.spec.topics;

        // Spatial identity: each edge "owns" a contiguous slice of topics
        // (regions care about local matters) — with wraparound.
        let per_edge = (topics as f64 / spec.num_edges as f64).ceil() as usize;
        let edge_home_topics: Vec<Vec<TopicId>> = (0..spec.num_edges)
            .map(|e| {
                (0..per_edge.max(1))
                    .map(|i| (e * per_edge + i) % topics)
                    .collect()
            })
            .collect();

        // Trending topics per drift window.
        let windows = spec.steps / spec.drift_period.max(1) + 1;
        let trends: Vec<TopicId> = (0..windows).map(|_| rng.below(topics)).collect();

        // Per-topic QA pools.
        let topic_qas: Vec<Vec<QaId>> =
            (0..topics).map(|t| corpus.qa_by_topic(t)).collect();

        let mut events = Vec::with_capacity(spec.steps);
        for step in 0..spec.steps {
            let edge_id = rng.below(spec.num_edges);
            let trend = trends[step / spec.drift_period.max(1)];
            let topic = sample_topic(
                corpus,
                &edge_home_topics[edge_id],
                trend,
                &spec,
                &mut rng,
            );
            // Sample a QA from the topic (fall back to any QA if empty).
            let qa_id = if topic_qas[topic].is_empty() {
                rng.below(corpus.qa.len())
            } else {
                *rng.choose(&topic_qas[topic])
            };
            events.push(QueryEvent {
                step,
                edge_id,
                qa_id,
                gap_ms: rng.exponential(1.0 / spec.mean_gap_ms),
            });
        }

        Workload {
            spec,
            events,
            edge_home_topics,
            trends,
        }
    }

    /// Instantaneous topic distribution seen at (edge, step) — used by
    /// tests and by the cloud's knowledge distributor to anticipate
    /// demand.
    pub fn topic_distribution(
        &self,
        corpus: &Corpus,
        edge_id: usize,
        step: usize,
    ) -> Vec<f64> {
        let topics = corpus.spec.topics;
        let trend = self.trends[step / self.spec.drift_period.max(1)];
        let mut probs = vec![0.0; topics];
        let home = &self.edge_home_topics[edge_id];
        for t in 0..topics {
            let base = corpus.topic_popularity[t];
            let local = if home.contains(&t) {
                1.0 / home.len() as f64
            } else {
                0.0
            };
            probs[t] = (1.0 - self.spec.spatial_tilt) * base + self.spec.spatial_tilt * local;
        }
        for p in probs.iter_mut() {
            *p *= 1.0 - self.spec.trend_share;
        }
        probs[trend] += self.spec.trend_share;
        probs
    }
}

fn sample_topic(
    corpus: &Corpus,
    home: &[TopicId],
    trend: TopicId,
    spec: &WorkloadSpec,
    rng: &mut Rng,
) -> TopicId {
    if rng.chance(spec.trend_share) {
        return trend;
    }
    if rng.chance(spec.spatial_tilt) {
        return *rng.choose(home);
    }
    // Base popularity (zipf) sampling.
    let mut u = rng.f64();
    for (t, &p) in corpus.topic_popularity.iter().enumerate() {
        u -= p;
        if u <= 0.0 {
            return t;
        }
    }
    corpus.spec.topics - 1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::Profile;

    fn wl(steps: usize) -> (Corpus, Workload) {
        let c = Corpus::generate(Profile::Wiki, 5);
        let spec = WorkloadSpec {
            steps,
            ..WorkloadSpec::default()
        };
        let w = Workload::generate(&c, spec, 5);
        (c, w)
    }

    #[test]
    fn generates_requested_steps() {
        let (_, w) = wl(500);
        assert_eq!(w.events.len(), 500);
        for (i, e) in w.events.iter().enumerate() {
            assert_eq!(e.step, i);
            assert!(e.edge_id < w.spec.num_edges);
            assert!(e.gap_ms >= 0.0);
        }
    }

    #[test]
    fn deterministic() {
        let c = Corpus::generate(Profile::Wiki, 5);
        let a = Workload::generate(&c, WorkloadSpec::default(), 9);
        let b = Workload::generate(&c, WorkloadSpec::default(), 9);
        assert_eq!(a.events.len(), b.events.len());
        assert!(a
            .events
            .iter()
            .zip(&b.events)
            .all(|(x, y)| x.qa_id == y.qa_id && x.edge_id == y.edge_id));
    }

    #[test]
    fn spatial_skew_differs_across_edges() {
        let (c, w) = wl(2000);
        // Count topic frequency per edge; home topics should dominate.
        let mut per_edge = vec![vec![0usize; c.spec.topics]; w.spec.num_edges];
        for e in &w.events {
            per_edge[e.edge_id][c.qa[e.qa_id].topic] += 1;
        }
        let mut home_hits = 0usize;
        let mut total = 0usize;
        for (eid, counts) in per_edge.iter().enumerate() {
            for (t, &n) in counts.iter().enumerate() {
                total += n;
                if w.edge_home_topics[eid].contains(&t) {
                    home_hits += n;
                }
            }
        }
        let share = home_hits as f64 / total as f64;
        // Home topics are ~25% of topics but should get well above 25% of
        // traffic under tilt=0.6.
        assert!(share > 0.4, "home share {share}");
    }

    #[test]
    fn temporal_drift_changes_mix() {
        let (c, w) = wl(4000);
        // Distribution inside one drift window should over-represent the
        // window's trend topic.
        let period = w.spec.drift_period;
        for window in 0..3 {
            let trend = w.trends[window];
            let in_window: Vec<_> = w
                .events
                .iter()
                .filter(|e| e.step / period == window)
                .collect();
            let hits = in_window
                .iter()
                .filter(|e| c.qa[e.qa_id].topic == trend)
                .count();
            let share = hits as f64 / in_window.len().max(1) as f64;
            assert!(
                share > 0.2,
                "window {window}: trend share {share} (expected boost)"
            );
        }
    }

    #[test]
    fn topic_distribution_sums_to_one() {
        let (c, w) = wl(100);
        for edge in 0..w.spec.num_edges {
            let d = w.topic_distribution(&c, edge, 50);
            let sum: f64 = d.iter().sum();
            assert!((sum - 1.0).abs() < 1e-9, "edge {edge} sum {sum}");
        }
    }
}
