//! System configuration: typed config + a TOML-subset parser.
//!
//! The offline image has no serde/toml, so `parse_toml` handles the
//! subset real deployments need: `[section]` headers, `key = value` with
//! string / int / float / bool values, comments, and blank lines.
//! `SystemConfig` is the single source of truth for a serving run; every
//! example and bench builds one (defaults mirror the paper's prototype
//! §5: 1,000-chunk edge stores, updates every 20 QA pairs, ≤500
//! distributed chunks, 4 edge nodes).

use std::collections::BTreeMap;

use crate::cluster::placement::PlacementPolicy;
use crate::corpus::Profile;
use crate::cost::CostWeights;
use crate::netsim::NetSpec;

/// Parsed TOML-subset document: section -> key -> raw string value.
pub type TomlDoc = BTreeMap<String, BTreeMap<String, String>>;

/// Parse the TOML subset (sections, scalar keys, `#` comments).
pub fn parse_toml(input: &str) -> Result<TomlDoc, String> {
    let mut doc: TomlDoc = BTreeMap::new();
    let mut section = String::new();
    doc.insert(String::new(), BTreeMap::new());
    for (lineno, raw) in input.lines().enumerate() {
        let line = strip_comment(raw).trim().to_string();
        if line.is_empty() {
            continue;
        }
        if line.starts_with('[') {
            if !line.ends_with(']') {
                return Err(format!("line {}: malformed section header", lineno + 1));
            }
            section = line[1..line.len() - 1].trim().to_string();
            doc.entry(section.clone()).or_default();
        } else if let Some((k, v)) = line.split_once('=') {
            let key = k.trim().to_string();
            let mut val = v.trim().to_string();
            if val.len() >= 2 && val.starts_with('"') && val.ends_with('"') {
                val = val[1..val.len() - 1].to_string();
            }
            doc.entry(section.clone()).or_default().insert(key, val);
        } else {
            return Err(format!("line {}: expected key = value", lineno + 1));
        }
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // Only strip `#` outside quotes.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

/// QoS regime for the collaborative gate (paper §6.2 evaluates two).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QosPreset {
    /// Delays up to 5 s acceptable; minimize cost ("cost-efficient").
    CostEfficient,
    /// Responses must land under 1 s ("delay-oriented").
    DelayOriented,
}

impl QosPreset {
    pub fn parse(s: &str) -> Option<QosPreset> {
        match s {
            "cost" | "cost-efficient" => Some(QosPreset::CostEfficient),
            "delay" | "delay-oriented" => Some(QosPreset::DelayOriented),
            _ => None,
        }
    }

    /// (QoS_min_accuracy, QoS_max_delay_seconds). The accuracy floor is
    /// dataset-dependent (paper §4.1: "the QoS constraints can be
    /// adjusted to suit different scenarios"): the specialized Harry
    /// Potter domain tops out near 77% even for 72B+GraphRAG (Table 4),
    /// so its floor sits lower.
    pub fn constraints_for(&self, dataset: Profile) -> (f64, f64) {
        let min_acc = match dataset {
            Profile::Wiki => 0.85,
            Profile::HarryPotter => 0.72,
        };
        match self {
            QosPreset::CostEfficient => (min_acc, 5.0),
            QosPreset::DelayOriented => (min_acc, 1.0),
        }
    }

    /// Wiki-profile constraints (compatibility shim).
    pub fn constraints(&self) -> (f64, f64) {
        self.constraints_for(Profile::Wiki)
    }

    pub fn name(&self) -> &'static str {
        match self {
            QosPreset::CostEfficient => "Cost-Efficient",
            QosPreset::DelayOriented => "Delay-Oriented",
        }
    }
}

/// Knobs for the distributed knowledge plane ([`crate::cluster`]).
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    /// Neighbors per edge in collaborative mode (summary routing and
    /// gossip both fan out to this many peers; the legacy paper modes
    /// always use a full mesh so their behavior is unchanged).
    pub degree: usize,
    /// Edge-store eviction policy. `HotnessLru` is the collaborative
    /// default; `fifo` restores the paper-faithful §5 baseline.
    pub placement: PlacementPolicy,
    /// Virtual-time steps between gossip rounds.
    pub gossip_interval: usize,
    /// Hottest residents advertised per gossip digest.
    pub gossip_hot_k: usize,
    /// Gossip rounds a fresh replica stays pinned against eviction.
    pub pin_rounds: usize,
    /// Half-life (steps) of the popularity counters.
    pub hotness_half_life: f64,
    /// Learned per-link gossip budgets
    /// ([`crate::cluster::feedback`]): `none` (default — the static
    /// hot-k digest, bit-identical to the pre-feedback plane) or
    /// `hit-rate` (gate-observed hit rates + per-link digest usefulness
    /// scale each link's advertisement).
    pub feedback: crate::cluster::feedback::FeedbackMode,
    /// Floor of the learned per-link digest budget; only meaningful
    /// when `feedback` is not `none`.
    pub min_hot_k: usize,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            degree: 2,
            placement: PlacementPolicy::HotnessLru,
            gossip_interval: 25,
            gossip_hot_k: 64,
            pin_rounds: 2,
            hotness_half_life: 200.0,
            feedback: crate::cluster::feedback::FeedbackMode::None,
            min_hot_k: 8,
        }
    }
}

/// Knobs for the IVF ANN retrieval layer ([`crate::vecstore::ivf`])
/// and the centroid-blended routing built on it.
#[derive(Clone, Debug)]
pub struct AnnConfig {
    /// k-means posting lists per edge store.
    pub nlist: usize,
    /// Lists probed per query (recall-vs-latency dial).
    pub nprobe: usize,
    /// Stores below this many rows always take the exact flat scan —
    /// bit-identical to the pre-ANN path, so small edge stores are
    /// unaffected by enabling ANN.
    pub exact_below: usize,
    /// A posting list re-centers and re-assigns its members once its
    /// insert/remove churn exceeds this fraction of its size.
    pub retrain_drift: f64,
    /// Feature-hashed embedding width (the MiniLM stand-in geometry).
    pub embed_dim: usize,
    /// Weight of the coarse-centroid alignment term in
    /// `EdgeCluster::route_blended`; 0 disables the blend.
    pub route_blend: f64,
}

impl Default for AnnConfig {
    fn default() -> Self {
        AnnConfig {
            nlist: 32,
            nprobe: 4,
            exact_below: 4096,
            retrain_drift: 0.5,
            embed_dim: 64,
            route_blend: 0.25,
        }
    }
}

/// Knobs for the asynchronous serving plane ([`crate::serve`]). The
/// defaults deliberately make `serve_async` bit-identical to the
/// synchronous sim paths: unbounded queue, one virtual worker,
/// admission off, gossip in the foreground.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Per-edge in-flight capacity; arrivals beyond it are shed with
    /// backpressure accounting. 0 means unbounded (default — a finite
    /// default would shed queries and silently break equivalence with
    /// the synchronous path).
    pub queue_cap: usize,
    /// Virtual servers draining the queues (and background-pool
    /// threads when `gossip_background` is on).
    pub workers: usize,
    /// End-to-end latency SLO the admission rule compares against.
    pub slo_ms: f64,
    /// What to do when predicted latency blows the SLO
    /// (none / shed / downgrade).
    pub admission: crate::serve::queue::AdmissionPolicy,
    /// Run gossip rounds as background work items overlapping query
    /// service instead of blocking every server (foreground).
    pub gossip_background: bool,
    /// Weighted-fair dequeue weights across the three priority lanes
    /// (high, normal, low), e.g. `"4,2,1"`. `None` (default) keeps the
    /// legacy strict-priority pop bit-identically.
    pub wfq_weights: Option<[f64; 3]>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            queue_cap: 0,
            workers: 1,
            slo_ms: 2000.0,
            admission: crate::serve::queue::AdmissionPolicy::None,
            gossip_background: false,
            wfq_weights: None,
        }
    }
}

/// Knobs for the deterministic fault-injection plane
/// ([`crate::chaos`]). Disabled by default — a disabled chaos section
/// keeps every sim/serve path bit-identical to a fault-free build.
#[derive(Clone, Debug)]
pub struct ChaosConfig {
    /// Master switch: schedule the configured scenario's fault events
    /// into the serve loop.
    pub enabled: bool,
    /// Scenario preset name; one of
    /// [`crate::chaos::Scenario::PRESETS`] (`rolling-restart`,
    /// `split-brain`, `flaky-uplink`, `random`). Validated at parse
    /// time.
    pub scenario: String,
    /// Virtual-time step of the first fault.
    pub at_step: usize,
    /// Length of the fault window in steps (per-edge stagger for
    /// `rolling-restart`, partition length for `split-brain`, degrade
    /// window for `flaky-uplink`).
    pub duration_steps: usize,
    /// Link latency multiplier for degrade events (`flaky-uplink`).
    pub degrade_factor: f64,
    /// Number of fault events drawn by the `random` scenario. The
    /// schedule is built *before* the serve loop from its own seeded
    /// RNG stream, so admitted-query streams are untouched.
    pub random_faults: usize,
    /// Seed for the `random` scenario's fault-schedule RNG. Same seed
    /// ⇒ bit-identical schedule; independent of the workload seed.
    pub random_seed: u64,
    /// SLA: worst-case recovery ≤ this many ms (≤ 0 disables).
    pub sla_recovery_ms: f64,
    /// SLA: max version lag ≤ this many versions (< 0 disables).
    pub sla_max_staleness: i64,
    /// SLA: availability ≥ this fraction (≤ 0 disables).
    pub sla_min_availability: f64,
}

impl Default for ChaosConfig {
    fn default() -> Self {
        ChaosConfig {
            enabled: false,
            scenario: "split-brain".to_string(),
            at_step: 40,
            duration_steps: 60,
            degrade_factor: 8.0,
            random_faults: 8,
            random_seed: 7,
            sla_recovery_ms: 0.0,
            sla_max_staleness: -1,
            sla_min_availability: 0.0,
        }
    }
}

/// Full system configuration.
#[derive(Clone, Debug)]
pub struct SystemConfig {
    pub dataset: Profile,
    pub num_edges: usize,
    /// Edge chunk-store capacity (paper: 1,000 local data chunks).
    pub edge_capacity: usize,
    /// Cloud triggers an edge update after this many new QA pairs (paper: 20).
    pub update_trigger: usize,
    /// Max chunks distributed per update (paper: ≤500 from top-k communities).
    pub distribute_max_chunks: usize,
    /// Top-k communities used for updates.
    pub top_k_communities: usize,
    /// Retrieval depth (chunks fed into the generator context).
    pub retrieve_k: usize,
    /// Embedding similarity threshold for keyword matches (paper: 50%).
    pub sim_threshold: f64,
    /// Edge SLM tier name (matches artifact manifest).
    pub edge_tier: String,
    /// Cloud LLM tier name.
    pub cloud_tier: String,
    /// Gate warm-up steps T₀ (paper Table 5: 100–500).
    pub warmup_steps: usize,
    /// Gate exploration parameter β.
    pub beta: f64,
    pub qos: QosPreset,
    pub cost_weights: CostWeights,
    pub net: NetSpec,
    pub cluster: ClusterConfig,
    pub ann: AnnConfig,
    pub serve: ServeConfig,
    pub chaos: ChaosConfig,
    pub seed: u64,
}

impl Default for SystemConfig {
    fn default() -> Self {
        SystemConfig {
            dataset: Profile::Wiki,
            num_edges: 4,
            edge_capacity: 1000,
            update_trigger: 20,
            distribute_max_chunks: 500,
            top_k_communities: 5,
            retrieve_k: 6,
            sim_threshold: 0.5,
            edge_tier: "qwen3b".to_string(),
            cloud_tier: "qwen72b".to_string(),
            warmup_steps: 300,
            beta: 0.5,
            qos: QosPreset::CostEfficient,
            cost_weights: CostWeights::default(),
            net: NetSpec::default(),
            cluster: ClusterConfig::default(),
            ann: AnnConfig::default(),
            serve: ServeConfig::default(),
            chaos: ChaosConfig::default(),
            seed: 42,
        }
    }
}

impl SystemConfig {
    /// Load from a TOML-subset file; unknown keys are rejected so typos
    /// fail loudly.
    pub fn from_toml(text: &str) -> Result<SystemConfig, String> {
        let doc = parse_toml(text)?;
        let mut cfg = SystemConfig::default();
        for (section, kv) in &doc {
            for (key, val) in kv {
                let full = if section.is_empty() {
                    key.clone()
                } else {
                    format!("{section}.{key}")
                };
                cfg.apply(&full, val)?;
            }
        }
        Ok(cfg)
    }

    fn apply(&mut self, key: &str, val: &str) -> Result<(), String> {
        let bad = |k: &str, v: &str| format!("bad value {v:?} for {k}");
        match key {
            "system.dataset" | "dataset" => {
                self.dataset = Profile::parse(val).ok_or_else(|| bad(key, val))?;
            }
            "system.num_edges" | "num_edges" => {
                self.num_edges = val.parse().map_err(|_| bad(key, val))?;
            }
            "system.seed" | "seed" => {
                self.seed = val.parse().map_err(|_| bad(key, val))?;
            }
            "edge.capacity" => self.edge_capacity = val.parse().map_err(|_| bad(key, val))?,
            "edge.update_trigger" => {
                self.update_trigger = val.parse().map_err(|_| bad(key, val))?;
            }
            "edge.tier" => self.edge_tier = val.to_string(),
            "cloud.tier" => self.cloud_tier = val.to_string(),
            "cloud.distribute_max_chunks" => {
                self.distribute_max_chunks = val.parse().map_err(|_| bad(key, val))?;
            }
            "cloud.top_k_communities" => {
                self.top_k_communities = val.parse().map_err(|_| bad(key, val))?;
            }
            "retrieval.k" => self.retrieve_k = val.parse().map_err(|_| bad(key, val))?,
            "retrieval.sim_threshold" => {
                self.sim_threshold = val.parse().map_err(|_| bad(key, val))?;
            }
            "gate.warmup_steps" => {
                self.warmup_steps = val.parse().map_err(|_| bad(key, val))?;
            }
            "gate.beta" => self.beta = val.parse().map_err(|_| bad(key, val))?,
            "gate.qos" => self.qos = QosPreset::parse(val).ok_or_else(|| bad(key, val))?,
            "cost.delta1" => {
                self.cost_weights.delta1 = val.parse().map_err(|_| bad(key, val))?;
            }
            "cost.delta2" => {
                self.cost_weights.delta2 = val.parse().map_err(|_| bad(key, val))?;
            }
            "net.user_edge_base_ms" => {
                self.net.user_edge_base_ms = val.parse().map_err(|_| bad(key, val))?;
            }
            "net.edge_edge_base_ms" => {
                self.net.edge_edge_base_ms = val.parse().map_err(|_| bad(key, val))?;
            }
            "net.edge_cloud_base_ms" => {
                self.net.edge_cloud_base_ms = val.parse().map_err(|_| bad(key, val))?;
            }
            "net.jitter_sigma" => {
                self.net.jitter_sigma = val.parse().map_err(|_| bad(key, val))?;
            }
            "cluster.degree" => {
                self.cluster.degree = val.parse().map_err(|_| bad(key, val))?;
            }
            "cluster.placement" => {
                self.cluster.placement =
                    PlacementPolicy::parse(val).ok_or_else(|| bad(key, val))?;
            }
            "cluster.gossip_interval" => {
                self.cluster.gossip_interval = val.parse().map_err(|_| bad(key, val))?;
            }
            "cluster.gossip_hot_k" => {
                self.cluster.gossip_hot_k = val.parse().map_err(|_| bad(key, val))?;
            }
            "cluster.pin_rounds" => {
                self.cluster.pin_rounds = val.parse().map_err(|_| bad(key, val))?;
            }
            "cluster.hotness_half_life" => {
                self.cluster.hotness_half_life = val.parse().map_err(|_| bad(key, val))?;
            }
            "cluster.feedback" => {
                self.cluster.feedback = crate::cluster::feedback::FeedbackMode::parse(val)
                    .ok_or_else(|| bad(key, val))?;
            }
            "cluster.min_hot_k" => {
                let k: usize = val.parse().map_err(|_| bad(key, val))?;
                if k == 0 {
                    return Err(bad(key, val));
                }
                self.cluster.min_hot_k = k;
            }
            "ann.nlist" => self.ann.nlist = val.parse().map_err(|_| bad(key, val))?,
            "ann.nprobe" => self.ann.nprobe = val.parse().map_err(|_| bad(key, val))?,
            "ann.exact_below" => {
                self.ann.exact_below = val.parse().map_err(|_| bad(key, val))?;
            }
            "ann.retrain_drift" => {
                self.ann.retrain_drift = val.parse().map_err(|_| bad(key, val))?;
            }
            "ann.embed_dim" => {
                self.ann.embed_dim = val.parse().map_err(|_| bad(key, val))?;
            }
            "ann.route_blend" => {
                self.ann.route_blend = val.parse().map_err(|_| bad(key, val))?;
            }
            "serve.queue_cap" => {
                self.serve.queue_cap = val.parse().map_err(|_| bad(key, val))?;
            }
            "serve.workers" => {
                self.serve.workers = val.parse().map_err(|_| bad(key, val))?;
            }
            "serve.slo_ms" => self.serve.slo_ms = val.parse().map_err(|_| bad(key, val))?,
            "serve.admission" => {
                self.serve.admission = crate::serve::queue::AdmissionPolicy::parse(val)
                    .ok_or_else(|| bad(key, val))?;
            }
            "serve.gossip_background" => {
                self.serve.gossip_background = val.parse().map_err(|_| bad(key, val))?;
            }
            "serve.wfq_weights" => {
                // "4,2,1" → [4.0, 2.0, 1.0]; "none" disables. All three
                // weights must be finite and > 0 (a zero weight would
                // starve its lane forever, which strict priority at
                // least does predictably).
                if val == "none" {
                    self.serve.wfq_weights = None;
                } else {
                    let parts: Vec<f64> = val
                        .split(',')
                        .map(|p| p.trim().parse::<f64>())
                        .collect::<Result<_, _>>()
                        .map_err(|_| bad(key, val))?;
                    let w: [f64; 3] =
                        parts.try_into().map_err(|_| bad(key, val))?;
                    if w.iter().any(|x| !x.is_finite() || *x <= 0.0) {
                        return Err(bad(key, val));
                    }
                    self.serve.wfq_weights = Some(w);
                }
            }
            "chaos.enabled" => {
                self.chaos.enabled = val.parse().map_err(|_| bad(key, val))?;
            }
            "chaos.scenario" => {
                if !crate::chaos::Scenario::is_known(val) {
                    return Err(format!(
                        "unknown chaos scenario {val:?} (presets: {})",
                        crate::chaos::Scenario::PRESETS.join(", ")
                    ));
                }
                self.chaos.scenario = val.to_string();
            }
            "chaos.at_step" => {
                self.chaos.at_step = val.parse().map_err(|_| bad(key, val))?;
            }
            "chaos.duration_steps" => {
                self.chaos.duration_steps = val.parse().map_err(|_| bad(key, val))?;
            }
            "chaos.degrade_factor" => {
                let f: f64 = val.parse().map_err(|_| bad(key, val))?;
                if !f.is_finite() || f <= 0.0 {
                    return Err(bad(key, val));
                }
                self.chaos.degrade_factor = f;
            }
            "chaos.random_faults" => {
                self.chaos.random_faults = val.parse().map_err(|_| bad(key, val))?;
            }
            "chaos.random_seed" => {
                self.chaos.random_seed = val.parse().map_err(|_| bad(key, val))?;
            }
            "chaos.sla_recovery_ms" => {
                self.chaos.sla_recovery_ms = val.parse().map_err(|_| bad(key, val))?;
            }
            "chaos.sla_max_staleness" => {
                self.chaos.sla_max_staleness = val.parse().map_err(|_| bad(key, val))?;
            }
            "chaos.sla_min_availability" => {
                self.chaos.sla_min_availability = val.parse().map_err(|_| bad(key, val))?;
            }
            other => return Err(format!("unknown config key {other:?}")),
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_toml_sections_and_scalars() {
        let doc = parse_toml(
            r#"
            # top comment
            dataset = "wiki"
            [edge]
            capacity = 1000   # trailing comment
            tier = "qwen3b"
            [gate]
            beta = 2.5
            "#,
        )
        .unwrap();
        assert_eq!(doc[""]["dataset"], "wiki");
        assert_eq!(doc["edge"]["capacity"], "1000");
        assert_eq!(doc["edge"]["tier"], "qwen3b");
        assert_eq!(doc["gate"]["beta"], "2.5");
    }

    #[test]
    fn parse_toml_rejects_malformed() {
        assert!(parse_toml("[unclosed").is_err());
        assert!(parse_toml("keynovalue").is_err());
    }

    #[test]
    fn config_from_toml_overrides_defaults() {
        let cfg = SystemConfig::from_toml(
            r#"
            dataset = "hp"
            num_edges = 6
            [edge]
            capacity = 600
            update_trigger = 10
            [gate]
            qos = "delay"
            warmup_steps = 100
            "#,
        )
        .unwrap();
        assert_eq!(cfg.dataset, Profile::HarryPotter);
        assert_eq!(cfg.num_edges, 6);
        assert_eq!(cfg.edge_capacity, 600);
        assert_eq!(cfg.update_trigger, 10);
        assert_eq!(cfg.qos, QosPreset::DelayOriented);
        assert_eq!(cfg.warmup_steps, 100);
        // untouched defaults survive
        assert_eq!(cfg.distribute_max_chunks, 500);
    }

    #[test]
    fn config_rejects_unknown_keys() {
        assert!(SystemConfig::from_toml("[edge]\nbogus = 1").is_err());
        assert!(SystemConfig::from_toml("dataset = \"nope\"").is_err());
        assert!(SystemConfig::from_toml("[cluster]\nbogus = 1").is_err());
    }

    #[test]
    fn cluster_knobs_from_toml() {
        let cfg = SystemConfig::from_toml(
            r#"
            [cluster]
            degree = 3
            placement = "fifo"
            gossip_interval = 40
            gossip_hot_k = 16
            pin_rounds = 4
            hotness_half_life = 90.5
            feedback = "hit-rate"
            min_hot_k = 12
            "#,
        )
        .unwrap();
        assert_eq!(cfg.cluster.degree, 3);
        assert_eq!(cfg.cluster.placement, PlacementPolicy::Fifo);
        assert_eq!(cfg.cluster.gossip_interval, 40);
        assert_eq!(cfg.cluster.gossip_hot_k, 16);
        assert_eq!(cfg.cluster.pin_rounds, 4);
        assert_eq!(cfg.cluster.hotness_half_life, 90.5);
        assert_eq!(cfg.cluster.feedback, crate::cluster::feedback::FeedbackMode::HitRate);
        assert_eq!(cfg.cluster.min_hot_k, 12);
        assert!(SystemConfig::from_toml("[cluster]\nplacement = \"nope\"").is_err());
        assert!(SystemConfig::from_toml("[cluster]\nfeedback = \"nope\"").is_err());
        // A zero budget floor would let a link advertise nothing and
        // wedge the suppression fingerprints; reject it at parse time.
        assert!(SystemConfig::from_toml("[cluster]\nmin_hot_k = 0").is_err());
        // Untouched defaults: feedback stays off (bit-identity).
        assert_eq!(
            SystemConfig::default().cluster.placement,
            PlacementPolicy::HotnessLru
        );
        assert_eq!(
            SystemConfig::default().cluster.feedback,
            crate::cluster::feedback::FeedbackMode::None
        );
        assert_eq!(SystemConfig::default().cluster.min_hot_k, 8);
    }

    #[test]
    fn ann_knobs_from_toml() {
        let cfg = SystemConfig::from_toml(
            r#"
            [ann]
            nlist = 64
            nprobe = 8
            exact_below = 512
            retrain_drift = 0.3
            embed_dim = 128
            route_blend = 0.6
            "#,
        )
        .unwrap();
        assert_eq!(cfg.ann.nlist, 64);
        assert_eq!(cfg.ann.nprobe, 8);
        assert_eq!(cfg.ann.exact_below, 512);
        assert_eq!(cfg.ann.retrain_drift, 0.3);
        assert_eq!(cfg.ann.embed_dim, 128);
        assert_eq!(cfg.ann.route_blend, 0.6);
        assert!(SystemConfig::from_toml("[ann]\nbogus = 1").is_err());
        // Untouched defaults: exact fallback covers paper-scale stores.
        assert!(SystemConfig::default().ann.exact_below > 1000);
    }

    #[test]
    fn serve_knobs_from_toml() {
        use crate::serve::queue::AdmissionPolicy;
        let cfg = SystemConfig::from_toml(
            r#"
            [serve]
            queue_cap = 64
            workers = 4
            slo_ms = 1500.5
            admission = "downgrade"
            gossip_background = true
            "#,
        )
        .unwrap();
        assert_eq!(cfg.serve.queue_cap, 64);
        assert_eq!(cfg.serve.workers, 4);
        assert_eq!(cfg.serve.slo_ms, 1500.5);
        assert_eq!(cfg.serve.admission, AdmissionPolicy::Downgrade);
        assert!(cfg.serve.gossip_background);
        assert!(SystemConfig::from_toml("[serve]\nbogus = 1").is_err());
        assert!(SystemConfig::from_toml("[serve]\nadmission = \"nope\"").is_err());
        // The defaults keep serve_async ≡ the synchronous path: no cap,
        // one worker, admission off, foreground gossip.
        let d = SystemConfig::default().serve;
        assert_eq!(d.queue_cap, 0);
        assert_eq!(d.workers, 1);
        assert_eq!(d.admission, AdmissionPolicy::None);
        assert!(!d.gossip_background);
    }

    #[test]
    fn wfq_weights_from_toml() {
        let cfg = SystemConfig::from_toml("[serve]\nwfq_weights = \"4,2,1\"").unwrap();
        assert_eq!(cfg.serve.wfq_weights, Some([4.0, 2.0, 1.0]));
        let cfg = SystemConfig::from_toml("[serve]\nwfq_weights = \"none\"").unwrap();
        assert_eq!(cfg.serve.wfq_weights, None);
        // Wrong arity, zero, negative, and garbage all fail loudly.
        assert!(SystemConfig::from_toml("[serve]\nwfq_weights = \"4,2\"").is_err());
        assert!(SystemConfig::from_toml("[serve]\nwfq_weights = \"4,0,1\"").is_err());
        assert!(SystemConfig::from_toml("[serve]\nwfq_weights = \"4,-2,1\"").is_err());
        assert!(SystemConfig::from_toml("[serve]\nwfq_weights = \"a,b,c\"").is_err());
        // Default keeps strict priority.
        assert_eq!(SystemConfig::default().serve.wfq_weights, None);
    }

    #[test]
    fn wfq_weights_reject_non_finite_at_parse_time() {
        // Rust's f64 parser happily accepts "inf"/"nan", so without the
        // explicit finiteness guard these would survive parsing and
        // only blow up (or worse, silently misbehave) at queue
        // construction. They must be a config error, not a runtime one.
        for bad in ["inf,2,1", "4,inf,1", "nan,2,1", "4,2,NaN", "-inf,2,1", "1e999,2,1"] {
            assert!(
                SystemConfig::from_toml(&format!("[serve]\nwfq_weights = \"{bad}\"")).is_err(),
                "wfq_weights = {bad:?} must be rejected at parse time"
            );
        }
        // The guard must not over-reject ordinary float weights.
        let cfg = SystemConfig::from_toml("[serve]\nwfq_weights = \"2.5, 1.5, 0.5\"").unwrap();
        assert_eq!(cfg.serve.wfq_weights, Some([2.5, 1.5, 0.5]));
    }

    #[test]
    fn chaos_knobs_from_toml() {
        let cfg = SystemConfig::from_toml(
            r#"
            [chaos]
            enabled = true
            scenario = "flaky-uplink"
            at_step = 30
            duration_steps = 50
            degrade_factor = 6.5
            random_faults = 12
            random_seed = 99
            sla_recovery_ms = 4000.0
            sla_max_staleness = 2
            sla_min_availability = 0.95
            "#,
        )
        .unwrap();
        assert!(cfg.chaos.enabled);
        assert_eq!(cfg.chaos.scenario, "flaky-uplink");
        assert_eq!(cfg.chaos.at_step, 30);
        assert_eq!(cfg.chaos.duration_steps, 50);
        assert_eq!(cfg.chaos.degrade_factor, 6.5);
        assert_eq!(cfg.chaos.random_faults, 12);
        assert_eq!(cfg.chaos.random_seed, 99);
        assert_eq!(cfg.chaos.sla_recovery_ms, 4000.0);
        assert_eq!(cfg.chaos.sla_max_staleness, 2);
        assert_eq!(cfg.chaos.sla_min_availability, 0.95);
        // Scenario names are validated at parse time so the serve loop
        // can rely on Scenario::from_config succeeding.
        assert!(SystemConfig::from_toml("[chaos]\nscenario = \"nope\"").is_err());
        assert!(SystemConfig::from_toml("[chaos]\ndegrade_factor = 0").is_err());
        assert!(SystemConfig::from_toml("[chaos]\nbogus = 1").is_err());
        // Disabled by default — the bit-identity guarantee.
        let d = SystemConfig::default().chaos;
        assert!(!d.enabled);
        assert_eq!(d.scenario, "split-brain");
        assert!(d.sla_recovery_ms <= 0.0 && d.sla_max_staleness < 0);
    }

    #[test]
    fn defaults_match_paper_prototype() {
        let c = SystemConfig::default();
        assert_eq!(c.edge_capacity, 1000); // §5: 1,000 local data chunks
        assert_eq!(c.update_trigger, 20); // §5: 20 new QA pairs
        assert_eq!(c.distribute_max_chunks, 500); // §5: up to 500 chunks
        assert_eq!(c.sim_threshold, 0.5); // §5: >50% similarity
    }

    #[test]
    fn qos_presets() {
        let (acc, delay) = QosPreset::CostEfficient.constraints();
        assert!(acc >= 0.75 && delay == 5.0);
        let (_, d2) = QosPreset::DelayOriented.constraints();
        assert_eq!(d2, 1.0);
    }
}
