//! Cosine-similarity vector store (the dense half of retrieval).
//!
//! Stores unit-normalized embeddings produced by the runtime embedder
//! (the MiniLM stand-in) and answers top-k / threshold queries. The scan
//! is brute force but engineered for scale (ROADMAP: millions of users,
//! edge stores far beyond the paper's 1,000-chunk prototype):
//!
//! * **O(1) id bookkeeping** — an id→slot `HashMap` backs `insert` /
//!   `remove` / `contains`, so mutation cost no longer grows with the
//!   store (the seed did an `O(n)` `iter().position()` per call).
//! * **Blocked, 8-lane-unrolled dot kernel** — [`dot_f32`] accumulates
//!   into eight independent lanes so the compiler auto-vectorizes the
//!   inner loop; the scan is memory-bandwidth bound, as it should be.
//! * **Bounded-heap top-k** — `O(n log k)` partial select instead of the
//!   seed's full `O(n log n)` sort; at k=8 over 100k rows the sort was
//!   the dominant cost.
//! * **Sharded parallel scan** — stores with ≥ [`SHARD_MIN_ROWS`] rows
//!   split across `std::thread` scoped workers with a deterministic
//!   merge; results are bit-identical to the serial scan (each row's
//!   score is computed independently, and the merge applies the same
//!   total order). See `benches/perf_hotpath.rs` for measured rates and
//!   `tests/perf_equivalence.rs` for the equivalence properties.
//!
//! Ranking order everywhere: score descending, ties broken by ascending
//! id. Scores are finite by construction (rows are L2-normalized on
//! insert, queries are normalized by the scan).
//!
//! **Remove-then-top_k interaction.** `remove` is a *swap-remove*: the
//! last row moves into the vacated slot, so churn permutes the store's
//! internal slot order. That permutation is invisible to queries — the
//! total order above is over `(score, id)`, never slot position — so a
//! churned store and a freshly rebuilt store with the same surviving
//! rows return bit-identical `top_k` results (asserted by
//! `removal_reorders_slots_but_not_ranking` below). Anything that walks
//! rows in slot order (the scan itself, shard boundaries) must
//! therefore never let position influence ranking — only `(score, id)`.
//!
//! For stores past ~10⁵ rows the [`ivf`] submodule layers an
//! inverted-file ANN index on top: same kernel, same ranking order,
//! sublinear probed volume, exact fallback below a size threshold.

pub mod ivf;

use std::collections::HashMap;

/// Minimum rows of scan work per parallel shard; `top_k` adds one
/// worker per multiple of this (so parallelism starts at 2× this size)
/// to keep thread-spawn cost amortized.
pub const SHARD_MIN_ROWS: usize = 16_384;

/// Blocked 8-lane dot product over f32 slices. The eight independent
/// accumulators break the serial dependency chain so the autovectorizer
/// emits wide FMA lanes; the pairwise reduction keeps the result
/// deterministic for a given slice (it does differ from a strict
/// sequential sum in the last ulps, which every consumer tolerates).
#[inline]
pub fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; 8];
    let chunks_a = a.chunks_exact(8);
    let chunks_b = b.chunks_exact(8);
    let rem_a = chunks_a.remainder();
    let rem_b = chunks_b.remainder();
    for (ca, cb) in chunks_a.zip(chunks_b) {
        acc[0] += ca[0] * cb[0];
        acc[1] += ca[1] * cb[1];
        acc[2] += ca[2] * cb[2];
        acc[3] += ca[3] * cb[3];
        acc[4] += ca[4] * cb[4];
        acc[5] += ca[5] * cb[5];
        acc[6] += ca[6] * cb[6];
        acc[7] += ca[7] * cb[7];
    }
    let mut s = ((acc[0] + acc[4]) + (acc[1] + acc[5]))
        + ((acc[2] + acc[6]) + (acc[3] + acc[7]));
    for (x, y) in rem_a.iter().zip(rem_b) {
        s += x * y;
    }
    s
}

/// The store's single ranking order: score descending, ties broken by
/// ascending id. Total order (ids are unique per store, scores finite),
/// so heap selection, shard merge, and final sorts all agree — every
/// "bit-identical" equivalence guarantee hangs off this one function.
#[inline]
pub fn rank_desc(a: &(usize, f32), b: &(usize, f32)) -> std::cmp::Ordering {
    b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0))
}

/// `a` ranks ahead of `b` under [`rank_desc`].
#[inline]
fn ranks_ahead(a: (usize, f32), b: (usize, f32)) -> bool {
    rank_desc(&a, &b) == std::cmp::Ordering::Less
}

/// Bounded selector keeping the k best (id, score) candidates seen so
/// far, backed by a binary min-heap keyed by "worst first". O(log k)
/// per displacing insert, O(1) per rejected candidate.
struct TopK {
    k: usize,
    /// Binary heap, root = worst kept candidate.
    heap: Vec<(usize, f32)>,
}

impl TopK {
    fn new(k: usize) -> TopK {
        TopK {
            k,
            heap: Vec::with_capacity(k),
        }
    }

    /// `a` is "worse" than `b` (belongs nearer the root).
    #[inline]
    fn worse(a: (usize, f32), b: (usize, f32)) -> bool {
        ranks_ahead(b, a)
    }

    #[inline]
    fn push(&mut self, cand: (usize, f32)) {
        if self.heap.len() < self.k {
            self.heap.push(cand);
            // Sift up.
            let mut i = self.heap.len() - 1;
            while i > 0 {
                let parent = (i - 1) / 2;
                if Self::worse(self.heap[i], self.heap[parent]) {
                    self.heap.swap(i, parent);
                    i = parent;
                } else {
                    break;
                }
            }
        } else if ranks_ahead(cand, self.heap[0]) {
            // Displace the current worst, sift down.
            self.heap[0] = cand;
            let n = self.heap.len();
            let mut i = 0;
            loop {
                let (l, r) = (2 * i + 1, 2 * i + 2);
                let mut worst = i;
                if l < n && Self::worse(self.heap[l], self.heap[worst]) {
                    worst = l;
                }
                if r < n && Self::worse(self.heap[r], self.heap[worst]) {
                    worst = r;
                }
                if worst == i {
                    break;
                }
                self.heap.swap(i, worst);
                i = worst;
            }
        }
    }

    /// Extract the kept candidates, best first.
    fn into_sorted(self) -> Vec<(usize, f32)> {
        let mut v = self.heap;
        v.sort_by(rank_desc);
        v
    }
}

/// A vector store over fixed-dimension embeddings.
#[derive(Clone, Debug, Default)]
pub struct VecStore {
    dim: usize,
    ids: Vec<usize>,
    /// Row-major, one row per id; rows are L2-normalized on insert.
    data: Vec<f32>,
    /// id → row slot; keeps insert/remove O(1) in the store size.
    slot_of: HashMap<usize, usize>,
}

impl VecStore {
    pub fn new(dim: usize) -> Self {
        VecStore {
            dim,
            ids: Vec::new(),
            data: Vec::new(),
            slot_of: HashMap::new(),
        }
    }

    /// Pre-size for `rows` vectors (bulk-load path).
    pub fn with_capacity(dim: usize, rows: usize) -> Self {
        VecStore {
            dim,
            ids: Vec::with_capacity(rows),
            data: Vec::with_capacity(rows * dim),
            slot_of: HashMap::with_capacity(rows),
        }
    }

    pub fn len(&self) -> usize {
        self.ids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ids.is_empty()
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn contains(&self, id: usize) -> bool {
        self.slot_of.contains_key(&id)
    }

    /// Insert (or replace) a vector under `id`. The stored copy is
    /// L2-normalized so `score == cosine`.
    pub fn insert(&mut self, id: usize, v: &[f32]) {
        assert_eq!(v.len(), self.dim, "dim mismatch");
        let norm = (v.iter().map(|x| x * x).sum::<f32>()).sqrt().max(1e-12);
        if let Some(&pos) = self.slot_of.get(&id) {
            let row = &mut self.data[pos * self.dim..(pos + 1) * self.dim];
            for (r, x) in row.iter_mut().zip(v) {
                *r = *x / norm;
            }
        } else {
            self.slot_of.insert(id, self.ids.len());
            self.ids.push(id);
            self.data.extend(v.iter().map(|x| x / norm));
        }
    }

    /// Remove a vector (swap-remove; O(dim) data movement, O(1) lookup).
    pub fn remove(&mut self, id: usize) -> bool {
        let Some(pos) = self.slot_of.remove(&id) else {
            return false;
        };
        let last = self.ids.len() - 1;
        self.ids.swap(pos, last);
        self.ids.pop();
        if pos != last {
            // The former last row moved into `pos`.
            self.slot_of.insert(self.ids[pos], pos);
            let (head, tail) = self.data.split_at_mut(last * self.dim);
            head[pos * self.dim..(pos + 1) * self.dim].copy_from_slice(&tail[..self.dim]);
        }
        self.data.truncate(last * self.dim);
        true
    }

    #[inline]
    fn row(&self, pos: usize) -> &[f32] {
        &self.data[pos * self.dim..(pos + 1) * self.dim]
    }

    /// Id stored at `pos` (internal: the IVF layer walks slots).
    #[inline]
    fn id_at(&self, pos: usize) -> usize {
        self.ids[pos]
    }

    /// Slot of `id`, if resident (internal: used by the IVF layer).
    #[inline]
    fn slot(&self, id: usize) -> Option<usize> {
        self.slot_of.get(&id).copied()
    }

    #[inline]
    fn query_norm(&self, q: &[f32]) -> f32 {
        assert_eq!(q.len(), self.dim, "query dim mismatch");
        (q.iter().map(|x| x * x).sum::<f32>()).sqrt().max(1e-12)
    }

    /// Cosine of `q` against every stored vector, in slot order. Mostly
    /// useful as the reference scorer for equivalence tests; the serving
    /// paths use the bounded-heap scans below.
    pub fn score_all(&self, q: &[f32]) -> Vec<(usize, f32)> {
        let qn = self.query_norm(q);
        self.ids
            .iter()
            .enumerate()
            .map(|(pos, &id)| (id, dot_f32(self.row(pos), q) / qn))
            .collect()
    }

    /// Cosine of `q` against every stored vector: returns (id, score)
    /// top-k, descending, ties broken by id. Large stores scan in
    /// parallel shards (bit-identical results either way).
    pub fn top_k(&self, q: &[f32], k: usize) -> Vec<(usize, f32)> {
        // Scale worker count with the store so each shard amortizes at
        // least SHARD_MIN_ROWS of scan work over its spawn cost: 2
        // shards at 2×16k rows, up to the hardware limit at ≥8×16k.
        // Stores just past the threshold stay serial rather than paying
        // thread churn for a sub-millisecond scan.
        let cores = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        let shards = (self.len() / SHARD_MIN_ROWS).min(cores).min(8);
        if shards >= 2 {
            self.top_k_with_shards(q, k, shards)
        } else {
            self.top_k_serial(q, k)
        }
    }

    /// Single-threaded bounded-heap scan (O(n log k)).
    pub fn top_k_serial(&self, q: &[f32], k: usize) -> Vec<(usize, f32)> {
        let qn = self.query_norm(q);
        if k == 0 || self.is_empty() {
            return Vec::new();
        }
        self.scan_range(q, qn, 0, self.len(), k).into_sorted()
    }

    /// Sharded parallel scan with deterministic merge: each worker runs
    /// the same bounded-heap scan over a contiguous slot range, then the
    /// per-shard winners (≤ shards·k candidates) are merged under the
    /// global order. Bit-identical to [`Self::top_k_serial`] because a
    /// row's score does not depend on which shard computes it.
    pub fn top_k_with_shards(&self, q: &[f32], k: usize, shards: usize) -> Vec<(usize, f32)> {
        let n = self.len();
        if k == 0 || n == 0 {
            return Vec::new();
        }
        let shards = shards.clamp(1, n);
        if shards == 1 {
            return self.top_k_serial(q, k);
        }
        let qn = self.query_norm(q);
        let per = (n + shards - 1) / shards;
        let partials: Vec<Vec<(usize, f32)>> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..shards)
                .map(|t| {
                    let lo = t * per;
                    let hi = ((t + 1) * per).min(n);
                    scope.spawn(move || {
                        if lo >= hi {
                            Vec::new()
                        } else {
                            self.scan_range(q, qn, lo, hi, k).into_sorted()
                        }
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("shard scan panicked"))
                .collect()
        });
        // Deterministic merge: global order over all shard winners.
        let mut merged: Vec<(usize, f32)> = partials.into_iter().flatten().collect();
        merged.sort_by(rank_desc);
        merged.truncate(k);
        merged
    }

    /// Bounded-heap scan over slots `[lo, hi)`. The heap is capped at
    /// the range size: a pathological `k` (e.g. `usize::MAX`) must not
    /// reserve a k-sized buffer when only `hi - lo` candidates exist.
    fn scan_range(&self, q: &[f32], qn: f32, lo: usize, hi: usize, k: usize) -> TopK {
        let mut top = TopK::new(k.min(hi - lo));
        for pos in lo..hi {
            let s = dot_f32(self.row(pos), q) / qn;
            top.push((self.ids[pos], s));
        }
        top
    }

    /// Reference top-k via full sort — the seed implementation, retained
    /// so benches can report the before/after ratio on the same machine
    /// and property tests can assert exact equivalence.
    pub fn top_k_fullsort(&self, q: &[f32], k: usize) -> Vec<(usize, f32)> {
        let mut scored = self.score_all(q);
        scored.sort_by(rank_desc);
        scored.truncate(k);
        scored
    }

    /// All ids whose cosine against `q` is at least `threshold` — the
    /// paper's ">50% similarity ⇒ valid keyword match" rule. Single
    /// linear pass; only the survivors are sorted (the seed full-sorted
    /// the entire store via `top_k(q, len)`).
    pub fn above_threshold(&self, q: &[f32], threshold: f32) -> Vec<(usize, f32)> {
        if self.is_empty() {
            return Vec::new();
        }
        let qn = self.query_norm(q);
        let mut hits: Vec<(usize, f32)> = Vec::new();
        for pos in 0..self.len() {
            let s = dot_f32(self.row(pos), q) / qn;
            if s >= threshold {
                hits.push((self.ids[pos], s));
            }
        }
        hits.sort_by(rank_desc);
        hits
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_and_topk() {
        let mut vs = VecStore::new(3);
        vs.insert(10, &[1.0, 0.0, 0.0]);
        vs.insert(20, &[0.0, 1.0, 0.0]);
        vs.insert(30, &[0.7, 0.7, 0.0]);
        let top = vs.top_k(&[1.0, 0.0, 0.0], 2);
        assert_eq!(top[0].0, 10);
        assert!((top[0].1 - 1.0).abs() < 1e-6);
        assert_eq!(top[1].0, 30);
    }

    #[test]
    fn normalization_on_insert() {
        let mut vs = VecStore::new(2);
        vs.insert(1, &[10.0, 0.0]); // scaled input
        let top = vs.top_k(&[1.0, 0.0], 1);
        assert!((top[0].1 - 1.0).abs() < 1e-6);
    }

    #[test]
    fn replace_same_id() {
        let mut vs = VecStore::new(2);
        vs.insert(1, &[1.0, 0.0]);
        vs.insert(1, &[0.0, 1.0]);
        assert_eq!(vs.len(), 1);
        let top = vs.top_k(&[0.0, 1.0], 1);
        assert!((top[0].1 - 1.0).abs() < 1e-6);
    }

    /// Regression for the remove-then-top_k interaction documented in
    /// the module header: swap-remove churn permutes slot order but
    /// must never change what `top_k` returns. A store that went
    /// through interleaved inserts/removes is compared bit-for-bit
    /// against a store freshly rebuilt from only the survivors.
    #[test]
    fn removal_reorders_slots_but_not_ranking() {
        let dim = 16;
        let mut rng = crate::util::rng::Rng::new(0xC0FFEE);
        let vec_for = |rng: &mut crate::util::rng::Rng| -> Vec<f32> {
            (0..dim).map(|_| rng.f64() as f32 - 0.5).collect()
        };

        let mut churned = VecStore::new(dim);
        let mut rows: Vec<(usize, Vec<f32>)> = Vec::new();
        for id in 0..64 {
            let v = vec_for(&mut rng);
            churned.insert(id, &v);
            rows.push((id, v));
        }
        // Remove interior rows (each triggers a swap from the tail),
        // including a back-to-back pair so a just-moved row moves again.
        for id in [3usize, 17, 18, 40, 41, 42, 0] {
            assert!(churned.remove(id));
            rows.retain(|(i, _)| *i != id);
        }
        // Churn further: re-insert one removed id with a fresh vector.
        let v = vec_for(&mut rng);
        churned.insert(17, &v);
        rows.push((17, v));

        let mut rebuilt = VecStore::new(dim);
        for (id, v) in &rows {
            rebuilt.insert(*id, v);
        }
        assert_eq!(churned.len(), rebuilt.len());

        for qi in 0..8 {
            let q = vec_for(&mut rng);
            let a = churned.top_k(&q, 10);
            let b = rebuilt.top_k(&q, 10);
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.0, y.0, "query {qi}: id order diverged");
                assert_eq!(
                    x.1.to_bits(),
                    y.1.to_bits(),
                    "query {qi}: score not bit-identical"
                );
            }
        }
    }

    #[test]
    fn remove_swaps_correctly() {
        let mut vs = VecStore::new(2);
        vs.insert(1, &[1.0, 0.0]);
        vs.insert(2, &[0.0, 1.0]);
        vs.insert(3, &[-1.0, 0.0]);
        assert!(vs.remove(1));
        assert!(!vs.remove(99));
        assert_eq!(vs.len(), 2);
        assert!(!vs.contains(1));
        assert!(vs.contains(3));
        let top = vs.top_k(&[0.0, 1.0], 2);
        assert_eq!(top[0].0, 2);
        assert_eq!(top[1].0, 3);
    }

    #[test]
    fn remove_then_reinsert_keeps_slots_coherent() {
        let mut vs = VecStore::new(2);
        for id in 0..10 {
            vs.insert(id, &[id as f32 + 1.0, 1.0]);
        }
        // Remove from the middle (forces swap-relocation), then reuse ids.
        assert!(vs.remove(3));
        assert!(vs.remove(0));
        vs.insert(3, &[0.0, 1.0]);
        assert_eq!(vs.len(), 9);
        let top = vs.top_k(&[0.0, 1.0], 1);
        assert_eq!(top[0].0, 3);
        assert!((top[0].1 - 1.0).abs() < 1e-6);
    }

    #[test]
    fn threshold_rule() {
        let mut vs = VecStore::new(2);
        vs.insert(1, &[1.0, 0.0]);
        vs.insert(2, &[0.6, 0.8]);
        vs.insert(3, &[0.0, 1.0]);
        let hits = vs.above_threshold(&[1.0, 0.0], 0.5);
        assert_eq!(hits.iter().map(|h| h.0).collect::<Vec<_>>(), vec![1, 2]);
    }

    #[test]
    fn empty_store() {
        let vs = VecStore::new(4);
        assert!(vs.top_k(&[1.0, 0.0, 0.0, 0.0], 5).is_empty());
        assert!(vs.above_threshold(&[1.0, 0.0, 0.0, 0.0], 0.5).is_empty());
    }

    #[test]
    fn k_zero_and_k_beyond_len() {
        let mut vs = VecStore::new(2);
        vs.insert(7, &[1.0, 0.0]);
        assert!(vs.top_k(&[1.0, 0.0], 0).is_empty());
        assert_eq!(vs.top_k(&[1.0, 0.0], 10).len(), 1);
    }

    #[test]
    fn pathological_k_no_overallocation() {
        // k far beyond the store must neither panic nor reserve k-sized
        // buffers (TopK caps at the scan-range size), and must keep the
        // same tie-break order as the fullsort reference.
        let mut vs = VecStore::new(2);
        for i in 0..6 {
            vs.insert(i, &[(i % 3) as f32 + 1.0, 1.0]); // duplicate rows → ties
        }
        let q = [1.0, 0.0];
        let all = vs.top_k(&q, usize::MAX);
        assert_eq!(all.len(), 6);
        assert_eq!(all, vs.top_k_fullsort(&q, usize::MAX));
        assert_eq!(vs.top_k_serial(&q, usize::MAX), all);
        assert_eq!(vs.top_k_with_shards(&q, usize::MAX, 3), all);
        assert!(vs.top_k(&q, 0).is_empty());
    }

    #[test]
    fn heap_matches_fullsort_small() {
        let mut vs = VecStore::new(4);
        // Include duplicated rows to exercise score ties.
        let rows: [[f32; 4]; 6] = [
            [1.0, 0.0, 0.0, 0.0],
            [0.5, 0.5, 0.0, 0.0],
            [1.0, 0.0, 0.0, 0.0],
            [0.0, 0.0, 1.0, 0.0],
            [0.5, 0.5, 0.0, 0.0],
            [0.2, 0.9, 0.1, 0.0],
        ];
        for (i, r) in rows.iter().enumerate() {
            vs.insert(i * 3, r);
        }
        let q = [0.8, 0.1, 0.1, 0.0];
        for k in 0..=7 {
            assert_eq!(vs.top_k_serial(&q, k), vs.top_k_fullsort(&q, k), "k={k}");
        }
    }

    #[test]
    fn sharded_matches_serial_small() {
        let mut vs = VecStore::new(8);
        for i in 0..300 {
            let v: Vec<f32> = (0..8).map(|j| ((i * 7 + j * 13) % 17) as f32 - 8.0).collect();
            vs.insert(i, &v);
        }
        let q: Vec<f32> = (0..8).map(|j| (j as f32) - 3.5).collect();
        let serial = vs.top_k_serial(&q, 10);
        for shards in [2, 3, 5, 8] {
            assert_eq!(vs.top_k_with_shards(&q, 10, shards), serial, "shards={shards}");
        }
    }

    #[test]
    fn dot_kernel_matches_scalar() {
        for n in [0usize, 1, 7, 8, 9, 31, 64, 65] {
            let a: Vec<f32> = (0..n).map(|i| (i as f32 * 0.37).sin()).collect();
            let b: Vec<f32> = (0..n).map(|i| (i as f32 * 0.11).cos()).collect();
            let scalar: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot_f32(&a, &b) - scalar).abs() < 1e-4, "n={n}");
        }
    }
}
