//! Cosine-similarity vector store (the dense half of retrieval).
//!
//! Stores unit-normalized embeddings produced by the runtime embedder
//! (the MiniLM stand-in) and answers top-k / threshold queries. Brute
//! force with a blocked scan — at edge-store scale (≤ a few thousand
//! vectors × 64 dims) this is memory-bandwidth bound and far from the
//! bottleneck; see benches/perf_hotpath.rs for measured scan rates.

/// A vector store over fixed-dimension embeddings.
#[derive(Clone, Debug)]
pub struct VecStore {
    dim: usize,
    ids: Vec<usize>,
    /// Row-major, one row per id; rows are L2-normalized on insert.
    data: Vec<f32>,
}

impl VecStore {
    pub fn new(dim: usize) -> Self {
        VecStore {
            dim,
            ids: Vec::new(),
            data: Vec::new(),
        }
    }

    pub fn len(&self) -> usize {
        self.ids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ids.is_empty()
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Insert (or replace) a vector under `id`. The stored copy is
    /// L2-normalized so `score == cosine`.
    pub fn insert(&mut self, id: usize, v: &[f32]) {
        assert_eq!(v.len(), self.dim, "dim mismatch");
        let norm = (v.iter().map(|x| x * x).sum::<f32>()).sqrt().max(1e-12);
        if let Some(pos) = self.ids.iter().position(|&i| i == id) {
            let row = &mut self.data[pos * self.dim..(pos + 1) * self.dim];
            for (r, x) in row.iter_mut().zip(v) {
                *r = *x / norm;
            }
        } else {
            self.ids.push(id);
            self.data.extend(v.iter().map(|x| x / norm));
        }
    }

    /// Remove a vector (swap-remove; O(dim)).
    pub fn remove(&mut self, id: usize) -> bool {
        if let Some(pos) = self.ids.iter().position(|&i| i == id) {
            let last = self.ids.len() - 1;
            self.ids.swap(pos, last);
            self.ids.pop();
            if pos != last {
                let (head, tail) = self.data.split_at_mut(last * self.dim);
                head[pos * self.dim..(pos + 1) * self.dim].copy_from_slice(&tail[..self.dim]);
            }
            self.data.truncate(last * self.dim);
            true
        } else {
            false
        }
    }

    /// Cosine of `q` against every stored vector: returns (id, score)
    /// top-k, descending, ties broken by id.
    pub fn top_k(&self, q: &[f32], k: usize) -> Vec<(usize, f32)> {
        assert_eq!(q.len(), self.dim);
        let qn = (q.iter().map(|x| x * x).sum::<f32>()).sqrt().max(1e-12);
        let mut scored: Vec<(usize, f32)> = self
            .ids
            .iter()
            .enumerate()
            .map(|(pos, &id)| {
                let row = &self.data[pos * self.dim..(pos + 1) * self.dim];
                let mut s = 0.0f32;
                for i in 0..self.dim {
                    s += row[i] * q[i];
                }
                (id, s / qn)
            })
            .collect();
        scored.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
        scored.truncate(k);
        scored
    }

    /// All ids whose cosine against `q` is at least `threshold` — the
    /// paper's ">50% similarity ⇒ valid keyword match" rule.
    pub fn above_threshold(&self, q: &[f32], threshold: f32) -> Vec<(usize, f32)> {
        let mut v: Vec<(usize, f32)> = self
            .top_k(q, self.len())
            .into_iter()
            .take_while(|&(_, s)| s >= threshold)
            .collect();
        v.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_and_topk() {
        let mut vs = VecStore::new(3);
        vs.insert(10, &[1.0, 0.0, 0.0]);
        vs.insert(20, &[0.0, 1.0, 0.0]);
        vs.insert(30, &[0.7, 0.7, 0.0]);
        let top = vs.top_k(&[1.0, 0.0, 0.0], 2);
        assert_eq!(top[0].0, 10);
        assert!((top[0].1 - 1.0).abs() < 1e-6);
        assert_eq!(top[1].0, 30);
    }

    #[test]
    fn normalization_on_insert() {
        let mut vs = VecStore::new(2);
        vs.insert(1, &[10.0, 0.0]); // scaled input
        let top = vs.top_k(&[1.0, 0.0], 1);
        assert!((top[0].1 - 1.0).abs() < 1e-6);
    }

    #[test]
    fn replace_same_id() {
        let mut vs = VecStore::new(2);
        vs.insert(1, &[1.0, 0.0]);
        vs.insert(1, &[0.0, 1.0]);
        assert_eq!(vs.len(), 1);
        let top = vs.top_k(&[0.0, 1.0], 1);
        assert!((top[0].1 - 1.0).abs() < 1e-6);
    }

    #[test]
    fn remove_swaps_correctly() {
        let mut vs = VecStore::new(2);
        vs.insert(1, &[1.0, 0.0]);
        vs.insert(2, &[0.0, 1.0]);
        vs.insert(3, &[-1.0, 0.0]);
        assert!(vs.remove(1));
        assert!(!vs.remove(99));
        assert_eq!(vs.len(), 2);
        let top = vs.top_k(&[0.0, 1.0], 2);
        assert_eq!(top[0].0, 2);
        assert_eq!(top[1].0, 3);
    }

    #[test]
    fn threshold_rule() {
        let mut vs = VecStore::new(2);
        vs.insert(1, &[1.0, 0.0]);
        vs.insert(2, &[0.6, 0.8]);
        vs.insert(3, &[0.0, 1.0]);
        let hits = vs.above_threshold(&[1.0, 0.0], 0.5);
        assert_eq!(hits.iter().map(|h| h.0).collect::<Vec<_>>(), vec![1, 2]);
    }

    #[test]
    fn empty_store() {
        let vs = VecStore::new(4);
        assert!(vs.top_k(&[1.0, 0.0, 0.0, 0.0], 5).is_empty());
    }
}
