//! IVF (inverted-file) ANN layer over [`VecStore`] — sublinear top-k.
//!
//! The flat scan in the parent module is O(n·d) per query; fine for the
//! paper's 1,000-chunk prototype, the dominant serving cost at the
//! 100k/1M-row scales the ROADMAP targets. This module adds the classic
//! IVF construction (the same partition-then-probe idea CoEdge-RAG and
//! other distributed-RAG systems lean on):
//!
//! * **Offline spherical k-means** — a deterministic Lloyd's loop
//!   (seeded via [`crate::util::rng::Rng`], fixed iteration count, f64
//!   accumulation, empty clusters keep their previous centroid) trains
//!   up to `nlist` unit-norm centroids on a size-capped sample, then a
//!   final full pass assigns every row to its nearest list. Rows are
//!   L2-normalized, so "nearest by cosine" is "max dot".
//! * **Contiguous posting lists** — each list owns a flat `Vec<f32>`
//!   slab plus a parallel id array; probing a list is the same
//!   cache-friendly strided [`dot_f32`] scan as the flat path, feeding
//!   the same bounded-heap `TopK`. Slab rows are byte copies of the
//!   flat store's normalized rows, so scores are bitwise identical.
//! * **nprobe-bounded queries** — score all centroids (O(nlist·d)),
//!   probe the best `nprobe` lists, merge under [`rank_desc`]. Probed
//!   volume is ≈ `nprobe/nlist` of the store; when it crosses
//!   [`SHARD_MIN_ROWS`] the probed lists shard across scoped threads
//!   exactly like the flat scan, with the same deterministic merge.
//! * **Exact fallback** — stores below `exact_below` rows (or not yet
//!   trained) delegate to `VecStore::top_k`, so small edge stores keep
//!   bit-identical behavior to PR 1. Probing *all* lists is also
//!   bit-identical to the exact scan (same scores, same total order),
//!   which is what `tests/ann_equivalence.rs` pins.
//! * **Incremental maintenance** — `insert`/`remove` keep an
//!   id→(list,slot) map in sync with the parent's id→slot map using the
//!   same swap-remove discipline. A per-list mutation counter triggers
//!   a cheap single-list refresh (re-center + re-assign members, no
//!   global retrain) once churn exceeds `retrain_drift` of the list.
//!
//! Memory: rows are stored twice (flat store + slabs) — the standard
//! IVF trade; the flat copy keeps the exact fallback and the recall
//! accounting in `sim` allocation-free.

use std::collections::HashMap;

use crate::util::rng::Rng;

use super::{dot_f32, rank_desc, TopK, VecStore, SHARD_MIN_ROWS};

/// Tuning knobs for [`IvfStore`]. `SystemConfig`'s `[ann]` section maps
/// onto the first four; the k-means knobs stay internal (tests shrink
/// them so debug-profile runs stay fast).
#[derive(Clone, Copy, Debug)]
pub struct IvfParams {
    /// Posting lists to train (effective count is `min(nlist, rows)`).
    pub nlist: usize,
    /// Lists probed per query — the recall-vs-latency dial.
    pub nprobe: usize,
    /// Below this many rows queries use the exact flat scan, and the
    /// store auto-trains when an insert first crosses it.
    pub exact_below: usize,
    /// A list is refreshed (re-centered + members re-assigned) once its
    /// insert/remove churn exceeds this fraction of its size.
    pub retrain_drift: f64,
    /// Lloyd iterations per (re)train; fixed for determinism.
    pub kmeans_iters: usize,
    /// Max rows sampled for the k-means loop (the final assignment pass
    /// always covers every row).
    pub train_sample: usize,
    /// Seed for sampling and initialization.
    pub seed: u64,
}

impl Default for IvfParams {
    fn default() -> Self {
        IvfParams {
            nlist: 32,
            nprobe: 4,
            exact_below: 4096,
            retrain_drift: 0.5,
            kmeans_iters: 8,
            train_sample: 65_536,
            seed: 0x1fa6,
        }
    }
}

/// Maintenance counters (observability; not part of the query path).
#[derive(Clone, Copy, Debug, Default)]
pub struct IvfStats {
    /// Full k-means (re)trains.
    pub trains: u64,
    /// Drift-triggered single-list refreshes.
    pub list_refreshes: u64,
    /// Rows moved between lists by refreshes.
    pub reassigned_rows: u64,
}

/// One inverted list: parallel id array + contiguous row slab.
#[derive(Clone, Debug, Default)]
struct PostingList {
    ids: Vec<usize>,
    /// Row-major slab, `ids.len() × dim`; rows are byte copies of the
    /// flat store's normalized rows.
    data: Vec<f32>,
    /// Inserts/removes since the list's centroid was last computed.
    mutations: usize,
}

impl PostingList {
    #[inline]
    fn len(&self) -> usize {
        self.ids.len()
    }

    #[inline]
    fn row(&self, slot: usize, dim: usize) -> &[f32] {
        &self.data[slot * dim..(slot + 1) * dim]
    }

    fn push(&mut self, id: usize, row: &[f32]) {
        self.ids.push(id);
        self.data.extend_from_slice(row);
    }

    /// Swap-remove `slot`; returns the id that moved into `slot`, if
    /// any, so the caller can fix its location entry.
    fn swap_remove(&mut self, slot: usize, dim: usize) -> Option<usize> {
        let last = self.ids.len() - 1;
        self.ids.swap_remove(slot);
        if slot != last {
            let (head, tail) = self.data.split_at_mut(last * dim);
            head[slot * dim..(slot + 1) * dim].copy_from_slice(&tail[..dim]);
        }
        self.data.truncate(last * dim);
        if slot < self.ids.len() {
            Some(self.ids[slot])
        } else {
            None
        }
    }
}

/// Index of the centroid with max dot against `v` (ties → lowest index,
/// making assignment deterministic).
fn nearest_list(centroids: &[f32], dim: usize, v: &[f32]) -> usize {
    let mut best = 0usize;
    let mut best_dot = f32::NEG_INFINITY;
    for (c, row) in centroids.chunks_exact(dim).enumerate() {
        let d = dot_f32(row, v);
        if d > best_dot {
            best = c;
            best_dot = d;
        }
    }
    best
}

/// An IVF index wrapping a flat [`VecStore`]. Same ranking contract as
/// the parent: score descending, ties by ascending id.
#[derive(Clone, Debug)]
pub struct IvfStore {
    params: IvfParams,
    flat: VecStore,
    /// `nlist_eff × dim` unit-norm centroid matrix; empty ⇒ untrained.
    centroids: Vec<f32>,
    lists: Vec<PostingList>,
    /// id → (list, slot); populated iff trained.
    loc_of: HashMap<usize, (u32, u32)>,
    /// Bumps on every (re)train and list refresh; 0 ⇒ untrained. The
    /// cluster layer gossips this alongside the centroid digest so
    /// unchanged digests are suppressed.
    centroid_version: u64,
    /// Scratch row so attach/refresh avoid aliasing the slabs.
    row_buf: Vec<f32>,
    pub stats: IvfStats,
}

impl IvfStore {
    pub fn new(dim: usize, params: IvfParams) -> Self {
        IvfStore {
            params,
            flat: VecStore::new(dim),
            centroids: Vec::new(),
            lists: Vec::new(),
            loc_of: HashMap::new(),
            centroid_version: 0,
            row_buf: Vec::with_capacity(dim),
            stats: IvfStats::default(),
        }
    }

    /// Wrap an already-loaded flat store and train immediately (bulk
    /// path: benches/demos load once, then build with the sharded
    /// assignment pass instead of per-insert attachment).
    pub fn from_flat(flat: VecStore, params: IvfParams) -> Self {
        let mut s = IvfStore {
            params,
            row_buf: Vec::with_capacity(flat.dim()),
            flat,
            centroids: Vec::new(),
            lists: Vec::new(),
            loc_of: HashMap::new(),
            centroid_version: 0,
            stats: IvfStats::default(),
        };
        s.build();
        s
    }

    pub fn len(&self) -> usize {
        self.flat.len()
    }

    pub fn is_empty(&self) -> bool {
        self.flat.is_empty()
    }

    pub fn dim(&self) -> usize {
        self.flat.dim()
    }

    pub fn contains(&self, id: usize) -> bool {
        self.flat.contains(id)
    }

    pub fn params(&self) -> &IvfParams {
        &self.params
    }

    /// The wrapped flat store (exact reference).
    pub fn exact(&self) -> &VecStore {
        &self.flat
    }

    pub fn trained(&self) -> bool {
        !self.centroids.is_empty()
    }

    /// Effective list count (≤ `params.nlist`; rows may be scarce).
    pub fn nlist_eff(&self) -> usize {
        if self.flat.dim() == 0 {
            0
        } else {
            self.centroids.len() / self.flat.dim()
        }
    }

    /// Unit-norm centroid matrix (`nlist_eff × dim`), row-major. Empty
    /// until trained. This is what the cluster layer gossips.
    pub fn centroids(&self) -> &[f32] {
        &self.centroids
    }

    /// 0 ⇒ untrained; bumps on every train / list refresh.
    pub fn centroid_version(&self) -> u64 {
        self.centroid_version
    }

    /// Whether queries currently take the exact path (untrained, or the
    /// store is small enough that a flat scan is already cheap).
    pub fn uses_exact(&self) -> bool {
        !self.trained() || self.flat.len() < self.params.exact_below
    }

    /// Insert (or replace) a vector under `id`, keeping the posting
    /// lists in sync. First insert past `exact_below` triggers the
    /// initial train.
    pub fn insert(&mut self, id: usize, v: &[f32]) {
        if self.trained() && self.flat.contains(id) {
            self.detach(id);
        }
        self.flat.insert(id, v);
        if self.trained() {
            self.attach(id);
        } else if self.flat.len() >= self.params.exact_below {
            self.build();
        }
    }

    /// Remove a vector, keeping the posting lists in sync.
    pub fn remove(&mut self, id: usize) -> bool {
        if self.trained() {
            self.detach(id);
        }
        self.flat.remove(id)
    }

    /// Approximate top-k at the configured `nprobe`.
    pub fn top_k(&self, q: &[f32], k: usize) -> Vec<(usize, f32)> {
        self.top_k_with(q, k, self.params.nprobe)
    }

    /// Exact top-k via the flat store (the recall reference).
    pub fn top_k_exact(&self, q: &[f32], k: usize) -> Vec<(usize, f32)> {
        self.flat.top_k(q, k)
    }

    /// Approximate top-k probing the best `nprobe` lists. Probing all
    /// lists (`nprobe ≥ nlist_eff`) is bit-identical to the exact scan:
    /// every row is scored with the same kernel on the same bytes and
    /// merged under the same total order.
    pub fn top_k_with(&self, q: &[f32], k: usize, nprobe: usize) -> Vec<(usize, f32)> {
        if self.uses_exact() {
            return self.flat.top_k(q, k);
        }
        if k == 0 {
            return Vec::new();
        }
        let qn = self.flat.query_norm(q);
        let nlist = self.nlist_eff();
        let nprobe = nprobe.clamp(1, nlist);

        // Coarse stage: rank centroids, keep the best nprobe.
        let mut coarse = TopK::new(nprobe);
        for (c, row) in self.centroids.chunks_exact(self.flat.dim()).enumerate() {
            coarse.push((c, dot_f32(row, q)));
        }
        let probes: Vec<usize> = coarse.into_sorted().into_iter().map(|(c, _)| c).collect();
        let rows: usize = probes.iter().map(|&c| self.lists[c].len()).sum();

        // Fine stage: scan the probed slabs, sharded like the flat path
        // when the probed volume is large enough to amortize spawns.
        let cores = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        let shards = (rows / SHARD_MIN_ROWS).min(cores).min(8).min(probes.len());
        if shards < 2 {
            let mut top = TopK::new(k.min(rows));
            for &c in &probes {
                self.scan_list(c, q, qn, &mut top);
            }
            return top.into_sorted();
        }
        // Deal probed lists round-robin across shards; within a shard
        // the scan order is fixed, and the merge applies the same total
        // order as the serial path, so results are shard-invariant.
        let mut groups: Vec<Vec<usize>> = vec![Vec::new(); shards];
        for (i, &c) in probes.iter().enumerate() {
            groups[i % shards].push(c);
        }
        let partials: Vec<Vec<(usize, f32)>> = std::thread::scope(|scope| {
            let handles: Vec<_> = groups
                .iter()
                .map(|grp| {
                    scope.spawn(move || {
                        let cap: usize = grp.iter().map(|&c| self.lists[c].len()).sum();
                        let mut top = TopK::new(k.min(cap));
                        for &c in grp {
                            self.scan_list(c, q, qn, &mut top);
                        }
                        top.into_sorted()
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("ivf probe shard panicked"))
                .collect()
        });
        let mut merged: Vec<(usize, f32)> = partials.into_iter().flatten().collect();
        merged.sort_by(rank_desc);
        merged.truncate(k);
        merged
    }

    /// (Re)train from scratch: sample-capped spherical k-means, then a
    /// full assignment pass rebuilding every posting list.
    pub fn build(&mut self) {
        let dim = self.flat.dim();
        let n = self.flat.len();
        self.centroids.clear();
        self.lists.clear();
        self.loc_of.clear();
        if n == 0 {
            return; // stays untrained; queries fall back to exact
        }
        let k = self.params.nlist.max(1).min(n);
        let mut rng = Rng::new(self.params.seed);
        // Init from k distinct rows (already unit-norm).
        let seeds = rng.sample_indices(n, k);
        self.centroids.reserve(k * dim);
        for &s in &seeds {
            self.centroids.extend_from_slice(self.flat.row(s));
        }
        let sample: Vec<usize> = if n > self.params.train_sample {
            rng.sample_indices(n, self.params.train_sample)
        } else {
            (0..n).collect()
        };
        let mut assign = vec![0u32; sample.len()];
        for _ in 0..self.params.kmeans_iters {
            self.assign_slots(&sample, &mut assign);
            // Re-center: normalized member mean, f64 accumulation so
            // summation order never leaks into the result.
            let mut sums = vec![0.0f64; k * dim];
            let mut counts = vec![0usize; k];
            for (&slot, &c) in sample.iter().zip(&assign) {
                let c = c as usize;
                counts[c] += 1;
                for (s, x) in sums[c * dim..(c + 1) * dim].iter_mut().zip(self.flat.row(slot)) {
                    *s += *x as f64;
                }
            }
            for c in 0..k {
                if counts[c] == 0 {
                    continue; // keep the previous centroid (deterministic)
                }
                let sum = &sums[c * dim..(c + 1) * dim];
                let norm = sum.iter().map(|x| x * x).sum::<f64>().sqrt().max(1e-12);
                for (dst, s) in self.centroids[c * dim..(c + 1) * dim].iter_mut().zip(sum) {
                    *dst = (*s / norm) as f32;
                }
            }
        }
        // Full assignment pass (sharded): every row lands in a list.
        let all: Vec<usize> = (0..n).collect();
        let mut full = vec![0u32; n];
        self.assign_slots(&all, &mut full);
        self.lists = vec![PostingList::default(); k];
        for (slot, &c) in full.iter().enumerate() {
            let l = c as usize;
            let id = self.flat.id_at(slot);
            self.loc_of.insert(id, (l as u32, self.lists[l].len() as u32));
            self.lists[l].ids.push(id);
            self.lists[l].data.extend_from_slice(self.flat.row(slot));
        }
        self.centroid_version += 1;
        self.stats.trains += 1;
    }

    /// Structural invariants, used by churn tests: the id→(list,slot)
    /// map, the lists, and the flat store must agree exactly, and slab
    /// rows must be byte copies of flat rows.
    pub fn check_consistency(&self) -> Result<(), String> {
        if !self.trained() {
            if !self.lists.is_empty() || !self.loc_of.is_empty() {
                return Err("untrained store has posting state".into());
            }
            return Ok(());
        }
        let dim = self.flat.dim();
        let mut seen = 0usize;
        for (l, pl) in self.lists.iter().enumerate() {
            if pl.data.len() != pl.ids.len() * dim {
                return Err(format!("list {l}: slab/id length mismatch"));
            }
            for (slot, &id) in pl.ids.iter().enumerate() {
                seen += 1;
                match self.loc_of.get(&id) {
                    Some(&(ll, ss)) if (ll as usize, ss as usize) == (l, slot) => {}
                    other => {
                        return Err(format!("id {id}: loc {other:?} != ({l},{slot})"));
                    }
                }
                let pos = self
                    .flat
                    .slot(id)
                    .ok_or_else(|| format!("id {id} in list {l} but not in flat store"))?;
                if self.flat.row(pos) != pl.row(slot, dim) {
                    return Err(format!("id {id}: slab row diverged from flat row"));
                }
            }
        }
        if seen != self.flat.len() || self.loc_of.len() != self.flat.len() {
            return Err(format!(
                "coverage: {seen} listed, {} located, {} stored",
                self.loc_of.len(),
                self.flat.len()
            ));
        }
        Ok(())
    }

    /// Scan one posting list into the running top-k.
    fn scan_list(&self, l: usize, q: &[f32], qn: f32, top: &mut TopK) {
        let dim = self.flat.dim();
        let pl = &self.lists[l];
        for slot in 0..pl.len() {
            let s = dot_f32(pl.row(slot, dim), q) / qn;
            top.push((pl.ids[slot], s));
        }
    }

    /// Nearest-centroid assignment for `slots` (flat slot indices) into
    /// `out`, sharded across scoped threads when the batch is large.
    fn assign_slots(&self, slots: &[usize], out: &mut [u32]) {
        let dim = self.flat.dim();
        let n = slots.len();
        let cores = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        let shards = (n / SHARD_MIN_ROWS).min(cores).min(8);
        if shards < 2 {
            for (o, &slot) in out.iter_mut().zip(slots) {
                *o = nearest_list(&self.centroids, dim, self.flat.row(slot)) as u32;
            }
            return;
        }
        let per = (n + shards - 1) / shards;
        std::thread::scope(|scope| {
            let mut rest = &mut out[..];
            let mut handles = Vec::new();
            for t in 0..shards {
                let lo = t * per;
                let hi = ((t + 1) * per).min(n);
                if lo >= hi {
                    break;
                }
                let (chunk, tail) = rest.split_at_mut(hi - lo);
                rest = tail;
                let span = &slots[lo..hi];
                handles.push(scope.spawn(move || {
                    for (o, &slot) in chunk.iter_mut().zip(span) {
                        *o = nearest_list(&self.centroids, dim, self.flat.row(slot)) as u32;
                    }
                }));
            }
            for h in handles {
                h.join().expect("ivf assign shard panicked");
            }
        });
    }

    /// Attach `id` (already in the flat store) to its nearest list.
    fn attach(&mut self, id: usize) {
        let pos = self.flat.slot(id).expect("attach: id not in flat store");
        self.row_buf.clear();
        self.row_buf.extend_from_slice(self.flat.row(pos));
        let l = nearest_list(&self.centroids, self.flat.dim(), &self.row_buf);
        let slot = self.lists[l].len() as u32;
        self.lists[l].push(id, &self.row_buf);
        self.loc_of.insert(id, (l as u32, slot));
        self.lists[l].mutations += 1;
        self.maybe_refresh(l);
    }

    /// Remove `id` from its posting list (flat store untouched).
    fn detach(&mut self, id: usize) {
        let Some((l, slot)) = self.loc_of.remove(&id) else {
            return;
        };
        let (l, slot) = (l as usize, slot as usize);
        if let Some(moved) = self.lists[l].swap_remove(slot, self.flat.dim()) {
            self.loc_of.insert(moved, (l as u32, slot as u32));
        }
        self.lists[l].mutations += 1;
        self.maybe_refresh(l);
    }

    fn maybe_refresh(&mut self, l: usize) {
        let len = self.lists[l].len();
        if self.lists[l].mutations as f64 > self.params.retrain_drift * len.max(1) as f64 {
            self.refresh_list(l);
        }
    }

    /// Cheap drift repair for one list (no global retrain): re-center
    /// on the current members, then move members whose nearest centroid
    /// changed. Moves bypass the drift counters — they are rebalancing,
    /// not fresh churn, so refreshes never cascade.
    fn refresh_list(&mut self, l: usize) {
        self.stats.list_refreshes += 1;
        self.lists[l].mutations = 0;
        let dim = self.flat.dim();
        if self.lists[l].ids.is_empty() {
            return; // keep the previous centroid, as in training
        }
        let mut mean = vec![0.0f64; dim];
        for slot in 0..self.lists[l].len() {
            for (m, x) in mean.iter_mut().zip(self.lists[l].row(slot, dim)) {
                *m += *x as f64;
            }
        }
        let norm = mean.iter().map(|x| x * x).sum::<f64>().sqrt().max(1e-12);
        for (c, m) in self.centroids[l * dim..(l + 1) * dim].iter_mut().zip(&mean) {
            *c = (*m / norm) as f32;
        }
        self.centroid_version += 1;
        let mut slot = 0;
        while slot < self.lists[l].len() {
            let target = nearest_list(&self.centroids, dim, self.lists[l].row(slot, dim));
            if target == l {
                slot += 1;
                continue;
            }
            let id = self.lists[l].ids[slot];
            self.row_buf.clear();
            self.row_buf.extend_from_slice(self.lists[l].row(slot, dim));
            if let Some(moved) = self.lists[l].swap_remove(slot, dim) {
                self.loc_of.insert(moved, (l as u32, slot as u32));
            }
            let tslot = self.lists[target].len() as u32;
            self.lists[target].push(id, &self.row_buf);
            self.loc_of.insert(id, (target as u32, tslot));
            self.stats.reassigned_rows += 1;
            // Don't advance: the swapped-in row needs checking too.
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grid_vec(rng: &mut Rng, dim: usize) -> Vec<f32> {
        // Integer grid components force score ties, exercising the
        // id tie-break on both paths.
        (0..dim).map(|_| rng.below(9) as f32 - 4.0).collect()
    }

    fn filled(rows: usize, dim: usize, params: IvfParams, seed: u64) -> IvfStore {
        let mut rng = Rng::new(seed);
        let mut s = IvfStore::new(dim, params);
        for i in 0..rows {
            s.insert(i, &grid_vec(&mut rng, dim));
        }
        s
    }

    fn assert_bit_identical(a: &[(usize, f32)], b: &[(usize, f32)]) {
        assert_eq!(a.len(), b.len(), "result lengths differ");
        for (x, y) in a.iter().zip(b) {
            assert_eq!(x.0, y.0, "ids diverge");
            assert_eq!(x.1.to_bits(), y.1.to_bits(), "score bits diverge");
        }
    }

    #[test]
    fn untrained_small_store_is_exact() {
        let s = filled(
            200,
            8,
            IvfParams {
                exact_below: 1000,
                ..IvfParams::default()
            },
            1,
        );
        assert!(!s.trained());
        assert!(s.uses_exact());
        let q = vec![1.0; 8];
        assert_bit_identical(&s.top_k(&q, 10), &s.exact().top_k_serial(&q, 10));
    }

    #[test]
    fn auto_trains_when_crossing_threshold() {
        let params = IvfParams {
            nlist: 4,
            exact_below: 64,
            kmeans_iters: 3,
            ..IvfParams::default()
        };
        let s = filled(100, 8, params, 2);
        assert!(s.trained());
        assert_eq!(s.stats.trains, 1);
        assert!(!s.uses_exact());
        assert!(s.centroid_version() >= 1);
        s.check_consistency().unwrap();
    }

    #[test]
    fn probing_all_lists_matches_exact_bitwise() {
        let params = IvfParams {
            nlist: 5,
            nprobe: 2,
            exact_below: 32,
            kmeans_iters: 3,
            ..IvfParams::default()
        };
        let s = filled(300, 8, params, 3);
        assert!(s.trained());
        let mut rng = Rng::new(99);
        for _ in 0..10 {
            let q = grid_vec(&mut rng, 8);
            let full = s.top_k_with(&q, 12, s.nlist_eff());
            let exact = s.exact().top_k_serial(&q, 12);
            assert_bit_identical(&full, &exact);
        }
    }

    #[test]
    fn k_edge_cases_on_ivf_path() {
        let params = IvfParams {
            nlist: 4,
            nprobe: 4,
            exact_below: 16,
            kmeans_iters: 2,
            ..IvfParams::default()
        };
        let s = filled(50, 4, params, 4);
        assert!(!s.uses_exact());
        let q = vec![1.0, 0.0, 0.0, 0.0];
        assert!(s.top_k(&q, 0).is_empty());
        // k beyond len returns every row, same order as the reference.
        let all = s.top_k_with(&q, usize::MAX, 4);
        assert_eq!(all.len(), 50);
        assert_bit_identical(&all, &s.exact().top_k_fullsort(&q, usize::MAX));
    }

    #[test]
    fn insert_remove_keeps_lists_in_sync() {
        let params = IvfParams {
            nlist: 4,
            nprobe: 4,
            exact_below: 32,
            kmeans_iters: 2,
            retrain_drift: 0.4,
            ..IvfParams::default()
        };
        let mut s = filled(80, 6, params, 5);
        let mut rng = Rng::new(17);
        for _ in 0..300 {
            let id = rng.below(120);
            if rng.chance(0.55) {
                s.insert(id, &grid_vec(&mut rng, 6));
            } else {
                s.remove(id);
            }
        }
        s.check_consistency().unwrap();
        // Replacement keeps exactly one copy.
        s.insert(7, &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        s.insert(7, &[6.0, 5.0, 4.0, 3.0, 2.0, 1.0]);
        s.check_consistency().unwrap();
        let q = grid_vec(&mut rng, 6);
        assert_bit_identical(
            &s.top_k_with(&q, 200, s.nlist_eff()),
            &s.exact().top_k_serial(&q, 200),
        );
    }

    #[test]
    fn drift_triggers_list_refresh_without_retrain() {
        let params = IvfParams {
            nlist: 3,
            exact_below: 24,
            kmeans_iters: 2,
            retrain_drift: 0.25,
            ..IvfParams::default()
        };
        let mut s = filled(60, 4, params, 6);
        assert_eq!(s.stats.trains, 1);
        let v0 = s.centroid_version();
        let mut rng = Rng::new(23);
        for step in 0..200 {
            s.insert(1000 + step, &grid_vec(&mut rng, 4));
            s.remove(rng.below(1000 + step));
        }
        assert!(s.stats.list_refreshes > 0, "drift never triggered a refresh");
        assert!(s.centroid_version() > v0);
        assert_eq!(s.stats.trains, 1, "refresh escalated to a full retrain");
        s.check_consistency().unwrap();
    }

    #[test]
    fn from_flat_matches_incremental_contents() {
        let mut rng = Rng::new(31);
        let mut flat = VecStore::new(6);
        for i in 0..150 {
            flat.insert(i, &grid_vec(&mut rng, 6));
        }
        let params = IvfParams {
            nlist: 4,
            exact_below: 32,
            kmeans_iters: 3,
            ..IvfParams::default()
        };
        let s = IvfStore::from_flat(flat, params);
        assert!(s.trained());
        assert_eq!(s.len(), 150);
        s.check_consistency().unwrap();
    }

    #[test]
    fn empty_and_tiny_stores() {
        let mut s = IvfStore::new(4, IvfParams::default());
        assert!(s.top_k(&[1.0, 0.0, 0.0, 0.0], 5).is_empty());
        s.build(); // no rows: stays untrained, no panic
        assert!(!s.trained());
        s.insert(1, &[1.0, 0.0, 0.0, 0.0]);
        assert_eq!(s.top_k(&[1.0, 0.0, 0.0, 0.0], 5).len(), 1);
        assert!(s.remove(1));
        assert!(!s.remove(1));
    }
}
