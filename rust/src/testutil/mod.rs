//! Mini property-testing harness (proptest substitute, DESIGN.md §1).
//!
//! Offline image has no proptest; this provides the 90% we need: run a
//! property over many seeded-random cases, and on failure report the
//! failing case number + seed so the exact case replays deterministically.
//!
//! ```ignore
//! proptest(200, |rng| {
//!     let n = rng.range(1, 50);
//!     // ... build inputs from rng, assert invariants ...
//! });
//! ```

use std::path::PathBuf;

use crate::util::rng::Rng;

/// Locate the PJRT artifact directory, or `None` (with a loud SKIP
/// notice) when artifacts haven't been built. Every PJRT-dependent
/// test/bench gates on this so `cargo test -q` stays green without
/// `make artifacts`. Override the location with `EACO_ARTIFACTS_DIR`.
pub fn artifacts_dir() -> Option<PathBuf> {
    let dir = std::env::var_os("EACO_ARTIFACTS_DIR")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"));
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!(
            "SKIP: PJRT artifacts not present at {} (run `make artifacts`)",
            dir.display()
        );
        None
    }
}

/// Run `prop` over `cases` generated cases. Panics (with seed + case
/// index) on the first failing case. The base seed is fixed so CI is
/// deterministic; set `EACO_PROPTEST_SEED` to explore other schedules.
pub fn proptest<F: FnMut(&mut Rng)>(cases: usize, mut prop: F) {
    let base = std::env::var("EACO_PROPTEST_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xEAC0_u64);
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng);
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!(
                "property failed at case {case}/{cases} (seed {seed:#x}, base {base:#x}): {msg}"
            );
        }
    }
}

/// Assert two floats are within absolute tolerance.
#[macro_export]
macro_rules! assert_close {
    ($a:expr, $b:expr, $tol:expr) => {{
        let (a, b, tol): (f64, f64, f64) = ($a, $b, $tol);
        assert!(
            (a - b).abs() <= tol,
            "assert_close failed: {} vs {} (tol {})",
            a,
            b,
            tol
        );
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        proptest(50, |rng| {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
        });
    }

    #[test]
    fn reports_failing_case() {
        let r = std::panic::catch_unwind(|| {
            proptest(10, |rng| {
                let x = rng.below(100);
                assert!(x != x, "always fails {x}");
            });
        });
        let payload = r.unwrap_err();
        let msg = payload
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        assert!(msg.contains("property failed at case 0"), "{msg}");
    }

    #[test]
    fn cases_use_distinct_seeds() {
        let mut first_draws = Vec::new();
        proptest(5, |rng| {
            first_draws.push(rng.next_u64());
        });
        let mut dedup = first_draws.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), first_draws.len());
    }

    #[test]
    fn assert_close_works() {
        assert_close!(1.0, 1.0 + 1e-9, 1e-6);
    }
}
