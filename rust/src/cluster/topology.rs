//! Edge graph: neighbor sets + netsim-derived link costs.
//!
//! The cluster does not treat the edge tier as a flat broadcast domain:
//! each edge gossips with and routes to a bounded *neighbor set* — the
//! `degree` cheapest peers by [`crate::netsim::NetSim::pair_cost_ms`]
//! (a static ring-distance metric over the same base inter-edge latency
//! the delay simulation uses). This is what turns the per-query
//! all-edges scan into an O(degree) probe and bounds gossip fan-out as
//! the fleet grows.

use crate::netsim::NetSim;

/// Static edge graph for one cluster.
#[derive(Clone, Debug)]
pub struct Topology {
    pub num_edges: usize,
    /// Neighbors actually wired per edge (min(requested, n-1)).
    pub degree: usize,
    /// Per-edge neighbor ids, each list sorted ascending by id so
    /// routing iterates candidates in the same order the
    /// `best_edge_for` oracle scans edges (determinism + equivalence).
    neighbors: Vec<Vec<usize>>,
    /// Flattened n×n link-cost matrix (ms).
    cost_ms: Vec<f64>,
}

impl Topology {
    /// Wire each edge to its `degree` cheapest peers (ties broken by
    /// lower id). Costs come from the network simulator so the graph
    /// reflects the same world the delay model samples.
    pub fn build(net: &NetSim, degree: usize) -> Topology {
        let n = net.num_edges.max(1);
        let degree = degree.min(n.saturating_sub(1));
        let mut cost_ms = vec![0.0; n * n];
        for a in 0..n {
            for b in 0..n {
                cost_ms[a * n + b] = net.pair_cost_ms(a, b);
            }
        }
        let mut neighbors = Vec::with_capacity(n);
        for a in 0..n {
            let mut peers: Vec<usize> = (0..n).filter(|&b| b != a).collect();
            peers.sort_by(|&x, &y| {
                cost_ms[a * n + x]
                    .partial_cmp(&cost_ms[a * n + y])
                    .unwrap()
                    .then(x.cmp(&y))
            });
            peers.truncate(degree);
            peers.sort_unstable(); // candidate iteration order = id order
            neighbors.push(peers);
        }
        Topology {
            num_edges: n,
            degree,
            neighbors,
            cost_ms,
        }
    }

    /// Neighbor ids of `e`, sorted ascending.
    pub fn neighbors(&self, e: usize) -> &[usize] {
        &self.neighbors[e]
    }

    pub fn link_cost_ms(&self, a: usize, b: usize) -> f64 {
        self.cost_ms[a * self.num_edges + b]
    }

    /// Total directed links (gossip channels) in the graph.
    pub fn num_links(&self) -> usize {
        self.neighbors.iter().map(|v| v.len()).sum()
    }

    /// Rewire the graph around dead edges: each alive edge re-selects
    /// its `degree` cheapest peers *among alive edges* (same cost/tie
    /// rules as [`Topology::build`], so an all-alive rewire reproduces
    /// the built graph exactly); dead edges keep no neighbors and appear
    /// in no one's list. Link costs are static (the machines' positions
    /// don't move), only adjacency changes.
    pub fn rewire(&mut self, alive: &[bool]) {
        self.rewire_grouped(alive, None);
    }

    /// Partition-aware rewire: like [`Topology::rewire`], but when
    /// `group` is `Some`, each alive edge only selects peers in *its
    /// own* partition group — cross-group links are severed, which
    /// suppresses gossip and neighbor routing across the partition
    /// boundary (both walk these neighbor lists). `group[e]` is the
    /// partition id of edge `e`; `None` means no partition is active.
    pub fn rewire_grouped(&mut self, alive: &[bool], group: Option<&[usize]>) {
        debug_assert_eq!(alive.len(), self.num_edges);
        let n = self.num_edges;
        let same_group =
            |a: usize, b: usize| group.is_none_or(|g| g.get(a) == g.get(b));
        for a in 0..n {
            if !alive[a] {
                self.neighbors[a].clear();
                continue;
            }
            let mut peers: Vec<usize> = (0..n)
                .filter(|&b| b != a && alive[b] && same_group(a, b))
                .collect();
            peers.sort_by(|&x, &y| {
                self.cost_ms[a * n + x]
                    .partial_cmp(&self.cost_ms[a * n + y])
                    .unwrap()
                    .then(x.cmp(&y))
            });
            peers.truncate(self.degree);
            peers.sort_unstable();
            self.neighbors[a] = peers;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::NetSpec;

    fn topo(n: usize, degree: usize) -> Topology {
        Topology::build(&NetSim::new(n, NetSpec::default(), 11), degree)
    }

    #[test]
    fn degree_bounded_and_self_free() {
        let t = topo(8, 3);
        assert_eq!(t.degree, 3);
        for e in 0..8 {
            assert_eq!(t.neighbors(e).len(), 3);
            assert!(!t.neighbors(e).contains(&e), "self-loop at {e}");
        }
        assert_eq!(t.num_links(), 24);
    }

    #[test]
    fn full_degree_covers_all_peers() {
        let t = topo(5, 99);
        assert_eq!(t.degree, 4);
        for e in 0..5 {
            let mut expect: Vec<usize> = (0..5).filter(|&b| b != e).collect();
            expect.sort_unstable();
            assert_eq!(t.neighbors(e), expect.as_slice());
        }
    }

    #[test]
    fn neighbors_are_cheapest_links() {
        let t = topo(8, 2);
        // Ring costs: edge 0's cheapest peers are 1 and 7.
        assert_eq!(t.neighbors(0), &[1, 7]);
        let worst = t.link_cost_ms(0, 4);
        for &nb in t.neighbors(0) {
            assert!(t.link_cost_ms(0, nb) < worst);
        }
    }

    #[test]
    fn single_edge_cluster_degenerates() {
        let t = topo(1, 2);
        assert_eq!(t.degree, 0);
        assert!(t.neighbors(0).is_empty());
    }

    #[test]
    fn rewire_routes_around_dead_edges() {
        let mut t = topo(8, 2);
        let built: Vec<Vec<usize>> = (0..8).map(|e| t.neighbors(e).to_vec()).collect();
        // Kill edge 1 (a ring neighbor of 0 and 2).
        let mut alive = vec![true; 8];
        alive[1] = false;
        t.rewire(&alive);
        assert!(t.neighbors(1).is_empty(), "dead edge keeps neighbors");
        for e in [0usize, 2, 3, 7] {
            assert!(!t.neighbors(e).contains(&1), "edge {e} kept dead neighbor");
            assert_eq!(t.neighbors(e).len(), 2, "degree not restored at {e}");
        }
        // Edge 0's replacement for 1 is its next-cheapest alive peer (2).
        assert_eq!(t.neighbors(0), &[2, 7]);
        // Reviving everyone reproduces the built graph exactly.
        t.rewire(&vec![true; 8]);
        for e in 0..8 {
            assert_eq!(t.neighbors(e), built[e].as_slice());
        }
    }

    #[test]
    fn grouped_rewire_severs_cross_group_links() {
        let mut t = topo(8, 3);
        let built: Vec<Vec<usize>> = (0..8).map(|e| t.neighbors(e).to_vec()).collect();
        let alive = vec![true; 8];
        // Split-brain halves: {0..3} vs {4..7}.
        let group = [0usize, 0, 0, 0, 1, 1, 1, 1];
        t.rewire_grouped(&alive, Some(&group));
        for a in 0..8 {
            assert!(!t.neighbors(a).is_empty(), "edge {a} isolated inside its group");
            for &b in t.neighbors(a) {
                assert_eq!(group[a], group[b], "cross-group link {a}->{b} survived");
            }
        }
        // Edge 0's ring neighbor 7 is across the boundary; it must fall
        // back to in-group peers only.
        assert!(!t.neighbors(0).contains(&7));
        // Healing (group=None) reproduces the built graph exactly.
        t.rewire_grouped(&alive, None);
        for e in 0..8 {
            assert_eq!(t.neighbors(e), built[e].as_slice());
        }
    }

    #[test]
    fn grouped_rewire_respects_liveness_too() {
        let mut t = topo(6, 2);
        let mut alive = vec![true; 6];
        alive[1] = false;
        let group = [0usize, 0, 0, 1, 1, 1];
        t.rewire_grouped(&alive, Some(&group));
        assert!(t.neighbors(1).is_empty());
        // Edge 0 and 2 pair up (1 dead, {3,4,5} out-of-group).
        assert_eq!(t.neighbors(0), &[2]);
        assert_eq!(t.neighbors(2), &[0]);
    }

    #[test]
    fn rewire_with_one_survivor_leaves_it_isolated() {
        let mut t = topo(4, 2);
        let mut alive = vec![false; 4];
        alive[2] = true;
        t.rewire(&alive);
        for e in 0..4 {
            assert!(t.neighbors(e).is_empty());
        }
        assert_eq!(t.num_links(), 0);
    }
}
