//! Edge graph: neighbor sets + netsim-derived link costs.
//!
//! The cluster does not treat the edge tier as a flat broadcast domain:
//! each edge gossips with and routes to a bounded *neighbor set* — the
//! `degree` cheapest peers by [`crate::netsim::NetSim::pair_cost_ms`]
//! (a static ring-distance metric over the same base inter-edge latency
//! the delay simulation uses). This is what turns the per-query
//! all-edges scan into an O(degree) probe and bounds gossip fan-out as
//! the fleet grows.

use crate::netsim::NetSim;

/// Static edge graph for one cluster.
#[derive(Clone, Debug)]
pub struct Topology {
    pub num_edges: usize,
    /// Neighbors actually wired per edge (min(requested, n-1)).
    pub degree: usize,
    /// Per-edge neighbor ids, each list sorted ascending by id so
    /// routing iterates candidates in the same order the
    /// `best_edge_for` oracle scans edges (determinism + equivalence).
    neighbors: Vec<Vec<usize>>,
    /// Flattened n×n link-cost matrix (ms).
    cost_ms: Vec<f64>,
}

impl Topology {
    /// Wire each edge to its `degree` cheapest peers (ties broken by
    /// lower id). Costs come from the network simulator so the graph
    /// reflects the same world the delay model samples.
    pub fn build(net: &NetSim, degree: usize) -> Topology {
        let n = net.num_edges.max(1);
        let degree = degree.min(n.saturating_sub(1));
        let mut cost_ms = vec![0.0; n * n];
        for a in 0..n {
            for b in 0..n {
                cost_ms[a * n + b] = net.pair_cost_ms(a, b);
            }
        }
        let mut neighbors = Vec::with_capacity(n);
        for a in 0..n {
            let mut peers: Vec<usize> = (0..n).filter(|&b| b != a).collect();
            peers.sort_by(|&x, &y| {
                cost_ms[a * n + x]
                    .partial_cmp(&cost_ms[a * n + y])
                    .unwrap()
                    .then(x.cmp(&y))
            });
            peers.truncate(degree);
            peers.sort_unstable(); // candidate iteration order = id order
            neighbors.push(peers);
        }
        Topology {
            num_edges: n,
            degree,
            neighbors,
            cost_ms,
        }
    }

    /// Neighbor ids of `e`, sorted ascending.
    pub fn neighbors(&self, e: usize) -> &[usize] {
        &self.neighbors[e]
    }

    pub fn link_cost_ms(&self, a: usize, b: usize) -> f64 {
        self.cost_ms[a * self.num_edges + b]
    }

    /// Total directed links (gossip channels) in the graph.
    pub fn num_links(&self) -> usize {
        self.neighbors.iter().map(|v| v.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::NetSpec;

    fn topo(n: usize, degree: usize) -> Topology {
        Topology::build(&NetSim::new(n, NetSpec::default(), 11), degree)
    }

    #[test]
    fn degree_bounded_and_self_free() {
        let t = topo(8, 3);
        assert_eq!(t.degree, 3);
        for e in 0..8 {
            assert_eq!(t.neighbors(e).len(), 3);
            assert!(!t.neighbors(e).contains(&e), "self-loop at {e}");
        }
        assert_eq!(t.num_links(), 24);
    }

    #[test]
    fn full_degree_covers_all_peers() {
        let t = topo(5, 99);
        assert_eq!(t.degree, 4);
        for e in 0..5 {
            let mut expect: Vec<usize> = (0..5).filter(|&b| b != e).collect();
            expect.sort_unstable();
            assert_eq!(t.neighbors(e), expect.as_slice());
        }
    }

    #[test]
    fn neighbors_are_cheapest_links() {
        let t = topo(8, 2);
        // Ring costs: edge 0's cheapest peers are 1 and 7.
        assert_eq!(t.neighbors(0), &[1, 7]);
        let worst = t.link_cost_ms(0, 4);
        for &nb in t.neighbors(0) {
            assert!(t.link_cost_ms(0, nb) < worst);
        }
    }

    #[test]
    fn single_edge_cluster_degenerates() {
        let t = topo(1, 2);
        assert_eq!(t.degree, 0);
        assert!(t.neighbors(0).is_empty());
    }
}
