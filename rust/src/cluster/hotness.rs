//! Exponentially-decayed topic/chunk popularity counters.
//!
//! The paper's cloud distributor reacts to raw query counts; the cluster
//! plane wants a *recency-weighted* demand signal so placement can evict
//! cold-first and gossip can advertise what is hot *now*. Counters decay
//! with a configurable half-life in virtual-time steps and are updated
//! lazily (value and last-touched step per cell, decay applied on read)
//! so the steady state does no allocation and no periodic sweep — the
//! same discipline as the PR-1 retrieval scratch buffers.

use std::collections::HashMap;

use crate::corpus::{ChunkId, TopicId};

#[derive(Clone, Copy, Debug, Default)]
struct Cell {
    value: f64,
    last_step: usize,
}

impl Cell {
    fn decayed(&self, decay_per_step: f64, step: usize) -> f64 {
        if self.value == 0.0 {
            return 0.0;
        }
        let dt = step.saturating_sub(self.last_step).min(100_000) as i32;
        self.value * decay_per_step.powi(dt)
    }

    fn bump(&mut self, decay_per_step: f64, step: usize, weight: f64) {
        self.value = self.decayed(decay_per_step, step) + weight;
        self.last_step = step.max(self.last_step);
    }
}

/// Per-edge popularity tracker (one per cluster, cells keyed by edge
/// implicitly via the caller owning one tracker — the sim owns a single
/// cluster-wide tracker since demand is what placement shares).
#[derive(Clone, Debug)]
pub struct HotnessTracker {
    /// Multiplicative decay per step: 0.5^(1/half_life).
    decay_per_step: f64,
    pub half_life_steps: f64,
    topics: Vec<Cell>,
    chunks: HashMap<ChunkId, Cell>,
    /// Total recorded observations (observability).
    pub observations: u64,
}

impl HotnessTracker {
    pub fn new(num_topics: usize, half_life_steps: f64) -> HotnessTracker {
        let hl = half_life_steps.max(1.0);
        HotnessTracker {
            decay_per_step: 0.5f64.powf(1.0 / hl),
            half_life_steps: hl,
            topics: vec![Cell::default(); num_topics],
            chunks: HashMap::new(),
            observations: 0,
        }
    }

    /// Record one query against a topic at `step`.
    pub fn record_topic(&mut self, topic: TopicId, step: usize) {
        if let Some(c) = self.topics.get_mut(topic) {
            c.bump(self.decay_per_step, step, 1.0);
            self.observations += 1;
        }
    }

    /// Record retrieval demand for a chunk at `step`.
    pub fn record_chunk(&mut self, chunk: ChunkId, step: usize) {
        self.chunks
            .entry(chunk)
            .or_default()
            .bump(self.decay_per_step, step, 1.0);
        self.observations += 1;
    }

    /// Current (decayed) topic popularity.
    pub fn topic_hotness(&self, topic: TopicId, step: usize) -> f64 {
        self.topics
            .get(topic)
            .map(|c| c.decayed(self.decay_per_step, step))
            .unwrap_or(0.0)
    }

    /// Current (decayed) chunk demand; 0 for never-requested chunks.
    pub fn chunk_hotness(&self, chunk: ChunkId, step: usize) -> f64 {
        self.chunks
            .get(&chunk)
            .map(|c| c.decayed(self.decay_per_step, step))
            .unwrap_or(0.0)
    }

    /// Number of chunks with any recorded demand.
    pub fn tracked_chunks(&self) -> usize {
        self.chunks.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hotness_accumulates_and_decays() {
        let mut h = HotnessTracker::new(4, 100.0);
        for _ in 0..10 {
            h.record_topic(1, 0);
        }
        assert!((h.topic_hotness(1, 0) - 10.0).abs() < 1e-12);
        // One half-life later: half the mass.
        let at_hl = h.topic_hotness(1, 100);
        assert!((at_hl - 5.0).abs() < 1e-9, "at half-life {at_hl}");
        // Far future: cold.
        assert!(h.topic_hotness(1, 5000) < 1e-9);
        // Untouched topic stays exactly zero.
        assert_eq!(h.topic_hotness(2, 50), 0.0);
    }

    #[test]
    fn recency_beats_stale_volume() {
        let mut h = HotnessTracker::new(1, 50.0);
        // Chunk 7: heavy traffic long ago. Chunk 8: light traffic now.
        for _ in 0..20 {
            h.record_chunk(7, 0);
        }
        for _ in 0..3 {
            h.record_chunk(8, 400);
        }
        assert!(h.chunk_hotness(8, 400) > h.chunk_hotness(7, 400));
        assert_eq!(h.tracked_chunks(), 2);
    }

    #[test]
    fn lazy_decay_matches_eager() {
        let mut a = HotnessTracker::new(1, 80.0);
        let mut b = HotnessTracker::new(1, 80.0);
        // a: bumps at steps 0 and 60 read at 90; b: same bumps, extra
        // interleaved reads (reads must not perturb state).
        for h in [&mut a, &mut b] {
            h.record_chunk(0, 0);
        }
        let _ = b.chunk_hotness(0, 30);
        for h in [&mut a, &mut b] {
            h.record_chunk(0, 60);
        }
        let _ = b.chunk_hotness(0, 75);
        assert!((a.chunk_hotness(0, 90) - b.chunk_hotness(0, 90)).abs() < 1e-15);
    }

    #[test]
    fn out_of_order_bump_never_inflates() {
        // Fault replay / out-of-order serve events can bump a cell at a
        // step *older* than its last touch. A signed dt would turn the
        // decay into amplification (0.5^(1/hl) raised to a negative
        // power > 1); the clamp must keep total mass bounded by the
        // number of bumps.
        let mut h = HotnessTracker::new(1, 50.0);
        h.record_chunk(3, 100);
        h.record_chunk(3, 50); // older than last_step
        let now = h.chunk_hotness(3, 100);
        assert!(
            (now - 2.0).abs() < 1e-12,
            "two unit bumps must read as exactly 2.0, got {now}"
        );
        // Same invariant for topics, with a bigger replay gap.
        h.record_topic(0, 1000);
        h.record_topic(0, 0);
        assert!(h.topic_hotness(0, 1000) <= 2.0 + 1e-12);
        // And decay still applies forward from the newest touch.
        assert!((h.chunk_hotness(3, 150) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn out_of_range_topic_ignored() {
        let mut h = HotnessTracker::new(2, 10.0);
        h.record_topic(99, 0);
        assert_eq!(h.observations, 0);
        assert_eq!(h.topic_hotness(99, 0), 0.0);
    }
}
