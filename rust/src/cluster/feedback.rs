//! Closed-loop gossip budgets learned from gate-observed hit rates.
//!
//! The paper's "adaptive knowledge update" is a *loop*: retrieval
//! outcomes should steer what the edges replicate next, not just a
//! static hot-k digest. Everything the loop needs already flows through
//! the staged pipeline — `TierChosen` says which tier served a query
//! and whether it hit, `QueryDone` closes it out, and every gossip
//! round knows how many digest entries each link offered vs actually
//! transferred. This module folds those signals into exponentially-
//! decayed counters (the same lazy-decay cell discipline as
//! [`super::hotness`] — value + last-touched step, decay applied on
//! read, no sweeps) and answers two questions for the gossiper:
//!
//! * **How much should link `s→r` advertise?** A per-link hot-k budget
//!   in `[min_hot_k, gossip_hot_k]`, scaled by the link's observed
//!   digest usefulness (transferred/offered) — but floored back up by
//!   the fleet's edge-tier *miss pressure*, so a fleet that is missing
//!   a lot keeps replicating aggressively while a warmed-up fleet stops
//!   paying full digest overhead on links that transfer nothing.
//!   Unobserved (cold) links get the full budget: no evidence, no cut.
//! * **Which chunks go first?** The digest re-ranks by blending raw
//!   hotness with each chunk's decayed *hit contribution* (how often it
//!   appeared in the retrieved set of a query that hit), so chunks that
//!   demonstrably close queries outrank chunks that are merely probed.
//!
//! With `[cluster] feedback = "none"` none of this state exists and the
//! gossip path is bit-identical to the static digest. All counters are
//! folded at arrival processing in strict workload order (the same
//! discipline as every [`crate::pipeline::StageSink`]), so the loop
//! rides `serve_workload` without perturbing worker-count invariance,
//! and it consumes no simulation RNG.

use std::collections::HashMap;

use crate::corpus::ChunkId;

/// Tier indices mirror `sim::TIER_*` (none/local/neighbor/cloud).
pub const NUM_TIERS: usize = 4;
/// The local + neighbor tiers whose misses signal replication pressure.
const EDGE_TIERS: [usize; 2] = [1, 2];

/// Which feedback law drives the per-link gossip budgets.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FeedbackMode {
    /// No learned state; gossip digests are the static hot-k ranking
    /// (bit-identical to the pre-feedback plane). The default.
    None,
    /// Gate-observed hit rates drive per-link budgets and digest
    /// re-ranking as described in the module docs.
    HitRate,
}

impl FeedbackMode {
    pub fn parse(s: &str) -> Option<FeedbackMode> {
        match s.to_ascii_lowercase().as_str() {
            "none" | "off" => Some(FeedbackMode::None),
            "hit-rate" | "hit_rate" => Some(FeedbackMode::HitRate),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            FeedbackMode::None => "none",
            FeedbackMode::HitRate => "hit-rate",
        }
    }
}

/// One lazy-decay counter: decayed on read, bumped in place. Same
/// contract as the private cell in [`super::hotness`], including the
/// out-of-order clamp — replayed events at older steps must add mass,
/// never amplify it.
#[derive(Clone, Copy, Debug, Default)]
struct Cell {
    value: f64,
    last_step: usize,
}

impl Cell {
    fn decayed(&self, decay_per_step: f64, step: usize) -> f64 {
        if self.value == 0.0 {
            return 0.0;
        }
        let dt = step.saturating_sub(self.last_step).min(100_000) as i32;
        self.value * decay_per_step.powi(dt)
    }

    fn bump(&mut self, decay_per_step: f64, step: usize, weight: f64) {
        self.value = self.decayed(decay_per_step, step) + weight;
        self.last_step = step.max(self.last_step);
    }
}

/// Per-link digest accounting: entries offered vs entries that actually
/// transferred, both decayed so a link's ancient history fades.
#[derive(Clone, Copy, Debug, Default)]
struct LinkCell {
    offered: Cell,
    used: Cell,
}

/// The learned feedback state one [`super::EdgeCluster`] owns.
///
/// Counters accumulate via [`FeedbackState::observe_query`] (fed from
/// the pipeline's observe point, in strict workload order) and
/// [`FeedbackState::observe_link`] (fed by the gossiper after each
/// link's transfer pass); the gossiper reads them back through
/// [`FeedbackState::link_budget`] and [`FeedbackState::blended_score`].
#[derive(Clone, Debug)]
pub struct FeedbackState {
    decay_per_step: f64,
    pub half_life_steps: f64,
    /// Budget floor: no link's digest drops below this many entries.
    pub min_hot_k: usize,
    tier_hits: [Cell; NUM_TIERS],
    tier_misses: [Cell; NUM_TIERS],
    /// `links[s][r]`: digest usefulness of the directed link s→r.
    links: Vec<Vec<LinkCell>>,
    /// Decayed count of appearances in a *hitting* query's retrieved
    /// set, per chunk.
    chunk_hits: HashMap<ChunkId, Cell>,
    /// Total queries folded (observability).
    pub observations: u64,
}

impl FeedbackState {
    pub fn new(num_edges: usize, half_life_steps: f64, min_hot_k: usize) -> FeedbackState {
        let hl = half_life_steps.max(1.0);
        FeedbackState {
            decay_per_step: 0.5f64.powf(1.0 / hl),
            half_life_steps: hl,
            min_hot_k: min_hot_k.max(1),
            tier_hits: [Cell::default(); NUM_TIERS],
            tier_misses: [Cell::default(); NUM_TIERS],
            links: vec![vec![LinkCell::default(); num_edges]; num_edges],
            chunk_hits: HashMap::new(),
            observations: 0,
        }
    }

    /// Fold one served query: which tier answered, whether retrieval
    /// hit, and (on a hit) which chunks were in the retrieved set.
    pub fn observe_query(&mut self, tier: usize, hit: bool, retrieved: &[ChunkId], step: usize) {
        self.observations += 1;
        let t = tier.min(NUM_TIERS - 1);
        if hit {
            self.tier_hits[t].bump(self.decay_per_step, step, 1.0);
            for &c in retrieved {
                self.chunk_hits
                    .entry(c)
                    .or_default()
                    .bump(self.decay_per_step, step, 1.0);
            }
        } else {
            self.tier_misses[t].bump(self.decay_per_step, step, 1.0);
        }
    }

    /// Fold one gossip link's round outcome: `offered` digest entries
    /// shipped, `transferred` of them actually pulled by the receiver.
    pub fn observe_link(&mut self, s: usize, r: usize, offered: u64, transferred: u64, step: usize) {
        let Some(cell) = self.links.get_mut(s).and_then(|row| row.get_mut(r)) else {
            return;
        };
        if offered > 0 {
            cell.offered.bump(self.decay_per_step, step, offered as f64);
        }
        if transferred > 0 {
            cell.used.bump(self.decay_per_step, step, transferred as f64);
        }
    }

    /// Churn hook: an edge died/was wiped — its link history is no
    /// longer evidence about the revived incarnation.
    pub fn forget_edge(&mut self, e: usize) {
        for (s, row) in self.links.iter_mut().enumerate() {
            if s == e {
                for c in row.iter_mut() {
                    *c = LinkCell::default();
                }
            } else if let Some(c) = row.get_mut(e) {
                *c = LinkCell::default();
            }
        }
    }

    /// Decayed hit rate of one tier; `None` until the tier has data.
    pub fn tier_hit_rate(&self, tier: usize, step: usize) -> Option<f64> {
        let t = tier.min(NUM_TIERS - 1);
        let h = self.tier_hits[t].decayed(self.decay_per_step, step);
        let m = self.tier_misses[t].decayed(self.decay_per_step, step);
        if h + m < 1e-9 {
            None
        } else {
            Some(h / (h + m))
        }
    }

    /// Fraction of recent edge-tier (local + neighbor) traffic that
    /// *missed* — the fleet-wide replication-pressure signal. 1.0 when
    /// there is no evidence yet: an unobserved fleet replicates at full
    /// budget rather than guessing it is warm.
    pub fn edge_miss_pressure(&self, step: usize) -> f64 {
        let mut hits = 0.0;
        let mut misses = 0.0;
        for t in EDGE_TIERS {
            hits += self.tier_hits[t].decayed(self.decay_per_step, step);
            misses += self.tier_misses[t].decayed(self.decay_per_step, step);
        }
        if hits + misses < 1e-9 {
            1.0
        } else {
            misses / (hits + misses)
        }
    }

    /// The learned digest budget for link `s→r`, in
    /// `[min_hot_k, hot_k]`:
    ///
    /// ```text
    /// drive  = max(transferred/offered on s→r, edge miss pressure)
    /// budget = min_hot_k + round(drive · (hot_k − min_hot_k))
    /// ```
    ///
    /// Cold links (no offers recorded) get the full `hot_k`.
    pub fn link_budget(&self, s: usize, r: usize, hot_k: usize, step: usize) -> usize {
        let Some(cell) = self.links.get(s).and_then(|row| row.get(r)) else {
            return hot_k;
        };
        let offered = cell.offered.decayed(self.decay_per_step, step);
        if offered < 1e-9 {
            return hot_k;
        }
        let used = cell.used.decayed(self.decay_per_step, step);
        let usefulness = (used / offered).clamp(0.0, 1.0);
        let drive = usefulness.max(self.edge_miss_pressure(step)).clamp(0.0, 1.0);
        let lo = self.min_hot_k.min(hot_k).max(1);
        lo + ((hot_k - lo) as f64 * drive).round() as usize
    }

    /// Digest ranking score: raw hotness plus the chunk's decayed hit
    /// contribution, so proven query-closers outrank merely-probed
    /// chunks. Both terms are decayed unit-bump counters, so they share
    /// a scale and the sum stays deterministic.
    pub fn blended_score(&self, cid: ChunkId, hotness: f64, step: usize) -> f64 {
        let contrib = self
            .chunk_hits
            .get(&cid)
            .map(|c| c.decayed(self.decay_per_step, step))
            .unwrap_or(0.0);
        hotness + contrib
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn feedback_mode_parse_roundtrip() {
        for m in [FeedbackMode::None, FeedbackMode::HitRate] {
            assert_eq!(FeedbackMode::parse(m.name()), Some(m));
        }
        assert_eq!(FeedbackMode::parse("off"), Some(FeedbackMode::None));
        assert_eq!(FeedbackMode::parse("HIT_RATE"), Some(FeedbackMode::HitRate));
        assert_eq!(FeedbackMode::parse("bogus"), None);
    }

    #[test]
    fn cold_state_gives_full_budget_and_pure_hotness_rank() {
        let fb = FeedbackState::new(4, 100.0, 8);
        assert_eq!(fb.link_budget(0, 1, 64, 10), 64);
        assert_eq!(fb.edge_miss_pressure(10), 1.0);
        assert_eq!(fb.tier_hit_rate(1, 10), None);
        assert_eq!(fb.blended_score(5, 3.25, 10), 3.25);
    }

    #[test]
    fn useless_links_shrink_to_the_floor_once_the_fleet_is_warm() {
        let mut fb = FeedbackState::new(2, 100.0, 8);
        // Warm fleet: edge tier hits everything → miss pressure ~ 0.
        for _ in 0..50 {
            fb.observe_query(1, true, &[], 10);
        }
        // Link 0→1 keeps offering but nothing transfers.
        for _ in 0..10 {
            fb.observe_link(0, 1, 64, 0, 10);
        }
        assert_eq!(fb.link_budget(0, 1, 64, 10), 8, "useless link at the floor");
        // A link that transfers everything keeps the full budget.
        fb.observe_link(1, 0, 64, 64, 10);
        assert_eq!(fb.link_budget(1, 0, 64, 10), 64);
    }

    #[test]
    fn miss_pressure_floors_budgets_back_up() {
        let mut fb = FeedbackState::new(2, 100.0, 8);
        // Useless link, but the fleet is missing half its edge traffic.
        for _ in 0..20 {
            fb.observe_query(1, true, &[], 10);
            fb.observe_query(2, false, &[], 10);
        }
        fb.observe_link(0, 1, 64, 0, 10);
        let b = fb.link_budget(0, 1, 64, 10);
        // drive = max(0, 0.5) → 8 + round(0.5 · 56) = 36.
        assert_eq!(b, 36, "miss pressure must override link uselessness");
        assert!((fb.edge_miss_pressure(10) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn budgets_stay_within_bounds_and_are_deterministic() {
        let mut fb = FeedbackState::new(3, 50.0, 8);
        for i in 0..200usize {
            fb.observe_query(1 + i % 2, i % 3 != 0, &[i % 7], i);
            fb.observe_link(i % 3, (i + 1) % 3, (i % 64) as u64, (i % 9) as u64, i);
        }
        for s in 0..3 {
            for r in 0..3 {
                let b = fb.link_budget(s, r, 64, 200);
                assert!((8..=64).contains(&b), "budget {b} out of [8, 64]");
                assert_eq!(b, fb.link_budget(s, r, 64, 200), "budget must be pure");
            }
        }
        // min_hot_k above hot_k degrades gracefully to hot_k.
        let tight = FeedbackState::new(2, 50.0, 100);
        assert_eq!(tight.link_budget(0, 1, 16, 0), 16);
    }

    #[test]
    fn hit_contribution_reranks_over_raw_hotness() {
        let mut fb = FeedbackState::new(2, 100.0, 8);
        // Chunk 3 closes queries; chunk 9 is probed but never helps.
        for _ in 0..5 {
            fb.observe_query(1, true, &[3], 20);
        }
        assert!(fb.blended_score(3, 1.0, 20) > fb.blended_score(9, 1.0, 20));
        // Decay applies: far in the future the contribution fades out.
        assert!(fb.blended_score(3, 1.0, 5000) < 1.0 + 1e-6);
        assert_eq!(fb.tier_hit_rate(1, 20), Some(1.0));
    }

    #[test]
    fn forget_edge_clears_both_directions() {
        let mut fb = FeedbackState::new(3, 100.0, 8);
        for _ in 0..10 {
            fb.observe_query(1, true, &[], 5);
            fb.observe_link(0, 1, 64, 0, 5);
            fb.observe_link(1, 2, 64, 0, 5);
        }
        assert!(fb.link_budget(0, 1, 64, 5) < 64);
        fb.forget_edge(1);
        // Links into and out of edge 1 are cold again (full budget).
        assert_eq!(fb.link_budget(0, 1, 64, 5), 64);
        assert_eq!(fb.link_budget(1, 2, 64, 5), 64);
    }

    #[test]
    fn out_of_order_observations_never_inflate() {
        let mut fb = FeedbackState::new(2, 50.0, 8);
        fb.observe_query(1, true, &[4], 100);
        fb.observe_query(1, true, &[4], 40); // replay at an older step
        // Two unit bumps read as exactly 2, never amplified.
        assert!((fb.blended_score(4, 0.0, 100) - 2.0).abs() < 1e-12);
    }
}
