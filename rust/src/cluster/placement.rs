//! Pluggable chunk placement: the paper's FIFO baseline + hotness-aware
//! eviction, with versioned chunks so staleness is observable.
//!
//! The seed repo hard-wired the §5 FIFO policy into the edge store. The
//! placement engine keeps that policy available — and bit-identical to
//! the seed, see `tests/cluster_equivalence.rs` — while adding
//! `HotnessLru`, which evicts the *coldest* resident (by the decayed
//! demand counters in [`super::hotness`]) and pins in-flight gossip
//! replicas so a chunk cannot be evicted in the same breath it was
//! replicated. Every admitted chunk carries a version from the cloud's
//! [`super::replicate::VersionAuthority`]; a resident copy older than
//! the authority's latest is *stale*, and [`PlacementEngine::staleness`]
//! counts exactly that.

use std::collections::HashMap;

use crate::corpus::{ChunkId, Corpus};
use crate::edge::EdgeNode;

use super::hotness::HotnessTracker;
use super::replicate::VersionAuthority;

/// Eviction policy for edge chunk stores.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlacementPolicy {
    /// Paper §5: evict the oldest resident (insertion order).
    Fifo,
    /// Evict the coldest resident by decayed demand; oldest-first on
    /// ties; pinned (in-flight) replicas are skipped while any unpinned
    /// resident remains.
    HotnessLru,
}

impl PlacementPolicy {
    pub fn parse(s: &str) -> Option<PlacementPolicy> {
        match s {
            "fifo" => Some(PlacementPolicy::Fifo),
            "hotness-lru" | "hotness_lru" | "lru" => Some(PlacementPolicy::HotnessLru),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            PlacementPolicy::Fifo => "fifo",
            PlacementPolicy::HotnessLru => "hotness-lru",
        }
    }
}

/// What happened to an admitted chunk.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admitted {
    Inserted,
    /// Already resident; recency refreshed, version raised if newer.
    Refreshed,
}

/// Drives insert/evict decisions for every edge store in a cluster.
/// Owns the per-edge replica metadata (versions, pins) that the bare
/// [`EdgeNode`] — kept paper-minimal — does not carry.
#[derive(Clone, Debug)]
pub struct PlacementEngine {
    pub policy: PlacementPolicy,
    /// Per-edge resident chunk versions (absent ⇒ version 0, the
    /// pre-deployment provisioning version).
    versions: Vec<HashMap<ChunkId, u64>>,
    /// Per-edge pinned replicas: chunk → gossip round the pin expires at.
    pins: Vec<HashMap<ChunkId, usize>>,
    pub evictions_fifo: u64,
    pub evictions_cold: u64,
    pub pin_saves: u64,
}

impl PlacementEngine {
    pub fn new(num_edges: usize, policy: PlacementPolicy) -> PlacementEngine {
        PlacementEngine {
            policy,
            versions: vec![HashMap::new(); num_edges],
            pins: vec![HashMap::new(); num_edges],
            evictions_fifo: 0,
            evictions_cold: 0,
            pin_saves: 0,
        }
    }

    /// Version of an edge's resident copy (0 if untracked/provisioned).
    pub fn version_of(&self, edge: usize, chunk: ChunkId) -> u64 {
        self.versions[edge].get(&chunk).copied().unwrap_or(0)
    }

    /// Apply a knowledge push to one edge store — the engine-driven
    /// analogue of [`EdgeNode::apply_update`], and bit-identical to it
    /// under [`PlacementPolicy::Fifo`] (same order, same `EdgeStats`;
    /// pins never influence the FIFO victim). `pin_until_round` covers
    /// every admitted chunk: a freshly-pushed chunk has no demand
    /// history yet (hotness 0), so without a pin `HotnessLru` would
    /// evict it right back out of a warmed store in the same call.
    #[allow(clippy::too_many_arguments)]
    pub fn apply_update(
        &mut self,
        node: &mut EdgeNode,
        corpus: &Corpus,
        hot: &HotnessTracker,
        step: usize,
        chunks: &[ChunkId],
        versions: &VersionAuthority,
        pin_until_round: Option<usize>,
        current_round: usize,
    ) {
        node.stats.updates += 1;
        match self.policy {
            // Interleaved insert/evict — the seed's exact FIFO order.
            PlacementPolicy::Fifo => {
                for &cid in chunks {
                    self.admit(
                        node,
                        corpus,
                        hot,
                        step,
                        cid,
                        versions.latest(cid),
                        pin_until_round,
                        current_round,
                    );
                }
            }
            // Batch path: admit everything, then pick all victims in a
            // single scan — O(batch + capacity log capacity) instead of
            // the per-eviction rescan's O(batch × capacity).
            PlacementPolicy::HotnessLru => {
                for &cid in chunks {
                    self.admit_unbounded(node, corpus, cid, versions.latest(cid), pin_until_round);
                }
                self.evict_to_capacity(node, hot, step, current_round);
            }
        }
    }

    /// Insert or refresh without enforcing capacity (the batch path
    /// evicts once at the end; [`Self::admit`] evicts immediately).
    fn admit_unbounded(
        &mut self,
        node: &mut EdgeNode,
        corpus: &Corpus,
        cid: ChunkId,
        version: u64,
        pin_until_round: Option<usize>,
    ) -> Admitted {
        let e = node.id;
        let admitted = if node.contains(cid) {
            node.refresh_resident(cid);
            let v = self.versions[e].entry(cid).or_insert(0);
            if version > *v {
                *v = version;
            }
            Admitted::Refreshed
        } else {
            node.insert_resident(corpus, cid);
            if version > 0 {
                self.versions[e].insert(cid, version);
            }
            Admitted::Inserted
        };
        // In-flight replicas (gossip transfers, fresh cloud pushes) get
        // pinned on refresh too: the transfer deserves the protection.
        if let Some(round) = pin_until_round {
            self.pins[e].insert(cid, round);
        }
        admitted
    }

    /// Evict until the store fits, selecting every victim in one scan:
    /// coldest-first among unpinned residents (ties → oldest), then —
    /// only if the store still overflows — among pinned ones, so
    /// capacity is never violated.
    pub fn evict_to_capacity(
        &mut self,
        node: &mut EdgeNode,
        hot: &HotnessTracker,
        step: usize,
        current_round: usize,
    ) {
        let over = node.len().saturating_sub(node.capacity());
        if over == 0 {
            return;
        }
        let e = node.id;
        let mut cand: Vec<(bool, f64, usize, ChunkId)> = node
            .resident_chunks()
            .enumerate()
            .map(|(pos, cid)| {
                let pinned = self.pins[e]
                    .get(&cid)
                    .is_some_and(|&until| until >= current_round);
                (pinned, hot.chunk_hotness(cid, step), pos, cid)
            })
            .collect();
        cand.sort_by(|a, b| {
            a.0.cmp(&b.0)
                .then(a.1.partial_cmp(&b.1).unwrap())
                .then(a.2.cmp(&b.2))
        });
        // Pin accounting, same meaning as the per-admit path: did pin
        // protection change the outcome? (A pinned chunk colder than an
        // evicted unpinned one was spared.)
        let coldest_pinned = cand
            .iter()
            .filter(|c| c.0)
            .map(|c| (c.1, c.2))
            .next(); // cand is sorted: first pinned entry is its coldest
        if let Some(cp) = coldest_pinned {
            if cand
                .iter()
                .take(over)
                .any(|c| !c.0 && (c.1, c.2) > cp)
            {
                self.pin_saves += 1;
            }
        }
        for &(_, _, _, cid) in cand.iter().take(over) {
            self.evictions_cold += 1;
            self.versions[e].remove(&cid);
            self.pins[e].remove(&cid);
            node.evict_resident(cid);
        }
    }

    /// Admit one chunk (insert or refresh), then evict per policy until
    /// the store fits. `pin_until_round` marks an in-flight replica that
    /// eviction must skip until the given gossip round passes.
    #[allow(clippy::too_many_arguments)]
    pub fn admit(
        &mut self,
        node: &mut EdgeNode,
        corpus: &Corpus,
        hot: &HotnessTracker,
        step: usize,
        cid: ChunkId,
        version: u64,
        pin_until_round: Option<usize>,
        current_round: usize,
    ) -> Admitted {
        let e = node.id;
        let admitted = self.admit_unbounded(node, corpus, cid, version, pin_until_round);
        while node.len() > node.capacity() {
            let victim = self.pick_victim(node, hot, step, current_round);
            self.versions[e].remove(&victim);
            self.pins[e].remove(&victim);
            node.evict_resident(victim);
        }
        admitted
    }

    /// Eviction victim per policy. Deterministic: scans residents in
    /// insertion order; `HotnessLru` keeps the first (oldest) resident
    /// among equally-cold candidates, so a fully-cold store degrades to
    /// exact FIFO behavior.
    fn pick_victim(
        &mut self,
        node: &EdgeNode,
        hot: &HotnessTracker,
        step: usize,
        current_round: usize,
    ) -> ChunkId {
        let oldest = node
            .oldest_resident()
            .expect("eviction requested on empty store");
        match self.policy {
            PlacementPolicy::Fifo => {
                self.evictions_fifo += 1;
                oldest
            }
            PlacementPolicy::HotnessLru => {
                let e = node.id;
                let mut best: Option<(ChunkId, f64)> = None;
                let mut best_any: Option<(ChunkId, f64)> = None;
                let mut saw_pinned = false;
                for cid in node.resident_chunks() {
                    let h = hot.chunk_hotness(cid, step);
                    match best_any {
                        Some((_, bh)) if h >= bh => {}
                        _ => best_any = Some((cid, h)),
                    }
                    if self.pins[e]
                        .get(&cid)
                        .is_some_and(|&until| until >= current_round)
                    {
                        saw_pinned = true;
                        continue;
                    }
                    match best {
                        Some((_, bh)) if h >= bh => {}
                        _ => best = Some((cid, h)),
                    }
                }
                match best {
                    Some((cid, _)) => {
                        // Pin protection "saved" something only if the
                        // overall-coldest resident was pinned (i.e. the
                        // pin actually changed the outcome).
                        if saw_pinned && best_any.map(|(c, _)| c) != Some(cid) {
                            self.pin_saves += 1;
                        }
                        self.evictions_cold += 1;
                        cid
                    }
                    // Everything pinned: still evict coldest-first
                    // (ties → oldest) so a pinned influx keeps its
                    // hottest chunks rather than FIFO-thrashing them;
                    // capacity is never violated.
                    None => {
                        self.evictions_cold += 1;
                        best_any.map(|(cid, _)| cid).unwrap_or(oldest)
                    }
                }
            }
        }
    }

    /// (stale, resident) counts for one edge: residents whose version
    /// trails the authority's latest publication.
    pub fn staleness(
        &self,
        node: &EdgeNode,
        authority: &VersionAuthority,
    ) -> (usize, usize) {
        let e = node.id;
        let mut stale = 0;
        let mut resident = 0;
        for cid in node.resident_chunks() {
            resident += 1;
            if self.version_of(e, cid) < authority.latest(cid) {
                stale += 1;
            }
        }
        (stale, resident)
    }

    /// Drop pins that expired before `current_round` (bounded memory).
    pub fn expire_pins(&mut self, current_round: usize) {
        for p in self.pins.iter_mut() {
            p.retain(|_, &mut until| until >= current_round);
        }
    }

    pub fn pinned_count(&self, edge: usize) -> usize {
        self.pins[edge].len()
    }

    /// Forget everything known about `edge`'s store (churn: the machine
    /// died and its store was wiped). Version and pin maps must not
    /// survive the wipe — a revived edge starts from a genuinely empty
    /// state and re-admits content through the normal gossip path.
    pub fn forget_edge(&mut self, edge: usize) {
        self.versions[edge].clear();
        self.pins[edge].clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::Profile;

    fn setup(policy: PlacementPolicy, cap: usize) -> (Corpus, EdgeNode, PlacementEngine) {
        let c = Corpus::generate(Profile::Wiki, 2);
        let node = EdgeNode::new(0, cap);
        let eng = PlacementEngine::new(1, policy);
        (c, node, eng)
    }

    #[test]
    fn policy_parse_roundtrip() {
        for p in [PlacementPolicy::Fifo, PlacementPolicy::HotnessLru] {
            assert_eq!(PlacementPolicy::parse(p.name()), Some(p));
        }
        assert_eq!(PlacementPolicy::parse("lru"), Some(PlacementPolicy::HotnessLru));
        assert!(PlacementPolicy::parse("random").is_none());
    }

    #[test]
    fn fifo_policy_matches_bare_edge_node() {
        let (c, mut node, mut eng) = setup(PlacementPolicy::Fifo, 30);
        let mut reference = EdgeNode::new(0, 30);
        let hot = HotnessTracker::new(c.spec.topics, 100.0);
        let auth = VersionAuthority::new(c.chunks.len());
        let batches: Vec<Vec<ChunkId>> =
            vec![(0..40).collect(), vec![3, 5, 41], (20..55).collect()];
        for b in &batches {
            eng.apply_update(&mut node, &c, &hot, 0, b, &auth, None, 0);
            reference.apply_update(&c, b);
            let a: Vec<ChunkId> = node.resident_chunks().collect();
            let r: Vec<ChunkId> = reference.resident_chunks().collect();
            assert_eq!(a, r, "resident order diverged");
        }
        assert_eq!(node.stats.inserted, reference.stats.inserted);
        assert_eq!(node.stats.evicted, reference.stats.evicted);
        assert_eq!(node.stats.updates, reference.stats.updates);
    }

    #[test]
    fn hotness_lru_evicts_coldest_not_oldest() {
        let (c, mut node, mut eng) = setup(PlacementPolicy::HotnessLru, 3);
        let mut hot = HotnessTracker::new(c.spec.topics, 100.0);
        let auth = VersionAuthority::new(c.chunks.len());
        eng.apply_update(&mut node, &c, &hot, 0, &[0, 1, 2], &auth, None, 0);
        // Chunk 0 is oldest but hot; chunk 1 is cold.
        hot.record_chunk(0, 1);
        hot.record_chunk(0, 1);
        hot.record_chunk(2, 1);
        eng.apply_update(&mut node, &c, &hot, 1, &[9], &auth, None, 0);
        assert!(node.contains(0), "hot oldest survived");
        assert!(!node.contains(1), "cold chunk evicted");
        assert!(node.contains(9));
        assert_eq!(eng.evictions_cold, 1);
    }

    #[test]
    fn pinned_replicas_survive_eviction() {
        let (c, mut node, mut eng) = setup(PlacementPolicy::HotnessLru, 2);
        let hot = HotnessTracker::new(c.spec.topics, 100.0);
        // Chunk 5 arrives via gossip, pinned through round 3.
        eng.admit(&mut node, &c, &hot, 0, 5, 1, Some(3), 1);
        eng.admit(&mut node, &c, &hot, 0, 6, 1, None, 1);
        // Everything cold — without the pin, 5 (oldest) would evict.
        eng.admit(&mut node, &c, &hot, 1, 7, 1, None, 1);
        assert!(node.contains(5), "pinned replica evicted");
        assert!(!node.contains(6));
        // After the pin expires the chunk is fair game again.
        eng.admit(&mut node, &c, &hot, 2, 8, 1, None, 9);
        assert!(!node.contains(5));
        assert_eq!(node.len(), 2);
    }

    #[test]
    fn batch_eviction_single_scan_respects_pins_and_capacity() {
        let (c, mut node, mut eng) = setup(PlacementPolicy::HotnessLru, 4);
        let hot = HotnessTracker::new(c.spec.topics, 100.0);
        let mut auth = VersionAuthority::new(c.chunks.len());
        auth.publish(&(0..8).collect::<Vec<_>>());
        // Chunk 0 arrives via gossip (pinned through round 5), then a
        // cloud batch twice the capacity lands in one push.
        eng.admit(&mut node, &c, &hot, 0, 0, 1, Some(5), 1);
        let batch: Vec<ChunkId> = (1..8).collect();
        eng.apply_update(&mut node, &c, &hot, 1, &batch, &auth, None, 1);
        assert_eq!(node.len(), 4, "capacity restored in one pass");
        assert!(node.contains(0), "pinned replica survived batch eviction");
        // Cold unpinned victims went oldest-first: 1..4 evicted, tail kept.
        for cid in [5, 6, 7] {
            assert!(node.contains(cid), "chunk {cid} should survive");
        }
    }

    #[test]
    fn versions_track_staleness() {
        let (c, mut node, mut eng) = setup(PlacementPolicy::Fifo, 50);
        let hot = HotnessTracker::new(c.spec.topics, 100.0);
        let mut auth = VersionAuthority::new(c.chunks.len());
        auth.publish(&[1, 2, 3]);
        eng.apply_update(&mut node, &c, &hot, 0, &[1, 2, 3], &auth, None, 0);
        assert_eq!(eng.staleness(&node, &auth), (0, 3));
        assert_eq!(eng.version_of(0, 1), 1);
        // Cloud republishes chunk 2: resident copy goes stale…
        auth.publish(&[2]);
        assert_eq!(eng.staleness(&node, &auth), (1, 3));
        // …until the fresh copy is admitted (refresh path raises version).
        eng.apply_update(&mut node, &c, &hot, 1, &[2], &auth, None, 0);
        assert_eq!(eng.staleness(&node, &auth), (0, 3));
        assert_eq!(eng.version_of(0, 2), 2);
    }
}
