//! The distributed knowledge plane: many edges, one control plane.
//!
//! The paper (§3.3, Fig. 1) sketches *edge-assisted and collaborative*
//! RAG; the seed repo realized it as isolated FIFO stores plus a
//! per-query scan of **every** edge's full keyword index
//! ([`crate::edge::best_edge_for`]) — an O(#edges × |query|)
//! string-hashing broadcast that cannot scale to a real fleet. This
//! subsystem is the scalable form:
//!
//! * [`topology`] — neighbor graph with netsim-derived link costs.
//! * [`hotness`] — exponentially-decayed topic/chunk demand counters.
//! * [`placement`] — pluggable eviction ([`placement::PlacementPolicy`]:
//!   paper-faithful FIFO, hotness-aware LRU) with versioned chunks.
//! * [`replicate`] — round-based delta gossip of hot chunks between
//!   neighbors, making the cloud one publisher among peers.
//! * [`EdgeCluster`] — owns the [`EdgeNode`]s and routes each query to
//!   local-or-best-neighbor via compact per-edge keyword summaries
//!   (integer fingerprint probes, pre-hashed once per query).
//!
//! Everything is deterministic under virtual time; the sim's
//! `KnowledgeMode::Collaborative` drives it end-to-end.

pub mod hotness;
pub mod placement;
pub mod replicate;
pub mod topology;

use crate::cloud::UpdatePlan;
use crate::config::ClusterConfig;
use crate::corpus::{ChunkId, Corpus, TopicId};
use crate::edge::EdgeNode;
use crate::index::keyword_sig;
use crate::netsim::NetSim;

use hotness::HotnessTracker;
use placement::PlacementEngine;
use replicate::{Gossiper, VersionAuthority};
use topology::Topology;

/// Outcome of summary routing for one query.
#[derive(Clone, Copy, Debug)]
pub struct RouteDecision {
    /// Chosen serving edge (the local edge unless a neighbor is
    /// strictly better).
    pub edge: usize,
    /// Estimated overlap ratio of the chosen edge (matches
    /// [`crate::index::KeywordIndex::overlap_ratio`] up to 64-bit
    /// fingerprint collisions).
    pub overlap: f64,
    /// Best estimated overlap among *non-local* candidates — the gate's
    /// neighbor-coverage signal (0 when the edge has no neighbors).
    pub neighbor_overlap: f64,
}

/// The edge fleet plus its control plane.
pub struct EdgeCluster {
    pub nodes: Vec<EdgeNode>,
    pub topology: Topology,
    pub hotness: HotnessTracker,
    pub placement: PlacementEngine,
    pub gossiper: Gossiper,
    pub authority: VersionAuthority,
    /// Serving-route observability, maintained by the serving loop for
    /// queries actually dispatched edge-assisted (gate-context probes
    /// call [`Self::route`] too and must not inflate these).
    pub routed_local: u64,
    pub routed_neighbor: u64,
    /// Per-query scratch (allocation-free steady state).
    sig_buf: Vec<u64>,
    norm_buf: String,
}

impl EdgeCluster {
    /// Build a cluster of `num_edges` stores of `capacity` chunks.
    /// The topology uses `cfg.degree` neighbors per edge unless
    /// `degree_override` is given (the legacy paper modes pass a full
    /// mesh so the seed's all-edges semantics are preserved).
    pub fn new(
        cfg: &ClusterConfig,
        degree_override: Option<usize>,
        num_edges: usize,
        capacity: usize,
        num_topics: usize,
        num_chunks: usize,
        net: &NetSim,
    ) -> EdgeCluster {
        let degree = degree_override.unwrap_or(cfg.degree);
        let nodes: Vec<EdgeNode> =
            (0..num_edges).map(|i| EdgeNode::new(i, capacity)).collect();
        EdgeCluster {
            nodes,
            topology: Topology::build(net, degree),
            hotness: HotnessTracker::new(num_topics, cfg.hotness_half_life),
            placement: PlacementEngine::new(num_edges, cfg.placement),
            gossiper: Gossiper::new(
                num_edges,
                replicate::GossipConfig {
                    interval_steps: cfg.gossip_interval,
                    hot_k: cfg.gossip_hot_k,
                    pin_rounds: cfg.pin_rounds,
                },
            ),
            authority: VersionAuthority::new(num_chunks),
            routed_local: 0,
            routed_neighbor: 0,
            sig_buf: Vec::new(),
            norm_buf: String::new(),
        }
    }

    pub fn num_edges(&self) -> usize {
        self.nodes.len()
    }

    /// Route a query: score the local edge and its neighbors against
    /// their keyword summaries and pick the best, preferring local on
    /// ties — the same decision rule as the retained
    /// [`crate::edge::best_edge_for`] oracle, at O(degree × |query|)
    /// integer probes instead of an all-edges string-hashing scan.
    /// Query keywords are normalized+hashed exactly once.
    pub fn route(&mut self, local: usize, query_keywords: &[&str]) -> RouteDecision {
        self.sig_buf.clear();
        for kw in query_keywords {
            self.sig_buf.push(keyword_sig(kw, &mut self.norm_buf));
        }
        let len = self.sig_buf.len();
        if len == 0 {
            return RouteDecision { edge: local, overlap: 0.0, neighbor_overlap: 0.0 };
        }
        let local_hits = self.nodes[local].summary.hits(&self.sig_buf);
        let mut best = (local, local_hits);
        let mut neighbor_best = 0usize;
        // Neighbor lists are sorted ascending by id, so ties resolve to
        // the lowest id — the oracle's scan order.
        for &nb in self.topology.neighbors(local) {
            let hits = self.nodes[nb].summary.hits(&self.sig_buf);
            if hits > neighbor_best {
                neighbor_best = hits;
            }
            if hits > best.1 {
                best = (nb, hits);
            }
        }
        RouteDecision {
            edge: best.0,
            overlap: best.1 as f64 / len as f64,
            neighbor_overlap: neighbor_best as f64 / len as f64,
        }
    }

    /// Record one *served* edge-assisted routing decision (the serving
    /// loop calls this for the dispatch, not for gate probes).
    pub fn note_served_route(&mut self, local: bool) {
        if local {
            self.routed_local += 1;
        } else {
            self.routed_neighbor += 1;
        }
    }

    /// Record demand signals for a served query (feeds HotnessLru
    /// placement and the gossip digests).
    pub fn observe_query(&mut self, topic: TopicId, retrieved: &[ChunkId], step: usize) {
        self.hotness.record_topic(topic, step);
        for &c in retrieved {
            self.hotness.record_chunk(c, step);
        }
    }

    /// Apply a cloud knowledge push through the placement engine: the
    /// authority versions the publication and the engine admits/evicts
    /// per policy; the next gossip round picks the change up via the
    /// edge's digest fingerprint.
    pub fn apply_cloud_update(&mut self, corpus: &Corpus, step: usize, plan: &UpdatePlan) {
        self.authority.publish(&plan.chunks);
        // Pushed chunks are pinned like gossip arrivals: they carry no
        // demand history yet, and an unpinned zero-hotness chunk would
        // be HotnessLru's first eviction victim on a warmed store.
        let round = self.gossiper.round();
        let pin = Some(round + self.gossiper.cfg.pin_rounds);
        self.placement.apply_update(
            &mut self.nodes[plan.edge_id],
            corpus,
            &self.hotness,
            step,
            &plan.chunks,
            &self.authority,
            pin,
            round,
        );
    }

    /// Run a gossip round if one is due at `step`. Returns true if a
    /// round ran.
    pub fn maybe_gossip(&mut self, corpus: &Corpus, step: usize) -> bool {
        if !self.gossiper.due(step) {
            return false;
        }
        self.gossiper.run_round(
            &self.topology,
            &mut self.nodes,
            &mut self.placement,
            &self.hotness,
            corpus,
            step,
        );
        true
    }

    /// Aggregate (stale, resident) counts across the fleet.
    pub fn staleness(&self) -> (usize, usize) {
        let mut stale = 0;
        let mut resident = 0;
        for n in &self.nodes {
            let (s, r) = self.placement.staleness(n, &self.authority);
            stale += s;
            resident += r;
        }
        (stale, resident)
    }

    /// Chunk payload bytes moved edge↔edge so far.
    pub fn bytes_gossiped(&self) -> usize {
        self.gossiper.stats.bytes_transferred
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;
    use crate::corpus::Profile;
    use crate::edge::best_edge_for;
    use crate::netsim::NetSpec;
    use crate::util::rng::Rng;

    fn cluster(n: usize, degree: usize, cap: usize, c: &Corpus) -> EdgeCluster {
        let net = NetSim::new(n, NetSpec::default(), 7);
        EdgeCluster::new(
            &ClusterConfig::default(),
            Some(degree),
            n,
            cap,
            c.spec.topics,
            c.chunks.len(),
            &net,
        )
    }

    #[test]
    fn route_matches_oracle_at_full_degree() {
        let c = Corpus::generate(Profile::Wiki, 6);
        let mut cl = cluster(4, 3, 300, &c);
        let mut rng = Rng::new(9);
        for e in 0..4 {
            let chunks: Vec<ChunkId> = (0..250).map(|_| rng.below(c.chunks.len())).collect();
            cl.nodes[e].apply_update(&c, &chunks);
        }
        let mut agree = 0;
        let total = 500;
        for _ in 0..total {
            let qa = &c.qa[rng.below(c.qa.len())];
            let kws = c.qa_keywords(qa);
            let local = rng.below(4);
            let oracle = best_edge_for(&cl.nodes, local, &kws);
            let dec = cl.route(local, &kws);
            if dec.edge == oracle.0 {
                agree += 1;
                assert!(
                    (dec.overlap - oracle.1).abs() < 1e-12,
                    "overlap estimate drifted: {} vs {}",
                    dec.overlap,
                    oracle.1
                );
            }
        }
        assert!(agree >= total * 95 / 100, "only {agree}/{total} agree");
    }

    #[test]
    fn route_prefers_local_on_ties_and_empty() {
        let c = Corpus::generate(Profile::Wiki, 6);
        let mut cl = cluster(3, 2, 100, &c);
        let dec = cl.route(1, &[]);
        assert_eq!(dec.edge, 1);
        assert_eq!(dec.overlap, 0.0);
        // All stores empty: every hit count ties at 0 → stay local.
        let dec = cl.route(2, &["anything"]);
        assert_eq!(dec.edge, 2);
        // Counters track served dispatches, not route probes.
        assert_eq!((cl.routed_local, cl.routed_neighbor), (0, 0));
        cl.note_served_route(true);
        cl.note_served_route(false);
        assert_eq!((cl.routed_local, cl.routed_neighbor), (1, 1));
    }

    #[test]
    fn route_only_considers_neighbors() {
        let c = Corpus::generate(Profile::Wiki, 6);
        // Ring of degree 1: edge 0's only neighbor is edge 1.
        let mut cl = cluster(4, 1, 300, &c);
        let qa = &c.qa[0];
        // Edge 3 has the content but is not a neighbor of edge 0.
        cl.nodes[3].apply_update(&c, &qa.supporting_chunks);
        let kws = c.qa_keywords(qa);
        let dec = cl.route(0, &kws);
        assert_ne!(dec.edge, 3, "routed outside the neighbor set");
    }

    #[test]
    fn cloud_update_then_gossip_spreads_and_versions() {
        let c = Corpus::generate(Profile::Wiki, 6);
        let mut cl = cluster(3, 2, 400, &c);
        let plan = UpdatePlan {
            edge_id: 0,
            chunks: (0..30).collect(),
            communities: vec![],
        };
        cl.apply_cloud_update(&c, 0, &plan);
        assert_eq!(cl.nodes[0].len(), 30);
        let (stale, resident) = cl.staleness();
        assert_eq!((stale, resident), (0, 30));
        // Make a few chunks hot so digests advertise them, then gossip.
        cl.observe_query(c.chunks[2].topic, &[2, 11], 5);
        assert!(cl.maybe_gossip(&c, 25));
        assert!(cl.nodes[1].contains(2) || cl.nodes[1].contains(11));
        assert!(cl.bytes_gossiped() > 0);
        assert!(!cl.maybe_gossip(&c, 26), "next round not due yet");
    }
}
