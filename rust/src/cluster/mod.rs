//! The distributed knowledge plane: many edges, one control plane.
//!
//! The paper (§3.3, Fig. 1) sketches *edge-assisted and collaborative*
//! RAG; the seed repo realized it as isolated FIFO stores plus a
//! per-query scan of **every** edge's full keyword index
//! ([`crate::edge::best_edge_for`]) — an O(#edges × |query|)
//! string-hashing broadcast that cannot scale to a real fleet. This
//! subsystem is the scalable form:
//!
//! * [`topology`] — neighbor graph with netsim-derived link costs.
//! * [`hotness`] — exponentially-decayed topic/chunk demand counters.
//! * [`placement`] — pluggable eviction ([`placement::PlacementPolicy`]:
//!   paper-faithful FIFO, hotness-aware LRU) with versioned chunks.
//! * [`replicate`] — round-based delta gossip of hot chunks between
//!   neighbors, making the cloud one publisher among peers.
//! * [`feedback`] — closed-loop gossip budgets: gate-observed hit rates
//!   and per-link digest usefulness learn how much each link should
//!   advertise (`[cluster] feedback = "hit-rate"`; the default `none`
//!   keeps the static plane bit-identical).
//! * [`EdgeCluster`] — owns the [`EdgeNode`]s and routes each query to
//!   local-or-best-neighbor via compact per-edge keyword summaries
//!   (integer fingerprint probes, pre-hashed once per query).
//!
//! Everything is deterministic under virtual time; the sim's
//! `KnowledgeMode::Collaborative` drives it end-to-end.

pub mod feedback;
pub mod hotness;
pub mod placement;
pub mod replicate;
pub mod topology;

use crate::cloud::UpdatePlan;
use crate::config::{AnnConfig, ClusterConfig};
use crate::corpus::{ChunkId, Corpus, TopicId};
use crate::edge::semantic::{self, CentroidDigest};
use crate::edge::EdgeNode;
use crate::index::keyword_sig;
use crate::netsim::NetSim;

use feedback::{FeedbackMode, FeedbackState};
use hotness::HotnessTracker;
use placement::PlacementEngine;
use replicate::{Gossiper, VersionAuthority};
use topology::Topology;

/// Outcome of summary routing for one query.
#[derive(Clone, Copy, Debug)]
pub struct RouteDecision {
    /// Chosen serving edge (the local edge unless a neighbor is
    /// strictly better).
    pub edge: usize,
    /// Estimated overlap ratio of the chosen edge (matches
    /// [`crate::index::KeywordIndex::overlap_ratio`] up to 64-bit
    /// fingerprint collisions).
    pub overlap: f64,
    /// Best estimated overlap among *non-local* candidates — the gate's
    /// neighbor-coverage signal (0 when the edge has no neighbors).
    pub neighbor_overlap: f64,
}

/// Wire accounting for one executed gossip round — the serving plane
/// treats rounds as schedulable work items and derives their virtual
/// duration from these byte counts (see [`crate::serve`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct GossipRound {
    /// Round number after execution (1-based).
    pub round: usize,
    /// Digest advertisements actually sent this round.
    pub digests_sent: u64,
    /// Chunks transferred edge↔edge this round.
    pub chunks: u64,
    /// Chunk payload bytes moved this round.
    pub payload_bytes: usize,
    /// Digest advertisement bytes this round.
    pub digest_bytes: usize,
    /// Centroid digest bytes this round (ANN plane only).
    pub centroid_bytes: usize,
}

impl GossipRound {
    /// Total bytes on the wire for this round.
    pub fn wire_bytes(&self) -> usize {
        self.payload_bytes + self.digest_bytes + self.centroid_bytes
    }
}

/// The edge fleet plus its control plane.
pub struct EdgeCluster {
    pub nodes: Vec<EdgeNode>,
    pub topology: Topology,
    pub hotness: HotnessTracker,
    pub placement: PlacementEngine,
    pub gossiper: Gossiper,
    pub authority: VersionAuthority,
    /// Learned gossip-budget state (`Some` iff `[cluster] feedback`
    /// is not `"none"`); fed by the pipeline's observe point and the
    /// gossiper's per-link outcomes, read back before each round.
    pub feedback: Option<FeedbackState>,
    /// Serving-route observability, maintained by the serving loop for
    /// queries actually dispatched edge-assisted (gate-context probes
    /// call [`Self::route`] too and must not inflate these).
    pub routed_local: u64,
    pub routed_neighbor: u64,
    /// Per-query scratch (allocation-free steady state).
    sig_buf: Vec<u64>,
    norm_buf: String,
    /// Weight of the coarse-centroid alignment term in
    /// [`Self::route_blended`] (0 until [`Self::enable_ann`]).
    route_blend: f64,
    /// `centroid_known[r][s]`: the last centroid digest edge `r` synced
    /// from edge `s` — the receiver-side view that [`Self::route_blended`]
    /// scores neighbors with and that gossip version-suppresses against.
    centroid_known: Vec<Vec<Option<CentroidDigest>>>,
    ann_enabled: bool,
    /// Liveness per edge (churn hooks [`Self::kill_edge`] /
    /// [`Self::revive_edge`]). All-true until a kill, in which case the
    /// topology is rewired around the dead nodes.
    alive: Vec<bool>,
    /// Active partition: `group_of[e]` is edge `e`'s partition group.
    /// `None` (the default) means the fleet is fully connected. While
    /// `Some`, the topology only wires edges within the same group, so
    /// gossip and neighbor routing are suppressed across the boundary.
    group_of: Option<Vec<usize>>,
}

impl EdgeCluster {
    /// Build a cluster of `num_edges` stores of `capacity` chunks.
    /// The topology uses `cfg.degree` neighbors per edge unless
    /// `degree_override` is given (the legacy paper modes pass a full
    /// mesh so the seed's all-edges semantics are preserved).
    pub fn new(
        cfg: &ClusterConfig,
        degree_override: Option<usize>,
        num_edges: usize,
        capacity: usize,
        num_topics: usize,
        num_chunks: usize,
        net: &NetSim,
    ) -> EdgeCluster {
        let degree = degree_override.unwrap_or(cfg.degree);
        let nodes: Vec<EdgeNode> =
            (0..num_edges).map(|i| EdgeNode::new(i, capacity)).collect();
        EdgeCluster {
            nodes,
            topology: Topology::build(net, degree),
            hotness: HotnessTracker::new(num_topics, cfg.hotness_half_life),
            placement: PlacementEngine::new(num_edges, cfg.placement),
            gossiper: Gossiper::new(
                num_edges,
                replicate::GossipConfig {
                    interval_steps: cfg.gossip_interval,
                    hot_k: cfg.gossip_hot_k,
                    pin_rounds: cfg.pin_rounds,
                },
            ),
            authority: VersionAuthority::new(num_chunks),
            feedback: match cfg.feedback {
                FeedbackMode::None => None,
                FeedbackMode::HitRate => Some(FeedbackState::new(
                    num_edges,
                    cfg.hotness_half_life,
                    cfg.min_hot_k,
                )),
            },
            routed_local: 0,
            routed_neighbor: 0,
            sig_buf: Vec::new(),
            norm_buf: String::new(),
            route_blend: 0.0,
            centroid_known: Vec::new(),
            ann_enabled: false,
            alive: vec![true; num_edges],
            group_of: None,
        }
    }

    /// Turn on the dense retrieval plane: every node gets a semantic
    /// (IVF) store over its residents, routing gains the centroid-blend
    /// term, and gossip rounds start shipping centroid digests. Nodes
    /// get distinct k-means seeds so their list structures decorrelate.
    pub fn enable_ann(&mut self, corpus: &Corpus, ann: &AnnConfig, seed: u64) {
        for n in &mut self.nodes {
            let node_seed = seed ^ ((n.id as u64 + 1) << 32);
            n.enable_semantic(corpus, ann, node_seed);
        }
        let num = self.nodes.len();
        self.centroid_known = vec![vec![None; num]; num];
        self.route_blend = ann.route_blend;
        self.ann_enabled = true;
    }

    pub fn ann_enabled(&self) -> bool {
        self.ann_enabled
    }

    pub fn num_edges(&self) -> usize {
        self.nodes.len()
    }

    /// Route a query: score the local edge and its neighbors against
    /// their keyword summaries and pick the best, preferring local on
    /// ties — the same decision rule as the retained
    /// [`crate::edge::best_edge_for`] oracle, at O(degree × |query|)
    /// integer probes instead of an all-edges string-hashing scan.
    /// Query keywords are normalized+hashed exactly once.
    pub fn route(&mut self, local: usize, query_keywords: &[&str]) -> RouteDecision {
        self.route_blended(local, query_keywords, None)
    }

    /// [`Self::route`] plus an optional coarse-centroid term: each
    /// candidate's score is its keyword hit count plus `route_blend ×`
    /// the query's alignment with that edge's centroid digest (its own
    /// live centroids for the local edge, the last gossiped digest for
    /// neighbors — stale by at most one gossip interval). With no
    /// embedding, no digests, or a zero blend the alignment term is 0
    /// for every candidate, so the f64 comparisons reduce to the legacy
    /// integer decision exactly (integer hit counts are exact in f64).
    /// The overlap fields stay keyword-derived either way — they feed
    /// the gate's coverage features, which keep keyword semantics.
    pub fn route_blended(
        &mut self,
        local: usize,
        query_keywords: &[&str],
        q_emb: Option<&[f32]>,
    ) -> RouteDecision {
        self.sig_buf.clear();
        for kw in query_keywords {
            self.sig_buf.push(keyword_sig(kw, &mut self.norm_buf));
        }
        let len = self.sig_buf.len();
        if len == 0 {
            return RouteDecision { edge: local, overlap: 0.0, neighbor_overlap: 0.0 };
        }
        let qn = q_emb.map(semantic::query_norm).unwrap_or(1.0);
        let local_hits = self.nodes[local].summary.hits(&self.sig_buf);
        let local_score = local_hits as f64 + self.centroid_bonus(local, local, q_emb, qn);
        let mut best = (local, local_score, local_hits);
        let mut neighbor_best = 0usize;
        // Neighbor lists are sorted ascending by id, so ties resolve to
        // the lowest id — the oracle's scan order.
        for &nb in self.topology.neighbors(local) {
            let hits = self.nodes[nb].summary.hits(&self.sig_buf);
            if hits > neighbor_best {
                neighbor_best = hits;
            }
            let score = hits as f64 + self.centroid_bonus(local, nb, q_emb, qn);
            if score > best.1 {
                best = (nb, score, hits);
            }
        }
        RouteDecision {
            edge: best.0,
            overlap: best.2 as f64 / len as f64,
            neighbor_overlap: neighbor_best as f64 / len as f64,
        }
    }

    /// `route_blend ×` alignment of the query with `cand`'s centroids,
    /// as seen from `local` (live for self, last-gossiped for peers).
    fn centroid_bonus(&self, local: usize, cand: usize, q_emb: Option<&[f32]>, qn: f32) -> f64 {
        let Some(q) = q_emb else { return 0.0 };
        if !self.ann_enabled || self.route_blend <= 0.0 {
            return 0.0;
        }
        let alignment = if cand == local {
            self.nodes[cand]
                .semantic
                .as_ref()
                .map(|s| s.alignment(q, qn))
                .unwrap_or(0.0)
        } else {
            self.centroid_known[local][cand]
                .as_ref()
                .map(|d| d.alignment(q, qn))
                .unwrap_or(0.0)
        };
        self.route_blend * alignment
    }

    /// Record one *served* edge-assisted routing decision (the serving
    /// loop calls this for the dispatch, not for gate probes).
    pub fn note_served_route(&mut self, local: bool) {
        if local {
            self.routed_local += 1;
        } else {
            self.routed_neighbor += 1;
        }
    }

    /// Record demand signals for a served query (feeds HotnessLru
    /// placement and the gossip digests).
    pub fn observe_query(&mut self, topic: TopicId, retrieved: &[ChunkId], step: usize) {
        self.hotness.record_topic(topic, step);
        for &c in retrieved {
            self.hotness.record_chunk(c, step);
        }
    }

    /// Close the adaptive-knowledge loop for one served query: which
    /// tier answered, whether retrieval hit, and the retrieved set.
    /// No-op unless `[cluster] feedback` enabled the learned plane, so
    /// the default path carries no extra state. Called by the pipeline
    /// at its observe point — strict workload order on every driver.
    pub fn observe_outcome(&mut self, tier: usize, hit: bool, retrieved: &[ChunkId], step: usize) {
        if let Some(fb) = self.feedback.as_mut() {
            fb.observe_query(tier, hit, retrieved, step);
        }
    }

    /// Apply a cloud knowledge push through the placement engine: the
    /// authority versions the publication and the engine admits/evicts
    /// per policy; the next gossip round picks the change up via the
    /// edge's digest fingerprint.
    pub fn apply_cloud_update(&mut self, corpus: &Corpus, step: usize, plan: &UpdatePlan) {
        self.authority.publish(&plan.chunks);
        // Pushed chunks are pinned like gossip arrivals: they carry no
        // demand history yet, and an unpinned zero-hotness chunk would
        // be HotnessLru's first eviction victim on a warmed store.
        let round = self.gossiper.round();
        let pin = Some(round + self.gossiper.cfg.pin_rounds);
        self.placement.apply_update(
            &mut self.nodes[plan.edge_id],
            corpus,
            &self.hotness,
            step,
            &plan.chunks,
            &self.authority,
            pin,
            round,
        );
    }

    /// Is a gossip round due at `step`? (Pure; the serving plane polls
    /// this to schedule rounds as work items.)
    pub fn gossip_due(&self, step: usize) -> bool {
        self.gossiper.due(step)
    }

    /// Run one gossip round unconditionally and report its wire
    /// accounting. Gossip consumes no simulation RNG, so the caller may
    /// run a due round at any point before the step's retrieval without
    /// perturbing the random stream — this is what lets the async
    /// serving plane execute rounds as background work items while
    /// staying bit-identical to the in-line cadence.
    pub fn run_gossip_round(&mut self, corpus: &Corpus, step: usize) -> GossipRound {
        let before = self.gossiper.stats;
        self.gossiper.run_round_with(
            &self.topology,
            &mut self.nodes,
            &mut self.placement,
            &self.hotness,
            corpus,
            step,
            self.feedback.as_mut(),
        );
        if self.ann_enabled {
            self.gossiper
                .sync_centroids(&self.topology, &self.nodes, &mut self.centroid_known);
        }
        let after = self.gossiper.stats;
        GossipRound {
            round: self.gossiper.round(),
            digests_sent: after.digests_sent - before.digests_sent,
            chunks: after.chunks_transferred - before.chunks_transferred,
            payload_bytes: after.bytes_transferred - before.bytes_transferred,
            digest_bytes: after.digest_bytes - before.digest_bytes,
            centroid_bytes: after.centroid_bytes - before.centroid_bytes,
        }
    }

    /// Run a gossip round if one is due at `step`. Returns true if a
    /// round ran.
    pub fn maybe_gossip(&mut self, corpus: &Corpus, step: usize) -> bool {
        if !self.gossiper.due(step) {
            return false;
        }
        self.run_gossip_round(corpus, step);
        true
    }

    /// Is edge `e` alive (serving + gossiping)?
    pub fn is_alive(&self, e: usize) -> bool {
        self.alive[e]
    }

    pub fn alive_count(&self) -> usize {
        self.alive.iter().filter(|a| **a).count()
    }

    /// Re-derive the neighbor graph from the current liveness and
    /// partition state — the single rewire path every churn/fault hook
    /// funnels through.
    fn rewire_topology(&mut self) {
        self.topology.rewire_grouped(&self.alive, self.group_of.as_deref());
    }

    /// Kill an edge mid-run: its store is wiped (a machine loss, not a
    /// graceful drain), placement/gossip forget everything they knew
    /// about it (so a revived edge re-syncs from scratch instead of
    /// being digest-suppressed), and the topology rewires around it —
    /// live edges adopt their nearest live peers and nobody keeps a
    /// dead neighbor. No-op if already dead; killing the last alive
    /// edge is well-defined (empty topology, routing sheds via
    /// [`Self::nearest_alive`] returning `None`).
    pub fn kill_edge(&mut self, e: usize) {
        if !self.alive[e] {
            return;
        }
        self.alive[e] = false;
        let resident: Vec<ChunkId> = self.nodes[e].resident_chunks().collect();
        for cid in resident {
            self.nodes[e].evict_resident(cid);
        }
        self.placement.forget_edge(e);
        self.gossiper.forget_edge(e);
        if let Some(fb) = self.feedback.as_mut() {
            fb.forget_edge(e);
        }
        if self.ann_enabled {
            for row in self.centroid_known.iter_mut() {
                row[e] = None;
            }
            for known in self.centroid_known[e].iter_mut() {
                *known = None;
            }
        }
        self.rewire_topology();
    }

    /// Revive a dead edge: it rejoins the topology with an empty store
    /// and cold-syncs through subsequent gossip rounds (its neighbors'
    /// digests are all unseen, so the first due round starts refilling
    /// it). No-op if already alive.
    pub fn revive_edge(&mut self, e: usize) {
        if self.alive[e] {
            return;
        }
        self.alive[e] = true;
        self.rewire_topology();
    }

    /// Correlated failure: kill every edge in `edges` (a rack / zone
    /// going dark) with a single topology rewire at the end. Dead or
    /// repeated ids are no-ops, mirroring [`Self::kill_edge`].
    pub fn kill_group(&mut self, edges: &[usize]) {
        let mut changed = false;
        for &e in edges {
            if e >= self.nodes.len() || !self.alive[e] {
                continue;
            }
            self.alive[e] = false;
            let resident: Vec<ChunkId> = self.nodes[e].resident_chunks().collect();
            for cid in resident {
                self.nodes[e].evict_resident(cid);
            }
            self.placement.forget_edge(e);
            self.gossiper.forget_edge(e);
            if let Some(fb) = self.feedback.as_mut() {
                fb.forget_edge(e);
            }
            if self.ann_enabled {
                for row in self.centroid_known.iter_mut() {
                    row[e] = None;
                }
                for known in self.centroid_known[e].iter_mut() {
                    *known = None;
                }
            }
            changed = true;
        }
        if changed {
            self.rewire_topology();
        }
    }

    /// Revive every edge in `edges` (rack power restored) with a single
    /// rewire. Alive or out-of-range ids are no-ops.
    pub fn revive_group(&mut self, edges: &[usize]) {
        let mut changed = false;
        for &e in edges {
            if e >= self.nodes.len() || self.alive[e] {
                continue;
            }
            self.alive[e] = true;
            changed = true;
        }
        if changed {
            self.rewire_topology();
        }
    }

    /// Partition the fleet into the given groups: edges in different
    /// groups lose all topology links (gossip + neighbor routing stop
    /// crossing the boundary) until [`Self::heal_partition`]. Edges not
    /// listed in any group are isolated in singleton groups. The
    /// network-plane counterpart is
    /// [`crate::netsim::NetSim::set_partition`]; the chaos injector
    /// applies both so the knowledge and delay planes agree.
    pub fn apply_partition(&mut self, groups: &[Vec<usize>]) {
        let n = self.nodes.len();
        // Singleton default: group ids >= groups.len() never collide
        // with a listed group.
        let mut group_of: Vec<usize> = (0..n).map(|e| groups.len() + e).collect();
        for (gid, members) in groups.iter().enumerate() {
            for &e in members {
                if e < n {
                    group_of[e] = gid;
                }
            }
        }
        self.group_of = Some(group_of);
        self.rewire_topology();
    }

    /// Heal an active partition: the topology re-forms across the old
    /// boundary and the next gossip rounds reconcile version lag. No-op
    /// if no partition is active.
    pub fn heal_partition(&mut self) {
        if self.group_of.take().is_some() {
            self.rewire_topology();
        }
    }

    /// Is a partition currently active?
    pub fn partitioned(&self) -> bool {
        self.group_of.is_some()
    }

    /// The partition group map, if a partition is active (one group id
    /// per edge) — the injector mirrors this into the netsim.
    pub fn partition_groups(&self) -> Option<&[usize]> {
        self.group_of.as_deref()
    }

    /// The cheapest-link alive edge to serve traffic homed at `e`:
    /// `e` itself when alive, else the alive edge with the lowest
    /// netsim link cost. Ties break to the **lowest edge id** (the
    /// comparator is `cost.partial_cmp(..).then(a.cmp(&b))`, and
    /// `min_by` keeps the first minimum) — pinned by test so reroute
    /// targets stay deterministic. Returns `None` when no candidate is
    /// alive. During a partition, candidates are confined to `e`'s own
    /// group: traffic homed in a partition with no alive edge is shed,
    /// not teleported across the unreachable boundary.
    pub fn nearest_alive(&self, e: usize) -> Option<usize> {
        if self.alive.get(e).copied().unwrap_or(false) {
            return Some(e);
        }
        let same_group = |x: usize| {
            self.group_of
                .as_ref()
                .is_none_or(|g| g.get(x) == g.get(e))
        };
        (0..self.nodes.len())
            .filter(|&x| x != e && self.alive[x] && same_group(x))
            .min_by(|&a, &b| {
                self.topology
                    .link_cost_ms(e, a)
                    .partial_cmp(&self.topology.link_cost_ms(e, b))
                    .unwrap()
                    .then(a.cmp(&b))
            })
    }

    /// Max version lag across the fleet: over every alive edge's
    /// residents, the largest `authority.latest(c) - resident version`.
    /// 0 when every resident copy is current — the staleness signal the
    /// chaos probes sample during/after partitions.
    pub fn max_version_lag(&self) -> u64 {
        let mut worst = 0u64;
        for (e, node) in self.nodes.iter().enumerate() {
            if !self.alive[e] {
                continue;
            }
            for c in node.resident_chunks() {
                let lag = self
                    .authority
                    .latest(c)
                    .saturating_sub(self.placement.version_of(e, c));
                worst = worst.max(lag);
            }
        }
        worst
    }

    /// Aggregate (stale, resident) counts across the fleet.
    pub fn staleness(&self) -> (usize, usize) {
        let mut stale = 0;
        let mut resident = 0;
        for n in &self.nodes {
            let (s, r) = self.placement.staleness(n, &self.authority);
            stale += s;
            resident += r;
        }
        (stale, resident)
    }

    /// Chunk payload bytes moved edge↔edge so far.
    pub fn bytes_gossiped(&self) -> usize {
        self.gossiper.stats.bytes_transferred
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;
    use crate::corpus::Profile;
    use crate::edge::best_edge_for;
    use crate::netsim::NetSpec;
    use crate::util::rng::Rng;

    fn cluster(n: usize, degree: usize, cap: usize, c: &Corpus) -> EdgeCluster {
        let net = NetSim::new(n, NetSpec::default(), 7);
        EdgeCluster::new(
            &ClusterConfig::default(),
            Some(degree),
            n,
            cap,
            c.spec.topics,
            c.chunks.len(),
            &net,
        )
    }

    #[test]
    fn route_matches_oracle_at_full_degree() {
        let c = Corpus::generate(Profile::Wiki, 6);
        let mut cl = cluster(4, 3, 300, &c);
        let mut rng = Rng::new(9);
        for e in 0..4 {
            let chunks: Vec<ChunkId> = (0..250).map(|_| rng.below(c.chunks.len())).collect();
            cl.nodes[e].apply_update(&c, &chunks);
        }
        let mut agree = 0;
        let total = 500;
        for _ in 0..total {
            let qa = &c.qa[rng.below(c.qa.len())];
            let kws = c.qa_keywords(qa);
            let local = rng.below(4);
            let oracle = best_edge_for(&cl.nodes, local, &kws);
            let dec = cl.route(local, &kws);
            if dec.edge == oracle.0 {
                agree += 1;
                assert!(
                    (dec.overlap - oracle.1).abs() < 1e-12,
                    "overlap estimate drifted: {} vs {}",
                    dec.overlap,
                    oracle.1
                );
            }
        }
        assert!(agree >= total * 95 / 100, "only {agree}/{total} agree");
    }

    #[test]
    fn route_prefers_local_on_ties_and_empty() {
        let c = Corpus::generate(Profile::Wiki, 6);
        let mut cl = cluster(3, 2, 100, &c);
        let dec = cl.route(1, &[]);
        assert_eq!(dec.edge, 1);
        assert_eq!(dec.overlap, 0.0);
        // All stores empty: every hit count ties at 0 → stay local.
        let dec = cl.route(2, &["anything"]);
        assert_eq!(dec.edge, 2);
        // Counters track served dispatches, not route probes.
        assert_eq!((cl.routed_local, cl.routed_neighbor), (0, 0));
        cl.note_served_route(true);
        cl.note_served_route(false);
        assert_eq!((cl.routed_local, cl.routed_neighbor), (1, 1));
    }

    #[test]
    fn route_only_considers_neighbors() {
        let c = Corpus::generate(Profile::Wiki, 6);
        // Ring of degree 1: edge 0's only neighbor is edge 1.
        let mut cl = cluster(4, 1, 300, &c);
        let qa = &c.qa[0];
        // Edge 3 has the content but is not a neighbor of edge 0.
        cl.nodes[3].apply_update(&c, &qa.supporting_chunks);
        let kws = c.qa_keywords(qa);
        let dec = cl.route(0, &kws);
        assert_ne!(dec.edge, 3, "routed outside the neighbor set");
    }

    #[test]
    fn blended_routing_matches_legacy_without_digests() {
        use crate::edge::semantic::embed_keywords;
        use crate::runtime::FeatureHasher;
        let c = Corpus::generate(Profile::Wiki, 6);
        let mut cl = cluster(4, 3, 300, &c);
        let mut rng = Rng::new(11);
        for e in 0..4 {
            let chunks: Vec<ChunkId> = (0..200).map(|_| rng.below(c.chunks.len())).collect();
            cl.nodes[e].apply_update(&c, &chunks);
        }
        // Default exact_below (4096) keeps every store untrained: no
        // centroids anywhere, so the blend term is identically zero and
        // blended decisions must equal the legacy keyword decisions.
        let ann = AnnConfig::default();
        cl.enable_ann(&c, &ann, 3);
        assert!(cl.ann_enabled());
        let hasher = FeatureHasher::new(ann.embed_dim);
        for i in 0..50 {
            let qa = &c.qa[i % c.qa.len()];
            let kws = c.qa_keywords(qa);
            let q = embed_keywords(&hasher, &kws);
            let local = i % 4;
            let legacy = cl.route(local, &kws);
            let blended = cl.route_blended(local, &kws, Some(&q));
            assert_eq!(blended.edge, legacy.edge);
            assert_eq!(blended.overlap, legacy.overlap);
            assert_eq!(blended.neighbor_overlap, legacy.neighbor_overlap);
        }
    }

    #[test]
    fn ann_gossip_ships_centroids_and_routing_stays_in_neighbor_set() {
        use crate::edge::semantic::embed_keywords;
        use crate::runtime::FeatureHasher;
        let c = Corpus::generate(Profile::Wiki, 6);
        let mut cl = cluster(3, 2, 300, &c);
        for e in 0..3usize {
            let chunks: Vec<ChunkId> = c
                .chunks
                .iter()
                .filter(|ch| ch.id % 3 == e)
                .take(200)
                .map(|ch| ch.id)
                .collect();
            cl.nodes[e].apply_update(&c, &chunks);
        }
        let ann = AnnConfig {
            exact_below: 32,
            nlist: 4,
            ..AnnConfig::default()
        };
        cl.enable_ann(&c, &ann, 5);
        // 200 residents ≥ exact_below → every store trained on enable.
        for n in &cl.nodes {
            assert!(n.semantic.as_ref().unwrap().centroid_version() >= 1);
        }
        // Centroid digests piggyback on the first gossip round.
        assert!(cl.maybe_gossip(&c, 25));
        assert!(cl.gossiper.stats.centroid_digests_sent > 0);
        assert!(cl.gossiper.stats.centroid_bytes > 0);
        let shipped =
            cl.gossiper.stats.centroid_digests_sent + cl.gossiper.stats.centroid_digests_suppressed;
        // Blended decisions stay inside {local} ∪ neighbors and keep
        // keyword-derived overlap fields.
        let hasher = FeatureHasher::new(ann.embed_dim);
        for i in 0..30 {
            let qa = &c.qa[i % c.qa.len()];
            let kws = c.qa_keywords(qa);
            let q = embed_keywords(&hasher, &kws);
            let dec = cl.route_blended(0, &kws, Some(&q));
            assert!(
                dec.edge == 0 || cl.topology.neighbors(0).contains(&dec.edge),
                "routed outside the neighbor set"
            );
            assert!((0.0..=1.0).contains(&dec.overlap));
        }
        // A later round either suppresses (unchanged versions) or
        // re-ships (stores mutated during gossip) — both move the total.
        assert!(cl.maybe_gossip(&c, 50));
        assert!(
            cl.gossiper.stats.centroid_digests_sent
                + cl.gossiper.stats.centroid_digests_suppressed
                > shipped
        );
    }

    #[test]
    fn cloud_update_then_gossip_spreads_and_versions() {
        let c = Corpus::generate(Profile::Wiki, 6);
        let mut cl = cluster(3, 2, 400, &c);
        let plan = UpdatePlan {
            edge_id: 0,
            chunks: (0..30).collect(),
            communities: vec![],
        };
        cl.apply_cloud_update(&c, 0, &plan);
        assert_eq!(cl.nodes[0].len(), 30);
        let (stale, resident) = cl.staleness();
        assert_eq!((stale, resident), (0, 30));
        // Make a few chunks hot so digests advertise them, then gossip.
        cl.observe_query(c.chunks[2].topic, &[2, 11], 5);
        assert!(cl.maybe_gossip(&c, 25));
        assert!(cl.nodes[1].contains(2) || cl.nodes[1].contains(11));
        assert!(cl.bytes_gossiped() > 0);
        assert!(!cl.maybe_gossip(&c, 26), "next round not due yet");
    }

    #[test]
    fn gossip_round_as_work_item_reports_wire_accounting() {
        let c = Corpus::generate(Profile::Wiki, 6);
        let mut cl = cluster(3, 2, 400, &c);
        let plan = UpdatePlan { edge_id: 0, chunks: (0..40).collect(), communities: vec![] };
        cl.apply_cloud_update(&c, 0, &plan);
        cl.observe_query(c.chunks[3].topic, &[3, 17, 25], 5);
        assert!(cl.gossip_due(25));
        let bytes0 = cl.bytes_gossiped();
        let report = cl.run_gossip_round(&c, 25);
        assert_eq!(report.round, 1);
        assert!(report.digests_sent > 0);
        assert!(report.chunks > 0, "hot chunks should transfer on round 1");
        assert_eq!(report.payload_bytes, cl.bytes_gossiped() - bytes0);
        assert!(report.digest_bytes > 0);
        assert!(report.wire_bytes() >= report.payload_bytes + report.digest_bytes);
        assert!(!cl.gossip_due(26), "running the round advances the cadence");
        // Second round: deltas are per-round, not cumulative.
        let r2 = cl.run_gossip_round(&c, 50);
        assert_eq!(r2.round, 2);
        assert_eq!(bytes0 + report.payload_bytes + r2.payload_bytes, cl.bytes_gossiped());
    }

    #[test]
    fn kill_edge_wipes_and_reroutes_topology() {
        let c = Corpus::generate(Profile::Wiki, 6);
        let mut cl = cluster(4, 2, 300, &c);
        let chunks: Vec<ChunkId> = (0..100).collect();
        for e in 0..4 {
            cl.nodes[e].apply_update(&c, &chunks);
        }
        assert_eq!(cl.alive_count(), 4);
        cl.kill_edge(1);
        assert!(!cl.is_alive(1));
        assert_eq!(cl.alive_count(), 3);
        assert!(cl.nodes[1].is_empty(), "dead edge's store must be wiped");
        assert!(cl.topology.neighbors(1).is_empty());
        for e in [0usize, 2, 3] {
            assert!(!cl.topology.neighbors(e).contains(&1));
        }
        // Killing twice is a no-op.
        cl.kill_edge(1);
        assert_eq!(cl.alive_count(), 3);
        // nearest_alive: self when alive, cheapest alive peer when dead.
        assert_eq!(cl.nearest_alive(0), Some(0));
        let alt = cl.nearest_alive(1).unwrap();
        assert_ne!(alt, 1);
        assert!(cl.is_alive(alt));
        for x in [0usize, 2, 3] {
            assert!(
                cl.topology.link_cost_ms(1, alt) <= cl.topology.link_cost_ms(1, x),
                "nearest_alive must pick the cheapest link"
            );
        }
        // Summary routing no longer selects the dead edge either: its
        // store (and thus summary) is empty and it is nobody's neighbor.
        let qa = &c.qa[0];
        let kws = c.qa_keywords(qa);
        for e in [0usize, 2, 3] {
            let dec = cl.route(e, &kws);
            assert_ne!(dec.edge, 1, "routed to a dead edge");
        }
    }

    #[test]
    fn revived_edge_cold_syncs_via_gossip() {
        let c = Corpus::generate(Profile::Wiki, 6);
        let mut cl = cluster(3, 2, 400, &c);
        let chunks: Vec<ChunkId> = (0..120).collect();
        for e in 0..3 {
            cl.nodes[e].apply_update(&c, &chunks);
        }
        // Heat some chunks so digests advertise them, then a first round
        // populates the suppression state.
        cl.observe_query(c.chunks[5].topic, &[5, 9, 13], 2);
        assert!(cl.maybe_gossip(&c, 25));
        cl.kill_edge(2);
        assert!(cl.nodes[2].is_empty());
        cl.revive_edge(2);
        assert!(cl.is_alive(2));
        assert_eq!(cl.topology.neighbors(2).len(), 2, "revived edge rejoins the graph");
        // Keep demand warm and run the next due rounds: the revived
        // edge's store refills from its neighbors' digests (cold sync)
        // even though those digests were synced once before the death.
        for step in [50usize, 75, 100] {
            cl.observe_query(c.chunks[5].topic, &[5, 9, 13], step);
            assert!(cl.maybe_gossip(&c, step));
        }
        assert!(!cl.nodes[2].is_empty(), "revived edge did not cold-sync");
        // Revive on an alive edge is a no-op.
        cl.revive_edge(2);
        assert_eq!(cl.alive_count(), 3);
    }

    #[test]
    fn nearest_alive_none_when_fleet_down() {
        let c = Corpus::generate(Profile::Wiki, 6);
        let mut cl = cluster(3, 2, 100, &c);
        for e in 0..3 {
            cl.kill_edge(e);
        }
        assert_eq!(cl.alive_count(), 0);
        assert_eq!(cl.nearest_alive(0), None);
        assert_eq!(cl.nearest_alive(2), None);
    }

    #[test]
    fn killing_last_alive_edge_is_well_defined() {
        let c = Corpus::generate(Profile::Wiki, 6);
        let mut cl = cluster(3, 2, 100, &c);
        let chunks: Vec<ChunkId> = (0..50).collect();
        cl.nodes[2].apply_update(&c, &chunks);
        cl.kill_edge(0);
        cl.kill_edge(1);
        // Edge 2 is the last survivor; killing it must not panic and
        // must leave a coherent (empty) fleet.
        cl.kill_edge(2);
        assert_eq!(cl.alive_count(), 0);
        assert!(cl.nodes[2].is_empty());
        assert_eq!(cl.topology.num_links(), 0);
        assert_eq!(cl.nearest_alive(2), None);
        // Gossip on an all-dead fleet is a structural no-op.
        let r = cl.run_gossip_round(&c, 25);
        assert_eq!(r.chunks, 0);
        assert_eq!(r.payload_bytes, 0);
        // The fleet can still come back.
        cl.revive_edge(1);
        assert_eq!(cl.alive_count(), 1);
        assert_eq!(cl.nearest_alive(0), Some(1));
    }

    #[test]
    fn nearest_alive_tie_breaks_to_lowest_id() {
        let c = Corpus::generate(Profile::Wiki, 6);
        // 4-edge ring: cost(1,0) == cost(1,2) by ring symmetry, so the
        // reroute target for dead edge 1 must tie-break to id 0.
        let mut cl = cluster(4, 2, 100, &c);
        let d01 = cl.topology.link_cost_ms(1, 0);
        let d12 = cl.topology.link_cost_ms(1, 2);
        assert_eq!(d01.to_bits(), d12.to_bits(), "ring costs expected symmetric");
        cl.kill_edge(1);
        assert_eq!(cl.nearest_alive(1), Some(0), "tie must break to lowest id");
    }

    #[test]
    fn correlated_failure_kills_and_revives_as_a_group() {
        let c = Corpus::generate(Profile::Wiki, 6);
        let mut cl = cluster(6, 2, 200, &c);
        let chunks: Vec<ChunkId> = (0..80).collect();
        for e in 0..6 {
            cl.nodes[e].apply_update(&c, &chunks);
        }
        // Rack {2,3,4} goes dark; repeated and dead ids are no-ops.
        cl.kill_group(&[2, 3, 4, 3]);
        assert_eq!(cl.alive_count(), 3);
        for e in [2usize, 3, 4] {
            assert!(!cl.is_alive(e));
            assert!(cl.nodes[e].is_empty());
            assert!(cl.topology.neighbors(e).is_empty());
        }
        for e in [0usize, 1, 5] {
            for &nb in cl.topology.neighbors(e) {
                assert!(cl.is_alive(nb), "live edge {e} kept dead neighbor {nb}");
            }
        }
        cl.revive_group(&[2, 3, 4]);
        assert_eq!(cl.alive_count(), 6);
        assert!(!cl.topology.neighbors(3).is_empty());
        // Reviving an alive group again is a no-op.
        cl.revive_group(&[2, 3, 4]);
        assert_eq!(cl.alive_count(), 6);
    }

    #[test]
    fn partition_confines_topology_routing_and_reroute() {
        let c = Corpus::generate(Profile::Wiki, 6);
        let mut cl = cluster(4, 2, 200, &c);
        cl.apply_partition(&[vec![0, 1], vec![2, 3]]);
        assert!(cl.partitioned());
        assert_eq!(cl.partition_groups().unwrap(), &[0, 0, 1, 1]);
        for a in 0..4usize {
            for &b in cl.topology.neighbors(a) {
                assert_eq!(a / 2, b / 2, "cross-partition link {a}->{b}");
            }
        }
        // Reroute for a dead edge stays inside its partition group.
        cl.kill_edge(3);
        assert_eq!(cl.nearest_alive(3), Some(2));
        // ... and sheds (None) when its whole group is down.
        cl.kill_edge(2);
        assert_eq!(cl.nearest_alive(3), None, "reroute must not cross the partition");
        assert_eq!(cl.nearest_alive(0), Some(0));
        // Heal: the revived topology spans groups again and reroute may
        // cross the old boundary.
        cl.heal_partition();
        assert!(!cl.partitioned());
        assert!(cl.nearest_alive(3).is_some());
        // Healing twice is a no-op.
        cl.heal_partition();
        assert!(!cl.partitioned());
    }

    #[test]
    fn partition_with_unlisted_edges_isolates_them() {
        let c = Corpus::generate(Profile::Wiki, 6);
        let mut cl = cluster(5, 2, 100, &c);
        // Edge 4 appears in no group: singleton isolation.
        cl.apply_partition(&[vec![0, 1], vec![2, 3]]);
        assert!(cl.topology.neighbors(4).is_empty());
        cl.kill_edge(4);
        assert_eq!(cl.nearest_alive(4), None, "singleton group has no fallback");
    }

    #[test]
    fn split_brain_bounds_staleness_then_converges_after_heal() {
        let c = Corpus::generate(Profile::Wiki, 6);
        let mut cl = cluster(4, 3, 400, &c);
        // Provision chunk 7 everywhere at version 1 via one publication
        // reaching every edge.
        for e in 0..4 {
            let plan = UpdatePlan { edge_id: e, chunks: vec![7], communities: vec![] };
            if e == 0 {
                cl.apply_cloud_update(&c, 0, &plan);
            } else {
                // Same version: publish once, then place on the rest.
                cl.nodes[e].apply_update(&c, &[7]);
            }
        }
        assert_eq!(cl.max_version_lag(), 1, "manual placements lag the v1 publication");
        // Keep chunk 7 hot so digests advertise it, and gossip until the
        // fleet is consistent at v1.
        for step in [5usize, 25, 50] {
            cl.observe_query(c.chunks[7].topic, &[7], step);
            cl.run_gossip_round(&c, step);
        }
        assert_eq!(cl.max_version_lag(), 0, "fleet should settle at v1");
        // Split-brain, then publish v2 to side A only.
        cl.apply_partition(&[vec![0, 1], vec![2, 3]]);
        let plan = UpdatePlan { edge_id: 0, chunks: vec![7], communities: vec![] };
        cl.apply_cloud_update(&c, 60, &plan);
        cl.observe_query(c.chunks[7].topic, &[7], 60);
        cl.run_gossip_round(&c, 75);
        // During the partition side B cannot see v2: lag is exactly the
        // one missed version, bounded — not unbounded drift.
        assert_eq!(cl.max_version_lag(), 1, "staleness during partition must be bounded");
        // Heal and converge within 2 gossip rounds.
        cl.heal_partition();
        let mut lag = cl.max_version_lag();
        for (i, step) in [100usize, 125].into_iter().enumerate() {
            cl.observe_query(c.chunks[7].topic, &[7], step);
            cl.run_gossip_round(&c, step);
            lag = cl.max_version_lag();
            if lag == 0 {
                break;
            }
            assert!(i < 1, "did not converge within 2 post-heal rounds");
        }
        assert_eq!(lag, 0, "post-heal gossip must reconcile version lag");
    }
}
