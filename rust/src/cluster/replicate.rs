//! Round-based delta gossip of hot chunks between neighbor edges.
//!
//! The paper's only knowledge publisher is the cloud (§3.3's update
//! loop). At fleet scale that makes the cloud a fan-out bottleneck and
//! leaves co-located edges unable to share what they already fetched.
//! The gossip plane makes every edge a publisher among peers:
//!
//! * **Rounds** fire on a virtual-time cadence; each round walks every
//!   directed neighbor link in deterministic id order.
//! * **Delta suppression** — each edge computes one digest per round
//!   and an order-independent *fingerprint* of its (chunk, version)
//!   content; every receiver keeps a version vector of the last
//!   fingerprint it synced per peer, and an unchanged fingerprint ships
//!   nothing at all. Keying on digest content (not a store-mutation
//!   clock) means demand shifts over already-resident chunks — which
//!   reorder the hot-k set without any store mutation — re-advertise
//!   correctly instead of stalling forever.
//! * **Digests** advertise only the sender's `hot_k` hottest residents
//!   (ids + versions, [`DIGEST_ENTRY_BYTES`]/entry accounted) — not the
//!   store.
//! * **Versioned transfer** — the receiver pulls only chunks it lacks
//!   or holds stale (lower version) copies of; fresh replicas are
//!   pinned for `pin_rounds` so placement can't immediately undo the
//!   work ("in-flight" protection).
//!
//! Everything is driven by plain function calls under virtual time —
//! deterministic, replayable, no threads — matching the sim's design.

use crate::corpus::{ChunkId, Corpus};
use crate::edge::EdgeNode;

use super::feedback::FeedbackState;
use super::hotness::HotnessTracker;
use super::placement::PlacementEngine;
use super::topology::Topology;

/// Wire size of one digest entry: chunk id (4 B truncated) + version
/// (8 B).
pub const DIGEST_ENTRY_BYTES: usize = 12;

/// Gossip protocol knobs.
#[derive(Clone, Copy, Debug)]
pub struct GossipConfig {
    /// Virtual-time steps between rounds.
    pub interval_steps: usize,
    /// Digest size: hottest residents advertised per link per round.
    pub hot_k: usize,
    /// Rounds a freshly-replicated chunk stays pinned against eviction.
    pub pin_rounds: usize,
}

impl Default for GossipConfig {
    fn default() -> Self {
        GossipConfig {
            interval_steps: 25,
            hot_k: 64,
            pin_rounds: 2,
        }
    }
}

/// Wire/observability counters for the replication plane.
#[derive(Clone, Copy, Debug, Default)]
pub struct ReplicationStats {
    pub rounds: u64,
    pub digests_sent: u64,
    /// Links skipped because the sender's digest fingerprint was
    /// unchanged since the receiver last synced (or the digest empty).
    pub digests_suppressed: u64,
    pub chunks_offered: u64,
    pub chunks_transferred: u64,
    /// Chunk payload bytes moved edge↔edge.
    pub bytes_transferred: usize,
    /// Digest overhead bytes ([`DIGEST_ENTRY_BYTES`] per entry).
    pub digest_bytes: usize,
    /// Centroid digests shipped to neighbors (ANN routing plane).
    pub centroid_digests_sent: u64,
    /// Centroid digests skipped because the receiver already held the
    /// sender's current centroid version.
    pub centroid_digests_suppressed: u64,
    /// Centroid digest bytes on the wire (~`nlist · dim · 4` each).
    pub centroid_bytes: usize,
}

/// Monotone per-chunk publication counter — the cloud bumps a chunk's
/// version every time it (re)distributes it, making staleness a
/// first-class observable instead of an invisible property of FIFO age.
#[derive(Clone, Debug)]
pub struct VersionAuthority {
    latest: Vec<u64>,
    pub publishes: u64,
}

impl VersionAuthority {
    pub fn new(num_chunks: usize) -> VersionAuthority {
        VersionAuthority {
            latest: vec![0; num_chunks],
            publishes: 0,
        }
    }

    /// Record a (re)publication of these chunks.
    pub fn publish(&mut self, chunks: &[ChunkId]) {
        self.publishes += 1;
        for &c in chunks {
            if let Some(v) = self.latest.get_mut(c) {
                *v += 1;
            }
        }
    }

    pub fn latest(&self, chunk: ChunkId) -> u64 {
        self.latest.get(chunk).copied().unwrap_or(0)
    }
}

/// Gossip state: round counter and the receiver-side version vectors of
/// last-synced digest fingerprints that realize delta suppression.
#[derive(Clone, Debug)]
pub struct Gossiper {
    pub cfg: GossipConfig,
    pub stats: ReplicationStats,
    round: usize,
    next_step: usize,
    /// `seen[r][s]`: fingerprint of the last digest edge `r` synced
    /// from edge `s` (0 = never synced).
    seen: Vec<Vec<u64>>,
    /// Reusable digest buffer (allocation-free steady state).
    digest: Vec<(ChunkId, u64, f64)>,
}

/// Order-independent fingerprint of one digest entry (mixed so that
/// (id, version) pairs don't cancel under the XOR combine).
fn entry_fingerprint(cid: ChunkId, ver: u64) -> u64 {
    (cid as u64 ^ 0x9E37_79B9_7F4A_7C15)
        .wrapping_mul(0xC2B2_AE3D_27D4_EB4F)
        .wrapping_add(ver.wrapping_mul(0x9E37_79B9_7F4A_7C15))
}

impl Gossiper {
    pub fn new(num_edges: usize, cfg: GossipConfig) -> Gossiper {
        Gossiper {
            cfg,
            stats: ReplicationStats::default(),
            round: 0,
            next_step: cfg.interval_steps.max(1),
            seen: vec![vec![0; num_edges]; num_edges],
            digest: Vec::new(),
        }
    }

    pub fn round(&self) -> usize {
        self.round
    }

    /// Is a round due at this virtual-time step?
    pub fn due(&self, step: usize) -> bool {
        step >= self.next_step
    }

    /// Forget all suppression state involving edge `e` (churn: the edge
    /// died or was wiped). Zeroing `seen[e][*]` makes a revived `e`
    /// re-pull every neighbor digest (cold sync), and zeroing
    /// `seen[*][e]` makes neighbors re-evaluate whatever a revived `e`
    /// advertises instead of trusting pre-death fingerprints.
    pub fn forget_edge(&mut self, e: usize) {
        for (r, row) in self.seen.iter_mut().enumerate() {
            if r == e {
                for f in row.iter_mut() {
                    *f = 0;
                }
            } else if e < row.len() {
                row[e] = 0;
            }
        }
    }

    /// Run one gossip round over every directed neighbor link, in
    /// sender-id order (deterministic). Mutates receiver stores through
    /// the placement engine; a transfer changes the receiver's own
    /// digest, so its next-round fingerprint differs and the content
    /// propagates onward (epidemic spread).
    pub fn run_round(
        &mut self,
        topo: &Topology,
        nodes: &mut [EdgeNode],
        placement: &mut PlacementEngine,
        hot: &HotnessTracker,
        corpus: &Corpus,
        step: usize,
    ) {
        self.run_round_with(topo, nodes, placement, hot, corpus, step, None);
    }

    /// [`Self::run_round`] with an optional learned-feedback plane.
    ///
    /// With `feedback = None` this is the static protocol, bit-for-bit:
    /// one hotness-ranked hot-k digest per sender, one full-digest
    /// fingerprint shared by every link. With `Some(fb)` the digest is
    /// re-ranked by [`FeedbackState::blended_score`] and each link ships
    /// only its [`FeedbackState::link_budget`]-long prefix — suppression
    /// fingerprints, byte accounting, and the offer loop all run over
    /// that prefix, and the link's offered/transferred outcome is folded
    /// back into the state (closing the loop). Feedback reads consume no
    /// RNG, so rounds stay schedulable anywhere before the step's
    /// retrieval exactly like the static plane.
    #[allow(clippy::too_many_arguments)]
    pub fn run_round_with(
        &mut self,
        topo: &Topology,
        nodes: &mut [EdgeNode],
        placement: &mut PlacementEngine,
        hot: &HotnessTracker,
        corpus: &Corpus,
        step: usize,
        mut feedback: Option<&mut FeedbackState>,
    ) {
        self.round += 1;
        self.stats.rounds += 1;
        self.next_step = step + self.cfg.interval_steps.max(1);
        let n = nodes.len();
        for s in 0..n {
            let neighbors = topo.neighbors(s);
            if neighbors.is_empty() {
                continue;
            }
            // Sender digest, once per round: hottest `hot_k` residents
            // (ties → older first, then id — deterministic). Under
            // feedback the rank blends in per-chunk hit contribution.
            self.digest.clear();
            for cid in nodes[s].resident_chunks() {
                let h = hot.chunk_hotness(cid, step);
                let score = match feedback.as_deref() {
                    Some(fb) => fb.blended_score(cid, h, step),
                    None => h,
                };
                self.digest.push((cid, placement.version_of(s, cid), score));
            }
            self.digest.sort_by(|a, b| {
                b.2.partial_cmp(&a.2).unwrap().then(a.0.cmp(&b.0))
            });
            self.digest.truncate(self.cfg.hot_k);
            if self.digest.is_empty() {
                self.stats.digests_suppressed += neighbors.len() as u64;
                continue;
            }

            for &r in neighbors {
                debug_assert_ne!(r, s);
                // Per-link advertisement: the budget-long prefix of the
                // ranked digest (the whole digest when feedback is off).
                let budget = match feedback.as_deref() {
                    Some(fb) => {
                        fb.link_budget(s, r, self.cfg.hot_k, step).min(self.digest.len())
                    }
                    None => self.digest.len(),
                };
                let link_digest = &self.digest[..budget.max(1)];
                let fingerprint = link_digest
                    .iter()
                    .fold(0u64, |acc, &(cid, ver, _)| acc ^ entry_fingerprint(cid, ver));
                if self.seen[r][s] == fingerprint {
                    self.stats.digests_suppressed += 1;
                    continue;
                }
                self.seen[r][s] = fingerprint;
                self.stats.digests_sent += 1;
                self.stats.digest_bytes += DIGEST_ENTRY_BYTES * link_digest.len();

                let pin_until = self.round + self.cfg.pin_rounds;
                let round = self.round;
                let mut offered = 0u64;
                let mut transferred = 0u64;
                let mut bytes = 0usize;
                for &(cid, ver, _) in link_digest {
                    offered += 1;
                    let missing = !nodes[r].contains(cid);
                    if missing || placement.version_of(r, cid) < ver {
                        transferred += 1;
                        bytes += corpus.chunks[cid].text.len();
                        placement.admit(
                            &mut nodes[r],
                            corpus,
                            hot,
                            step,
                            cid,
                            ver,
                            Some(pin_until),
                            round,
                        );
                    }
                }
                self.stats.chunks_offered += offered;
                self.stats.chunks_transferred += transferred;
                self.stats.bytes_transferred += bytes;
                if let Some(fb) = feedback.as_deref_mut() {
                    fb.observe_link(s, r, offered, transferred, step);
                }
            }
        }
        placement.expire_pins(self.round);
    }

    /// Ship coarse-centroid digests along the same neighbor links,
    /// version-suppressed like the chunk digests: a receiver that
    /// already holds the sender's current centroid version gets
    /// nothing. Untrained stores (version 0) never advertise. Runs
    /// piggybacked on each gossip round when the ANN plane is enabled.
    pub fn sync_centroids(
        &mut self,
        topo: &Topology,
        nodes: &[EdgeNode],
        known: &mut [Vec<Option<crate::edge::semantic::CentroidDigest>>],
    ) {
        for (s, node) in nodes.iter().enumerate() {
            let Some(sem) = node.semantic.as_ref() else {
                continue;
            };
            let version = sem.centroid_version();
            if version == 0 {
                continue;
            }
            for &r in topo.neighbors(s) {
                if known[r][s].as_ref().map(|d| d.version) == Some(version) {
                    self.stats.centroid_digests_suppressed += 1;
                    continue;
                }
                let digest = sem.digest().expect("trained store has a digest");
                self.stats.centroid_digests_sent += 1;
                self.stats.centroid_bytes += digest.wire_bytes();
                known[r][s] = Some(digest);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::Profile;
    use crate::netsim::{NetSim, NetSpec};
    use crate::cluster::placement::PlacementPolicy;

    fn world(
        n: usize,
        cap: usize,
    ) -> (Corpus, Vec<EdgeNode>, Topology, PlacementEngine, HotnessTracker) {
        let c = Corpus::generate(Profile::Wiki, 4);
        let nodes: Vec<EdgeNode> = (0..n).map(|i| EdgeNode::new(i, cap)).collect();
        let topo = Topology::build(&NetSim::new(n, NetSpec::default(), 5), n - 1);
        let eng = PlacementEngine::new(n, PlacementPolicy::HotnessLru);
        let hot = HotnessTracker::new(c.spec.topics, 100.0);
        (c, nodes, topo, eng, hot)
    }

    #[test]
    fn hot_chunks_spread_to_neighbors() {
        let (c, mut nodes, topo, mut eng, mut hot) = world(3, 200);
        // Edge 0 holds chunks 0..20; 5 and 7 are hot.
        nodes[0].apply_update(&c, &(0..20).collect::<Vec<_>>());
        for _ in 0..5 {
            hot.record_chunk(5, 10);
            hot.record_chunk(7, 10);
        }
        let mut g = Gossiper::new(3, GossipConfig { hot_k: 4, ..Default::default() });
        g.run_round(&topo, &mut nodes, &mut eng, &hot, &c, 25);
        assert!(nodes[1].contains(5) && nodes[1].contains(7));
        assert!(nodes[2].contains(5));
        assert!(g.stats.bytes_transferred > 0);
        assert!(g.stats.chunks_transferred >= 4);
    }

    #[test]
    fn quiet_stores_suppress_digests() {
        let (c, mut nodes, topo, mut eng, hot) = world(3, 100);
        nodes[0].apply_update(&c, &[1, 2, 3]);
        let mut g = Gossiper::new(3, GossipConfig::default());
        g.run_round(&topo, &mut nodes, &mut eng, &hot, &c, 25);
        let sent_first = g.stats.digests_sent;
        assert!(sent_first > 0);
        // Nothing changed anywhere after round 1 → digests fingerprint
        // identically and later rounds are pure suppression (receivers
        // re-advertised once within round 1 as their stores filled).
        g.run_round(&topo, &mut nodes, &mut eng, &hot, &c, 50);
        let sent_second = g.stats.digests_sent;
        g.run_round(&topo, &mut nodes, &mut eng, &hot, &c, 75);
        assert_eq!(
            g.stats.digests_sent, sent_second,
            "steady state keeps gossiping"
        );
        assert!(g.stats.digests_suppressed > 0);
    }

    #[test]
    fn stale_replicas_refresh_via_gossip() {
        let (c, mut nodes, topo, mut eng, hot) = world(2, 100);
        let mut auth = VersionAuthority::new(c.chunks.len());
        // Both edges hold chunk 4; edge 0 then receives a republication.
        nodes[0].apply_update(&c, &[4]);
        nodes[1].apply_update(&c, &[4]);
        auth.publish(&[4]);
        auth.publish(&[4]);
        eng.apply_update(&mut nodes[0], &c, &hot, 0, &[4], &auth, None, 0);
        assert_eq!(eng.staleness(&nodes[1], &auth), (1, 1), "edge 1 stale");
        let mut g = Gossiper::new(2, GossipConfig::default());
        g.run_round(&topo, &mut nodes, &mut eng, &hot, &c, 25);
        assert_eq!(eng.staleness(&nodes[1], &auth), (0, 1), "gossip refreshed");
        assert_eq!(eng.version_of(1, 4), 2);
    }

    #[test]
    fn demand_shift_readvertises_without_store_mutation() {
        let (c, mut nodes, topo, mut eng, mut hot) = world(2, 200);
        nodes[0].apply_update(&c, &(0..10).collect::<Vec<_>>());
        let mut g = Gossiper::new(2, GossipConfig { hot_k: 2, ..Default::default() });
        g.run_round(&topo, &mut nodes, &mut eng, &hot, &c, 25);
        // hot_k = 2 and everything cold → only ids 0 and 1 replicated.
        assert!(nodes[1].contains(0) && nodes[1].contains(1));
        assert!(!nodes[1].contains(7));
        let sent_first = g.stats.digests_sent;
        // No store mutates, but demand shifts to chunk 7: the digest
        // fingerprint changes, so the next round re-advertises instead
        // of suppressing forever.
        for _ in 0..4 {
            hot.record_chunk(7, 30);
        }
        g.run_round(&topo, &mut nodes, &mut eng, &hot, &c, 50);
        assert!(nodes[1].contains(7), "hot chunk never replicated");
        assert!(g.stats.digests_sent > sent_first);
    }

    #[test]
    fn centroid_sync_versions_and_suppresses() {
        use crate::config::AnnConfig;
        let (c, mut nodes, topo, _eng, _hot) = world(3, 200);
        nodes[0].apply_update(&c, &(0..80).collect::<Vec<_>>());
        let ann = AnnConfig {
            exact_below: 16,
            nlist: 4,
            ..AnnConfig::default()
        };
        // Only edge 0 is trained; edge 1 has a tiny (untrained) store.
        nodes[0].enable_semantic(&c, &ann, 1);
        nodes[1].apply_update(&c, &[0, 1]);
        nodes[1].enable_semantic(&c, &ann, 2);
        let mut g = Gossiper::new(3, GossipConfig::default());
        let mut known: Vec<Vec<Option<crate::edge::semantic::CentroidDigest>>> =
            vec![vec![None; 3]; 3];
        g.sync_centroids(&topo, &nodes, &mut known);
        // Edge 0's digest reached both neighbors; untrained edges sent
        // nothing.
        assert_eq!(g.stats.centroid_digests_sent, 2);
        assert!(g.stats.centroid_bytes > 0);
        assert!(known[1][0].is_some() && known[2][0].is_some());
        assert!(known[0][1].is_none(), "untrained store advertised");
        let ver = known[1][0].as_ref().unwrap().version;
        assert!(ver >= 1);
        // Second sync with unchanged centroids is pure suppression.
        g.sync_centroids(&topo, &nodes, &mut known);
        assert_eq!(g.stats.centroid_digests_sent, 2);
        assert_eq!(g.stats.centroid_digests_suppressed, 2);
        // A version bump (fresh content re-centers lists) re-ships.
        nodes[0].apply_update(&c, &(80..140).collect::<Vec<_>>());
        if nodes[0].semantic.as_ref().unwrap().centroid_version() > ver {
            g.sync_centroids(&topo, &nodes, &mut known);
            assert!(g.stats.centroid_digests_sent > 2);
            assert_eq!(known[1][0].as_ref().unwrap().version,
                nodes[0].semantic.as_ref().unwrap().centroid_version());
        }
    }

    #[test]
    fn rounds_fire_on_cadence() {
        let g = Gossiper::new(2, GossipConfig { interval_steps: 10, ..Default::default() });
        assert!(!g.due(0));
        assert!(!g.due(9));
        assert!(g.due(10));
    }
}
