//! Minimal offline subset of the `anyhow` crate.
//!
//! The offline image has no crates.io access, so this vendored shim
//! provides exactly the surface the workspace uses: [`Error`],
//! [`Result`], the [`anyhow!`]/[`bail!`] macros, and the [`Context`]
//! extension trait for `Result`/`Option`. Errors are a message chain
//! (context frames prepended), which matches how the codebase consumes
//! them (`{e}` / `{e:?}` formatting, never downcasting).

use std::fmt;

/// An error: a human-readable message with optional context frames.
pub struct Error {
    /// Context frames, most recent first, ending with the root message.
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Prepend a context frame (what `Context::context` does).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

/// Any std error converts into `Error` (enables `?` on io results etc.).
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `anyhow`-style result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from format args.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from format args.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Context extension for results and options.
pub trait Context<T> {
    /// Wrap the error with a fixed context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    /// Wrap the error with a lazily built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::msg(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "no such file")
    }

    #[test]
    fn display_chains_context() {
        let e: Error = Err::<(), _>(io_err())
            .with_context(|| "reading manifest.json".to_string())
            .unwrap_err();
        let s = format!("{e}");
        assert!(s.starts_with("reading manifest.json: "), "{s}");
        assert!(s.contains("no such file"), "{s}");
    }

    #[test]
    fn macros_format() {
        let e = anyhow!("bad value {}", 42);
        assert_eq!(format!("{e}"), "bad value 42");
        fn f() -> Result<()> {
            bail!("boom {}", "now")
        }
        assert_eq!(format!("{}", f().unwrap_err()), "boom now");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn g() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(format!("{}", g().unwrap_err()).contains("no such file"));
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing field").unwrap_err();
        assert_eq!(format!("{e}"), "missing field");
    }
}
