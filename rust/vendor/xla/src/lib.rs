//! Offline stub of the `xla` PJRT bindings.
//!
//! The offline image cannot build the real XLA/PJRT FFI crate, so this
//! stub mirrors exactly the API surface `eaco_rag::runtime` consumes and
//! fails at **runtime** (never at compile time) with an actionable
//! message. Every PJRT-dependent test and bench first gates on
//! `artifacts/manifest.json` being present, so the stub is never reached
//! under `cargo test -q` in a fresh checkout; a build against the real
//! bindings swaps this path dependency for the real crate without any
//! source change in `eaco_rag`.

/// Stub error: carries the unavailability notice.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: PJRT backend not available in this offline build \
         (vendored xla stub); link the real `xla` crate to serve models"
    ))
}

/// Element types transferable to/from device buffers.
pub trait NativeType: Copy + 'static {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u8 {}

/// Parsed HLO module (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// XLA computation handle (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device-resident buffer (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Host literal (stub).
pub struct Literal;

impl Literal {
    pub fn to_tuple1(self) -> Result<Literal> {
        Err(unavailable("Literal::to_tuple1"))
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }
}

/// Compiled executable (stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute_b"))
    }
}

/// PJRT client (stub): construction fails with the notice.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _shape: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(unavailable("PjRtClient::buffer_from_host_buffer"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_fails_with_notice() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(format!("{err}").contains("offline"), "{err}");
    }

    #[test]
    fn hlo_parse_fails_with_notice() {
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
