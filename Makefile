# EACO-RAG workspace drivers.
#
# The Rust workspace lives under rust/ (vendored offline deps under
# rust/vendor/); the JAX/Pallas AOT compiler under python/compile/.

CARGO ?= cargo

# Perf-trajectory output name; bump per PR (BENCH_OUT=BENCH_PR<N>.json).
BENCH_OUT ?= BENCH_PR10.json

.PHONY: build test ci bench-json bench-smoke chaos-trend artifacts

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

# Everything CI runs (see .github/workflows/ci.yml). PJRT-gated tests
# skip themselves when artifacts/ is absent.
ci:
	$(CARGO) fmt --check
	$(CARGO) clippy --all-targets -- -D warnings
	$(CARGO) build --release
	$(CARGO) test -q

# Machine-readable perf trajectory: runs the hot-path bench in release
# mode and writes $(BENCH_OUT) at the repo root — an array of
# {"bench", "iters", "mean_ns", "p50_ns", "p99_ns", "min_ns",
#  "throughput_per_s"[, "gbps"]} records (see util::stats::BenchResult
# ::to_json). Compare against the previous BENCH_PR<N-1>.json.
# EACO_BENCH_FULL=1 adds the slow scenarios (10k-observation GP window).
bench-json:
	EACO_BENCH_OUT=$(abspath $(BENCH_OUT)) $(CARGO) bench --bench perf_hotpath

# CI smoke for the bench harness: tiny workloads, one iteration per
# family, output to target/ (never overwrites a committed trajectory).
# Proves the harness builds and runs; the numbers mean nothing.
bench-smoke:
	EACO_BENCH_SMOKE=1 EACO_BENCH_OUT=$(abspath target/bench_smoke.json) \
		$(CARGO) bench --bench perf_hotpath

# Cross-run SLA trend gate: run the default chaos scenario twice,
# appending both reports to a fresh trend file in target/. The runs are
# deterministic, so the second entry must match the first and the diff
# (chaos::trend::regression) must report no SLA regression — this
# exercises the exact machinery CI uses to compare a PR's chaos run
# against the previous one. Exits non-zero on any regression.
chaos-trend:
	rm -f target/chaos_trend.json
	$(CARGO) run --release -q -p eaco-rag -- chaos --steps 200 \
		--sla-availability 0.5 --append-trend target/chaos_trend.json
	$(CARGO) run --release -q -p eaco-rag -- chaos --steps 200 \
		--sla-availability 0.5 --append-trend target/chaos_trend.json

# AOT-compile the L2 model artifacts into rust/artifacts/ (requires the
# python-side JAX toolchain; PJRT tests/benches skip without this).
artifacts:
	cd python && python3 -m compile.aot --out-dir ../rust/artifacts
